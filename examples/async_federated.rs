//! Algorithm 2 (asynchronous Qsparse-local-SGD) on the *threaded* runtime:
//! real worker threads, encoded wire messages, aggregate-on-arrival master —
//! the federated-learning flavor of the paper (§4), with pathological
//! label-skew sharding for good measure.
//!
//!     cargo run --release --example async_federated

use qsparse::compress::parse_spec;
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::data::{gaussian_clusters_split, Sharding};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::topology::RandomGaps;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let (workers, h, steps, n) = (15usize, 8usize, 800usize, 6000usize);
    let (train, test) = gaussian_clusters_split(n, n / 4, 784, 10, 0.12, 1.0, 99);

    // Per-worker random sync gaps ~ U[1, H] (paper §5.2.3).
    let schedule = RandomGaps::generate(workers, h, steps, 4242);
    println!("async schedules (gap(I_T^r) ≤ {h}):");
    for r in 0..4 {
        let pts: Vec<u32> = schedule.points(r).iter().take(8).copied().collect();
        println!("  worker {r}: first syncs at t = {pts:?}…  (measured gap {})",
            schedule.measured_gap(r));
    }
    println!("  … {} more workers\n", workers - 4);

    let lam = 1.0 / n as f64;
    let factory = move || -> Box<dyn GradModel> {
        Box::new(SoftmaxRegression::new(784, 10, lam))
    };

    for (label, spec_str) in [
        ("async vanilla SGD", "identity"),
        ("async TopK-SGD", "topk:k=40"),
        ("async Qsparse (SignTopK)", "signtopk:k=40,m=1"),
        ("async Qsparse (QTopK 4-bit)", "qtopk:k=40,bits=4,scaled"),
    ] {
        let mut cfg = CoordinatorConfig::new(
            Arc::from(parse_spec(spec_str)?),
            Arc::new(schedule.clone()),
        );
        cfg.workers = workers;
        cfg.batch = 8;
        cfg.steps = steps;
        cfg.lr = LrSchedule::InvTime { xi: 1900.0, a: 1570.0 };
        cfg.sharding = Sharding::LabelSkew; // each worker hoards ~1 class
        cfg.seed = 7;
        let hist = run_threaded(
            &cfg,
            factory,
            Arc::new(train.clone()),
            Some(Arc::new(test.clone())),
        )?;
        let p = hist.points.last().unwrap();
        println!(
            "{label:<30} loss={:.4}  test_err={:.2}%  uplink={:.2} Mbit",
            p.train_loss,
            100.0 * p.test_err,
            p.bits_up as f64 / 1e6
        );
    }
    Ok(())
}
