//! End-to-end driver: distributed Qsparse-local-SGD training of a
//! decoder-only transformer LM through the full three-layer stack.
//!
//!   L1  Pallas kernels (tiled matmul+bias, fused softmax-xent) …
//!   L2  … inside the JAX transformer (python/compile/model.py), AOT-lowered
//!       once to artifacts/lm.grad.hlo.txt …
//!   L3  … executed from this rust binary via PJRT, wrapped in the paper's
//!       algorithm: R workers, local steps, Top_k + quantization with error
//!       feedback, bit-accounted uplink.
//!
//!     make artifacts
//!     cargo run --release --example train_transformer [steps] [compressor]
//!
//! Trains on a synthetic bigram corpus and logs the loss curve; the run
//! recorded in EXPERIMENTS.md §End-to-end uses the default 300 steps.

use qsparse::compress::parse_spec;
use qsparse::data::{synthetic_corpus, Dataset, Sharding};
use qsparse::engine::{run_from, TrainSpec};
use qsparse::optim::LrSchedule;
use qsparse::runtime::PjrtRuntime;
use qsparse::topology::FixedPeriod;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().map_or(Ok(300), |s| s.parse())?;
    let comp_spec = args.get(1).cloned().unwrap_or_else(|| "qtopk:k=4700,bits=4,scaled".into());

    let rt = PjrtRuntime::open("artifacts").map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` first to build the AOT models")
    })?;
    let model = rt.load_model("lm")?;
    let entry = model.entry.clone();
    let seq = entry.seq.expect("lm artifact");
    println!(
        "transformer LM: d={} params, vocab={}, seq={}, batch={} (HLO: {})",
        entry.d, entry.classes, seq, entry.batch, entry.grad_file
    );

    // Synthetic corpus → (b, seq+1) windows encoded as f32 rows.
    let tokens = synthetic_corpus(400_000, entry.classes, 11);
    let window = seq + 1;
    let n_rows = (tokens.len() - window) / seq;
    let mut features = Vec::with_capacity(n_rows * window);
    for i in 0..n_rows {
        let start = i * seq;
        features.extend(tokens[start..start + window].iter().map(|&t| t as f32));
    }
    let train = Dataset {
        features,
        labels: vec![0; n_rows], // targets are derived inside the artifact
        n: n_rows,
        dim: window,
        classes: entry.classes,
    };
    println!("corpus: {} tokens → {} training windows\n", tokens.len(), train.n);

    let init = rt
        .load_init("lm")?
        .ok_or_else(|| anyhow::anyhow!("lm.init.f32 missing — re-run make artifacts"))?;

    let compressor = parse_spec(&comp_spec)?;
    let schedule = FixedPeriod::new(4);
    let spec = TrainSpec {
        model: &model,
        train: &train,
        test: None,
        workers: 4,
        batch: entry.batch,
        steps,
        lr: LrSchedule::Const { eta: 0.25 },
        momentum: 0.9,
        compressor: compressor.as_ref(),
        down_compressor: &qsparse::compress::IDENTITY,
        schedule: &schedule,
        participation: &qsparse::topology::FULL_PARTICIPATION,
        agg_scale: qsparse::protocol::AggScale::Workers,
        server_opt: qsparse::optim::ServerOptSpec::Avg,
        codec: qsparse::compress::Codec::Raw,
        sharding: Sharding::Iid,
        seed: 20190527,
        eval_every: 20,
        eval_rows: entry.batch * 2,
        threads: 1,
    };
    println!(
        "Qsparse-local-SGD: R=4 workers, H=4 local steps, compressor={}, T={steps}",
        compressor.name()
    );
    println!("{:>6} {:>12} {:>14} {:>12}", "step", "train_loss", "uplink_Mbit", "mem‖m‖²");
    #[allow(clippy::disallowed_methods)] // progress display only
    let t0 = std::time::Instant::now();
    let hist = run_from(&spec, init);
    for p in &hist.points {
        println!(
            "{:>6} {:>12.4} {:>14.3} {:>12.2e}",
            p.step,
            p.train_loss,
            p.bits_up as f64 / 1e6,
            p.mem_norm_sq
        );
    }
    let p0 = hist.points.first().unwrap();
    let p1 = hist.points.last().unwrap();
    let dense_bits = 32.0 * entry.d as f64 * (steps as f64 / 4.0) * 4.0; // per-worker dense H=1
    println!(
        "\nloss {:.3} → {:.3} in {steps} steps ({:.1}s); uplink {:.1} Mbit vs {:.1} Mbit dense ({}x saving)",
        p0.train_loss,
        p1.train_loss,
        t0.elapsed().as_secs_f64(),
        p1.bits_up as f64 / 1e6,
        dense_bits / 1e6,
        (dense_bits / p1.bits_up as f64) as u64
    );
    anyhow::ensure!(p1.train_loss < p0.train_loss, "loss did not decrease");
    Ok(())
}
