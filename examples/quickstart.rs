//! Quickstart: Qsparse-local-SGD in ~40 lines.
//!
//! Trains the paper's convex workload (ℓ2-regularized softmax regression,
//! d = 7850) with R = 15 workers, comparing vanilla distributed SGD against
//! Qsparse-local-SGD (SignTop_k compression + H = 8 local steps + error
//! feedback). Pure-rust substrate — no artifacts needed.
//!
//!     cargo run --release --example quickstart

use qsparse::compress::{Identity, SignTopK};
use qsparse::data::gaussian_clusters_split;
use qsparse::engine::{run, TrainSpec};
use qsparse::grad::SoftmaxRegression;
use qsparse::optim::LrSchedule;
use qsparse::topology::FixedPeriod;

fn main() {
    let n = 6000;
    let (train, test) = gaussian_clusters_split(n, n / 4, 784, 10, 0.12, 1.0, 7);
    let model = SoftmaxRegression::new(784, 10, 1.0 / n as f64);

    let mut run_one = |name: &str, comp: &dyn qsparse::Compressor, h: usize| {
        let schedule = FixedPeriod::new(h);
        let mut spec = TrainSpec::new(&model, &train, comp, &schedule);
        spec.test = Some(&test);
        spec.workers = 15;
        spec.batch = 8;
        spec.steps = 1000;
        spec.lr = LrSchedule::InvTime { xi: 1900.0, a: 1570.0 };
        let hist = run(&spec);
        let p = hist.points.last().unwrap();
        println!(
            "{name:<30} loss={:.4}  test_err={:.2}%  uplink={:.2} Mbit",
            p.train_loss,
            100.0 * p.test_err,
            p.bits_up as f64 / 1e6
        );
        p.bits_up
    };

    println!("Qsparse-local-SGD quickstart (R=15, b=8, d=7850, T=1000)\n");
    let dense_bits = run_one("vanilla distributed SGD", &Identity, 1);
    let qsparse_bits = run_one("Qsparse-local (SignTopK, H=8)", &SignTopK::new(40, 1), 8);
    println!(
        "\ncommunication saving: {:.0}x fewer uplink bits at matched quality",
        dense_bits as f64 / qsparse_bits as f64
    );
}
