//! The paper's convex experiment (§5.2) through the FULL three-layer stack:
//! the gradient/loss of the softmax model is computed by the AOT-compiled
//! JAX artifact (with its Pallas linear + fused softmax-xent kernels) via
//! PJRT — python never runs here. The rust coordinator supplies workers,
//! compression, error feedback and local iterations.
//!
//!     make artifacts           # once
//!     cargo run --release --example convex_mnist
//!
//! Reproduces the fig4/fig6 story: composed operators converge like vanilla
//! SGD while sending orders of magnitude fewer bits; local steps (H = 8)
//! multiply the savings.

use qsparse::compress::parse_spec;
use qsparse::data::{gaussian_clusters_split, Sharding};
use qsparse::engine::{run, TrainSpec};
use qsparse::optim::LrSchedule;
use qsparse::runtime::PjrtRuntime;
use qsparse::topology::FixedPeriod;

fn main() -> anyhow::Result<()> {
    let rt = PjrtRuntime::open("artifacts").map_err(|e| {
        anyhow::anyhow!("{e}\nhint: run `make artifacts` first to build the AOT models")
    })?;
    let model = rt.load_model("softmax")?;
    let entry = model.entry.clone();
    println!(
        "loaded pjrt:softmax  d={} batch={} (HLO from python/compile/aot.py)\n",
        entry.d, entry.batch
    );

    // MNIST-geometry data: 784 features, 10 classes, R = 15 workers, b = 8.
    let n = 6000;
    let (train, test) =
        gaussian_clusters_split(n, n / 4, entry.feat, entry.classes, 0.12, 1.0, 20190527);

    let series: Vec<(&str, String, usize)> = vec![
        ("vanilla SGD", "identity".into(), 1),
        ("TopK-SGD (k=40)", "topk:k=40".into(), 1),
        ("QTopK 4-bit", "qtopk:k=40,bits=4,scaled".into(), 1),
        ("SignTopK", "signtopk:k=40,m=1".into(), 1),
        ("Qsparse-local (SignTopK, H=8)", "signtopk:k=40,m=1".into(), 8),
    ];

    println!(
        "{:<32} {:>9} {:>10} {:>12} {:>9}",
        "series", "loss", "test_err", "Mbits_up", "saving"
    );
    let steps = 600;
    let mut baseline = None;
    for (label, comp_spec, h) in series {
        let comp = parse_spec(&comp_spec)?;
        let schedule = FixedPeriod::new(h);
        let mut spec = TrainSpec::new(&model, &train, comp.as_ref(), &schedule);
        spec.test = Some(&test);
        spec.workers = 15;
        spec.batch = entry.batch;
        spec.steps = steps;
        spec.sharding = Sharding::Iid;
        spec.eval_every = 100;
        spec.eval_rows = 128;
        spec.lr = LrSchedule::InvTime { xi: 1900.0, a: 1570.0 };
        let hist = run(&spec);
        let p = hist.points.last().unwrap();
        let saving = baseline
            .map(|b: u64| format!("{:.0}x", b as f64 / p.bits_up as f64))
            .unwrap_or_else(|| "1x".to_string());
        baseline.get_or_insert(p.bits_up);
        println!(
            "{label:<32} {:>9.4} {:>9.2}% {:>12.2} {:>9}",
            p.train_loss,
            100.0 * p.test_err,
            p.bits_up as f64 / 1e6,
            saving
        );
    }
    Ok(())
}
