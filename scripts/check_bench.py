#!/usr/bin/env python3
"""CI gate for BENCH_train_step.json (the machine-readable perf trajectory).

The bench binary (`cargo bench --bench train_step -- --quick --json`) writes
one entry per probe. A probe that silently disappears — a renamed case, a
skipped section — used to pass CI while the trajectory quietly went blind.

The required probe set is NOT hardcoded here: it is read from
`scripts/bench_probes.txt`, the shared manifest that `tools/repo-lint`
cross-checks against the bench source in both directions. This script owns
the runtime half of the contract and fails the job when

  1. any required manifest probe is missing from the JSON (exact keys, plus
     `*`-prefix keys for names that embed the runner's core count — the
     prefixes are only enforced on multi-core runners, since the bench only
     emits them there; `?`-optional manifest lines are never required), or
  2. any steady-state allocation probe reports a nonzero count, or
  3. any `codec/rans-vs-raw-bits/...` ratio exceeds its cap: 1.0 for every
     probe (the per-message fallback must make the entropy-coded container
     free to decline), and a tighter savings floor on the deterministic
     TopK/QTopK gradient probes, or
  4. any `simd/speedup-vs-scalar/...` ratio exceeds 1.0 on a multi-core
     runner: the dispatched SIMD kernel must never lose to its scalar twin
     (the bench compares best-of-N samples, and emits exactly 1.0 when
     detection already lands on scalar, so this is not a flaky gate; on
     single-core runners timing is preemption-noisy, so it is
     trajectory-only there).

Zero-allocation rule: every `alloc/...` probe is a steady-state allocation
count and must be exactly 0, *except* the parallel-engine probe
(`threads=N` for N > 1), whose residual is mpsc channel transport by
design — that one is trajectory-only. Concretely: an `alloc/` key must be
zero when it has no `threads=` parameter or when it says `threads=1`.
(The bench binary asserts the same invariants in-process; this script is
the belt to that suspender — it still bites if someone deletes the probe
or its assert.)

Usage: scripts/check_bench.py [path-to-BENCH_train_step.json]
"""

import json
import os
import sys

MANIFEST = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "bench_probes.txt")

# rANS wire-bit ratio caps. Every codec probe must be ≤ 1.0 — the encoder
# falls back to the raw container per message whenever entropy coding would
# not strictly win, so a ratio above 1.0 means that fallback broke. The
# sparse-gradient probes are deterministic (fixed data seed, fixed
# operator), so their savings are hard numbers, not flaky measurements:
# gap/level coding must deliver ≥ 20% on TopK and QTopK uplinks, and the
# clustered-support probe is the regime the coder targets.
RANS_RATIO_CAP = {
    "codec/rans-vs-raw-bits/topk:k=400(d=7850)": 0.80,
    "codec/rans-vs-raw-bits/qtopk:k=400,bits=4(d=7850)": 0.80,
    "codec/rans-vs-raw-bits/skewed-gaps(d=1M)": 0.80,
}

# SIMD auto-vs-scalar time ratio (auto_min / scalar_min): the vectorized
# kernels must be no slower than the portable reference. Enforced only on
# multi-core runners, where the bench's best-of-N comparison is stable.
SIMD_RATIO_CAP = 1.0


def load_manifest(path):
    """Parse bench_probes.txt into (required_exact, required_prefix) lists.

    Grammar (mirrored by tools/repo-lint): plain line = required exact key;
    trailing `*` = required prefix; leading `?` = documented-but-optional
    (skipped here entirely).
    """
    exact, prefixes = [], []
    with open(path) as f:
        for raw in f:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            if line.startswith("?"):
                continue  # optional: documented, never required
            if line.endswith("*"):
                prefixes.append(line[:-1])
            else:
                exact.append(line)
    return exact, prefixes


def alloc_must_be_zero(key: str) -> bool:
    if not key.startswith("alloc/"):
        return False
    return "threads=" not in key or "threads=1)" in key


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_train_step.json"
    try:
        required_exact, required_prefix = load_manifest(MANIFEST)
    except OSError as e:
        print(f"FAIL: cannot read probe manifest {MANIFEST}: {e}")
        return 1
    # Core-count-embedding probes only exist on multi-core machines; the
    # checker runs on the same runner that ran the bench in CI.
    multicore = (os.cpu_count() or 1) > 1
    if not multicore:
        required_prefix = []
    try:
        with open(path) as f:
            entries = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot read {path}: {e}")
        return 1

    failures = []
    # The committed seed baseline carries a "_note" provenance marker (its
    # numbers are hand-estimated, not measured). The bench's own output
    # never writes that key, so its presence means the bench did not
    # regenerate the file this run — refuse to validate estimates.
    if any(k.startswith("_") for k in entries):
        failures.append(
            "file carries a seed/provenance marker (_*) — it is the committed "
            "estimate, not this run's bench output; regenerate with "
            "`cargo bench --bench train_step -- --quick --json`"
        )
    for key in required_exact:
        if key not in entries:
            failures.append(f"missing probe: {key}")
    for prefix in required_prefix:
        if not any(k.startswith(prefix) for k in entries):
            failures.append(f"missing probe with prefix: {prefix}")
    for key, entry in sorted(entries.items()):
        if key.startswith("_"):  # provenance/meta keys, not probes
            continue
        mean = entry.get("mean") if isinstance(entry, dict) else None
        if alloc_must_be_zero(key) and mean != 0:
            failures.append(f"nonzero steady-state alloc count: {key} = {mean}")
        if key.startswith("codec/rans-vs-raw-bits/"):
            cap = RANS_RATIO_CAP.get(key, 1.0)
            if mean is None or mean > cap:
                failures.append(
                    f"rANS wire-bit ratio above cap: {key} = {mean} (cap {cap})"
                )
        if key.startswith("simd/speedup-vs-scalar/") and multicore:
            if mean is None or mean > SIMD_RATIO_CAP:
                failures.append(
                    f"SIMD kernel slower than scalar twin: {key} = {mean} "
                    f"(cap {SIMD_RATIO_CAP})"
                )

    if failures:
        print(f"FAIL: {path} ({len(entries)} entries)")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    zeros = sum(1 for k in entries if alloc_must_be_zero(k))
    print(
        f"OK: {path} has all {len(required_exact)} exact + "
        f"{len(required_prefix)} prefixed probes from "
        f"{os.path.basename(MANIFEST)}; {zeros} alloc probes at 0"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
