#!/usr/bin/env python3
"""CI gate for BENCH_train_step.json (the machine-readable perf trajectory).

The bench binary (`cargo bench --bench train_step -- --quick --json`) writes
one entry per probe. A probe that silently disappears — a renamed case, a
skipped section — used to pass CI while the trajectory quietly went blind.
This script fails the job when

  1. any expected probe key is missing (exact names for the
     hardware-independent probes, prefixes for the ones whose names embed
     the runner's core count), or
  2. any steady-state allocation probe reports a nonzero count, or
  3. any `codec/rans-vs-raw-bits/...` ratio exceeds its cap: 1.0 for every
     probe (the per-message fallback must make the entropy-coded container
     free to decline), and a tighter savings floor on the deterministic
     TopK/QTopK gradient probes.

Zero-allocation rule: every `alloc/...` probe is a steady-state allocation
count and must be exactly 0, *except* the parallel-engine probe
(`threads=N` for N > 1), whose residual is mpsc channel transport by
design — that one is trajectory-only. Concretely: an `alloc/` key must be
zero when it has no `threads=` parameter or when it says `threads=1`.
(The bench binary asserts the same invariants in-process; this script is
the belt to that suspender — it still bites if someone deletes the probe
or its assert.)

Usage: scripts/check_bench.py [path-to-BENCH_train_step.json]
"""

import json
import os
import sys

# Probes whose names are hardware-independent: exact match required.
REQUIRED_EXACT = [
    "grad/native-softmax(b=8,d=7850)",
    "grad/native-mlp(b=16,d=17k)",
    "engine/step(R=8,signtopk,H=1)",
    "alloc/engine-steady-per-step(R=8,signtopk,H=1,threads=1)",
    "alloc/engine-steady-per-step(R=8,randk,H=1,threads=1)",
    "broadcast/dense(R=8,d=7850)",
    "broadcast/topk:k=400(R=8,d=7850)",
    "broadcast/qtopk:k=400,bits=4(R=8,d=7850)",
    "aggregate/full(R=8,1/R)(d=7850)",
    "aggregate/fixed(m=2,1/|S|)(d=7850)",
    "master/round-speedup(R=32,threads=8)",
    "alloc/threaded-decode-fold-per-update(R=8,qtopk)",
    "threaded/steady-allocs-per-step(R=4,topk,H=2)",
] + [
    f"master/round(R={r},d=7850,down=topk400,threads={t})"
    for r in (8, 32, 128)
    for t in (1, 2, 8)
] + [
    f"{kind}/{spec}(d=7850)"
    for spec in ("signtopk:k=170,m=1", "topk:k=400", "qtopk:k=400,bits=4",
                 "randk:k=400")
    for kind in ("compress", "compress_into", "encode", "encode_into",
                 "wire_bits", "decode", "decode_into",
                 "encode-rans", "decode-rans", "wire_bits-rans")
] + [
    f"alloc/{kind}-per-call/{spec}"
    for spec in ("signtopk:k=170,m=1", "topk:k=400", "qtopk:k=400,bits=4",
                 "randk:k=400")
    for kind in ("compress_into", "decode_into", "encode-rans", "decode-rans")
] + [
    f"codec/rans-vs-raw-bits/{spec}(d=7850)"
    for spec in ("signtopk:k=170,m=1", "topk:k=400", "qtopk:k=400,bits=4",
                 "randk:k=400")
] + [
    "codec/rans-vs-raw-bits/skewed-gaps(d=1M)",
]

# rANS wire-bit ratio caps. Every codec probe must be ≤ 1.0 — the encoder
# falls back to the raw container per message whenever entropy coding would
# not strictly win, so a ratio above 1.0 means that fallback broke. The
# sparse-gradient probes are deterministic (fixed data seed, fixed
# operator), so their savings are hard numbers, not flaky measurements:
# gap/level coding must deliver ≥ 20% on TopK and QTopK uplinks, and the
# clustered-support probe is the regime the coder targets.
RANS_RATIO_CAP = {
    "codec/rans-vs-raw-bits/topk:k=400(d=7850)": 0.80,
    "codec/rans-vs-raw-bits/qtopk:k=400,bits=4(d=7850)": 0.80,
    "codec/rans-vs-raw-bits/skewed-gaps(d=1M)": 0.80,
}

# Probes whose names embed the runner's core count (threads={pool}), and
# which the bench only emits at all when the machine has >1 core: at least
# one key with each prefix must exist — unless this runner is single-core
# (the checker runs on the same machine that ran the bench in CI).
REQUIRED_PREFIX = (
    [
        "engine/step-par(R=8,signtopk,H=1,threads=",
        "engine/speedup(R=8,threads=",
    ]
    if (os.cpu_count() or 1) > 1
    else []
)


def alloc_must_be_zero(key: str) -> bool:
    if not key.startswith("alloc/"):
        return False
    return "threads=" not in key or "threads=1)" in key


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_train_step.json"
    try:
        with open(path) as f:
            entries = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot read {path}: {e}")
        return 1

    failures = []
    # The committed seed baseline carries a "_note" provenance marker (its
    # numbers are hand-estimated, not measured). The bench's own output
    # never writes that key, so its presence means the bench did not
    # regenerate the file this run — refuse to validate estimates.
    if any(k.startswith("_") for k in entries):
        failures.append(
            "file carries a seed/provenance marker (_*) — it is the committed "
            "estimate, not this run's bench output; regenerate with "
            "`cargo bench --bench train_step -- --quick --json`"
        )
    for key in REQUIRED_EXACT:
        if key not in entries:
            failures.append(f"missing probe: {key}")
    for prefix in REQUIRED_PREFIX:
        if not any(k.startswith(prefix) for k in entries):
            failures.append(f"missing probe with prefix: {prefix}")
    for key, entry in sorted(entries.items()):
        if key.startswith("_"):  # provenance/meta keys, not probes
            continue
        mean = entry.get("mean") if isinstance(entry, dict) else None
        if alloc_must_be_zero(key) and mean != 0:
            failures.append(f"nonzero steady-state alloc count: {key} = {mean}")
        if key.startswith("codec/rans-vs-raw-bits/"):
            cap = RANS_RATIO_CAP.get(key, 1.0)
            if mean is None or mean > cap:
                failures.append(
                    f"rANS wire-bit ratio above cap: {key} = {mean} (cap {cap})"
                )

    if failures:
        print(f"FAIL: {path} ({len(entries)} entries)")
        for f_ in failures:
            print(f"  - {f_}")
        return 1
    zeros = sum(1 for k in entries if alloc_must_be_zero(k))
    print(
        f"OK: {path} has all {len(REQUIRED_EXACT)} exact + "
        f"{len(REQUIRED_PREFIX)} prefixed probes; {zeros} alloc probes at 0"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
