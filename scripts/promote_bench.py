#!/usr/bin/env python3
"""Promote a measured CI bench artifact over the hand-estimated seed baseline.

The committed `BENCH_train_step.json` started life as a SEED BASELINE: its
numbers were hand-estimated (the seeding environment had no Rust toolchain)
and it carries a `_note` provenance marker saying so. `check_bench.py`
refuses to validate any file still carrying a `_*` marker, so the estimate
can never masquerade as a measurement in CI.

This script closes the loop: download the `BENCH_train_step` artifact from a
green CI run (the `build-and-test` job uploads the measured file on every
run), then

    python3 scripts/promote_bench.py path/to/downloaded/BENCH_train_step.json

It validates the measured file with the same gate CI uses (probe manifest
completeness, zero steady-state allocs, rANS ratio caps — see
check_bench.py), stamps it with a `_provenance` record naming the source,
and writes it over the committed baseline. Commit the result. From then on
the committed file is a measurement and the `_note` estimate marker is gone
for good; `_provenance` is informational only and does not trip the
seed-marker refusal (check_bench.py is pointed at the bench's *fresh*
output in CI, never at the committed file).

Usage:
    scripts/promote_bench.py MEASURED_JSON [--run RUN_URL_OR_ID] [--force]

--run    recorded in the `_provenance` stamp (defaults to "unspecified").
--force  skip the check_bench.py validation gate (not recommended).
"""

import argparse
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(HERE)
BASELINE = os.path.join(REPO, "BENCH_train_step.json")
CHECKER = os.path.join(HERE, "check_bench.py")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("measured", help="downloaded CI artifact (measured JSON)")
    ap.add_argument("--run", default="unspecified",
                    help="CI run URL or id to record in _provenance")
    ap.add_argument("--force", action="store_true",
                    help="skip check_bench.py validation (not recommended)")
    args = ap.parse_args()

    try:
        with open(args.measured) as f:
            entries = json.load(f)
    except (OSError, ValueError) as e:
        print(f"FAIL: cannot read {args.measured}: {e}")
        return 1

    markers = [k for k in entries if k.startswith("_")]
    if markers:
        print(f"FAIL: {args.measured} carries marker keys {markers} — that is "
              "a committed estimate/promoted file, not a fresh CI artifact. "
              "Download the artifact the build-and-test job uploaded.")
        return 1

    if not args.force:
        gate = subprocess.run(
            [sys.executable, CHECKER, args.measured], cwd=REPO)
        if gate.returncode != 0:
            print("FAIL: measured file does not pass check_bench.py; "
                  "refusing to promote (override with --force).")
            return 1

    out = {
        "_provenance": {
            "kind": "ci-measurement",
            "source_run": args.run,
            "promoted_by": "scripts/promote_bench.py",
        }
    }
    out.update(entries)
    with open(BASELINE, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"OK: promoted {args.measured} -> {os.path.relpath(BASELINE, REPO)} "
          f"({len(entries)} probes, run={args.run}). Commit the result.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
