//! repo-lint — machine-checks the invariants the library's correctness
//! argument rests on (README "Safety & determinism contracts"):
//!
//! 1. **SAFETY comments** — every `unsafe` keyword in non-test code is
//!    immediately preceded (through a contiguous comment/attribute block) by
//!    a `// SAFETY:` justification or a `/// # Safety` doc section.
//! 2. **Unsafe confinement** — `unsafe` may appear only in the fork-join
//!    core (`engine/parallel.rs`, its reuse in `coordinator/master.rs`),
//!    the SIMD backends (`simd/avx2.rs`, `simd/neon.rs`) and the bench
//!    counting allocator; every other module is covered by an explicit
//!    `#![forbid(unsafe_code)]`.
//! 3. **Determinism** — deterministic-path modules (`protocol`, `compress`,
//!    `engine`, `coordinator`, `topology`, `optim`, `simd`, `sim`,
//!    `faults`) must not touch wall clocks (`Instant`, `SystemTime`) or
//!    RandomState-backed containers (`HashMap`, `HashSet`) outside
//!    `#[cfg(test)]` code.
//! 4. **Panic-free decode** — the wire-facing parsers (`compress/encode.rs`,
//!    `compress/rans.rs`, `util/json.rs`, `protocol/checkpoint.rs`) must
//!    not contain `.unwrap()`, `.expect(`, `panic!`, `unreachable!`,
//!    `todo!` or `unimplemented!` outside tests: corrupt input must surface
//!    as a named error.
//! 5. **Bench-probe drift** — `scripts/bench_probes.txt` (the manifest
//!    `scripts/check_bench.py` enforces in CI) and the probe-name literals
//!    in `benches/train_step.rs` must agree in both directions, so a probe
//!    cannot be renamed or dropped on one side only.
//! 6. **SIMD confinement** — `#[target_feature]` and arch-intrinsic imports
//!    (`core::arch`, `std::arch`) may appear only inside `rust/src/simd/`;
//!    everything else goes through the safe dispatcher entry points, so the
//!    forced-scalar CI job provably covers all non-SIMD code.
//!
//! The scanner is a line-preserving state machine that blanks comments and
//! string contents (so tokens in comments or literals never count as code)
//! while collecting string literals (for rule 5). It is deliberately
//! lexical, not a full parser: simple, fast, and conservative — if it ever
//! misfires on new code, prefer restructuring the code over weakening the
//! rule.
//!
//! Usage: `cargo run -p repo-lint` from anywhere in the workspace.
//! Exit code 1 and one `path:line: [rule] message` per finding.

#![forbid(unsafe_code)]

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Files allowed to contain the `unsafe` keyword (rule 2).
const ALLOW_UNSAFE: &[&str] = &[
    "rust/src/engine/parallel.rs",
    "rust/src/coordinator/master.rs",
    "rust/src/simd/avx2.rs",
    "rust/src/simd/neon.rs",
    "benches/train_step.rs",
];

/// Module roots that cannot carry `#![forbid(unsafe_code)]` because a child
/// module is on the allow-list (the attribute would propagate into it).
/// Rule 2 still bans `unsafe` in these files themselves.
const FORBID_EXEMPT: &[&str] = &[
    "rust/src/lib.rs",
    "rust/src/engine/mod.rs",
    "rust/src/coordinator/mod.rs",
    "rust/src/simd/mod.rs",
];

/// Deterministic-path directory prefixes (rule 3).
const DET_DIRS: &[&str] = &[
    "rust/src/protocol",
    "rust/src/compress",
    "rust/src/engine",
    "rust/src/coordinator",
    "rust/src/topology",
    "rust/src/optim",
    "rust/src/simd",
    "rust/src/sim",
    "rust/src/faults",
];

/// Identifiers banned in deterministic paths (matched as whole words in
/// code, so comments and `BTreeMap` don't trip it).
const DET_TOKENS: &[&str] = &["HashMap", "HashSet", "Instant", "SystemTime"];

/// Wire-facing parser files where panicking constructs are banned (rule 4).
const NO_PANIC_FILES: &[&str] = &[
    "rust/src/compress/encode.rs",
    "rust/src/compress/rans.rs",
    "rust/src/util/json.rs",
    "rust/src/protocol/checkpoint.rs",
];

/// Panicking constructs (substring match on blanked code, so `unwrap_or`
/// and a `fn expect_byte` helper don't count).
const PANIC_PATS: &[&str] =
    &[".unwrap()", ".expect(", "panic!", "unreachable!", "todo!", "unimplemented!"];

/// Files allowed to use `#[target_feature]` / arch intrinsics (rule 6).
const SIMD_ALLOW: &[&str] = &[
    "rust/src/simd/mod.rs",
    "rust/src/simd/avx2.rs",
    "rust/src/simd/neon.rs",
];

/// Tokens confined to the SIMD module (substring match on blanked code:
/// `#[target_feature(...)]`, `use core::arch::...`, and the
/// `std::arch::is_*_feature_detected!` macros all contain one).
const SIMD_TOKENS: &[&str] = &["target_feature", "core::arch", "std::arch"];

// ---------------------------------------------------------------------------
// Lexical scanner
// ---------------------------------------------------------------------------

/// Source with comments and string contents blanked to spaces (newlines
/// kept, so line numbers survive), plus the collected string literals.
struct Stripped {
    code: String,
    literals: Vec<String>,
}

fn strip_code(src: &str) -> Stripped {
    #[derive(Clone, Copy)]
    enum Mode {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(usize),
        Char,
    }
    let b = src.as_bytes();
    let mut out = String::with_capacity(src.len());
    let mut literals = Vec::new();
    let mut cur = String::new();
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match mode {
            Mode::Code => {
                if c == b'/' && b.get(i + 1) == Some(&b'/') {
                    mode = Mode::LineComment;
                    out.push_str("  ");
                    i += 2;
                } else if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    mode = Mode::BlockComment(1);
                    out.push_str("  ");
                    i += 2;
                } else if c == b'"' {
                    mode = Mode::Str;
                    cur.clear();
                    out.push(' ');
                    i += 1;
                } else if c == b'r' && matches!(b.get(i + 1), Some(&b'#') | Some(&b'"')) {
                    // Possible raw string r"..." / r#"..."#.
                    let mut j = i + 1;
                    let mut hashes = 0;
                    while b.get(j) == Some(&b'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if b.get(j) == Some(&b'"') {
                        mode = Mode::RawStr(hashes);
                        cur.clear();
                        for _ in i..=j {
                            out.push(' ');
                        }
                        i = j + 1;
                    } else {
                        out.push(c as char);
                        i += 1;
                    }
                } else if c == b'\'' {
                    // Lifetime (`'a`) vs char literal (`'a'`, `'\n'`): a
                    // lifetime is `'` + ident char not followed by `'`.
                    let next_ident = b
                        .get(i + 1)
                        .is_some_and(|&n| n.is_ascii_alphanumeric() || n == b'_');
                    if next_ident && b.get(i + 2) != Some(&b'\'') {
                        out.push('\'');
                        i += 1;
                    } else {
                        mode = Mode::Char;
                        out.push(' ');
                        i += 1;
                    }
                } else {
                    out.push(c as char);
                    i += 1;
                }
            }
            Mode::LineComment => {
                if c == b'\n' {
                    mode = Mode::Code;
                    out.push('\n');
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == b'/' && b.get(i + 1) == Some(&b'*') {
                    mode = Mode::BlockComment(depth + 1);
                    out.push_str("  ");
                    i += 2;
                } else if c == b'*' && b.get(i + 1) == Some(&b'/') {
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(if c == b'\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            Mode::Str => {
                if c == b'\\' {
                    // Keep the newline of a `\`-continuation so line numbers
                    // stay aligned with the original source.
                    out.push(' ');
                    if let Some(&n) = b.get(i + 1) {
                        cur.push('\\');
                        cur.push(n as char);
                        out.push(if n == b'\n' { '\n' } else { ' ' });
                    }
                    i += 2;
                } else if c == b'"' {
                    literals.push(std::mem::take(&mut cur));
                    mode = Mode::Code;
                    out.push(' ');
                    i += 1;
                } else {
                    cur.push(c as char);
                    out.push(if c == b'\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                let closes = c == b'"'
                    && (0..hashes).all(|h| b.get(i + 1 + h) == Some(&b'#'));
                if closes {
                    literals.push(std::mem::take(&mut cur));
                    mode = Mode::Code;
                    for _ in 0..=hashes {
                        out.push(' ');
                    }
                    i += 1 + hashes;
                } else {
                    cur.push(c as char);
                    out.push(if c == b'\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
            Mode::Char => {
                if c == b'\\' {
                    out.push(' ');
                    if b.get(i + 1) == Some(&b'\n') {
                        out.push('\n');
                    } else if i + 1 < b.len() {
                        out.push(' ');
                    }
                    i += 2;
                } else if c == b'\'' {
                    mode = Mode::Code;
                    out.push(' ');
                    i += 1;
                } else {
                    out.push(if c == b'\n' { '\n' } else { ' ' });
                    i += 1;
                }
            }
        }
    }
    Stripped { code: out, literals }
}

/// Whole-word occurrence check on a blanked-code line.
fn has_word(line: &str, word: &str) -> bool {
    let lb = line.as_bytes();
    let mut start = 0;
    while let Some(off) = line[start..].find(word) {
        let at = start + off;
        let before_ok = at == 0 || {
            let p = lb[at - 1];
            !(p.is_ascii_alphanumeric() || p == b'_')
        };
        let end = at + word.len();
        let after_ok = end >= lb.len() || {
            let n = lb[end];
            !(n.is_ascii_alphanumeric() || n == b'_')
        };
        if before_ok && after_ok {
            return true;
        }
        start = at + word.len();
    }
    false
}

/// Per-line flags: `true` for lines inside a `#[cfg(test)]`-gated item
/// (brace-matched on the blanked code, so strings can't confuse it).
fn test_region_flags(code_lines: &[&str]) -> Vec<bool> {
    let mut flags = vec![false; code_lines.len()];
    let mut i = 0;
    while i < code_lines.len() {
        if code_lines[i].contains("#[cfg(test)]") {
            let mut depth: i64 = 0;
            let mut started = false;
            let mut k = i;
            while k < code_lines.len() {
                for ch in code_lines[k].chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            started = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if started && depth <= 0 {
                    break;
                }
                k += 1;
            }
            let end = k.min(code_lines.len() - 1);
            for f in flags.iter_mut().take(end + 1).skip(i) {
                *f = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
    flags
}

// ---------------------------------------------------------------------------
// Per-file rules (1, 3, 4) and the cross-file forbid rule (2)
// ---------------------------------------------------------------------------

/// Walk upward from `line` (0-based) through the contiguous block of
/// comment/attribute lines and report whether any mentions SAFETY.
fn safety_comment_above(orig_lines: &[&str], line: usize) -> bool {
    let mut l = line;
    while l > 0 {
        l -= 1;
        let t = orig_lines[l].trim_start();
        if t.starts_with("//") || t.starts_with("#[") {
            if t.contains("SAFETY") || t.contains("# Safety") {
                return true;
            }
        } else {
            break;
        }
    }
    false
}

fn check_file(rel: &str, src: &str) -> Vec<String> {
    let stripped = strip_code(src);
    let code_lines: Vec<&str> = stripped.code.split('\n').collect();
    let orig_lines: Vec<&str> = src.split('\n').collect();
    let in_test = test_region_flags(&code_lines);
    let allow_unsafe = ALLOW_UNSAFE.contains(&rel);
    let det_path = DET_DIRS.iter().any(|d| rel.starts_with(d));
    let no_panic = NO_PANIC_FILES.contains(&rel);
    let simd_allow = SIMD_ALLOW.contains(&rel);
    let mut out = Vec::new();

    for (idx, cl) in code_lines.iter().enumerate() {
        if in_test[idx] {
            continue;
        }
        let ln = idx + 1;
        if has_word(cl, "unsafe") {
            if !allow_unsafe {
                out.push(format!(
                    "{rel}:{ln}: [unsafe-confinement] `unsafe` outside the allow-list \
                     (engine/parallel.rs, coordinator/master.rs, simd/avx2.rs, \
                     simd/neon.rs, benches/train_step.rs)"
                ));
            }
            if !safety_comment_above(&orig_lines, idx) {
                out.push(format!(
                    "{rel}:{ln}: [safety-comment] `unsafe` without an immediately \
                     preceding `// SAFETY:` (or `/// # Safety`) justification"
                ));
            }
        }
        if det_path {
            for tok in DET_TOKENS {
                if has_word(cl, tok) {
                    out.push(format!(
                        "{rel}:{ln}: [determinism] `{tok}` in a deterministic-path \
                         module (use BTreeMap/BTreeSet; timing belongs in util::stats)"
                    ));
                }
            }
        }
        if no_panic {
            for pat in PANIC_PATS {
                if cl.contains(pat) {
                    out.push(format!(
                        "{rel}:{ln}: [no-panic] `{pat}` in a wire-facing parser — \
                         corrupt input must return a named error, never panic"
                    ));
                }
            }
        }
        if !simd_allow {
            for tok in SIMD_TOKENS {
                if cl.contains(tok) {
                    out.push(format!(
                        "{rel}:{ln}: [simd-confinement] `{tok}` outside rust/src/simd/ — \
                         intrinsics and feature gating live behind the simd dispatcher \
                         so the forced-scalar job covers everything else"
                    ));
                }
            }
        }
    }
    out
}

/// Rule 2b: every library file outside the unsafe allow-list must be covered
/// by `#![forbid(unsafe_code)]` — its own, or an ancestor `mod.rs`'s (the
/// attribute propagates to child modules). `files` maps repo-relative path
/// to blanked code.
fn check_forbid_coverage(files: &BTreeMap<String, String>) -> Vec<String> {
    let mut out = Vec::new();
    for (rel, code) in files {
        if !rel.starts_with("rust/src") {
            continue;
        }
        if ALLOW_UNSAFE.contains(&rel.as_str()) || FORBID_EXEMPT.contains(&rel.as_str()) {
            continue;
        }
        let mut guarded = code.contains("forbid(unsafe_code)");
        let mut dir = Path::new(rel).parent();
        while let (false, Some(d)) = (guarded, dir) {
            if d == Path::new("rust") || d.as_os_str().is_empty() {
                break;
            }
            let mod_rs = d.join("mod.rs");
            let key = mod_rs.to_string_lossy().replace('\\', "/");
            if key != *rel {
                if let Some(parent_code) = files.get(&key) {
                    guarded = parent_code.contains("forbid(unsafe_code)");
                }
            }
            dir = d.parent();
        }
        if !guarded {
            out.push(format!(
                "{rel}: [forbid-unsafe] not covered by `#![forbid(unsafe_code)]` \
                 (add it to the file or its module root)"
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 5: bench-probe manifest drift
// ---------------------------------------------------------------------------

struct ManifestEntry {
    /// Glob form: the key, with a trailing `*` for prefix entries.
    glob: String,
    /// Original line (for messages).
    raw: String,
}

fn parse_manifest(text: &str) -> Vec<ManifestEntry> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let raw = line.to_string();
        let body = line.strip_prefix('?').unwrap_or(line);
        out.push(ManifestEntry { glob: body.to_string(), raw });
    }
    out
}

/// Do two glob patterns (where `*` matches any substring) share at least one
/// concrete string? Memoized two-pattern match.
fn glob_overlap(a: &str, b: &str) -> bool {
    let (a, b) = (a.as_bytes(), b.as_bytes());
    let mut memo = vec![0u8; (a.len() + 1) * (b.len() + 1)]; // 0=unset 1=false 2=true
    fn go(a: &[u8], b: &[u8], i: usize, j: usize, memo: &mut [u8]) -> bool {
        let k = i * (b.len() + 1) + j;
        if memo[k] != 0 {
            return memo[k] == 2;
        }
        let r = if i == a.len() && j == b.len() {
            true
        } else if i < a.len() && a[i] == b'*' {
            go(a, b, i + 1, j, memo) || (j < b.len() && go(a, b, i, j + 1, memo))
        } else if j < b.len() && b[j] == b'*' {
            go(a, b, i, j + 1, memo) || (i < a.len() && go(a, b, i + 1, j, memo))
        } else if i < a.len() && j < b.len() && a[i] == b[j] {
            go(a, b, i + 1, j + 1, memo)
        } else {
            false
        };
        memo[k] = if r { 2 } else { 1 };
        r
    }
    go(a, b, 0, 0, &mut memo)
}

/// `format!` template → glob: each `{...}` hole becomes `*`; `{{`/`}}`
/// escapes become literal braces.
fn template_to_glob(lit: &str) -> String {
    let b = lit.as_bytes();
    let mut out = String::new();
    let mut i = 0;
    while i < b.len() {
        match b[i] {
            b'{' if b.get(i + 1) == Some(&b'{') => {
                out.push('{');
                i += 2;
            }
            b'}' if b.get(i + 1) == Some(&b'}') => {
                out.push('}');
                i += 2;
            }
            b'{' => {
                while i < b.len() && b[i] != b'}' {
                    i += 1;
                }
                i += 1; // consume '}'
                out.push('*');
            }
            c => {
                out.push(c as char);
                i += 1;
            }
        }
    }
    out
}

fn check_bench_drift(manifest_text: &str, bench_literals: &[String]) -> Vec<String> {
    let manifest = parse_manifest(manifest_text);
    let mut out = Vec::new();
    if manifest.is_empty() {
        return vec!["scripts/bench_probes.txt: [bench-drift] manifest is empty".into()];
    }
    // Probe families come from the manifest itself, so the extractor below
    // stays in sync with the key namespace by construction.
    let families: std::collections::BTreeSet<&str> = manifest
        .iter()
        .filter_map(|e| e.glob.split('/').next())
        .collect();
    // Candidate probe templates: bench string literals that contain a '/',
    // no whitespace, and whose first segment is a known probe family.
    let templates: Vec<(String, String)> = bench_literals
        .iter()
        .filter(|l| l.contains('/') && !l.chars().any(char::is_whitespace))
        .map(|l| (l.clone(), template_to_glob(l)))
        .filter(|(_, g)| g.split('/').next().is_some_and(|f| families.contains(f)))
        .collect();
    for e in &manifest {
        if !templates.iter().any(|(_, tg)| glob_overlap(&e.glob, tg)) {
            out.push(format!(
                "scripts/bench_probes.txt: [bench-drift] `{}` is not producible by any \
                 probe literal in benches/train_step.rs",
                e.raw
            ));
        }
    }
    for (lit, tg) in &templates {
        if !manifest.iter().any(|e| glob_overlap(&e.glob, tg)) {
            out.push(format!(
                "benches/train_step.rs: [bench-drift] probe `{lit}` has no entry in \
                 scripts/bench_probes.txt (check_bench.py would never require it)"
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Pure core over in-memory sources, so the unit tests can feed fixtures.
/// `files`: repo-relative path → raw source, for all linted .rs files.
fn lint_sources(files: &BTreeMap<String, String>, manifest_text: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut blanked = BTreeMap::new();
    let mut bench_literals = Vec::new();
    for (rel, src) in files {
        out.extend(check_file(rel, src));
        let s = strip_code(src);
        if rel == "benches/train_step.rs" {
            bench_literals = s.literals;
        }
        blanked.insert(rel.clone(), s.code);
    }
    out.extend(check_forbid_coverage(&blanked));
    out.extend(check_bench_drift(manifest_text, &bench_literals));
    out.sort();
    out
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let p = entry?.path();
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

fn main() {
    // tools/repo-lint/ → repo root is two levels up.
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../..");
    let root = root.canonicalize().unwrap_or(root);
    let mut paths = Vec::new();
    for sub in ["rust/src", "benches"] {
        if let Err(e) = walk_rs(&root.join(sub), &mut paths) {
            eprintln!("repo-lint: cannot walk {sub}: {e}");
            std::process::exit(2);
        }
    }
    let mut files = BTreeMap::new();
    for p in &paths {
        let rel = p
            .strip_prefix(&root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        match std::fs::read_to_string(p) {
            Ok(src) => {
                files.insert(rel, src);
            }
            Err(e) => {
                eprintln!("repo-lint: cannot read {rel}: {e}");
                std::process::exit(2);
            }
        }
    }
    let manifest = match std::fs::read_to_string(root.join("scripts/bench_probes.txt")) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("repo-lint: cannot read scripts/bench_probes.txt: {e}");
            std::process::exit(2);
        }
    };
    let violations = lint_sources(&files, &manifest);
    if violations.is_empty() {
        println!(
            "repo-lint OK: {} files, {} manifest probes, all invariants hold",
            files.len(),
            parse_manifest(&manifest).len()
        );
    } else {
        for v in &violations {
            println!("{v}");
        }
        println!("repo-lint FAIL: {} violation(s)", violations.len());
        std::process::exit(1);
    }
}

// ---------------------------------------------------------------------------
// Tests — each rule must fire on a seeded violation and stay quiet on the
// compliant twin.
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn one_file(rel: &str, src: &str) -> Vec<String> {
        check_file(rel, src)
    }

    #[test]
    fn stripper_blanks_comments_and_strings_but_keeps_lines() {
        let src = "let a = 1; // unsafe HashMap\nlet b = \"unsafe {}\"; /* panic! */\n";
        let s = strip_code(src);
        assert_eq!(s.code.matches('\n').count(), src.matches('\n').count());
        assert!(!s.code.contains("unsafe"));
        assert!(!s.code.contains("panic!"));
        assert_eq!(s.literals, vec!["unsafe {}".to_string()]);
    }

    #[test]
    fn stripper_handles_raw_strings_nested_comments_and_chars() {
        let src = "let r = r#\"a \"quoted\" HashMap\"#;\n/* outer /* inner */ HashMap */\nlet c = '\"'; let lt: &'static str = \"x\";\n";
        let s = strip_code(src);
        assert!(!s.code.contains("HashMap"));
        assert_eq!(s.literals, vec!["a \"quoted\" HashMap".to_string(), "x".to_string()]);
        // The `'static` lifetime must not open a char literal and swallow code.
        assert!(s.code.contains("let lt"));
    }

    #[test]
    fn safety_rule_fires_without_comment_and_passes_with_one() {
        let bad = "fn f() {\n    unsafe { g() };\n}\n";
        let v = one_file("rust/src/engine/parallel.rs", bad);
        assert!(v.iter().any(|m| m.contains("[safety-comment]")), "{v:?}");

        let good = "fn f() {\n    // SAFETY: g is fine here.\n    unsafe { g() };\n}\n";
        assert!(one_file("rust/src/engine/parallel.rs", good).is_empty());

        let doc = "/// # Safety\n/// Caller must...\npub unsafe fn g() {}\n";
        assert!(one_file("rust/src/engine/parallel.rs", doc).is_empty());

        // The comment block may be interleaved with attributes.
        let attr = "// SAFETY: fine.\n#[inline]\nunsafe fn g() {}\n";
        assert!(one_file("rust/src/engine/parallel.rs", attr).is_empty());
    }

    #[test]
    fn unsafe_confinement_fires_outside_allow_list() {
        let src = "// SAFETY: ok.\nunsafe fn g() {}\n";
        let v = one_file("rust/src/compress/encode.rs", src);
        assert!(v.iter().any(|m| m.contains("[unsafe-confinement]")), "{v:?}");
        assert!(one_file("rust/src/engine/parallel.rs", src).is_empty());
    }

    #[test]
    fn unsafe_in_word_or_comment_does_not_count() {
        let src = "#![deny(unsafe_op_in_unsafe_fn)]\n// unsafe is banned here\nlet s = \"unsafe\";\n";
        assert!(one_file("rust/src/compress/encode.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let src = "fn lib() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        let m = std::collections::HashMap::new();\n        x.unwrap();\n        unsafe { y() };\n    }\n}\n";
        assert!(one_file("rust/src/compress/encode.rs", src).is_empty());
    }

    #[test]
    fn determinism_rule_fires_in_banned_modules_only() {
        let src = "use std::collections::HashMap;\n";
        for rel in [
            "rust/src/compress/mod.rs",
            "rust/src/protocol/worker.rs",
            "rust/src/engine/mod.rs",
            "rust/src/coordinator/master.rs",
            "rust/src/topology/mod.rs",
            "rust/src/optim/mod.rs",
            "rust/src/simd/scalar.rs",
        ] {
            let v = one_file(rel, src);
            assert!(v.iter().any(|m| m.contains("[determinism]")), "{rel}: {v:?}");
        }
        assert!(one_file("rust/src/util/rng.rs", src).is_empty());
        let v = one_file("rust/src/engine/mod.rs", "let t = Instant::now();\n");
        assert!(v.iter().any(|m| m.contains("[determinism]")));
    }

    #[test]
    fn no_panic_rule_fires_in_parsers_and_allows_non_panicking_kin() {
        for pat in ["x.unwrap();", "x.expect(\"m\");", "panic!(\"m\");", "unreachable!();"] {
            let src = format!("fn f() {{ {pat} }}\n");
            let v = one_file("rust/src/compress/rans.rs", &src);
            assert!(v.iter().any(|m| m.contains("[no-panic]")), "{pat}: {v:?}");
        }
        // unwrap_or / expect_byte / debug_assert are fine.
        let ok = "fn f() { x.unwrap_or(0); p.expect_byte(b'{'); debug_assert!(c); }\n";
        assert!(one_file("rust/src/compress/rans.rs", ok).is_empty());
        // Same constructs outside the parser files are fine (for this rule).
        assert!(one_file("rust/src/grad/mlp.rs", "fn f() { x.unwrap(); }\n").is_empty());
    }

    #[test]
    fn simd_confinement_fires_outside_simd_module() {
        // An unguarded intrinsic call in ordinary library code trips both
        // the SIMD and unsafe confinement rules.
        let bad = "use core::arch::x86_64::*;\nfn f(x: __m256) -> __m256 { unsafe { _mm256_add_ps(x, x) } }\n";
        let v = one_file("rust/src/compress/sparsify.rs", bad);
        assert!(v.iter().any(|m| m.contains("[simd-confinement]")), "{v:?}");
        assert!(v.iter().any(|m| m.contains("[unsafe-confinement]")), "{v:?}");

        // Even an unsafe-allow-listed file cannot host `#[target_feature]`.
        let tf = "// SAFETY: caller checked avx2.\n#[target_feature(enable = \"avx2\")]\nunsafe fn g() {}\n";
        let v = one_file("rust/src/engine/parallel.rs", tf);
        assert!(v.iter().any(|m| m.contains("[simd-confinement]")), "{v:?}");

        // A feature-detection macro outside the dispatcher also counts.
        let det = "fn d() -> bool { std::arch::is_x86_feature_detected!(\"avx2\") }\n";
        let v = one_file("rust/src/engine/mod.rs", det);
        assert!(v.iter().any(|m| m.contains("[simd-confinement]")), "{v:?}");

        // The simd backends themselves are exempt from both rules when the
        // guard idiom (SAFETY comment / # Safety doc) is followed.
        let ok = "use core::arch::x86_64::*;\n/// # Safety\n/// Caller must verify AVX2 first.\n#[target_feature(enable = \"avx2\")]\npub(crate) unsafe fn h() {}\n";
        assert!(one_file("rust/src/simd/avx2.rs", ok).is_empty());
        // Tokens in comments or test regions never count.
        let comment = "// dispatches to core::arch intrinsics\nfn f() {}\n";
        assert!(one_file("rust/src/compress/mod.rs", comment).is_empty());
    }

    #[test]
    fn forbid_coverage_accepts_own_or_ancestor_attr_and_flags_bare_files() {
        let mut files = BTreeMap::new();
        files.insert("rust/src/grad/mod.rs".into(), "#![forbid(unsafe_code)]\nmod mlp;\n".into());
        files.insert("rust/src/grad/mlp.rs".into(), "fn f() {}\n".into());
        files.insert("rust/src/data/mod.rs".into(), "fn f() {}\n".into());
        let v = check_forbid_coverage(&files);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("rust/src/data/mod.rs"));
    }

    #[test]
    fn glob_overlap_basics() {
        assert!(glob_overlap("a/b(c=1)", "a/b(c=1)"));
        assert!(glob_overlap("a/*(c=1)", "a/b(c=*)"));
        assert!(glob_overlap("master/round(R=8,threads=1)", "master/round(R=*,threads=*)"));
        assert!(glob_overlap("engine/step-par(threads=*", "engine/step-par(threads=*)"));
        assert!(!glob_overlap("a/b", "a/c"));
        assert!(!glob_overlap("alloc/x-per-call/k", "alloc/y-per-call/*"));
    }

    #[test]
    fn template_to_glob_handles_holes_and_escapes() {
        assert_eq!(template_to_glob("m/r(R={w},t={t:.1})"), "m/r(R=*,t=*)");
        assert_eq!(template_to_glob("lit{{x}}"), "lit{x}");
        assert_eq!(template_to_glob("plain/key"), "plain/key");
    }

    #[test]
    fn bench_drift_fires_in_both_directions() {
        let manifest = "alpha/key(d=1)\nbeta/thing(threads=*\n";
        // Happy path: both entries producible, both literals covered.
        let lits = vec!["alpha/key(d=1)".to_string(), "beta/thing(threads={t})".to_string()];
        assert!(check_bench_drift(manifest, &lits).is_empty());
        // Manifest entry the bench cannot produce.
        let v = check_bench_drift(manifest, &lits[1..].to_vec());
        assert!(v.iter().any(|m| m.contains("not producible")), "{v:?}");
        // Bench literal the manifest does not know (same family namespace).
        let extra = vec![
            lits[0].clone(),
            lits[1].clone(),
            "alpha/renamed(d=1)".to_string(),
        ];
        let v = check_bench_drift(manifest, &extra);
        assert!(v.iter().any(|m| m.contains("no entry")), "{v:?}");
        // Non-probe literals (paths, messages) are ignored.
        let noise = vec![
            lits[0].clone(),
            lits[1].clone(),
            "artifacts/manifest.json".to_string(),
            "some message / with spaces".to_string(),
        ];
        assert!(check_bench_drift(manifest, &noise).is_empty());
    }

    #[test]
    fn optional_and_prefix_manifest_lines_parse() {
        let m = parse_manifest("# comment\n\n?grad/pjrt-x(b=8)\nengine/speedup(R=8,threads=*\nplain/key\n");
        assert_eq!(m.len(), 3);
        assert_eq!(m[0].glob, "grad/pjrt-x(b=8)");
        assert_eq!(m[1].glob, "engine/speedup(R=8,threads=*");
        assert_eq!(m[2].glob, "plain/key");
    }

    #[test]
    fn lint_sources_reports_across_rules_and_sorts() {
        let mut files = BTreeMap::new();
        files.insert(
            "rust/src/compress/mod.rs".into(),
            "#![forbid(unsafe_code)]\nuse std::collections::HashMap;\n".into(),
        );
        files.insert(
            "benches/train_step.rs".into(),
            "fn main() { let k = \"alpha/key(d=1)\"; }\n".into(),
        );
        let v = lint_sources(&files, "alpha/key(d=1)\n");
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("[determinism]"));
    }
}
