"""AOT pipeline: lowering produces parseable HLO text + coherent manifest."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M


def test_to_hlo_text_roundtrips_through_xla_parser():
    lowered = jax.jit(lambda a, b: (a @ b,)).lower(
        jax.ShapeDtypeStruct((2, 3), jnp.float32), jax.ShapeDtypeStruct((3, 2), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "parameter(0)" in text.replace(" ", "") or "parameter(0" in text


def test_build_variant_softmax(tmp_path):
    entry, grad_hlo, eval_hlo = aot.build_variant("softmax", aot.VARIANTS["softmax"])
    assert entry["d"] == 7850
    assert entry["batch"] == 8
    assert "HloModule" in grad_hlo and "HloModule" in eval_hlo
    # The fused step must contain the dot from the Pallas matmul path.
    assert "dot(" in grad_hlo


def test_manifest_written(tmp_path, monkeypatch):
    out = tmp_path / "arts"
    import sys

    monkeypatch.setattr(
        sys, "argv", ["aot", "--out-dir", str(out), "--models", "softmax"]
    )
    aot.main()
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == 1
    (m,) = manifest["models"]
    assert m["name"] == "softmax"
    assert os.path.exists(out / m["grad_file"])
    assert os.path.exists(out / m["eval_file"])
    assert m["grad_sha"]


def test_lm_variant_entry_fields():
    cfg = aot.VARIANTS["lm"]["cfg"]
    entry, _, _ = aot.build_variant("lm", aot.VARIANTS["lm"])
    assert entry["seq"] == cfg.seq
    assert entry["feat"] == cfg.seq + 1
    assert sum(entry["layer_sizes"]) == entry["d"]


def test_init_params_shapes():
    for name in ("softmax", "mlp", "lm"):
        spec = aot.VARIANTS[name]
        p = aot.init_params_for(spec)
        assert p.shape == (spec["cfg"].d,)
        assert p.dtype == jnp.float32
