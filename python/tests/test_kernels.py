"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracles.

hypothesis sweeps shapes and value scales; assert_allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear, matmul_bias, softmax_xent, softmax_xent_fused
from compile.kernels.ref import linear_ref, softmax_xent_ref

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rng_arrays(seed, *shapes, scale=1.0):
    r = np.random.RandomState(seed)
    return [(r.randn(*s) * scale).astype(np.float32) for s in shapes]


@given(
    m=st.integers(1, 40),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    relu=st.booleans(),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_matches_ref(m, k, n, relu, scale, seed):
    x, w, b = rng_arrays(seed, (m, k), (k, n), (n,), scale=scale)
    got = matmul_bias(jnp.array(x), jnp.array(w), jnp.array(b), relu=relu)
    want = linear_ref(x, w, b, relu)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale * scale * k)


@given(
    m=st.integers(1, 16),
    k=st.integers(1, 32),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_no_bias(m, k, n, seed):
    (x, w) = rng_arrays(seed, (m, k), (k, n))
    got = matmul_bias(jnp.array(x), jnp.array(w))
    np.testing.assert_allclose(got, x @ w, rtol=2e-4, atol=1e-4 * k)


def test_matmul_tile_boundaries():
    # Shapes exactly at and just over the default tile sizes.
    for m, k, n in [(128, 256, 128), (129, 257, 129), (8, 128, 128), (1, 1, 1)]:
        x, w, b = rng_arrays(m * 1000 + n, (m, k), (k, n), (n,))
        got = matmul_bias(jnp.array(x), jnp.array(w), jnp.array(b))
        np.testing.assert_allclose(got, linear_ref(x, w, b), rtol=2e-4, atol=1e-3)


@given(
    b=st.integers(1, 40),
    c=st.integers(2, 30),
    scale=st.sampled_from([1e-2, 1.0, 30.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_softmax_xent_matches_ref(b, c, scale, seed):
    r = np.random.RandomState(seed)
    logits = (r.randn(b, c) * scale).astype(np.float32)
    labels = r.randint(0, c, size=b).astype(np.int32)
    nll, probs = softmax_xent_fused(jnp.array(logits), jnp.array(labels))
    want_loss, want_probs = softmax_xent_ref(logits, labels)
    np.testing.assert_allclose(np.mean(nll), want_loss, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(probs, want_probs, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.sum(probs, axis=-1), np.ones(b), rtol=1e-5)


def test_softmax_xent_extreme_logits_stable():
    logits = np.array([[1e4, -1e4, 0.0], [-1e4, -1e4, -1e4]], dtype=np.float32)
    labels = np.array([0, 2], dtype=np.int32)
    loss = softmax_xent(jnp.array(logits), jnp.array(labels))
    assert np.isfinite(float(loss))


@given(seed=st.integers(0, 2**31 - 1))
def test_linear_gradients_match_ref(seed):
    """custom_vjp backward (Pallas matmuls) vs jax-autodiff of the reference."""
    x, w, b = rng_arrays(seed, (6, 10), (10, 7), (7,))

    def f_pallas(x, w, b):
        return jnp.sum(jnp.sin(linear(jnp.array(x), w, b, True)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(linear_ref(x, w, b, True)))

    g_pallas = jax.grad(f_pallas, argnums=(0, 1, 2))(jnp.array(x), jnp.array(w), jnp.array(b))
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(jnp.array(x), jnp.array(w), jnp.array(b))
    for gp, gr in zip(g_pallas, g_ref):
        np.testing.assert_allclose(gp, gr, rtol=2e-4, atol=2e-4)


@given(seed=st.integers(0, 2**31 - 1))
def test_softmax_xent_gradient_matches_ref(seed):
    r = np.random.RandomState(seed)
    logits = r.randn(5, 8).astype(np.float32)
    labels = r.randint(0, 8, size=5).astype(np.int32)

    g_pallas = jax.grad(lambda z: softmax_xent(z, jnp.array(labels)))(jnp.array(logits))
    g_ref = jax.grad(lambda z: softmax_xent_ref(z, jnp.array(labels))[0])(jnp.array(logits))
    np.testing.assert_allclose(g_pallas, g_ref, rtol=1e-4, atol=1e-5)


def test_relu_mask_uses_post_activation():
    # Exactly-zero pre-activations: gradient must be 0 there (y > 0 mask).
    x = jnp.zeros((2, 3), jnp.float32)
    w = jnp.zeros((3, 4), jnp.float32)
    b = jnp.zeros((4,), jnp.float32)
    g = jax.grad(lambda b: jnp.sum(linear(x, w, b, True)))(b)
    np.testing.assert_allclose(g, np.zeros(4))


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_matmul_dtype_output(dtype):
    x = jnp.ones((4, 4), dtype)
    w = jnp.ones((4, 4), dtype)
    out = matmul_bias(x, w)
    assert out.dtype == jnp.float32
    np.testing.assert_allclose(out, 4.0 * np.ones((4, 4)))
