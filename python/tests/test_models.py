"""L2 model correctness: flat-parameter models vs independent references."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M

settings.register_profile("models", max_examples=10, deadline=None)
settings.load_profile("models")


def test_softmax_loss_at_zero_is_log_c():
    cfg = M.SoftmaxConfig(dim=12, classes=7, lam=0.0)
    r = np.random.RandomState(0)
    x = r.randn(8, 12).astype(np.float32)
    y = r.randint(0, 7, size=8).astype(np.int32)
    loss = M.softmax_loss(cfg, jnp.zeros((cfg.d,), jnp.float32), jnp.array(x), jnp.array(y))
    np.testing.assert_allclose(float(loss), np.log(7.0), rtol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_softmax_grad_matches_pure_jnp(seed):
    cfg = M.SoftmaxConfig(dim=9, classes=4, lam=0.01)
    r = np.random.RandomState(seed)
    p = (r.randn(cfg.d) * 0.3).astype(np.float32)
    x = r.randn(6, 9).astype(np.float32)
    y = r.randint(0, 4, size=6).astype(np.int32)

    def ref_loss(p, x, y):
        w = p[: 9 * 4].reshape(9, 4)
        z = p[9 * 4 :]
        logits = x @ w + z
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
        return nll + 0.5 * cfg.lam * jnp.sum(w * w)

    g_model = jax.grad(lambda p: M.softmax_loss(cfg, p, jnp.array(x), jnp.array(y)))(jnp.array(p))
    g_ref = jax.grad(ref_loss)(jnp.array(p), jnp.array(x), jnp.array(y))
    np.testing.assert_allclose(g_model, g_ref, rtol=2e-4, atol=2e-4)


def test_mlp_dim_and_init():
    cfg = M.MlpConfig(widths=(20, 16, 5))
    assert cfg.d == 21 * 16 + 17 * 5
    p = M.mlp_init(cfg, 0)
    assert p.shape == (cfg.d,)
    # biases zero, weights He-scaled
    layers = cfg.unflatten(p)
    for (w, b), fan_in in zip(layers, (20, 16)):
        np.testing.assert_allclose(b, 0.0)
        assert abs(float(jnp.std(w)) - (2.0 / fan_in) ** 0.5) < 0.3 * (2.0 / fan_in) ** 0.5


@given(seed=st.integers(0, 2**31 - 1))
def test_mlp_learns_one_step(seed):
    cfg = M.MlpConfig(widths=(10, 8, 3))
    r = np.random.RandomState(seed)
    x = r.randn(16, 10).astype(np.float32)
    y = r.randint(0, 3, size=16).astype(np.int32)
    p = M.mlp_init(cfg, seed % 1000)
    f = M.make_loss_and_grad(lambda p, x, y: M.mlp_loss(cfg, p, x, y))
    loss0, g = f(p, jnp.array(x), jnp.array(y))
    p2 = p - 0.5 * g
    loss1, _ = f(p2, jnp.array(x), jnp.array(y))
    assert float(loss1) < float(loss0)


def test_lm_shapes_and_loss_at_init():
    cfg = M.LmConfig(vocab=50, seq=12, layers=1, model_dim=16, heads=2)
    p = M.lm_init(cfg, 0)
    assert p.shape == (cfg.d,)
    r = np.random.RandomState(1)
    toks = r.randint(0, 50, size=(3, 13)).astype(np.float32)
    loss = M.lm_loss(cfg, p, jnp.array(toks), jnp.zeros((3,), jnp.int32))
    # Near-uniform prediction at init.
    assert abs(float(loss) - np.log(50.0)) < 0.3 * np.log(50.0)
    logits = M.lm_logits(cfg, p, jnp.array(toks[:, :-1]).astype(jnp.int32))
    assert logits.shape == (3, 12, 50)


def test_lm_causality():
    """Changing a future token must not affect past logits."""
    cfg = M.LmConfig(vocab=30, seq=8, layers=1, model_dim=16, heads=2)
    p = M.lm_init(cfg, 3)
    r = np.random.RandomState(2)
    toks = r.randint(0, 30, size=(1, 8)).astype(np.int32)
    base = M.lm_logits(cfg, p, jnp.array(toks))
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 7) % 30
    pert = M.lm_logits(cfg, p, jnp.array(toks2))
    np.testing.assert_allclose(base[0, :-1], pert[0, :-1], rtol=1e-5, atol=1e-5)
    assert not np.allclose(base[0, -1], pert[0, -1])


def test_lm_layer_sizes_sum_to_d():
    cfg = M.LmConfig(vocab=40, seq=6, layers=2, model_dim=8, heads=2)
    assert sum(cfg.layer_sizes()) == cfg.d


def test_classifier_eval_counts():
    cfg = M.SoftmaxConfig(dim=4, classes=3, lam=0.0)
    ev = M.make_classifier_eval(lambda p, x: M.softmax_logits(cfg, p, x), 3)
    # Hand-crafted params: identity-ish weights → predictable argmax.
    p = np.zeros(cfg.d, np.float32)
    w = np.zeros((4, 3), np.float32)
    w[0, 0] = w[1, 1] = w[2, 2] = 5.0
    p[: 12] = w.reshape(-1)
    x = np.eye(4, dtype=np.float32)[:3]  # rows predict class 0,1,2
    y_right = np.array([0, 1, 2], np.int32)
    y_wrong = np.array([1, 2, 0], np.int32)
    _, top1_r, _ = ev(jnp.array(p), jnp.array(x), jnp.array(y_right))
    _, top1_w, _ = ev(jnp.array(p), jnp.array(x), jnp.array(y_wrong))
    assert float(top1_r) == 0.0
    assert float(top1_w) == 3.0
