"""L2 — JAX models over a *flat* f32 parameter vector.

The rust coordinator owns model parameters as one flat Vec<f32> (the
compression operators, error memories and the aggregation rule are all
defined over flat vectors). Every model here therefore exposes:

    loss_and_grad(params_flat, x, y) -> (loss, grad_flat)
    evaluate(params_flat, x, y)      -> (loss, top1_errors, top5_errors)

Each function is jitted and AOT-lowered by `aot.py` to HLO text, one
artifact per (model, batch) configuration. The dense layers and the
softmax cross-entropy run through the L1 Pallas kernels.

Models:
  * softmax — ℓ2-regularized softmax regression (paper §5.2.1, convex)
  * mlp     — ReLU MLP classifier (non-convex stand-in; DESIGN.md §6)
  * lm      — decoder-only transformer LM (end-to-end driver). Token
              sequences cross the boundary as f32 and are floored to int
              inside, so the rust engine's (f32 features, labels) batch
              type carries them unchanged.
"""

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import linear, softmax_xent


# -- softmax regression --------------------------------------------------------


@dataclass(frozen=True)
class SoftmaxConfig:
    dim: int = 784
    classes: int = 10
    lam: float = 1.0 / 60000.0

    @property
    def d(self):
        return (self.dim + 1) * self.classes

    def unflatten(self, params):
        w = params[: self.dim * self.classes].reshape(self.dim, self.classes)
        z = params[self.dim * self.classes :]
        return w, z


def softmax_loss(cfg: SoftmaxConfig, params, x, y):
    w, z = cfg.unflatten(params)
    logits = linear(x, w, z)
    loss = softmax_xent(logits, y)
    return loss + 0.5 * cfg.lam * jnp.sum(w * w)


def softmax_logits(cfg: SoftmaxConfig, params, x):
    w, z = cfg.unflatten(params)
    return linear(x, w, z)


# -- MLP -----------------------------------------------------------------------


@dataclass(frozen=True)
class MlpConfig:
    widths: tuple = (784, 256, 10)

    @property
    def d(self):
        return sum((i + 1) * o for i, o in zip(self.widths[:-1], self.widths[1:]))

    def unflatten(self, params):
        layers, off = [], 0
        for i, o in zip(self.widths[:-1], self.widths[1:]):
            w = params[off : off + i * o].reshape(i, o)
            off += i * o
            b = params[off : off + o]
            off += o
            layers.append((w, b))
        return layers


def mlp_logits(cfg: MlpConfig, params, x):
    layers = cfg.unflatten(params)
    h = x
    for li, (w, b) in enumerate(layers):
        h = linear(h, w, b, li + 1 < len(layers))
    return h


def mlp_loss(cfg: MlpConfig, params, x, y):
    return softmax_xent(mlp_logits(cfg, params, x), y)


def mlp_init(cfg: MlpConfig, seed: int):
    """He init — mirrored by rust/src/grad/mlp.rs `init_params` (not bitwise:
    each side seeds its own RNG; the engine never mixes the two)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for i, o in zip(cfg.widths[:-1], cfg.widths[1:]):
        key, k1 = jax.random.split(key)
        chunks.append((jax.random.normal(k1, (i, o)) * (2.0 / i) ** 0.5).reshape(-1))
        chunks.append(jnp.zeros((o,)))
    return jnp.concatenate(chunks).astype(jnp.float32)


# -- transformer LM --------------------------------------------------------------


@dataclass(frozen=True)
class LmConfig:
    vocab: int = 256
    seq: int = 64
    layers: int = 2
    model_dim: int = 128
    heads: int = 4
    ffn_mult: int = 4

    @property
    def head_dim(self):
        assert self.model_dim % self.heads == 0
        return self.model_dim // self.heads

    def shapes(self):
        """Ordered (name, shape) of every parameter tensor."""
        dm, v, s = self.model_dim, self.vocab, self.seq
        f = self.ffn_mult * dm
        out = [("tok_emb", (v, dm)), ("pos_emb", (s, dm))]
        for l in range(self.layers):
            out += [
                (f"l{l}.ln1_g", (dm,)),
                (f"l{l}.ln1_b", (dm,)),
                (f"l{l}.wqkv", (dm, 3 * dm)),
                (f"l{l}.bqkv", (3 * dm,)),
                (f"l{l}.wo", (dm, dm)),
                (f"l{l}.bo", (dm,)),
                (f"l{l}.ln2_g", (dm,)),
                (f"l{l}.ln2_b", (dm,)),
                (f"l{l}.wf1", (dm, f)),
                (f"l{l}.bf1", (f,)),
                (f"l{l}.wf2", (f, dm)),
                (f"l{l}.bf2", (dm,)),
            ]
        out += [("lnf_g", (dm,)), ("lnf_b", (dm,)), ("head", (dm, v)), ("head_b", (v,))]
        return out

    @property
    def d(self):
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.shapes())

    def unflatten(self, params):
        tensors, off = {}, 0
        for name, shape in self.shapes():
            n = 1
            for s in shape:
                n *= s
            tensors[name] = params[off : off + n].reshape(shape)
            off += n
        return tensors

    def layer_sizes(self):
        """Flat size per named tensor (for piecewise/per-layer compression)."""
        sizes = []
        for _, shape in self.shapes():
            n = 1
            for s in shape:
                n *= s
            sizes.append(n)
        return sizes


def _layernorm(h, g, b, eps=1e-5):
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    return (h - mu) / jnp.sqrt(var + eps) * g + b


def lm_logits(cfg: LmConfig, params, tokens):
    """tokens: (b, seq) int32 → logits (b, seq, vocab)."""
    p = cfg.unflatten(params)
    b, s = tokens.shape
    dm, nh, hd = cfg.model_dim, cfg.heads, cfg.head_dim
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
    causal = jnp.tril(jnp.ones((s, s), dtype=bool))
    for l in range(cfg.layers):
        x1 = _layernorm(h, p[f"l{l}.ln1_g"], p[f"l{l}.ln1_b"])
        qkv = linear(x1.reshape(b * s, dm), p[f"l{l}.wqkv"], p[f"l{l}.bqkv"])
        qkv = qkv.reshape(b, s, 3, nh, hd)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (hd**0.5)
        att = jnp.where(causal[None, None], att, -1e30)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, dm)
        proj = linear(ctx.reshape(b * s, dm), p[f"l{l}.wo"], p[f"l{l}.bo"])
        h = h + proj.reshape(b, s, dm)
        x2 = _layernorm(h, p[f"l{l}.ln2_g"], p[f"l{l}.ln2_b"])
        f1 = linear(x2.reshape(b * s, dm), p[f"l{l}.wf1"], p[f"l{l}.bf1"], True)
        f2 = linear(f1, p[f"l{l}.wf2"], p[f"l{l}.bf2"])
        h = h + f2.reshape(b, s, dm)
    h = _layernorm(h, p["lnf_g"], p["lnf_b"])
    logits = linear(h.reshape(b * s, dm), p["head"], p["head_b"])
    return logits.reshape(b, s, cfg.vocab)


def lm_loss(cfg: LmConfig, params, xtokens_f32, _y_unused):
    """Next-token NLL. xtokens_f32: (b, seq+1) f32-encoded tokens."""
    tokens = xtokens_f32.astype(jnp.int32)
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = lm_logits(cfg, params, inp)
    b, s, v = logits.shape
    return softmax_xent(logits.reshape(b * s, v), tgt.reshape(b * s))


def lm_init(cfg: LmConfig, seed: int):
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in cfg.shapes():
        key, k1 = jax.random.split(key)
        if name.endswith(("_b", ".bqkv", ".bo", ".bf1", ".bf2")) or name.endswith("_g"):
            init = jnp.ones(shape) if name.endswith("_g") else jnp.zeros(shape)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            init = jax.random.normal(k1, shape) * (1.0 / fan_in) ** 0.5
        chunks.append(init.reshape(-1))
    return jnp.concatenate(chunks).astype(jnp.float32)


# -- shared loss/grad + eval wrappers -------------------------------------------


def make_loss_and_grad(loss_fn):
    """(params, x, y) → (loss, grad) as a single fused computation."""

    def f(params, x, y):
        loss, grad = jax.value_and_grad(loss_fn)(params, x, y)
        return loss, grad

    return f


def make_classifier_eval(logits_fn, classes):
    """(params, x, y) → (mean_loss, top1_errs, top5_errs) counts as f32."""

    def f(params, x, y):
        logits = logits_fn(params, x)
        loss = softmax_xent(logits, y)
        y = y.astype(jnp.int32)
        ly = jnp.take_along_axis(logits, y[:, None], axis=-1)
        # Rank with first-index tie-break (mirrors the rust substrates: at
        # all-equal logits top-1 error must be (C−1)/C, not 0).
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        better = jnp.sum(
            (logits > ly) | ((logits == ly) & (iota < y[:, None])), axis=-1
        )
        top1 = jnp.sum(better >= 1).astype(jnp.float32)
        top5 = jnp.sum(better >= min(5, classes)).astype(jnp.float32)
        return loss, top1, top5

    return f


def make_lm_eval(cfg: LmConfig):
    def f(params, x, y):
        tokens = x.astype(jnp.int32)
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        logits = lm_logits(cfg, params, inp)
        b, s, v = logits.shape
        flat, tflat = logits.reshape(b * s, v), tgt.reshape(b * s)
        loss = softmax_xent(flat, tflat)
        ly = jnp.take_along_axis(flat, tflat[:, None], axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, flat.shape, 1)
        better = jnp.sum(
            (flat > ly) | ((flat == ly) & (iota < tflat[:, None])), axis=-1
        )
        top1 = jnp.sum(better >= 1).astype(jnp.float32)
        top5 = jnp.sum(better >= 5).astype(jnp.float32)
        return loss, top1, top5

    return f
