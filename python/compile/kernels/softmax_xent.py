"""L1 Pallas kernel: fused row-block softmax cross-entropy.

One grid step processes a (br, C) block of logits entirely in VMEM: the
row max, exp, row sum, log and the label gather all happen on-chip — the
TPU analogue of the warp-level reductions a CUDA softmax kernel would use.
Outputs the per-row negative log-likelihood and the softmax probabilities
(saved for the backward pass: d logits = (p − onehot)/b).

interpret=True: see matmul_bias.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BR = 64  # rows per block


def _softmax_xent_kernel(logits_ref, labels_ref, nll_ref, probs_ref):
    z = logits_ref[...]  # (br, c)
    labels = labels_ref[...]  # (br, 1) int32
    m = jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    logp = (z - m) - jnp.log(s)
    probs_ref[...] = e / s
    c = z.shape[-1]
    onehot = labels == jax.lax.broadcasted_iota(jnp.int32, (z.shape[0], c), 1)
    nll_ref[...] = -jnp.sum(jnp.where(onehot, logp, 0.0), axis=-1, keepdims=True)


def _pad_rows(a, mult):
    rem = (-a.shape[0]) % mult
    if rem == 0:
        return a
    pad = [(0, rem)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("br",))
def softmax_xent_fused(logits, labels, br=BR):
    """Per-row NLL and probabilities via the fused Pallas kernel.

    logits (b, c) f32, labels (b,) int — returns (nll (b,), probs (b, c)).
    """
    b, c = logits.shape
    br = min(br, _ceil8(b))
    lp = _pad_rows(logits, br)
    # Pad labels with class 0; padded rows are sliced away below.
    yp = _pad_rows(labels.astype(jnp.int32).reshape(-1, 1), br)
    grid = (lp.shape[0] // br,)
    nll, probs = pl.pallas_call(
        _softmax_xent_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, c), lambda i: (i, 0)),
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, 1), lambda i: (i, 0)),
            pl.BlockSpec((br, c), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lp.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((lp.shape[0], c), jnp.float32),
        ],
        interpret=True,
    )(lp, yp)
    return nll[:b, 0], probs[:b]


def _ceil8(v):
    return max(8, ((v + 7) // 8) * 8)


@jax.custom_vjp
def softmax_xent(logits, labels):
    """Differentiable mean cross-entropy over the batch (Pallas-fused)."""
    nll, _ = softmax_xent_fused(logits, labels)
    return jnp.mean(nll)


def _sx_fwd(logits, labels):
    nll, probs = softmax_xent_fused(logits, labels)
    return jnp.mean(nll), (probs, labels)


def _sx_bwd(res, g):
    probs, labels = res
    b, c = probs.shape
    onehot = labels.astype(jnp.int32)[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (b, c), 1
    )
    dlogits = (probs - onehot.astype(probs.dtype)) * (g / b)
    return dlogits, None


softmax_xent.defvjp(_sx_fwd, _sx_bwd)
