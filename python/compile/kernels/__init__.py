"""L1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .matmul_bias import linear, matmul_bias
from .softmax_xent import softmax_xent, softmax_xent_fused

__all__ = ["linear", "matmul_bias", "softmax_xent", "softmax_xent_fused"]
