"""L1 Pallas kernel: tiled matmul + bias (+ optional ReLU).

TPU-idiomatic structure (DESIGN.md §7 Hardware-Adaptation): the grid walks
(M/bm, N/bn, K/bk) tiles; each grid step moves one (bm, bk) tile of `x` and
one (bk, bn) tile of `w` from HBM into VMEM (expressed by the BlockSpecs),
accumulates a partial product in a f32 VMEM scratch accumulator via
`jnp.dot(..., preferred_element_type=f32)` — the MXU systolic-array path —
and writes the output tile once on the last K step, fusing bias add and the
activation so the tile never round-trips to HBM in between.

Kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is both the correctness oracle path
and the form embedded in the AOT artifacts. Real-TPU perf is estimated from
the BlockSpec footprint in DESIGN.md §Perf.

The differentiable wrapper `linear()` carries a custom VJP whose backward
matmuls (dx = g·wᵀ, dw = xᵀ·g) reuse the same kernel, so the AOT-lowered
training step runs Pallas in both the forward and backward pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default tile sizes: multiples of the TPU native (8, 128) f32 tile; the MXU
# is a 128x128 systolic array, so bm = bn = 128 feeds it fully while three
# f32 buffers (x-tile, w-tile, acc) stay ≲ 0.6 MiB of VMEM.
BM, BN, BK = 128, 128, 256


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int, relu: bool, bias_ref=None):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _finalize():
        out = acc_ref[...]
        if bias_ref is not None:
            out = out + bias_ref[...]
        if relu:
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _pad_to(a, axis, mult):
    size = a.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return a
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, rem)
    return jnp.pad(a, pad)


@functools.partial(jax.jit, static_argnames=("relu", "bm", "bn", "bk"))
def matmul_bias(x, w, b=None, relu=False, bm=BM, bn=BN, bk=BK):
    """y = x @ w (+ b) (+ ReLU) via the tiled Pallas kernel.

    Shapes: x (m, k), w (k, n), b (n,) or None. Arbitrary sizes — inputs are
    zero-padded up to tile multiples and the result is sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    bm = min(bm, _ceil_mult(m, 8))
    bn = min(bn, _ceil_mult(n, 128))
    bk = min(bk, _ceil_mult(k, 128))
    xp = _pad_to(_pad_to(x, 0, bm), 1, bk)
    wp = _pad_to(_pad_to(w, 0, bk), 1, bn)
    gm, gn, gk = xp.shape[0] // bm, wp.shape[1] // bn, xp.shape[1] // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
    ]
    args = [xp, wp]
    if b is not None:
        bp = _pad_to(b.reshape(1, -1), 1, bn)
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)))
        args.append(bp)
        kernel = functools.partial(_matmul_kernel_with_bias, nk=gk, relu=relu)
    else:
        kernel = functools.partial(_matmul_kernel, nk=gk, relu=relu)

    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(*args)
    return out[:m, :n]


def _matmul_kernel_with_bias(x_ref, w_ref, bias_ref, o_ref, acc_ref, *, nk, relu):
    _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, nk=nk, relu=relu, bias_ref=bias_ref)


def _ceil_mult(v, mult):
    return max(mult, ((v + mult - 1) // mult) * mult)


# -- differentiable wrapper ---------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def linear(x, w, b, relu=False):
    """Differentiable y = relu?(x @ w + b) backed by the Pallas kernel."""
    return matmul_bias(x, w, b, relu=relu)


def _linear_fwd(x, w, b, relu):
    y = matmul_bias(x, w, b, relu=relu)
    return y, (x, w, y)


def _linear_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = g * (y > 0.0)
    # Backward matmuls reuse the same Pallas kernel (no bias, no relu).
    dx = matmul_bias(g, w.T)
    dw = matmul_bias(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


linear.defvjp(_linear_fwd, _linear_bwd)
