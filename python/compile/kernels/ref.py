"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas kernels (run
with interpret=True) match these references to float tolerance.
"""

import jax.numpy as jnp


def linear_ref(x, w, b, relu=False):
    """y = x @ w + b, optionally ReLU'd. x: (m, k), w: (k, n), b: (n,)."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def softmax_xent_ref(logits, labels):
    """Mean cross-entropy over rows plus row-wise softmax probabilities.

    logits: (b, c) f32; labels: (b,) int32.
    Returns (mean_loss: scalar, probs: (b, c)).
    """
    m = jnp.max(logits, axis=-1, keepdims=True)
    z = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(z), axis=-1, keepdims=True))
    logp = z - lse
    nll = -jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll), jnp.exp(logp)
