"""AOT lowering: JAX (L2, with L1 Pallas kernels) → HLO text + manifest.

HLO *text* is the interchange format — the image's xla_extension 0.5.1
rejects jax≥0.5's serialized protos (64-bit instruction ids), while the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md). Lowered with return_tuple=True; the rust
runtime unwraps with Literal::to_tuple*.

Usage:  python -m compile.aot --out-dir ../artifacts [--models softmax,mlp,...]

Writes, per model variant:
    <name>.grad.hlo.txt — (params, x, y) -> (loss, grad_flat)
    <name>.eval.hlo.txt — (params, x, y) -> (loss, top1_errs, top5_errs)
and a single manifest.json describing shapes/dtypes/param layout.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Model variants exported by default. The convex softmax matches the paper's
# MNIST geometry (d = 7850, b = 8); mlp/lm batch sizes match the figure
# harness and the end-to-end example.
VARIANTS = {
    "softmax": dict(
        kind="softmax",
        cfg=M.SoftmaxConfig(dim=784, classes=10, lam=1.0 / 60000.0),
        batch=8,
    ),
    "mlp": dict(kind="mlp", cfg=M.MlpConfig(widths=(256, 64, 10)), batch=16),
    "lm": dict(
        kind="lm",
        cfg=M.LmConfig(vocab=256, seq=64, layers=2, model_dim=128, heads=4),
        batch=8,
    ),
    # ~10M-parameter transformer for the end-to-end training example
    # (examples/train_transformer.rs). CPU-PJRT friendly.
    "lm10m": dict(
        kind="lm",
        cfg=M.LmConfig(vocab=2048, seq=128, layers=4, model_dim=256, heads=8),
        batch=4,
    ),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_variant(name, spec):
    kind, cfg, batch = spec["kind"], spec["cfg"], spec["batch"]
    if kind == "softmax":
        loss_fn = lambda p, x, y: M.softmax_loss(cfg, p, x, y)
        eval_fn = M.make_classifier_eval(lambda p, x: M.softmax_logits(cfg, p, x), cfg.classes)
        x_shape, y_shape = (batch, cfg.dim), (batch,)
        y_dtype = jnp.int32
        feat = cfg.dim
        classes = cfg.classes
    elif kind == "mlp":
        loss_fn = lambda p, x, y: M.mlp_loss(cfg, p, x, y)
        eval_fn = M.make_classifier_eval(lambda p, x: M.mlp_logits(cfg, p, x), cfg.widths[-1])
        x_shape, y_shape = (batch, cfg.widths[0]), (batch,)
        y_dtype = jnp.int32
        feat = cfg.widths[0]
        classes = cfg.widths[-1]
    elif kind == "lm":
        loss_fn = lambda p, x, y: M.lm_loss(cfg, p, x, y)
        eval_fn = M.make_lm_eval(cfg)
        # tokens travel as f32 (b, seq+1); y is a dummy int32 scalar batch.
        x_shape, y_shape = (batch, cfg.seq + 1), (batch,)
        y_dtype = jnp.int32
        feat = cfg.seq + 1
        classes = cfg.vocab
    else:
        raise ValueError(kind)

    d = cfg.d
    grad_fn = M.make_loss_and_grad(loss_fn)
    p_spec = jax.ShapeDtypeStruct((d,), jnp.float32)
    x_spec = jax.ShapeDtypeStruct(x_shape, jnp.float32)
    y_spec = jax.ShapeDtypeStruct(y_shape, y_dtype)

    # keep_unused: the LM loss derives targets from x and ignores y; the
    # rust runtime always passes (params, x, y), so keep the parameter.
    grad_hlo = to_hlo_text(jax.jit(grad_fn, keep_unused=True).lower(p_spec, x_spec, y_spec))
    eval_hlo = to_hlo_text(jax.jit(eval_fn, keep_unused=True).lower(p_spec, x_spec, y_spec))

    entry = {
        "name": name,
        "kind": kind,
        "d": int(d),
        "batch": int(batch),
        "feat": int(feat),
        "classes": int(classes),
        "x_shape": list(x_shape),
        "y_shape": list(y_shape),
        "grad_file": f"{name}.grad.hlo.txt",
        "eval_file": f"{name}.eval.hlo.txt",
        "eval_rows": int(x_shape[0]),
    }
    if kind == "lm":
        entry["seq"] = int(cfg.seq)
        entry["vocab"] = int(cfg.vocab)
        entry["layer_sizes"] = [int(s) for s in cfg.layer_sizes()]
    if kind == "mlp":
        entry["widths"] = list(cfg.widths)
    if kind == "softmax":
        entry["lam"] = float(cfg.lam)
    return entry, grad_hlo, eval_hlo


def init_params_for(spec, seed=0):
    kind, cfg = spec["kind"], spec["cfg"]
    if kind == "mlp":
        return M.mlp_init(cfg, seed)
    if kind == "lm":
        return M.lm_init(cfg, seed)
    return jnp.zeros((cfg.d,), jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="softmax,mlp,lm")
    ap.add_argument("--with-init", action="store_true",
                    help="also dump <name>.init.f32 raw initial parameters")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": 1, "models": []}
    for name in [m.strip() for m in args.models.split(",") if m.strip()]:
        spec = VARIANTS[name]
        entry, grad_hlo, eval_hlo = build_variant(name, spec)
        for fname, text in ((entry["grad_file"], grad_hlo), (entry["eval_file"], eval_hlo)):
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text) / 1e6:.2f} MB)")
        if args.with_init or spec["kind"] in ("mlp", "lm"):
            import numpy as np

            init = np.asarray(init_params_for(spec), dtype=np.float32)
            ipath = os.path.join(args.out_dir, f"{name}.init.f32")
            init.tofile(ipath)
            entry["init_file"] = f"{name}.init.f32"
            print(f"wrote {ipath} ({init.nbytes / 1e6:.2f} MB)")
        entry["grad_sha"] = hashlib.sha256(grad_hlo.encode()).hexdigest()[:16]
        manifest["models"].append(entry)

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
