//! Adversarial wire-decode property tests: no input — corrupted, truncated,
//! or outright random — may panic, abort, or oversize-allocate in the
//! decoder. Corruption that breaks framing must surface as `Err(DecodeError)`
//! while leaving the reused `MessageBuf` in a state that decodes the next
//! valid message correctly (the threaded master reuses one buf per worker).
//!
//! The sandbox has no fuzzer, so this is a seeded-PCG mutation sweep: fully
//! reproducible, hundreds of mutations per run. Under Miri the sweep shrinks
//! (~100× interpreter slowdown) but still exercises every mutation kind.

use qsparse::compress::{encode, parse_spec, Codec, Compressor, MessageBuf, WireEncoder};
use qsparse::util::rng::Pcg64;

/// Wire-format ceiling on any decoded element count (mirrors the decoder's
/// internal `MAX_WIRE_ELEMS`): a successful decode of corrupt input is
/// acceptable, a successful decode of a decompression bomb is not.
const MAX_WIRE_ELEMS: usize = 1 << 27;

fn operators(d: usize) -> Vec<Box<dyn Compressor>> {
    let k = (d / 4).max(1);
    [
        "identity".to_string(),
        format!("topk:k={k}"),
        "qsgd:bits=4".to_string(),
        "sign".to_string(),
        format!("qtopk:k={k},bits=4"),
        format!("signtopk:k={k},m=1"),
    ]
    .iter()
    .map(|s| parse_spec(s).unwrap())
    .collect()
}

fn gen_vector(rng: &mut Pcg64, d: usize, family: usize) -> Vec<f32> {
    match family % 3 {
        0 => (0..d).map(|_| rng.normal_f32()).collect(),
        1 => (0..d)
            .map(|i| if i % 5 == 0 { rng.normal_f32() * 10.0 } else { 0.0 })
            .collect(),
        _ => (0..d).map(|i| (i % 3) as f32 - 1.0).collect(),
    }
}

/// Decode through both entry points; they must agree on Ok/Err, and the
/// recycled buf must still decode a pristine stream afterwards.
fn decode_both(
    bytes: &[u8],
    bit_len: u64,
    buf: &mut MessageBuf,
    pristine: (&[u8], u64),
    ctx: &str,
) -> bool {
    let by_value = encode::decode(bytes, bit_len);
    let into = encode::decode_into(bytes, bit_len, buf);
    assert_eq!(
        by_value.is_ok(),
        into.is_ok(),
        "{ctx}: decode and decode_into disagree: {by_value:?} vs {into:?}"
    );
    if let Ok(msg) = &by_value {
        assert_eq!(msg, buf.message(), "{ctx}: decode_into produced a different message");
        assert!(msg.dim() <= MAX_WIRE_ELEMS, "{ctx}: decompression bomb: d={}", msg.dim());
        assert!(msg.nnz() <= MAX_WIRE_ELEMS, "{ctx}: decompression bomb: nnz={}", msg.nnz());
    }
    // Buf poisoning check: a pristine decode through the same buf must work
    // no matter what the corrupt stream did to it.
    encode::decode_into(pristine.0, pristine.1, buf)
        .unwrap_or_else(|e| panic!("{ctx}: buf poisoned, pristine stream now fails: {e}"));
    by_value.is_ok()
}

#[test]
fn corrupt_streams_error_never_panic() {
    let (trials, flips_per_msg) = if cfg!(miri) { (2, 2) } else { (12, 8) };
    let mut rng = Pcg64::seeded(0xBADC0DE);
    let mut wire = WireEncoder::new(Codec::Rans);
    let mut buf = MessageBuf::new();
    // Guaranteed-Err mutations (truncations and length lies) are counted to
    // prove the sweep actually exercised the error paths.
    let mut guaranteed_err = 0u64;
    for trial in 0..trials {
        let d = 16 + rng.below_usize(400);
        let x = gen_vector(&mut rng, d, trial);
        for op in operators(d) {
            let msg = op.compress(&x, &mut rng);
            for codec in [Codec::Raw, Codec::Rans] {
                let (bytes, bit_len) = match codec {
                    Codec::Raw => encode::encode(&msg),
                    Codec::Rans => {
                        let (b, l) = wire.encode(&msg);
                        (b.to_vec(), l)
                    }
                };
                let ctx = format!("trial {trial} {} {codec:?}", op.name());
                let pristine = (&bytes[..], bit_len);

                // 1. Single-bit flips anywhere in the stream: may decode to a
                //    different valid message, must never panic or bomb.
                for _ in 0..flips_per_msg {
                    if bytes.is_empty() {
                        continue;
                    }
                    let mut m = bytes.clone();
                    let bit = rng.below_usize(m.len() * 8);
                    m[bit / 8] ^= 1 << (bit % 8);
                    decode_both(&m, bit_len, &mut buf, pristine, &format!("{ctx} flip@{bit}"));
                }

                // 2. Truncations with the original bit_len: framing now lies
                //    about the buffer, so every one must be an Err.
                for frac in [0, 1, 2, 3] {
                    let keep = bytes.len() * frac / 4;
                    if keep == bytes.len() || bit_len == 0 {
                        continue;
                    }
                    let ok = decode_both(
                        &bytes[..keep],
                        bit_len,
                        &mut buf,
                        pristine,
                        &format!("{ctx} trunc@{keep}"),
                    );
                    assert!(!ok, "{ctx}: truncated to {keep}B but decode succeeded");
                    guaranteed_err += 1;
                }

                // 3. bit_len inflation past the byte buffer: guaranteed Err.
                for lie in [8 * bytes.len() as u64 + 1, 8 * bytes.len() as u64 + 63, u64::MAX] {
                    let ok = decode_both(
                        &bytes,
                        lie,
                        &mut buf,
                        pristine,
                        &format!("{ctx} bit_len={lie}"),
                    );
                    assert!(!ok, "{ctx}: lying bit_len {lie} but decode succeeded");
                    guaranteed_err += 1;
                }

                // 4. bit_len deflation: the reader runs dry mid-message (or
                //    the message happens to fit — then it must round-trip
                //    sanely); either way, no panic.
                if bit_len > 1 {
                    let short = rng.next_u64() % bit_len;
                    decode_both(&bytes, short, &mut buf, pristine, &format!("{ctx} short={short}"));
                }
            }
        }
    }
    let floor = if cfg!(miri) { 50 } else { 200 };
    assert!(
        guaranteed_err >= floor,
        "only {guaranteed_err} guaranteed-error mutations ran (floor {floor})"
    );
}

#[test]
fn random_garbage_never_panics() {
    let streams = if cfg!(miri) { 12 } else { 150 };
    let mut rng = Pcg64::seeded(0x6A5BA6E);
    let mut buf = MessageBuf::new();
    // A pristine stream to verify the buf stays usable throughout.
    let op = parse_spec("topk:k=8").unwrap();
    let msg = op.compress(&gen_vector(&mut rng, 64, 0), &mut rng);
    let (pb, pl) = encode::encode(&msg);
    for i in 0..streams {
        let len = rng.below_usize(96);
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let bit_len = match i % 3 {
            0 => 8 * len as u64,
            1 => rng.next_u64() % (8 * len as u64 + 1),
            _ => rng.next_u64(), // usually absurd — must hit the framing guard
        };
        decode_both(&bytes, bit_len, &mut buf, (&pb[..], pl), &format!("garbage {i} len={len}"));
    }
}

/// All-zero and all-one streams of many sizes: degenerate patterns that
/// historically tickle length-field parsers (zeros make Elias-γ read forever,
/// ones make every count enormous).
#[test]
fn degenerate_bit_patterns_never_panic() {
    let max = if cfg!(miri) { 16 } else { 128 };
    let mut buf = MessageBuf::new();
    let op = parse_spec("sign").unwrap();
    let mut rng = Pcg64::seeded(7);
    let msg = op.compress(&gen_vector(&mut rng, 32, 0), &mut rng);
    let (pb, pl) = encode::encode(&msg);
    for n in 0..max {
        for fill in [0x00u8, 0xFF, 0xAA] {
            let bytes = vec![fill; n];
            decode_both(&bytes, 8 * n as u64, &mut buf, (&pb[..], pl), &format!("fill={fill:#x} n={n}"));
        }
    }
}
