//! Fault-tolerant rounds, end to end: deterministic fault injection on both
//! runtimes (event-driven simulator and threaded coordinator), EF
//! re-absorption of lost updates, and bit-identical checkpoint/resume.
//!
//! The determinism claims are *twin* tests: the same seeded fault spec run
//! twice must produce bit-identical histories, on the simulator (single
//! thread, virtual clock) and on the threaded runtime (real threads,
//! nondeterministic arrival order — determinism comes from the barrier's
//! sorted fold and the stateless per-(worker, step) fault decisions).

use qsparse::compress::parse_spec;
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::data::gaussian_clusters_split;
use qsparse::engine::{run_from_resumable, History, TrainSpec};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::{LrSchedule, ServerOptSpec};
use qsparse::protocol::checkpoint::spec_fingerprint;
use qsparse::protocol::CheckpointError;
use qsparse::sim::{run_from_faulty, SimSpec};
use qsparse::topology::FixedPeriod;
use qsparse::FaultSpec;
use std::sync::Arc;

const N: usize = 300;

/// Miri runs every thread and event for real, so it gets a short horizon;
/// native runs use enough steps for the convergence assertions to bite.
fn steps() -> usize {
    if cfg!(miri) {
        12
    } else {
        80
    }
}

/// Longer horizon for the convergence-under-loss assertions (faults slow
/// progress down, so they get twice the steps of the identity tests).
fn long_steps() -> usize {
    if cfg!(miri) {
        12
    } else {
        160
    }
}

fn data() -> (qsparse::data::Dataset, qsparse::data::Dataset) {
    gaussian_clusters_split(N, N / 4, 16, 4, 0.5, 1.0, 55)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(16, 4, 1.0 / N as f64)
}

/// Everything the cocktail can throw at a run: drops, corruption,
/// duplication, delay-reordering, downlink loss and crash-restarts.
fn cocktail() -> FaultSpec {
    FaultSpec::parse(
        "drop=0.1,corrupt=0.05,dup=0.1,delay=0.1:5000,drop-down=0.05,corrupt-down=0.05,\
         crash=0.02,deadline=60000,seed=42",
    )
    .unwrap()
}

fn assert_identical(a: &History, b: &History, ctx: &str) {
    assert_eq!(a.final_params, b.final_params, "{ctx}: final params differ");
    assert_eq!(a.points.len(), b.points.len(), "{ctx}: grids differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.step, pb.step, "{ctx}");
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{ctx}: train_loss at step {}",
            pa.step
        );
        assert_eq!(
            (pa.bits_up, pa.bits_down),
            (pb.bits_up, pb.bits_down),
            "{ctx}: wire bits at step {}",
            pa.step
        );
    }
}

// ---- simulator -------------------------------------------------------------

fn sim_run(train: &qsparse::data::Dataset, faults: Option<&FaultSpec>, steps: usize) -> History {
    let m = model();
    let comp = parse_spec("qtopk:k=10,bits=4").unwrap();
    let sched = FixedPeriod::new(4);
    let mut spec = TrainSpec::new(&m, train, comp.as_ref(), &sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = steps;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    let sim = SimSpec { compute_sigma: 0.8, bw_sigma: 0.5, latency: 2_000, ..SimSpec::default() };
    run_from_faulty(&spec, &sim, faults, vec![0.0; m.dim()]).history
}

/// Same seed ⇒ same faults ⇒ the same trajectory, bit for bit, and the
/// cocktail still drains every staged message (the run terminates with a
/// full history rather than deadlocking on a lost round).
#[test]
fn sim_fault_twins_are_bit_identical() {
    let (train, _) = data();
    let faults = cocktail();
    let a = sim_run(&train, Some(&faults), steps());
    let b = sim_run(&train, Some(&faults), steps());
    assert_identical(&a, &b, "sim twins");
    assert!(a.final_loss().is_finite());
    assert!(!a.points.is_empty());
}

/// Convergence under loss: with 20% uplink drops the error memory
/// re-absorbs every lost update (m ← m + ĝ), so training still converges —
/// lost mass is delayed, not destroyed.
#[test]
fn sim_converges_under_uplink_drops() {
    let (train, _) = data();
    let faults = FaultSpec::parse("drop=0.2,deadline=60000,seed=7").unwrap();
    let hist = sim_run(&train, Some(&faults), long_steps());
    let first = hist.points.first().unwrap().train_loss;
    let last = hist.final_loss();
    assert!(last.is_finite());
    if !cfg!(miri) {
        assert!(last < (4.0f64).ln() * 0.6, "no convergence under drops: {last}");
        assert!(last < first, "loss did not improve: {first} → {last}");
    }
}

// ---- threaded coordinator --------------------------------------------------

fn coord_cfg(faults: Option<FaultSpec>, delta_down: bool, steps: usize) -> CoordinatorConfig {
    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("qtopk:k=10,bits=4").unwrap()),
        Arc::new(FixedPeriod::new(4)),
    );
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = steps;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    if delta_down {
        cfg.down_compressor = Arc::from(parse_spec("topk:k=40").unwrap());
    }
    cfg.faults = faults;
    cfg
}

fn coord_run(cfg: &CoordinatorConfig, train: &qsparse::data::Dataset) -> History {
    run_threaded(
        cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train.clone()),
        None,
    )
    .unwrap()
}

/// Duplication and delay are *absorbed* faults: the per-(worker, step)
/// idempotence guard applies each update exactly once and the sorted
/// barrier fold makes arrival order irrelevant, so a dup/delay-only run is
/// bit-identical to the faultless run — the strongest form of the
/// "duplicated uplink is idempotent, out-of-order application is
/// equivalent" property.
#[test]
fn threaded_dup_and_delay_only_matches_faultless_bit_for_bit() {
    let (train, _) = data();
    let faults = FaultSpec::parse("dup=0.2,delay=0.2:5000,seed=5").unwrap();
    for delta_down in [false, true] {
        let clean = coord_run(&coord_cfg(None, delta_down, steps()), &train);
        let faulty = coord_run(&coord_cfg(Some(faults), delta_down, steps()), &train);
        assert_identical(&clean, &faulty, &format!("dup/delay-only, delta_down={delta_down}"));
    }
}

/// Twin determinism under real threads: the cocktail's decisions are a pure
/// hash of (seed, worker, step, channel), so two runs racing their threads
/// differently must still agree bit for bit.
#[test]
fn threaded_fault_twins_are_bit_identical() {
    let (train, _) = data();
    for delta_down in [false, true] {
        let cfg = coord_cfg(Some(cocktail()), delta_down, steps());
        let a = coord_run(&cfg, &train);
        let b = coord_run(&cfg, &train);
        assert_identical(&a, &b, &format!("threaded twins, delta_down={delta_down}"));
        assert!(a.final_loss().is_finite());
    }
}

/// Convergence under loss on the threaded runtime: dropped updates are
/// acknowledged with `Missed` and re-absorbed by the sender.
#[test]
fn threaded_converges_under_uplink_drops() {
    let (train, _) = data();
    let faults = FaultSpec::parse("drop=0.2,deadline=60000,seed=7").unwrap();
    let hist = coord_run(&coord_cfg(Some(faults), false, long_steps()), &train);
    let last = hist.final_loss();
    assert!(last.is_finite());
    if !cfg!(miri) {
        assert!(last < (4.0f64).ln() * 0.6, "no convergence under drops: {last}");
    }
}

/// Fault injection on the aggregate-on-arrival (async) path has no round
/// barrier to complete, so the config must be rejected up front rather than
/// hanging a worker that waits for a reply the master never queues.
#[test]
fn threaded_faults_require_synchronous_schedule() {
    let (train, _) = data();
    let mut cfg = coord_cfg(Some(cocktail()), false, steps());
    cfg.schedule = Arc::new(qsparse::topology::RandomGaps::generate(4, 4, cfg.steps, 99));
    let err = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train),
        None,
    )
    .unwrap_err();
    assert!(err.to_string().contains("synchronous"), "unexpected error: {err}");
}

// ---- checkpoint/resume -----------------------------------------------------

/// A full engine config for the checkpoint tests: worker momentum, server
/// momentum, compressed downlink — every piece of state the snapshot must
/// carry for the resumed run to be bit-identical.
fn ckpt_spec<'a>(
    m: &'a SoftmaxRegression,
    train: &'a qsparse::data::Dataset,
    test: &'a qsparse::data::Dataset,
    comp: &'a dyn qsparse::compress::Compressor,
    down: &'a dyn qsparse::compress::Compressor,
    sched: &'a FixedPeriod,
) -> TrainSpec<'a> {
    let mut spec = TrainSpec::new(m, train, comp, sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = steps();
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.momentum = 0.5;
    spec.test = Some(test);
    spec.down_compressor = down;
    spec.server_opt = ServerOptSpec::parse("momentum:beta=0.9,lr=0.1").unwrap();
    spec.eval_every = 5;
    spec
}

/// Run to completion, snapshotting along the way; resuming from *every*
/// snapshot must reproduce the uninterrupted run bit for bit — history
/// points, wire-bit counters and final parameters.
#[test]
fn checkpoint_resume_is_bit_identical() {
    let (train, test) = data();
    let m = model();
    let comp = parse_spec("qtopk:k=10,bits=4").unwrap();
    let down = parse_spec("topk:k=40").unwrap();
    let sched = FixedPeriod::new(4);
    let spec = ckpt_spec(&m, &train, &test, comp.as_ref(), down.as_ref(), &sched);
    let fp = spec_fingerprint("integration-faults-checkpoint-spec");
    let init = vec![0.0f32; m.dim()];

    let full = run_from_resumable(&spec, init.clone(), None, fp, 0, &mut |_, _| {}).unwrap();

    let every = (steps() / 3).max(1);
    let mut snaps: Vec<(usize, Vec<u8>)> = Vec::new();
    let checkpointed =
        run_from_resumable(&spec, init.clone(), None, fp, every, &mut |step, bytes| {
            snaps.push((step, bytes))
        })
        .unwrap();
    assert_identical(&full, &checkpointed, "checkpoint emission must not perturb the run");
    assert!(!snaps.is_empty(), "no snapshots emitted at every={every}");

    for (step, bytes) in &snaps {
        let resumed =
            run_from_resumable(&spec, init.clone(), Some(bytes), fp, 0, &mut |_, _| {}).unwrap();
        assert_identical(&full, &resumed, &format!("resume from step {step}"));
    }
}

/// Corrupted, truncated or mismatched checkpoints are structured errors —
/// never a panic, never a silently hybrid run.
#[test]
fn damaged_checkpoints_fail_with_structured_errors() {
    let (train, test) = data();
    let m = model();
    let comp = parse_spec("qtopk:k=10,bits=4").unwrap();
    let down = parse_spec("topk:k=40").unwrap();
    let sched = FixedPeriod::new(4);
    let spec = ckpt_spec(&m, &train, &test, comp.as_ref(), down.as_ref(), &sched);
    let fp = spec_fingerprint("integration-faults-checkpoint-spec");
    let init = vec![0.0f32; m.dim()];

    let every = (steps() / 2).max(1);
    let mut snaps: Vec<(usize, Vec<u8>)> = Vec::new();
    run_from_resumable(&spec, init.clone(), None, fp, every, &mut |step, bytes| {
        snaps.push((step, bytes))
    })
    .unwrap();
    let bytes = snaps.pop().expect("at least one snapshot").1;

    // Wrong spec fingerprint: a checkpoint cannot continue a different run.
    let other = spec_fingerprint("some-other-spec");
    assert_eq!(
        run_from_resumable(&spec, init.clone(), Some(&bytes), other, 0, &mut |_, _| {}).err(),
        Some(CheckpointError::SpecMismatch)
    );

    // Flipped magic byte.
    let mut mangled = bytes.clone();
    mangled[0] ^= 0xff;
    assert_eq!(
        run_from_resumable(&spec, init.clone(), Some(&mangled), fp, 0, &mut |_, _| {}).err(),
        Some(CheckpointError::BadMagic)
    );

    // Every truncation point is an error, never a panic.
    for cut in [0, 3, 4, 5, 12, 40, bytes.len() / 2, bytes.len() - 1] {
        let r = run_from_resumable(&spec, init.clone(), Some(&bytes[..cut]), fp, 0, &mut |_, _| {});
        assert!(r.is_err(), "truncation at {cut} bytes must be rejected");
    }
}
