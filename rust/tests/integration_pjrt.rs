//! PJRT runtime integration: the AOT artifacts must agree with the native
//! rust substrates numerically (same math, two implementations).
//!
//! These tests need `make artifacts` to have run; they are skipped (pass
//! trivially with a notice) when `artifacts/manifest.json` is absent so
//! `cargo test` works on a fresh checkout.

use qsparse::data::{gaussian_clusters, Batch};
use qsparse::grad::{GradModel, Mlp, SoftmaxRegression};
use qsparse::runtime::PjrtRuntime;
use qsparse::util::rng::Pcg64;

fn artifacts() -> Option<PjrtRuntime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    if !PjrtRuntime::backend_available() {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    Some(PjrtRuntime::open("artifacts").expect("open artifacts"))
}

#[test]
fn manifest_lists_models() {
    let Some(rt) = artifacts() else { return };
    let names = rt.manifest().names();
    for required in ["softmax", "mlp", "lm"] {
        assert!(names.contains(&required), "missing artifact {required}");
    }
}

/// PJRT softmax gradient ≈ native rust gradient on identical inputs.
#[test]
fn pjrt_softmax_matches_native_grad() {
    let Some(rt) = artifacts() else { return };
    let model = rt.load_model("softmax").unwrap();
    let e = model.entry.clone();
    // The artifact's λ is 1/60000 (MNIST n); mirror it natively.
    let native = SoftmaxRegression::new(e.feat, e.classes, 1.0 / 60000.0);
    assert_eq!(model.dim(), native.dim());

    let ds = gaussian_clusters(64, e.feat, e.classes, 0.4, 1.0, 3);
    let batch = ds.gather(&(0..e.batch).collect::<Vec<_>>());
    let mut rng = Pcg64::seeded(17);
    let params: Vec<f32> = (0..model.dim()).map(|_| rng.normal_f32() * 0.05).collect();

    let mut g_pjrt = vec![0.0f32; model.dim()];
    let loss_pjrt = model.loss_grad(&params, &batch, &mut g_pjrt);
    let mut g_native = vec![0.0f32; native.dim()];
    let loss_native = native.loss_grad(&params, &batch, &mut g_native);

    assert!(
        (loss_pjrt - loss_native).abs() < 1e-4 * (1.0 + loss_native.abs()),
        "loss: pjrt {loss_pjrt} vs native {loss_native}"
    );
    let mut worst = 0.0f32;
    for (a, b) in g_pjrt.iter().zip(&g_native) {
        worst = worst.max((a - b).abs());
    }
    assert!(worst < 2e-4, "grad max abs diff {worst}");
}

/// PJRT eval agrees with native error rates.
#[test]
fn pjrt_softmax_eval_matches_native() {
    let Some(rt) = artifacts() else { return };
    let model = rt.load_model("softmax").unwrap();
    let e = model.entry.clone();
    let native = SoftmaxRegression::new(e.feat, e.classes, 1.0 / 60000.0);
    let ds = gaussian_clusters(64, e.feat, e.classes, 0.4, 1.0, 5);
    let batch = ds.gather(&(0..e.batch * 4).collect::<Vec<_>>());
    let mut rng = Pcg64::seeded(29);
    let params: Vec<f32> = (0..model.dim()).map(|_| rng.normal_f32() * 0.1).collect();
    let err_p = model.error_rate(&params, &batch);
    let err_n = native.error_rate(&params, &batch);
    assert!(
        (err_p - err_n).abs() <= 0.0 + 1e-9,
        "top1 err: pjrt {err_p} vs native {err_n}"
    );
    let e5_p = model.topn_error_rate(&params, &batch, 5);
    let e5_n = native.topn_error_rate(&params, &batch, 5);
    assert!((e5_p - e5_n).abs() <= 1e-9, "top5 err: {e5_p} vs {e5_n}");
}

/// PJRT MLP loss decreases under plain gradient steps (artifact fwd/bwd is
/// a working training oracle; detailed numerics are covered in pytest).
#[test]
fn pjrt_mlp_trains() {
    let Some(rt) = artifacts() else { return };
    let model = rt.load_model("mlp").unwrap();
    let e = model.entry.clone();
    let mut params = rt.load_init("mlp").unwrap().expect("mlp init");
    let ds = gaussian_clusters(256, e.feat, e.classes, 0.3, 1.0, 9);
    let mut g = vec![0.0f32; model.dim()];
    let batch = ds.gather(&(0..e.batch).collect::<Vec<_>>());
    let l0 = model.loss_grad(&params, &batch, &mut g);
    for step in 0..30 {
        let idx: Vec<usize> = (0..e.batch).map(|i| (step * e.batch + i) % ds.n).collect();
        let b = ds.gather(&idx);
        model.loss_grad(&params, &b, &mut g);
        for (p, gv) in params.iter_mut().zip(&g) {
            *p -= 0.1 * gv;
        }
    }
    let l1 = model.loss_grad(&params, &batch, &mut g);
    assert!(l1 < l0, "mlp artifact did not learn: {l0} → {l1}");
}

/// Native MLP and the JAX MLP share the parameter layout: the exported init
/// vector has the right length and a plausible He-init scale.
#[test]
fn mlp_init_layout_compatible() {
    let Some(rt) = artifacts() else { return };
    let entry = rt.manifest().model("mlp").unwrap().clone();
    let widths: Vec<usize> = vec![entry.feat, 64, entry.classes];
    let native = Mlp::new(widths);
    assert_eq!(native.dim(), entry.d, "flat layout size mismatch");
    let init = rt.load_init("mlp").unwrap().unwrap();
    assert_eq!(init.len(), entry.d);
    let nz = init.iter().filter(|v| **v != 0.0).count();
    // weights nonzero, biases zero: nz = Σ in·out
    assert_eq!(nz, entry.feat * 64 + 64 * entry.classes);
}

/// The LM artifact runs a full grad step and its loss at init is ≈ ln(vocab).
#[test]
fn pjrt_lm_loss_at_init() {
    let Some(rt) = artifacts() else { return };
    let model = rt.load_model("lm").unwrap();
    let e = model.entry.clone();
    let seq = e.seq.unwrap();
    let params = rt.load_init("lm").unwrap().unwrap();
    let mut rng = Pcg64::seeded(41);
    let x: Vec<f32> = (0..e.batch * (seq + 1))
        .map(|_| rng.below(e.classes as u64) as f32)
        .collect();
    let batch = Batch { x, y: vec![0; e.batch], b: e.batch, dim: seq + 1 };
    let mut g = vec![0.0f32; model.dim()];
    let loss = model.loss_grad(&params, &batch, &mut g);
    let expect = (e.classes as f64).ln();
    assert!(
        (loss - expect).abs() < 0.35 * expect,
        "LM init loss {loss} ≉ ln(vocab) {expect}"
    );
    assert!(g.iter().any(|&v| v != 0.0), "zero gradient");
}
