//! Parallel engine ≡ sequential engine, bit for bit.
//!
//! `TrainSpec::threads` must be a pure wall-clock knob: for every operator,
//! sync period, participation policy, downlink mode and thread count the
//! `History` (losses, bit accounting, memory norms, final parameters) has
//! to be identical to the sequential engine's — the engine folds sync
//! updates in worker-index order and every worker draws only from its own
//! salted PCG streams, so thread interleaving must be unobservable.

use qsparse::compress::parse_spec;
use qsparse::engine::{run, History, TrainSpec};
use qsparse::grad::SoftmaxRegression;
use qsparse::optim::LrSchedule;
use qsparse::protocol::AggScale;
use qsparse::topology::{FixedPeriod, ParticipationSpec};

const N: usize = 240;
const WORKERS: usize = 8;
const STEPS: usize = 60;

fn data() -> qsparse::data::Dataset {
    qsparse::data::gaussian_clusters(N, 12, 4, 1.5, 0.5, 77)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(12, 4, 1.0 / N as f64)
}

/// Bitwise history equality — not tolerance-based: f64 metrics compared by
/// bit pattern, parameters and bit counters by Eq.
fn assert_bit_identical(a: &History, b: &History, ctx: &str) {
    assert_eq!(a.final_params, b.final_params, "{ctx}: final params differ");
    let asteps: Vec<usize> = a.points.iter().map(|p| p.step).collect();
    let bsteps: Vec<usize> = b.points.iter().map(|p| p.step).collect();
    assert_eq!(asteps, bsteps, "{ctx}: metric grids differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        let s = pa.step;
        assert_eq!(pa.bits_up, pb.bits_up, "{ctx}: bits_up at step {s}");
        assert_eq!(pa.bits_down, pb.bits_down, "{ctx}: bits_down at step {s}");
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{ctx}: train_loss at step {s} ({} vs {})",
            pa.train_loss,
            pb.train_loss
        );
        assert_eq!(
            pa.mem_norm_sq.to_bits(),
            pb.mem_norm_sq.to_bits(),
            "{ctx}: mem_norm_sq at step {s}"
        );
    }
}

fn run_cfg(
    up: &str,
    down: &str,
    h: usize,
    part: &str,
    scale: AggScale,
    threads: usize,
) -> History {
    let ds = data();
    let m = model();
    let upc = parse_spec(up).unwrap();
    let downc = parse_spec(down).unwrap();
    let sched = FixedPeriod::new(h);
    let participation = ParticipationSpec::parse(part)
        .unwrap()
        .materialize(WORKERS, STEPS, 5);
    let mut spec = TrainSpec::new(&m, &ds, upc.as_ref(), &sched);
    spec.down_compressor = downc.as_ref();
    spec.workers = WORKERS;
    spec.batch = 4;
    spec.steps = STEPS;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.participation = &participation;
    spec.agg_scale = scale;
    spec.eval_every = 7; // off-grid vs H — exercises between-round metrics
    spec.seed = 5;
    spec.threads = threads;
    run(&spec)
}

/// Operator × sync-period grid, full participation, dense downlink (the
/// paper's setting): thread counts 1/2/8 must agree bit for bit.
#[test]
#[cfg_attr(miri, ignore)] // heavy sweeps — integration_master_parallel has miri_ twins
fn parallel_bit_identical_across_operators_and_h() {
    for up in ["topk:k=10", "qtopk:k=10,bits=4", "signtopk:k=10,m=1", "qsgd:bits=4"] {
        for h in [1usize, 4] {
            let seq = run_cfg(up, "identity", h, "full", AggScale::Workers, 1);
            assert!(
                seq.final_loss().is_finite() && seq.total_bits_up() > 0,
                "{up} H={h}: degenerate baseline"
            );
            for threads in [2usize, 8] {
                let par = run_cfg(up, "identity", h, "full", AggScale::Workers, threads);
                assert_bit_identical(&seq, &par, &format!("{up} H={h} threads={threads}"));
            }
        }
    }
}

/// Sampled participation (both policies and both fold scales) combined with
/// a compressed downlink: the hardest case — per-worker downlink state and
/// RNG streams advance only for participants, in worker order.
#[test]
#[cfg_attr(miri, ignore)]
fn parallel_bit_identical_sampled_participation_compressed_downlink() {
    for (part, scale) in [
        ("fixed:5", AggScale::Participants),
        ("bernoulli:0.5", AggScale::Workers),
    ] {
        for down in ["topk:k=8", "qsgd:bits=2"] {
            for h in [1usize, 4] {
                let seq = run_cfg("qtopk:k=10,bits=4", down, h, part, scale, 1);
                for threads in [2usize, 8] {
                    let par = run_cfg("qtopk:k=10,bits=4", down, h, part, scale, threads);
                    assert_bit_identical(
                        &seq,
                        &par,
                        &format!("part={part} down={down} H={h} threads={threads}"),
                    );
                }
            }
        }
    }
}

/// Thread-count sweep incl. auto (`threads = 0`) and oversubscription
/// (more threads than cores): same bits, same losses.
#[test]
#[cfg_attr(miri, ignore)]
fn parallel_thread_count_sweep_including_auto() {
    let seq = run_cfg("signtopk:k=10,m=1", "topk:k=8", 1, "fixed:5", AggScale::Participants, 1);
    for threads in [0usize, 2, 3, 8] {
        let par = run_cfg(
            "signtopk:k=10,m=1",
            "topk:k=8",
            1,
            "fixed:5",
            AggScale::Participants,
            threads,
        );
        assert_bit_identical(&seq, &par, &format!("threads={threads}"));
    }
}

/// `threads` larger than the worker count clamps cleanly (one worker per
/// pool thread at most) and an H > 1 schedule lets threads run ahead
/// between barriers without reordering anything observable.
#[test]
#[cfg_attr(miri, ignore)]
fn parallel_clamps_threads_to_workers() {
    let seq = run_cfg("topk:k=10", "identity", 4, "full", AggScale::Workers, 1);
    let par = run_cfg("topk:k=10", "identity", 4, "full", AggScale::Workers, 64);
    assert_bit_identical(&seq, &par, "threads=64 (> R=8)");
}
