//! Integration tests: the event-driven network simulator (`sim::`).
//!
//! The simulator is a timing overlay on the engine's arithmetic: timing
//! parameters decide *when* things happen (the virtual clock), never *what*
//! the math computes. Hence the two pillars here: degenerate parity (the
//! sim `History` is bit-identical to `engine::run` for every compressor
//! family and wire codec) and determinism twins (the same spec + seed
//! reproduces the exact per-eval-point FNV state-hash sequence). Queue
//! tie-breaking and bandwidth→duration rounding unit tests live next to
//! the code in `sim::queue` / `sim::client`.

use qsparse::compress::{parse_spec, Codec};
use qsparse::data::{gaussian_clusters, Dataset};
use qsparse::engine::{self, TrainSpec};
use qsparse::grad::SoftmaxRegression;
use qsparse::optim::LrSchedule;
use qsparse::sim::{self, SimSpec};
use qsparse::topology::{FixedPeriod, RandomGaps, SyncSchedule};

fn setup(n: usize) -> (Dataset, SoftmaxRegression) {
    let ds = gaussian_clusters(n, 8, 3, 2.0, 0.4, 7);
    let model = SoftmaxRegression::new(8, 3, 1.0 / n as f64);
    (ds, model)
}

fn base_spec<'a>(
    model: &'a SoftmaxRegression,
    ds: &'a Dataset,
    comp: &'a dyn qsparse::Compressor,
    sched: &'a dyn SyncSchedule,
) -> TrainSpec<'a> {
    let mut spec = TrainSpec::new(model, ds, comp, sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = if cfg!(miri) { 12 } else { 48 };
    spec.eval_every = 8;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec
}

/// Homogeneous speeds, zero latency, sync `H`: the sim must reproduce
/// `engine::run` bit for bit — every metric of every eval point and the
/// final parameters — for each compressor family under both wire codecs,
/// with a compressed (error-compensated) downlink in the loop.
#[test]
fn degenerate_parity_across_compressors_and_codecs() {
    let n = if cfg!(miri) { 48 } else { 200 };
    let (ds, model) = setup(n);
    let sched = FixedPeriod::new(4);
    let down = parse_spec("topk:k=12").unwrap();
    for comp_spec in ["topk:k=6", "qtopk:k=6,bits=4,scaled", "qsgd:bits=4", "signtopk:k=6,m=1"] {
        let comp = parse_spec(comp_spec).unwrap();
        for codec in [Codec::Raw, Codec::Rans] {
            let mut spec = base_spec(&model, &ds, comp.as_ref(), &sched);
            spec.down_compressor = down.as_ref();
            spec.codec = codec;
            let want = engine::run(&spec);
            let got = sim::run(&spec, &SimSpec::default());
            let tag = format!("{comp_spec} codec={}", codec.as_str());
            assert_eq!(got.history.points.len(), want.points.len(), "{tag}");
            for (a, b) in got.history.points.iter().zip(&want.points) {
                assert_eq!(a.step, b.step, "{tag}");
                assert_eq!(
                    a.train_loss.to_bits(),
                    b.train_loss.to_bits(),
                    "{tag} step {}",
                    a.step
                );
                assert_eq!((a.bits_up, a.bits_down), (b.bits_up, b.bits_down), "{tag}");
                assert_eq!(
                    a.mem_norm_sq.to_bits(),
                    b.mem_norm_sq.to_bits(),
                    "{tag} step {}",
                    a.step
                );
            }
            assert_eq!(got.history.final_params, want.final_params, "{tag}");
        }
    }
}

/// Two runs of the same spec + seed under a fully loaded scenario (skewed
/// speeds, stragglers, churn, async gaps) must process the same number of
/// events and emit the identical state-hash sequence; a different seed
/// must not.
#[test]
fn determinism_twin_same_seed_same_hash_sequence() {
    let n = if cfg!(miri) { 48 } else { 160 };
    let (ds, model) = setup(n);
    let comp = parse_spec("qtopk:k=6,bits=4,scaled").unwrap();
    let steps = if cfg!(miri) { 12 } else { 48 };
    let sched = RandomGaps::generate(4, 4, steps, 123);
    let mut spec = base_spec(&model, &ds, comp.as_ref(), &sched);
    spec.steps = steps;
    let scenario = SimSpec {
        compute_sigma: 0.8,
        bw_sigma: 0.5,
        latency: 1_000,
        straggler_prob: 0.1,
        straggler_mult: 5.0,
        churn_online_mean: 60_000,
        churn_offline_mean: 30_000,
        ..SimSpec::default()
    };
    let a = sim::run(&spec, &scenario);
    let b = sim::run(&spec, &scenario);
    assert_eq!(a.events, b.events, "event counts diverged between twins");
    assert_eq!(a.final_ticks, b.final_ticks);
    let ha: Vec<u64> = a.points.iter().map(|p| p.state_hash).collect();
    let hb: Vec<u64> = b.points.iter().map(|p| p.state_hash).collect();
    assert_eq!(ha, hb, "state-hash sequences diverged between twins");
    assert_eq!(a.history.final_params, b.history.final_params);
    // The fingerprint must actually track the trajectory, not be constant.
    assert!(ha.windows(2).any(|w| w[0] != w[1]), "state hash never moved: {ha:?}");
    // And a different seed is a different universe.
    spec.seed ^= 1;
    let c = sim::run(&spec, &scenario);
    assert_ne!(
        c.points.last().map(|p| p.state_hash),
        a.points.last().map(|p| p.state_hash),
        "seed change did not move the final state hash"
    );
}

/// Churn smoke: offline windows make workers skip syncs, yet the run
/// drains (all eval points emitted), the clock stays monotone, the loss
/// stays finite, and skipped uploads can only reduce uplink traffic.
#[test]
fn churn_scenario_completes_and_stays_monotone() {
    let n = if cfg!(miri) { 48 } else { 160 };
    let (ds, model) = setup(n);
    let comp = parse_spec("topk:k=6").unwrap();
    let sched = FixedPeriod::new(4);
    let spec = base_spec(&model, &ds, comp.as_ref(), &sched);
    let churned = sim::run(
        &spec,
        &SimSpec {
            compute_sigma: 0.6,
            churn_online_mean: 40_000,
            churn_offline_mean: 40_000,
            ..SimSpec::default()
        },
    );
    assert_eq!(churned.points.len(), churned.history.points.len());
    let ticks: Vec<u64> = churned.points.iter().map(|p| p.ticks).collect();
    assert!(ticks.windows(2).all(|w| w[0] <= w[1]), "non-monotone clock: {ticks:?}");
    assert!(churned.history.final_loss().is_finite());
    let steady = sim::run(&spec, &SimSpec { compute_sigma: 0.6, ..SimSpec::default() });
    assert!(
        churned.history.total_bits_up() <= steady.history.total_bits_up(),
        "churn increased uplink traffic"
    );
}
