//! The shared protocol core across both execution substrates, and the
//! bidirectional (downlink-compressed) extension.
//!
//! * engine ≡ threaded bit-identity must survive a non-trivial
//!   `down_compressor` (per-worker server-side error feedback + per-worker
//!   RNG streams make this order-independent by construction);
//! * `identity` downlink must reproduce the historical dense-broadcast
//!   semantics exactly (and its bit accounting in closed form);
//! * downlink messages must round-trip the wire encoding, and the
//!   error-feedback recursion must drain worker staleness.

use qsparse::compress::{encode, parse_spec};
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::engine::{run, TrainSpec};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::protocol::MasterCore;
use qsparse::topology::{FixedPeriod, RandomGaps};
use qsparse::util::rng::Pcg64;
use qsparse::util::stats::norm2_sq;
use std::sync::Arc;

const N: usize = 300;

fn data() -> (qsparse::data::Dataset, qsparse::data::Dataset) {
    qsparse::data::gaussian_clusters_split(N, N / 4, 16, 4, 0.5, 1.0, 55)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(16, 4, 1.0 / N as f64)
}

/// Synchronous schedules barrier in the master, so the threaded run must be
/// *bit-identical* to the engine — including when the downlink broadcasts
/// compressed deltas (deterministic and stochastic operators alike).
#[test]
fn threaded_sync_bitexact_vs_engine_with_compressed_downlink() {
    let (train, test) = data();
    let m = model();
    for (up_spec, down_spec) in [
        ("topk:k=10", "topk:k=16"),
        ("qtopk:k=10,bits=4", "qsgd:bits=4"),
        ("identity", "signtopk:k=12,m=1"),
    ] {
        let up = parse_spec(up_spec).unwrap();
        let down = parse_spec(down_spec).unwrap();
        let sched = FixedPeriod::new(4);
        let mut spec = TrainSpec::new(&m, &train, up.as_ref(), &sched);
        spec.down_compressor = down.as_ref();
        spec.workers = 4;
        spec.batch = 4;
        spec.steps = 80;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.test = Some(&test);
        let engine_hist = run(&spec);

        let mut cfg = CoordinatorConfig::new(
            Arc::from(parse_spec(up_spec).unwrap()),
            Arc::new(FixedPeriod::new(4)),
        );
        cfg.down_compressor = Arc::from(parse_spec(down_spec).unwrap());
        cfg.workers = 4;
        cfg.batch = 4;
        cfg.steps = 80;
        cfg.lr = LrSchedule::Const { eta: 0.3 };
        cfg.seed = spec.seed;
        let threaded_hist = run_threaded(
            &cfg,
            || Box::new(model()) as Box<dyn GradModel>,
            Arc::new(train.clone()),
            Some(Arc::new(test.clone())),
        )
        .unwrap();

        assert_eq!(
            engine_hist.final_params, threaded_hist.final_params,
            "{up_spec}⇑ {down_spec}⇓: threaded sync run diverged from the engine"
        );
        assert_eq!(
            engine_hist.total_bits_up(),
            threaded_hist.total_bits_up(),
            "{up_spec}⇑ {down_spec}⇓: uplink bit accounting differs"
        );
        assert_eq!(
            engine_hist.total_bits_down(),
            threaded_hist.total_bits_down(),
            "{up_spec}⇑ {down_spec}⇓: downlink bit accounting differs"
        );
        let egrid: Vec<usize> = engine_hist.points.iter().map(|p| p.step).collect();
        let tgrid: Vec<usize> = threaded_hist.points.iter().map(|p| p.step).collect();
        assert_eq!(egrid, tgrid, "{up_spec}⇑ {down_spec}⇓: metric step grids differ");
    }
}

/// `identity` downlink is the historical dense broadcast: the explicit spec
/// and the default must take the same path, and bits_down must equal the
/// closed-form dense accounting (one encoded dense model per worker per
/// sync) — no hidden delta encoding.
#[test]
fn identity_downlink_is_dense_broadcast() {
    let (train, _test) = data();
    let m = model();
    let up = parse_spec("topk:k=8").unwrap();
    let sched = FixedPeriod::new(2);

    let mk = |explicit_down: bool| {
        let down = parse_spec("identity").unwrap();
        let mut spec = TrainSpec::new(&m, &train, up.as_ref(), &sched);
        if explicit_down {
            spec.down_compressor = down.as_ref();
        }
        spec.workers = 5;
        spec.batch = 4;
        spec.steps = 60;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        run(&spec)
    };
    let default_down = mk(false);
    let explicit_down = mk(true);
    assert_eq!(default_down.final_params, explicit_down.final_params);

    // 60 steps, H=2 ⇒ 30 sync rounds × 5 workers, one dense model each.
    let d = m.dim();
    let expect = 30 * 5 * encode::dense_model_bits(d);
    assert_eq!(default_down.total_bits_down(), expect);
}

/// Downlink protocol property: over drifting global models, every broadcast
/// message round-trips `encode`/`decode` exactly, anchors reconstructed from
/// decoded deltas track the master's view, and freezing the model drains the
/// staleness through error feedback.
#[test]
fn prop_downlink_roundtrip_and_staleness_drain() {
    let mut rng = Pcg64::seeded(0xD0_11CE);
    for trial in 0..12 {
        let d = 16 + rng.below_usize(64);
        let workers = 1 + rng.below_usize(4);
        let down_specs =
            ["topk:k=4", "randk:k=6", "qsgd:bits=4", "signtopk:k=6,m=1", "qtopk:k=5,bits=2"];
        let down = parse_spec(down_specs[trial % down_specs.len()]).unwrap();

        let init: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let mut master = MasterCore::new(init.clone(), workers, trial as u64, true);
        let mut anchors = vec![init; workers];

        for _round in 0..8 {
            let drift: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.05).collect();
            master
                .apply_update(&qsparse::Message::Dense { values: drift })
                .unwrap();
            for (r, anchor) in anchors.iter_mut().enumerate() {
                let msg = master.delta_broadcast(r, down.as_ref());
                // Wire round-trip is exact.
                let (bytes, bit_len) = encode::encode(&msg);
                let back = encode::decode(&bytes, bit_len).expect("downlink decode");
                assert_eq!(msg, back, "trial {trial}: downlink message mangled on the wire");
                back.add_into(anchor, 1.0);
                // Server memory ≡ global − anchor (up to f32 rounding).
                let resid: Vec<f32> = master
                    .params()
                    .iter()
                    .zip(anchor.iter())
                    .map(|(g, a)| g - a)
                    .collect();
                let mem = master.down_memory(r).unwrap();
                let diff: Vec<f32> = resid.iter().zip(mem).map(|(x, y)| x - y).collect();
                assert!(
                    norm2_sq(&diff) <= 1e-6 * (1.0 + norm2_sq(&resid)),
                    "trial {trial}: server memory drifted from anchor staleness"
                );
            }
        }
        // Freeze the model; EF must re-offer everything that was dropped.
        let before: f64 = (0..workers).map(|r| norm2_sq(&master.down_memory(r).unwrap())).sum();
        for _round in 0..120 {
            for (r, anchor) in anchors.iter_mut().enumerate() {
                let msg = master.delta_broadcast(r, down.as_ref());
                msg.add_into(anchor, 1.0);
            }
        }
        let after: f64 = (0..workers).map(|r| norm2_sq(&master.down_memory(r).unwrap())).sum();
        assert!(
            after <= 0.2 * before + 1e-9,
            "trial {trial}: staleness did not drain ({before:.3e} → {after:.3e})"
        );
    }
}

/// The asynchronous (aggregate-on-arrival) threaded path works with a
/// compressed downlink: per-worker server memories keep anchors consistent
/// even though workers sync at different steps, and the run converges.
#[test]
fn threaded_async_with_compressed_downlink_converges() {
    let (train, test) = data();
    let steps = 150;
    let sched = RandomGaps::generate(4, 6, steps, 999);
    // One broadcast per sync point per worker — the dense baseline in bits.
    let total_syncs: u64 = (0..4).map(|r| sched.points(r).len() as u64).sum();
    let dense_baseline = total_syncs * encode::dense_model_bits(model().dim());

    let mut cfg =
        CoordinatorConfig::new(Arc::from(parse_spec("topk:k=10").unwrap()), Arc::new(sched));
    cfg.down_compressor = Arc::from(parse_spec("topk:k=8").unwrap());
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = steps;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    let hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train),
        Some(Arc::new(test)),
    )
    .unwrap();
    assert!(
        hist.final_loss() < (4.0f64).ln() * 0.7,
        "async compressed-downlink run did not converge: {}",
        hist.final_loss()
    );
    assert!(hist.total_bits_up() > 0);
    // Compressed downlink must actually beat the dense accounting (a silent
    // fallback to dense broadcasts would fail this).
    let bd = hist.total_bits_down();
    assert!(bd > 0);
    assert!(
        bd * 4 < dense_baseline,
        "async downlink not compressed: {bd} vs dense baseline {dense_baseline}"
    );
}

/// Threaded runs now report the worker error-memory norm (it was NaN before
/// the protocol refactor) and it matches the engine's under a synchronous
/// schedule.
#[test]
fn threaded_reports_mem_norm_matching_engine() {
    let (train, test) = data();
    let m = model();
    let up = parse_spec("topk:k=6").unwrap();
    let sched = FixedPeriod::new(4);
    let mut spec = TrainSpec::new(&m, &train, up.as_ref(), &sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = 80;
    spec.eval_every = 4; // align eval points with the H=4 barriers
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.test = Some(&test);
    let engine_hist = run(&spec);

    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("topk:k=6").unwrap()),
        Arc::new(FixedPeriod::new(4)),
    );
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = 80;
    cfg.eval_every = 4;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    let threaded_hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train.clone()),
        Some(Arc::new(test.clone())),
    )
    .unwrap();

    // Memory changes only at syncs, so at matching eval steps the threaded
    // aggregate of last-reported ‖m‖² equals the engine's live average.
    let mut checked = 0;
    for ep in &engine_hist.points {
        if let Some(tp) = threaded_hist.points.iter().find(|p| p.step == ep.step) {
            assert!(
                !tp.mem_norm_sq.is_nan(),
                "threaded mem_norm_sq still NaN at step {}",
                tp.step
            );
            assert!(
                (tp.mem_norm_sq - ep.mem_norm_sq).abs()
                    <= 1e-9 * (1.0 + ep.mem_norm_sq.abs()),
                "step {}: threaded mem {} vs engine {}",
                ep.step,
                tp.mem_norm_sq,
                ep.mem_norm_sq
            );
            checked += 1;
        }
    }
    assert!(checked >= 3, "too few comparable eval points ({checked})");
}
