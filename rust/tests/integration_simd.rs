//! Forced-scalar vs auto SIMD dispatch: end-to-end bit identity.
//!
//! The SIMD backends (`simd::avx2` / `simd::neon`) are drop-in twins of the
//! portable scalar kernels — same bits, different instructions. The unit
//! property tests in `rust/src/simd/mod.rs` prove each kernel matches on
//! adversarial inputs; this suite proves the contract survives composition:
//! whole training `History`s (losses, bit accounting, memory norms, final
//! parameters), wire bytes, and top-k supports must be identical whether
//! dispatch lands on the vector path or is pinned to scalar via
//! `force_backend`.
//!
//! The backend override is process-global and the test harness is
//! multi-threaded, so every flip happens under one static mutex.

use qsparse::compress::sparsify::{top_k_indices, top_k_indices_into, TopKScratch};
use qsparse::compress::{encode, parse_spec, Codec};
use qsparse::engine::{run, History, TrainSpec};
use qsparse::grad::SoftmaxRegression;
use qsparse::optim::LrSchedule;
use qsparse::simd::{force_backend, Backend};
use qsparse::topology::FixedPeriod;
use qsparse::util::rng::Pcg64;
use std::sync::Mutex;

/// Serializes `force_backend` flips across this binary's test threads.
static BACKEND_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` twice under the lock — pinned to scalar, then on auto detection
/// — and return both results. Restores auto dispatch before releasing. On
/// a machine whose detection already lands on scalar (or under
/// `QSPARSE_FORCE_SCALAR=1`) both runs take the same path and the
/// comparison is trivially true — the CI default job is the one with AVX2.
fn scalar_vs_auto<T>(mut f: impl FnMut() -> T) -> (T, T) {
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    force_backend(Some(Backend::Scalar));
    let s = f();
    force_backend(None);
    let a = f();
    (s, a)
}

const N: usize = 240;

/// Bitwise history equality — not tolerance-based: f64 metrics compared by
/// bit pattern, parameters and bit counters by Eq.
fn assert_bit_identical(a: &History, b: &History, ctx: &str) {
    assert_eq!(a.final_params, b.final_params, "{ctx}: final params differ");
    let asteps: Vec<usize> = a.points.iter().map(|p| p.step).collect();
    let bsteps: Vec<usize> = b.points.iter().map(|p| p.step).collect();
    assert_eq!(asteps, bsteps, "{ctx}: metric grids differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        let s = pa.step;
        assert_eq!(pa.bits_up, pb.bits_up, "{ctx}: bits_up at step {s}");
        assert_eq!(pa.bits_down, pb.bits_down, "{ctx}: bits_down at step {s}");
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{ctx}: train_loss at step {s} ({} vs {})",
            pa.train_loss,
            pb.train_loss
        );
        assert_eq!(
            pa.mem_norm_sq.to_bits(),
            pb.mem_norm_sq.to_bits(),
            "{ctx}: mem_norm_sq at step {s}"
        );
    }
}

fn run_cfg(up: &str, codec: Codec) -> History {
    let ds = qsparse::data::gaussian_clusters(N, 12, 4, 1.5, 0.5, 77);
    let m = SoftmaxRegression::new(12, 4, 1.0 / N as f64);
    let upc = parse_spec(up).unwrap();
    let sched = FixedPeriod::new(2);
    let mut spec = TrainSpec::new(&m, &ds, upc.as_ref(), &sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = 40;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.eval_every = 7; // off-grid vs H — exercises between-round metrics
    spec.seed = 5;
    spec.codec = codec;
    run(&spec)
}

/// Whole-training parity: every operator family whose hot path routes
/// through the SIMD kernels (top-k keying/scan, QSGD quantization, the
/// fold, wire bit accounting), under both wire codecs.
#[test]
#[cfg_attr(miri, ignore)] // heavy sweep; the simd unit tests cover Miri
fn history_bit_identical_forced_scalar_vs_auto() {
    for up in ["topk:k=8", "qtopk:k=8,bits=4", "qsgd:bits=4", "signtopk:k=8,m=1"] {
        for codec in [Codec::Raw, Codec::Rans] {
            let (s, a) = scalar_vs_auto(|| run_cfg(up, codec));
            assert!(
                s.final_loss().is_finite() && s.total_bits_up() > 0,
                "{up} {}: degenerate baseline",
                codec.as_str()
            );
            assert_bit_identical(&s, &a, &format!("{up} codec={}", codec.as_str()));
        }
    }
}

/// A deterministic gradient-like vector with exact ties, denormals and
/// signed zeros sprinkled at lane/chunk boundaries.
fn adversarial_grad(d: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::seeded(seed);
    let mut x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    for base in (0..d).step_by(97) {
        x[base] = 2.0; // exact tie class
        if base + 7 < d {
            x[base + 7] = f32::from_bits(1); // smallest denormal
        }
        if base + 8 < d {
            x[base + 8] = -f32::from_bits(1);
        }
        if base + 15 < d {
            x[base + 15] = 0.0;
        }
        if base + 16 < d {
            x[base + 16] = -0.0;
        }
        if base + 31 < d {
            x[base + 31] = -2.0; // tie in magnitude, opposite sign
        }
        if base + 32 < d {
            x[base + 32] = f32::MIN_POSITIVE / 2.0;
        }
    }
    x
}

/// Wire parity: compress + encode under each backend must produce the same
/// message and the same bytes, and decoding those bytes must round-trip —
/// covering the bulk `BitWriter` writes and the fixed-width index unpack.
#[test]
fn encoded_bytes_identical_forced_scalar_vs_auto() {
    let x = adversarial_grad(1000, 97);
    for up in ["topk:k=50", "qtopk:k=50,bits=4", "qsgd:bits=4", "signtopk:k=50,m=1"] {
        let (s, a) = scalar_vs_auto(|| {
            let op = parse_spec(up).unwrap();
            let mut rng = Pcg64::seeded(131);
            let msg = op.compress(&x, &mut rng);
            let (bytes, bit_len) = encode::encode(&msg);
            let decoded = encode::decode(&bytes, bit_len).expect("self-encoded bytes decode");
            (msg, bytes, bit_len, decoded)
        });
        assert_eq!(s.0, a.0, "{up}: compressed messages differ");
        assert_eq!(s.1, a.1, "{up}: wire bytes differ");
        assert_eq!(s.2, a.2, "{up}: wire bit lengths differ");
        assert_eq!(s.3, a.3, "{up}: decoded messages differ");
        assert_eq!(s.0, s.3, "{up}: round-trip changed the message");
    }
}

/// Magnitude key used by top-k ordering (NaN lowest, |v| bit order).
fn mag_key(v: f32) -> u32 {
    if v.is_nan() {
        0
    } else {
        v.abs().to_bits()
    }
}

/// The selected support is a valid top-k set: every selected magnitude is
/// ≥ every unselected one.
fn assert_valid_topk(x: &[f32], idx: &[u32], k: usize, ctx: &str) {
    assert_eq!(idx.len(), k, "{ctx}: wrong support size");
    let sel: std::collections::BTreeSet<u32> = idx.iter().copied().collect();
    assert_eq!(sel.len(), k, "{ctx}: duplicate indices");
    let min_sel = idx.iter().map(|&i| mag_key(x[i as usize])).min().unwrap_or(0);
    let max_unsel = (0..x.len() as u32)
        .filter(|i| !sel.contains(i))
        .map(|i| mag_key(x[i as usize]))
        .max()
        .unwrap_or(0);
    assert!(
        min_sel >= max_unsel,
        "{ctx}: selected magnitude below an unselected one ({min_sel} < {max_unsel})"
    );
}

/// All-equal input: every index set is a valid top-k, so this pins the
/// tie-break itself — the support must not depend on the backend, at a
/// length (37) that straddles both the 4-lane and 8-lane boundaries.
#[test]
fn top_k_tie_break_is_backend_independent() {
    let x = vec![1.0f32; 37];
    for k in [7usize, 8] {
        let (s, a) = scalar_vs_auto(|| top_k_indices(&x, k));
        assert_valid_topk(&x, &s, k, &format!("all-equal k={k}"));
        assert_eq!(s, a, "all-equal d=37 k={k}: backends disagree");
    }
}

/// Denormals, signed zeros and magnitude ties placed at lane boundaries:
/// the packed-key path must rank them identically on every backend.
#[test]
fn top_k_denormals_and_zeros_at_lane_boundaries() {
    let x = adversarial_grad(40, 7);
    for k in [1usize, 7, 8, 9, 16, 33, 39, 40] {
        let (s, a) = scalar_vs_auto(|| top_k_indices(&x, k));
        assert_valid_topk(&x, &s, k, &format!("d=40 k={k}"));
        assert_eq!(s, a, "d=40 k={k}: backends disagree");
    }
}

/// Large-d sampled-threshold path (d ≥ 2^16, k·8 < d): the strided sample,
/// the threshold scan with its cap-abort, and the candidate select must all
/// agree across backends — including with tie classes big enough that the
/// threshold lands inside one.
#[test]
#[cfg_attr(miri, ignore)] // 2^17 elements is interpreter-hostile
fn top_k_sampled_path_is_backend_independent() {
    let d = 1usize << 17;
    let x = adversarial_grad(d, 23);
    for k in [64usize, 500] {
        let (s, a) = scalar_vs_auto(|| {
            let mut out = Vec::new();
            let mut scratch = TopKScratch::default();
            top_k_indices_into(&x, k, &mut out, &mut scratch);
            out
        });
        assert_valid_topk(&x, &s, k, &format!("sampled d=2^17 k={k}"));
        assert_eq!(s, a, "sampled d=2^17 k={k}: backends disagree");
    }
}
