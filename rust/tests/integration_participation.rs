//! Sampled partial participation: engine ≡ threaded bit-identity under
//! sampled worker subsets, unbiasedness of the `1/|S_t|` fold, determinism
//! of the materialized participant sets, and exact backward compatibility
//! of `p = 1.0` + `1/R` with the full-participation code path.

use qsparse::compress::parse_spec;
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::engine::{run, History, TrainSpec};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::protocol::{AggScale, MasterCore};
use qsparse::topology::{FixedPeriod, ParticipationSpec, RandomGaps};
use qsparse::Message;
use std::sync::Arc;

const N: usize = 300;

fn data() -> (qsparse::data::Dataset, qsparse::data::Dataset) {
    qsparse::data::gaussian_clusters_split(N, N / 4, 16, 4, 0.5, 1.0, 55)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(16, 4, 1.0 / N as f64)
}

fn feq(a: f64, b: f64) -> bool {
    (a.is_nan() && b.is_nan()) || a == b
}

/// Both histories must sample the same steps and carry identical values —
/// the engine/threaded comparability guarantee the figures rely on.
fn assert_histories_identical(e: &History, t: &History, ctx: &str) {
    let es: Vec<usize> = e.points.iter().map(|p| p.step).collect();
    let ts: Vec<usize> = t.points.iter().map(|p| p.step).collect();
    assert_eq!(es, ts, "{ctx}: metric step grids differ");
    for (ep, tp) in e.points.iter().zip(&t.points) {
        assert_eq!(ep.bits_up, tp.bits_up, "{ctx}: bits_up at step {}", ep.step);
        assert_eq!(ep.bits_down, tp.bits_down, "{ctx}: bits_down at step {}", ep.step);
        assert!(
            feq(ep.train_loss, tp.train_loss),
            "{ctx}: train_loss at step {}: {} vs {}",
            ep.step,
            ep.train_loss,
            tp.train_loss
        );
        assert!(
            feq(ep.test_err, tp.test_err),
            "{ctx}: test_err at step {}: {} vs {}",
            ep.step,
            ep.test_err,
            tp.test_err
        );
        assert!(
            feq(ep.mem_norm_sq, tp.mem_norm_sq),
            "{ctx}: mem_norm_sq at step {}: {} vs {}",
            ep.step,
            ep.mem_norm_sq,
            tp.mem_norm_sq
        );
    }
    assert_eq!(e.final_params, t.final_params, "{ctx}: final params diverged");
}

/// The acceptance test: H > 1, a stochastic non-Identity downlink, sampled
/// participation, unbiased scaling — the threaded run must still reproduce
/// the engine's `History` exactly (same steps, same values), because rounds
/// are applied in step order with per-round |S_t| barriers.
#[test]
fn engine_threaded_bitexact_under_sampled_participation() {
    let (train, test) = data();
    let m = model();
    let steps = 80;
    let workers = 6;
    // Full-participation reference for the bits-thinning check below.
    let full_bits = {
        let up = parse_spec("topk:k=10").unwrap();
        let down = parse_spec("qtopk:k=16,bits=4").unwrap();
        let sched = FixedPeriod::new(4);
        let mut spec = TrainSpec::new(&m, &train, up.as_ref(), &sched);
        spec.down_compressor = down.as_ref();
        spec.workers = workers;
        spec.batch = 4;
        spec.steps = steps;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.test = Some(&test);
        run(&spec).total_bits_up()
    };
    for (part_spec, scale) in [
        ("fixed:3", AggScale::Participants),
        ("bernoulli:0.5", AggScale::Participants),
        ("bernoulli:0.5", AggScale::Workers),
    ] {
        let participation =
            ParticipationSpec::parse(part_spec).unwrap().materialize(workers, steps, 0);
        let up = parse_spec("topk:k=10").unwrap();
        let down = parse_spec("qtopk:k=16,bits=4").unwrap();
        let sched = FixedPeriod::new(4);
        let mut spec = TrainSpec::new(&m, &train, up.as_ref(), &sched);
        spec.down_compressor = down.as_ref();
        spec.participation = &participation;
        spec.agg_scale = scale;
        spec.workers = workers;
        spec.batch = 4;
        spec.steps = steps;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.test = Some(&test);
        let engine_hist = run(&spec);

        let mut cfg = CoordinatorConfig::new(
            Arc::from(parse_spec("topk:k=10").unwrap()),
            Arc::new(FixedPeriod::new(4)),
        );
        cfg.down_compressor = Arc::from(parse_spec("qtopk:k=16,bits=4").unwrap());
        cfg.participation = participation.clone();
        cfg.agg_scale = scale;
        cfg.workers = workers;
        cfg.batch = 4;
        cfg.steps = steps;
        cfg.lr = LrSchedule::Const { eta: 0.3 };
        cfg.seed = spec.seed;
        // Same eval subsets as the engine run, so metric *values* (not just
        // the step grid) must agree bit-for-bit.
        cfg.eval_rows = spec.eval_rows;
        let threaded_hist = run_threaded(
            &cfg,
            || Box::new(model()) as Box<dyn GradModel>,
            Arc::new(train.clone()),
            Some(Arc::new(test.clone())),
        )
        .unwrap();

        assert_histories_identical(
            &engine_hist,
            &threaded_hist,
            &format!("{part_spec}/{scale:?}"),
        );
        // Sampling must actually have thinned the rounds: strictly fewer
        // uplink bits than the full-participation run (a regression that
        // ignored `Participation` would keep the substrates in agreement
        // with each other but fail this).
        let bits = engine_hist.total_bits_up();
        assert!(
            bits > 0 && bits < full_bits,
            "{part_spec}: sampled bits {bits} not below full-participation {full_bits}"
        );
    }
}

/// `p = 1.0` participation with the paper's `1/R` fold is the identity
/// configuration: it must reproduce the default (full-participation) seeded
/// trajectory bit-for-bit, on both substrates.
#[test]
fn full_participation_one_over_r_is_bitexact_backcompat() {
    let (train, test) = data();
    let m = model();
    let steps = 80;
    let mk_engine = |explicit: bool| {
        let up = parse_spec("signtopk:k=10,m=1").unwrap();
        let sched = FixedPeriod::new(4);
        let participation =
            ParticipationSpec::parse("bernoulli:1.0").unwrap().materialize(4, steps, 0);
        let mut spec = TrainSpec::new(&m, &train, up.as_ref(), &sched);
        if explicit {
            spec.participation = &participation;
            spec.agg_scale = AggScale::Workers;
        }
        spec.workers = 4;
        spec.batch = 4;
        spec.steps = steps;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.test = Some(&test);
        run(&spec)
    };
    let default_hist = mk_engine(false);
    let explicit_hist = mk_engine(true);
    assert_histories_identical(&default_hist, &explicit_hist, "engine p=1.0 vs default");

    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("signtopk:k=10,m=1").unwrap()),
        Arc::new(FixedPeriod::new(4)),
    );
    cfg.participation =
        ParticipationSpec::parse("bernoulli:1.0").unwrap().materialize(4, steps, 0);
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = steps;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    cfg.eval_rows = 512; // match TrainSpec::new's eval subset exactly
    let threaded_hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train.clone()),
        Some(Arc::new(test.clone())),
    )
    .unwrap();
    assert_histories_identical(&default_hist, &threaded_hist, "threaded p=1.0 vs default");
}

/// The `1/|S_t|` fold is unbiased: over many sampled rounds with fixed
/// per-worker updates, the mean round step matches the full-participation
/// step, while the paper's `1/R` fold under sampling is biased low by
/// exactly E|S_t|/R.
#[test]
fn participant_scaling_unbiased_in_expectation() {
    let d = 32;
    let r_count = 10;
    let m = 4;
    let rounds = 6000;
    let mut rng = qsparse::util::rng::Pcg64::seeded(77);
    let updates: Vec<Vec<f32>> = (0..r_count)
        .map(|_| (0..d).map(|_| rng.normal_f32() * 0.01).collect())
        .collect();
    let part = ParticipationSpec::FixedSize { m }.materialize(r_count, rounds, 123);

    let run_sampled = |scale: AggScale| -> Vec<f32> {
        let mut master = MasterCore::new(vec![0.0; d], r_count, 0, false);
        master.set_agg_scale(scale);
        for t in 0..rounds {
            let s_t: Vec<usize> = (0..r_count).filter(|&r| part.participates(r, t)).collect();
            master.begin_round(s_t.len());
            for r in s_t {
                master
                    .apply_update(&Message::Dense { values: updates[r].clone() })
                    .unwrap();
            }
        }
        master.into_params()
    };

    // Full participation, 1/R — the reference drift.
    let mut full = MasterCore::new(vec![0.0; d], r_count, 0, false);
    for _t in 0..rounds {
        full.begin_round(r_count);
        for g in &updates {
            full.apply_update(&Message::Dense { values: g.clone() }).unwrap();
        }
    }
    let x_full = full.into_params();

    let norm = |x: &[f32]| x.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
    let dist = |a: &[f32], b: &[f32]| {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| ((x - y) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };

    let x_unbiased = run_sampled(AggScale::Participants);
    assert!(
        dist(&x_unbiased, &x_full) < 0.1 * norm(&x_full),
        "1/|S_t| drift {} deviates from full-participation drift {} by {}",
        norm(&x_unbiased),
        norm(&x_full),
        dist(&x_unbiased, &x_full)
    );

    // 1/R under m-of-R sampling under-steps by ≈ m/R = 0.4.
    let x_biased = run_sampled(AggScale::Workers);
    let ratio = norm(&x_biased) / norm(&x_full);
    assert!(
        (0.3..0.5).contains(&ratio),
        "1/R under sampling should shrink the step by ≈ m/R = 0.4, got {ratio}"
    );
}

/// The aggregate-on-arrival (asynchronous) threaded path also honors
/// sampled participation and the unbiased scale: the run converges, bits
/// flow, and metrics sit on the engine's step grid.
#[test]
fn threaded_async_with_sampled_participation_converges() {
    let (train, test) = data();
    let steps = 150;
    let sched = RandomGaps::generate(4, 6, steps, 999);
    let participation =
        ParticipationSpec::parse("bernoulli:0.5").unwrap().materialize(4, steps, 7);
    let mut cfg =
        CoordinatorConfig::new(Arc::from(parse_spec("topk:k=10").unwrap()), Arc::new(sched));
    cfg.down_compressor = Arc::from(parse_spec("topk:k=8").unwrap());
    cfg.participation = participation;
    cfg.agg_scale = AggScale::Participants;
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = steps;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    let hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train),
        Some(Arc::new(test)),
    )
    .unwrap();
    assert!(
        hist.final_loss() < 1.0,
        "async sampled-participation run did not converge: {}",
        hist.final_loss()
    );
    assert!(hist.total_bits_up() > 0 && hist.total_bits_down() > 0);
    // Engine metric grid: 0, 10, …, 150.
    let grid: Vec<usize> = hist.points.iter().map(|p| p.step).collect();
    let expect: Vec<usize> = (0..=15).map(|k| k * 10).collect();
    assert_eq!(grid, expect, "async path off the engine step grid");
}
