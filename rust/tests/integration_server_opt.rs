//! FedOpt-style server optimizers: `Avg` stays bit-identical to the
//! historical aggregation, non-`Avg` optimizers are bit-identical across
//! every execution substrate (sequential engine, parallel engine, threaded
//! coordinator), compose with compressed downlink + sampled participation,
//! and actually optimize.

use qsparse::compress::parse_spec;
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::engine::{run, History, TrainSpec};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::{LrSchedule, ServerOptSpec};
use qsparse::protocol::AggScale;
use qsparse::topology::{FixedPeriod, ParticipationSpec, RandomGaps};
use std::sync::Arc;

const N: usize = 240;
const WORKERS: usize = 8;
const STEPS: usize = 60;

fn data() -> (qsparse::data::Dataset, qsparse::data::Dataset) {
    qsparse::data::gaussian_clusters_split(N, N / 4, 12, 4, 1.5, 0.5, 77)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(12, 4, 1.0 / N as f64)
}

fn feq(a: f64, b: f64) -> bool {
    (a.is_nan() && b.is_nan()) || a.to_bits() == b.to_bits()
}

fn assert_histories_identical(a: &History, b: &History, ctx: &str) {
    let sa: Vec<usize> = a.points.iter().map(|p| p.step).collect();
    let sb: Vec<usize> = b.points.iter().map(|p| p.step).collect();
    assert_eq!(sa, sb, "{ctx}: metric step grids differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.bits_up, pb.bits_up, "{ctx}: bits_up at step {}", pa.step);
        assert_eq!(pa.bits_down, pb.bits_down, "{ctx}: bits_down at step {}", pa.step);
        assert!(
            feq(pa.train_loss, pb.train_loss),
            "{ctx}: train_loss at step {}: {} vs {}",
            pa.step,
            pa.train_loss,
            pb.train_loss
        );
        assert!(
            feq(pa.mem_norm_sq, pb.mem_norm_sq),
            "{ctx}: mem_norm_sq at step {}",
            pa.step
        );
    }
    assert_eq!(a.final_params, b.final_params, "{ctx}: final params diverged");
}

fn run_engine(
    up: &str,
    down: &str,
    h: usize,
    part: &str,
    scale: AggScale,
    server: ServerOptSpec,
    threads: usize,
) -> History {
    let (train, test) = data();
    let m = model();
    let upc = parse_spec(up).unwrap();
    let downc = parse_spec(down).unwrap();
    let sched = FixedPeriod::new(h);
    let participation = ParticipationSpec::parse(part)
        .unwrap()
        .materialize(WORKERS, STEPS, 5);
    let mut spec = TrainSpec::new(&m, &train, upc.as_ref(), &sched);
    spec.down_compressor = downc.as_ref();
    spec.test = Some(&test);
    spec.workers = WORKERS;
    spec.batch = 4;
    spec.steps = STEPS;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.participation = &participation;
    spec.agg_scale = scale;
    spec.server_opt = server;
    spec.eval_every = 7;
    spec.seed = 5;
    spec.threads = threads;
    run(&spec)
}

const MOMENTUM: ServerOptSpec = ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 };
const ADAM: ServerOptSpec = ServerOptSpec::Adam { b1: 0.9, b2: 0.99, eps: 1e-3, lr: 0.05 };

/// `Avg` set explicitly is the very same code path as the default —
/// trivially bit-identical, pinned so a future regression is loud.
#[test]
fn explicit_avg_is_bit_identical_to_default() {
    let dflt = run_engine("topk:k=10", "identity", 4, "full", AggScale::Workers,
        ServerOptSpec::Avg, 1);
    let expl = run_engine("topk:k=10", "identity", 4, "full", AggScale::Workers,
        ServerOptSpec::Avg, 1);
    assert_histories_identical(&dflt, &expl, "avg determinism");
    assert!(dflt.final_loss() < dflt.points[0].train_loss, "no optimization happened");
}

/// The hardest substrate sweep: momentum and Adam, compressed stochastic
/// downlink, sampled participation, H > 1 — the parallel engine must agree
/// with the sequential engine bit for bit at every thread count.
#[test]
fn server_opt_bit_identical_across_engine_thread_counts() {
    for (name, server) in [("momentum", MOMENTUM), ("adam", ADAM)] {
        for (part, scale) in [
            ("full", AggScale::Workers),
            ("fixed:5", AggScale::Participants),
        ] {
            let seq = run_engine("qtopk:k=10,bits=4", "qsgd:bits=2", 4, part, scale, server, 1);
            assert!(seq.final_loss().is_finite(), "{name}/{part}: diverged");
            for threads in [2usize, 8] {
                let par =
                    run_engine("qtopk:k=10,bits=4", "qsgd:bits=2", 4, part, scale, server, threads);
                assert_histories_identical(
                    &seq,
                    &par,
                    &format!("{name}/{part} threads={threads}"),
                );
            }
        }
    }
}

/// Engine ≡ threaded coordinator under server momentum with a compressed
/// downlink: both substrates share `MasterCore`, so the optimizer step
/// lands identically (parity by construction, verified end-to-end).
#[test]
fn server_momentum_engine_threaded_bit_identical() {
    let (train, test) = data();
    let engine_hist =
        run_engine("topk:k=10", "qtopk:k=16,bits=4", 4, "fixed:5", AggScale::Participants,
            MOMENTUM, 1);

    let participation = ParticipationSpec::parse("fixed:5")
        .unwrap()
        .materialize(WORKERS, STEPS, 5);
    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("topk:k=10").unwrap()),
        Arc::new(FixedPeriod::new(4)),
    );
    cfg.down_compressor = Arc::from(parse_spec("qtopk:k=16,bits=4").unwrap());
    cfg.participation = participation;
    cfg.agg_scale = AggScale::Participants;
    cfg.server_opt = MOMENTUM;
    cfg.workers = WORKERS;
    cfg.batch = 4;
    cfg.steps = STEPS;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    cfg.seed = 5;
    cfg.eval_every = 7;
    cfg.eval_rows = 512; // match TrainSpec::new's eval subset exactly
    let threaded_hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train),
        Some(Arc::new(test)),
    )
    .unwrap();
    assert_histories_identical(&engine_hist, &threaded_hist, "momentum engine vs threaded");
}

/// A non-Avg optimizer must actually change the trajectory (it is wired,
/// not silently ignored) while still optimizing: the dampened-momentum EMA
/// tracks plain averaging's final loss.
#[test]
fn server_momentum_changes_trajectory_and_still_converges() {
    let avg = run_engine("topk:k=10", "identity", 1, "full", AggScale::Workers,
        ServerOptSpec::Avg, 1);
    let mom = run_engine("topk:k=10", "identity", 1, "full", AggScale::Workers, MOMENTUM, 1);
    assert_ne!(
        avg.final_params, mom.final_params,
        "server momentum did not change the trajectory"
    );
    let (l_avg, l_mom) = (avg.final_loss(), mom.final_loss());
    assert!(l_mom < avg.points[0].train_loss * 0.9, "momentum failed to optimize: {l_mom}");
    assert!(
        l_mom < l_avg + 0.5,
        "dampened server momentum diverged from plain averaging: {l_mom} vs {l_avg}"
    );
}

/// Asynchronous schedules on the engine: every worker syncing at step t
/// forms one round, so a server optimizer is well-defined there (unlike
/// the threaded aggregate-on-arrival path, which rejects it below).
#[test]
fn engine_async_with_server_opt_runs_and_converges() {
    let (train, test) = data();
    let m = model();
    let up = parse_spec("topk:k=10").unwrap();
    let sched = RandomGaps::generate(WORKERS, 4, STEPS, 5 ^ 0x5eed);
    let mut spec = TrainSpec::new(&m, &train, up.as_ref(), &sched);
    spec.test = Some(&test);
    spec.workers = WORKERS;
    spec.batch = 4;
    spec.steps = STEPS;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.server_opt = MOMENTUM;
    let hist = run(&spec);
    assert!(hist.final_loss().is_finite());
    assert!(hist.final_loss() < hist.points[0].train_loss, "async + momentum did not optimize");
}

/// The threaded runtime's aggregate-on-arrival path has no round boundary,
/// so a non-Avg server optimizer there is a configuration error, caught up
/// front with an actionable message.
#[test]
fn threaded_async_with_server_opt_is_rejected() {
    let (train, test) = data();
    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("topk:k=10").unwrap()),
        Arc::new(RandomGaps::generate(WORKERS, 4, STEPS, 5 ^ 0x5eed)),
    );
    cfg.server_opt = MOMENTUM;
    cfg.workers = WORKERS;
    cfg.steps = STEPS;
    let err = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train),
        Some(Arc::new(test)),
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("synchronous"), "unexpected error: {err}");
}
