//! `ExperimentSpec` serialization properties (randomized round-trip,
//! unknown-field/bad-value error quality), golden equality of the bundled
//! `specs/*.json` against the in-code figure tables, and bit-identity of
//! the spec-resolved figure path vs the pre-redesign hand-built
//! `TrainSpec` construction (fig9 + fig10, quick-mode workload).

use qsparse::compress::{parse_spec, Codec};
use qsparse::data::Sharding;
use qsparse::engine::{self, History, TrainSpec};
use qsparse::figures::{self, FigureSpec};
use qsparse::optim::{LrSchedule, ServerOptSpec};
use qsparse::protocol::AggScale;
use qsparse::spec::{
    CompressorSpec, ExperimentSpec, ScheduleSpec, Workload, WorkloadInstance, SEED,
};
use qsparse::topology::{FixedPeriod, ParticipationSpec, RandomGaps, SyncSchedule};
use qsparse::util::rng::Pcg64;

// -- randomized round-trip --------------------------------------------------

fn random_spec(rng: &mut Pcg64) -> ExperimentSpec {
    let workload = if rng.f64() < 0.5 {
        Workload::ConvexSoftmax
    } else {
        Workload::NonConvexMlp
    };
    let mut s = ExperimentSpec::for_workload(workload);
    s.label = format!("run-{}", rng.below(10_000));
    s.steps = 1 + rng.below_usize(3000);
    s.workers = 1 + rng.below_usize(32);
    s.batch = 1 + rng.below_usize(64);
    s.lr = match rng.below(3) {
        0 => LrSchedule::Const { eta: rng.f64() },
        1 => LrSchedule::InvTime { xi: rng.f64() * 100.0, a: 1.0 + rng.f64() * 50.0 },
        _ => LrSchedule::WarmupPiecewise {
            peak: rng.f64(),
            warmup: rng.below_usize(20),
            milestones: vec![rng.below_usize(100), 100 + rng.below_usize(100)],
            decay: 0.01 + rng.f64() * 0.9,
        },
    };
    s.momentum = rng.f64() * 0.999;
    const OPS: &[&str] = &[
        "identity",
        "topk:k=7",
        "randk:k=3",
        "qsgd:bits=2",
        "sign",
        "qtopk:k=9,bits=4,scaled",
        "signtopk:k=5,m=2",
    ];
    s.up = CompressorSpec::parse(OPS[rng.below_usize(OPS.len())]).unwrap();
    s.down = CompressorSpec::parse(OPS[rng.below_usize(OPS.len())]).unwrap();
    let h = 1 + rng.below_usize(9);
    s.schedule = if rng.f64() < 0.5 {
        ScheduleSpec::Sync { h }
    } else {
        ScheduleSpec::Async { h }
    };
    s.participation = match rng.below(3) {
        0 => ParticipationSpec::Full,
        1 => ParticipationSpec::Bernoulli { p: 0.05 + 0.9 * rng.f64() },
        _ => ParticipationSpec::FixedSize { m: 1 + rng.below_usize(s.workers) },
    };
    s.agg_scale = if rng.f64() < 0.5 { AggScale::Workers } else { AggScale::Participants };
    s.server_opt = match rng.below(3) {
        0 => ServerOptSpec::Avg,
        1 => ServerOptSpec::Momentum { beta: rng.f64() * 0.99, lr: 0.01 + rng.f64() },
        _ => ServerOptSpec::Adam {
            b1: rng.f64() * 0.99,
            b2: rng.f64() * 0.99,
            eps: 1e-8 + rng.f64() * 1e-3,
            lr: 0.001 + rng.f64(),
        },
    };
    s.codec = if rng.f64() < 0.5 { Codec::Raw } else { Codec::Rans };
    s.sharding = if rng.f64() < 0.5 { Sharding::Iid } else { Sharding::LabelSkew };
    s.seed = rng.below(1 << 48);
    s.sim = if rng.f64() < 0.5 {
        None
    } else {
        let churn = rng.f64() < 0.5;
        Some(qsparse::sim::SimSpec {
            ticks_per_sec: 1 + rng.below(10_000_000),
            compute_mean: 1.0 + rng.f64() * 10_000.0,
            compute_sigma: rng.f64() * 1.5,
            bw_mean: 0.5 + rng.f64() * 1000.0,
            bw_sigma: rng.f64(),
            latency: rng.below(100_000),
            straggler_prob: rng.f64(),
            straggler_mult: 1.0 + rng.f64() * 20.0,
            churn_online_mean: if churn { 1 + rng.below(1 << 30) } else { 0 },
            churn_offline_mean: if churn { 1 + rng.below(1 << 30) } else { 0 },
            churn_sigma: rng.f64(),
        })
    };
    s.faults = if rng.f64() < 0.5 {
        None
    } else {
        // Keep the uplink probabilities summing < 1 and satisfy the
        // delay/deadline coupling rules `FaultSpec::validate` enforces.
        let drop_up = rng.f64() * 0.25;
        let corrupt_up = rng.f64() * 0.25;
        let delay_up = rng.f64() * 0.25;
        Some(qsparse::FaultSpec {
            seed: rng.below(1 << 48),
            drop_up,
            corrupt_up,
            dup_up: rng.f64() * 0.25,
            delay_up,
            delay_ticks: if delay_up > 0.0 { 1 + rng.below(100_000) } else { 0 },
            drop_down: rng.f64() * 0.5,
            corrupt_down: rng.f64() * 0.5,
            crash: rng.f64() * 0.1,
            deadline_ticks: if drop_up > 0.0 || corrupt_up > 0.0 {
                1 + rng.below(1 << 30)
            } else {
                0
            },
        })
    };
    s.threads = rng.below_usize(9);
    s.eval_every = 1 + rng.below_usize(50);
    s.eval_rows = 1 + rng.below_usize(1024);
    s
}

#[test]
fn randomized_specs_roundtrip_through_json() {
    let mut rng = Pcg64::seeded(0x57ec);
    for case in 0..200 {
        let s = random_spec(&mut rng);
        s.validate()
            .unwrap_or_else(|e| panic!("case {case}: generated spec invalid: {e}\n{s:?}"));
        let j = s.to_json();
        let back = ExperimentSpec::from_json(&j)
            .unwrap_or_else(|e| panic!("case {case}: {e}\n{j}"));
        assert_eq!(back, s, "case {case} (value round-trip)");
        let back = ExperimentSpec::from_json_str(&j.to_string()).unwrap();
        assert_eq!(back, s, "case {case} (compact text round-trip)");
        let back = ExperimentSpec::from_json_str(&j.pretty()).unwrap();
        assert_eq!(back, s, "case {case} (pretty text round-trip)");
    }
}

#[test]
fn figure_spec_unknown_field_is_rejected() {
    let mut j = figures::figure_spec("fig9").unwrap().to_json().to_string();
    assert!(FigureSpec::from_json_str(&j).is_ok());
    j.insert_str(1, "\"serie\":[],");
    let err = FigureSpec::from_json_str(&j).unwrap_err().to_string();
    assert!(err.contains("serie"), "{err}");
}

#[test]
fn experiment_spec_error_messages_name_the_field() {
    for (json, needle) in [
        (r#"{"workload": "convex", "bogus_knob": 1}"#, "bogus_knob"),
        (r#"{"eval_every": 0}"#, "eval_every"),
        (r#"{"down": "topk"}"#, "down"),
        (r#"{"agg_scale": "both"}"#, "agg"),
        (r#"{"threads": -1}"#, "threads"),
        (r#"{"workload": "transformer"}"#, "workload"),
    ] {
        let err = ExperimentSpec::from_json_str(json).unwrap_err().to_string();
        assert!(err.contains(needle), "{json}: {err}");
    }
}

// -- golden: bundled JSON ≡ in-code tables ---------------------------------

#[test]
fn bundled_specs_match_in_code_tables() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/specs");
    for id in figures::all_figure_ids() {
        let path = format!("{dir}/{id}.json");
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("{path}: {e} — run `qsparse specs dump`"));
        let bundled = FigureSpec::from_json_str(&text).unwrap_or_else(|e| panic!("{path}: {e}"));
        let code = figures::figure_spec(id).unwrap();
        assert_eq!(bundled, code, "{id}: bundle drifted — run `qsparse specs dump`");
    }
}

// -- bit-identity vs the pre-redesign hand-built path -----------------------

/// The legacy `run_series` body, verbatim: parse spec strings, build the
/// schedule/participation with the historical salts, hand-assemble a
/// `TrainSpec` from the workload instance's fields.
#[allow(clippy::too_many_arguments)]
fn legacy_run_series(
    w: &WorkloadInstance,
    up: &str,
    down: &str,
    h: usize,
    part: &str,
    agg: AggScale,
    steps: usize,
    seed: u64,
) -> History {
    let compressor = parse_spec(up).unwrap();
    let down_compressor = parse_spec(down).unwrap();
    let schedule: Box<dyn SyncSchedule> = Box::new(FixedPeriod::new(h));
    let participation =
        ParticipationSpec::parse(part).unwrap().materialize(w.workers, steps, seed);
    let spec = TrainSpec {
        model: w.model.as_ref(),
        train: &w.train,
        test: Some(&w.test),
        workers: w.workers,
        batch: w.batch,
        steps,
        lr: w.lr.clone(),
        momentum: w.momentum,
        compressor: compressor.as_ref(),
        down_compressor: down_compressor.as_ref(),
        schedule: schedule.as_ref(),
        participation: &participation,
        agg_scale: agg,
        server_opt: ServerOptSpec::Avg,
        codec: Codec::Raw,
        sharding: Sharding::Iid,
        seed,
        eval_every: w.eval_every,
        eval_rows: 512,
        threads: 1,
    };
    engine::run_from(&spec, w.init.clone())
}

fn assert_bit_identical(a: &History, b: &History, ctx: &str) {
    assert_eq!(a.final_params, b.final_params, "{ctx}: final params differ");
    let sa: Vec<usize> = a.points.iter().map(|p| p.step).collect();
    let sb: Vec<usize> = b.points.iter().map(|p| p.step).collect();
    assert_eq!(sa, sb, "{ctx}: metric grids differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.bits_up, pb.bits_up, "{ctx}: bits_up at step {}", pa.step);
        assert_eq!(pa.bits_down, pb.bits_down, "{ctx}: bits_down at step {}", pa.step);
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{ctx}: train_loss at step {}",
            pa.step
        );
        assert_eq!(
            pa.mem_norm_sq.to_bits(),
            pb.mem_norm_sq.to_bits(),
            "{ctx}: mem_norm_sq at step {}",
            pa.step
        );
    }
}

/// Acceptance: every fig9 and fig10 series regenerated through
/// `ExperimentSpec` is bit-identical to the pre-redesign hardcoded table
/// (quick-mode workload; the horizon is shortened uniformly on both sides,
/// which the per-step trajectory comparison is insensitive to).
#[test]
fn fig9_fig10_spec_path_bit_identical_to_legacy_tables() {
    let steps = 40;
    let w = Workload::ConvexSoftmax.instantiate(true);

    // The legacy fig9 table rows: (label, up, down, h).
    let fig9: &[(&str, &str, &str, usize)] = &[
        ("SGD", "identity", "identity", 1),
        ("QTopK-up", "qtopk:k=40,bits=4,scaled", "identity", 1),
        ("QTopK-bidir", "qtopk:k=40,bits=4,scaled", "qtopk:k=400,bits=4", 1),
        ("TopK-bidir", "topk:k=40", "topk:k=400", 1),
        ("SignTopK-bidir_8L", "signtopk:k=40,m=1", "qtopk:k=400,bits=4", 8),
    ];
    let spec9 = figures::figure_spec("fig9").unwrap();
    assert_eq!(spec9.series.len(), fig9.len());
    for (s, &(label, up, down, h)) in spec9.series.iter().zip(fig9) {
        assert_eq!(s.label, label, "fig9 series order changed");
        let want = legacy_run_series(&w, up, down, h, "full", AggScale::Workers, steps, SEED);
        let got = figures::run_series(&w, s, steps).unwrap();
        assert_bit_identical(&got, &want, &format!("fig9/{label}"));
    }

    // The legacy fig10 table rows: (label, participation, scale).
    let fig10: &[(&str, &str, AggScale)] = &[
        ("QTopK-bidir_p1.00", "full", AggScale::Workers),
        ("QTopK-bidir_p0.50", "bernoulli:0.5", AggScale::Participants),
        ("QTopK-bidir_p0.25", "bernoulli:0.25", AggScale::Participants),
        ("QTopK-bidir_m8", "fixed:8", AggScale::Participants),
        ("QTopK-bidir_p0.50_1R", "bernoulli:0.5", AggScale::Workers),
    ];
    let spec10 = figures::figure_spec("fig10").unwrap();
    assert_eq!(spec10.series.len(), fig10.len());
    for (s, &(label, part, scale)) in spec10.series.iter().zip(fig10) {
        assert_eq!(s.label, label, "fig10 series order changed");
        let want = legacy_run_series(
            &w,
            "qtopk:k=40,bits=4,scaled",
            "qtopk:k=400,bits=4",
            4,
            part,
            scale,
            steps,
            SEED,
        );
        let got = figures::run_series(&w, s, steps).unwrap();
        assert_bit_identical(&got, &want, &format!("fig10/{label}"));
    }
}

/// The async figure (fig7) exercises the `RandomGaps` salt through the
/// spec path — one series suffices to pin the `seed ^ 0x5eed` derivation.
#[test]
fn fig7_async_series_bit_identical_to_legacy_schedule() {
    let steps = 40;
    let w = Workload::ConvexSoftmax.instantiate(true);
    let spec7 = figures::figure_spec("fig7").unwrap();
    let s = &spec7.series[2]; // TopK-async
    assert_eq!(s.label, "TopK-async");
    let up = parse_spec("topk:k=40").unwrap();
    let down = parse_spec("identity").unwrap();
    let schedule = RandomGaps::generate(w.workers, 8, steps, SEED ^ 0x5eed);
    let participation = ParticipationSpec::Full.materialize(w.workers, steps, SEED);
    let legacy = TrainSpec {
        model: w.model.as_ref(),
        train: &w.train,
        test: Some(&w.test),
        workers: w.workers,
        batch: w.batch,
        steps,
        lr: w.lr.clone(),
        momentum: w.momentum,
        compressor: up.as_ref(),
        down_compressor: down.as_ref(),
        schedule: &schedule,
        participation: &participation,
        agg_scale: AggScale::Workers,
        server_opt: ServerOptSpec::Avg,
        codec: Codec::Raw,
        sharding: Sharding::Iid,
        seed: SEED,
        eval_every: w.eval_every,
        eval_rows: 512,
        threads: 1,
    };
    let want = engine::run_from(&legacy, w.init.clone());
    let got = figures::run_series(&w, s, steps).unwrap();
    assert_bit_identical(&got, &want, "fig7/TopK-async");
}
