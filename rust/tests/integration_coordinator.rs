//! Threaded coordinator vs engine: the same algorithm under real threads +
//! encoded wire messages must reproduce the deterministic engine.

use qsparse::compress::parse_spec;
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::data::{gaussian_clusters_split, Sharding};
use qsparse::engine::{run, TrainSpec};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::topology::{FixedPeriod, RandomGaps};
use std::sync::Arc;

const N: usize = 300;

fn data() -> (qsparse::data::Dataset, qsparse::data::Dataset) {
    gaussian_clusters_split(N, N / 4, 16, 4, 0.5, 1.0, 55)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(16, 4, 1.0 / N as f64)
}

/// Synchronous schedules barrier in the master, so the threaded run must be
/// *bit-identical* to the engine with the same seed.
#[test]
fn threaded_sync_bitexact_vs_engine() {
    let (train, test) = data();
    let m = model();
    for comp_spec in ["identity", "topk:k=10", "signtopk:k=10,m=1", "qtopk:k=10,bits=4"] {
        let comp = parse_spec(comp_spec).unwrap();
        let sched = FixedPeriod::new(4);
        let mut spec = TrainSpec::new(&m, &train, comp.as_ref(), &sched);
        spec.workers = 4;
        spec.batch = 4;
        spec.steps = 80;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.test = Some(&test);
        let engine_hist = run(&spec);

        let mut cfg = CoordinatorConfig::new(
            Arc::from(parse_spec(comp_spec).unwrap()),
            Arc::new(FixedPeriod::new(4)),
        );
        cfg.workers = 4;
        cfg.batch = 4;
        cfg.steps = 80;
        cfg.lr = LrSchedule::Const { eta: 0.3 };
        cfg.seed = spec.seed;
        let threaded_hist = run_threaded(
            &cfg,
            || Box::new(model()) as Box<dyn GradModel>,
            Arc::new(train.clone()),
            Some(Arc::new(test.clone())),
        )
        .unwrap();

        assert_eq!(
            engine_hist.final_params, threaded_hist.final_params,
            "{comp_spec}: threaded sync run diverged from the engine"
        );
        assert_eq!(
            engine_hist.total_bits_up(),
            threaded_hist.total_bits_up(),
            "{comp_spec}: wire bit accounting differs"
        );
        // The two substrates must sample metrics on the same step grid
        // (H > 1 used to shift the threaded recorder onto sync boundaries).
        let egrid: Vec<usize> = engine_hist.points.iter().map(|p| p.step).collect();
        let tgrid: Vec<usize> = threaded_hist.points.iter().map(|p| p.step).collect();
        assert_eq!(egrid, tgrid, "{comp_spec}: metric step grids differ");
    }
}

/// Asynchronous (aggregate-on-arrival) mode converges and transmits the same
/// number of bits as the engine with the same schedule (arrival order may
/// differ, so parameters are compared by loss, not bitwise).
#[test]
fn threaded_async_converges_and_bits_match() {
    let (train, test) = data();
    let steps = 150;
    let sched = RandomGaps::generate(4, 6, steps, 999);
    let comp = parse_spec("signtopk:k=10,m=1").unwrap();

    let m = model();
    let mut spec = TrainSpec::new(&m, &train, comp.as_ref(), &sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = steps;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    let engine_hist = run(&spec);

    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("signtopk:k=10,m=1").unwrap()),
        Arc::new(sched),
    );
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = steps;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    let threaded_hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train.clone()),
        Some(Arc::new(test.clone())),
    )
    .unwrap();

    // Message *count* is schedule-determined and identical; message *bytes*
    // depend on update content, which differs under aggregate-on-arrival
    // (each worker sees the freshest model at its own sync instant), so the
    // totals agree only approximately.
    let be = engine_hist.total_bits_up() as f64;
    let bt = threaded_hist.total_bits_up() as f64;
    assert!(
        (be - bt).abs() / be < 0.05,
        "bit totals diverged: engine {be} vs threaded {bt}"
    );
    let le = engine_hist.final_loss();
    let lt = threaded_hist.final_loss();
    assert!(lt < (4.0f64).ln() * 0.6, "threaded async did not converge: {lt}");
    assert!((le - lt).abs() < 0.25, "engine {le} vs threaded {lt}");
    // Even the aggregate-on-arrival path records on the engine's step grid,
    // so async histories are comparable point-by-point (values approximate,
    // steps exact).
    let egrid: Vec<usize> = engine_hist.points.iter().map(|p| p.step).collect();
    let tgrid: Vec<usize> = threaded_hist.points.iter().map(|p| p.step).collect();
    assert_eq!(egrid, tgrid, "async metric step grids differ");
}

/// One worker (R = 1) degenerates to sequential SGD with compression.
#[test]
fn threaded_single_worker() {
    let (train, _) = data();
    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("topk:k=20").unwrap()),
        Arc::new(FixedPeriod::new(2)),
    );
    cfg.workers = 1;
    cfg.batch = 8;
    cfg.steps = 120;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    let hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train),
        None,
    )
    .unwrap();
    assert!(hist.final_loss() < (4.0f64).ln() * 0.6, "loss {}", hist.final_loss());
    // No test set → NaN test metrics, but loss curve exists.
    assert!(hist.points.iter().all(|p| p.test_err.is_nan()));
}
