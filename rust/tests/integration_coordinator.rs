//! Threaded coordinator vs engine: the same algorithm under real threads +
//! encoded wire messages must reproduce the deterministic engine.

use qsparse::compress::{parse_spec, Codec};
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::data::{gaussian_clusters_split, Sharding};
use qsparse::engine::{run, History, TrainSpec};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::topology::{FixedPeriod, RandomGaps};
use std::sync::Arc;

const N: usize = 300;

fn data() -> (qsparse::data::Dataset, qsparse::data::Dataset) {
    gaussian_clusters_split(N, N / 4, 16, 4, 0.5, 1.0, 55)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(16, 4, 1.0 / N as f64)
}

/// Large-d workload (d = 64·16 + 16 = 1040 ≥ the coordinator's sharded-fold
/// threshold) for the fold-pool and codec tests.
fn big_data() -> (qsparse::data::Dataset, qsparse::data::Dataset) {
    gaussian_clusters_split(400, 100, 64, 16, 0.5, 1.0, 77)
}

fn big_model() -> SoftmaxRegression {
    SoftmaxRegression::new(64, 16, 1.0 / 400.0)
}

/// Synchronous schedules barrier in the master, so the threaded run must be
/// *bit-identical* to the engine with the same seed.
#[test]
fn threaded_sync_bitexact_vs_engine() {
    let (train, test) = data();
    let m = model();
    for comp_spec in ["identity", "topk:k=10", "signtopk:k=10,m=1", "qtopk:k=10,bits=4"] {
        let comp = parse_spec(comp_spec).unwrap();
        let sched = FixedPeriod::new(4);
        let mut spec = TrainSpec::new(&m, &train, comp.as_ref(), &sched);
        spec.workers = 4;
        spec.batch = 4;
        spec.steps = 80;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.test = Some(&test);
        let engine_hist = run(&spec);

        let mut cfg = CoordinatorConfig::new(
            Arc::from(parse_spec(comp_spec).unwrap()),
            Arc::new(FixedPeriod::new(4)),
        );
        cfg.workers = 4;
        cfg.batch = 4;
        cfg.steps = 80;
        cfg.lr = LrSchedule::Const { eta: 0.3 };
        cfg.seed = spec.seed;
        let threaded_hist = run_threaded(
            &cfg,
            || Box::new(model()) as Box<dyn GradModel>,
            Arc::new(train.clone()),
            Some(Arc::new(test.clone())),
        )
        .unwrap();

        assert_eq!(
            engine_hist.final_params, threaded_hist.final_params,
            "{comp_spec}: threaded sync run diverged from the engine"
        );
        assert_eq!(
            engine_hist.total_bits_up(),
            threaded_hist.total_bits_up(),
            "{comp_spec}: wire bit accounting differs"
        );
        // The two substrates must sample metrics on the same step grid
        // (H > 1 used to shift the threaded recorder onto sync boundaries).
        let egrid: Vec<usize> = engine_hist.points.iter().map(|p| p.step).collect();
        let tgrid: Vec<usize> = threaded_hist.points.iter().map(|p| p.step).collect();
        assert_eq!(egrid, tgrid, "{comp_spec}: metric step grids differ");
    }
}

/// Asynchronous (aggregate-on-arrival) mode converges and transmits the same
/// number of bits as the engine with the same schedule (arrival order may
/// differ, so parameters are compared by loss, not bitwise).
#[test]
fn threaded_async_converges_and_bits_match() {
    let (train, test) = data();
    let steps = 150;
    let sched = RandomGaps::generate(4, 6, steps, 999);
    let comp = parse_spec("signtopk:k=10,m=1").unwrap();

    let m = model();
    let mut spec = TrainSpec::new(&m, &train, comp.as_ref(), &sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = steps;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    let engine_hist = run(&spec);

    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("signtopk:k=10,m=1").unwrap()),
        Arc::new(sched),
    );
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = steps;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    let threaded_hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train.clone()),
        Some(Arc::new(test.clone())),
    )
    .unwrap();

    // Message *count* is schedule-determined and identical; message *bytes*
    // depend on update content, which differs under aggregate-on-arrival
    // (each worker sees the freshest model at its own sync instant), so the
    // totals agree only approximately.
    let be = engine_hist.total_bits_up() as f64;
    let bt = threaded_hist.total_bits_up() as f64;
    assert!(
        (be - bt).abs() / be < 0.05,
        "bit totals diverged: engine {be} vs threaded {bt}"
    );
    let le = engine_hist.final_loss();
    let lt = threaded_hist.final_loss();
    assert!(lt < (4.0f64).ln() * 0.6, "threaded async did not converge: {lt}");
    assert!((le - lt).abs() < 0.25, "engine {le} vs threaded {lt}");
    // Even the aggregate-on-arrival path records on the engine's step grid,
    // so async histories are comparable point-by-point (values approximate,
    // steps exact).
    let egrid: Vec<usize> = engine_hist.points.iter().map(|p| p.step).collect();
    let tgrid: Vec<usize> = threaded_hist.points.iter().map(|p| p.step).collect();
    assert_eq!(egrid, tgrid, "async metric step grids differ");
}

/// With `codec: rans` on both directions (compressed uplink AND downlink),
/// the threaded runtime must still be bit-identical to the engine: the
/// workers serialize through `WireEncoder` while the engine only walks
/// `wire_bits_with`, so any drift between the cost walk and the real
/// serializer shows up here as a bits mismatch, and any decode corruption
/// as diverging parameters.
#[test]
fn threaded_rans_bitexact_vs_engine_bidirectional() {
    let (train, test) = data();
    let m = model();
    let comp = parse_spec("qtopk:k=10,bits=4").unwrap();
    let down = parse_spec("topk:k=40").unwrap();
    let sched = FixedPeriod::new(4);
    let mut spec = TrainSpec::new(&m, &train, comp.as_ref(), &sched);
    spec.workers = 4;
    spec.batch = 4;
    spec.steps = 80;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.test = Some(&test);
    spec.down_compressor = down.as_ref();
    spec.codec = Codec::Rans;
    let engine_hist = run(&spec);

    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("qtopk:k=10,bits=4").unwrap()),
        Arc::new(FixedPeriod::new(4)),
    );
    cfg.workers = 4;
    cfg.batch = 4;
    cfg.steps = 80;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    cfg.seed = spec.seed;
    cfg.down_compressor = Arc::from(parse_spec("topk:k=40").unwrap());
    cfg.codec = Codec::Rans;
    let threaded_hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train.clone()),
        Some(Arc::new(test.clone())),
    )
    .unwrap();

    assert_eq!(
        engine_hist.final_params, threaded_hist.final_params,
        "rans threaded run diverged from the engine"
    );
    assert_eq!(engine_hist.points.len(), threaded_hist.points.len());
    for (a, b) in engine_hist.points.iter().zip(&threaded_hist.points) {
        assert_eq!(a.step, b.step);
        assert_eq!(
            (a.bits_up, a.bits_down),
            (b.bits_up, b.bits_down),
            "rans wire accounting diverged at step {}",
            a.step
        );
    }
}

/// Assert two histories describe the same trajectory bit for bit (steps,
/// losses, parameters) — bits are compared separately by the callers.
fn assert_same_trajectory(a: &History, b: &History, ctx: &str) {
    assert_eq!(a.final_params, b.final_params, "{ctx}: final params differ");
    assert_eq!(a.points.len(), b.points.len(), "{ctx}: grids differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.step, pb.step, "{ctx}");
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{ctx}: train_loss at step {}",
            pa.step
        );
    }
}

/// The d = 1040 workload drives the coordinator's sharded fold pool
/// (d ≥ 1024, multi-worker barrier) and the codec end to end: engine ≡
/// threaded bit-identity under both codecs, raw ≡ rans trajectory identity
/// by construction, and a strict wire saving for rans on both directions.
#[test]
fn sharded_fold_and_rans_bit_identity_at_large_d() {
    let (train, test) = big_data();
    let m = big_model();
    let run_engine = |codec: Codec| {
        let comp = parse_spec("topk:k=100").unwrap();
        let down = parse_spec("qtopk:k=400,bits=4").unwrap();
        let sched = FixedPeriod::new(4);
        let mut spec = TrainSpec::new(&m, &train, comp.as_ref(), &sched);
        spec.workers = 4;
        spec.batch = 4;
        spec.steps = 48;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.test = Some(&test);
        spec.down_compressor = down.as_ref();
        spec.codec = codec;
        run(&spec)
    };
    let run_coord = |codec: Codec| {
        let mut cfg = CoordinatorConfig::new(
            Arc::from(parse_spec("topk:k=100").unwrap()),
            Arc::new(FixedPeriod::new(4)),
        );
        cfg.workers = 4;
        cfg.batch = 4;
        cfg.steps = 48;
        cfg.lr = LrSchedule::Const { eta: 0.3 };
        cfg.down_compressor = Arc::from(parse_spec("qtopk:k=400,bits=4").unwrap());
        cfg.codec = codec;
        run_threaded(
            &cfg,
            || Box::new(big_model()) as Box<dyn GradModel>,
            Arc::new(train.clone()),
            Some(Arc::new(test.clone())),
        )
        .unwrap()
    };
    for codec in [Codec::Raw, Codec::Rans] {
        let engine_hist = run_engine(codec);
        let threaded_hist = run_coord(codec);
        let ctx = format!("codec {codec:?}");
        assert_same_trajectory(&engine_hist, &threaded_hist, &ctx);
        for (a, b) in engine_hist.points.iter().zip(&threaded_hist.points) {
            assert_eq!(
                (a.bits_up, a.bits_down),
                (b.bits_up, b.bits_down),
                "{ctx}: bits diverged at step {}",
                a.step
            );
        }
    }
    // raw vs rans: identical trajectories (the codec only re-encodes the
    // wire), strictly fewer bits in both directions for rans.
    let raw = run_engine(Codec::Raw);
    let rans = run_engine(Codec::Rans);
    assert_same_trajectory(&raw, &rans, "raw vs rans");
    let (raw_last, rans_last) = (raw.points.last().unwrap(), rans.points.last().unwrap());
    assert!(
        rans_last.bits_up < raw_last.bits_up,
        "rans uplink must beat raw: {} vs {}",
        rans_last.bits_up,
        raw_last.bits_up
    );
    assert!(
        rans_last.bits_down < raw_last.bits_down,
        "rans downlink must beat raw: {} vs {}",
        rans_last.bits_down,
        raw_last.bits_down
    );
}

/// One worker (R = 1) degenerates to sequential SGD with compression.
#[test]
fn threaded_single_worker() {
    let (train, _) = data();
    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("topk:k=20").unwrap()),
        Arc::new(FixedPeriod::new(2)),
    );
    cfg.workers = 1;
    cfg.batch = 8;
    cfg.steps = 120;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    let hist = run_threaded(
        &cfg,
        || Box::new(model()) as Box<dyn GradModel>,
        Arc::new(train),
        None,
    )
    .unwrap();
    assert!(hist.final_loss() < (4.0f64).ln() * 0.6, "loss {}", hist.final_loss());
    // No test set → NaN test metrics, but loss curve exists.
    assert!(hist.points.iter().all(|p| p.test_err.is_nan()));
}
