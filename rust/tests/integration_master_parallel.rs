//! Parallel master round ≡ sequential master round, bit for bit.
//!
//! PR 5 moved the master's round onto the persistent pool: the fold is
//! sharded (each pool thread folds every round message over a disjoint
//! chunk of the fold target, in worker-index order) and the per-worker
//! downlink compression fans out to the threads that own the workers. The
//! claim is that none of this changes a single f32 operation, so for every
//! uplink operator × downlink mode × participation policy × server
//! optimizer the `History` (losses, bit accounting, memory norms, final
//! parameters) is identical to the sequential engine's for every thread
//! count — the acceptance matrix of the parallel-master-round issue.

use qsparse::compress::parse_spec;
use qsparse::engine::{run, History, TrainSpec};
use qsparse::grad::SoftmaxRegression;
use qsparse::optim::{LrSchedule, ServerOptSpec};
use qsparse::protocol::AggScale;
use qsparse::topology::{FixedPeriod, ParticipationSpec};

const N: usize = 240;
const WORKERS: usize = 8;
const STEPS: usize = 60;

const UPLINKS: [&str; 3] = ["topk:k=10", "qtopk:k=10,bits=4", "signtopk:k=10,m=1"];
const DOWNLINKS: [&str; 3] = ["identity", "topk:k=8", "qsgd:bits=2"];
const PARTICIPATIONS: [&str; 2] = ["full", "fixed:5"];
const SERVER_OPTS: [ServerOptSpec; 2] = [
    ServerOptSpec::Avg,
    ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 },
];
const THREADS: [usize; 2] = [2, 8];

fn data() -> qsparse::data::Dataset {
    qsparse::data::gaussian_clusters(N, 12, 4, 1.5, 0.5, 77)
}

fn model() -> SoftmaxRegression {
    SoftmaxRegression::new(12, 4, 1.0 / N as f64)
}

/// Bitwise history equality — not tolerance-based: f64 metrics compared by
/// bit pattern, parameters and bit counters by Eq.
fn assert_bit_identical(a: &History, b: &History, ctx: &str) {
    assert_eq!(a.final_params, b.final_params, "{ctx}: final params differ");
    let asteps: Vec<usize> = a.points.iter().map(|p| p.step).collect();
    let bsteps: Vec<usize> = b.points.iter().map(|p| p.step).collect();
    assert_eq!(asteps, bsteps, "{ctx}: metric grids differ");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        let s = pa.step;
        assert_eq!(pa.bits_up, pb.bits_up, "{ctx}: bits_up at step {s}");
        assert_eq!(pa.bits_down, pb.bits_down, "{ctx}: bits_down at step {s}");
        assert_eq!(
            pa.train_loss.to_bits(),
            pb.train_loss.to_bits(),
            "{ctx}: train_loss at step {s} ({} vs {})",
            pa.train_loss,
            pb.train_loss
        );
        assert_eq!(
            pa.mem_norm_sq.to_bits(),
            pb.mem_norm_sq.to_bits(),
            "{ctx}: mem_norm_sq at step {s}"
        );
    }
}

fn run_cfg(up: &str, down: &str, part: &str, server: ServerOptSpec, threads: usize) -> History {
    let ds = data();
    let m = model();
    let upc = parse_spec(up).unwrap();
    let downc = parse_spec(down).unwrap();
    let sched = FixedPeriod::new(2);
    let participation = ParticipationSpec::parse(part)
        .unwrap()
        .materialize(WORKERS, STEPS, 5);
    let mut spec = TrainSpec::new(&m, &ds, upc.as_ref(), &sched);
    spec.down_compressor = downc.as_ref();
    spec.workers = WORKERS;
    spec.batch = 4;
    spec.steps = STEPS;
    spec.lr = LrSchedule::Const { eta: 0.3 };
    spec.participation = &participation;
    // Unbiased scaling under sampling exercises `begin_round` on the
    // sharded path too; under full participation it equals 1/R anyway.
    spec.agg_scale = if part == "full" { AggScale::Workers } else { AggScale::Participants };
    spec.server_opt = server;
    spec.eval_every = 7; // off-grid vs H=2 — exercises between-round metrics
    spec.seed = 5;
    spec.threads = threads;
    run(&spec)
}

/// One uplink operator's full sub-matrix: downlink × participation ×
/// server-opt, thread counts {1 (reference), 2, 8}.
fn sweep_uplink(up: &str) {
    for down in DOWNLINKS {
        for part in PARTICIPATIONS {
            for server in SERVER_OPTS {
                let seq = run_cfg(up, down, part, server, 1);
                assert!(
                    seq.final_loss().is_finite() && seq.total_bits_up() > 0,
                    "{up}/{down}/{part}/{server:?}: degenerate baseline"
                );
                for threads in THREADS {
                    let par = run_cfg(up, down, part, server, threads);
                    assert_bit_identical(
                        &seq,
                        &par,
                        &format!("{up} down={down} part={part} server={server:?} threads={threads}"),
                    );
                }
            }
        }
    }
}

#[test]
#[cfg_attr(miri, ignore)] // minutes of work — the miri_ twins below cover the unsafe core
fn master_parallel_matrix_topk_uplink() {
    sweep_uplink(UPLINKS[0]);
}

#[test]
#[cfg_attr(miri, ignore)]
fn master_parallel_matrix_qtopk_uplink() {
    sweep_uplink(UPLINKS[1]);
}

#[test]
#[cfg_attr(miri, ignore)]
fn master_parallel_matrix_signtopk_uplink() {
    sweep_uplink(UPLINKS[2]);
}

/// The sharded fold also has to agree under H = 1 (a round every tick —
/// the fold-heaviest schedule) with the momentum server optimizer, whose
/// fold target is the round accumulator rather than the model.
#[test]
#[cfg_attr(miri, ignore)]
fn master_parallel_h1_momentum_accum_fold() {
    let ds = data();
    let m = model();
    let upc = parse_spec("qtopk:k=10,bits=4").unwrap();
    let downc = parse_spec("topk:k=8").unwrap();
    let sched = FixedPeriod::new(1);
    let participation = ParticipationSpec::parse("full").unwrap().materialize(WORKERS, STEPS, 5);
    let mk = |threads: usize| {
        let mut spec = TrainSpec::new(&m, &ds, upc.as_ref(), &sched);
        spec.down_compressor = downc.as_ref();
        spec.workers = WORKERS;
        spec.batch = 4;
        spec.steps = STEPS;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.participation = &participation;
        spec.server_opt = ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 };
        spec.eval_every = 7;
        spec.seed = 5;
        spec.threads = threads;
        run(&spec)
    };
    let seq = mk(1);
    for threads in [2usize, 3, 8] {
        assert_bit_identical(&seq, &mk(threads), &format!("H=1 momentum threads={threads}"));
    }
}

// ---------------------------------------------------------------------------
// Miri-scale twins.
//
// The matrix tests above are minutes of work — far past Miri's ~100×
// interpreter slowdown budget — so under Miri they are ignored and these
// small twins drive the same unsafe machinery through interleavings Miri
// can model-check: the engine's fork-join raw-pointer views
// (`engine::parallel`) and the coordinator's FoldPool sharded fold +
// on-arrival decode (`coordinator::master`). Under Miri the sharded fold's
// dimension threshold drops to 16 (see `SHARD_FOLD_MIN_D`), so the d = 52
// softmax model below engages it — provided the interpreter reports more
// than one CPU, which CI arranges with `MIRIFLAGS=-Zmiri-num-cpus=4`.

const MIRI_N: usize = 32;
const MIRI_WORKERS: usize = 4;
const MIRI_STEPS: usize = 6;

fn miri_data() -> qsparse::data::Dataset {
    qsparse::data::gaussian_clusters(MIRI_N, 12, 4, 1.5, 0.5, 77)
}

fn miri_model() -> SoftmaxRegression {
    SoftmaxRegression::new(12, 4, 1.0 / MIRI_N as f64)
}

/// Engine fork-join under Miri: sampled participation + momentum server
/// optimizer across thread counts, bit-identical to the sequential loop.
#[test]
fn miri_engine_fork_join_bit_identity() {
    let ds = miri_data();
    let m = miri_model();
    let upc = parse_spec("qtopk:k=6,bits=4").unwrap();
    let downc = parse_spec("topk:k=8").unwrap();
    let sched = FixedPeriod::new(2);
    let participation = ParticipationSpec::parse("fixed:2")
        .unwrap()
        .materialize(MIRI_WORKERS, MIRI_STEPS, 5);
    let mk = |threads: usize| {
        let mut spec = TrainSpec::new(&m, &ds, upc.as_ref(), &sched);
        spec.down_compressor = downc.as_ref();
        spec.workers = MIRI_WORKERS;
        spec.batch = 4;
        spec.steps = MIRI_STEPS;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.participation = &participation;
        spec.agg_scale = AggScale::Participants;
        spec.server_opt = ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 };
        spec.eval_every = 3;
        spec.seed = 5;
        spec.threads = threads;
        run(&spec)
    };
    let seq = mk(1);
    for threads in [2usize, 3] {
        assert_bit_identical(&seq, &mk(threads), &format!("miri engine threads={threads}"));
    }
}

/// Threaded master under Miri: real OS threads, encoded rans wire both
/// directions, sampled participation and momentum through the FoldPool's
/// sharded fold — bit-identical to the sequential engine.
#[test]
fn miri_threaded_master_sharded_fold_vs_engine() {
    use qsparse::compress::Codec;
    use qsparse::coordinator::{run_threaded, CoordinatorConfig};
    use qsparse::grad::GradModel;
    use std::sync::Arc;

    let ds = miri_data();
    let m = miri_model();
    let upc = parse_spec("qtopk:k=6,bits=4").unwrap();
    let downc = parse_spec("topk:k=8").unwrap();
    let sched = FixedPeriod::new(2);
    let participation = ParticipationSpec::parse("fixed:2")
        .unwrap()
        .materialize(MIRI_WORKERS, MIRI_STEPS, 5);

    let engine_hist = {
        let mut spec = TrainSpec::new(&m, &ds, upc.as_ref(), &sched);
        spec.down_compressor = downc.as_ref();
        spec.workers = MIRI_WORKERS;
        spec.batch = 4;
        spec.steps = MIRI_STEPS;
        spec.lr = LrSchedule::Const { eta: 0.3 };
        spec.participation = &participation;
        spec.agg_scale = AggScale::Participants;
        spec.server_opt = ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 };
        spec.codec = Codec::Rans;
        spec.eval_every = 3;
        spec.eval_rows = 256;
        spec.seed = 5;
        run(&spec)
    };

    let mut cfg = CoordinatorConfig::new(
        Arc::from(parse_spec("qtopk:k=6,bits=4").unwrap()),
        Arc::new(FixedPeriod::new(2)),
    );
    cfg.workers = MIRI_WORKERS;
    cfg.batch = 4;
    cfg.steps = MIRI_STEPS;
    cfg.lr = LrSchedule::Const { eta: 0.3 };
    cfg.down_compressor = Arc::from(parse_spec("topk:k=8").unwrap());
    cfg.participation = participation.clone();
    cfg.agg_scale = AggScale::Participants;
    cfg.server_opt = ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 };
    cfg.codec = Codec::Rans;
    cfg.eval_every = 3;
    cfg.eval_rows = 256;
    cfg.seed = 5;
    let threaded_hist = run_threaded(
        &cfg,
        || Box::new(miri_model()) as Box<dyn GradModel>,
        Arc::new(ds.clone()),
        None,
    )
    .unwrap();

    assert_bit_identical(&engine_hist, &threaded_hist, "miri threaded vs engine");
}
