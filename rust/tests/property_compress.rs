//! Seeded property tests over the compression stack (the sandbox has no
//! proptest crate, so these are randomized sweeps with fixed seeds — fully
//! reproducible, wide input coverage including adversarial shapes).

use qsparse::compress::{encode, parse_spec, Compressor, Message, MessageBuf};
use qsparse::util::rng::Pcg64;
use qsparse::util::stats::norm2_sq;

/// Input families that historically break compressors.
fn gen_vector(rng: &mut Pcg64, d: usize, family: usize) -> Vec<f32> {
    match family % 6 {
        0 => (0..d).map(|_| rng.normal_f32()).collect(), // gaussian
        1 => vec![0.0; d],                               // all zeros
        2 => {
            // single spike
            let mut v = vec![0.0f32; d];
            v[rng.below_usize(d)] = rng.normal_f32() * 100.0;
            v
        }
        3 => (0..d).map(|_| 1.0f32).collect(), // constant (ties everywhere)
        4 => (0..d)
            .map(|_| rng.normal_f32() * 10f32.powi(rng.below(9) as i32 - 4))
            .collect(), // wide dynamic range
        _ => (0..d)
            .map(|i| if i % 7 == 0 { rng.normal_f32() } else { 0.0 })
            .collect(), // sparse input
    }
}

fn operators(d: usize, rng: &mut Pcg64) -> Vec<Box<dyn Compressor>> {
    let k = 1 + rng.below_usize(d);
    let bits = 2 + rng.below(7) as u32;
    [
        "identity".to_string(),
        format!("topk:k={k}"),
        format!("randk:k={k}"),
        format!("qsgd:bits={bits}"),
        "sign".to_string(),
        format!("qtopk:k={k},bits={bits}"),
        format!("qtopk:k={k},bits={bits},scaled"),
        format!("signtopk:k={k},m=1"),
        format!("signtopk:k={k},m=2"),
    ]
    .iter()
    .map(|s| parse_spec(s).unwrap())
    .collect()
}

/// Wire round-trip: decode(encode(m)) == m for every operator × input family
/// × dimension.
#[test]
fn prop_encode_decode_roundtrip() {
    let mut rng = Pcg64::seeded(0xDEC0DE);
    for trial in 0..120 {
        let d = 1 + rng.below_usize(600);
        let x = gen_vector(&mut rng, d, trial);
        for op in operators(d, &mut rng) {
            let msg = op.compress(&x, &mut rng);
            let (bytes, len) = encode::encode(&msg);
            let back = encode::decode(&bytes, len)
                .unwrap_or_else(|e| panic!("trial {trial} {} failed to decode: {e}", op.name()));
            assert_eq!(msg, back, "trial {trial} {}", op.name());
            assert_eq!(len, msg.wire_bits());
            // byte buffer is minimal
            assert!(bytes.len() as u64 * 8 < len + 8);
        }
    }
}

/// The pure O(nnz) cost walk `encode::wire_bits` equals the serialized bit
/// length `encode(msg).1` for every operator × input family × dimension —
/// including the gap-vs-raw index-coding decision point (clustered supports
/// take gaps, scattered high-d supports take raw).
#[test]
fn prop_wire_bits_matches_encoding() {
    let mut rng = Pcg64::seeded(0xB175);
    for trial in 0..120 {
        let d = 1 + rng.below_usize(900);
        let x = gen_vector(&mut rng, d, trial);
        for op in operators(d, &mut rng) {
            let msg = op.compress(&x, &mut rng);
            let (_bytes, len) = encode::encode(&msg);
            assert_eq!(
                encode::wire_bits(&msg),
                len,
                "trial {trial} {}: cost walk diverged from serializer",
                op.name()
            );
        }
    }
    // Hand-built clustered support (gap coding maximally favorable).
    let d = 1 << 20;
    let msg = Message::SparseF32 {
        d,
        idx: (500..628u32).collect(),
        vals: vec![1.5f32; 128],
    };
    assert_eq!(encode::wire_bits(&msg), encode::encode(&msg).1);
}

/// The rANS wire codec, over every operator family × input family ×
/// dimension: decode(encode(m)) == m through one shared reusable
/// `WireEncoder`, the pure cost walk `wire_bits_with(Rans)` equals the
/// serialized bit length, and the per-message raw fallback guarantees
/// entropy coding never exceeds the raw format.
#[test]
fn prop_rans_roundtrip_wire_bits_and_fallback() {
    use qsparse::compress::{Codec, WireEncoder};
    let mut rng = Pcg64::seeded(0xA75C0DE);
    let mut wire = WireEncoder::new(Codec::Rans);
    for trial in 0..120 {
        let d = 1 + rng.below_usize(700);
        let x = gen_vector(&mut rng, d, trial);
        for op in operators(d, &mut rng) {
            let msg = op.compress(&x, &mut rng);
            let (bytes, len) = {
                let (b, l) = wire.encode(&msg);
                (b.to_vec(), l)
            };
            assert_eq!(
                len,
                msg.wire_bits_with(Codec::Rans),
                "trial {trial} {}: rans cost walk diverged from serializer",
                op.name()
            );
            assert!(
                len <= msg.wire_bits(),
                "trial {trial} {}: rans ({len}) exceeded raw ({})",
                op.name(),
                msg.wire_bits()
            );
            assert!(bytes.len() as u64 * 8 < len + 8);
            let back = encode::decode(&bytes, len)
                .unwrap_or_else(|e| panic!("trial {trial} {} failed to decode: {e}", op.name()));
            assert_eq!(msg, back, "trial {trial} {}", op.name());
        }
    }
    // Hand-built clustered support: gap histograms are maximally skewed, so
    // the entropy path must engage (strictly beat raw) and round-trip.
    let d = 1 << 20;
    let msg = Message::SparseF32 { d, idx: (500..628u32).collect(), vals: vec![1.5f32; 128] };
    let rans = msg.wire_bits_with(Codec::Rans);
    assert!(rans < msg.wire_bits(), "clustered support must take the entropy path");
    let (bytes, len) = {
        let (b, l) = wire.encode(&msg);
        (b.to_vec(), l)
    };
    assert_eq!(len, rans);
    assert_eq!(encode::decode(&bytes, len), Ok(msg));
}

/// `compress_into` is bit-identical to `compress` — same message, same RNG
/// consumption — and stays so across repeated reuse of one `MessageBuf`
/// (buffer recycling must not leak state between calls or operators).
#[test]
fn prop_compress_into_matches_compress() {
    let mut rng = Pcg64::seeded(0x1A70);
    let mut buf = MessageBuf::new();
    for trial in 0..60 {
        let d = 1 + rng.below_usize(500);
        let x = gen_vector(&mut rng, d, trial);
        for op in operators(d, &mut rng) {
            let mut r1 = Pcg64::new(trial as u64, 9);
            let mut r2 = r1.clone();
            let direct = op.compress(&x, &mut r1);
            // Same shared buf across operators/trials: variant switches and
            // stale capacities must not change the result.
            op.compress_into(&x, &mut r2, &mut buf);
            assert_eq!(&direct, buf.message(), "trial {trial} {}", op.name());
            assert_eq!(
                r1.next_u64(),
                r2.next_u64(),
                "trial {trial} {}: RNG consumption diverged",
                op.name()
            );
        }
    }
    // Large-d Top_k: exercise the sampled-threshold selection path through
    // the scratch buffers (d ≥ 2^16, k ≪ d), twice for reuse.
    let d = 1 << 17;
    let mut rng = Pcg64::seeded(0x7071);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let op = parse_spec("topk:k=500").unwrap();
    for _ in 0..2 {
        let mut r1 = Pcg64::seeded(1);
        let mut r2 = Pcg64::seeded(1);
        let direct = op.compress(&x, &mut r1);
        op.compress_into(&x, &mut r2, &mut buf);
        assert_eq!(&direct, buf.message());
    }
}

/// take/recycle keeps working mid-stream (the parallel engine's message
/// hand-off): taking the produced message, using it, and recycling it must
/// leave the next compress_into unaffected.
#[test]
fn prop_message_take_recycle_roundtrip() {
    let mut rng = Pcg64::seeded(0x7A6E);
    let mut buf = MessageBuf::new();
    let op = parse_spec("qtopk:k=12,bits=4").unwrap();
    for trial in 0..20 {
        let d = 32 + rng.below_usize(200);
        let x = gen_vector(&mut rng, d, trial);
        let mut r1 = Pcg64::new(trial as u64, 3);
        let mut r2 = r1.clone();
        let direct = op.compress(&x, &mut r1);
        op.compress_into(&x, &mut r2, &mut buf);
        let taken = buf.take();
        assert_eq!(direct, taken, "trial {trial}");
        buf.recycle(taken);
    }
}

/// Mathematical consistency: to_dense ≡ add_into, dims preserved, nnz sane.
#[test]
fn prop_message_views_consistent() {
    let mut rng = Pcg64::seeded(0xC0DE);
    for trial in 0..80 {
        let d = 1 + rng.below_usize(300);
        let x = gen_vector(&mut rng, d, trial);
        for op in operators(d, &mut rng) {
            let msg = op.compress(&x, &mut rng);
            assert_eq!(msg.dim(), d, "{}", op.name());
            assert!(msg.nnz() <= d);
            let dense = msg.to_dense();
            let mut acc = vec![7.0f32; d];
            msg.add_into(&mut acc, -3.0);
            for (a, dv) in acc.iter().zip(&dense) {
                let expect = 7.0 - 3.0 * dv;
                assert!(
                    (a - expect).abs() <= 1e-5 * (1.0 + expect.abs()),
                    "{}: {a} vs {expect}",
                    op.name()
                );
            }
        }
    }
}

/// Definition 3 (γ-compression): E‖x − C(x)‖² ≤ (1 − γ)‖x‖², Monte-Carlo
/// over stochastic operators, exact for deterministic ones.
#[test]
fn prop_compression_property_all_operators() {
    let mut rng = Pcg64::seeded(0x9A77A);
    for trial in 0..25 {
        let d = 8 + rng.below_usize(200);
        // Gaussian + wide-range families (zero vectors are trivially fine).
        let x = gen_vector(&mut rng, d, if trial % 2 == 0 { 0 } else { 4 });
        let xn = norm2_sq(&x);
        if xn == 0.0 {
            continue;
        }
        for op in operators(d, &mut rng) {
            let gamma = op.gamma(d);
            if gamma <= 0.0 {
                continue; // outside the operating regime (Remark 1)
            }
            let trials = 300;
            let mut acc = 0.0;
            for _ in 0..trials {
                let dense = op.compress(&x, &mut rng).to_dense();
                let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
                acc += norm2_sq(&resid);
            }
            let mean = acc / trials as f64;
            assert!(
                mean <= (1.0 - gamma) * xn * 1.10 + 1e-9,
                "trial {trial} {} d={d}: E‖x−C‖²={mean:.4e} > (1−γ)‖x‖²={:.4e}",
                op.name(),
                (1.0 - gamma) * xn
            );
        }
    }
}

/// Error-feedback invariant: over any message sequence, memory + total
/// transmitted = total input (conservation of mass).
#[test]
fn prop_error_feedback_conserves_mass() {
    use qsparse::compress::ErrorMemory;
    let mut rng = Pcg64::seeded(0xFEED);
    for trial in 0..40 {
        let d = 4 + rng.below_usize(100);
        for op in operators(d, &mut rng) {
            let mut mem = ErrorMemory::zeros(d);
            let mut total_in = vec![0.0f64; d];
            let mut total_out = vec![0.0f64; d];
            for _round in 0..12 {
                let delta = gen_vector(&mut rng, d, trial);
                for (t, &v) in total_in.iter_mut().zip(&delta) {
                    *t += v as f64;
                }
                let msg = op.compress_via(&mut mem, &delta, &mut rng);
                let dense = msg.to_dense();
                for (t, &v) in total_out.iter_mut().zip(&dense) {
                    *t += v as f64;
                }
            }
            for i in 0..d {
                let lhs = total_in[i];
                let rhs = total_out[i] + mem.as_slice()[i] as f64;
                assert!(
                    (lhs - rhs).abs() <= 1e-2 * (1.0 + lhs.abs()),
                    "{} coord {i}: in={lhs} out+mem={rhs}",
                    op.name()
                );
            }
        }
    }
}

/// Helper so the conservation test reads naturally.
trait CompressVia {
    fn compress_via(
        &self,
        mem: &mut qsparse::compress::ErrorMemory,
        delta: &[f32],
        rng: &mut Pcg64,
    ) -> Message;
}

impl CompressVia for Box<dyn Compressor> {
    fn compress_via(
        &self,
        mem: &mut qsparse::compress::ErrorMemory,
        delta: &[f32],
        rng: &mut Pcg64,
    ) -> Message {
        mem.compress_update(delta, self.as_ref(), rng)
    }
}

/// Elias-γ codes round-trip for arbitrary u64 magnitudes.
#[test]
fn prop_elias_gamma_roundtrip() {
    let mut rng = Pcg64::seeded(0xE11A5);
    let mut w = encode::BitWriter::new();
    let mut values = Vec::new();
    for _ in 0..2000 {
        let v = 1 + (rng.next_u64() >> rng.below(60) as u32);
        w.push_elias_gamma(v);
        values.push(v);
    }
    let (bytes, len) = w.into_bytes();
    let mut r = encode::BitReader::new(&bytes, len);
    for (i, &v) in values.iter().enumerate() {
        assert_eq!(r.read_elias_gamma(), Some(v), "value {i}");
    }
    assert_eq!(r.read_bit(), None);
}

/// JSON emit→parse fixpoint on randomly generated documents.
#[test]
fn prop_json_roundtrip() {
    use qsparse::util::json::Json;
    fn gen(rng: &mut Pcg64, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 1),
            2 => Json::Num((rng.normal() * 1e3).round() / 8.0),
            3 => Json::Str(
                (0..rng.below_usize(12))
                    .map(|_| char::from_u32(0x20 + rng.below(0x50) as u32).unwrap())
                    .collect(),
            ),
            4 => Json::Arr((0..rng.below_usize(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below_usize(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Pcg64::seeded(0x15011);
    for _ in 0..200 {
        let doc = gen(&mut rng, 3);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("{text}: {e}"));
        assert_eq!(doc, back, "{text}");
    }
}
