//! Integration tests: the engine against the paper's algorithmic claims.

use qsparse::compress::{parse_spec, Identity, TopK};
use qsparse::data::{gaussian_clusters_split, Dataset, Sharding};
use qsparse::engine::{run, run_from, TrainSpec};
use qsparse::grad::{GradModel, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::topology::{FixedPeriod, RandomGaps, SyncSchedule};

fn setup(n: usize) -> (Dataset, Dataset, SoftmaxRegression) {
    let (train, test) = gaussian_clusters_split(n, n / 4, 20, 4, 0.4, 1.0, 77);
    let model = SoftmaxRegression::new(20, 4, 1.0 / n as f64);
    (train, test, model)
}

fn base_spec<'a>(
    model: &'a SoftmaxRegression,
    train: &'a Dataset,
    comp: &'a dyn qsparse::Compressor,
    sched: &'a dyn SyncSchedule,
) -> TrainSpec<'a> {
    let mut spec = TrainSpec::new(model, train, comp, sched);
    spec.workers = 5;
    spec.batch = 4;
    spec.steps = 200;
    spec.lr = LrSchedule::Const { eta: 0.4 };
    spec
}

/// H = 1 + identity compressor must be *exactly* vanilla distributed SGD:
/// x_{t+1} = x_t − (η/R) Σ_r ∇f_{i_t^r}(x_t), reproduced here by hand.
#[test]
fn h1_identity_is_bitexact_vanilla_sgd() {
    let (train, _test, model) = setup(200);
    let id = Identity;
    let sched = FixedPeriod::new(1);
    let mut spec = base_spec(&model, &train, &id, &sched);
    spec.steps = 25;
    let hist = run(&spec);

    // Manual replication with the same RNG streams / samplers.
    use qsparse::data::{shard_indices, ShardSampler};
    let d = model.dim();
    let shards = shard_indices(&train, spec.workers, Sharding::Iid);
    let mut samplers: Vec<ShardSampler> = (0..spec.workers)
        .map(|r| ShardSampler::new(shards[r].clone(), spec.batch, spec.seed, r))
        .collect();
    let mut x = vec![0.0f32; d];
    let mut g = vec![0.0f32; d];
    for _t in 0..spec.steps {
        // Engine: each worker does x_local = x − η g_r, sends delta = η g_r;
        // master: x ← x − (1/R) Σ η g_r. Equivalent to the averaged step,
        // with the same per-worker f32 rounding (delta = x − (x − ηg)).
        let eta = 0.4f32;
        let mut acc = vec![0.0f32; d];
        for s in samplers.iter_mut() {
            let batch = s.next_batch(&train);
            model.loss_grad(&x, &batch, &mut g);
            for ((a, &xv), &gv) in acc.iter_mut().zip(&x).zip(&g) {
                *a += xv - (xv - eta * gv);
            }
        }
        for (xv, a) in x.iter_mut().zip(&acc) {
            *xv -= a / spec.workers as f32;
        }
    }
    let final_loss_manual = {
        let all: Vec<usize> = (0..train.n).collect();
        model.loss(&x, &train.gather(&all))
    };
    for (a, b) in hist.final_params.iter().zip(&x) {
        assert!((a - b).abs() <= 1e-6, "iterates diverged: {a} vs {b}");
    }
    assert!(final_loss_manual.is_finite());
}

/// RandomGaps with H = 1 is the synchronous schedule; Algorithm 2 must then
/// coincide with Algorithm 1 exactly.
#[test]
fn async_h1_equals_sync() {
    let (train, _test, model) = setup(200);
    let id = Identity;
    let s_sync = FixedPeriod::new(1);
    let s_async = RandomGaps::generate(5, 1, 60, 123);
    let mut a = base_spec(&model, &train, &id, &s_sync);
    a.steps = 60;
    let mut b = base_spec(&model, &train, &id, &s_async);
    b.steps = 60;
    let ha = run(&a);
    let hb = run(&b);
    assert_eq!(ha.final_params, hb.final_params);
    assert_eq!(ha.total_bits_up(), hb.total_bits_up());
}

/// Every operator in the zoo converges on the strongly convex objective
/// (Theorem 3 / Theorem 6 sanity).
#[test]
fn all_operators_converge_convex() {
    let (train, _test, model) = setup(400);
    let l0 = (4.0f64).ln();
    for spec_str in [
        "identity",
        "topk:k=6",
        "randk:k=12",
        "qsgd:bits=4",
        "sign",
        "qtopk:k=8,bits=4",
        "qtopk:k=8,bits=4,scaled",
        "qtopk:k=8,bits=2,scaled",
        "signtopk:k=8,m=1",
        "signtopk:k=8,m=2",
    ] {
        let comp = parse_spec(spec_str).unwrap();
        for h in [1usize, 4] {
            let sched = FixedPeriod::new(h);
            let mut spec = base_spec(&model, &train, comp.as_ref(), &sched);
            spec.steps = 400;
            spec.lr = LrSchedule::InvTime { xi: 60.0, a: 100.0 };
            let hist = run(&spec);
            let lf = hist.final_loss();
            assert!(
                lf < 0.45 * l0,
                "{spec_str} H={h}: loss {l0:.3} → {lf:.3} (did not converge)"
            );
        }
    }
}

/// Lemma 5 flavor: with a fixed learning rate the average error memory stays
/// bounded over time (no blow-up), and it scales like O(η²).
#[test]
fn memory_bounded_and_scales_with_eta_sq() {
    let (train, _test, model) = setup(400);
    let comp = TopK::new(8);
    let sched = FixedPeriod::new(4);
    let run_with_eta = |eta: f64| {
        let mut spec = base_spec(&model, &train, &comp, &sched);
        spec.steps = 300;
        spec.lr = LrSchedule::Const { eta };
        let hist = run(&spec);
        // max over the second half (steady state)
        hist.points
            .iter()
            .filter(|p| p.step > 150)
            .map(|p| p.mem_norm_sq)
            .fold(0.0f64, f64::max)
    };
    let m1 = run_with_eta(0.2);
    let m2 = run_with_eta(0.1);
    assert!(m1.is_finite() && m1 > 0.0);
    // η halved ⇒ memory bound quarters (allow slack for gradient drift).
    let ratio = m1 / m2;
    assert!(
        (2.0..9.0).contains(&ratio),
        "memory did not scale ~η²: m(0.2)={m1:.3e} m(0.1)={m2:.3e} ratio={ratio:.2}"
    );
}

/// Increasing H with the identity compressor divides the bits by ~H while
/// keeping convergence in range (the local-SGD tradeoff, fig 2/5).
#[test]
fn bits_scale_inversely_with_h() {
    let (train, _test, model) = setup(400);
    let id = Identity;
    let mut bits = Vec::new();
    for h in [1usize, 2, 4, 8] {
        let sched = FixedPeriod::new(h);
        let mut spec = base_spec(&model, &train, &id, &sched);
        spec.steps = 160;
        let hist = run(&spec);
        bits.push(hist.total_bits_up());
    }
    for (i, h) in [2usize, 4, 8].iter().enumerate() {
        let ratio = bits[0] as f64 / bits[i + 1] as f64;
        assert!(
            (ratio - *h as f64).abs() < 0.2 * *h as f64,
            "H={h}: bits ratio {ratio}"
        );
    }
}

/// Sharding by label skew still converges (error feedback handles it), just
/// slower than IID.
#[test]
fn label_skew_converges() {
    let (train, _test, model) = setup(400);
    let comp = TopK::new(8);
    let sched = FixedPeriod::new(2);
    let mut spec = base_spec(&model, &train, &comp, &sched);
    spec.steps = 500;
    spec.sharding = Sharding::LabelSkew;
    spec.lr = LrSchedule::InvTime { xi: 60.0, a: 100.0 };
    let hist = run(&spec);
    assert!(hist.final_loss() < 0.8 * (4.0f64).ln(), "loss {}", hist.final_loss());
}

/// run_from with a nonzero init starts from that init (t=0 loss matches).
#[test]
fn run_from_respects_init() {
    let (train, _test, model) = setup(100);
    let id = Identity;
    let sched = FixedPeriod::new(1);
    let mut spec = base_spec(&model, &train, &id, &sched);
    spec.steps = 1;
    spec.eval_rows = train.n;
    let init = vec![0.5f32; model.dim()];
    let hist = run_from(&spec, init.clone());
    let all: Vec<usize> = (0..train.n).collect();
    let _batch = train.gather(&all);
    let p0 = &hist.points[0];
    // t=0 loss is evaluated at the provided init, not at zeros.
    let zeros_loss = (4.0f64).ln();
    assert!((p0.train_loss - zeros_loss).abs() > 1e-3);
}
