//! # qsparse — Qsparse-local-SGD
//!
//! A production-grade reproduction of *“Qsparse-local-SGD: Distributed SGD
//! with Quantization, Sparsification, and Local Computations”* (Basu, Data,
//! Karakus, Diggavi — NeurIPS 2019), built as a three-layer rust + JAX +
//! Pallas stack:
//!
//! * **L3 (this crate)** — the distributed coordinator: compression
//!   operators with exact wire-format bit accounting, error-feedback memory
//!   on both the uplink (workers) and the downlink (master), synchronous
//!   (Algorithm 1) and asynchronous (Algorithm 2) schedules, sampled
//!   partial participation with participation-aware aggregation scaling
//!   (`topology::Participation` + `protocol::AggScale`), a shared protocol
//!   core (`protocol::{WorkerCore, MasterCore}`) driven by a deterministic
//!   simulation engine, a threaded master/worker runtime and a
//!   discrete-event network simulator (`sim::`) that reports simulated
//!   seconds-to-target under stragglers, skewed bandwidth and churn.
//! * **L2** — JAX models (`python/compile/model.py`), AOT-lowered to HLO
//!   text and executed from rust via PJRT (`runtime::`).
//! * **L1** — Pallas kernels (`python/compile/kernels/`) inside the L2
//!   models.
//!
//! See `DESIGN.md` for the system inventory and `EXPERIMENTS.md` for the
//! paper-vs-measured record. Start with `examples/quickstart.rs`.

// Every pointer dereference inside the fork-join views' unsafe fns must be
// an explicit `unsafe {}` block with its own `// SAFETY:` justification
// (`engine::parallel` module docs; machine-checked by `tools/repo-lint`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod compress;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod faults;
pub mod figures;
pub mod grad;
pub mod optim;
pub mod protocol;
pub mod runtime;
pub mod sim;
pub mod simd;
pub mod spec;
pub mod topology;
pub mod util;

pub use compress::{Compressor, Message, MessageBuf};
pub use engine::{History, TrainSpec};
pub use faults::{FaultAction, FaultPlan, FaultSpec};
pub use grad::GradModel;
pub use optim::{ServerOpt, ServerOptSpec};
pub use protocol::{AggScale, DownlinkWorker, MasterCore, WorkerCore};
pub use sim::{SimResult, SimSpec};
pub use spec::{CompressorSpec, ExperimentSpec, ResolvedExperiment, ScheduleSpec, Workload};
pub use topology::{Participation, ParticipationSpec};
