//! Master actor: a `protocol::MasterCore` behind mpsc channels — decode
//! updates, aggregate, broadcast, record metrics.
//!
//! Aggregation policy (Algorithm 2 line 19): every received update is folded
//! as x ← x − s·g (s = 1/R, or 1/|S_t| under `AggScale::Participants`) and
//! the fresh model is returned to the sender. With a synchronous schedule
//! every *participant* of a round blocks at the same step, so the master
//! *barriers*: it buffers updates in per-step buckets, applies each round
//! once its |S_t| updates arrived — in step order, because sampled
//! participation lets non-participants run ahead into later rounds — and
//! then replies to that round's participants, making the threaded run
//! bit-identical to the engine (which tests rely on).
//!
//! Broadcast: Identity downlink shares one cached `Arc<[f32]>` model
//! snapshot (rebuilt only after the model changes) across a round's reply
//! channels; a non-Identity downlink sends each participant its own encoded
//! error-compensated model delta.
//!
//! Metrics are recorded on the engine's exact step grid
//! (`step % eval_every == 0`, plus the final step): grid points that fall
//! between sync rounds are emitted with the pre-round model, which is
//! precisely the model the engine evaluates there.
//!
//! Receive path: every update is decoded *on arrival* into the sender's
//! recycled `MessageBuf` (`encode::decode_into`) — each worker has at most
//! one update in flight (it blocks on the reply), so one buffer per worker
//! suffices and the decode work overlaps the barrier wait instead of
//! serializing into the round-application tail. Spent wire buffers are
//! recycled through the command channels in both directions (see
//! `UpdateMsg`/`ModelMsg`), so the master's steady-state decode → fold →
//! encode cycle stays off the allocator; what remains per message is the
//! channel transport itself.

use super::{CoordinatorConfig, ModelMsg, ToMaster, UpdateMsg};
use crate::compress::{encode, Message, MessageBuf, WireEncoder};
use crate::data::Dataset;
use crate::engine::parallel::{ChunkView, MsgsView};
use crate::engine::{History, MetricPoint};
use crate::grad::GradModel;
use crate::protocol::MasterCore;
use crate::topology::sync_participants_into;
use crate::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Minimum model dimension for the sharded round fold — below this the
/// per-round rendezvous with the fold shards costs more than the fold.
/// Under Miri the threshold drops so the d-small concurrency tests drive
/// real `FoldPool` interleavings through the race detector.
const SHARD_FOLD_MIN_D: usize = if cfg!(miri) { 16 } else { 1024 };

/// Run a full threaded training job.
///
/// `model_factory` is invoked once on the master thread (for evaluation) and
/// once inside every worker thread — required because `GradModel` may be
/// `!Send` (PJRT). Factories must produce models over the same artifact.
pub fn run_threaded<F>(
    cfg: &CoordinatorConfig,
    model_factory: F,
    train: Arc<Dataset>,
    test: Option<Arc<Dataset>>,
) -> anyhow::Result<History>
where
    F: Fn() -> Box<dyn GradModel> + Send + Clone + 'static,
{
    let eval_model = model_factory();
    let d = eval_model.dim();
    let init = cfg.init.clone().unwrap_or_else(|| vec![0.0f32; d]);
    anyhow::ensure!(init.len() == d, "init length mismatch");
    let dense_down = cfg.down_compressor.is_identity();
    let barrier = cfg.schedule.is_synchronous();
    anyhow::ensure!(
        barrier || cfg.server_opt.is_avg(),
        "a non-averaging server optimizer requires a synchronous schedule on the threaded \
         runtime: the aggregate-on-arrival path applies updates one at a time, so there is no \
         round aggregate to step on (use the engine, or `qsparse sim` — whose event-driven \
         rounds give async schedules a round clock — instead)"
    );
    let mut core = MasterCore::new(init.clone(), cfg.workers, cfg.seed, !dense_down);
    core.set_agg_scale(cfg.agg_scale);
    core.set_server_opt(cfg.server_opt);

    let shards = crate::data::shard_indices(&train, cfg.workers, cfg.sharding);
    let (to_master_tx, to_master_rx) = mpsc::channel::<ToMaster>();
    let mut reply_txs = Vec::with_capacity(cfg.workers);
    let mut handles = Vec::with_capacity(cfg.workers);

    for r in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<ModelMsg>();
        reply_txs.push(tx);
        let args = super::worker::WorkerArgs {
            id: r,
            cfg: cfg.clone(),
            train: Arc::clone(&train),
            shard: shards[r].clone(),
            init: init.clone(),
            to_master: to_master_tx.clone(),
            from_master: rx,
        };
        let factory = model_factory.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("qsparse-worker-{r}"))
                .spawn(move || super::worker::worker_main(factory(), args))?,
        );
    }
    drop(to_master_tx);

    // Fixed eval subsets (mirrors engine::EvalSets).
    let mut eval_rng = Pcg64::new(cfg.seed ^ 0xe7a1, 5);
    let train_eval = {
        let take = cfg.eval_rows.min(train.n);
        train.gather(&eval_rng.sample_indices(train.n, take))
    };
    let test_eval = test.as_ref().map(|ts| {
        let take = cfg.eval_rows.min(ts.n);
        ts.gather(&eval_rng.sample_indices(ts.n, take))
    });

    let mut grid = GridRecorder::new(cfg.eval_every);
    let mut bits_up = 0u64;
    let mut bits_down = 0u64;
    let mut finished = 0usize;
    // Last reported ‖m‖² per worker (memories live in worker threads, but
    // they only change at syncs, so the latest report is the current value).
    let mut mem_norms = vec![0.0f64; cfg.workers];
    // Scratch buffer for the async path's per-step S_t.
    let mut s_t = Vec::with_capacity(cfg.workers);

    // Barrier mode: the ordered sync rounds (sync step t, participants S_t),
    // shared with the engine by construction (same schedule, same
    // materialized participation). The master waits for exactly |S_t|
    // updates per round and applies rounds in step order — under sampled
    // participation a skipped worker runs ahead and may deliver its *next*
    // round's update before the current round completes.
    let rounds: Vec<(usize, Vec<usize>)> = if barrier {
        let mut rounds = Vec::new();
        let mut set = Vec::with_capacity(cfg.workers);
        for t in 0..cfg.steps {
            sync_participants_into(
                cfg.schedule.as_ref(),
                &cfg.participation,
                cfg.workers,
                t,
                &mut set,
            );
            if !set.is_empty() {
                rounds.push((t, set.clone()));
            }
        }
        rounds
    } else {
        Vec::new()
    };
    let mut round_idx = 0usize;
    // Arrived-but-unapplied update *metadata*, keyed by sync step — the
    // decoded messages themselves sit in their senders' `upd_bufs` slots
    // (at most one in-flight update per worker, so a slot is never
    // overwritten before its round applies). BTreeMap: deterministic-path
    // module (repo-lint bans RandomState-backed maps here).
    let mut buckets: BTreeMap<usize, Vec<UpdateMeta>> = BTreeMap::new();
    // Per-worker recycled decode buffers and the spent wire-byte pool.
    let mut upd_bufs: Vec<MessageBuf> = (0..cfg.workers).map(|_| MessageBuf::new()).collect();
    let mut spare_bytes: Vec<Vec<u8>> = Vec::new();
    // Reused downlink compression buffer and wire encoder.
    let mut down_buf = MessageBuf::new();
    let mut wire = WireEncoder::new(cfg.codec);
    // Sharded round fold (barrier mode, large models only): a persistent
    // mini-pool of fold threads, each folding every round message over its
    // own disjoint chunk of the fold target in worker-index order — per
    // coordinate the addition sequence equals the sequential
    // `apply_update` loop's, so `History` stays bit-identical (tested).
    let nshards = std::thread::available_parallelism().map_or(1, |n| n.get()).min(8);
    let fold_pool = (barrier && cfg.workers >= 2 && d >= SHARD_FOLD_MIN_D && nshards >= 2)
        .then(|| FoldPool::spawn(nshards));
    // The round's messages in worker-index order, taken out of (and after
    // the fold returned to) their owners' decode buffers — reused each
    // round.
    let mut round_msgs: Vec<Message> = Vec::with_capacity(cfg.workers);

    let measure = |step: usize, global: &[f32], bits_up: u64, bits_down: u64, mem: f64| {
        let train_loss = eval_model.loss(global, &train_eval);
        let (test_err, test_top5) = match &test_eval {
            Some(tb) => (
                eval_model.error_rate(global, tb),
                eval_model.topn_error_rate(global, tb, 5),
            ),
            None => (f64::NAN, f64::NAN),
        };
        MetricPoint {
            step,
            train_loss,
            test_err,
            test_top5_err: test_top5,
            bits_up,
            bits_down,
            mem_norm_sq: mem,
        }
    };
    grid.history.push(measure(0, core.params(), 0, 0, 0.0));

    while finished < cfg.workers {
        match to_master_rx.recv() {
            Err(_) => break,
            Ok(ToMaster::Finished(_)) => finished += 1,
            Ok(ToMaster::Update(mut upd)) => {
                // Decode on arrival into the sender's recycled buffer, then
                // return the spent byte vectors to the recycle pool.
                decode_update_into(&upd, &mut upd_bufs[upd.worker])?;
                recycle(&mut spare_bytes, std::mem::take(&mut upd.bytes));
                recycle(&mut spare_bytes, std::mem::take(&mut upd.spent_down));
                let meta = UpdateMeta {
                    worker: upd.worker,
                    bit_len: upd.bit_len,
                    mem_norm_sq: upd.mem_norm_sq,
                };
                if barrier {
                    buckets.entry(upd.step).or_default().push(meta);
                    // Apply every round that is now complete, in step order.
                    while round_idx < rounds.len() {
                        let (step, parts) = &rounds[round_idx];
                        let (step, expect) = (*step, parts.len());
                        if buckets.get(&step).map_or(0, Vec::len) < expect {
                            break;
                        }
                        let mut batch = buckets.remove(&step).expect("bucket checked above");
                        // Grid points at or before this round's sync step see
                        // the pre-round model — exactly what the engine
                        // records between rounds (bits/memories are accounted
                        // at application, so they too reflect applied rounds
                        // only).
                        grid.catch_up(step, |s| {
                            measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                        });
                        // Apply in worker order: f32 addition is not
                        // associative, and a fixed order makes the threaded
                        // sync run bit-identical to the engine (tested).
                        batch.sort_by_key(|u| u.worker);
                        core.begin_round(expect);
                        for u in &batch {
                            bits_up += u.bit_len;
                            mem_norms[u.worker] = u.mem_norm_sq;
                        }
                        match &fold_pool {
                            Some(pool) => {
                                // Sharded fold: move the round's decoded
                                // messages into one worker-ordered list,
                                // fan the disjoint chunks out, then hand
                                // each message back to its owner's buffer
                                // so decode storage keeps recycling.
                                round_msgs.clear();
                                for u in &batch {
                                    let msg = std::mem::take(&mut upd_bufs[u.worker].msg);
                                    anyhow::ensure!(
                                        msg.dim() == d,
                                        "update dimension mismatch: message d={} vs model d={d}",
                                        msg.dim(),
                                    );
                                    round_msgs.push(msg);
                                }
                                pool.fold(&round_msgs, &mut core);
                                for (u, msg) in batch.iter().zip(round_msgs.drain(..)) {
                                    upd_bufs[u.worker].msg = msg;
                                }
                            }
                            None => {
                                for u in &batch {
                                    core.apply_update(upd_bufs[u.worker].message())?;
                                }
                            }
                        }
                        // Server optimizer step on the round aggregate
                        // (no-op for Avg) — before any broadcast encoding.
                        core.end_round();
                        // Reply to this round's participants only — a
                        // non-participant never blocks on the master, and a
                        // queued stale model would corrupt its next sync.
                        if dense_down {
                            let payload = core.params_snapshot();
                            let bits = encode::dense_model_bits(d);
                            for &r in parts {
                                bits_down += bits;
                                let _ = reply_txs[r].send(ModelMsg::Dense {
                                    params: Arc::clone(&payload),
                                    recycled: spare_bytes.pop().unwrap_or_default(),
                                });
                            }
                        } else {
                            for &r in parts {
                                let (bytes, bit_len) = encode_delta(
                                    &mut core,
                                    cfg.down_compressor.as_ref(),
                                    &mut down_buf,
                                    &mut wire,
                                    r,
                                    spare_bytes.pop().unwrap_or_default(),
                                );
                                bits_down += bit_len;
                                let _ = reply_txs[r].send(ModelMsg::Delta {
                                    bytes,
                                    bit_len,
                                    recycled: spare_bytes.pop().unwrap_or_default(),
                                });
                            }
                        }
                        grid.boundary(step, |s| {
                            measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                        });
                        round_idx += 1;
                    }
                } else {
                    // Aggregate-on-arrival (asynchronous schedules).
                    let step = upd.step;
                    let worker = meta.worker;
                    grid.catch_up(step, |s| {
                        measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                    });
                    bits_up += meta.bit_len;
                    mem_norms[worker] = meta.mem_norm_sq;
                    // |S_t| for the unbiased scale (same shared predicate as
                    // the engine; the sender is a member, so it is never
                    // empty).
                    sync_participants_into(
                        cfg.schedule.as_ref(),
                        &cfg.participation,
                        cfg.workers,
                        step,
                        &mut s_t,
                    );
                    core.begin_round(s_t.len());
                    core.apply_update(upd_bufs[worker].message())?;
                    // Avg is guaranteed here (non-Avg + async is rejected up
                    // front), so this is a documented no-op.
                    core.end_round();
                    if dense_down {
                        bits_down += encode::dense_model_bits(d);
                        let _ = reply_txs[worker].send(ModelMsg::Dense {
                            params: core.params_snapshot(),
                            recycled: spare_bytes.pop().unwrap_or_default(),
                        });
                    } else {
                        let (bytes, bit_len) = encode_delta(
                            &mut core,
                            cfg.down_compressor.as_ref(),
                            &mut down_buf,
                            &mut wire,
                            worker,
                            spare_bytes.pop().unwrap_or_default(),
                        );
                        bits_down += bit_len;
                        let _ = reply_txs[worker].send(ModelMsg::Delta {
                            bytes,
                            bit_len,
                            recycled: spare_bytes.pop().unwrap_or_default(),
                        });
                    }
                    grid.boundary(step, |s| {
                        measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                    });
                }
            }
        }
    }
    // Tail of the grid (steps after the last sync leave the model frozen),
    // then the final step if it is not itself a grid point.
    grid.catch_up(cfg.steps, |s| {
        measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
    });
    let mut history = grid.history;
    if history.points.last().map_or(true, |p| p.step != cfg.steps) {
        history.push(measure(cfg.steps, core.params(), bits_up, bits_down, avg(&mem_norms)));
    }

    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))?;
    }
    if let Some(pool) = fold_pool {
        pool.join();
    }
    history.final_params = core.into_params();
    Ok(history)
}

/// Records `MetricPoint`s on the engine's exact step grid: multiples of
/// `eval_every`, with grid points between sync rounds evaluated on the
/// pre-round state (the model is frozen there) and round boundaries on the
/// post-round state — see `engine::run_from`'s recording rule.
struct GridRecorder {
    history: History,
    /// Next unrecorded grid point.
    next_eval: usize,
    eval_every: usize,
}

impl GridRecorder {
    fn new(eval_every: usize) -> Self {
        GridRecorder { history: History::new(), next_eval: eval_every, eval_every }
    }

    /// Record every unrecorded grid point ≤ `step` with the *current*
    /// (pre-round) state.
    fn catch_up(&mut self, step: usize, mut mk: impl FnMut(usize) -> MetricPoint) {
        while self.next_eval <= step {
            self.history.push(mk(self.next_eval));
            self.next_eval += self.eval_every;
        }
    }

    /// Record the boundary `step + 1` of a just-applied round iff it is the
    /// next grid point.
    fn boundary(&mut self, step: usize, mk: impl FnOnce(usize) -> MetricPoint) {
        if step + 1 == self.next_eval {
            self.history.push(mk(step + 1));
            self.next_eval += self.eval_every;
        }
    }
}

/// Per-update bookkeeping kept while a round waits behind the barrier; the
/// decoded message itself stays in the sender's `upd_bufs` slot.
struct UpdateMeta {
    worker: usize,
    bit_len: u64,
    mem_norm_sq: f64,
}

/// Return a spent wire buffer to the recycle pool (empty vectors carry no
/// capacity and are dropped instead of occupying a slot).
fn recycle(pool: &mut Vec<Vec<u8>>, bytes: Vec<u8>) {
    if bytes.capacity() > 0 {
        pool.push(bytes);
    }
}

/// Compress and wire-encode the downlink delta for worker `r` into the
/// recycled `spare` buffer — shared by the barrier and
/// aggregate-on-arrival paths so their encoding and bit accounting cannot
/// diverge.
fn encode_delta(
    core: &mut MasterCore,
    down: &dyn crate::compress::Compressor,
    buf: &mut MessageBuf,
    wire: &mut WireEncoder,
    r: usize,
    spare: Vec<u8>,
) -> (Vec<u8>, u64) {
    core.delta_broadcast_into(r, down, buf);
    let (bytes, bit_len) = wire.encode(buf.message());
    let mut out = spare;
    out.clear();
    out.extend_from_slice(bytes);
    (out, bit_len)
}

/// A persistent mini-pool of fold threads for the barrier path's sharded
/// round fold. Reuses the engine pool's `MsgsView`/`ChunkView` machinery
/// and contract: the master carves disjoint chunks of
/// `MasterCore::fold_target`, sends one command per shard, and touches
/// neither the message list nor the fold target again until every ack is
/// back.
struct FoldPool {
    txs: Vec<mpsc::Sender<FoldCmd>>,
    acks: Vec<mpsc::Receiver<()>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// One shard's fold command (see `engine::parallel::ChunkView::fold`).
struct FoldCmd {
    msgs: MsgsView,
    chunk: ChunkView,
    scale: f32,
}

impl FoldPool {
    fn spawn(nshards: usize) -> Self {
        let mut txs = Vec::with_capacity(nshards);
        let mut acks = Vec::with_capacity(nshards);
        let mut handles = Vec::with_capacity(nshards);
        for i in 0..nshards {
            let (cmd_tx, cmd_rx) = mpsc::channel::<FoldCmd>();
            let (ack_tx, ack_rx) = mpsc::channel::<()>();
            txs.push(cmd_tx);
            acks.push(ack_rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("qsparse-fold-{i}"))
                    .spawn(move || {
                        for cmd in cmd_rx {
                            // SAFETY: per the view contracts, the master
                            // keeps the message list and fold target
                            // untouched until this shard's ack, and no
                            // other shard's chunk overlaps.
                            unsafe { cmd.chunk.fold(cmd.msgs, cmd.scale) };
                            if ack_tx.send(()).is_err() {
                                return; // master gone
                            }
                        }
                    })
                    .expect("failed to spawn fold shard thread"),
            );
        }
        FoldPool { txs, acks, handles }
    }

    /// Fold the round's worker-ordered messages into the master's fold
    /// target, sharded by coordinate range. Blocks until every shard acks,
    /// so the borrow handed out by `fold_target` is quiescent again on
    /// return.
    fn fold(&self, msgs: &[Message], core: &mut MasterCore) {
        let view = MsgsView::new(msgs);
        let (target, scale) = core.fold_target();
        let d = target.len();
        let n = self.txs.len();
        for (ti, tx) in self.txs.iter().enumerate() {
            let (lo, hi) = (ti * d / n, (ti + 1) * d / n);
            // The [lo, hi) ranges partition 0..d, so the chunks are
            // disjoint.
            let chunk = ChunkView::new(target, lo, hi);
            tx.send(FoldCmd { msgs: view, chunk, scale }).expect("fold shard thread died");
        }
        for ack in &self.acks {
            ack.recv().expect("fold shard thread died");
        }
    }

    fn join(self) {
        drop(self.txs);
        drop(self.acks);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

/// Decode an update into the sender's recycled buffer (`decode_into`
/// recycles the previous message's vectors, so with a fixed per-worker
/// operator the steady state allocates nothing here).
fn decode_update_into(upd: &UpdateMsg, buf: &mut MessageBuf) -> anyhow::Result<()> {
    encode::decode_into(&upd.bytes, upd.bit_len, buf)
        .map_err(|e| anyhow::anyhow!("undecodable update from worker {}: {e}", upd.worker))
}

fn avg(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}
