//! Master actor: a `protocol::MasterCore` behind mpsc channels — decode
//! updates, aggregate, broadcast, record metrics.
//!
//! Aggregation policy (Algorithm 2 line 19): every received update is folded
//! as x ← x − s·g (s = 1/R, or 1/|S_t| under `AggScale::Participants`) and
//! the fresh model is returned to the sender. With a synchronous schedule
//! every *participant* of a round blocks at the same step, so the master
//! *barriers*: it buffers updates in per-step buckets, applies each round
//! once its |S_t| updates arrived — in step order, because sampled
//! participation lets non-participants run ahead into later rounds — and
//! then replies to that round's participants, making the threaded run
//! bit-identical to the engine (which tests rely on).
//!
//! Broadcast: Identity downlink shares one cached `Arc<[f32]>` model
//! snapshot (rebuilt only after the model changes) across a round's reply
//! channels; a non-Identity downlink sends each participant its own encoded
//! error-compensated model delta.
//!
//! Metrics are recorded on the engine's exact step grid
//! (`step % eval_every == 0`, plus the final step): grid points that fall
//! between sync rounds are emitted with the pre-round model, which is
//! precisely the model the engine evaluates there.
//!
//! Receive path: every update is decoded *on arrival* into the sender's
//! recycled `MessageBuf` (`encode::decode_into`) — each worker has at most
//! one update in flight (it blocks on the reply), so one buffer per worker
//! suffices and the decode work overlaps the barrier wait instead of
//! serializing into the round-application tail. Spent wire buffers are
//! recycled through the command channels in both directions (see
//! `UpdateMsg`/`ModelMsg`), so the master's steady-state decode → fold →
//! encode cycle stays off the allocator; what remains per message is the
//! channel transport itself.
//!
//! Fault injection (`CoordinatorConfig::faults`): the stateless
//! [`FaultPlan`] is evaluated at this channel boundary, per (worker, sync
//! step). Round completion becomes *count-based*: every expected
//! participant is accounted for by a delivered update, an
//! immediately-acknowledged loss (`ModelMsg::Missed` — dropped or
//! undecodable uplink; the sender's error memory re-absorbs the update),
//! or a crash both sides derive from the same pure hash. Delayed messages
//! are overtaken by whatever is already queued on the channel (real
//! reordering, no wall clock); duplicated uplinks re-enter the queue as a
//! literal second copy and die on the per-(worker, step) idempotence
//! guard. An undecodable update — injected or organic — is a *logged
//! drop*, never an abort. Downlink faults are decided before the
//! per-worker mirror advances, so a lost or corrupted reply costs one
//! round of staleness, never mirror divergence.

use super::{CoordinatorConfig, CoordinatorError, ModelMsg, ToMaster, UpdateMsg};
use crate::compress::{encode, Message, MessageBuf, WireEncoder};
use crate::data::Dataset;
use crate::engine::parallel::{ChunkView, MsgsView};
use crate::engine::{History, MetricPoint};
use crate::faults::{Channel, FaultAction, FaultPlan};
use crate::grad::GradModel;
use crate::protocol::MasterCore;
use crate::topology::sync_participants_into;
use crate::util::rng::Pcg64;
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::sync::Arc;

/// Minimum model dimension for the sharded round fold — below this the
/// per-round rendezvous with the fold shards costs more than the fold.
/// Under Miri the threshold drops so the d-small concurrency tests drive
/// real `FoldPool` interleavings through the race detector.
const SHARD_FOLD_MIN_D: usize = if cfg!(miri) { 16 } else { 1024 };

/// Run a full threaded training job.
///
/// `model_factory` is invoked once on the master thread (for evaluation) and
/// once inside every worker thread — required because `GradModel` may be
/// `!Send` (PJRT). Factories must produce models over the same artifact.
pub fn run_threaded<F>(
    cfg: &CoordinatorConfig,
    model_factory: F,
    train: Arc<Dataset>,
    test: Option<Arc<Dataset>>,
) -> anyhow::Result<History>
where
    F: Fn() -> Box<dyn GradModel> + Send + Clone + 'static,
{
    let eval_model = model_factory();
    let d = eval_model.dim();
    let init = cfg.init.clone().unwrap_or_else(|| vec![0.0f32; d]);
    anyhow::ensure!(init.len() == d, "init length mismatch");
    let dense_down = cfg.down_compressor.is_identity();
    let barrier = cfg.schedule.is_synchronous();
    anyhow::ensure!(
        barrier || cfg.server_opt.is_avg(),
        "a non-averaging server optimizer requires a synchronous schedule on the threaded \
         runtime: the aggregate-on-arrival path applies updates one at a time, so there is no \
         round aggregate to step on (use the engine, or `qsparse sim` — whose event-driven \
         rounds give async schedules a round clock — instead)"
    );
    let plan = cfg.faults.and_then(FaultPlan::new);
    if let Some(p) = &plan {
        p.spec().validate()?;
    }
    anyhow::ensure!(
        plan.is_none() || barrier,
        "fault injection on the threaded runtime requires a synchronous schedule: round \
         completion under faults is counted per sync round (use `qsparse sim` for asynchronous \
         fault experiments)"
    );
    let mut core = MasterCore::new(init.clone(), cfg.workers, cfg.seed, !dense_down);
    core.set_agg_scale(cfg.agg_scale);
    core.set_server_opt(cfg.server_opt);

    let shards = crate::data::shard_indices(&train, cfg.workers, cfg.sharding);
    let (to_master_tx, to_master_rx) = mpsc::channel::<ToMaster>();
    let mut reply_txs = Vec::with_capacity(cfg.workers);
    let mut handles = Vec::with_capacity(cfg.workers);

    for r in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<ModelMsg>();
        reply_txs.push(tx);
        let args = super::worker::WorkerArgs {
            id: r,
            cfg: cfg.clone(),
            train: Arc::clone(&train),
            shard: shards[r].clone(),
            init: init.clone(),
            to_master: to_master_tx.clone(),
            from_master: rx,
        };
        let factory = model_factory.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("qsparse-worker-{r}"))
                .spawn(move || super::worker::worker_main(factory(), args))?,
        );
    }
    drop(to_master_tx);

    // Fixed eval subsets (mirrors engine::EvalSets).
    let mut eval_rng = Pcg64::new(cfg.seed ^ 0xe7a1, 5);
    let train_eval = {
        let take = cfg.eval_rows.min(train.n);
        train.gather(&eval_rng.sample_indices(train.n, take))
    };
    let test_eval = test.as_ref().map(|ts| {
        let take = cfg.eval_rows.min(ts.n);
        ts.gather(&eval_rng.sample_indices(ts.n, take))
    });

    let mut grid = GridRecorder::new(cfg.eval_every);
    let mut bits_up = 0u64;
    let mut bits_down = 0u64;
    let mut finished = 0usize;
    // Last reported ‖m‖² per worker (memories live in worker threads, but
    // they only change at syncs, so the latest report is the current value).
    let mut mem_norms = vec![0.0f64; cfg.workers];
    // Scratch buffer for the async path's per-step S_t.
    let mut s_t = Vec::with_capacity(cfg.workers);

    // Barrier mode: the ordered sync rounds (sync step t, participants S_t),
    // shared with the engine by construction (same schedule, same
    // materialized participation). The master waits for exactly |S_t|
    // updates per round and applies rounds in step order — under sampled
    // participation a skipped worker runs ahead and may deliver its *next*
    // round's update before the current round completes.
    let rounds: Vec<(usize, Vec<usize>)> = if barrier {
        let mut rounds = Vec::new();
        let mut set = Vec::with_capacity(cfg.workers);
        for t in 0..cfg.steps {
            sync_participants_into(
                cfg.schedule.as_ref(),
                &cfg.participation,
                cfg.workers,
                t,
                &mut set,
            );
            if !set.is_empty() {
                rounds.push((t, set.clone()));
            }
        }
        rounds
    } else {
        Vec::new()
    };
    let mut round_idx = 0usize;
    // Arrived-but-unapplied update *metadata*, keyed by sync step — the
    // decoded messages themselves sit in their senders' `upd_bufs` slots
    // (at most one in-flight update per worker, so a slot is never
    // overwritten before its round applies). BTreeMap: deterministic-path
    // module (repo-lint bans RandomState-backed maps here).
    let mut buckets: BTreeMap<usize, Vec<UpdateMeta>> = BTreeMap::new();
    // Per-worker recycled decode buffers and the spent wire-byte pool.
    let mut upd_bufs: Vec<MessageBuf> = (0..cfg.workers).map(|_| MessageBuf::new()).collect();
    let mut spare_bytes: Vec<Vec<u8>> = Vec::new();
    // Reused downlink compression buffer and wire encoder.
    let mut down_buf = MessageBuf::new();
    let mut wire = WireEncoder::new(cfg.codec);
    // Sharded round fold (barrier mode, large models only): a persistent
    // mini-pool of fold threads, each folding every round message over its
    // own disjoint chunk of the fold target in worker-index order — per
    // coordinate the addition sequence equals the sequential
    // `apply_update` loop's, so `History` stays bit-identical (tested).
    let nshards = std::thread::available_parallelism().map_or(1, |n| n.get()).min(8);
    let fold_pool = (barrier && cfg.workers >= 2 && d >= SHARD_FOLD_MIN_D && nshards >= 2)
        .then(|| FoldPool::spawn(nshards))
        .transpose()?;
    // The round's messages in worker-index order, taken out of (and after
    // the fold returned to) their owners' decode buffers — reused each
    // round.
    let mut round_msgs: Vec<Message> = Vec::with_capacity(cfg.workers);

    let measure = |step: usize, global: &[f32], bits_up: u64, bits_down: u64, mem: f64| {
        let train_loss = eval_model.loss(global, &train_eval);
        let (test_err, test_top5) = match &test_eval {
            Some(tb) => (
                eval_model.error_rate(global, tb),
                eval_model.topn_error_rate(global, tb, 5),
            ),
            None => (f64::NAN, f64::NAN),
        };
        MetricPoint {
            step,
            train_loss,
            test_err,
            test_top5_err: test_top5,
            bits_up,
            bits_down,
            mem_norm_sq: mem,
        }
    };
    grid.history.push(measure(0, core.params(), 0, 0, 0.0));

    // Inbound staging queue: one channel receipt can expand into several
    // arrivals (overtakers pulled ahead of a delayed message, the literal
    // second copy of a duplicated one) — see `Inbound`.
    let mut inbound: VecDeque<Inbound> = VecDeque::new();
    let mut disconnected = false;

    while finished < cfg.workers {
        debug_assert!(inbound.is_empty(), "inbound queue drains every receipt");
        match to_master_rx.recv() {
            Err(_) => {
                disconnected = true;
                break;
            }
            Ok(m) => inbound.push_back(Inbound::Fresh(m)),
        }
        while let Some(item) = inbound.pop_front() {
            let (mut upd, decided) = match item {
                Inbound::Fresh(ToMaster::Finished(_)) => {
                    finished += 1;
                    continue;
                }
                Inbound::Fresh(ToMaster::Update(u)) => (u, false),
                Inbound::Decided(u) => (u, true),
            };
            // Uplink fault decision at the channel boundary. A message is
            // decided at most once: decisions are pure per (worker, step),
            // so a re-decided delayed message would delay forever.
            let action = match (&plan, decided) {
                (Some(p), false) => p.decide(upd.worker, upd.step, Channel::Up),
                _ => FaultAction::Deliver,
            };
            match action {
                FaultAction::Delay(_) => {
                    // Reorder at the boundary: everything already queued on
                    // the transport overtakes this message, then it
                    // delivers — no wall clock, no stalled barrier.
                    while let Ok(m) = to_master_rx.try_recv() {
                        inbound.push_back(Inbound::Fresh(m));
                    }
                    inbound.push_back(Inbound::Decided(upd));
                    continue;
                }
                FaultAction::Duplicate => {
                    // Deliver this copy; enqueue a literal second copy that
                    // will reach the per-(worker, step) idempotence guard
                    // below as a genuine duplicate arrival.
                    inbound.push_back(Inbound::Decided(UpdateMsg {
                        worker: upd.worker,
                        step: upd.step,
                        bytes: upd.bytes.clone(),
                        bit_len: upd.bit_len,
                        mem_norm_sq: upd.mem_norm_sq,
                        spent_down: Vec::new(),
                    }));
                }
                FaultAction::Corrupt => FaultPlan::corrupt_bytes(&mut upd.bytes),
                FaultAction::Drop | FaultAction::Deliver => {}
            }
            // Decode on arrival into the sender's recycled buffer. An
            // undecodable update — injected corruption or an organic wire
            // fault — is a logged drop, never an abort: the sender's error
            // memory re-absorbs the update (satellite of the EF analysis:
            // compressed mass is never lost, only deferred).
            let delivered = !matches!(action, FaultAction::Drop)
                && match encode::decode_into(&upd.bytes, upd.bit_len, &mut upd_bufs[upd.worker]) {
                    Ok(()) => true,
                    Err(e) => {
                        eprintln!(
                            "master: dropping undecodable update from worker {} at step {}: {e}",
                            upd.worker, upd.step
                        );
                        false
                    }
                };
            recycle(&mut spare_bytes, std::mem::take(&mut upd.bytes));
            recycle(&mut spare_bytes, std::mem::take(&mut upd.spent_down));
            let meta = UpdateMeta {
                worker: upd.worker,
                bit_len: upd.bit_len,
                mem_norm_sq: upd.mem_norm_sq,
                delivered,
            };
            if barrier {
                // Idempotence and ordering guards (reachable only under
                // faults — the fault-free transport delivers exactly once,
                // and a worker blocks until its round applied).
                if plan.is_some() {
                    if rounds[..round_idx].binary_search_by_key(&upd.step, |r| r.0).is_ok() {
                        // Stale copy for an already-applied round: rejected,
                        // never re-folded.
                        continue;
                    }
                    if buckets
                        .get(&upd.step)
                        .is_some_and(|b| b.iter().any(|m| m.worker == meta.worker))
                    {
                        // Second copy of a duplicated uplink: applied once
                        // per (worker, step).
                        continue;
                    }
                }
                if !meta.delivered {
                    // Immediate loss acknowledgement — the sender blocks on
                    // this reply; `lost_uplink` tells it to re-absorb.
                    let _ = reply_txs[meta.worker].send(ModelMsg::Missed {
                        lost_uplink: true,
                        recycled: spare_bytes.pop().unwrap_or_default(),
                    });
                }
                buckets.entry(upd.step).or_default().push(meta);
                // Apply every round that is now complete, in step order.
                // Under faults completion is count-based: updates and
                // acknowledged losses both report; crashed participants are
                // subtracted via the same pure predicate the worker used.
                while round_idx < rounds.len() {
                    let (step, parts) = &rounds[round_idx];
                    let (step, expect) = (*step, parts.len());
                    let expect_reports = match &plan {
                        Some(p) => parts.iter().filter(|&&w| !p.crash_at(w, step)).count(),
                        None => expect,
                    };
                    if buckets.get(&step).map_or(0, Vec::len) < expect_reports {
                        break;
                    }
                    let mut batch = buckets.remove(&step).unwrap_or_default();
                    // Grid points at or before this round's sync step see
                    // the pre-round model — exactly what the engine
                    // records between rounds (bits/memories are accounted
                    // at application, so they too reflect applied rounds
                    // only).
                    grid.catch_up(step, |s| {
                        measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                    });
                    // Apply in worker order: f32 addition is not
                    // associative, and a fixed order makes the threaded
                    // sync run bit-identical to the engine (tested).
                    batch.sort_by_key(|u| u.worker);
                    core.begin_round(expect);
                    // Wire bits were spent for lost updates too — account
                    // every report, fold only the delivered ones.
                    for u in &batch {
                        bits_up += u.bit_len;
                        mem_norms[u.worker] = u.mem_norm_sq;
                    }
                    match &fold_pool {
                        Some(pool) => {
                            // Sharded fold: move the round's decoded
                            // messages into one worker-ordered list,
                            // fan the disjoint chunks out, then hand
                            // each message back to its owner's buffer
                            // so decode storage keeps recycling.
                            round_msgs.clear();
                            for u in batch.iter().filter(|u| u.delivered) {
                                let msg = std::mem::take(&mut upd_bufs[u.worker].msg);
                                anyhow::ensure!(
                                    msg.dim() == d,
                                    "update dimension mismatch: message d={} vs model d={d}",
                                    msg.dim(),
                                );
                                round_msgs.push(msg);
                            }
                            let folded = pool.fold(&round_msgs, &mut core);
                            for (u, msg) in
                                batch.iter().filter(|u| u.delivered).zip(round_msgs.drain(..))
                            {
                                upd_bufs[u.worker].msg = msg;
                            }
                            folded?;
                        }
                        None => {
                            for u in batch.iter().filter(|u| u.delivered) {
                                core.apply_update(upd_bufs[u.worker].message())?;
                            }
                        }
                    }
                    // Server optimizer step on the round aggregate
                    // (no-op for Avg) — before any broadcast encoding.
                    core.end_round();
                    // Reply to this round's *delivered* participants only:
                    // lost senders were acknowledged on arrival and moved
                    // on, crashed ones never blocked, and a queued stale
                    // model would corrupt a non-participant's next sync.
                    // Downlink faults are decided before any mirror
                    // advance, so both sides stay consistent.
                    if dense_down {
                        let payload = core.params_snapshot();
                        let bits = encode::dense_model_bits(d);
                        for u in batch.iter().filter(|u| u.delivered) {
                            let r = u.worker;
                            let down = plan.map_or(FaultAction::Deliver, |p| {
                                p.decide(r, step, Channel::Down)
                            });
                            if matches!(down, FaultAction::Drop | FaultAction::Corrupt) {
                                // The dense broadcast has no wire-decode
                                // stage, so both downlink faults degrade
                                // to a dropped reply.
                                let _ = reply_txs[r].send(ModelMsg::Missed {
                                    lost_uplink: false,
                                    recycled: spare_bytes.pop().unwrap_or_default(),
                                });
                                continue;
                            }
                            bits_down += bits;
                            let _ = reply_txs[r].send(ModelMsg::Dense {
                                params: Arc::clone(&payload),
                                recycled: spare_bytes.pop().unwrap_or_default(),
                            });
                        }
                    } else {
                        for u in batch.iter().filter(|u| u.delivered) {
                            let r = u.worker;
                            let down = plan.map_or(FaultAction::Deliver, |p| {
                                p.decide(r, step, Channel::Down)
                            });
                            match down {
                                FaultAction::Drop => {
                                    // Mirror untouched; the worker keeps
                                    // its anchor and the next delta simply
                                    // spans the missed round.
                                    let _ = reply_txs[r].send(ModelMsg::Missed {
                                        lost_uplink: false,
                                        recycled: spare_bytes.pop().unwrap_or_default(),
                                    });
                                }
                                FaultAction::Corrupt => {
                                    // Exercise the worker's decode-drop
                                    // path with deliberately undecodable
                                    // bytes (tag 7 = `BadTag` on every
                                    // codec) *without* advancing the
                                    // mirror — a corrupted delta must
                                    // never desynchronize the pair.
                                    let mut bytes = spare_bytes.pop().unwrap_or_default();
                                    bytes.clear();
                                    bytes.push(0xE0);
                                    let _ = reply_txs[r].send(ModelMsg::Delta {
                                        bytes,
                                        bit_len: 8,
                                        recycled: spare_bytes.pop().unwrap_or_default(),
                                    });
                                }
                                _ => {
                                    let (bytes, bit_len) = encode_delta(
                                        &mut core,
                                        cfg.down_compressor.as_ref(),
                                        &mut down_buf,
                                        &mut wire,
                                        r,
                                        spare_bytes.pop().unwrap_or_default(),
                                    );
                                    bits_down += bit_len;
                                    let _ = reply_txs[r].send(ModelMsg::Delta {
                                        bytes,
                                        bit_len,
                                        recycled: spare_bytes.pop().unwrap_or_default(),
                                    });
                                }
                            }
                        }
                    }
                    grid.boundary(step, |s| {
                        measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                    });
                    round_idx += 1;
                }
            } else {
                // Aggregate-on-arrival (asynchronous schedules; `plan` is
                // `None` here — faults require the barrier).
                let step = upd.step;
                let worker = meta.worker;
                grid.catch_up(step, |s| {
                    measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                });
                bits_up += meta.bit_len;
                mem_norms[worker] = meta.mem_norm_sq;
                if !meta.delivered {
                    // Organic wire fault: acknowledge the loss so the
                    // sender re-absorbs and keeps training.
                    let _ = reply_txs[worker].send(ModelMsg::Missed {
                        lost_uplink: true,
                        recycled: spare_bytes.pop().unwrap_or_default(),
                    });
                    continue;
                }
                // |S_t| for the unbiased scale (same shared predicate as
                // the engine; the sender is a member, so it is never
                // empty).
                sync_participants_into(
                    cfg.schedule.as_ref(),
                    &cfg.participation,
                    cfg.workers,
                    step,
                    &mut s_t,
                );
                core.begin_round(s_t.len());
                core.apply_update(upd_bufs[worker].message())?;
                // Avg is guaranteed here (non-Avg + async is rejected up
                // front), so this is a documented no-op.
                core.end_round();
                if dense_down {
                    bits_down += encode::dense_model_bits(d);
                    let _ = reply_txs[worker].send(ModelMsg::Dense {
                        params: core.params_snapshot(),
                        recycled: spare_bytes.pop().unwrap_or_default(),
                    });
                } else {
                    let (bytes, bit_len) = encode_delta(
                        &mut core,
                        cfg.down_compressor.as_ref(),
                        &mut down_buf,
                        &mut wire,
                        worker,
                        spare_bytes.pop().unwrap_or_default(),
                    );
                    bits_down += bit_len;
                    let _ = reply_txs[worker].send(ModelMsg::Delta {
                        bytes,
                        bit_len,
                        recycled: spare_bytes.pop().unwrap_or_default(),
                    });
                }
                grid.boundary(step, |s| {
                    measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
                });
            }
        }
    }
    // Degenerate fault tail: a round whose every participant crashed
    // completes with zero reports; if no later arrival ran the barrier
    // loop past it, apply it now (empty fold — just the server-opt round
    // step and the grid record). Any round with a live participant cannot
    // be pending here: its sender would still be blocked, so `finished`
    // could not have reached `cfg.workers`.
    if let Some(p) = plan.as_ref().filter(|_| !disconnected) {
        while round_idx < rounds.len() {
            let (step, parts) = &rounds[round_idx];
            let (step, expect) = (*step, parts.len());
            if parts.iter().any(|&w| !p.crash_at(w, step)) {
                break;
            }
            grid.catch_up(step, |s| {
                measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
            });
            core.begin_round(expect);
            core.end_round();
            grid.boundary(step, |s| {
                measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
            });
            round_idx += 1;
        }
    }
    // Tail of the grid (steps after the last sync leave the model frozen),
    // then the final step if it is not itself a grid point.
    grid.catch_up(cfg.steps, |s| {
        measure(s, core.params(), bits_up, bits_down, avg(&mem_norms))
    });
    let mut history = grid.history;
    if history.points.last().map_or(true, |p| p.step != cfg.steps) {
        history.push(measure(cfg.steps, core.params(), bits_up, bits_down, avg(&mem_norms)));
    }

    // Graceful teardown: release the reply channels first, so a worker
    // still blocked in `recv` (possible only when a peer died mid-round)
    // unblocks and exits instead of deadlocking the joins; then surface
    // panics and disconnects as named `CoordinatorError`s.
    drop(reply_txs);
    let mut teardown: Result<(), CoordinatorError> = Ok(());
    for (w, h) in handles.into_iter().enumerate() {
        if h.join().is_err() && teardown.is_ok() {
            teardown = Err(CoordinatorError::WorkerPanicked { worker: w });
        }
    }
    if let Some(pool) = fold_pool {
        pool.join();
    }
    teardown?;
    if disconnected && finished < cfg.workers {
        // Drain what the barrier still holds — these rounds can never
        // complete — and report the loss by name.
        let pending_rounds = buckets.len();
        buckets.clear();
        return Err(CoordinatorError::WorkersDisconnected {
            finished,
            expected: cfg.workers,
            pending_rounds,
        }
        .into());
    }
    history.final_params = core.into_params();
    Ok(history)
}

/// Records `MetricPoint`s on the engine's exact step grid: multiples of
/// `eval_every`, with grid points between sync rounds evaluated on the
/// pre-round state (the model is frozen there) and round boundaries on the
/// post-round state — see `engine::run_from`'s recording rule.
struct GridRecorder {
    history: History,
    /// Next unrecorded grid point.
    next_eval: usize,
    eval_every: usize,
}

impl GridRecorder {
    fn new(eval_every: usize) -> Self {
        GridRecorder { history: History::new(), next_eval: eval_every, eval_every }
    }

    /// Record every unrecorded grid point ≤ `step` with the *current*
    /// (pre-round) state.
    fn catch_up(&mut self, step: usize, mut mk: impl FnMut(usize) -> MetricPoint) {
        while self.next_eval <= step {
            self.history.push(mk(self.next_eval));
            self.next_eval += self.eval_every;
        }
    }

    /// Record the boundary `step + 1` of a just-applied round iff it is the
    /// next grid point.
    fn boundary(&mut self, step: usize, mk: impl FnOnce(usize) -> MetricPoint) {
        if step + 1 == self.next_eval {
            self.history.push(mk(step + 1));
            self.next_eval += self.eval_every;
        }
    }
}

/// Per-update bookkeeping kept while a round waits behind the barrier; the
/// decoded message itself stays in the sender's `upd_bufs` slot.
struct UpdateMeta {
    worker: usize,
    bit_len: u64,
    mem_norm_sq: f64,
    /// `true`: the decoded update awaits the fold in its sender's buffer.
    /// `false`: the uplink was lost (dropped or undecodable) — the report
    /// counts toward round completion but nothing is folded, and the
    /// sender was already acknowledged with `ModelMsg::Missed`.
    delivered: bool,
}

/// One staged inbound arrival. A single channel receipt can expand into
/// several of these: a delayed message re-enters behind the overtakers
/// pulled off the transport ahead of it, and a duplicated uplink enqueues
/// a literal second copy. `Decided` wraps updates whose uplink fault was
/// already resolved — decisions are pure per (worker, step), so deciding
/// twice would delay (or duplicate) forever.
enum Inbound {
    Fresh(ToMaster),
    Decided(UpdateMsg),
}

/// Return a spent wire buffer to the recycle pool (empty vectors carry no
/// capacity and are dropped instead of occupying a slot).
fn recycle(pool: &mut Vec<Vec<u8>>, bytes: Vec<u8>) {
    if bytes.capacity() > 0 {
        pool.push(bytes);
    }
}

/// Compress and wire-encode the downlink delta for worker `r` into the
/// recycled `spare` buffer — shared by the barrier and
/// aggregate-on-arrival paths so their encoding and bit accounting cannot
/// diverge.
fn encode_delta(
    core: &mut MasterCore,
    down: &dyn crate::compress::Compressor,
    buf: &mut MessageBuf,
    wire: &mut WireEncoder,
    r: usize,
    spare: Vec<u8>,
) -> (Vec<u8>, u64) {
    core.delta_broadcast_into(r, down, buf);
    let (bytes, bit_len) = wire.encode(buf.message());
    let mut out = spare;
    out.clear();
    out.extend_from_slice(bytes);
    (out, bit_len)
}

/// A persistent mini-pool of fold threads for the barrier path's sharded
/// round fold. Reuses the engine pool's `MsgsView`/`ChunkView` machinery
/// and contract: the master carves disjoint chunks of
/// `MasterCore::fold_target`, sends one command per shard, and touches
/// neither the message list nor the fold target again until every ack is
/// back.
struct FoldPool {
    txs: Vec<mpsc::Sender<FoldCmd>>,
    acks: Vec<mpsc::Receiver<()>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// One shard's fold command (see `engine::parallel::ChunkView::fold`).
struct FoldCmd {
    msgs: MsgsView,
    chunk: ChunkView,
    scale: f32,
}

impl FoldPool {
    fn spawn(nshards: usize) -> std::io::Result<Self> {
        let mut txs = Vec::with_capacity(nshards);
        let mut acks = Vec::with_capacity(nshards);
        let mut handles = Vec::with_capacity(nshards);
        for i in 0..nshards {
            let (cmd_tx, cmd_rx) = mpsc::channel::<FoldCmd>();
            let (ack_tx, ack_rx) = mpsc::channel::<()>();
            txs.push(cmd_tx);
            acks.push(ack_rx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("qsparse-fold-{i}"))
                    .spawn(move || {
                        for cmd in cmd_rx {
                            // SAFETY: per the view contracts, the master
                            // keeps the message list and fold target
                            // untouched until this shard's ack, and no
                            // other shard's chunk overlaps.
                            unsafe { cmd.chunk.fold(cmd.msgs, cmd.scale) };
                            if ack_tx.send(()).is_err() {
                                return; // master gone
                            }
                        }
                    })?,
            );
        }
        Ok(FoldPool { txs, acks, handles })
    }

    /// Fold the round's worker-ordered messages into the master's fold
    /// target, sharded by coordinate range. Blocks until every shard acks,
    /// so the borrow handed out by `fold_target` is quiescent again on
    /// return. A dead shard is a named error, not an abort — but an ack is
    /// still awaited per command actually sent, so no live shard holds a
    /// view into the fold target when this returns (aliasing contract).
    fn fold(&self, msgs: &[Message], core: &mut MasterCore) -> Result<(), CoordinatorError> {
        let view = MsgsView::new(msgs);
        let (target, scale) = core.fold_target();
        let d = target.len();
        let n = self.txs.len();
        let mut sent = 0usize;
        let mut failed = false;
        for (ti, tx) in self.txs.iter().enumerate() {
            let (lo, hi) = (ti * d / n, (ti + 1) * d / n);
            // The [lo, hi) ranges partition 0..d, so the chunks are
            // disjoint.
            let chunk = ChunkView::new(target, lo, hi);
            if tx.send(FoldCmd { msgs: view, chunk, scale }).is_err() {
                failed = true;
                break;
            }
            sent += 1;
        }
        for ack in self.acks.iter().take(sent) {
            failed |= ack.recv().is_err();
        }
        if failed {
            Err(CoordinatorError::FoldShardDied)
        } else {
            Ok(())
        }
    }

    fn join(self) {
        drop(self.txs);
        drop(self.acks);
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn avg(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}
