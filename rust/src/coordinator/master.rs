//! Master actor: a `protocol::MasterCore` behind mpsc channels — decode
//! updates, aggregate, broadcast, record metrics.
//!
//! Aggregation policy (Algorithm 2 line 19): every received update is folded
//! as x ← x − (1/R)·g and the fresh model is returned to the sender. With a
//! synchronous schedule all R workers block at the same step, so the master
//! *barriers*: it buffers the step's updates, applies them together and then
//! replies to everyone — making the threaded run semantically identical to
//! Algorithm 1 (and bit-identical to the engine, which tests rely on).
//!
//! Broadcast: Identity downlink shares one `Arc<[f32]>` model snapshot per
//! aggregation round across all R reply channels; a non-Identity downlink
//! sends each worker its own encoded error-compensated model delta.

use super::{CoordinatorConfig, ModelMsg, ToMaster, UpdateMsg};
use crate::compress::{encode, Message};
use crate::data::Dataset;
use crate::engine::{History, MetricPoint};
use crate::grad::GradModel;
use crate::protocol::MasterCore;
use crate::util::rng::Pcg64;
use std::sync::mpsc;
use std::sync::Arc;

/// Run a full threaded training job.
///
/// `model_factory` is invoked once on the master thread (for evaluation) and
/// once inside every worker thread — required because `GradModel` may be
/// `!Send` (PJRT). Factories must produce models over the same artifact.
pub fn run_threaded<F>(
    cfg: &CoordinatorConfig,
    model_factory: F,
    train: Arc<Dataset>,
    test: Option<Arc<Dataset>>,
) -> anyhow::Result<History>
where
    F: Fn() -> Box<dyn GradModel> + Send + Clone + 'static,
{
    let eval_model = model_factory();
    let d = eval_model.dim();
    let init = cfg.init.clone().unwrap_or_else(|| vec![0.0f32; d]);
    anyhow::ensure!(init.len() == d, "init length mismatch");
    let dense_down = cfg.down_compressor.is_identity();
    let mut core = MasterCore::new(init.clone(), cfg.workers, cfg.seed, !dense_down);

    let shards = crate::data::shard_indices(&train, cfg.workers, cfg.sharding);
    let (to_master_tx, to_master_rx) = mpsc::channel::<ToMaster>();
    let mut reply_txs = Vec::with_capacity(cfg.workers);
    let mut handles = Vec::with_capacity(cfg.workers);

    for r in 0..cfg.workers {
        let (tx, rx) = mpsc::channel::<ModelMsg>();
        reply_txs.push(tx);
        let args = super::worker::WorkerArgs {
            id: r,
            cfg: cfg.clone(),
            train: Arc::clone(&train),
            shard: shards[r].clone(),
            init: init.clone(),
            to_master: to_master_tx.clone(),
            from_master: rx,
        };
        let factory = model_factory.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("qsparse-worker-{r}"))
                .spawn(move || super::worker::worker_main(factory(), args))?,
        );
    }
    drop(to_master_tx);

    // Fixed eval subsets (mirrors engine::EvalSets).
    let mut eval_rng = Pcg64::new(cfg.seed ^ 0xe7a1, 5);
    let train_eval = {
        let take = cfg.eval_rows.min(train.n);
        train.gather(&eval_rng.sample_indices(train.n, take))
    };
    let test_eval = test.as_ref().map(|ts| {
        let take = cfg.eval_rows.min(ts.n);
        ts.gather(&eval_rng.sample_indices(ts.n, take))
    });

    let mut history = History::new();
    let mut bits_up = 0u64;
    let mut bits_down = 0u64;
    let mut finished = 0usize;
    let mut last_eval_step = 0usize;
    let barrier = cfg.schedule.is_synchronous();
    let mut pending: Vec<UpdateMsg> = Vec::new();
    // Last reported ‖m‖² per worker (memories live in worker threads, but
    // they only change at syncs, so the latest report is the current value).
    let mut mem_norms = vec![0.0f64; cfg.workers];

    let mut record = |step: usize, global: &[f32], bits_up: u64, bits_down: u64, mem: f64| {
        let train_loss = eval_model.loss(global, &train_eval);
        let (test_err, test_top5) = match &test_eval {
            Some(tb) => (
                eval_model.error_rate(global, tb),
                eval_model.topn_error_rate(global, tb, 5),
            ),
            None => (f64::NAN, f64::NAN),
        };
        history.push(MetricPoint {
            step,
            train_loss,
            test_err,
            test_top5_err: test_top5,
            bits_up,
            bits_down,
            mem_norm_sq: mem,
        });
    };
    record(0, core.params(), 0, 0, 0.0);

    while finished < cfg.workers {
        match to_master_rx.recv() {
            Err(_) => break,
            Ok(ToMaster::Finished(_)) => finished += 1,
            Ok(ToMaster::Update(upd)) => {
                bits_up += upd.bit_len;
                if barrier {
                    let step = upd.step;
                    pending.push(upd);
                    if pending.len() == cfg.workers {
                        // Apply in worker order: f32 addition is not
                        // associative, and a fixed order makes the threaded
                        // sync run bit-identical to the engine (tested).
                        pending.sort_by_key(|u| u.worker);
                        for u in pending.drain(..) {
                            mem_norms[u.worker] = u.mem_norm_sq;
                            core.apply_update(&decode_update(&u)?)?;
                        }
                        if dense_down {
                            let payload: Arc<[f32]> = Arc::from(core.params());
                            let bits = encode::dense_model_bits(d);
                            for tx in &reply_txs {
                                bits_down += bits;
                                let _ = tx.send(ModelMsg::Dense(Arc::clone(&payload)));
                            }
                        } else {
                            for (r, tx) in reply_txs.iter().enumerate() {
                                let msg =
                                    core.delta_broadcast(r, cfg.down_compressor.as_ref());
                                let (bytes, bit_len) = encode::encode(&msg);
                                bits_down += bit_len;
                                let _ = tx.send(ModelMsg::Delta { bytes, bit_len });
                            }
                        }
                        if step + 1 >= last_eval_step + cfg.eval_every || step + 1 == cfg.steps {
                            last_eval_step = step + 1;
                            record(step + 1, core.params(), bits_up, bits_down, avg(&mem_norms));
                        }
                    }
                } else {
                    let step = upd.step;
                    let worker = upd.worker;
                    mem_norms[worker] = upd.mem_norm_sq;
                    core.apply_update(&decode_update(&upd)?)?;
                    if dense_down {
                        bits_down += encode::dense_model_bits(d);
                        let _ = reply_txs[worker].send(ModelMsg::Dense(Arc::from(core.params())));
                    } else {
                        let msg = core.delta_broadcast(worker, cfg.down_compressor.as_ref());
                        let (bytes, bit_len) = encode::encode(&msg);
                        bits_down += bit_len;
                        let _ = reply_txs[worker].send(ModelMsg::Delta { bytes, bit_len });
                    }
                    if step + 1 >= last_eval_step + cfg.eval_every {
                        last_eval_step = step + 1;
                        record(step + 1, core.params(), bits_up, bits_down, avg(&mem_norms));
                    }
                }
            }
        }
    }
    if last_eval_step != cfg.steps {
        record(cfg.steps, core.params(), bits_up, bits_down, avg(&mem_norms));
    }
    drop(record);

    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker thread panicked"))?;
    }
    history.final_params = core.into_params();
    Ok(history)
}

fn decode_update(upd: &UpdateMsg) -> anyhow::Result<Message> {
    encode::decode(&upd.bytes, upd.bit_len)
        .ok_or_else(|| anyhow::anyhow!("undecodable update from worker {}", upd.worker))
}

fn avg(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}
