//! Worker actor: local SGD steps, error-compensated compression, encoded
//! uplink, blocking model refresh on sync (Algorithm 1/2 worker side).

use super::{CoordinatorConfig, ModelMsg, ToMaster, UpdateMsg};
use crate::compress::{encode, ErrorMemory};
use crate::data::{Dataset, ShardSampler};
use crate::grad::GradModel;
use crate::optim::LocalSgd;
use crate::util::rng::Pcg64;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

pub(crate) struct WorkerArgs {
    pub id: usize,
    pub cfg: CoordinatorConfig,
    pub train: Arc<Dataset>,
    pub shard: Vec<usize>,
    pub init: Vec<f32>,
    pub to_master: Sender<ToMaster>,
    pub from_master: Receiver<ModelMsg>,
}

pub(crate) fn worker_main(model: Box<dyn GradModel>, args: WorkerArgs) {
    let WorkerArgs { id, cfg, train, shard, init, to_master, from_master } = args;
    let d = model.dim();
    let mut local = init.clone();
    let mut anchor = init;
    let mut memory = ErrorMemory::zeros(d);
    let mut opt = LocalSgd::new(d, cfg.momentum, 0.0);
    let mut sampler = ShardSampler::new(shard, cfg.batch, cfg.seed, id);
    let mut rng = Pcg64::new(cfg.seed ^ 0xc0ffee, id as u64 + 1);
    let mut grad = vec![0.0f32; d];
    let mut delta = vec![0.0f32; d];

    for t in 0..cfg.steps {
        let batch = sampler.next_batch(&train);
        model.loss_grad(&local, &batch, &mut grad);
        opt.step(&mut local, &grad, cfg.lr.at(t));

        if cfg.schedule.syncs_at(id, t) {
            for ((dv, a), l) in delta.iter_mut().zip(&anchor).zip(&local) {
                *dv = a - l;
            }
            let msg = memory.compress_update(&delta, cfg.compressor.as_ref(), &mut rng);
            let (bytes, bit_len) = encode::encode(&msg);
            if to_master
                .send(ToMaster::Update(UpdateMsg { worker: id, step: t, bytes, bit_len }))
                .is_err()
            {
                return; // master gone
            }
            match from_master.recv() {
                Ok(ModelMsg { params }) => {
                    local.copy_from_slice(&params);
                    anchor.copy_from_slice(&params);
                }
                Err(_) => return,
            }
        }
    }
    let _ = to_master.send(ToMaster::Finished(id));
}
