//! Worker actor: a `protocol::WorkerCore` behind mpsc channels — local SGD
//! steps, error-compensated compression, encoded uplink, blocking model
//! refresh on sync (Algorithm 1/2 worker side).
//!
//! Fault tolerance: an undecodable downlink is a *logged drop*, never an
//! abort — the worker keeps its anchor (`miss_broadcast`) and the master's
//! per-worker mirror stays consistent because faults never advance it. A
//! `ModelMsg::Missed { lost_uplink: true }` acknowledgement re-absorbs the
//! just-sent update into the error memory (`reabsorb_last_update`), so a
//! lost uplink costs a round of staleness, not the mass of the update.
//! Crash-restarts are decided by the stateless `FaultPlan` hash that the
//! master evaluates identically, so neither side waits on the other.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

use super::{CoordinatorConfig, ModelMsg, ToMaster, UpdateMsg};
use crate::compress::{encode, WireEncoder};
use crate::data::Dataset;
use crate::grad::GradModel;
use crate::protocol::WorkerCore;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

pub(crate) struct WorkerArgs {
    pub id: usize,
    pub cfg: CoordinatorConfig,
    pub train: Arc<Dataset>,
    pub shard: Vec<usize>,
    pub init: Vec<f32>,
    pub to_master: Sender<ToMaster>,
    pub from_master: Receiver<ModelMsg>,
}

pub(crate) fn worker_main(model: Box<dyn GradModel>, args: WorkerArgs) {
    let WorkerArgs { id, cfg, train, shard, init, to_master, from_master } = args;
    assert_eq!(init.len(), model.dim(), "init/model dimension mismatch");
    let mut core = WorkerCore::new(id, init, shard, cfg.batch, cfg.momentum, cfg.seed);
    let plan = cfg.faults.and_then(crate::faults::FaultPlan::new);
    // Reused wire encoder plus the recycled byte buffers: the uplink buffer
    // comes back with every master reply, the downlink delta's buffer goes
    // back with the next update — so the steady-state sync loop assembles,
    // copies and decodes wire bytes without fresh allocation.
    let mut wire = WireEncoder::new(cfg.codec);
    let mut up_bytes: Vec<u8> = Vec::new();
    let mut spent_down: Vec<u8> = Vec::new();
    // Reused downlink delta decode storage (`encode::decode_into`).
    let mut down_buf = crate::compress::MessageBuf::new();

    for t in 0..cfg.steps {
        core.local_step(model.as_ref(), &train, cfg.lr.at(t));

        // Sync only when scheduled AND sampled into this round's S_t; a
        // non-participant keeps its local run going (no uplink, no model
        // refresh) exactly like the engine's simulated workers.
        if cfg.schedule.syncs_at(id, t) && cfg.participation.participates(id, t) {
            // Crash-restart instead of syncing. The master evaluates the
            // same pure predicate for this (worker, step), so it neither
            // waits for this update nor queues a reply.
            if plan.is_some_and(|p| p.crash_at(id, t)) {
                core.crash_restart();
                continue;
            }
            let bit_len = {
                let msg = core.make_update(cfg.compressor.as_ref());
                let (bytes, bit_len) = wire.encode(msg);
                up_bytes.clear();
                up_bytes.extend_from_slice(bytes);
                bit_len
            };
            let update = UpdateMsg {
                worker: id,
                step: t,
                bytes: std::mem::take(&mut up_bytes),
                bit_len,
                mem_norm_sq: core.mem_norm_sq(),
                spent_down: std::mem::take(&mut spent_down),
            };
            if to_master.send(ToMaster::Update(update)).is_err() {
                return; // master gone
            }
            match from_master.recv() {
                Ok(ModelMsg::Dense { params, recycled }) => {
                    up_bytes = recycled;
                    core.apply_dense_broadcast(&params);
                }
                Ok(ModelMsg::Delta { bytes, bit_len, recycled }) => {
                    up_bytes = recycled;
                    // An undecodable downlink is a logged drop, not an
                    // abort: the worker keeps its anchor, and because the
                    // master only sends corrupted bytes *without* advancing
                    // this worker's downlink mirror, both sides stay
                    // consistent — the next delta spans the missed round.
                    match encode::decode_into(&bytes, bit_len, &mut down_buf) {
                        Ok(()) => core.apply_delta_broadcast(down_buf.message()),
                        Err(e) => {
                            eprintln!(
                                "worker {id}: dropping undecodable downlink delta at step {t}: {e}"
                            );
                            core.miss_broadcast();
                        }
                    }
                    spent_down = bytes;
                }
                Ok(ModelMsg::Missed { lost_uplink, recycled }) => {
                    up_bytes = recycled;
                    if lost_uplink {
                        // The update never reached the fold: fold its mass
                        // back into the error memory (m ← m + ĝ restores
                        // the pre-compression residual exactly) and resume
                        // from the unchanged anchor.
                        core.reabsorb_last_update();
                    } else {
                        // Update applied, reply lost: anchor only.
                        core.miss_broadcast();
                    }
                }
                Err(_) => return,
            }
        }
    }
    let _ = to_master.send(ToMaster::Finished(id));
}
