//! Threaded master/worker runtime.
//!
//! The engine (`engine::`) proves the algorithms deterministically; this
//! module runs them as an actual distributed system: one OS thread per
//! worker plus a master thread, communicating exclusively through mpsc
//! channels carrying *encoded* wire messages (`compress::encode`). The
//! master decodes each update, folds it into the global model, and replies
//! with the fresh model — exactly the Algorithm 1/2 message pattern, so the
//! wire format, bit accounting and error-feedback logic are exercised
//! end-to-end under real concurrency.
//!
//! Because `GradModel` implementations may be `!Send` (PJRT wraps an `Rc`
//! client), every thread constructs its own model through a `Send + Clone`
//! factory.

mod master;
mod worker;

pub use master::run_threaded;

use crate::compress::Compressor;
use crate::data::Sharding;
use crate::optim::LrSchedule;
use crate::topology::SyncSchedule;
use std::sync::Arc;

/// Configuration for a threaded run (mirrors `engine::TrainSpec` minus the
/// borrowed references, which don't work across threads).
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub batch: usize,
    pub steps: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    pub compressor: Arc<dyn Compressor>,
    pub schedule: Arc<dyn SyncSchedule>,
    pub sharding: Sharding,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_rows: usize,
    /// Initial parameters (zeros if None).
    pub init: Option<Vec<f32>>,
}

impl CoordinatorConfig {
    pub fn new(compressor: Arc<dyn Compressor>, schedule: Arc<dyn SyncSchedule>) -> Self {
        CoordinatorConfig {
            workers: 4,
            batch: 8,
            steps: 100,
            lr: LrSchedule::Const { eta: 0.1 },
            momentum: 0.0,
            compressor,
            schedule,
            sharding: Sharding::Iid,
            seed: 0,
            eval_every: 10,
            eval_rows: 256,
            init: None,
        }
    }
}

/// Worker → master: an encoded compressed update.
pub(crate) struct UpdateMsg {
    pub worker: usize,
    /// Global-clock step at which the worker synchronized.
    pub step: usize,
    pub bytes: Vec<u8>,
    pub bit_len: u64,
}

/// Worker → master control messages.
pub(crate) enum ToMaster {
    Update(UpdateMsg),
    Finished(#[allow(dead_code)] usize),
}

/// Master → worker: the fresh global model after aggregation.
pub(crate) struct ModelMsg {
    pub params: Vec<f32>,
}
