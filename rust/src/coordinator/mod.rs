//! Threaded master/worker runtime.
//!
//! The engine (`engine::`) proves the algorithms deterministically; this
//! module runs them as an actual distributed system: one OS thread per
//! worker plus a master thread, communicating exclusively through mpsc
//! channels carrying *encoded* wire messages (`compress::encode`). The
//! master decodes each update, folds it into the global model, and replies
//! with the fresh model — exactly the Algorithm 1/2 message pattern, so the
//! wire format, bit accounting and error-feedback logic are exercised
//! end-to-end under real concurrency.
//!
//! All update/aggregate/broadcast arithmetic is delegated to
//! `protocol::{WorkerCore, MasterCore}` — the same state machines the
//! engine drives — so the synchronous threaded run is bit-identical to the
//! engine by construction, not by parallel maintenance of two loops. This
//! extends to sampled partial participation: participant sets are
//! materialized from the seed (`topology::Participation`), the barrier
//! waits for exactly |S_t| updates per round (buckets keyed by sync step,
//! applied in step order), and metrics are recorded on the engine's exact
//! step grid (`step % eval_every == 0`, plus the final step).
//!
//! Downlink: with `down_compressor = Identity` the master broadcasts one
//! shared `Arc<[f32]>` model snapshot per round (no per-worker clone);
//! otherwise each worker receives an encoded error-compensated model delta
//! and `bits_down` counts the true wire length.
//!
//! Because `GradModel` implementations may be `!Send` (PJRT wraps an `Rc`
//! client), every thread constructs its own model through a `Send + Clone`
//! factory.

mod master;
mod worker;

pub use master::run_threaded;

use crate::compress::{Codec, Compressor, Identity};
use crate::data::Sharding;
use crate::faults::FaultSpec;
use crate::optim::{LrSchedule, ServerOptSpec};
use crate::protocol::AggScale;
use crate::topology::{Participation, SyncSchedule};
use std::sync::Arc;

/// Configuration for a threaded run (mirrors `engine::TrainSpec` minus the
/// borrowed references, which don't work across threads).
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub workers: usize,
    pub batch: usize,
    pub steps: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    pub compressor: Arc<dyn Compressor>,
    /// Downlink (master → worker) compressor; `Identity` (the default)
    /// broadcasts the dense model, preserving the historical behavior.
    pub down_compressor: Arc<dyn Compressor>,
    pub schedule: Arc<dyn SyncSchedule>,
    /// Sampled partial participation (mirrors `TrainSpec::participation`).
    /// Materialized up front, so worker threads and the master agree on
    /// every round's S_t without coordination.
    pub participation: Participation,
    /// `1/R` (paper) vs unbiased `1/|S_t|` aggregation scaling.
    pub agg_scale: AggScale,
    /// FedOpt-style server optimizer (mirrors `TrainSpec::server_opt`).
    /// Non-`Avg` optimizers require a synchronous schedule here: the
    /// aggregate-on-arrival path has no round boundary to step at.
    pub server_opt: ServerOptSpec,
    /// Wire codec for encoded messages in both directions (uplink updates
    /// and compressed downlink deltas). Decoded payloads are bit-identical
    /// either way — `rans` only shrinks the wire length. Dense `identity`
    /// model broadcasts always stay raw.
    pub codec: Codec,
    pub sharding: Sharding,
    pub seed: u64,
    pub eval_every: usize,
    pub eval_rows: usize,
    /// Initial parameters (zeros if None).
    pub init: Option<Vec<f32>>,
    /// Deterministic fault injection at the channel boundaries (None = the
    /// exact pre-existing fault-free paths). Requires a synchronous
    /// schedule: round completion under faults is count-based — every
    /// expected participant is accounted for by an update, an
    /// immediately-acknowledged loss, or a statelessly-agreed crash.
    pub faults: Option<FaultSpec>,
}

impl CoordinatorConfig {
    pub fn new(compressor: Arc<dyn Compressor>, schedule: Arc<dyn SyncSchedule>) -> Self {
        CoordinatorConfig {
            workers: 4,
            batch: 8,
            steps: 100,
            lr: LrSchedule::Const { eta: 0.1 },
            momentum: 0.0,
            compressor,
            down_compressor: Arc::new(Identity),
            schedule,
            participation: Participation::full(),
            agg_scale: AggScale::Workers,
            server_opt: ServerOptSpec::Avg,
            codec: Codec::Raw,
            sharding: Sharding::Iid,
            seed: 0,
            eval_every: 10,
            eval_rows: 256,
            init: None,
            faults: None,
        }
    }
}

/// Structured failures of the threaded runtime's channel fabric. Replaces
/// the old in-place `expect`s: teardown paths now drain what they hold and
/// surface a named error instead of aborting the process.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CoordinatorError {
    /// A fold shard hung up mid-round (its thread died or its channel
    /// closed before the ack came back).
    FoldShardDied,
    /// A worker thread panicked (detected at join).
    WorkerPanicked { worker: usize },
    /// The update channel closed before every worker reported `Finished`;
    /// `pending_rounds` barrier rounds were drained without applying.
    WorkersDisconnected { finished: usize, expected: usize, pending_rounds: usize },
}

impl std::fmt::Display for CoordinatorError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoordinatorError::FoldShardDied => {
                write!(f, "fold shard thread died before acking its chunk")
            }
            CoordinatorError::WorkerPanicked { worker } => {
                write!(f, "worker thread {worker} panicked")
            }
            CoordinatorError::WorkersDisconnected { finished, expected, pending_rounds } => write!(
                f,
                "update channel closed with {finished}/{expected} workers finished \
                 ({pending_rounds} incomplete rounds drained)"
            ),
        }
    }
}

impl std::error::Error for CoordinatorError {}

/// Worker → master: an encoded compressed update.
///
/// Byte buffers are recycled through the command channels in both
/// directions: the master returns each update's spent `bytes` with its
/// reply (`ModelMsg::recycled`), and the worker returns the previous
/// downlink delta's bytes here (`spent_down`) — so in steady state neither
/// side's wire path allocates fresh byte storage.
pub(crate) struct UpdateMsg {
    pub worker: usize,
    /// Global-clock step at which the worker synchronized.
    pub step: usize,
    pub bytes: Vec<u8>,
    pub bit_len: u64,
    /// ‖m_t^{(r)}‖² after this sync — aggregated by the master so the
    /// threaded `History` carries the same memory probe as the engine's.
    pub mem_norm_sq: f64,
    /// The byte buffer of the previous downlink delta this worker decoded,
    /// returned to the master's recycle pool (empty when the downlink is
    /// dense or this is the worker's first sync).
    pub spent_down: Vec<u8>,
}

/// Worker → master control messages.
pub(crate) enum ToMaster {
    Update(UpdateMsg),
    Finished(#[allow(dead_code)] usize),
}

/// Master → worker: the model refresh after aggregation. Either variant
/// carries `recycled`: a spent uplink byte buffer handed back so the
/// worker's next encoded update reuses its capacity.
pub(crate) enum ModelMsg {
    /// Dense model broadcast (Identity downlink). The payload is shared —
    /// one snapshot per aggregation round, not one clone per worker.
    Dense { params: Arc<[f32]>, recycled: Vec<u8> },
    /// Encoded error-compensated compressed model delta vs this worker's
    /// anchor (see `protocol::` module docs).
    Delta { bytes: Vec<u8>, bit_len: u64, recycled: Vec<u8> },
    /// Fault acknowledgement: this sync round is lost for the receiver.
    /// `lost_uplink = true` means the worker's update never reached the
    /// fold (dropped or undecodable) — the worker re-absorbs the sent
    /// delta into its error memory. `false` means the update was applied
    /// but the downlink reply was lost — the worker keeps its anchor (the
    /// master's per-worker downlink mirror did not advance either, so the
    /// next delta is simply computed over a longer span).
    Missed { lost_uplink: bool, recycled: Vec<u8> },
}
