//! Synchronization schedules I_T (paper Definition 4, §3, §4).
//!
//! A schedule decides, per worker, at which global-clock steps t the worker
//! synchronizes with the master (i.e. t+1 ∈ I_T^(r) in the paper's
//! indexing). `gap()` of a schedule is the maximum distance between
//! consecutive sync points; all theory constants are stated in terms of
//! H ≥ gap(I_T).

use crate::util::rng::Pcg64;

/// Per-worker synchronization schedule over a horizon of T steps.
pub trait SyncSchedule: Send + Sync {
    /// Does worker `r` synchronize at the end of step `t` (0-based)?
    fn syncs_at(&self, r: usize, t: usize) -> bool;

    /// Upper bound H on the gap (Definition 4).
    fn h(&self) -> usize;

    /// True iff all workers share the same sync points (Algorithm 1).
    fn is_synchronous(&self) -> bool;

    fn name(&self) -> String;
}

/// Synchronous schedule with a fixed period H: sync at t = H−1, 2H−1, …
/// (H = 1 is vanilla distributed SGD). gap(I_T) = H.
#[derive(Clone, Debug)]
pub struct FixedPeriod {
    pub h: usize,
}

impl FixedPeriod {
    pub fn new(h: usize) -> Self {
        assert!(h >= 1);
        FixedPeriod { h }
    }
}

impl SyncSchedule for FixedPeriod {
    fn syncs_at(&self, _r: usize, t: usize) -> bool {
        (t + 1) % self.h == 0
    }

    fn h(&self) -> usize {
        self.h
    }

    fn is_synchronous(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("sync(H={})", self.h)
    }
}

/// Asynchronous schedule (§5.2.3): after every synchronization, worker r
/// draws its next gap uniformly from {1, …, H}. Schedules are materialized
/// deterministically from a seed so the simulator and the threaded
/// coordinator see the same I_T^(r).
#[derive(Clone, Debug)]
pub struct RandomGaps {
    h: usize,
    /// sync_points[r] = sorted sync steps for worker r over [0, horizon).
    sync_points: Vec<Vec<u32>>,
    horizon: usize,
}

impl RandomGaps {
    pub fn generate(workers: usize, h: usize, horizon: usize, seed: u64) -> Self {
        assert!(h >= 1);
        let mut sync_points = Vec::with_capacity(workers);
        for r in 0..workers {
            let mut rng = Pcg64::new(seed ^ 0xa5ce9d, r as u64 + 1);
            let mut pts = Vec::new();
            let mut t = 0usize;
            loop {
                let gap = rng.range_u64(1, h as u64) as usize;
                t += gap;
                if t > horizon {
                    break;
                }
                pts.push((t - 1) as u32); // sync at end of step t-1
            }
            // Ensure the horizon end is a sync point for every worker so the
            // final model reflects all local work (paper: T ∈ I_T^(r)).
            if pts.last().map(|&p| p as usize) != Some(horizon - 1) && horizon > 0 {
                pts.push((horizon - 1) as u32);
            }
            sync_points.push(pts);
        }
        RandomGaps { h, sync_points, horizon }
    }

    /// The explicit schedule for worker r (used by tests).
    pub fn points(&self, r: usize) -> &[u32] {
        &self.sync_points[r]
    }

    /// Measured gap(I_T^(r)) — must be ≤ H by construction.
    pub fn measured_gap(&self, r: usize) -> usize {
        let pts = &self.sync_points[r];
        let mut prev = -1i64;
        let mut worst = 0usize;
        for &p in pts {
            worst = worst.max((p as i64 - prev) as usize);
            prev = p as i64;
        }
        worst
    }

    pub fn horizon(&self) -> usize {
        self.horizon
    }
}

impl SyncSchedule for RandomGaps {
    fn syncs_at(&self, r: usize, t: usize) -> bool {
        self.sync_points[r].binary_search(&(t as u32)).is_ok()
    }

    fn h(&self) -> usize {
        self.h
    }

    fn is_synchronous(&self) -> bool {
        self.h == 1
    }

    fn name(&self) -> String {
        format!("async(H={})", self.h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_period_gap() {
        let s = FixedPeriod::new(4);
        let pts: Vec<usize> = (0..16).filter(|&t| s.syncs_at(0, t)).collect();
        assert_eq!(pts, vec![3, 7, 11, 15]);
        assert!(s.is_synchronous());
    }

    #[test]
    fn h1_syncs_every_step() {
        let s = FixedPeriod::new(1);
        assert!((0..10).all(|t| s.syncs_at(0, t)));
    }

    #[test]
    fn random_gaps_respect_h_and_end() {
        let h = 8;
        let horizon = 200;
        let s = RandomGaps::generate(5, h, horizon, 1234);
        for r in 0..5 {
            assert!(s.measured_gap(r) <= h, "worker {r} gap {}", s.measured_gap(r));
            assert_eq!(*s.points(r).last().unwrap() as usize, horizon - 1);
            // points sorted and unique
            let pts = s.points(r);
            assert!(pts.windows(2).all(|w| w[0] < w[1]));
        }
        // Workers have different schedules (overwhelmingly likely).
        assert_ne!(s.points(0), s.points(1));
    }

    #[test]
    fn random_gaps_deterministic_in_seed() {
        let a = RandomGaps::generate(3, 5, 100, 7);
        let b = RandomGaps::generate(3, 5, 100, 7);
        let c = RandomGaps::generate(3, 5, 100, 8);
        for r in 0..3 {
            assert_eq!(a.points(r), b.points(r));
        }
        assert_ne!(a.points(0), c.points(0));
    }

    #[test]
    fn random_gaps_h1_is_synchronous() {
        let s = RandomGaps::generate(4, 1, 50, 3);
        for r in 0..4 {
            let pts: Vec<usize> = (0..50).filter(|&t| s.syncs_at(r, t)).collect();
            assert_eq!(pts, (0..50).collect::<Vec<_>>());
        }
    }
}
