//! Synchronization schedules I_T (paper Definition 4, §3, §4) and sampled
//! worker participation.
//!
//! A schedule decides, per worker, at which global-clock steps t the worker
//! synchronizes with the master (i.e. t+1 ∈ I_T^(r) in the paper's
//! indexing). `gap()` of a schedule is the maximum distance between
//! consecutive sync points; all theory constants are stated in terms of
//! H ≥ gap(I_T).
//!
//! A [`Participation`] policy filters the schedule: a worker actually syncs
//! at step t only if it is scheduled *and* sampled into the round's
//! participant set S_t. Like [`RandomGaps`], participant sets are
//! materialized deterministically from the seed up front, so the engine and
//! the threaded coordinator see identical S_t regardless of thread
//! interleaving or the order workers are served in.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;

/// Stream salt for participation sampling (distinct from the uplink/downlink
/// compression salts and the schedule salt so no streams are shared).
const PARTICIPATION_RNG_SALT: u64 = 0x5e7ec7;

/// Per-worker synchronization schedule over a horizon of T steps.
pub trait SyncSchedule: Send + Sync {
    /// Does worker `r` synchronize at the end of step `t` (0-based)?
    fn syncs_at(&self, r: usize, t: usize) -> bool;

    /// Upper bound H on the gap (Definition 4).
    fn h(&self) -> usize;

    /// True iff all workers share the same sync points (Algorithm 1).
    fn is_synchronous(&self) -> bool;

    fn name(&self) -> String;
}

/// Synchronous schedule with a fixed period H: sync at t = H−1, 2H−1, …
/// (H = 1 is vanilla distributed SGD). gap(I_T) = H.
#[derive(Clone, Debug)]
pub struct FixedPeriod {
    pub h: usize,
}

impl FixedPeriod {
    pub fn new(h: usize) -> Self {
        assert!(h >= 1);
        FixedPeriod { h }
    }
}

impl SyncSchedule for FixedPeriod {
    fn syncs_at(&self, _r: usize, t: usize) -> bool {
        (t + 1) % self.h == 0
    }

    fn h(&self) -> usize {
        self.h
    }

    fn is_synchronous(&self) -> bool {
        true
    }

    fn name(&self) -> String {
        format!("sync(H={})", self.h)
    }
}

/// Asynchronous schedule (§5.2.3): after every synchronization, worker r
/// draws its next gap uniformly from {1, …, H}. Schedules are materialized
/// deterministically from a seed so the simulator and the threaded
/// coordinator see the same I_T^(r).
#[derive(Clone, Debug)]
pub struct RandomGaps {
    h: usize,
    /// sync_points[r] = sorted sync steps for worker r over [0, horizon).
    sync_points: Vec<Vec<u32>>,
    horizon: usize,
}

impl RandomGaps {
    pub fn generate(workers: usize, h: usize, horizon: usize, seed: u64) -> Self {
        assert!(h >= 1);
        let mut sync_points = Vec::with_capacity(workers);
        for r in 0..workers {
            let mut rng = Pcg64::new(seed ^ 0xa5ce9d, r as u64 + 1);
            let mut pts = Vec::new();
            let mut t = 0usize;
            loop {
                let gap = rng.range_u64(1, h as u64) as usize;
                t += gap;
                if t > horizon {
                    break;
                }
                pts.push((t - 1) as u32); // sync at end of step t-1
            }
            // Ensure the horizon end is a sync point for every worker so the
            // final model reflects all local work (paper: T ∈ I_T^(r)).
            if pts.last().map(|&p| p as usize) != Some(horizon - 1) && horizon > 0 {
                pts.push((horizon - 1) as u32);
            }
            sync_points.push(pts);
        }
        RandomGaps { h, sync_points, horizon }
    }

    /// The explicit schedule for worker r (used by tests).
    pub fn points(&self, r: usize) -> &[u32] {
        &self.sync_points[r]
    }

    /// Measured gap(I_T^(r)) — must be ≤ H by construction.
    pub fn measured_gap(&self, r: usize) -> usize {
        let pts = &self.sync_points[r];
        let mut prev = -1i64;
        let mut worst = 0usize;
        for &p in pts {
            worst = worst.max((p as i64 - prev) as usize);
            prev = p as i64;
        }
        worst
    }

    pub fn horizon(&self) -> usize {
        self.horizon
    }
}

impl SyncSchedule for RandomGaps {
    fn syncs_at(&self, r: usize, t: usize) -> bool {
        self.sync_points[r].binary_search(&(t as u32)).is_ok()
    }

    fn h(&self) -> usize {
        self.h
    }

    fn is_synchronous(&self) -> bool {
        self.h == 1
    }

    fn name(&self) -> String {
        format!("async(H={})", self.h)
    }
}

/// How the per-round participant set S_t is sampled.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ParticipationSpec {
    /// Every scheduled worker participates (the paper's setting).
    Full,
    /// Each worker independently participates with probability `p` per round
    /// (fixed-fraction Bernoulli sampling).
    Bernoulli { p: f64 },
    /// Exactly `m` workers, uniform without replacement, per round.
    FixedSize { m: usize },
}

impl ParticipationSpec {
    /// Parse a CLI spec: `full` | `bernoulli:P` (`P ∈ (0, 1]`, also accepts
    /// `bernoulli:p=P`) | `fixed:M` (also `choose:M`, `fixed:m=M`).
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        let (head, rest) = spec.split_once(':').map_or((spec, ""), |(h, r)| (h, r));
        let arg = |key: &str| -> anyhow::Result<String> {
            let r = rest.trim();
            let r = r.strip_prefix(key).and_then(|s| s.strip_prefix('=')).unwrap_or(r);
            anyhow::ensure!(!r.is_empty(), "participation `{head}` requires `{key}`");
            Ok(r.to_string())
        };
        match head {
            "full" => {
                anyhow::ensure!(rest.is_empty(), "participation `full` takes no arguments");
                Ok(ParticipationSpec::Full)
            }
            "bernoulli" => {
                let p: f64 = arg("p")?.parse().map_err(|e| anyhow::anyhow!("bad `p`: {e}"))?;
                anyhow::ensure!(p > 0.0 && p <= 1.0, "bernoulli p must be in (0, 1], got {p}");
                Ok(ParticipationSpec::Bernoulli { p })
            }
            "fixed" | "choose" => {
                let m: usize = arg("m")?.parse().map_err(|e| anyhow::anyhow!("bad `m`: {e}"))?;
                anyhow::ensure!(m >= 1, "fixed-size participation needs m >= 1");
                Ok(ParticipationSpec::FixedSize { m })
            }
            other => anyhow::bail!(
                "unknown participation `{other}` (expected full | bernoulli:P | fixed:M)"
            ),
        }
    }

    /// Canonical spec string — `parse(spec_str(s)) == s` (f64 `Display`
    /// round-trips exactly, so `bernoulli:p` survives serialization).
    pub fn spec_str(&self) -> String {
        match *self {
            ParticipationSpec::Full => "full".to_string(),
            ParticipationSpec::Bernoulli { p } => format!("bernoulli:{p}"),
            ParticipationSpec::FixedSize { m } => format!("fixed:{m}"),
        }
    }

    /// Check this spec against a worker count, returning a clean error for
    /// user-reachable misconfigurations (the asserts in `materialize` are
    /// internal invariants; CLI-facing callers validate first).
    pub fn validate(&self, workers: usize) -> anyhow::Result<()> {
        match *self {
            ParticipationSpec::Full => Ok(()),
            ParticipationSpec::Bernoulli { .. } => {
                anyhow::ensure!(
                    workers <= 64,
                    "sampled participation supports up to 64 workers (got R={workers})"
                );
                Ok(())
            }
            ParticipationSpec::FixedSize { m } => {
                anyhow::ensure!(
                    workers <= 64,
                    "sampled participation supports up to 64 workers (got R={workers})"
                );
                anyhow::ensure!(
                    m <= workers,
                    "fixed-size participation m={m} exceeds the worker count R={workers}"
                );
                Ok(())
            }
        }
    }

    /// Materialize the per-step participant sets over `[0, horizon)`. One
    /// RNG stream per step (salted from the seed), so the sets are a pure
    /// function of `(seed, t)` — independent of worker service order and
    /// shared verbatim by the engine and the threaded coordinator.
    pub fn materialize(&self, workers: usize, horizon: usize, seed: u64) -> Participation {
        assert!(workers >= 1);
        // The sampling variants store per-step u64 bitmasks; Full never
        // builds a mask, so it keeps working for arbitrarily many workers.
        let mask_capacity = |spec: &str| {
            assert!(
                workers <= 64,
                "{spec} participation masks hold up to 64 workers (R={workers})"
            );
        };
        let masks = match *self {
            ParticipationSpec::Full => None,
            ParticipationSpec::Bernoulli { p } => {
                mask_capacity("bernoulli");
                assert!(p > 0.0 && p <= 1.0, "bernoulli p must be in (0, 1]");
                let mut masks = Vec::with_capacity(horizon);
                for t in 0..horizon {
                    let mut rng = Pcg64::new(seed ^ PARTICIPATION_RNG_SALT, t as u64 + 1);
                    let mut mask = 0u64;
                    for r in 0..workers {
                        if rng.f64() < p {
                            mask |= 1 << r;
                        }
                    }
                    masks.push(mask);
                }
                Some(masks)
            }
            ParticipationSpec::FixedSize { m } => {
                mask_capacity("fixed-size");
                assert!(
                    (1..=workers).contains(&m),
                    "fixed-size participation needs 1 <= m <= workers, got m={m}, R={workers}"
                );
                let mut masks = Vec::with_capacity(horizon);
                for t in 0..horizon {
                    let mut rng = Pcg64::new(seed ^ PARTICIPATION_RNG_SALT, t as u64 + 1);
                    let mut mask = 0u64;
                    for r in rng.sample_indices(workers, m) {
                        mask |= 1 << r;
                    }
                    masks.push(mask);
                }
                Some(masks)
            }
        };
        Participation { spec: *self, masks }
    }
}

/// Materialized participant sets (see [`ParticipationSpec::materialize`]).
///
/// `participates(r, t)` is a pure lookup, so both execution substrates see
/// the same S_t by construction. Steps at or beyond the materialized horizon
/// fall back to full participation (mirroring `RandomGaps`, whose horizon
/// also bounds the run length).
#[derive(Clone, Debug)]
pub struct Participation {
    spec: ParticipationSpec,
    /// Per-step participant bitmasks (bit r = worker r); None ⇔ full.
    masks: Option<Vec<u64>>,
}

/// The default policy: every scheduled worker syncs every round.
pub static FULL_PARTICIPATION: Participation =
    Participation { spec: ParticipationSpec::Full, masks: None };

impl Participation {
    /// Full participation (no sampling) — the historical behavior.
    pub fn full() -> Self {
        FULL_PARTICIPATION.clone()
    }

    /// Does worker `r` participate in a sync round at step `t`?
    pub fn participates(&self, r: usize, t: usize) -> bool {
        match &self.masks {
            None => true,
            Some(masks) => t >= masks.len() || (masks[t] >> r) & 1 == 1,
        }
    }

    /// True iff this is the full (unsampled) policy.
    pub fn is_full(&self) -> bool {
        self.masks.is_none()
    }

    pub fn spec(&self) -> ParticipationSpec {
        self.spec
    }

    pub fn name(&self) -> String {
        match self.spec {
            ParticipationSpec::Full => "full".to_string(),
            ParticipationSpec::Bernoulli { p } => format!("bernoulli(p={p})"),
            ParticipationSpec::FixedSize { m } => format!("fixed(m={m})"),
        }
    }
}

/// Fill `out` with the round's participant set
/// S_t = {r : r is scheduled at t and sampled into round t}, in worker
/// order. Shared by the engine and the threaded coordinator so the two
/// substrates agree on S_t (and hence on the `1/|S_t|` scale) by
/// construction.
pub fn sync_participants_into(
    schedule: &dyn SyncSchedule,
    participation: &Participation,
    workers: usize,
    t: usize,
    out: &mut Vec<usize>,
) {
    out.clear();
    for r in 0..workers {
        if schedule.syncs_at(r, t) && participation.participates(r, t) {
            out.push(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_period_gap() {
        let s = FixedPeriod::new(4);
        let pts: Vec<usize> = (0..16).filter(|&t| s.syncs_at(0, t)).collect();
        assert_eq!(pts, vec![3, 7, 11, 15]);
        assert!(s.is_synchronous());
    }

    #[test]
    fn h1_syncs_every_step() {
        let s = FixedPeriod::new(1);
        assert!((0..10).all(|t| s.syncs_at(0, t)));
    }

    #[test]
    fn random_gaps_respect_h_and_end() {
        let h = 8;
        let horizon = 200;
        let s = RandomGaps::generate(5, h, horizon, 1234);
        for r in 0..5 {
            assert!(s.measured_gap(r) <= h, "worker {r} gap {}", s.measured_gap(r));
            assert_eq!(*s.points(r).last().unwrap() as usize, horizon - 1);
            // points sorted and unique
            let pts = s.points(r);
            assert!(pts.windows(2).all(|w| w[0] < w[1]));
        }
        // Workers have different schedules (overwhelmingly likely).
        assert_ne!(s.points(0), s.points(1));
    }

    #[test]
    fn random_gaps_deterministic_in_seed() {
        let a = RandomGaps::generate(3, 5, 100, 7);
        let b = RandomGaps::generate(3, 5, 100, 7);
        let c = RandomGaps::generate(3, 5, 100, 8);
        for r in 0..3 {
            assert_eq!(a.points(r), b.points(r));
        }
        assert_ne!(a.points(0), c.points(0));
    }

    #[test]
    fn random_gaps_h1_is_synchronous() {
        let s = RandomGaps::generate(4, 1, 50, 3);
        for r in 0..4 {
            let pts: Vec<usize> = (0..50).filter(|&t| s.syncs_at(r, t)).collect();
            assert_eq!(pts, (0..50).collect::<Vec<_>>());
        }
    }

    #[test]
    fn participation_spec_str_roundtrips() {
        for spec in [
            ParticipationSpec::Full,
            ParticipationSpec::Bernoulli { p: 0.5 },
            ParticipationSpec::Bernoulli { p: 1.0 / 3.0 },
            ParticipationSpec::FixedSize { m: 7 },
        ] {
            assert_eq!(ParticipationSpec::parse(&spec.spec_str()).unwrap(), spec);
        }
    }

    #[test]
    fn participation_parse_specs() {
        assert_eq!(ParticipationSpec::parse("full").unwrap(), ParticipationSpec::Full);
        assert_eq!(
            ParticipationSpec::parse("bernoulli:0.5").unwrap(),
            ParticipationSpec::Bernoulli { p: 0.5 }
        );
        assert_eq!(
            ParticipationSpec::parse("bernoulli:p=0.25").unwrap(),
            ParticipationSpec::Bernoulli { p: 0.25 }
        );
        assert_eq!(
            ParticipationSpec::parse("fixed:4").unwrap(),
            ParticipationSpec::FixedSize { m: 4 }
        );
        assert_eq!(
            ParticipationSpec::parse("choose:m=2").unwrap(),
            ParticipationSpec::FixedSize { m: 2 }
        );
        assert!(ParticipationSpec::parse("bernoulli:0.0").is_err());
        assert!(ParticipationSpec::parse("bernoulli:1.5").is_err());
        assert!(ParticipationSpec::parse("fixed:0").is_err());
        assert!(ParticipationSpec::parse("bogus").is_err());
        assert!(ParticipationSpec::parse("full:x").is_err());
    }

    #[test]
    fn fixed_size_rounds_have_exactly_m() {
        let part = ParticipationSpec::FixedSize { m: 3 }.materialize(8, 200, 5);
        for t in 0..200 {
            let count = (0..8).filter(|&r| part.participates(r, t)).count();
            assert_eq!(count, 3, "step {t}");
        }
    }

    #[test]
    fn bernoulli_fraction_tracks_p() {
        let part = ParticipationSpec::Bernoulli { p: 0.5 }.materialize(16, 400, 9);
        let hits: usize = (0..400)
            .map(|t| (0..16).filter(|&r| part.participates(r, t)).count())
            .sum();
        let frac = hits as f64 / (16.0 * 400.0);
        assert!((frac - 0.5).abs() < 0.05, "fraction {frac}");
    }

    #[test]
    fn participation_deterministic_in_seed() {
        let mk = |seed| ParticipationSpec::FixedSize { m: 3 }.materialize(8, 150, seed);
        let (a, b, c) = (mk(7), mk(7), mk(8));
        let sets = |p: &Participation| -> Vec<Vec<usize>> {
            (0..150)
                .map(|t| (0..8).filter(|&r| p.participates(r, t)).collect())
                .collect()
        };
        assert_eq!(sets(&a), sets(&b));
        assert_ne!(sets(&a), sets(&c));
    }

    #[test]
    fn participation_invariant_to_query_order() {
        // `participates` is a pure lookup: querying workers in any order
        // (the threaded master serves them in arrival order) yields the same
        // sets as the engine's 0..R sweep.
        let part = ParticipationSpec::Bernoulli { p: 0.4 }.materialize(10, 100, 3);
        for t in 0..100 {
            let fwd: Vec<usize> = (0..10).filter(|&r| part.participates(r, t)).collect();
            let mut rev: Vec<usize> =
                (0..10).rev().filter(|&r| part.participates(r, t)).collect();
            rev.reverse();
            assert_eq!(fwd, rev);
        }
    }

    #[test]
    fn bernoulli_p1_and_fixed_r_equal_full() {
        let full = Participation::full();
        let p1 = ParticipationSpec::Bernoulli { p: 1.0 }.materialize(6, 80, 11);
        let all = ParticipationSpec::FixedSize { m: 6 }.materialize(6, 80, 11);
        assert!(full.is_full());
        for t in 0..80 {
            for r in 0..6 {
                assert!(full.participates(r, t));
                assert!(p1.participates(r, t));
                assert!(all.participates(r, t));
            }
        }
    }

    #[test]
    fn validate_rejects_cli_misconfigurations() {
        assert!(ParticipationSpec::FixedSize { m: 20 }.validate(8).is_err());
        assert!(ParticipationSpec::Bernoulli { p: 0.5 }.validate(65).is_err());
        assert!(ParticipationSpec::Full.validate(1000).is_ok());
        assert!(ParticipationSpec::FixedSize { m: 8 }.validate(8).is_ok());
    }

    #[test]
    fn full_materializes_for_any_worker_count() {
        // Only the sampling variants need the 64-worker bitmask bound.
        let p = ParticipationSpec::Full.materialize(200, 50, 1);
        assert!(p.is_full());
        assert!(p.participates(199, 49));
    }

    #[test]
    fn sync_participants_filters_schedule_and_sampling() {
        let sched = FixedPeriod::new(4);
        let part = ParticipationSpec::FixedSize { m: 2 }.materialize(5, 40, 21);
        let mut buf = Vec::new();
        for t in 0..40 {
            sync_participants_into(&sched, &part, 5, t, &mut buf);
            if (t + 1) % 4 != 0 {
                assert!(buf.is_empty(), "no one syncs off-schedule (t={t})");
            } else {
                assert_eq!(buf.len(), 2, "t={t}");
                assert!(buf.windows(2).all(|w| w[0] < w[1]), "worker order");
            }
        }
    }
}
