//! Deterministic simulation engine for Algorithms 1 and 2.
//!
//! The engine advances a global clock t = 0..T. At every tick each worker
//! takes one local SGD(+momentum) step on its shard; workers whose schedule
//! fires at t compress their net progress (with error feedback) and the
//! master folds the received messages into the global model:
//!
//!   x_{t+1} = x_t − (1/R) Σ_{r ∈ S_t} g_t^{(r)}      (Alg 1 line 18 / Alg 2 line 19)
//!
//! With a `FixedPeriod` schedule this is exactly Algorithm 1; with
//! `RandomGaps` it is Algorithm 2. With `Identity` + H = 1 it degenerates to
//! vanilla distributed SGD (validated bit-for-bit in tests).
//!
//! The same worker/master arithmetic is reused by the threaded runtime in
//! `coordinator::`; the engine exists so experiments are reproducible from a
//! single seed and independent of thread interleaving.

pub mod metrics;

pub use metrics::{History, MetricPoint};

use crate::compress::{Compressor, ErrorMemory};
use crate::data::{shard_indices, Batch, Dataset, ShardSampler, Sharding};
use crate::grad::GradModel;
use crate::optim::{LocalSgd, LrSchedule};
use crate::topology::SyncSchedule;
use crate::util::rng::Pcg64;

/// Full specification of a training run.
pub struct TrainSpec<'a> {
    pub model: &'a dyn GradModel,
    pub train: &'a Dataset,
    /// Held-out set for test error; `None` disables test metrics.
    pub test: Option<&'a Dataset>,
    pub workers: usize,
    /// Per-worker minibatch size b.
    pub batch: usize,
    /// Global-clock steps T.
    pub steps: usize,
    pub lr: LrSchedule,
    /// Momentum applied to the local iterations (paper §5.1.1); 0 disables.
    pub momentum: f64,
    pub compressor: &'a dyn Compressor,
    pub schedule: &'a dyn SyncSchedule,
    pub sharding: Sharding,
    pub seed: u64,
    /// Record metrics every `eval_every` steps (and at the last step).
    pub eval_every: usize,
    /// Rows subsampled for loss/error evaluation (caps eval cost).
    pub eval_rows: usize,
}

impl<'a> TrainSpec<'a> {
    /// Reasonable defaults for the common fields; callers override the rest.
    pub fn new(
        model: &'a dyn GradModel,
        train: &'a Dataset,
        compressor: &'a dyn Compressor,
        schedule: &'a dyn SyncSchedule,
    ) -> Self {
        TrainSpec {
            model,
            train,
            test: None,
            workers: 4,
            batch: 8,
            steps: 100,
            lr: LrSchedule::Const { eta: 0.1 },
            momentum: 0.0,
            compressor,
            schedule,
            sharding: Sharding::Iid,
            seed: 0,
            eval_every: 10,
            eval_rows: 512,
        }
    }
}

/// Mutable per-worker state during a run.
struct WorkerState {
    /// x̂_t^{(r)} — local iterate.
    local: Vec<f32>,
    /// x_t^{(r)} — the last global model this worker received (its sync
    /// anchor; in Alg 1 this equals the master's x_t at sync points).
    anchor: Vec<f32>,
    memory: ErrorMemory,
    opt: LocalSgd,
    sampler: ShardSampler,
    rng: Pcg64,
    grad_buf: Vec<f32>,
}

/// Run a full training job; returns the metric history and final model.
pub fn run(spec: &TrainSpec) -> History {
    let d = spec.model.dim();
    assert!(spec.workers >= 1);
    // x_0 = 0 (the paper's convex runs); non-convex callers use `run_from`
    // with a model-appropriate init.
    run_from(spec, vec![0.0f32; d])
}

/// As `run`, but from explicit initial parameters (used by the non-convex
/// figures, which need a proper MLP init).
pub fn run_from(spec: &TrainSpec, mut global: Vec<f32>) -> History {
    let d = spec.model.dim();
    assert_eq!(global.len(), d);
    let r_count = spec.workers;
    let shards = shard_indices(spec.train, r_count, spec.sharding);

    let mut workers: Vec<WorkerState> = (0..r_count)
        .map(|r| WorkerState {
            local: global.clone(),
            anchor: global.clone(),
            memory: ErrorMemory::zeros(d),
            opt: LocalSgd::new(d, spec.momentum, 0.0),
            sampler: ShardSampler::new(shards[r].clone(), spec.batch, spec.seed, r),
            rng: Pcg64::new(spec.seed ^ 0xc0ffee, r as u64 + 1),
            grad_buf: vec![0.0f32; d],
        })
        .collect();

    let eval = EvalSets::new(spec);
    let mut history = History::new();
    let mut bits_up: u64 = 0;
    let mut bits_down: u64 = 0;
    let mut delta = vec![0.0f32; d];

    // t = 0 snapshot.
    history.push(eval.measure(spec, 0, &global, bits_up, bits_down, avg_mem(&workers)));

    for t in 0..spec.steps {
        let eta = spec.lr.at(t);
        // -- workers: one local step each ------------------------------------
        for w in workers.iter_mut() {
            let batch = w.sampler.next_batch(spec.train);
            spec.model.loss_grad(&w.local, &batch, &mut w.grad_buf);
            w.opt.step(&mut w.local, &w.grad_buf, eta);
        }
        // -- synchronization -------------------------------------------------
        let mut any_sync = false;
        for (r, w) in workers.iter_mut().enumerate() {
            if !spec.schedule.syncs_at(r, t) {
                continue;
            }
            any_sync = true;
            // delta = x_anchor − x̂_{t+1/2}  (net local progress, Alg 1 line 8)
            for ((dv, a), l) in delta.iter_mut().zip(&w.anchor).zip(&w.local) {
                *dv = a - l;
            }
            let msg = w.memory.compress_update(&delta, spec.compressor, &mut w.rng);
            bits_up += msg.wire_bits();
            // master: x ← x − (1/R) g
            msg.add_into(&mut global, -1.0 / r_count as f32);
        }
        if any_sync {
            // master broadcasts the new model to the workers that synced.
            for (r, w) in workers.iter_mut().enumerate() {
                if spec.schedule.syncs_at(r, t) {
                    w.local.copy_from_slice(&global);
                    w.anchor.copy_from_slice(&global);
                    bits_down += 32 * d as u64;
                }
            }
        }
        // -- metrics ----------------------------------------------------------
        let step = t + 1;
        if step % spec.eval_every == 0 || step == spec.steps {
            history.push(eval.measure(spec, step, &global, bits_up, bits_down, avg_mem(&workers)));
        }
    }

    history.final_params = global;
    history
}

fn avg_mem(workers: &[WorkerState]) -> f64 {
    workers.iter().map(|w| w.memory.norm_sq()).sum::<f64>() / workers.len() as f64
}

/// Fixed evaluation subsets (deterministic, shared by every series in a
/// figure so curves are comparable).
struct EvalSets {
    train_batch: Batch,
    test_batch: Option<Batch>,
}

impl EvalSets {
    fn new(spec: &TrainSpec) -> Self {
        let mut rng = Pcg64::new(spec.seed ^ 0xe7a1, 5);
        let take = spec.eval_rows.min(spec.train.n);
        let idx = rng.sample_indices(spec.train.n, take);
        let train_batch = spec.train.gather(&idx);
        let test_batch = spec.test.map(|ts| {
            let take = spec.eval_rows.min(ts.n);
            let idx = rng.sample_indices(ts.n, take);
            ts.gather(&idx)
        });
        EvalSets { train_batch, test_batch }
    }

    fn measure(
        &self,
        spec: &TrainSpec,
        step: usize,
        params: &[f32],
        bits_up: u64,
        bits_down: u64,
        mem_norm_sq: f64,
    ) -> MetricPoint {
        let train_loss = spec.model.loss(params, &self.train_batch);
        let (test_err, test_top5_err) = match &self.test_batch {
            Some(tb) => (
                spec.model.error_rate(params, tb),
                spec.model.topn_error_rate(params, tb, 5),
            ),
            None => (f64::NAN, f64::NAN),
        };
        MetricPoint {
            step,
            train_loss,
            test_err,
            test_top5_err,
            bits_up,
            bits_down,
            mem_norm_sq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};
    use crate::data::gaussian_clusters;
    use crate::grad::SoftmaxRegression;
    use crate::topology::FixedPeriod;

    fn small_setup() -> (Dataset, SoftmaxRegression) {
        let ds = gaussian_clusters(240, 10, 4, 2.0, 0.4, 33);
        let model = SoftmaxRegression::new(10, 4, 1.0 / 240.0);
        (ds, model)
    }

    #[test]
    fn vanilla_sgd_decreases_loss() {
        let (ds, model) = small_setup();
        let id = Identity;
        let sched = FixedPeriod::new(1);
        let mut spec = TrainSpec::new(&model, &ds, &id, &sched);
        spec.workers = 4;
        spec.steps = 150;
        spec.lr = LrSchedule::Const { eta: 0.5 };
        let h = run(&spec);
        let first = h.points.first().unwrap().train_loss;
        let last = h.points.last().unwrap().train_loss;
        assert!(last < first * 0.5, "loss {first} → {last}");
        assert_eq!(h.final_params.len(), model.dim());
    }

    #[test]
    fn h1_identity_memory_stays_zero() {
        let (ds, model) = small_setup();
        let id = Identity;
        let sched = FixedPeriod::new(1);
        let mut spec = TrainSpec::new(&model, &ds, &id, &sched);
        spec.steps = 30;
        let h = run(&spec);
        for p in &h.points {
            assert!(p.mem_norm_sq < 1e-12);
        }
    }

    #[test]
    fn topk_with_memory_converges_like_sgd() {
        let (ds, model) = small_setup();
        let sched = FixedPeriod::new(1);
        let id = Identity;
        let topk = TopK::new(model.dim() / 20);
        let mk = |comp: &dyn Compressor| {
            let mut spec = TrainSpec::new(&model, &ds, comp, &sched);
            spec.workers = 4;
            spec.steps = 400;
            spec.lr = LrSchedule::Const { eta: 0.5 };
            run(&spec).points.last().unwrap().train_loss
        };
        let l_sgd = mk(&id);
        let l_topk = mk(&topk);
        assert!(
            l_topk < l_sgd + 0.25,
            "topk failed to track sgd: {l_topk} vs {l_sgd}"
        );
    }

    #[test]
    fn bits_accounting_monotone_and_cheaper_for_sparse() {
        let (ds, model) = small_setup();
        let sched = FixedPeriod::new(1);
        let id = Identity;
        let topk = TopK::new(2);
        let mut spec = TrainSpec::new(&model, &ds, &id, &sched);
        spec.steps = 20;
        let h_id = run(&spec);
        let spec2 = TrainSpec { compressor: &topk, ..TrainSpec::new(&model, &ds, &topk, &sched) };
        let mut spec2 = spec2;
        spec2.steps = 20;
        let h_tk = run(&spec2);
        let bits_id = h_id.points.last().unwrap().bits_up;
        let bits_tk = h_tk.points.last().unwrap().bits_up;
        assert!(bits_tk < bits_id / 10, "topk bits {bits_tk} vs dense {bits_id}");
        // bits monotone over time
        let ups: Vec<u64> = h_id.points.iter().map(|p| p.bits_up).collect();
        assert!(ups.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn local_sgd_h4_sends_fewer_bits_same_ballpark_loss() {
        let (ds, model) = small_setup();
        let id = Identity;
        let s1 = FixedPeriod::new(1);
        let s4 = FixedPeriod::new(4);
        let run_with = |sched: &dyn crate::topology::SyncSchedule| {
            let mut spec = TrainSpec::new(&model, &ds, &id, sched);
            spec.workers = 4;
            spec.steps = 200;
            spec.lr = LrSchedule::Const { eta: 0.3 };
            run(&spec)
        };
        let h1 = run_with(&s1);
        let h4 = run_with(&s4);
        let b1 = h1.points.last().unwrap().bits_up;
        let b4 = h4.points.last().unwrap().bits_up;
        assert!((b1 as f64 / b4 as f64 - 4.0).abs() < 0.6, "ratio {}", b1 as f64 / b4 as f64);
        let l1 = h1.points.last().unwrap().train_loss;
        let l4 = h4.points.last().unwrap().train_loss;
        assert!(l4 < l1 + 0.3, "H=4 diverged: {l4} vs {l1}");
    }
}
