//! Deterministic simulation engine for Algorithms 1 and 2.
//!
//! The engine advances a global clock t = 0..T. At every tick each worker
//! takes one local SGD(+momentum) step on its shard; workers whose schedule
//! fires at t compress their net progress (with error feedback) and the
//! master folds the received messages into the global model:
//!
//!   x_{t+1} = x_t − s Σ_{r ∈ S_t} g_t^{(r)}      (Alg 1 line 18 / Alg 2 line 19)
//!
//! where S_t is the round's participant set (the scheduled workers, further
//! filtered by the sampled `Participation` policy) and the scale s is `1/R`
//! (the paper) or the unbiased `1/|S_t|` (`AggScale::Participants`). With a
//! `FixedPeriod` schedule and full participation this is exactly Algorithm
//! 1; with `RandomGaps` it is Algorithm 2. With `Identity` + H = 1 it
//! degenerates to vanilla distributed SGD (validated bit-for-bit in tests).
//!
//! The worker/master arithmetic itself lives in `protocol::{WorkerCore,
//! MasterCore}` and is shared verbatim with the threaded runtime in
//! `coordinator::` — the engine is a thin in-process driver over the cores,
//! so experiments are reproducible from a single seed and independent of
//! thread interleaving, and the two substrates stay bit-identical by
//! construction.
//!
//! Downlink: with `down_compressor = Identity` (the default) the master
//! broadcasts the dense model exactly as the paper assumes; any other
//! operator switches to error-compensated compressed model deltas (see
//! `protocol::` docs), and `bits_down` reports the true encoded length.
//!
//! Multicore: `TrainSpec::threads` moves worker local steps, uplink
//! compression *and the master round itself* — the sharded fold plus the
//! per-worker downlink compression — onto one persistent scoped thread
//! pool (`parallel::`) while keeping the `History` bit-for-bit identical
//! to the sequential loop: each worker draws only from its own salted PCG
//! streams, every fold-target chunk folds the round's messages in
//! worker-index order (per-coordinate the addition sequence is exactly the
//! sequential one), and per-worker downlink state lives on the thread that
//! owns the worker. The hot path (gather → grad → compress → fold →
//! broadcast) reuses per-worker scratch everywhere and performs no
//! steady-state heap allocation in the sequential engine.

pub mod metrics;
pub(crate) mod parallel;

pub use metrics::{History, MetricPoint};

use crate::compress::{encode, Codec, Compressor, MessageBuf};
use crate::data::{shard_indices, Batch, Dataset, Sharding};
use crate::grad::GradModel;
use crate::optim::{LrSchedule, ServerOptSpec};
use crate::protocol::{AggScale, MasterCore, WorkerCore};
use crate::topology::{sync_participants_into, Participation, SyncSchedule};
use crate::util::rng::Pcg64;

/// Full specification of a training run.
pub struct TrainSpec<'a> {
    pub model: &'a dyn GradModel,
    pub train: &'a Dataset,
    /// Held-out set for test error; `None` disables test metrics.
    pub test: Option<&'a Dataset>,
    pub workers: usize,
    /// Per-worker minibatch size b.
    pub batch: usize,
    /// Global-clock steps T.
    pub steps: usize,
    pub lr: LrSchedule,
    /// Momentum applied to the local iterations (paper §5.1.1); 0 disables.
    pub momentum: f64,
    pub compressor: &'a dyn Compressor,
    /// Downlink (master → worker) compressor. `Identity` broadcasts the
    /// dense model (the paper's setting, bit-identical to the historical
    /// behavior); anything else broadcasts error-compensated compressed
    /// model deltas with server-side error feedback.
    pub down_compressor: &'a dyn Compressor,
    pub schedule: &'a dyn SyncSchedule,
    /// Which scheduled workers actually sync each round (sampled partial
    /// participation). `FULL_PARTICIPATION` (the default) is the paper's
    /// setting: every scheduled worker syncs.
    pub participation: &'a Participation,
    /// `Workers` folds every update as `−(1/R)·g` (the paper); `Participants`
    /// uses the unbiased `−(1/|S_t|)·g` under sampled participation.
    pub agg_scale: AggScale,
    /// Wire codec for encoded messages (uplink and compressed downlink).
    /// The engine never serializes — it accounts bits through the exact
    /// `wire_bits_with` cost walk, which equals what a `WireEncoder` with
    /// the same codec emits (the threaded runtime serializes for real and
    /// the parity tests assert equal totals). Trajectories are codec-
    /// independent by construction; dense `identity` broadcasts stay raw.
    pub codec: Codec,
    /// FedOpt-style server optimizer applied to each round's aggregate
    /// before broadcast. `Avg` (the default) is the paper's plain
    /// averaging, bit-identical to the historical aggregation path.
    pub server_opt: ServerOptSpec,
    pub sharding: Sharding,
    pub seed: u64,
    /// Record metrics every `eval_every` steps (and at the last step).
    pub eval_every: usize,
    /// Rows subsampled for loss/error evaluation (caps eval cost).
    pub eval_rows: usize,
    /// Worker-pool threads for the engine: `1` (the default) runs the
    /// classic sequential loop; `0` uses all available cores; `n > 1` runs
    /// worker steps, uplink compression and the master round (sharded
    /// fold + per-worker downlink compression) on a persistent scoped
    /// thread pool. Every setting produces a bit-identical `History` —
    /// each worker draws only from its own salted RNG streams, and every
    /// fold-target chunk processes the round's updates in worker-index
    /// order — so this is purely a wall-clock knob. Requires a model with
    /// a `Sync` view (`GradModel::as_sync`); others (PJRT) silently fall
    /// back to sequential.
    pub threads: usize,
}

impl<'a> TrainSpec<'a> {
    /// Reasonable defaults for the common fields; callers override the rest.
    pub fn new(
        model: &'a dyn GradModel,
        train: &'a Dataset,
        compressor: &'a dyn Compressor,
        schedule: &'a dyn SyncSchedule,
    ) -> Self {
        TrainSpec {
            model,
            train,
            test: None,
            workers: 4,
            batch: 8,
            steps: 100,
            lr: LrSchedule::Const { eta: 0.1 },
            momentum: 0.0,
            compressor,
            down_compressor: &crate::compress::IDENTITY,
            schedule,
            participation: &crate::topology::FULL_PARTICIPATION,
            agg_scale: AggScale::Workers,
            codec: Codec::Raw,
            server_opt: ServerOptSpec::Avg,
            sharding: Sharding::Iid,
            seed: 0,
            eval_every: 10,
            eval_rows: 512,
            threads: 1,
        }
    }
}

/// Run a full training job; returns the metric history and final model.
pub fn run(spec: &TrainSpec) -> History {
    let d = spec.model.dim();
    assert!(spec.workers >= 1);
    // x_0 = 0 (the paper's convex runs); non-convex callers use `run_from`
    // with a model-appropriate init.
    run_from(spec, vec![0.0f32; d])
}

/// As `run`, but from explicit initial parameters (used by the non-convex
/// figures, which need a proper MLP init).
///
/// Dispatches on `spec.threads`: the parallel engine produces a `History`
/// bit-identical to the sequential loop (tested across operators, schedules
/// and thread counts in `integration_parallel.rs`), so the choice is purely
/// about wall-clock.
pub fn run_from(spec: &TrainSpec, global: Vec<f32>) -> History {
    let threads = resolve_threads(spec.threads, spec.workers);
    if threads > 1 {
        if let Some(model) = spec.model.as_sync() {
            return parallel::run_from_parallel(spec, model, global, threads);
        }
    }
    run_sequential(spec, global)
}

/// Effective pool size: 0 = all available cores, clamped to the worker
/// count (more threads than workers cannot help).
fn resolve_threads(threads: usize, workers: usize) -> usize {
    let t = if threads == 0 {
        std::thread::available_parallelism().map_or(1, |n| n.get())
    } else {
        threads
    };
    t.min(workers.max(1))
}

fn run_sequential(spec: &TrainSpec, global: Vec<f32>) -> History {
    match run_from_resumable(spec, global, None, 0, 0, &mut |_, _| {}) {
        Ok(h) => h,
        // Checkpoint errors only arise from parsing resume bytes; none
        // were supplied.
        Err(e) => unreachable!("resume-free run cannot fail: {e}"),
    }
}

/// The sequential loop with checkpoint/resume hooks. `run_sequential`
/// delegates here with both features disabled, so the bit-exactness of
/// existing trajectories is structural, not re-proved.
///
/// * `resume`: bytes written by a previous `on_checkpoint` callback. The
///   run restores every core and counter from them and continues from the
///   saved step — the result is bit-identical to the uninterrupted run
///   (asserted in `tests/integration_faults.rs`).
/// * `spec_fp`: fingerprint of the canonical experiment spec (see
///   [`crate::protocol::checkpoint::spec_fingerprint`]); stored in each
///   checkpoint and required to match on resume.
/// * `checkpoint_every`: emit a snapshot via `on_checkpoint(step, bytes)`
///   at every step divisible by it (0 disables). Snapshots are taken at
///   step boundaries *after* metrics, so the saved `History` is exactly
///   the uninterrupted run's prefix.
pub fn run_from_resumable(
    spec: &TrainSpec,
    global: Vec<f32>,
    resume: Option<&[u8]>,
    spec_fp: u64,
    checkpoint_every: usize,
    on_checkpoint: &mut dyn FnMut(usize, Vec<u8>),
) -> Result<History, crate::protocol::CheckpointError> {
    use crate::protocol::checkpoint;

    let d = spec.model.dim();
    assert_eq!(global.len(), d);
    let r_count = spec.workers;
    let shards = shard_indices(spec.train, r_count, spec.sharding);
    let dense_down = spec.down_compressor.is_identity();

    let mut workers: Vec<WorkerCore> = (0..r_count)
        .map(|r| {
            WorkerCore::new(
                r,
                global.clone(),
                shards[r].clone(),
                spec.batch,
                spec.momentum,
                spec.seed,
            )
        })
        .collect();
    let mut master = MasterCore::new(global, r_count, spec.seed, !dense_down);
    master.set_agg_scale(spec.agg_scale);
    master.set_server_opt(spec.server_opt);

    let eval = EvalSets::new(spec);
    let mut history = History::new();
    let mut bits_up: u64 = 0;
    let mut bits_down: u64 = 0;
    // Reused buffer for the round's participant set S_t.
    let mut round = Vec::with_capacity(r_count);
    // Reused downlink compression buffer (one message in flight at a time).
    let mut down_buf = MessageBuf::new();

    let start = match resume {
        Some(bytes) => {
            let resumed = checkpoint::load(bytes, spec_fp, &mut master, &mut workers)?;
            bits_up = resumed.bits_up;
            bits_down = resumed.bits_down;
            history = resumed.history;
            resumed.step
        }
        None => {
            // t = 0 snapshot.
            history.push(eval.measure(
                spec,
                0,
                master.params(),
                bits_up,
                bits_down,
                avg_mem(&workers),
            ));
            0
        }
    };

    for t in start..spec.steps {
        let eta = spec.lr.at(t);
        // -- workers: one local step each ------------------------------------
        for w in workers.iter_mut() {
            w.local_step(spec.model, spec.train, eta);
        }
        // -- synchronization: uplink then aggregation ------------------------
        // S_t = scheduled ∩ sampled participants; non-participants keep
        // running local steps and neither their uplink memory nor the
        // master's per-worker downlink state advances.
        sync_participants_into(spec.schedule, spec.participation, r_count, t, &mut round);
        if !round.is_empty() {
            master.begin_round(round.len());
            for &r in &round {
                let msg = workers[r].make_update(spec.compressor);
                bits_up += msg.wire_bits_with(spec.codec);
                master.apply_update(msg).expect("engine-internal update dim mismatch");
            }
            // Server optimizer step on the round's aggregate (no-op for Avg).
            master.end_round();
            // -- broadcast to the round's participants -----------------------
            for &r in &round {
                if dense_down {
                    workers[r].apply_dense_broadcast(master.params());
                    bits_down += encode::dense_model_bits(d);
                } else {
                    master.delta_broadcast_into(r, spec.down_compressor, &mut down_buf);
                    bits_down += down_buf.message().wire_bits_with(spec.codec);
                    workers[r].apply_delta_broadcast(down_buf.message());
                }
            }
        }
        // -- metrics ----------------------------------------------------------
        let step = t + 1;
        if step % spec.eval_every == 0 || step == spec.steps {
            history.push(eval.measure(
                spec,
                step,
                master.params(),
                bits_up,
                bits_down,
                avg_mem(&workers),
            ));
        }
        if checkpoint_every > 0 && step % checkpoint_every == 0 {
            let bytes = checkpoint::save(
                spec_fp, step, bits_up, bits_down, &history, &master, &workers,
            );
            on_checkpoint(step, bytes);
        }
    }

    history.final_params = master.into_params();
    Ok(history)
}

fn avg_mem(workers: &[WorkerCore]) -> f64 {
    workers.iter().map(|w| w.mem_norm_sq()).sum::<f64>() / workers.len() as f64
}

/// As `avg_mem`, over pre-collected per-worker ‖m‖² values (the parallel
/// engine tracks them from sync replies). Summation order is worker-index
/// order in both, so the two are bit-identical.
fn avg_mem_values(mem_norms: &[f64]) -> f64 {
    mem_norms.iter().sum::<f64>() / mem_norms.len() as f64
}

/// Fixed evaluation subsets (deterministic, shared by every series in a
/// figure so curves are comparable). `pub(crate)` so the event-driven
/// simulator (`crate::sim`) evaluates with byte-identical batches and
/// arithmetic — its degenerate-parity guarantee depends on sharing this
/// exact RNG stream and measurement code, not reimplementing them.
pub(crate) struct EvalSets {
    train_batch: Batch,
    test_batch: Option<Batch>,
}

impl EvalSets {
    pub(crate) fn new(spec: &TrainSpec) -> Self {
        let mut rng = Pcg64::new(spec.seed ^ 0xe7a1, 5);
        let take = spec.eval_rows.min(spec.train.n);
        let idx = rng.sample_indices(spec.train.n, take);
        let train_batch = spec.train.gather(&idx);
        let test_batch = spec.test.map(|ts| {
            let take = spec.eval_rows.min(ts.n);
            let idx = rng.sample_indices(ts.n, take);
            ts.gather(&idx)
        });
        EvalSets { train_batch, test_batch }
    }

    pub(crate) fn measure(
        &self,
        spec: &TrainSpec,
        step: usize,
        params: &[f32],
        bits_up: u64,
        bits_down: u64,
        mem_norm_sq: f64,
    ) -> MetricPoint {
        let train_loss = spec.model.loss(params, &self.train_batch);
        let (test_err, test_top5_err) = match &self.test_batch {
            Some(tb) => (
                spec.model.error_rate(params, tb),
                spec.model.topn_error_rate(params, tb, 5),
            ),
            None => (f64::NAN, f64::NAN),
        };
        MetricPoint {
            step,
            train_loss,
            test_err,
            test_top5_err,
            bits_up,
            bits_down,
            mem_norm_sq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};
    use crate::data::gaussian_clusters;
    use crate::grad::SoftmaxRegression;
    use crate::topology::FixedPeriod;

    fn small_setup() -> (Dataset, SoftmaxRegression) {
        let ds = gaussian_clusters(240, 10, 4, 2.0, 0.4, 33);
        let model = SoftmaxRegression::new(10, 4, 1.0 / 240.0);
        (ds, model)
    }

    #[test]
    fn vanilla_sgd_decreases_loss() {
        let (ds, model) = small_setup();
        let id = Identity;
        let sched = FixedPeriod::new(1);
        let mut spec = TrainSpec::new(&model, &ds, &id, &sched);
        spec.workers = 4;
        spec.steps = 150;
        spec.lr = LrSchedule::Const { eta: 0.5 };
        let h = run(&spec);
        let first = h.points.first().unwrap().train_loss;
        let last = h.points.last().unwrap().train_loss;
        assert!(last < first * 0.5, "loss {first} → {last}");
        assert_eq!(h.final_params.len(), model.dim());
    }

    #[test]
    fn h1_identity_memory_stays_zero() {
        let (ds, model) = small_setup();
        let id = Identity;
        let sched = FixedPeriod::new(1);
        let mut spec = TrainSpec::new(&model, &ds, &id, &sched);
        spec.steps = 30;
        let h = run(&spec);
        for p in &h.points {
            assert!(p.mem_norm_sq < 1e-12);
        }
    }

    #[test]
    fn topk_with_memory_converges_like_sgd() {
        let (ds, model) = small_setup();
        let sched = FixedPeriod::new(1);
        let id = Identity;
        let topk = TopK::new(model.dim() / 20);
        let mk = |comp: &dyn Compressor| {
            let mut spec = TrainSpec::new(&model, &ds, comp, &sched);
            spec.workers = 4;
            spec.steps = 400;
            spec.lr = LrSchedule::Const { eta: 0.5 };
            run(&spec).points.last().unwrap().train_loss
        };
        let l_sgd = mk(&id);
        let l_topk = mk(&topk);
        assert!(
            l_topk < l_sgd + 0.25,
            "topk failed to track sgd: {l_topk} vs {l_sgd}"
        );
    }

    #[test]
    fn bits_accounting_monotone_and_cheaper_for_sparse() {
        let (ds, model) = small_setup();
        let sched = FixedPeriod::new(1);
        let id = Identity;
        let topk = TopK::new(2);
        let mut spec = TrainSpec::new(&model, &ds, &id, &sched);
        spec.steps = 20;
        let h_id = run(&spec);
        let spec2 = TrainSpec { compressor: &topk, ..TrainSpec::new(&model, &ds, &topk, &sched) };
        let mut spec2 = spec2;
        spec2.steps = 20;
        let h_tk = run(&spec2);
        let bits_id = h_id.points.last().unwrap().bits_up;
        let bits_tk = h_tk.points.last().unwrap().bits_up;
        assert!(bits_tk < bits_id / 10, "topk bits {bits_tk} vs dense {bits_id}");
        // bits monotone over time
        let ups: Vec<u64> = h_id.points.iter().map(|p| p.bits_up).collect();
        assert!(ups.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn compressed_downlink_saves_bits_and_tracks_dense() {
        let (ds, model) = small_setup();
        let sched = FixedPeriod::new(1);
        let up = Identity;
        let mk = |down_spec: &str| {
            let down = crate::compress::parse_spec(down_spec).unwrap();
            let mut spec = TrainSpec::new(&model, &ds, &up, &sched);
            spec.down_compressor = down.as_ref();
            spec.workers = 4;
            spec.steps = 600;
            spec.lr = LrSchedule::Const { eta: 0.3 };
            run(&spec)
        };
        let dense = mk("identity");
        let compressed = mk("topk:k=2");
        let bd_dense = dense.points.last().unwrap().bits_down;
        let bd_comp = compressed.points.last().unwrap().bits_down;
        assert!(
            bd_comp * 10 < bd_dense,
            "downlink bits not ≥10× cheaper: {bd_comp} vs {bd_dense}"
        );
        let ld = dense.final_loss();
        let lc = compressed.final_loss();
        assert!(lc < ld + 0.3, "compressed downlink diverged: {lc} vs dense {ld}");
        // bits_down monotone over time.
        let downs: Vec<u64> = compressed.points.iter().map(|p| p.bits_down).collect();
        assert!(downs.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn local_sgd_h4_sends_fewer_bits_same_ballpark_loss() {
        let (ds, model) = small_setup();
        let id = Identity;
        let s1 = FixedPeriod::new(1);
        let s4 = FixedPeriod::new(4);
        let run_with = |sched: &dyn crate::topology::SyncSchedule| {
            let mut spec = TrainSpec::new(&model, &ds, &id, sched);
            spec.workers = 4;
            spec.steps = 200;
            spec.lr = LrSchedule::Const { eta: 0.3 };
            run(&spec)
        };
        let h1 = run_with(&s1);
        let h4 = run_with(&s4);
        let b1 = h1.points.last().unwrap().bits_up;
        let b4 = h4.points.last().unwrap().bits_up;
        assert!((b1 as f64 / b4 as f64 - 4.0).abs() < 0.6, "ratio {}", b1 as f64 / b4 as f64);
        let l1 = h1.points.last().unwrap().train_loss;
        let l4 = h4.points.last().unwrap().train_loss;
        assert!(l4 < l1 + 0.3, "H=4 diverged: {l4} vs {l1}");
    }
}
