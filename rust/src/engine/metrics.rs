//! Run metrics: the series the paper's figures plot.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

use crate::util::json::Json;

/// One recorded point along a training run.
#[derive(Clone, Debug)]
pub struct MetricPoint {
    /// Global-clock step (0 = initialization).
    pub step: usize,
    /// Mean loss of the *master* model on the fixed train eval subset.
    pub train_loss: f64,
    /// Top-1 test error of the master model (NaN if no test set).
    pub test_err: f64,
    /// Top-5 test error (NaN if no test set).
    pub test_top5_err: f64,
    /// Cumulative uplink bits (worker → master), exact wire encoding.
    pub bits_up: u64,
    /// Cumulative downlink bits (master → worker model broadcasts).
    pub bits_down: u64,
    /// Average squared error-memory norm across workers (Lemma 4/5 probe).
    pub mem_norm_sq: f64,
}

/// History of a training run.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub points: Vec<MetricPoint>,
    pub final_params: Vec<f32>,
}

impl History {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, p: MetricPoint) {
        self.points.push(p);
    }

    pub fn final_loss(&self) -> f64 {
        self.points.last().map_or(f64::NAN, |p| p.train_loss)
    }

    pub fn total_bits_up(&self) -> u64 {
        self.points.last().map_or(0, |p| p.bits_up)
    }

    pub fn total_bits_down(&self) -> u64 {
        self.points.last().map_or(0, |p| p.bits_down)
    }

    /// First cumulative uplink bit count at which `train_loss ≤ target`
    /// (the paper's “bits to reach target loss”); None if never reached.
    pub fn bits_to_loss(&self, target: f64) -> Option<u64> {
        self.points
            .iter()
            .find(|p| p.train_loss <= target)
            .map(|p| p.bits_up)
    }

    /// First cumulative uplink bits at which `test_err ≤ target`.
    pub fn bits_to_test_err(&self, target: f64) -> Option<u64> {
        self.points
            .iter()
            .find(|p| !p.test_err.is_nan() && p.test_err <= target)
            .map(|p| p.bits_up)
    }

    /// Minimum train loss seen.
    pub fn best_loss(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.train_loss)
            .fold(f64::INFINITY, f64::min)
    }

    /// CSV with a stable header; used by the figure harness.
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("step,train_loss,test_err,test_top5_err,bits_up,bits_down,mem_norm_sq\n");
        for p in &self.points {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{},{},{:.6e}\n",
                p.step, p.train_loss, p.test_err, p.test_top5_err, p.bits_up, p.bits_down,
                p.mem_norm_sq
            ));
        }
        out
    }

    /// JSON summary (used by `qsparse train --json`).
    pub fn summary_json(&self, name: &str, wall_secs: f64) -> Json {
        Json::obj(vec![
            ("name", Json::str(name)),
            ("steps", Json::from(self.points.last().map_or(0, |p| p.step))),
            ("final_loss", Json::num(self.final_loss())),
            ("best_loss", Json::num(self.best_loss())),
            (
                "final_test_err",
                Json::num(self.points.last().map_or(f64::NAN, |p| p.test_err)),
            ),
            ("bits_up", Json::from(self.total_bits_up())),
            (
                "bits_down",
                Json::from(self.points.last().map_or(0, |p| p.bits_down)),
            ),
            ("wall_secs", Json::num(wall_secs)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(steps: &[(usize, f64, u64)]) -> History {
        let mut h = History::new();
        for &(step, loss, bits) in steps {
            h.push(MetricPoint {
                step,
                train_loss: loss,
                test_err: loss / 2.0,
                test_top5_err: loss / 4.0,
                bits_up: bits,
                bits_down: 0,
                mem_norm_sq: 0.0,
            });
        }
        h
    }

    #[test]
    fn bits_to_loss_finds_first_crossing() {
        let h = mk(&[(0, 2.0, 0), (10, 1.0, 100), (20, 0.5, 200), (30, 0.4, 300)]);
        assert_eq!(h.bits_to_loss(1.0), Some(100));
        assert_eq!(h.bits_to_loss(0.45), Some(300));
        assert_eq!(h.bits_to_loss(0.1), None);
        assert_eq!(h.bits_to_test_err(0.25), Some(200));
    }

    #[test]
    fn csv_has_header_and_rows() {
        let h = mk(&[(0, 2.0, 0), (5, 1.5, 64)]);
        let csv = h.to_csv();
        assert!(csv.starts_with("step,train_loss"));
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn summary_json_fields() {
        let h = mk(&[(0, 2.0, 0), (5, 1.5, 64)]);
        let j = h.summary_json("test", 1.0);
        assert_eq!(j.get("steps").as_usize(), Some(5));
        assert_eq!(j.get("bits_up").as_usize(), Some(64));
        assert!(j.get("final_loss").as_f64().unwrap() - 1.5 < 1e-12);
    }
}
