//! Deterministic multicore engine: worker local steps and uplink
//! compression on a persistent `std::thread::scope` pool (std-only).
//!
//! Why this is safe to parallelize bit-for-bit: within a tick, each
//! worker's state transition depends only on its own `WorkerCore` (local
//! iterate, error memory, shard sampler, salted per-worker PCG streams) and
//! on immutable shared inputs (model parameters are per-worker copies, the
//! dataset/schedule/participation are read-only). The only cross-worker
//! arithmetic is the master's fold `x ← x − s·g` and the per-worker
//! broadcasts — both run on the coordinating thread, in ascending worker
//! index order, exactly as the sequential loop does. Hence the `History`
//! (losses, bit counts, memory norms, final parameters) is bit-identical
//! for every thread count — the same step-ordered-bucket argument the
//! threaded coordinator's barrier uses, validated in
//! `integration_parallel.rs`.
//!
//! Mechanics: `nthreads` long-lived pool threads each own a contiguous
//! chunk of `WorkerCore`s. Per tick the coordinator sends one `Step`
//! command per thread; on sync ticks each thread replies with its chunk's
//! compressed updates (taking the reusable message out of the worker's
//! buffer), the coordinator folds them in worker order, computes the
//! per-participant broadcast payloads, and returns them — together with the
//! now-consumed uplink messages, so their heap capacity is recycled into
//! the workers' buffers. Non-sync ticks need no rendezvous at all: threads
//! run ahead through queued `Step`s (H local steps per barrier, exactly the
//! paper's communication pattern). Steady-state allocations are limited to
//! the channel nodes and the small per-round command vectors; the
//! compress → fold arithmetic itself reuses the same buffers as the
//! sequential engine.

use super::{avg_mem_values, EvalSets, TrainSpec};
use crate::compress::{encode, Compressor, Message, MessageBuf};
use crate::data::{shard_indices, Dataset};
use crate::engine::History;
use crate::grad::GradModel;
use crate::protocol::{MasterCore, WorkerCore};
use crate::topology::{sync_participants_into, Participation, SyncSchedule};
use std::sync::mpsc;
use std::sync::Arc;

/// Ticks between forced rendezvous when no sync round occurs — bounds the
/// coordinator's run-ahead (and the queued `Cmd::Step` memory) under very
/// sparse schedules without adding a barrier to the common case.
const MAX_RUNAHEAD: usize = 64;

/// Coordinator → pool thread.
enum Cmd {
    /// Run one local step on every owned worker (global clock `t`); when
    /// `ack` is true the thread must send a `Reply` after this tick — set
    /// for every tick with a non-empty sync round (the reply carries the
    /// chunk's compressed updates) and, as pure backpressure, after
    /// `MAX_RUNAHEAD` consecutive roundless ticks (empty reply).
    Step { t: usize, eta: f64, ack: bool },
    /// Apply the round's broadcasts to owned participants. Each item also
    /// returns the worker's consumed uplink message for buffer reuse.
    Broadcast { items: Vec<BroadcastItem> },
    /// Shut down.
    Finish,
}

/// One participant's broadcast: (worker, payload, recycled uplink message).
struct BroadcastItem {
    worker: usize,
    payload: Down,
    recycled: Message,
}

/// Downlink payload (mirrors the two broadcast modes of the protocol).
enum Down {
    /// Dense model broadcast — one shared snapshot per round.
    Dense(Arc<[f32]>),
    /// Error-compensated compressed model delta for this worker.
    Delta(Message),
}

/// Pool thread → coordinator, one per thread per sync tick.
struct Reply {
    /// (worker, update message, post-update ‖m‖²) for owned participants.
    updates: Vec<(usize, Message, f64)>,
    /// Downlink delta messages consumed since the previous reply, returned
    /// so the coordinator's broadcast path reuses their capacity.
    spent_down: Vec<Message>,
}

pub(super) fn run_from_parallel(
    spec: &TrainSpec,
    model: &(dyn GradModel + Sync),
    global: Vec<f32>,
    nthreads: usize,
) -> History {
    let d = spec.model.dim();
    assert_eq!(global.len(), d);
    let r_count = spec.workers;
    assert!(r_count >= 1);
    assert!(nthreads >= 1 && nthreads <= r_count);
    let shards = shard_indices(spec.train, r_count, spec.sharding);
    let dense_down = spec.down_compressor.is_identity();

    // Contiguous worker → thread partition (sizes differ by at most one).
    let mut owner = vec![0usize; r_count];
    let mut chunks: Vec<Vec<WorkerCore>> = Vec::with_capacity(nthreads);
    {
        let base = r_count / nthreads;
        let rem = r_count % nthreads;
        let mut next = 0usize;
        for ti in 0..nthreads {
            let take = base + usize::from(ti < rem);
            let mut chunk = Vec::with_capacity(take);
            for r in next..next + take {
                owner[r] = ti;
                chunk.push(WorkerCore::new(
                    r,
                    global.clone(),
                    shards[r].clone(),
                    spec.batch,
                    spec.momentum,
                    spec.seed,
                ));
            }
            next += take;
            chunks.push(chunk);
        }
    }

    let mut master = MasterCore::new(global, r_count, spec.seed, !dense_down);
    master.set_agg_scale(spec.agg_scale);
    master.set_server_opt(spec.server_opt);
    let eval = EvalSets::new(spec);

    // Copies of the shared read-only inputs for the pool closures (the
    // closures must not capture `spec` itself: it holds the non-`Sync`
    // model reference).
    let train: &Dataset = spec.train;
    let compressor: &dyn Compressor = spec.compressor;
    let schedule: &dyn SyncSchedule = spec.schedule;
    let participation: &Participation = spec.participation;

    std::thread::scope(|s| {
        // One reply channel per thread: if a pool thread panics mid-run its
        // sender drops, the coordinator's recv() errors, and the panic
        // propagates at scope join — a shared channel would instead leave
        // the coordinator waiting forever for the dead thread's reply.
        let mut cmd_txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(nthreads);
        let mut reply_rxs: Vec<mpsc::Receiver<Reply>> = Vec::with_capacity(nthreads);
        for chunk in chunks {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
            cmd_txs.push(cmd_tx);
            reply_rxs.push(reply_rx);
            s.spawn(move || {
                pool_main(chunk, model, train, compressor, schedule, participation, cmd_rx, reply_tx)
            });
        }

        let mut history = History::new();
        let mut bits_up: u64 = 0;
        let mut bits_down: u64 = 0;
        // Reused buffers: round participant set, per-worker update slots,
        // last-reported ‖m‖² per worker, recycled downlink messages.
        let mut round = Vec::with_capacity(r_count);
        let mut slots: Vec<Option<Message>> = (0..r_count).map(|_| None).collect();
        let mut mem_norms = vec![0.0f64; r_count];
        let mut down_pool: Vec<Message> = Vec::new();
        let mut down_buf = MessageBuf::new();

        history.push(eval.measure(spec, 0, master.params(), 0, 0, 0.0));
        // Roundless ticks since the last rendezvous (run-ahead bound).
        let mut unsynced = 0usize;

        for t in 0..spec.steps {
            let eta = spec.lr.at(t);
            sync_participants_into(schedule, participation, r_count, t, &mut round);
            let sync = !round.is_empty();
            let ack = sync || unsynced + 1 >= MAX_RUNAHEAD;
            unsynced = if ack { 0 } else { unsynced + 1 };
            for tx in &cmd_txs {
                tx.send(Cmd::Step { t, eta, ack }).expect("engine pool thread died");
            }
            if ack && !sync {
                // Pure backpressure rendezvous: drain the (empty) replies.
                for rx in &reply_rxs {
                    let reply = rx.recv().expect("engine pool thread died");
                    down_pool.extend(reply.spent_down);
                    debug_assert!(reply.updates.is_empty());
                }
            }
            if sync {
                // One reply per thread (collected in thread order — the
                // fold below re-imposes worker-index order anyway).
                for rx in &reply_rxs {
                    let reply = rx.recv().expect("engine pool thread died");
                    down_pool.extend(reply.spent_down);
                    for (r, msg, mem) in reply.updates {
                        mem_norms[r] = mem;
                        slots[r] = Some(msg);
                    }
                }
                master.begin_round(round.len());
                for &r in &round {
                    let msg = slots[r].as_ref().expect("participant sent no update");
                    bits_up += msg.wire_bits();
                    master.apply_update(msg).expect("engine-internal update dim mismatch");
                }
                // Server optimizer step on the aggregate (no-op for Avg) —
                // before the snapshot/deltas so broadcasts see the stepped
                // model, exactly as in the sequential loop.
                master.end_round();
                // Broadcasts, in worker order (the master's downlink state
                // mutates per worker exactly as in the sequential loop).
                let dense_payload = dense_down.then(|| master.params_snapshot());
                let mut items: Vec<Vec<BroadcastItem>> =
                    (0..cmd_txs.len()).map(|_| Vec::new()).collect();
                for &r in &round {
                    let recycled = slots[r].take().expect("participant sent no update");
                    let payload = match &dense_payload {
                        Some(p) => {
                            bits_down += encode::dense_model_bits(d);
                            Down::Dense(Arc::clone(p))
                        }
                        None => {
                            if let Some(spare) = down_pool.pop() {
                                down_buf.recycle(spare);
                            }
                            master.delta_broadcast_into(r, spec.down_compressor, &mut down_buf);
                            bits_down += down_buf.message().wire_bits();
                            Down::Delta(down_buf.take())
                        }
                    };
                    items[owner[r]].push(BroadcastItem { worker: r, payload, recycled });
                }
                for (tx, its) in cmd_txs.iter().zip(items) {
                    if !its.is_empty() {
                        tx.send(Cmd::Broadcast { items: its }).expect("engine pool thread died");
                    }
                }
            }
            let step = t + 1;
            if step % spec.eval_every == 0 || step == spec.steps {
                history.push(eval.measure(
                    spec,
                    step,
                    master.params(),
                    bits_up,
                    bits_down,
                    avg_mem_values(&mem_norms),
                ));
            }
        }
        for tx in &cmd_txs {
            let _ = tx.send(Cmd::Finish);
        }
        drop(cmd_txs);
        history.final_params = master.into_params();
        history
    })
}

/// Run `f` over `items` on scoped threads — one per item, results in item
/// order. Used by the figure harness to run a figure's independent series
/// concurrently (each series seeds its own RNG streams, so outputs are
/// identical to the sequential loop's); the per-tick worker pool above
/// stays dedicated to a single training run.
pub(crate) fn map_parallel<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .iter()
            .enumerate()
            .map(|(i, item)| s.spawn(move || f(i, item)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel map worker panicked"))
            .collect()
    })
}

#[allow(clippy::too_many_arguments)]
fn pool_main(
    mut cores: Vec<WorkerCore>,
    model: &(dyn GradModel + Sync),
    train: &Dataset,
    compressor: &dyn Compressor,
    schedule: &dyn SyncSchedule,
    participation: &Participation,
    cmd_rx: mpsc::Receiver<Cmd>,
    reply_tx: mpsc::Sender<Reply>,
) {
    // Downlink messages consumed since the last reply (returned for reuse).
    let mut spent_down: Vec<Message> = Vec::new();
    for cmd in cmd_rx {
        match cmd {
            Cmd::Step { t, eta, ack } => {
                let mut updates = Vec::new();
                for core in cores.iter_mut() {
                    core.local_step(model, train, eta);
                    if ack
                        && schedule.syncs_at(core.id(), t)
                        && participation.participates(core.id(), t)
                    {
                        core.make_update(compressor);
                        let mem = core.mem_norm_sq();
                        updates.push((core.id(), core.take_update(), mem));
                    }
                }
                if ack {
                    let spent = std::mem::take(&mut spent_down);
                    if reply_tx.send(Reply { updates, spent_down: spent }).is_err() {
                        return; // coordinator gone
                    }
                }
            }
            Cmd::Broadcast { items } => {
                for item in items {
                    let core = cores
                        .iter_mut()
                        .find(|c| c.id() == item.worker)
                        .expect("broadcast routed to a thread that does not own the worker");
                    match item.payload {
                        Down::Dense(params) => core.apply_dense_broadcast(&params),
                        Down::Delta(msg) => {
                            core.apply_delta_broadcast(&msg);
                            spent_down.push(msg);
                        }
                    }
                    core.recycle_update(item.recycled);
                }
            }
            Cmd::Finish => return,
        }
    }
}
