//! Deterministic multicore engine: worker local steps, uplink compression
//! — and, since the master-round parallelization, the master's own round
//! (sharded fold + per-worker downlink) — on one persistent
//! `std::thread::scope` pool (std-only).
//!
//! Why this is safe to parallelize bit-for-bit: within a tick, each
//! worker's state transition depends only on its own `WorkerCore` (local
//! iterate, error memory, shard sampler, salted per-worker PCG streams) and
//! on immutable shared inputs (model parameters are per-worker copies, the
//! dataset/schedule/participation are read-only). The cross-worker
//! arithmetic is the master's round, and both halves of it parallelize
//! without changing a single f32 operation:
//!
//! * **Sharded fold** — the fold `x ← x − s·g` (or `accum ← accum + s·g`
//!   under a non-`Avg` server optimizer) is a per-coordinate sum over the
//!   round's messages. Each pool thread owns a disjoint contiguous chunk of
//!   the fold target and folds *every* round message over its chunk in
//!   worker-index order (`Message::add_into_range`; sparse supports are
//!   ascending, so each message's in-chunk span is binary-searched). Per
//!   coordinate the addition sequence is exactly the sequential loop's, so
//!   the result — and hence `History` — is bit-identical for every thread
//!   count.
//! * **Parallel downlink** — per-worker delta + compress + error-feedback
//!   advance touch only that worker's `DownlinkWorker` (anchor mirror +
//!   salted RNG stream), which lives on the pool thread that owns the
//!   worker. Against the same post-round model every worker's broadcast is
//!   independent of the order workers are served in — embarrassingly
//!   parallel and deterministic by construction. A side effect is that the
//!   master's `R·d` downlink anchor mirrors are sharded across the pool
//!   instead of centralized on the coordinator.
//!
//! Mechanics: `nthreads` long-lived pool threads each own a contiguous
//! chunk of `WorkerCore`s (plus their `DownlinkWorker`s under a compressed
//! downlink). Per tick the coordinator sends one `Step` command per thread;
//! on sync ticks each thread replies with its chunk's compressed updates,
//! the coordinator orders them by worker index and hands every thread a
//! raw view of the round's message list plus its disjoint chunk of the
//! fold target (`Cmd::Fold`), barriers on the fold acks, runs the server
//! optimizer step (`end_round`), and fans the broadcast out (`Cmd::Down`)
//! — dense payloads as one shared `Arc` snapshot (fire-and-forget),
//! compressed payloads as a read-only view of the model whose acks carry
//! the downlink wire bits and double as the barrier that keeps the model
//! immutable while threads read it. Consumed uplink messages ride the
//! `Down` command back to their owners so their heap capacity is recycled.
//! Non-sync ticks need no rendezvous at all: threads run ahead through
//! queued `Step`s (H local steps per barrier, exactly the paper's
//! communication pattern). Steady-state allocations are limited to the
//! channel nodes and the small per-round command vectors; the
//! compress → fold → broadcast arithmetic itself reuses the same buffers
//! as the sequential engine.
//!
//! # The fork-join ownership protocol (the crate's only `unsafe`)
//!
//! The raw views (`MsgsView`, `ChunkView`, `GlobalView`) are the only
//! unsafe code in the library. Their contract is the classic fork-join one
//! (what `rayon`'s scoped splits do), stated once here and referenced by
//! every `// SAFETY:` comment below:
//!
//! 1. **Fork** — the coordinator holds the exclusive (or shared) borrow of
//!    the data, carves *disjoint* raw views from it, and sends one view per
//!    pool thread over its command channel. The `mpsc` send is the
//!    happens-before edge that publishes the pointed-to data to the thread.
//! 2. **Work** — a pool thread dereferences its view only between receiving
//!    the command and sending the phase's ack. Mutable views (`ChunkView`)
//!    cover non-overlapping index ranges, so no two threads ever touch the
//!    same coordinate; shared views (`MsgsView`, `GlobalView`) are
//!    read-only on every thread.
//! 3. **Join** — the coordinator receives the ack from *every* thread
//!    before it re-borrows (or lets anything else mutate) the viewed data.
//!    The ack's `mpsc` receive is the happens-before edge back. Dense
//!    broadcasts are the one fire-and-forget payload, and they ride an
//!    `Arc` — no raw pointer, no barrier needed.
//!
//! The same protocol (and the same two view types) is reused by the
//! threaded coordinator's sharded fold in `coordinator::master`, with its
//! `FoldPool` ack channel as the join edge.
//!
//! What machine-checks this:
//!
//! * `cargo run -p repo-lint` — confines `unsafe` to this file, the
//!   coordinator's fold pool and the bench allocator; requires a
//!   `// SAFETY:` comment on every unsafe block/impl (and `# Safety` docs
//!   on unsafe fns); bans wall-clock and hash-order nondeterminism from
//!   the deterministic-path modules. The crate additionally denies
//!   `unsafe_op_in_unsafe_fn`, so every dereference is an explicit block.
//! * `cargo +nightly miri test miri_` — runs the `miri_`-prefixed
//!   concurrency tests (tiny d/R, real thread interleavings) under Miri's
//!   data-race detector. Heavy tests are `#[cfg_attr(miri, ignore)]`d.
//! * `RUSTFLAGS=-Zsanitizer=thread cargo +nightly test -Zbuild-std ...` —
//!   ThreadSanitizer over the threaded-coordinator integration tests (see
//!   the `tsan` CI job for the exact invocation).

use super::{avg_mem_values, EvalSets, TrainSpec};
use crate::compress::{encode, Codec, Compressor, Message, MessageBuf};
use crate::data::{shard_indices, Dataset};
use crate::engine::History;
use crate::grad::GradModel;
use crate::protocol::{DownlinkWorker, MasterCore, WorkerCore};
use crate::topology::{sync_participants_into, Participation, SyncSchedule};
use std::sync::mpsc;
use std::sync::Arc;

/// Ticks between forced rendezvous when no sync round occurs — bounds the
/// coordinator's run-ahead (and the queued `Cmd::Step` memory) under very
/// sparse schedules without adding a barrier to the common case.
const MAX_RUNAHEAD: usize = 64;

/// Raw view of the coordinator's round-message list (worker-index order),
/// shared read-only with every pool thread for the sharded fold. Also used
/// by the threaded coordinator's sharded fold (`coordinator::master`),
/// which obeys the same contract with its own barrier.
///
/// Safety contract: the holder keeps the backing `Vec<Message>` alive
/// and unmodified from the moment the view is sent until it has received
/// the fold ack from every thread; threads only dereference between
/// receiving the fold command and sending that ack. `Message` is `Sync`,
/// so shared `&` access from several threads is sound.
#[derive(Clone, Copy)]
pub(crate) struct MsgsView {
    ptr: *const Message,
    len: usize,
}

// SAFETY: the view is a read-only snapshot of `&[Message]`; `Message` is
// `Sync` (all-owned data, no interior mutability), so shared access from the
// receiving thread is sound, and the fork-join contract (module docs) keeps
// the backing list alive and unmodified while any view is live.
unsafe impl Send for MsgsView {}

impl MsgsView {
    /// Capture a view of `msgs`. Caller upholds the lifetime/immutability
    /// contract documented on the type.
    pub(crate) fn new(msgs: &[Message]) -> Self {
        MsgsView { ptr: msgs.as_ptr(), len: msgs.len() }
    }

    /// Re-materialize the slice.
    ///
    /// # Safety
    /// The backing `Vec<Message>` must still be alive and unmodified (see
    /// the type-level contract).
    pub(crate) unsafe fn as_slice<'a>(self) -> &'a [Message] {
        // SAFETY: `ptr`/`len` came from a live `&[Message]` (`new`), and the
        // caller's contract (above) guarantees the backing Vec has neither
        // moved nor been dropped since.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

/// Raw view of one thread's chunk `[lo, hi)` of the round's fold target.
/// The coordinator derives one per thread from the *same* exclusive borrow
/// (`MasterCore::fold_target`) over non-overlapping ranges, and re-borrows
/// the target only after every fold ack — so at any moment each coordinate
/// is reachable from exactly one live view.
pub(crate) struct ChunkView {
    ptr: *mut f32,
    lo: usize,
    hi: usize,
}

// SAFETY: a `ChunkView` is the unique owner of coordinates `[lo, hi)` of
// the fold target until its fold ack (the coordinator carves disjoint
// ranges from one `&mut` and blocks on every ack before re-borrowing —
// module docs), so moving it to one pool thread transfers exclusive access,
// exactly like sending a `&mut [f32]` sub-slice.
unsafe impl Send for ChunkView {}

impl ChunkView {
    /// Carve chunk `[lo, hi)` out of the exclusive borrow `target`.
    /// Caller guarantees the per-call ranges are disjoint and within
    /// `target.len()`, and does not touch `target` until every chunk's
    /// fold ack arrives.
    pub(crate) fn new(target: &mut [f32], lo: usize, hi: usize) -> Self {
        debug_assert!(lo <= hi && hi <= target.len());
        // SAFETY: `lo <= target.len()`, so the offset stays within (or one
        // past) the allocation.
        ChunkView { ptr: unsafe { target.as_mut_ptr().add(lo) }, lo, hi }
    }

    /// Fold every message of `msgs` over this chunk, in list order — the
    /// per-coordinate addition sequence of the sequential fold.
    ///
    /// # Safety
    /// Per the view contracts: the message list and fold target are alive
    /// and untouched by others, and no other live chunk overlaps [lo, hi).
    pub(crate) unsafe fn fold(&self, msgs: MsgsView, scale: f32) {
        // SAFETY: caller's contract — the coordinator holds the message
        // list unmodified until this chunk's fold ack.
        let msgs = unsafe { msgs.as_slice() };
        // SAFETY: `ptr` points at coordinate `lo` of a live fold target of
        // length ≥ `hi` (checked in `new`), and this view is the only one
        // covering `[lo, hi)` (caller's disjointness contract), so a unique
        // mutable sub-slice of `hi - lo` elements is sound.
        let out = unsafe { std::slice::from_raw_parts_mut(self.ptr, self.hi - self.lo) };
        for m in msgs {
            m.add_into_range(out, scale, self.lo..self.hi);
        }
    }
}

/// Raw read-only view of the post-round global model for the parallel
/// downlink. The coordinator blocks for every `Reply::DownDone` ack before
/// anything can mutate the model again (the next round's fold, the server
/// optimizer step, `into_params`).
#[derive(Clone, Copy)]
struct GlobalView {
    ptr: *const f32,
    len: usize,
}

// SAFETY: the view is read-only on every receiving thread and the
// coordinator keeps the model immutable until all `DownDone` acks arrive
// (fork-join contract, module docs) — shared `&[f32]` access is sound.
unsafe impl Send for GlobalView {}

/// Coordinator → pool thread.
enum Cmd {
    /// Run one local step on every owned worker (global clock `t`); when
    /// `ack` is true the thread must send a `Reply` after this tick — set
    /// for every tick with a non-empty sync round (the reply carries the
    /// chunk's compressed updates) and, as pure backpressure, after
    /// `MAX_RUNAHEAD` consecutive roundless ticks (empty reply).
    Step { t: usize, eta: f64, ack: bool },
    /// Sharded master fold: fold every round message, in worker-index
    /// order, over this thread's disjoint chunk of the fold target.
    /// Replies `Reply::FoldDone`.
    Fold { msgs: MsgsView, chunk: ChunkView, scale: f32 },
    /// Round broadcast for this thread's owned participants, which are
    /// exactly the workers listed in `recycled` (each paired with its
    /// consumed uplink message, returned for buffer reuse). A compressed
    /// payload replies `Reply::DownDone` with the encoded downlink bits;
    /// a dense payload needs no rendezvous (the `Arc` keeps it alive).
    Down { payload: DownPayload, recycled: Vec<(usize, Message)> },
    /// Shut down.
    Finish,
}

/// Downlink payload (mirrors the two broadcast modes of the protocol).
enum DownPayload {
    /// Dense model broadcast — one shared snapshot per round.
    Dense(Arc<[f32]>),
    /// Compressed downlink: each thread compresses its owned participants'
    /// error-compensated deltas against this view of the post-round model.
    Global(GlobalView),
}

/// Pool thread → coordinator.
enum Reply {
    /// (worker, update message, post-update ‖m‖²) for owned participants
    /// of a sync tick; empty for the pure backpressure rendezvous.
    Updates(Vec<(usize, Message, f64)>),
    /// Sharded-fold ack: this thread's chunk is fully folded.
    FoldDone,
    /// Compressed-downlink ack: deltas computed, applied and accounted.
    DownDone { bits_down: u64 },
}

/// Everything one pool thread owns: a contiguous chunk of workers, their
/// downlink state (compressed downlink only, index-aligned with `cores`),
/// the shared read-only inputs, and the per-thread downlink scratch.
struct PoolThread<'a> {
    cores: Vec<WorkerCore>,
    down: Vec<DownlinkWorker>,
    model: &'a (dyn GradModel + Sync),
    train: &'a Dataset,
    compressor: &'a dyn Compressor,
    down_compressor: &'a dyn Compressor,
    /// Wire codec for downlink bit accounting (`wire_bits_with` — the pure
    /// cost walk; the engine never serializes).
    codec: Codec,
    schedule: &'a dyn SyncSchedule,
    participation: &'a Participation,
    /// d-float delta scratch + message buffer for the parallel downlink.
    delta_scratch: Vec<f32>,
    down_buf: MessageBuf,
}

pub(super) fn run_from_parallel(
    spec: &TrainSpec,
    model: &(dyn GradModel + Sync),
    global: Vec<f32>,
    nthreads: usize,
) -> History {
    let d = spec.model.dim();
    assert_eq!(global.len(), d);
    let r_count = spec.workers;
    assert!(r_count >= 1);
    assert!(nthreads >= 1 && nthreads <= r_count);
    let shards = shard_indices(spec.train, r_count, spec.sharding);
    let dense_down = spec.down_compressor.is_identity();

    // Contiguous worker → thread partition (sizes differ by at most one).
    // Under a compressed downlink each thread also owns its workers'
    // `DownlinkWorker`s — the coordinator's master then carries no
    // per-worker downlink state at all.
    let mut owner = vec![0usize; r_count];
    let mut thread_states: Vec<PoolThread> = Vec::with_capacity(nthreads);
    {
        let base = r_count / nthreads;
        let rem = r_count % nthreads;
        let mut next = 0usize;
        for ti in 0..nthreads {
            let take = base + usize::from(ti < rem);
            let mut cores = Vec::with_capacity(take);
            let mut down = Vec::new();
            for r in next..next + take {
                owner[r] = ti;
                cores.push(WorkerCore::new(
                    r,
                    global.clone(),
                    shards[r].clone(),
                    spec.batch,
                    spec.momentum,
                    spec.seed,
                ));
                if !dense_down {
                    down.push(DownlinkWorker::new(global.clone(), spec.seed, r));
                }
            }
            next += take;
            thread_states.push(PoolThread {
                cores,
                down,
                model,
                train: spec.train,
                compressor: spec.compressor,
                down_compressor: spec.down_compressor,
                codec: spec.codec,
                schedule: spec.schedule,
                participation: spec.participation,
                delta_scratch: if dense_down { Vec::new() } else { vec![0.0f32; d] },
                down_buf: MessageBuf::new(),
            });
        }
    }

    // `compressed_downlink = false` even when the run compresses the
    // downlink: the per-worker state lives on the pool threads (above).
    let mut master = MasterCore::new(global, r_count, spec.seed, false);
    master.set_agg_scale(spec.agg_scale);
    master.set_server_opt(spec.server_opt);
    let eval = EvalSets::new(spec);

    // Copies for the coordinator loop (the pool closures must not capture
    // `spec` itself: it holds the non-`Sync` model reference).
    let schedule: &dyn SyncSchedule = spec.schedule;
    let participation: &Participation = spec.participation;

    std::thread::scope(|s| {
        // One reply channel per thread: if a pool thread panics mid-run its
        // sender drops, the coordinator's recv() errors, and the panic
        // propagates at scope join — a shared channel would instead leave
        // the coordinator waiting forever for the dead thread's reply.
        let mut cmd_txs: Vec<mpsc::Sender<Cmd>> = Vec::with_capacity(nthreads);
        let mut reply_rxs: Vec<mpsc::Receiver<Reply>> = Vec::with_capacity(nthreads);
        for st in thread_states {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
            cmd_txs.push(cmd_tx);
            reply_rxs.push(reply_rx);
            s.spawn(move || pool_main(st, cmd_rx, reply_tx));
        }

        let mut history = History::new();
        let mut bits_up: u64 = 0;
        let mut bits_down: u64 = 0;
        // Reused buffers: round participant set, per-worker update slots,
        // last-reported ‖m‖² per worker, the round's fold list (messages in
        // worker-index order), and the which-threads-owe-a-DownDone mask.
        // `items` reuses only its outer Vec — the per-thread routing Vecs
        // ride the Down command to the pool and are consumed there, the
        // same per-round channel cost class as the command nodes.
        let mut round = Vec::with_capacity(r_count);
        let mut slots: Vec<Option<Message>> = (0..r_count).map(|_| None).collect();
        let mut mem_norms = vec![0.0f64; r_count];
        let mut round_msgs: Vec<Message> = Vec::with_capacity(r_count);
        let mut items: Vec<Vec<(usize, Message)>> = (0..nthreads).map(|_| Vec::new()).collect();
        let mut expect_down = vec![false; nthreads];

        history.push(eval.measure(spec, 0, master.params(), 0, 0, 0.0));
        // Roundless ticks since the last rendezvous (run-ahead bound).
        let mut unsynced = 0usize;

        for t in 0..spec.steps {
            let eta = spec.lr.at(t);
            sync_participants_into(schedule, participation, r_count, t, &mut round);
            let sync = !round.is_empty();
            let ack = sync || unsynced + 1 >= MAX_RUNAHEAD;
            unsynced = if ack { 0 } else { unsynced + 1 };
            for tx in &cmd_txs {
                tx.send(Cmd::Step { t, eta, ack }).expect("engine pool thread died");
            }
            if ack && !sync {
                // Pure backpressure rendezvous: drain the (empty) replies.
                for rx in &reply_rxs {
                    match rx.recv().expect("engine pool thread died") {
                        Reply::Updates(u) => debug_assert!(u.is_empty()),
                        _ => unreachable!("unexpected reply at backpressure rendezvous"),
                    }
                }
            }
            if sync {
                // One reply per thread (collected in thread order — the
                // fold list below re-imposes worker-index order anyway).
                for rx in &reply_rxs {
                    match rx.recv().expect("engine pool thread died") {
                        Reply::Updates(updates) => {
                            for (r, msg, mem) in updates {
                                mem_norms[r] = mem;
                                slots[r] = Some(msg);
                            }
                        }
                        _ => unreachable!("expected the round's update reply"),
                    }
                }
                master.begin_round(round.len());
                // The fold list: the round's messages in worker-index
                // order, with uplink bits accounted exactly as the
                // sequential loop does.
                round_msgs.clear();
                for &r in &round {
                    let msg = slots[r].take().expect("participant sent no update");
                    assert_eq!(msg.dim(), d, "engine-internal update dim mismatch");
                    bits_up += msg.wire_bits_with(spec.codec);
                    round_msgs.push(msg);
                }
                // Sharded fold: each thread folds every message over its
                // own disjoint chunk, in the same message order — per
                // coordinate the addition sequence is identical to the
                // sequential fold, so the result is bit-identical.
                {
                    let msgs = MsgsView::new(&round_msgs);
                    let (target, scale) = master.fold_target();
                    for (ti, tx) in cmd_txs.iter().enumerate() {
                        let (lo, hi) = (ti * d / nthreads, (ti + 1) * d / nthreads);
                        // The [lo, hi) ranges partition 0..d, so the views
                        // are disjoint.
                        let chunk = ChunkView::new(target, lo, hi);
                        tx.send(Cmd::Fold { msgs, chunk, scale })
                            .expect("engine pool thread died");
                    }
                    for rx in &reply_rxs {
                        match rx.recv().expect("engine pool thread died") {
                            Reply::FoldDone => {}
                            _ => unreachable!("expected the fold ack"),
                        }
                    }
                }
                // Server optimizer step on the aggregate (no-op for Avg) —
                // before the snapshot/deltas so broadcasts see the stepped
                // model, exactly as in the sequential loop.
                master.end_round();
                // Broadcast fan-out: route each participant's consumed
                // uplink message back to its owner thread alongside the
                // round's payload.
                for (&r, msg) in round.iter().zip(round_msgs.drain(..)) {
                    items[owner[r]].push((r, msg));
                }
                if dense_down {
                    let payload = master.params_snapshot();
                    bits_down += round.len() as u64 * encode::dense_model_bits(d);
                    for (tx, its) in cmd_txs.iter().zip(items.iter_mut()) {
                        if !its.is_empty() {
                            tx.send(Cmd::Down {
                                payload: DownPayload::Dense(Arc::clone(&payload)),
                                recycled: std::mem::take(its),
                            })
                            .expect("engine pool thread died");
                        }
                    }
                } else {
                    // Parallel downlink: each owner thread compresses its
                    // participants' deltas against one read-only view of
                    // the post-round model. The acks return the wire bits
                    // and barrier the model against mutation while threads
                    // read it.
                    let global = GlobalView { ptr: master.params().as_ptr(), len: d };
                    expect_down.fill(false);
                    for (ti, (tx, its)) in cmd_txs.iter().zip(items.iter_mut()).enumerate() {
                        if !its.is_empty() {
                            tx.send(Cmd::Down {
                                payload: DownPayload::Global(global),
                                recycled: std::mem::take(its),
                            })
                            .expect("engine pool thread died");
                            expect_down[ti] = true;
                        }
                    }
                    for (rx, expected) in reply_rxs.iter().zip(&expect_down) {
                        if *expected {
                            match rx.recv().expect("engine pool thread died") {
                                Reply::DownDone { bits_down: b } => bits_down += b,
                                _ => unreachable!("expected the downlink ack"),
                            }
                        }
                    }
                }
            }
            let step = t + 1;
            if step % spec.eval_every == 0 || step == spec.steps {
                history.push(eval.measure(
                    spec,
                    step,
                    master.params(),
                    bits_up,
                    bits_down,
                    avg_mem_values(&mem_norms),
                ));
            }
        }
        for tx in &cmd_txs {
            let _ = tx.send(Cmd::Finish);
        }
        drop(cmd_txs);
        history.final_params = master.into_params();
        history
    })
}

/// Run `f` over `items` on scoped threads — one per item, results in item
/// order. Used by the figure harness to run a figure's independent series
/// concurrently (each series seeds its own RNG streams, so outputs are
/// identical to the sequential loop's); the per-tick worker pool above
/// stays dedicated to a single training run.
pub(crate) fn map_parallel<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    std::thread::scope(|s| {
        let f = &f;
        let handles: Vec<_> = items
            .iter()
            .enumerate()
            .map(|(i, item)| s.spawn(move || f(i, item)))
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel map worker panicked"))
            .collect()
    })
}

fn pool_main(mut st: PoolThread, cmd_rx: mpsc::Receiver<Cmd>, reply_tx: mpsc::Sender<Reply>) {
    for cmd in cmd_rx {
        match cmd {
            Cmd::Step { t, eta, ack } => {
                let mut updates = Vec::new();
                for core in st.cores.iter_mut() {
                    core.local_step(st.model, st.train, eta);
                    if ack
                        && st.schedule.syncs_at(core.id(), t)
                        && st.participation.participates(core.id(), t)
                    {
                        core.make_update(st.compressor);
                        let mem = core.mem_norm_sq();
                        updates.push((core.id(), core.take_update(), mem));
                    }
                }
                if ack && reply_tx.send(Reply::Updates(updates)).is_err() {
                    return; // coordinator gone
                }
            }
            Cmd::Fold { msgs, chunk, scale } => {
                // SAFETY: per the view contracts, the coordinator keeps the
                // message list and the fold target untouched until this
                // FoldDone ack, and no other thread's chunk overlaps
                // [lo, hi).
                unsafe { chunk.fold(msgs, scale) };
                if reply_tx.send(Reply::FoldDone).is_err() {
                    return;
                }
            }
            Cmd::Down { payload, recycled } => {
                let mut bits = 0u64;
                for (r, spent) in recycled {
                    let i = st
                        .cores
                        .iter()
                        .position(|c| c.id() == r)
                        .expect("broadcast routed to a thread that does not own the worker");
                    match &payload {
                        DownPayload::Dense(params) => st.cores[i].apply_dense_broadcast(params),
                        DownPayload::Global(g) => {
                            // SAFETY: the coordinator blocks for this
                            // thread's DownDone before the model can change.
                            let global = unsafe { std::slice::from_raw_parts(g.ptr, g.len) };
                            st.down[i].delta_into(
                                global,
                                &mut st.delta_scratch,
                                st.down_compressor,
                                &mut st.down_buf,
                            );
                            bits += st.down_buf.message().wire_bits_with(st.codec);
                            st.cores[i].apply_delta_broadcast(st.down_buf.message());
                        }
                    }
                    st.cores[i].recycle_update(spent);
                }
                if matches!(payload, DownPayload::Global(_))
                    && reply_tx.send(Reply::DownDone { bits_down: bits }).is_err()
                {
                    return;
                }
            }
            Cmd::Finish => return,
        }
    }
}
