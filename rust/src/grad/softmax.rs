//! ℓ2-regularized softmax (multinomial logistic) regression — the paper's
//! convex workload (§5.2.1).
//!
//! Cost:  −(1/b) Σ_i log h_{x,z}(a_i)[y_i] + (λ/2)‖W‖²
//! Params layout (flat, d = (dim+1)·classes):
//!   [ W (dim × classes, row-major by feature) | z (classes biases) ]
//! λ defaults to 1/n as in the paper. The regularizer covers W only (the
//! paper regularizes ‖x‖², i.e. the weight columns).

use super::GradModel;
use crate::data::Batch;

#[derive(Clone, Debug)]
pub struct SoftmaxRegression {
    pub dim: usize,
    pub classes: usize,
    pub lambda: f64,
}

impl SoftmaxRegression {
    pub fn new(dim: usize, classes: usize, lambda: f64) -> Self {
        assert!(classes >= 2);
        SoftmaxRegression { dim, classes, lambda }
    }

    #[inline]
    fn w_len(&self) -> usize {
        self.dim * self.classes
    }

    /// logits[c] = Σ_j x_j W[j,c] + z_c for one row.
    fn logits_row(&self, params: &[f32], row: &[f32], out: &mut [f32]) {
        let c = self.classes;
        let (w, z) = params.split_at(self.w_len());
        out.copy_from_slice(&z[..c]);
        for (j, &xj) in row.iter().enumerate() {
            if xj == 0.0 {
                continue;
            }
            let wrow = &w[j * c..(j + 1) * c];
            for (o, &wjc) in out.iter_mut().zip(wrow) {
                *o += xj * wjc;
            }
        }
    }

    /// Softmax in place; returns logsumexp.
    fn softmax_inplace(logits: &mut [f32]) -> f64 {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f64;
        for l in logits.iter_mut() {
            *l = (*l - max).exp();
            sum += *l as f64;
        }
        for l in logits.iter_mut() {
            *l = (*l as f64 / sum) as f32;
        }
        max as f64 + sum.ln()
    }
}

impl GradModel for SoftmaxRegression {
    fn dim(&self) -> usize {
        (self.dim + 1) * self.classes
    }

    fn loss_grad(&self, params: &[f32], batch: &Batch, grad: &mut [f32]) -> f64 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        assert_eq!(batch.dim, self.dim);
        let c = self.classes;
        let b = batch.b;
        grad.iter_mut().for_each(|g| *g = 0.0);
        let (gw, gz) = grad.split_at_mut(self.w_len());
        // Per-row probability scratch on the stack for realistic class
        // counts, so the engine's steady-state step stays allocation-free.
        let mut probs_stack = [0.0f32; 64];
        let mut probs_heap;
        let mut probs: &mut [f32] = if c <= 64 {
            &mut probs_stack[..c]
        } else {
            probs_heap = vec![0.0f32; c];
            &mut probs_heap
        };
        let mut loss = 0.0f64;
        let inv_b = 1.0 / b as f32;
        for i in 0..b {
            let row = &batch.x[i * self.dim..(i + 1) * self.dim];
            self.logits_row(params, row, &mut probs);
            let y = batch.y[i] as usize;
            // loss_i = logsumexp − logit_y; recompute logit_y before softmax
            // by tracking it: do softmax and use log(prob_y) instead.
            Self::softmax_inplace(&mut probs);
            loss -= (probs[y].max(1e-30) as f64).ln();
            // dL/dlogit = (p − onehot)/b
            for cc in 0..c {
                let delta = (probs[cc] - f32::from(cc == y)) * inv_b;
                gz[cc] += delta;
                if delta != 0.0 {
                    for (j, &xj) in row.iter().enumerate() {
                        gw[j * c + cc] += delta * xj;
                    }
                }
            }
        }
        loss /= b as f64;
        // ℓ2 on W.
        if self.lambda != 0.0 {
            let lam = self.lambda as f32;
            let w = &params[..self.w_len()];
            let mut reg = 0.0f64;
            for (g, &wv) in gw.iter_mut().zip(w) {
                *g += lam * wv;
                reg += (wv as f64) * (wv as f64);
            }
            loss += 0.5 * self.lambda * reg;
        }
        loss
    }

    fn error_rate(&self, params: &[f32], batch: &Batch) -> f64 {
        self.topn_error_rate(params, batch, 1)
    }

    fn topn_error_rate(&self, params: &[f32], batch: &Batch, n: usize) -> f64 {
        let c = self.classes;
        let mut logits = vec![0.0f32; c];
        let mut wrong = 0usize;
        for i in 0..batch.b {
            let row = &batch.x[i * self.dim..(i + 1) * self.dim];
            self.logits_row(params, row, &mut logits);
            let y = batch.y[i] as usize;
            let ly = logits[y];
            // Rank of the true class under argmax-with-first-index tie-break
            // (equal logits at a lower index outrank y — matters at x_0 = 0,
            // where all logits tie and top-1 error must be (C−1)/C).
            let better = logits
                .iter()
                .enumerate()
                .filter(|&(c, &l)| l > ly || (l == ly && c < y))
                .count();
            if better >= n {
                wrong += 1;
            }
        }
        wrong as f64 / batch.b as f64
    }

    fn name(&self) -> String {
        format!("softmax({}x{},λ={})", self.dim, self.classes, self.lambda)
    }

    fn as_sync(&self) -> Option<&(dyn GradModel + Sync)> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gaussian_clusters, Sharding};
    use crate::util::rng::Pcg64;

    fn setup() -> (SoftmaxRegression, crate::data::Batch) {
        let ds = gaussian_clusters(64, 12, 4, 1.5, 0.4, 11);
        let shards = crate::data::shard_indices(&ds, 1, Sharding::Iid);
        let batch = ds.gather(&shards[0][..16]);
        (SoftmaxRegression::new(12, 4, 0.01), batch)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (m, batch) = setup();
        let mut rng = Pcg64::seeded(60);
        let params: Vec<f32> = (0..m.dim()).map(|_| rng.normal_f32() * 0.1).collect();
        let coords: Vec<usize> = (0..m.dim()).step_by(7).collect();
        crate::grad::check_grad(&m, &params, &batch, &coords);
    }

    #[test]
    fn loss_at_zero_is_log_c() {
        let (m, batch) = setup();
        let params = vec![0.0f32; m.dim()];
        let loss = m.loss(&params, &batch);
        assert!((loss - (4.0f64).ln()).abs() < 1e-6, "{loss}");
    }

    #[test]
    fn gd_converges_and_classifies() {
        let ds = gaussian_clusters(256, 12, 4, 2.0, 0.3, 12);
        let m = SoftmaxRegression::new(12, 4, 1.0 / 256.0);
        let all: Vec<usize> = (0..ds.n).collect();
        let batch = ds.gather(&all);
        let mut params = vec![0.0f32; m.dim()];
        let mut g = vec![0.0f32; m.dim()];
        let l0 = m.loss(&params, &batch);
        for _ in 0..300 {
            m.loss_grad(&params, &batch, &mut g);
            for (p, gi) in params.iter_mut().zip(&g) {
                *p -= 0.5 * gi;
            }
        }
        let l1 = m.loss(&params, &batch);
        assert!(l1 < l0 * 0.2, "loss {l0} → {l1}");
        assert!(m.error_rate(&params, &batch) < 0.05);
        assert!(m.topn_error_rate(&params, &batch, 2) <= m.error_rate(&params, &batch));
    }
}
