//! Multi-layer perceptron with ReLU hidden layers and softmax cross-entropy
//! output — the native non-convex workload standing in for the paper's
//! ResNet-50 (DESIGN.md §6: the communication claims under test depend on d
//! and the update distribution, not on convolutional structure).
//!
//! Params layout (flat): for each layer l with shape (in_l × out_l):
//!   [ W_l row-major (in × out) | b_l (out) ] concatenated over layers.

use super::GradModel;
use crate::data::Batch;
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct Mlp {
    /// Layer widths including input and output, e.g. [784, 256, 10].
    pub widths: Vec<usize>,
}

impl Mlp {
    pub fn new(widths: Vec<usize>) -> Self {
        assert!(widths.len() >= 2);
        Mlp { widths }
    }

    pub fn layers(&self) -> usize {
        self.widths.len() - 1
    }

    /// Flat sizes per layer: (in+1)*out.
    pub fn layer_sizes(&self) -> Vec<usize> {
        (0..self.layers())
            .map(|l| (self.widths[l] + 1) * self.widths[l + 1])
            .collect()
    }

    fn layer_offsets(&self) -> Vec<usize> {
        let mut off = vec![0usize];
        for s in self.layer_sizes() {
            off.push(off.last().unwrap() + s);
        }
        off
    }

    /// He-style init matching the JAX model in python/compile/model.py.
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut rng = Pcg64::new(seed, 1313);
        let mut params = vec![0.0f32; self.dim()];
        let offs = self.layer_offsets();
        for l in 0..self.layers() {
            let (fan_in, fan_out) = (self.widths[l], self.widths[l + 1]);
            let std = (2.0 / fan_in as f64).sqrt() as f32;
            let w = &mut params[offs[l]..offs[l] + fan_in * fan_out];
            rng.fill_normal(w, std);
            // biases stay zero
        }
        params
    }

    /// Forward pass storing post-activation values per layer. Returns logits
    /// for each row (b × classes) plus the stored activations for backprop.
    fn forward(&self, params: &[f32], batch: &Batch) -> (Vec<Vec<f32>>, Vec<f32>) {
        let offs = self.layer_offsets();
        let b = batch.b;
        let mut acts: Vec<Vec<f32>> = Vec::with_capacity(self.layers());
        let mut cur = batch.x.clone();
        let mut cur_w = batch.dim;
        for l in 0..self.layers() {
            let (fan_in, fan_out) = (self.widths[l], self.widths[l + 1]);
            assert_eq!(cur_w, fan_in);
            let w = &params[offs[l]..offs[l] + fan_in * fan_out];
            let bias = &params[offs[l] + fan_in * fan_out..offs[l + 1]];
            let mut next = vec![0.0f32; b * fan_out];
            for i in 0..b {
                let xi = &cur[i * fan_in..(i + 1) * fan_in];
                let oi = &mut next[i * fan_out..(i + 1) * fan_out];
                oi.copy_from_slice(bias);
                for (j, &xj) in xi.iter().enumerate() {
                    if xj == 0.0 {
                        continue;
                    }
                    let wrow = &w[j * fan_out..(j + 1) * fan_out];
                    for (o, &wv) in oi.iter_mut().zip(wrow) {
                        *o += xj * wv;
                    }
                }
                if l + 1 < self.layers() {
                    for o in oi.iter_mut() {
                        *o = o.max(0.0); // ReLU
                    }
                }
            }
            acts.push(cur);
            cur = next;
            cur_w = fan_out;
        }
        (acts, cur)
    }
}

impl GradModel for Mlp {
    fn dim(&self) -> usize {
        self.layer_sizes().iter().sum()
    }

    fn loss_grad(&self, params: &[f32], batch: &Batch, grad: &mut [f32]) -> f64 {
        assert_eq!(params.len(), self.dim());
        assert_eq!(grad.len(), self.dim());
        let offs = self.layer_offsets();
        let b = batch.b;
        let classes = *self.widths.last().unwrap();
        let (acts, mut logits) = self.forward(params, batch);
        grad.iter_mut().for_each(|g| *g = 0.0);

        // Softmax + xent; logits becomes dL/dlogits.
        let mut loss = 0.0f64;
        let inv_b = 1.0 / b as f32;
        for i in 0..b {
            let row = &mut logits[i * classes..(i + 1) * classes];
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f64;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v as f64;
            }
            let y = batch.y[i] as usize;
            loss -= ((row[y] as f64 / sum).max(1e-30)).ln();
            for (c, v) in row.iter_mut().enumerate() {
                *v = ((*v as f64 / sum) as f32 - f32::from(c == y)) * inv_b;
            }
        }
        loss /= b as f64;

        // Backprop through layers (delta = dL/d pre-activation of layer l+1).
        let mut delta = logits;
        for l in (0..self.layers()).rev() {
            let (fan_in, fan_out) = (self.widths[l], self.widths[l + 1]);
            let input = &acts[l]; // b × fan_in (post-activation of prev layer)
            let w = &params[offs[l]..offs[l] + fan_in * fan_out];
            let (gw, gb) = grad[offs[l]..offs[l + 1]].split_at_mut(fan_in * fan_out);
            let mut prev_delta = if l > 0 { vec![0.0f32; b * fan_in] } else { Vec::new() };
            for i in 0..b {
                let di = &delta[i * fan_out..(i + 1) * fan_out];
                let xi = &input[i * fan_in..(i + 1) * fan_in];
                for (gbc, &dv) in gb.iter_mut().zip(di) {
                    *gbc += dv;
                }
                for (j, &xj) in xi.iter().enumerate() {
                    if xj != 0.0 {
                        let gwrow = &mut gw[j * fan_out..(j + 1) * fan_out];
                        for (g, &dv) in gwrow.iter_mut().zip(di) {
                            *g += xj * dv;
                        }
                    }
                }
                if l > 0 {
                    let pdi = &mut prev_delta[i * fan_in..(i + 1) * fan_in];
                    for (j, pd) in pdi.iter_mut().enumerate() {
                        if xi[j] > 0.0 {
                            // ReLU derivative via post-activation > 0.
                            let wrow = &w[j * fan_out..(j + 1) * fan_out];
                            let mut acc = 0.0f32;
                            for (&wv, &dv) in wrow.iter().zip(di) {
                                acc += wv * dv;
                            }
                            *pd = acc;
                        }
                    }
                }
            }
            delta = prev_delta;
        }
        loss
    }

    fn error_rate(&self, params: &[f32], batch: &Batch) -> f64 {
        self.topn_error_rate(params, batch, 1)
    }

    fn topn_error_rate(&self, params: &[f32], batch: &Batch, n: usize) -> f64 {
        let classes = *self.widths.last().unwrap();
        let (_, logits) = self.forward(params, batch);
        let mut wrong = 0usize;
        for i in 0..batch.b {
            let row = &logits[i * classes..(i + 1) * classes];
            let y = batch.y[i] as usize;
            let ly = row[y];
            // Tie-break by index (see SoftmaxRegression::topn_error_rate).
            let better = row
                .iter()
                .enumerate()
                .filter(|&(c, &l)| l > ly || (l == ly && c < y))
                .count();
            if better >= n {
                wrong += 1;
            }
        }
        wrong as f64 / batch.b as f64
    }

    fn name(&self) -> String {
        format!("mlp({:?})", self.widths)
    }

    fn as_sync(&self) -> Option<&(dyn GradModel + Sync)> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian_clusters;

    #[test]
    fn dim_matches_layout() {
        let m = Mlp::new(vec![8, 16, 4]);
        assert_eq!(m.dim(), (8 + 1) * 16 + (16 + 1) * 4);
        assert_eq!(m.layer_sizes(), vec![144, 68]);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let ds = gaussian_clusters(32, 6, 3, 1.5, 0.4, 21);
        let batch = ds.gather(&(0..12).collect::<Vec<_>>());
        let m = Mlp::new(vec![6, 10, 3]);
        let params = m.init_params(5);
        let coords: Vec<usize> = (0..m.dim()).step_by(11).collect();
        crate::grad::check_grad(&m, &params, &batch, &coords);
    }

    #[test]
    fn sgd_learns_clusters() {
        let ds = gaussian_clusters(512, 10, 4, 2.0, 0.4, 22);
        let m = Mlp::new(vec![10, 24, 4]);
        let mut params = m.init_params(3);
        let all: Vec<usize> = (0..ds.n).collect();
        let batch = ds.gather(&all);
        let mut g = vec![0.0f32; m.dim()];
        let l0 = m.loss(&params, &batch);
        for _ in 0..200 {
            m.loss_grad(&params, &batch, &mut g);
            for (p, gi) in params.iter_mut().zip(&g) {
                *p -= 0.3 * gi;
            }
        }
        let l1 = m.loss(&params, &batch);
        assert!(l1 < l0 * 0.3, "loss {l0} → {l1}");
        assert!(m.error_rate(&params, &batch) < 0.1);
    }

    #[test]
    fn init_deterministic() {
        let m = Mlp::new(vec![4, 8, 2]);
        assert_eq!(m.init_params(7), m.init_params(7));
        assert_ne!(m.init_params(7), m.init_params(8));
    }
}
