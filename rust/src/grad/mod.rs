//! Native gradient substrates (pure rust).
//!
//! These implement the paper's objective functions directly so the figure
//! harness can run large sweeps cheaply and so PJRT numerics can be
//! cross-checked. The PJRT-backed equivalents live in `runtime::`; both
//! implement `GradModel` and are interchangeable in the engine.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

pub mod mlp;
pub mod softmax;

pub use mlp::Mlp;
pub use softmax::SoftmaxRegression;

use crate::data::Batch;

/// A differentiable empirical-risk model over a flat parameter vector.
///
/// Not `Send`/`Sync`: the PJRT-backed implementation wraps an `Rc`-based
/// client. The threaded coordinator constructs one model per worker thread
/// via a `Send` factory instead of sharing one instance.
pub trait GradModel {
    /// Flat parameter dimension d.
    fn dim(&self) -> usize;

    /// Mean loss over the batch and its gradient (written into `grad`,
    /// which the caller provides zeroed or not — it is overwritten).
    fn loss_grad(&self, params: &[f32], batch: &Batch, grad: &mut [f32]) -> f64;

    /// Mean loss only (evaluation path).
    fn loss(&self, params: &[f32], batch: &Batch) -> f64 {
        let mut g = vec![0.0; self.dim()];
        self.loss_grad(params, batch, &mut g)
    }

    /// Classification error rate in [0,1] on a batch (1 − accuracy).
    fn error_rate(&self, params: &[f32], batch: &Batch) -> f64;

    /// Top-n error rate (paper reports top-1/top-5); default = top-1.
    fn topn_error_rate(&self, params: &[f32], batch: &Batch, _n: usize) -> f64 {
        self.error_rate(params, batch)
    }

    fn name(&self) -> String;

    /// A `Sync` view of this model, if the implementation supports sharing
    /// one instance across threads. The parallel engine
    /// (`TrainSpec::threads > 1`) requires it; models that cannot provide
    /// one (e.g. the `Rc`-based PJRT backend) return `None` — the default —
    /// and the engine falls back to the sequential path, which is
    /// bit-identical anyway. Pure-data models implement this as
    /// `Some(self)`.
    fn as_sync(&self) -> Option<&(dyn GradModel + Sync)> {
        None
    }
}

/// Numerical-gradient check helper shared by the model tests:
/// compares analytic ∂loss/∂θ_i with central differences on a few coords.
#[cfg(test)]
pub(crate) fn check_grad(model: &dyn GradModel, params: &[f32], batch: &Batch, coords: &[usize]) {
    let mut g = vec![0.0f32; model.dim()];
    model.loss_grad(params, batch, &mut g);
    let eps = 1e-3f32;
    for &i in coords {
        let mut p = params.to_vec();
        p[i] += eps;
        let lp = model.loss(&p, batch);
        p[i] -= 2.0 * eps;
        let lm = model.loss(&p, batch);
        let num = (lp - lm) / (2.0 * eps as f64);
        let ana = g[i] as f64;
        let denom = num.abs().max(ana.abs()).max(1e-4);
        assert!(
            (num - ana).abs() / denom < 2e-2,
            "{}: coord {i}: numeric {num} vs analytic {ana}",
            model.name()
        );
    }
}
