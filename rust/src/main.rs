//! `qsparse` — CLI entrypoint for the Qsparse-local-SGD reproduction.
//!
//! Subcommands:
//!   figure <id|all> [--out results] [--quick]     regenerate paper figures
//!   gamma-table [--d N] [--k N]                   Lemma 1–3 γ table
//!   train [options]                               one training run
//!   sim [options]                                 event-driven network sim
//!   specs <dump|validate> [--dir specs]           bundled experiment specs
//!   inspect [--artifacts DIR]                     list AOT artifacts
//!
//! `train` describes the run as one owned `ExperimentSpec` (spec::): flags
//! build or override it, `--spec FILE` loads it from JSON, `--dump-spec`
//! prints the resulting JSON instead of training — so any flag combination
//! round-trips through an artifact:
//!
//!   qsparse train --compressor topk:k=40 --h 8 --dump-spec > run.json
//!   qsparse train --spec run.json
//!
//! `train` options (all optional; flags override `--spec` fields):
//!   --spec FILE                   load an ExperimentSpec JSON
//!   --dump-spec                   print the spec JSON and exit
//!   --workload convex|nonconvex   native substrates (default convex)
//!   --pjrt NAME                   use the AOT artifact NAME instead
//!   --artifacts DIR               artifact dir (default artifacts)
//!   --label NAME                  run label (summaries/CSV naming)
//!   --compressor SPEC             e.g. topk:k=40 | qtopk:k=40,bits=4,scaled
//!   --down-compressor SPEC        downlink (master→worker) compressor;
//!                                 default identity = dense model broadcast
//!   --codec raw|rans              wire codec for encoded messages (rans =
//!                                 entropy-coded, same decoded payloads)
//!   --participation SPEC          full | bernoulli:P | fixed:M
//!   --agg-scale MODE              workers (1/R) | participants (1/|S_t|)
//!   --server-opt SPEC             avg | momentum:beta=B[,lr=L] |
//!                                 adam[:b1=..,b2=..,eps=..,lr=..]
//!   --h N                         sync period H (default 1; preserves the
//!                                 loaded spec's sync/async kind)
//!   --schedule SPEC               sync:H | async:H (replaces the schedule)
//!   --async                       Algorithm 2 random per-worker gaps
//!   --threaded                    threaded master/worker runtime (vs engine)
//!   --threads N                   engine worker-pool threads (0 = all cores)
//!   --faults SPEC                 deterministic fault injection, e.g.
//!                                 drop=0.1,corrupt=0.02,deadline=40000,seed=7
//!                                 (needs --threaded here, or `qsparse sim`)
//!   --checkpoint-every N          snapshot every N steps (sequential engine)
//!   --checkpoint-path FILE        snapshot file (default qsparse.ckpt)
//!   --resume FILE                 resume from a snapshot; the continued run
//!                                 is bit-identical to the uninterrupted one
//!   --steps N --workers N --batch N --eta F --momentum F --seed N
//!   --csv FILE                    write the metric history as CSV
//!   --json                        print a JSON summary
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

use qsparse::data::{gaussian_clusters_split, Sharding};
use qsparse::engine::{self, TrainSpec};
use qsparse::figures;
use qsparse::optim::{LrSchedule, ServerOptSpec};
use qsparse::protocol::AggScale;
use qsparse::runtime::PjrtRuntime;
use qsparse::spec::{CompressorSpec, ExperimentSpec, ScheduleSpec, Workload};
use qsparse::topology::ParticipationSpec;
use qsparse::util::json::Json;
use qsparse::util::stats::Stopwatch;
use std::collections::BTreeMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    match args.first().map(String::as_str) {
        Some("figure") => cmd_figure(&args[1..]),
        Some("gamma-table") => cmd_gamma(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("sim") => cmd_sim(&args[1..]),
        Some("specs") => cmd_specs(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand `{other}` (try `qsparse help`)"),
    }
}

const HELP: &str = "\
qsparse — Qsparse-local-SGD (NeurIPS 2019) reproduction

USAGE: qsparse <figure|gamma-table|train|sim|specs|inspect|help> [options]

  figure <id|all> [--out results] [--quick]
  gamma-table [--d 7850] [--k 40]
  train [--spec FILE] [--dump-spec] [--workload convex|nonconvex]
        [--pjrt NAME] [--label NAME] [--compressor SPEC]
        [--down-compressor SPEC] [--codec raw|rans]
        [--participation SPEC] [--agg-scale MODE]
        [--server-opt SPEC] [--h N] [--schedule SPEC] [--async] [--threaded]
        [--threads N] [--faults SPEC] [--checkpoint-every N]
        [--checkpoint-path FILE] [--resume FILE]
        [--steps N] [--workers N] [--batch N] [--eta F] [--momentum F]
        [--seed N] [--csv FILE] [--json]
  sim   [all `train` spec flags] [--ticks-per-sec N] [--compute-mean F]
        [--compute-sigma F] [--bw-mean F] [--bw-sigma F] [--latency N]
        [--straggler-prob F] [--straggler-mult F] [--churn-online N]
        [--churn-offline N] [--churn-sigma F] [--target-loss F]
        [--csv FILE] [--json]
  specs <dump|validate> [--dir specs]
  inspect [--artifacts DIR]

`train` is spec-first: flags assemble one owned ExperimentSpec, `--spec
FILE` loads it from JSON (remaining flags override individual fields), and
`--dump-spec` prints the spec instead of training, so every run is
reproducible from an artifact. `specs validate` parses, resolves and
smoke-runs every bundled figure spec under specs/.

Compressor SPECs: identity | topk:k=K | randk:k=K | qsgd:bits=B | sign |
  qtopk:k=K,bits=B[,scaled] | signtopk:k=K[,m=M]

--compressor is the uplink (worker→master). --down-compressor compresses the
downlink broadcast as an error-compensated model delta (server-side error
feedback); the default `identity` broadcasts the dense model.

--codec selects the wire codec for encoded messages in both directions:
`raw` (default, fixed-width fields) | `rans` (range-ANS entropy coding of
index gaps, values and quantization levels — decoded payloads are
bit-identical, only the wire length shrinks; dense identity broadcasts
always stay raw).

--participation samples which scheduled workers sync each round: `full`
(default) | `bernoulli:P` | `fixed:M`; --agg-scale picks `workers` (the
paper's 1/R) or `participants` (unbiased 1/|S_t|).

--server-opt applies a FedOpt-style optimizer to each round's aggregate on
the master before broadcast: `avg` (default, the paper's plain averaging,
bit-identical to the historical path) | `momentum:beta=B[,lr=L]` (server
heavy-ball; lr defaults to 1−beta, an EMA of round deltas) |
`adam[:b1=..,b2=..,eps=..,lr=..]` (FedAdam; defaults 0.9/0.99/1e-8/0.01).

--threads runs the engine's worker steps on a thread pool (0 = all cores).
Histories are bit-identical across thread counts; it is purely a speed knob.

--faults injects deterministic message faults from a seeded hash of
(worker, step, channel) — `drop=P,corrupt=P,dup=P,delay=P:TICKS,
drop-down=P,corrupt-down=P,crash=P,deadline=TICKS,seed=N`. The master
closes each round at the deadline (sim) or by accounting for every
expected participant (threaded); a worker whose update was lost re-absorbs
it into its error memory, so lost mass is delayed, not destroyed. Same
seed ⇒ same faults ⇒ bit-identical histories. `train` requires --threaded
(faults live on the channel fabric); `sim` injects on the virtual clock.

--checkpoint-every N writes a versioned binary snapshot (magic QSCK) of
every core, RNG stream and counter to --checkpoint-path each N steps;
--resume FILE continues from one, bit-identical to the uninterrupted run.
The header fingerprints the canonical spec JSON, so resuming under
different flags fails with a structured spec-mismatch error.

`sim` replays the same training arithmetic through a deterministic
discrete-event network simulator (virtual u64 tick clock): per-client
compute speed and link bandwidth are drawn from seeded lognormal-ish
distributions (--compute-sigma / --bw-sigma set the skew), transfer times
come from each message's actual wire bits under the configured codec, and
--straggler-prob/--straggler-mult and --churn-online/--churn-offline model
slowdowns and disconnect/reconnect churn. The learning history is
bit-identical to the engine whenever no worker misses a sync; the digest
adds simulated seconds, and the first crossing of --target-loss. The sim
scenario is part of the spec: `--dump-spec` embeds it as a \"sim\" object.
";

/// Tiny flag parser: positionals + `--key value` + boolean `--flag`s.
struct Flags {
    positional: Vec<String>,
    kv: BTreeMap<String, String>,
    bools: Vec<String>,
}

const BOOL_FLAGS: &[&str] = &["quick", "async", "threaded", "json", "dump-spec"];

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Flags> {
        let mut f = Flags { positional: Vec::new(), kv: BTreeMap::new(), bools: Vec::new() };
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    f.bools.push(key.to_string());
                } else {
                    i += 1;
                    let v = args
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{key} requires a value"))?;
                    f.kv.insert(key.to_string(), v.clone());
                }
            } else {
                f.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(f)
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

fn cmd_figure(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let which = f
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let out = f.get_or("out", "results");
    let quick = f.has("quick");
    let ids: Vec<String> = if which == "all" {
        figures::all_figure_ids().iter().map(|s| s.to_string()).collect()
    } else {
        vec![which]
    };
    for id in &ids {
        let spec = figures::figure_spec(id)
            .ok_or_else(|| anyhow::anyhow!("unknown figure `{id}`"))?;
        let sw = Stopwatch::start();
        let result = figures::run_figure(&spec, quick)?;
        result.write_csvs(&out)?;
        print!("{}", result.summary());
        println!("   ({} series, {:.1}s, CSVs in {out}/{id}/)\n", result.series.len(), sw.secs());
    }
    Ok(())
}

fn cmd_gamma(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let d: usize = f.parse_num("d", 7850)?;
    let k: usize = f.parse_num("k", 40)?;
    println!("γ table (Lemmas 1–3), d={d}, k={k}, Gaussian x:");
    println!("{:<28} {:>12} {:>22}", "operator", "γ(worst)", "measured E‖x−C‖²/‖x‖²");
    for (name, gamma, measured) in figures::gamma_table(d, k) {
        println!("{name:<28} {gamma:>12.6} {measured:>22.6}");
    }
    Ok(())
}

/// Assemble the run's `ExperimentSpec`: `--spec FILE` or workload defaults
/// as the base, then every explicitly-given flag overrides its field.
fn spec_from_flags(f: &Flags) -> anyhow::Result<ExperimentSpec> {
    let mut spec = match f.get("spec") {
        Some(path) => {
            anyhow::ensure!(
                f.get("workload").is_none(),
                "--workload cannot override --spec (the workload shapes every default; \
                 edit the file instead)"
            );
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--spec {path}: {e}"))?;
            ExperimentSpec::from_json_str(&text)
                .map_err(|e| anyhow::anyhow!("--spec {path}: {e}"))?
        }
        None => {
            let workload = Workload::parse(&f.get_or("workload", "convex"))?;
            let mut s = ExperimentSpec::for_workload(workload);
            // Historical `train` defaults (shorter than the figure horizon).
            s.steps = 500;
            s.eval_every = 25;
            s
        }
    };
    if let Some(label) = f.get("label") {
        spec.label = label.to_string();
    }
    spec.steps = f.parse_num("steps", spec.steps)?;
    spec.workers = f.parse_num("workers", spec.workers)?;
    spec.batch = f.parse_num("batch", spec.batch)?;
    spec.seed = f.parse_num("seed", spec.seed)?;
    spec.threads = f.parse_num("threads", spec.threads)?;
    spec.eval_every = f.parse_num("eval-every", spec.eval_every)?;
    spec.momentum = f.parse_num("momentum", spec.momentum)?;
    if let Some(e) = f.get("eta") {
        spec.lr = LrSchedule::Const { eta: e.parse().map_err(|e| anyhow::anyhow!("--eta: {e}"))? };
    }
    if let Some(c) = f.get("compressor") {
        spec.up = CompressorSpec::parse(c).map_err(|e| anyhow::anyhow!("--compressor: {e}"))?;
    }
    if let Some(c) = f.get("down-compressor") {
        spec.down =
            CompressorSpec::parse(c).map_err(|e| anyhow::anyhow!("--down-compressor: {e}"))?;
    }
    if let Some(c) = f.get("codec") {
        spec.codec = qsparse::compress::Codec::parse(c)
            .ok_or_else(|| anyhow::anyhow!("--codec: unknown codec `{c}` (raw | rans)"))?;
    }
    // `--schedule sync:H|async:H` replaces the whole schedule; `--h N`
    // changes only the period (preserving a loaded spec's sync/async kind);
    // `--async` switches the kind.
    if let Some(s) = f.get("schedule") {
        spec.schedule = ScheduleSpec::parse(s)?;
    }
    let h: usize = f.parse_num("h", spec.schedule.h())?;
    if f.has("async") {
        spec.schedule = ScheduleSpec::Async { h };
    } else if f.get("h").is_some() {
        spec.schedule = match spec.schedule {
            ScheduleSpec::Sync { .. } => ScheduleSpec::Sync { h },
            ScheduleSpec::Async { .. } => ScheduleSpec::Async { h },
        };
    }
    if let Some(p) = f.get("participation") {
        spec.participation = ParticipationSpec::parse(p)?;
    }
    if let Some(a) = f.get("agg-scale") {
        spec.agg_scale = AggScale::parse(a)?;
    }
    if let Some(s) = f.get("server-opt") {
        spec.server_opt = ServerOptSpec::parse(s)?;
    }
    if let Some(s) = f.get("faults") {
        spec.faults =
            Some(qsparse::FaultSpec::parse(s).map_err(|e| anyhow::anyhow!("--faults: {e}"))?);
    }
    spec.validate()?;
    Ok(spec)
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    if f.get("pjrt").is_some() {
        anyhow::ensure!(
            f.get("spec").is_none() && !f.has("dump-spec"),
            "--spec/--dump-spec cover the native workloads; PJRT artifacts describe their own \
             model geometry"
        );
        return cmd_train_pjrt(&f);
    }
    let spec = spec_from_flags(&f)?;
    if f.has("dump-spec") {
        print!("{}", spec.to_json().pretty());
        return Ok(());
    }
    let ckpt_every: usize = f.parse_num("checkpoint-every", 0)?;
    let resume_path = f.get("resume");
    let checkpointing = ckpt_every > 0 || resume_path.is_some();
    anyhow::ensure!(
        !(f.has("threaded") && checkpointing),
        "--checkpoint-every/--resume snapshot the sequential engine's state; \
         --threaded does not apply"
    );
    anyhow::ensure!(
        spec.faults.is_none() || f.has("threaded"),
        "fault injection on `train` needs a wire to inject into: add --threaded \
         (channel faults) or use `qsparse sim` (virtual-clock faults)"
    );
    let sw = Stopwatch::start();
    let resolved = spec.resolve(false)?;
    let history = if f.has("threaded") {
        resolved.run_threaded()?
    } else if checkpointing {
        run_checkpointed(&f, &resolved, ckpt_every, resume_path)?
    } else {
        resolved.run()
    };
    report_history(&f, &spec, &history, sw.secs())
}

/// The `--checkpoint-every` / `--resume` train path: the sequential engine
/// with snapshot hooks. The checkpoint header carries a fingerprint of the
/// canonical spec JSON, so resuming under a different flag set is a
/// structured `SpecMismatch`, never a silently hybrid run.
fn run_checkpointed(
    f: &Flags,
    resolved: &qsparse::spec::ResolvedExperiment,
    ckpt_every: usize,
    resume_path: Option<&str>,
) -> anyhow::Result<qsparse::History> {
    anyhow::ensure!(
        resolved.spec.threads <= 1,
        "checkpointing requires --threads 1: snapshots are taken by the \
         sequential engine (histories are bit-identical across thread counts, \
         so this only costs wall-clock)"
    );
    let fp = qsparse::protocol::checkpoint::spec_fingerprint(&resolved.spec.to_json().pretty());
    let resume_bytes = match resume_path {
        Some(p) => Some(std::fs::read(p).map_err(|e| anyhow::anyhow!("--resume {p}: {e}"))?),
        None => None,
    };
    let out = f.get_or("checkpoint-path", "qsparse.ckpt");
    let mut write_err: Option<anyhow::Error> = None;
    let history = engine::run_from_resumable(
        &resolved.train_spec(),
        resolved.workload.init.clone(),
        resume_bytes.as_deref(),
        fp,
        ckpt_every,
        &mut |step, bytes| {
            if write_err.is_none() {
                if let Err(e) = std::fs::write(&out, &bytes) {
                    write_err = Some(anyhow::anyhow!("--checkpoint-path {out} at step {step}: {e}"));
                } else {
                    eprintln!("checkpoint: step {step} → {out} ({} bytes)", bytes.len());
                }
            }
        },
    )?;
    match write_err {
        Some(e) => Err(e),
        None => Ok(history),
    }
}

/// `qsparse sim`: run the experiment through the deterministic
/// discrete-event network simulator (`sim::run_from`). The spec flags are
/// shared with `train`; the scenario flags override the spec's embedded
/// `"sim"` object (or `SimSpec::default()` when absent), so a scenario can
/// live in the JSON artifact or be sketched ad hoc on the command line.
fn cmd_sim(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    anyhow::ensure!(
        f.get("pjrt").is_none() && !f.has("threaded"),
        "`sim` drives the native workloads on a virtual clock; --pjrt and \
         --threaded do not apply"
    );
    let mut spec = spec_from_flags(&f)?;
    let mut sim = spec.sim.unwrap_or_default();
    sim.ticks_per_sec = f.parse_num("ticks-per-sec", sim.ticks_per_sec)?;
    sim.compute_mean = f.parse_num("compute-mean", sim.compute_mean)?;
    sim.compute_sigma = f.parse_num("compute-sigma", sim.compute_sigma)?;
    sim.bw_mean = f.parse_num("bw-mean", sim.bw_mean)?;
    sim.bw_sigma = f.parse_num("bw-sigma", sim.bw_sigma)?;
    sim.latency = f.parse_num("latency", sim.latency)?;
    sim.straggler_prob = f.parse_num("straggler-prob", sim.straggler_prob)?;
    sim.straggler_mult = f.parse_num("straggler-mult", sim.straggler_mult)?;
    sim.churn_online_mean = f.parse_num("churn-online", sim.churn_online_mean)?;
    sim.churn_offline_mean = f.parse_num("churn-offline", sim.churn_offline_mean)?;
    sim.churn_sigma = f.parse_num("churn-sigma", sim.churn_sigma)?;
    spec.sim = Some(sim);
    spec.validate()?;
    if f.has("dump-spec") {
        print!("{}", spec.to_json().pretty());
        return Ok(());
    }
    let target: Option<f64> = match f.get("target-loss") {
        None => None,
        Some(v) => Some(v.parse().map_err(|e| anyhow::anyhow!("--target-loss: {e}"))?),
    };
    let sw = Stopwatch::start();
    let resolved = spec.resolve(false)?;
    let result = resolved.run_sim();
    if let Some(csv) = f.get("csv") {
        std::fs::write(csv, result.history.to_csv())?;
    }
    let secs_to_target = target.map(|t| (t, result.secs_to_loss(t)));
    if f.has("json") {
        let part_spec = spec.participation.spec_str();
        let part = (spec.participation != ParticipationSpec::Full)
            .then(|| (part_spec.as_str(), spec.agg_scale.name()));
        let name = run_name(
            spec.up.as_str(),
            spec.down.as_str(),
            spec.down.is_identity(),
            part,
            &spec.server_opt,
        );
        let mut fields = vec![
            ("name", Json::str(name)),
            ("summary", result.history.summary_json(&spec.label, sw.secs())),
            ("sim_secs", Json::num(result.final_secs())),
            ("sim_events", Json::num(result.events as f64)),
        ];
        if let Some((t, hit)) = secs_to_target {
            fields.push(("target_loss", Json::num(t)));
            fields.push((
                "secs_to_target",
                hit.map_or(Json::Null, Json::num),
            ));
        }
        println!("{}", Json::obj(fields));
        return Ok(());
    }
    let last = result.history.points.last().unwrap();
    let target_str = match secs_to_target {
        None => String::new(),
        Some((t, Some(s))) => format!("  loss≤{t} at {s:.1} sim-s"),
        Some((t, None)) => format!("  loss≤{t} not reached"),
    };
    println!(
        "{}⇑ {}⇓ steps={} H={} workers={}  loss={:.4} test_err={:.4}  \
         bits_up={:.2}M bits_down={:.2}M  sim={:.1}s events={}{}  ({:.1}s wall)",
        spec.up.as_str(),
        spec.down.as_str(),
        last.step,
        spec.schedule.h(),
        spec.workers,
        last.train_loss,
        last.test_err,
        last.bits_up as f64 / 1e6,
        last.bits_down as f64 / 1e6,
        result.final_secs(),
        result.events,
        target_str,
        sw.secs()
    );
    Ok(())
}

/// Compose the run's summary name — `up[|down=..][|part=..|scale=..]
/// [|server=..]` — shared by the native and PJRT output paths so runs
/// differing in any knob stay distinguishable in both.
fn run_name(
    up: &str,
    down: &str,
    dense_down: bool,
    part: Option<(&str, &str)>,
    server: &ServerOptSpec,
) -> String {
    let mut name = if dense_down { up.to_string() } else { format!("{up}|down={down}") };
    if let Some((p, scale)) = part {
        name = format!("{name}|part={p}|scale={scale}");
    }
    if !server.is_avg() {
        name = format!("{name}|server={}", server.name());
    }
    name
}

/// Shared `train` output: CSV, JSON summary or the one-line digest.
fn report_history(
    f: &Flags,
    spec: &ExperimentSpec,
    history: &qsparse::History,
    secs: f64,
) -> anyhow::Result<()> {
    if let Some(csv) = f.get("csv") {
        std::fs::write(csv, history.to_csv())?;
    }
    let comp_spec = spec.up.as_str();
    let down_spec = spec.down.as_str();
    if f.has("json") {
        let part_spec = spec.participation.spec_str();
        let part = (spec.participation != ParticipationSpec::Full)
            .then(|| (part_spec.as_str(), spec.agg_scale.name()));
        let name = run_name(
            comp_spec,
            down_spec,
            spec.down.is_identity(),
            part,
            &spec.server_opt,
        );
        println!("{}", history.summary_json(&name, secs));
    } else {
        let last = history.points.last().unwrap();
        let part_str = if spec.participation == ParticipationSpec::Full {
            String::new()
        } else {
            format!(" part={}({})", spec.participation.spec_str(), spec.agg_scale.name())
        };
        let server_str = if spec.server_opt.is_avg() {
            String::new()
        } else {
            format!(" server={}", spec.server_opt.name())
        };
        println!(
            "{}⇑ {}⇓ steps={} H={} workers={}{}{}  loss={:.4} test_err={:.4}  \
             bits_up={:.2}M bits_down={:.2}M  ({:.1}s)",
            comp_spec,
            down_spec,
            last.step,
            spec.schedule.h(),
            spec.workers,
            part_str,
            server_str,
            last.train_loss,
            last.test_err,
            last.bits_up as f64 / 1e6,
            last.bits_down as f64 / 1e6,
            secs
        );
    }
    Ok(())
}

/// Legacy PJRT path: the model geometry comes from the AOT artifact, so the
/// run is assembled directly as a `TrainSpec` (native runs go through
/// `ExperimentSpec`).
fn cmd_train_pjrt(f: &Flags) -> anyhow::Result<()> {
    use qsparse::topology::{FixedPeriod, RandomGaps, SyncSchedule};
    let name = f.get("pjrt").expect("caller checked");
    let steps: usize = f.parse_num("steps", 500)?;
    let h: usize = f.parse_num("h", 1)?;
    let seed: u64 = f.parse_num("seed", figures::SEED)?;
    let comp_spec = f.get_or("compressor", "identity");
    let compressor = qsparse::compress::parse_spec(&comp_spec)?;
    let down_spec = f.get_or("down-compressor", "identity");
    let down_compressor = qsparse::compress::parse_spec(&down_spec)?;
    let codec_spec = f.get_or("codec", "raw");
    let codec = qsparse::compress::Codec::parse(&codec_spec)
        .ok_or_else(|| anyhow::anyhow!("--codec: unknown codec `{codec_spec}` (raw | rans)"))?;
    let sw = Stopwatch::start();

    anyhow::ensure!(
        !f.has("threaded"),
        "--threaded requires a Send model factory; native workloads only \
         (PJRT models are constructed per-thread in library/example code)"
    );
    let rt = PjrtRuntime::open(f.get_or("artifacts", "artifacts"))?;
    let model = rt.load_model(name)?;
    let entry = model.entry.clone();
    anyhow::ensure!(
        entry.kind != "lm",
        "LM training has a dedicated driver: examples/train_transformer.rs"
    );
    let n = 4000;
    let (train, test) =
        gaussian_clusters_split(n, n / 4, entry.feat, entry.classes, 0.3, 1.0, seed);
    let init = rt.load_init(name)?.unwrap_or_else(|| vec![0.0; entry.d]);
    let workers: usize = f.parse_num("workers", 4)?;
    let batch: usize = f.parse_num("batch", entry.batch)?;
    let lr = LrSchedule::Const { eta: f.parse_num("eta", 0.1)? };
    let momentum: f64 = f.parse_num("momentum", 0.0)?;

    let schedule: Box<dyn SyncSchedule> = if f.has("async") {
        Box::new(RandomGaps::generate(workers, h, steps, seed ^ 0x5eed))
    } else {
        Box::new(FixedPeriod::new(h))
    };
    let part_spec = f.get_or("participation", "full");
    let parsed_part = ParticipationSpec::parse(&part_spec)?;
    parsed_part.validate(workers)?;
    let participation = parsed_part.materialize(workers, steps, seed);
    let agg_scale = AggScale::parse(&f.get_or("agg-scale", "workers"))?;
    let server_opt = ServerOptSpec::parse(&f.get_or("server-opt", "avg"))?;

    let spec = TrainSpec {
        model: &model,
        train: &train,
        test: Some(&test),
        workers,
        batch,
        steps,
        lr,
        momentum,
        compressor: compressor.as_ref(),
        down_compressor: down_compressor.as_ref(),
        codec,
        schedule: schedule.as_ref(),
        participation: &participation,
        agg_scale,
        server_opt,
        sharding: Sharding::Iid,
        seed,
        eval_every: f.parse_num("eval-every", 25)?,
        eval_rows: 512,
        threads: f.parse_num("threads", 1)?,
    };
    let history = engine::run_from(&spec, init);

    if let Some(csv) = f.get("csv") {
        std::fs::write(csv, history.to_csv())?;
    }
    let part_str = if participation.is_full() {
        String::new()
    } else {
        format!(" part={part_spec}({})", agg_scale.name())
    };
    if f.has("json") {
        let part = (!participation.is_full()).then(|| (part_spec.as_str(), agg_scale.name()));
        let summary_name = run_name(
            &comp_spec,
            &down_spec,
            down_compressor.is_identity(),
            part,
            &server_opt,
        );
        println!("{}", history.summary_json(&summary_name, sw.secs()));
    } else {
        let last = history.points.last().unwrap();
        println!(
            "{}⇑ {}⇓ pjrt={} steps={} H={h} workers={}{}  loss={:.4} test_err={:.4}  \
             bits_up={:.2}M bits_down={:.2}M  ({:.1}s)",
            comp_spec,
            down_spec,
            name,
            last.step,
            workers,
            part_str,
            last.train_loss,
            last.test_err,
            last.bits_up as f64 / 1e6,
            last.bits_down as f64 / 1e6,
            sw.secs()
        );
    }
    Ok(())
}

/// `specs dump` regenerates the bundled figure specs; `specs validate`
/// parses, resolves and 10-step smoke-runs every bundled file and fails on
/// any drift from the in-code tables (schema, values or file set).
fn cmd_specs(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let action = f.positional.first().map(String::as_str).unwrap_or("dump");
    let dir = f.get_or("dir", "specs");
    match action {
        "dump" => {
            std::fs::create_dir_all(&dir)?;
            for id in figures::all_figure_ids() {
                let spec = figures::figure_spec(id).expect("listed id must have a spec");
                let path = format!("{dir}/{id}.json");
                std::fs::write(&path, spec.to_json().pretty())?;
                println!("wrote {path} ({} series)", spec.series.len());
            }
            Ok(())
        }
        "validate" => {
            let mut bundled_ids: Vec<String> = std::fs::read_dir(&dir)
                .map_err(|e| anyhow::anyhow!("{dir}: {e} (run `qsparse specs dump`?)"))?
                .filter_map(|entry| {
                    let name = entry.ok()?.file_name().into_string().ok()?;
                    name.strip_suffix(".json").map(str::to_string)
                })
                .collect();
            bundled_ids.sort();
            let mut known: Vec<String> =
                figures::all_figure_ids().iter().map(|s| s.to_string()).collect();
            known.sort();
            anyhow::ensure!(
                bundled_ids == known,
                "spec drift: {dir}/ holds {bundled_ids:?} but the figure registry knows \
                 {known:?} — run `qsparse specs dump`"
            );
            for id in figures::all_figure_ids() {
                let path = format!("{dir}/{id}.json");
                let text = std::fs::read_to_string(&path)?;
                let bundled = figures::FigureSpec::from_json_str(&text)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let code = figures::figure_spec(id).expect("listed id must have a spec");
                anyhow::ensure!(
                    bundled == code,
                    "{path} drifted from the in-code table — run `qsparse specs dump`"
                );
                let w = bundled.workload.instantiate(true);
                for s in &bundled.series {
                    let hist = figures::run_series(&w, s, 10)
                        .map_err(|e| anyhow::anyhow!("{id}/{}: {e}", s.label))?;
                    anyhow::ensure!(
                        hist.final_loss().is_finite(),
                        "{id}/{}: non-finite loss in the 10-step smoke run",
                        s.label
                    );
                }
                println!(
                    "{id}: ok ({} series, parse + resolve + 10-step smoke)",
                    code.series.len()
                );
            }
            Ok(())
        }
        other => anyhow::bail!("unknown specs action `{other}` (expected dump | validate)"),
    }
}

fn cmd_inspect(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let dir = f.get_or("artifacts", "artifacts");
    let rt = PjrtRuntime::open(&dir)?;
    println!("artifacts in {dir}:");
    for m in &rt.manifest().models {
        println!(
            "  {:<10} kind={:<8} d={:<9} batch={:<3} feat={:<5} classes={:<5} files=[{}, {}]{}",
            m.name,
            m.kind,
            m.d,
            m.batch,
            m.feat,
            m.classes,
            m.grad_file,
            m.eval_file,
            m.init_file.as_deref().map(|f| format!(" init={f}")).unwrap_or_default(),
        );
    }
    Ok(())
}
