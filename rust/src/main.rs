//! `qsparse` — CLI entrypoint for the Qsparse-local-SGD reproduction.
//!
//! Subcommands:
//!   figure <id|all> [--out results] [--quick]     regenerate paper figures
//!   gamma-table [--d N] [--k N]                   Lemma 1–3 γ table
//!   train [options]                               one training run
//!   inspect [--artifacts DIR]                     list AOT artifacts
//!
//! `train` options:
//!   --workload convex|nonconvex   native substrates (default convex)
//!   --pjrt NAME                   use the AOT artifact NAME instead
//!   --artifacts DIR               artifact dir (default artifacts)
//!   --compressor SPEC             e.g. topk:k=40 | qtopk:k=40,bits=4,scaled
//!   --down-compressor SPEC        downlink (master→worker) compressor;
//!                                 default identity = dense model broadcast
//!   --participation SPEC          sampled worker participation per sync
//!                                 round: full | bernoulli:P | fixed:M
//!   --agg-scale MODE              workers (paper 1/R) | participants
//!                                 (unbiased 1/|S_t| under sampling)
//!   --h N                         sync period H (default 1)
//!   --async                       Algorithm 2 random per-worker gaps
//!   --threaded                    threaded master/worker runtime (vs engine)
//!   --threads N                   engine worker-pool threads (1 sequential,
//!                                 0 = all cores; bit-identical either way)
//!   --steps N --workers N --batch N --eta F --momentum F --seed N
//!   --csv FILE                    write the metric history as CSV
//!   --json                        print a JSON summary

use qsparse::compress::parse_spec;
use qsparse::coordinator::{run_threaded, CoordinatorConfig};
use qsparse::data::{gaussian_clusters_split, Sharding};
use qsparse::engine::{self, TrainSpec};
use qsparse::figures;
use qsparse::grad::{GradModel, Mlp, SoftmaxRegression};
use qsparse::optim::LrSchedule;
use qsparse::protocol::AggScale;
use qsparse::runtime::PjrtRuntime;
use qsparse::topology::{FixedPeriod, ParticipationSpec, RandomGaps, SyncSchedule};
use qsparse::util::stats::Stopwatch;
use std::collections::HashMap;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    match args.first().map(String::as_str) {
        Some("figure") => cmd_figure(&args[1..]),
        Some("gamma-table") => cmd_gamma(&args[1..]),
        Some("train") => cmd_train(&args[1..]),
        Some("inspect") => cmd_inspect(&args[1..]),
        Some("help") | Some("--help") | Some("-h") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown subcommand `{other}` (try `qsparse help`)"),
    }
}

const HELP: &str = "\
qsparse — Qsparse-local-SGD (NeurIPS 2019) reproduction

USAGE: qsparse <figure|gamma-table|train|inspect|help> [options]

  figure <id|all> [--out results] [--quick]
  gamma-table [--d 7850] [--k 40]
  train [--workload convex|nonconvex] [--pjrt NAME] [--compressor SPEC]
        [--down-compressor SPEC] [--participation SPEC] [--agg-scale MODE]
        [--h N] [--async] [--threaded] [--threads N] [--steps N]
        [--workers N] [--batch N] [--eta F] [--momentum F] [--seed N]
        [--csv FILE] [--json]
  inspect [--artifacts DIR]

Compressor SPECs: identity | topk:k=K | randk:k=K | qsgd:bits=B | sign |
  qtopk:k=K,bits=B[,scaled] | signtopk:k=K[,m=M]

--compressor is the uplink (worker→master). --down-compressor compresses the
downlink broadcast as an error-compensated model delta (server-side error
feedback); the default `identity` broadcasts the dense model. bits_down in
CSV/JSON output is the exact encoded wire length either way.

--participation samples which scheduled workers sync each round:
`full` (default) | `bernoulli:P` (each worker independently w.p. P) |
`fixed:M` (exactly M workers, uniform without replacement). Sets are
materialized from the seed, so engine and threaded runs see the same S_t.
--agg-scale picks the fold scale: `workers` (the paper's 1/R, biased under
sampling) or `participants` (unbiased 1/|S_t|).
--threads runs the engine's worker steps on a thread pool (0 = all cores).
Histories are bit-identical across thread counts; it is purely a speed knob.
";

/// Tiny flag parser: positionals + `--key value` + boolean `--flag`s.
struct Flags {
    positional: Vec<String>,
    kv: HashMap<String, String>,
    bools: Vec<String>,
}

const BOOL_FLAGS: &[&str] = &["quick", "async", "threaded", "json"];

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Flags> {
        let mut f = Flags { positional: Vec::new(), kv: HashMap::new(), bools: Vec::new() };
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if let Some(key) = a.strip_prefix("--") {
                if BOOL_FLAGS.contains(&key) {
                    f.bools.push(key.to_string());
                } else {
                    i += 1;
                    let v = args
                        .get(i)
                        .ok_or_else(|| anyhow::anyhow!("--{key} requires a value"))?;
                    f.kv.insert(key.to_string(), v.clone());
                }
            } else {
                f.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(f)
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    fn parse_num<T: std::str::FromStr>(&self, key: &str, default: T) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }
}

fn cmd_figure(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let which = f
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let out = f.get_or("out", "results");
    let quick = f.has("quick");
    let ids: Vec<String> = if which == "all" {
        figures::all_figure_ids().iter().map(|s| s.to_string()).collect()
    } else {
        vec![which]
    };
    for id in &ids {
        let spec = figures::figure_spec(id)
            .ok_or_else(|| anyhow::anyhow!("unknown figure `{id}`"))?;
        let sw = Stopwatch::start();
        let result = figures::run_figure(&spec, quick)?;
        result.write_csvs(&out)?;
        print!("{}", result.summary());
        println!("   ({} series, {:.1}s, CSVs in {out}/{id}/)\n", result.series.len(), sw.secs());
    }
    Ok(())
}

fn cmd_gamma(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let d: usize = f.parse_num("d", 7850)?;
    let k: usize = f.parse_num("k", 40)?;
    println!("γ table (Lemmas 1–3), d={d}, k={k}, Gaussian x:");
    println!("{:<28} {:>12} {:>22}", "operator", "γ(worst)", "measured E‖x−C‖²/‖x‖²");
    for (name, gamma, measured) in figures::gamma_table(d, k) {
        println!("{name:<28} {gamma:>12.6} {measured:>22.6}");
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let steps: usize = f.parse_num("steps", 500)?;
    let h: usize = f.parse_num("h", 1)?;
    let seed: u64 = f.parse_num("seed", figures::SEED)?;
    let comp_spec = f.get_or("compressor", "identity");
    let compressor = parse_spec(&comp_spec)?;
    let down_spec = f.get_or("down-compressor", "identity");
    let down_compressor = parse_spec(&down_spec)?;
    let sw = Stopwatch::start();

    // Model + data + defaults per workload.
    type Setup = (
        Box<dyn GradModel>,
        qsparse::data::Dataset,
        qsparse::data::Dataset,
        Vec<f32>,
        usize,
        usize,
        LrSchedule,
        f64,
    );
    let (model, train, test, init, workers, batch, lr, momentum): Setup =
        if let Some(name) = f.get("pjrt") {
            let rt = PjrtRuntime::open(f.get_or("artifacts", "artifacts"))?;
            let model = rt.load_model(name)?;
            let entry = model.entry.clone();
            anyhow::ensure!(
                entry.kind != "lm",
                "LM training has a dedicated driver: examples/train_transformer.rs"
            );
            let n = 4000;
            let (train, test) =
                gaussian_clusters_split(n, n / 4, entry.feat, entry.classes, 0.3, 1.0, seed);
            let init = rt.load_init(name)?.unwrap_or_else(|| vec![0.0; entry.d]);
            let batch = entry.batch;
            (
                Box::new(model),
                train,
                test,
                init,
                4,
                batch,
                LrSchedule::Const { eta: 0.1 },
                0.0,
            )
        } else {
            match f.get_or("workload", "convex").as_str() {
                "convex" => {
                    let w = figures::Workload::ConvexSoftmax.instantiate(false);
                    (w.model, w.train, w.test, w.init, w.workers, w.batch, w.lr, w.momentum)
                }
                "nonconvex" => {
                    let w = figures::Workload::NonConvexMlp.instantiate(false);
                    (w.model, w.train, w.test, w.init, w.workers, w.batch, w.lr, w.momentum)
                }
                other => anyhow::bail!("unknown workload `{other}`"),
            }
        };
    let workers: usize = f.parse_num("workers", workers)?;
    let batch: usize = f.parse_num("batch", batch)?;
    let lr = match f.get("eta") {
        Some(e) => LrSchedule::Const { eta: e.parse()? },
        None => lr,
    };
    let momentum: f64 = f.parse_num("momentum", momentum)?;

    let schedule: Box<dyn SyncSchedule> = if f.has("async") {
        Box::new(RandomGaps::generate(workers, h, steps, seed ^ 0x5eed))
    } else {
        Box::new(FixedPeriod::new(h))
    };
    let part_spec = f.get_or("participation", "full");
    let parsed_part = ParticipationSpec::parse(&part_spec)?;
    parsed_part.validate(workers)?;
    let participation = parsed_part.materialize(workers, steps, seed);
    let agg_scale = AggScale::parse(&f.get_or("agg-scale", "workers"))?;

    let history = if f.has("threaded") {
        anyhow::ensure!(
            f.get("pjrt").is_none(),
            "--threaded requires a Send model factory; native workloads only \
             (PJRT models are constructed per-thread in library/example code)"
        );
        let is_convex = f.get_or("workload", "convex") == "convex";
        let (dim, classes, n) = (train.dim, train.classes, train.n);
        let factory = move || -> Box<dyn GradModel> {
            if is_convex {
                Box::new(SoftmaxRegression::new(dim, classes, 1.0 / n as f64))
            } else {
                Box::new(Mlp::new(vec![dim, 64, classes]))
            }
        };
        let mut cfg = CoordinatorConfig::new(Arc::from(compressor), Arc::from(schedule));
        cfg.down_compressor = Arc::from(down_compressor);
        cfg.participation = participation.clone();
        cfg.agg_scale = agg_scale;
        cfg.workers = workers;
        cfg.batch = batch;
        cfg.steps = steps;
        cfg.lr = lr;
        cfg.momentum = momentum;
        cfg.seed = seed;
        cfg.init = Some(init);
        run_threaded(&cfg, factory, Arc::new(train), Some(Arc::new(test)))?
    } else {
        let spec = TrainSpec {
            model: model.as_ref(),
            train: &train,
            test: Some(&test),
            workers,
            batch,
            steps,
            lr,
            momentum,
            compressor: compressor.as_ref(),
            down_compressor: down_compressor.as_ref(),
            schedule: schedule.as_ref(),
            participation: &participation,
            agg_scale,
            sharding: Sharding::Iid,
            seed,
            eval_every: f.parse_num("eval-every", 25)?,
            eval_rows: 512,
            threads: f.parse_num("threads", 1)?,
        };
        engine::run_from(&spec, init)
    };

    if let Some(csv) = f.get("csv") {
        std::fs::write(csv, history.to_csv())?;
    }
    if f.has("json") {
        let mut name = if down_spec == "identity" {
            comp_spec.clone()
        } else {
            format!("{comp_spec}|down={down_spec}")
        };
        if !participation.is_full() {
            name = format!("{name}|part={part_spec}|scale={}", agg_scale.name());
        }
        println!("{}", history.summary_json(&name, sw.secs()));
    } else {
        let last = history.points.last().unwrap();
        let part_str = if participation.is_full() {
            String::new()
        } else {
            format!(" part={part_spec}({})", agg_scale.name())
        };
        println!(
            "{}⇑ {}⇓ steps={} H={} workers={}{}  loss={:.4} test_err={:.4}  \
             bits_up={:.2}M bits_down={:.2}M  ({:.1}s)",
            comp_spec,
            down_spec,
            last.step,
            h,
            workers,
            part_str,
            last.train_loss,
            last.test_err,
            last.bits_up as f64 / 1e6,
            last.bits_down as f64 / 1e6,
            sw.secs()
        );
    }
    Ok(())
}

fn cmd_inspect(args: &[String]) -> anyhow::Result<()> {
    let f = Flags::parse(args)?;
    let dir = f.get_or("artifacts", "artifacts");
    let rt = PjrtRuntime::open(&dir)?;
    println!("artifacts in {dir}:");
    for m in &rt.manifest().models {
        println!(
            "  {:<10} kind={:<8} d={:<9} batch={:<3} feat={:<5} classes={:<5} files=[{}, {}]{}",
            m.name,
            m.kind,
            m.d,
            m.batch,
            m.feat,
            m.classes,
            m.grad_file,
            m.eval_file,
            m.init_file.as_deref().map(|f| format!(" init={f}")).unwrap_or_default(),
        );
    }
    Ok(())
}
