//! Deterministic fault injection: drops, corruption, duplication, delay
//! and worker crash-restarts, decided from a seed — never from wall time.
//!
//! The paper's error-feedback memory is already a ledger of everything the
//! compressor withheld; this module extends that ledger to everything the
//! *network* withheld. A worker whose update is dropped re-absorbs the
//! sent message into its memory (`WorkerCore::reabsorb_update`), so a lost
//! uplink is arithmetically identical to a coarser compressor for one
//! round — delayed, never destroyed.
//!
//! # Determinism
//!
//! Every fault decision is a pure function of `(fault seed, worker, step,
//! channel)`: [`FaultPlan::decide`] builds a fresh salted [`Pcg64`] per
//! decision and draws once. There is no shared RNG stream, so the decision
//! is independent of arrival order — the sim's virtual clock and the
//! threaded coordinator's real channels inject the *same* faults for the
//! same seed, and there is no injector state to checkpoint.
//!
//! # Semantics (shared by both substrates)
//!
//! * **drop (uplink)** — the encoded update never reaches the master; the
//!   round closes without it (deadline on the sim clock, count-based missed
//!   metas on the threaded path) and the worker re-absorbs the message into
//!   its error memory, then re-anchors (`local ← anchor`).
//! * **corrupt** — the wire bytes are mangled ([`FaultPlan::corrupt_bytes`]
//!   forces an undefined wire tag, so decoding *always* yields a structured
//!   [`DecodeError`](crate::compress::DecodeError)); the receiver logs and
//!   drops, and the sender compensates exactly as for a drop.
//! * **dup** — the update is delivered twice; per-(worker, step) dedup on
//!   the master makes the second copy a no-op.
//! * **delay** — delivery is deferred (extra virtual ticks on the sim; a
//!   reorder buffer on the threaded path). A delivery that misses its
//!   round's deadline degrades to a drop.
//! * **drop/corrupt (downlink)** — the broadcast for one worker is skipped
//!   before the master's downlink mirror advances, so the implicit
//!   downlink error feedback stays consistent; the worker re-anchors and
//!   continues from its stale model.
//! * **crash** — at a sync point the worker loses its volatile state
//!   (`WorkerCore::crash_restart`: error memory, optimizer velocity) and
//!   restarts from the last broadcast anchor.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

use crate::util::json::Json;
use crate::util::rng::Pcg64;

/// Salt for the fault-decision RNG keys (distinct from every other stream
/// salt in the crate: uplink 0xc0ffee, downlink 0xd05eed, participation
/// 0x5e7ec7, sim profile 0x513a11, straggler 0x57a616, churn 0xc6a12d, …).
const FAULT_RNG_SALT: u64 = 0xfa0175;

/// Per-channel key tags so uplink, downlink and crash decisions for the
/// same (worker, step) are independent draws.
const CH_UP: u64 = 0x75;
const CH_DOWN: u64 = 0xd0;
const CH_CRASH: u64 = 0xc4;

/// Fault scenario description — the `"faults"` object of an
/// `ExperimentSpec` JSON, or the `--faults` CLI grammar. `Default` is a
/// fault-free network (every probability 0, no deadline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Seed for the (stateless) fault-decision streams. Two runs with the
    /// same spec and the same fault seed inject identical faults.
    pub seed: u64,
    /// Per-update probability the uplink message is dropped in flight.
    pub drop_up: f64,
    /// Per-update probability the uplink wire bytes are corrupted.
    pub corrupt_up: f64,
    /// Per-update probability the uplink message is delivered twice.
    pub dup_up: f64,
    /// Per-update probability the uplink delivery is delayed (and thereby
    /// reordered against later senders).
    pub delay_up: f64,
    /// Maximum extra delivery delay in virtual ticks (uniform in
    /// [1, delay_ticks]); must be ≥ 1 when `delay_up > 0`.
    pub delay_ticks: u64,
    /// Per-broadcast probability a worker's downlink message is dropped.
    pub drop_down: f64,
    /// Per-broadcast probability a worker's downlink message is corrupted.
    pub corrupt_down: f64,
    /// Per-sync probability the worker crash-restarts at the sync point.
    pub crash: f64,
    /// Sim round deadline in virtual ticks: a round force-closes this many
    /// ticks after it opens, folding whatever arrived. 0 = barrier forever
    /// (requires `drop_up == 0` and `corrupt_up == 0`, or the sim would
    /// wait on a message that never comes).
    pub deadline_ticks: u64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            seed: 0,
            drop_up: 0.0,
            corrupt_up: 0.0,
            dup_up: 0.0,
            delay_up: 0.0,
            delay_ticks: 0,
            drop_down: 0.0,
            corrupt_down: 0.0,
            crash: 0.0,
            deadline_ticks: 0,
        }
    }
}

/// JSON field names (single source for the strict unknown-key check).
const FAULT_FIELDS: &[&str] = &[
    "seed",
    "drop_up",
    "corrupt_up",
    "dup_up",
    "delay_up",
    "delay_ticks",
    "drop_down",
    "corrupt_down",
    "crash",
    "deadline_ticks",
];

impl FaultSpec {
    /// True when any fault process can fire (the injector is constructed
    /// only then — fault-free runs take the exact pre-existing code paths).
    pub fn active(&self) -> bool {
        self.drop_up > 0.0
            || self.corrupt_up > 0.0
            || self.dup_up > 0.0
            || self.delay_up > 0.0
            || self.drop_down > 0.0
            || self.corrupt_down > 0.0
            || self.crash > 0.0
            || self.deadline_ticks > 0
    }

    /// Range-check the scenario (shared by spec validation and the CLI).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, p) in [
            ("drop_up", self.drop_up),
            ("corrupt_up", self.corrupt_up),
            ("dup_up", self.dup_up),
            ("delay_up", self.delay_up),
            ("drop_down", self.drop_down),
            ("corrupt_down", self.corrupt_down),
            ("crash", self.crash),
        ] {
            anyhow::ensure!(
                (0.0..=1.0).contains(&p),
                "faults: {name} must be in [0, 1], got {p}"
            );
        }
        anyhow::ensure!(
            self.drop_up + self.corrupt_up + self.dup_up + self.delay_up <= 1.0,
            "faults: uplink probabilities must sum to <= 1 (one fault per message)"
        );
        anyhow::ensure!(
            self.drop_down + self.corrupt_down <= 1.0,
            "faults: downlink probabilities must sum to <= 1"
        );
        if self.delay_up > 0.0 {
            anyhow::ensure!(
                self.delay_ticks >= 1,
                "faults: delay_up set but delay_ticks is 0 (no delay window)"
            );
        }
        if self.drop_up > 0.0 || self.corrupt_up > 0.0 {
            anyhow::ensure!(
                self.deadline_ticks >= 1,
                "faults: drop_up/corrupt_up need deadline_ticks >= 1 \
                 (a barriered round would wait forever on the lost update)"
            );
        }
        Ok(())
    }

    /// Emit the full scenario (every field, explicit) as a JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("drop_up", Json::num(self.drop_up)),
            ("corrupt_up", Json::num(self.corrupt_up)),
            ("dup_up", Json::num(self.dup_up)),
            ("delay_up", Json::num(self.delay_up)),
            ("delay_ticks", Json::num(self.delay_ticks as f64)),
            ("drop_down", Json::num(self.drop_down)),
            ("corrupt_down", Json::num(self.corrupt_down)),
            ("crash", Json::num(self.crash)),
            ("deadline_ticks", Json::num(self.deadline_ticks as f64)),
        ])
    }

    /// Parse a `"faults"` JSON object. Missing fields take their defaults;
    /// unknown fields are a hard error (same strictness as the enclosing
    /// `ExperimentSpec`). Ends with [`FaultSpec::validate`].
    pub fn from_json(j: &Json) -> anyhow::Result<FaultSpec> {
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("faults: expected a JSON object"))?;
        if let Some(unknown) = obj.keys().find(|k| !FAULT_FIELDS.contains(&k.as_str())) {
            anyhow::bail!("faults: unknown field `{unknown}`");
        }
        let f64_field = |key: &str, default: f64| -> anyhow::Result<f64> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("faults: field `{key}` must be a number")),
            }
        };
        let u64_field = |key: &str, default: u64| -> anyhow::Result<u64> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                    .map(|n| n as u64)
                    .ok_or_else(|| {
                        anyhow::anyhow!("faults: field `{key}` must be a non-negative integer")
                    }),
            }
        };
        let d = FaultSpec::default();
        let s = FaultSpec {
            seed: u64_field("seed", d.seed)?,
            drop_up: f64_field("drop_up", d.drop_up)?,
            corrupt_up: f64_field("corrupt_up", d.corrupt_up)?,
            dup_up: f64_field("dup_up", d.dup_up)?,
            delay_up: f64_field("delay_up", d.delay_up)?,
            delay_ticks: u64_field("delay_ticks", d.delay_ticks)?,
            drop_down: f64_field("drop_down", d.drop_down)?,
            corrupt_down: f64_field("corrupt_down", d.corrupt_down)?,
            crash: f64_field("crash", d.crash)?,
            deadline_ticks: u64_field("deadline_ticks", d.deadline_ticks)?,
        };
        s.validate()?;
        Ok(s)
    }

    /// Parse the `--faults` CLI grammar: comma-separated `key=value` pairs,
    /// e.g. `drop=0.1,corrupt=0.02,dup=0.05,delay=0.1:20000,drop-down=0.05,
    /// corrupt-down=0.01,crash=0.002,deadline=50000,seed=7`. Keys without a
    /// `-down` suffix refer to the uplink. `delay` takes `prob:max_ticks`.
    pub fn parse(text: &str) -> anyhow::Result<FaultSpec> {
        let mut s = FaultSpec::default();
        for part in text.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("faults: expected key=value, got `{part}`"))?;
            let prob = || -> anyhow::Result<f64> {
                val.parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("faults: `{key}` needs a number, got `{val}`"))
            };
            let int = || -> anyhow::Result<u64> {
                val.parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("faults: `{key}` needs an integer, got `{val}`"))
            };
            match key.trim() {
                "drop" => s.drop_up = prob()?,
                "corrupt" => s.corrupt_up = prob()?,
                "dup" => s.dup_up = prob()?,
                "delay" => {
                    let (p, ticks) = val.split_once(':').ok_or_else(|| {
                        anyhow::anyhow!("faults: `delay` takes prob:max_ticks, got `{val}`")
                    })?;
                    s.delay_up = p
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("faults: bad delay prob `{p}`"))?;
                    s.delay_ticks = ticks
                        .parse::<u64>()
                        .map_err(|_| anyhow::anyhow!("faults: bad delay ticks `{ticks}`"))?;
                }
                "drop-down" => s.drop_down = prob()?,
                "corrupt-down" => s.corrupt_down = prob()?,
                "crash" => s.crash = prob()?,
                "deadline" => s.deadline_ticks = int()?,
                "seed" => s.seed = int()?,
                other => anyhow::bail!(
                    "faults: unknown key `{other}` (known: drop, corrupt, dup, delay, \
                     drop-down, corrupt-down, crash, deadline, seed)"
                ),
            }
        }
        s.validate()?;
        Ok(s)
    }

    /// Render back to the CLI grammar (run names, logs).
    pub fn spec_str(&self) -> String {
        let mut parts = Vec::new();
        if self.drop_up > 0.0 {
            parts.push(format!("drop={}", self.drop_up));
        }
        if self.corrupt_up > 0.0 {
            parts.push(format!("corrupt={}", self.corrupt_up));
        }
        if self.dup_up > 0.0 {
            parts.push(format!("dup={}", self.dup_up));
        }
        if self.delay_up > 0.0 {
            parts.push(format!("delay={}:{}", self.delay_up, self.delay_ticks));
        }
        if self.drop_down > 0.0 {
            parts.push(format!("drop-down={}", self.drop_down));
        }
        if self.corrupt_down > 0.0 {
            parts.push(format!("corrupt-down={}", self.corrupt_down));
        }
        if self.crash > 0.0 {
            parts.push(format!("crash={}", self.crash));
        }
        if self.deadline_ticks > 0 {
            parts.push(format!("deadline={}", self.deadline_ticks));
        }
        parts.push(format!("seed={}", self.seed));
        parts.join(",")
    }
}

/// Which wire direction a decision is for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Channel {
    /// Worker → master update.
    Up,
    /// Master → worker broadcast.
    Down,
}

/// The injector's verdict for one message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Deliver normally.
    Deliver,
    /// The message never arrives.
    Drop,
    /// The wire bytes are mangled in flight (decode fails ⇒ logged drop).
    Corrupt,
    /// The message arrives twice.
    Duplicate,
    /// Delivery is deferred by the given extra virtual ticks (≥ 1).
    Delay(u64),
}

/// Stateless fault injector. Construct with [`FaultPlan::new`] — it
/// returns `None` for an inactive spec so fault-free runs keep the exact
/// pre-existing code paths (and their bit-exact histories).
#[derive(Clone, Copy, Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec) -> Option<FaultPlan> {
        spec.active().then_some(FaultPlan { spec })
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Sim round deadline (0 = barrier forever).
    pub fn deadline_ticks(&self) -> u64 {
        self.spec.deadline_ticks
    }

    /// One fresh decision stream per (worker, step, channel): the golden-
    /// ratio step mix gives distinct keys per step, the channel tag keeps
    /// up/down/crash draws independent, and `worker + 1` picks the stream
    /// (stream 0 stays free, matching the crate's other salted streams).
    fn rng(&self, worker: usize, step: usize, channel: u64) -> Pcg64 {
        let key = self.spec.seed
            ^ FAULT_RNG_SALT
            ^ (step as u64).wrapping_mul(0x9e3779b97f4a7c15)
            ^ channel;
        Pcg64::new(key, worker as u64 + 1)
    }

    /// Decide the fate of the message `worker` sends (or is sent) at
    /// global step `step`. Pure: same inputs ⇒ same action, on any
    /// substrate, in any arrival order.
    pub fn decide(&self, worker: usize, step: usize, channel: Channel) -> FaultAction {
        let s = &self.spec;
        match channel {
            Channel::Up => {
                if s.drop_up + s.corrupt_up + s.dup_up + s.delay_up <= 0.0 {
                    return FaultAction::Deliver;
                }
                let mut rng = self.rng(worker, step, CH_UP);
                let u = rng.f64();
                if u < s.drop_up {
                    FaultAction::Drop
                } else if u < s.drop_up + s.corrupt_up {
                    FaultAction::Corrupt
                } else if u < s.drop_up + s.corrupt_up + s.dup_up {
                    FaultAction::Duplicate
                } else if u < s.drop_up + s.corrupt_up + s.dup_up + s.delay_up {
                    FaultAction::Delay(rng.range_u64(1, s.delay_ticks.max(1)))
                } else {
                    FaultAction::Deliver
                }
            }
            Channel::Down => {
                if s.drop_down + s.corrupt_down <= 0.0 {
                    return FaultAction::Deliver;
                }
                let u = self.rng(worker, step, CH_DOWN).f64();
                if u < s.drop_down {
                    FaultAction::Drop
                } else if u < s.drop_down + s.corrupt_down {
                    FaultAction::Corrupt
                } else {
                    FaultAction::Deliver
                }
            }
        }
    }

    /// Does `worker` crash-restart at the sync point of `step`?
    pub fn crash_at(&self, worker: usize, step: usize) -> bool {
        self.spec.crash > 0.0 && self.rng(worker, step, CH_CRASH).f64() < self.spec.crash
    }

    /// Mangle encoded wire bytes so decoding *always* fails with a
    /// structured error: force the 3-bit wire tag (MSB-first in byte 0) to
    /// 7, which no codec defines — raw and rANS streams both reject it as
    /// `DecodeError::BadTag`. Deterministic, length-preserving.
    pub fn corrupt_bytes(bytes: &mut [u8]) {
        if let Some(b) = bytes.first_mut() {
            *b |= 0xE0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lossy() -> FaultSpec {
        FaultSpec {
            seed: 7,
            drop_up: 0.2,
            corrupt_up: 0.05,
            dup_up: 0.1,
            delay_up: 0.1,
            delay_ticks: 500,
            drop_down: 0.05,
            corrupt_down: 0.02,
            crash: 0.01,
            deadline_ticks: 50_000,
        }
    }

    #[test]
    fn default_is_inactive_and_roundtrips() {
        let s = FaultSpec::default();
        s.validate().unwrap();
        assert!(!s.active());
        assert!(FaultPlan::new(s).is_none());
        let back = FaultSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn nondefault_roundtrips_json_and_grammar() {
        let s = lossy();
        s.validate().unwrap();
        assert!(s.active());
        let text = s.to_json().pretty();
        let back = FaultSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
        let back = FaultSpec::parse(&s.spec_str()).unwrap();
        assert_eq!(back, s);
        let explicit = FaultSpec::parse(
            "drop=0.2,corrupt=0.05,dup=0.1,delay=0.1:500,drop-down=0.05,\
             corrupt-down=0.02,crash=0.01,deadline=50000,seed=7",
        )
        .unwrap();
        assert_eq!(explicit, s);
    }

    #[test]
    fn rejects_bad_ranges_and_unknown_keys() {
        assert!(FaultSpec::from_json(&Json::parse(r#"{"bogus": 1}"#).unwrap()).is_err());
        assert!(FaultSpec::from_json(&Json::parse(r#"{"drop_up": 1.5}"#).unwrap()).is_err());
        assert!(FaultSpec::from_json(&Json::parse(r#"{"delay_ticks": -1}"#).unwrap()).is_err());
        // delay without a window, drop without a deadline: config typos.
        assert!(FaultSpec::from_json(&Json::parse(r#"{"delay_up": 0.1}"#).unwrap()).is_err());
        assert!(FaultSpec::from_json(&Json::parse(r#"{"drop_up": 0.1}"#).unwrap()).is_err());
        assert!(FaultSpec::from_json(
            &Json::parse(r#"{"drop_up": 0.1, "deadline_ticks": 1000}"#).unwrap()
        )
        .is_ok());
        // Uplink fault probabilities must leave room for delivery decisions.
        assert!(FaultSpec::from_json(
            &Json::parse(r#"{"drop_up": 0.6, "dup_up": 0.6, "deadline_ticks": 1}"#).unwrap()
        )
        .is_err());
        assert!(FaultSpec::parse("drop=0.1").is_err());
        assert!(FaultSpec::parse("drop=0.1,deadline=1000").is_ok());
        assert!(FaultSpec::parse("warp=0.1").is_err());
        assert!(FaultSpec::parse("delay=0.1").is_err());
        assert!(FaultSpec::parse("drop=x,deadline=5").is_err());
    }

    #[test]
    fn decisions_are_deterministic_and_channel_separated() {
        let plan = FaultPlan::new(lossy()).unwrap();
        for worker in 0..8 {
            for step in (0..200).step_by(7) {
                let a = plan.decide(worker, step, Channel::Up);
                let b = plan.decide(worker, step, Channel::Up);
                assert_eq!(a, b, "uplink decision must be pure");
                assert_eq!(
                    plan.decide(worker, step, Channel::Down),
                    plan.decide(worker, step, Channel::Down)
                );
                assert_eq!(plan.crash_at(worker, step), plan.crash_at(worker, step));
            }
        }
        // A different fault seed must change at least one decision.
        let other = FaultPlan::new(FaultSpec { seed: 8, ..lossy() }).unwrap();
        let diverges = (0..8).any(|w| {
            (0..200).any(|t| plan.decide(w, t, Channel::Up) != other.decide(w, t, Channel::Up))
        });
        assert!(diverges, "fault seed must matter");
    }

    #[test]
    fn decision_rates_match_probabilities() {
        let plan = FaultPlan::new(lossy()).unwrap();
        let trials = 20_000usize;
        let mut counts = [0usize; 5]; // deliver, drop, corrupt, dup, delay
        for i in 0..trials {
            let idx = match plan.decide(i % 16, i / 16, Channel::Up) {
                FaultAction::Deliver => 0,
                FaultAction::Drop => 1,
                FaultAction::Corrupt => 2,
                FaultAction::Duplicate => 3,
                FaultAction::Delay(t) => {
                    assert!((1..=500).contains(&t));
                    4
                }
            };
            counts[idx] += 1;
        }
        let rate = |c: usize| c as f64 / trials as f64;
        assert!((rate(counts[1]) - 0.2).abs() < 0.02, "drop rate {}", rate(counts[1]));
        assert!((rate(counts[2]) - 0.05).abs() < 0.01, "corrupt rate {}", rate(counts[2]));
        assert!((rate(counts[3]) - 0.1).abs() < 0.015, "dup rate {}", rate(counts[3]));
        assert!((rate(counts[4]) - 0.1).abs() < 0.015, "delay rate {}", rate(counts[4]));
        assert!((rate(counts[0]) - 0.55).abs() < 0.03, "deliver rate {}", rate(counts[0]));
    }

    #[test]
    fn corrupt_bytes_forces_a_decode_error() {
        use crate::compress::{encode, parse_spec, MessageBuf};
        let op = parse_spec("topk:k=4").unwrap();
        let mut rng = Pcg64::seeded(13);
        let x: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) * 0.1).collect();
        let msg = op.compress(&x, &mut rng);
        let mut w = encode::BitWriter::new();
        encode::encode_into(&msg, &mut w);
        let bit_len = w.bit_len();
        let (mut bytes, _) = w.into_bytes();
        assert!(encode::decode(&bytes, bit_len).is_ok(), "sane stream must decode");
        FaultPlan::corrupt_bytes(&mut bytes);
        let mut buf = MessageBuf::new();
        let err = encode::decode_into(&bytes, bit_len, &mut buf);
        assert!(err.is_err(), "corrupted tag must be a structured decode error");
    }
}
