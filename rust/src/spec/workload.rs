//! The two native simulated workloads (moved here from `figures::` so the
//! owned [`crate::spec::ExperimentSpec`] can name them without a layering
//! cycle; `figures::` re-exports everything for backwards compatibility).
//!
//! * `ConvexSoftmax` — ℓ2-regularized softmax regression with the paper's
//!   MNIST geometry (d = 7850, R = 15, b = 8; §5.2) on synthetic clusters.
//! * `NonConvexMlp` — ReLU MLP with momentum 0.9 on local iterations,
//!   standing in for ResNet-50/ImageNet (§5.1; substitution DESIGN.md §6).
//!
//! [`Workload::defaults`] exposes the per-workload hyperparameters without
//! building any data — that is what `ExperimentSpec::for_workload` records
//! — while [`Workload::instantiate`] materializes model + datasets + init
//! (deterministically from [`SEED`]-derived constants, so every
//! instantiation of the same `(workload, quick)` pair is bit-identical).

use crate::data::{gaussian_clusters_split, Dataset};
use crate::grad::{GradModel, Mlp, SoftmaxRegression};
use crate::optim::LrSchedule;

/// Seed shared by all figures/workloads (NeurIPS 2019 submission deadline).
pub const SEED: u64 = 20190527;

/// The two simulated workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// d = 7850 softmax regression, R = 15, b = 8 (paper §5.2).
    ConvexSoftmax,
    /// MLP classifier with momentum, R = 8, b = 16 (stand-in for §5.1).
    NonConvexMlp,
}

/// Per-workload hyperparameter defaults — the values `ExperimentSpec`
/// records as concrete fields. Pure data; no datasets are built.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadDefaults {
    pub steps: usize,
    pub workers: usize,
    pub batch: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    /// Reference k for Top_k in this workload (paper: 40 convex, ~1% of d
    /// non-convex).
    pub k: usize,
    pub eval_every: usize,
}

/// Workload instantiation shared by all series of a figure (same data, same
/// eval subsets, same seed ⇒ curves are directly comparable).
pub struct WorkloadInstance {
    pub train: Dataset,
    pub test: Dataset,
    pub model: Box<dyn GradModel>,
    pub init: Vec<f32>,
    pub workers: usize,
    pub batch: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    /// Reference k for Top_k in this workload (paper: 40 convex, ~1k/tensor
    /// non-convex).
    pub k: usize,
    pub eval_every: usize,
}

impl Workload {
    /// Parse the spec token: `convex` | `nonconvex`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "convex" => Ok(Workload::ConvexSoftmax),
            "nonconvex" => Ok(Workload::NonConvexMlp),
            other => anyhow::bail!("unknown workload `{other}` (expected convex | nonconvex)"),
        }
    }

    /// Canonical spec token — `parse(spec_str(w)) == w`.
    pub fn spec_str(&self) -> &'static str {
        match self {
            Workload::ConvexSoftmax => "convex",
            Workload::NonConvexMlp => "nonconvex",
        }
    }

    /// The workload's hyperparameter defaults (no data is built). The
    /// numeric values are identical to the historical `instantiate` table,
    /// so specs recorded from these defaults reproduce the legacy figures
    /// bit for bit.
    pub fn defaults(&self) -> WorkloadDefaults {
        match self {
            Workload::ConvexSoftmax => {
                let d = (784 + 1) * 10;
                let k = 40; // paper §5.2.2
                let h_ref = 8usize;
                // η_t = ξ/(a+t), a = dH/k (paper §5.2.2), ξ so η_0 ≈ 1.2.
                let a = (d * h_ref / k) as f64;
                WorkloadDefaults {
                    steps: 1500,
                    workers: 15,
                    batch: 8,
                    lr: LrSchedule::InvTime { xi: 1.2 * a, a },
                    momentum: 0.0,
                    k,
                    eval_every: 25,
                }
            }
            Workload::NonConvexMlp => {
                let d = Mlp::new(vec![256, 64, 10]).dim();
                WorkloadDefaults {
                    steps: 800,
                    workers: 8,
                    batch: 16,
                    lr: LrSchedule::Const { eta: 0.08 },
                    momentum: 0.9,
                    k: d / 100, // ~1% like the paper's per-tensor min(d_t, 1000)
                    eval_every: 20,
                }
            }
        }
    }

    /// Build model + train/test data + init. Deterministic in
    /// `(self, quick)`: the data seeds are fixed constants, so repeated
    /// instantiations are bit-identical (figure series may therefore share
    /// one instance purely as a compute optimization).
    pub fn instantiate(self, quick: bool) -> WorkloadInstance {
        let dflt = self.defaults();
        match self {
            Workload::ConvexSoftmax => {
                let n = if quick { 1500 } else { 6000 };
                let dim = 784;
                let classes = 10;
                let (train, test) =
                    gaussian_clusters_split(n, n / 4, dim, classes, 0.12, 1.0, SEED);
                let model = SoftmaxRegression::new(dim, classes, 1.0 / n as f64);
                WorkloadInstance {
                    init: vec![0.0; model.dim()],
                    model: Box::new(model),
                    train,
                    test,
                    workers: dflt.workers,
                    batch: dflt.batch,
                    lr: dflt.lr,
                    momentum: dflt.momentum,
                    k: dflt.k,
                    eval_every: dflt.eval_every,
                }
            }
            Workload::NonConvexMlp => {
                let n = if quick { 1200 } else { 4000 };
                let dim = 256;
                let classes = 10;
                let widths = vec![dim, 64, classes];
                let (train, test) =
                    gaussian_clusters_split(n, n / 4, dim, classes, 0.22, 1.0, SEED ^ 2);
                let model = Mlp::new(widths);
                let init = model.init_params(SEED);
                WorkloadInstance {
                    init,
                    model: Box::new(model),
                    train,
                    test,
                    workers: dflt.workers,
                    batch: dflt.batch,
                    lr: dflt.lr,
                    momentum: dflt.momentum,
                    k: dflt.k,
                    eval_every: dflt.eval_every,
                }
            }
        }
    }

    /// A `Send + Clone` model factory over the given data geometry — what
    /// the threaded runtime needs (each worker thread constructs its own
    /// model). `n` is the training-set size (the convex model's ℓ2
    /// regularization is 1/n, matching `instantiate`).
    pub fn model_factory(
        self,
        dim: usize,
        classes: usize,
        n: usize,
    ) -> impl Fn() -> Box<dyn GradModel> + Send + Clone + 'static {
        move || -> Box<dyn GradModel> {
            match self {
                Workload::ConvexSoftmax => {
                    Box::new(SoftmaxRegression::new(dim, classes, 1.0 / n as f64))
                }
                Workload::NonConvexMlp => Box::new(Mlp::new(vec![dim, 64, classes])),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips() {
        for w in [Workload::ConvexSoftmax, Workload::NonConvexMlp] {
            assert_eq!(Workload::parse(w.spec_str()).unwrap(), w);
        }
        assert!(Workload::parse("resnet").is_err());
    }

    #[test]
    fn defaults_match_instantiate() {
        for w in [Workload::ConvexSoftmax, Workload::NonConvexMlp] {
            let d = w.defaults();
            let inst = w.instantiate(true);
            assert_eq!(d.workers, inst.workers);
            assert_eq!(d.batch, inst.batch);
            assert_eq!(d.lr, inst.lr);
            assert_eq!(d.momentum, inst.momentum);
            assert_eq!(d.k, inst.k);
            assert_eq!(d.eval_every, inst.eval_every);
            assert_eq!(inst.init.len(), inst.model.dim());
            assert!(inst.train.n > 0 && inst.test.n > 0);
        }
    }

    #[test]
    fn factory_models_match_instance_geometry() {
        for w in [Workload::ConvexSoftmax, Workload::NonConvexMlp] {
            let inst = w.instantiate(true);
            let factory = w.model_factory(inst.train.dim, inst.train.classes, inst.train.n);
            assert_eq!(factory().dim(), inst.model.dim());
        }
    }
}
