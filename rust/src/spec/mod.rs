//! The owned, serializable experiment description — the single place where
//! "describe a training run" lives.
//!
//! Historically three layers re-implemented this: `engine::TrainSpec<'a>`
//! (borrowed trait objects), the CLI flag parser in `main.rs`, and the
//! hardcoded figure tables in `figures::specs`. [`ExperimentSpec`] replaces
//! all three sources of truth with one plain-data struct that
//!
//! * round-trips through JSON (`to_json`/`from_json` over `util::json`,
//!   with unknown-field and bad-value errors — specs are artifacts, so a
//!   run is reproducible from a file: `qsparse train --spec FILE`, and
//!   `--dump-spec` emits the spec any flag combination describes);
//! * resolves every operator through one registry ([`ExperimentSpec::
//!   resolve`]): compressor spec strings via `compress::parse_spec`,
//!   schedules via `topology::{FixedPeriod, RandomGaps}` (same
//!   `seed ^ 0x5eed` salt as the historical call sites), participation via
//!   `ParticipationSpec::materialize`, the server optimizer via
//!   `optim::ServerOptSpec` — so new knobs are added in exactly one place;
//! * produces `TrainSpec<'a>` only as a short-lived borrowed view of a
//!   [`ResolvedExperiment`] ([`ResolvedExperiment::train_spec`]).
//!
//! Resolution is deterministic: the same spec (and `quick` flag) yields
//! bit-identical datasets, operators and RNG streams, hence bit-identical
//! `History` — the figure tables are `ExperimentSpec` values now (bundled
//! as JSON under `specs/`), asserted equal to the legacy hand-built runs.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

mod workload;

pub use workload::{Workload, WorkloadDefaults, WorkloadInstance, SEED};

use crate::compress::{parse_spec, Codec, Compressor};
use crate::coordinator::{run_threaded, CoordinatorConfig};
use crate::data::Sharding;
use crate::engine::{self, History, TrainSpec};
use crate::faults::FaultSpec;
use crate::optim::{LrSchedule, ServerOptSpec};
use crate::protocol::AggScale;
use crate::sim::SimSpec;
use crate::topology::{FixedPeriod, Participation, ParticipationSpec, RandomGaps, SyncSchedule};
use crate::util::json::Json;
use std::sync::Arc;

/// A validated compressor spec string (`compress::parse_spec` grammar),
/// kept verbatim so it serializes exactly as the user wrote it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompressorSpec(String);

impl CompressorSpec {
    /// Validate `spec` against the operator registry and wrap it.
    pub fn parse(spec: &str) -> anyhow::Result<Self> {
        parse_spec(spec)?;
        Ok(CompressorSpec(spec.to_string()))
    }

    /// The identity operator (dense payloads / dense broadcast).
    pub fn identity() -> Self {
        CompressorSpec("identity".to_string())
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }

    /// Build the operator. Infallible for specs constructed via `parse`,
    /// but kept fallible so `resolve()` reports corrupt hand-edited JSON.
    pub fn resolve(&self) -> anyhow::Result<Box<dyn Compressor>> {
        parse_spec(&self.0)
    }

    /// Does this spec name the identity operator (dense broadcast path)?
    pub fn is_identity(&self) -> bool {
        self.resolve().map(|c| c.is_identity()).unwrap_or(false)
    }
}

/// When (and how) workers synchronize: the paper's fixed period H
/// (Algorithm 1) or random per-worker gaps U[1, H] (Algorithm 2).
/// Spec grammar: `sync:H` | `async:H` (`sync` alone means H = 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScheduleSpec {
    Sync { h: usize },
    Async { h: usize },
}

impl ScheduleSpec {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (head, rest) = s.split_once(':').map_or((s, ""), |(h, r)| (h, r));
        let h: usize = if rest.is_empty() {
            1
        } else {
            rest.parse().map_err(|e| anyhow::anyhow!("schedule `{head}`: bad H: {e}"))?
        };
        anyhow::ensure!(h >= 1, "schedule `{head}`: H must be >= 1");
        match head {
            "sync" => Ok(ScheduleSpec::Sync { h }),
            "async" => Ok(ScheduleSpec::Async { h }),
            other => anyhow::bail!("unknown schedule `{other}` (expected sync:H | async:H)"),
        }
    }

    pub fn spec_str(&self) -> String {
        match self {
            ScheduleSpec::Sync { h } => format!("sync:{h}"),
            ScheduleSpec::Async { h } => format!("async:{h}"),
        }
    }

    pub fn h(&self) -> usize {
        match self {
            ScheduleSpec::Sync { h } | ScheduleSpec::Async { h } => *h,
        }
    }

    pub fn is_async(&self) -> bool {
        matches!(self, ScheduleSpec::Async { .. })
    }

    /// Build the schedule. `RandomGaps` is salted exactly as every
    /// historical call site (`seed ^ 0x5eed`), so seeded async runs are
    /// preserved across the spec redesign.
    pub fn materialize(&self, workers: usize, steps: usize, seed: u64) -> Box<dyn SyncSchedule> {
        match *self {
            ScheduleSpec::Sync { h } => Box::new(FixedPeriod::new(h)),
            ScheduleSpec::Async { h } => {
                Box::new(RandomGaps::generate(workers, h, steps, seed ^ 0x5eed))
            }
        }
    }
}

/// Owned, plain-data description of one training run. Every field is
/// concrete (no borrowed trait objects) and JSON-serializable; see the
/// module docs for the lifecycle (describe → serialize → resolve → run).
#[derive(Clone, Debug, PartialEq)]
pub struct ExperimentSpec {
    /// Series / run label (figure legends, CSV file names).
    pub label: String,
    /// Which model + data geometry to instantiate (`convex` | `nonconvex`).
    pub workload: Workload,
    /// Global-clock steps T.
    pub steps: usize,
    pub workers: usize,
    /// Per-worker minibatch size b.
    pub batch: usize,
    pub lr: LrSchedule,
    /// Momentum on the local iterations (paper §5.1.1); 0 disables.
    pub momentum: f64,
    /// Uplink (worker → master) compressor.
    pub up: CompressorSpec,
    /// Downlink (master → worker) compressor; `identity` = dense broadcast.
    pub down: CompressorSpec,
    pub schedule: ScheduleSpec,
    pub participation: ParticipationSpec,
    pub agg_scale: AggScale,
    /// Wire codec for encoded messages on both directions (`raw` | `rans`).
    /// Decoded payloads are bit-identical either way — `rans` only changes
    /// the wire length (and hence `bits_up`/`bits_down`), never the
    /// trajectory. Dense `identity` model broadcasts always stay raw.
    pub codec: Codec,
    /// FedOpt-style server optimizer (`avg` = the paper's plain averaging).
    pub server_opt: ServerOptSpec,
    pub sharding: Sharding,
    pub seed: u64,
    /// Network/compute scenario for the event-driven simulator
    /// (`qsparse sim`, `crate::sim`). `None` for engine/threaded runs; a
    /// simulator run of a `None` spec uses the degenerate default scenario.
    pub sim: Option<SimSpec>,
    /// Deterministic fault injection (drop/corrupt/duplicate/delay/crash,
    /// `crate::faults` grammar). Consumed by the simulator and the threaded
    /// runtime; `None` (the default) keeps the exact fault-free code paths.
    pub faults: Option<FaultSpec>,
    /// Engine worker-pool threads (wall-clock only; histories are
    /// bit-identical for every value). 0 = all cores.
    pub threads: usize,
    /// Metric grid: record every `eval_every` steps plus the final step.
    pub eval_every: usize,
    /// Rows subsampled for loss/error evaluation.
    pub eval_rows: usize,
}

/// The JSON field names of [`ExperimentSpec`], in emission order. Shared by
/// `to_json` and the unknown-field check in `from_json`.
const FIELDS: &[&str] = &[
    "label",
    "workload",
    "steps",
    "workers",
    "batch",
    "lr",
    "momentum",
    "up",
    "down",
    "schedule",
    "participation",
    "agg_scale",
    "codec",
    "server_opt",
    "sharding",
    "seed",
    "sim",
    "faults",
    "threads",
    "eval_every",
    "eval_rows",
];

impl ExperimentSpec {
    /// A spec pre-filled with `workload`'s defaults (the historical figure
    /// hyperparameters): identity compression both ways, H = 1 synchronous,
    /// full participation, plain averaging, seed [`SEED`].
    pub fn for_workload(workload: Workload) -> Self {
        let dflt = workload.defaults();
        ExperimentSpec {
            label: "run".to_string(),
            workload,
            steps: dflt.steps,
            workers: dflt.workers,
            batch: dflt.batch,
            lr: dflt.lr,
            momentum: dflt.momentum,
            up: CompressorSpec::identity(),
            down: CompressorSpec::identity(),
            schedule: ScheduleSpec::Sync { h: 1 },
            participation: ParticipationSpec::Full,
            agg_scale: AggScale::Workers,
            codec: Codec::Raw,
            server_opt: ServerOptSpec::Avg,
            sharding: Sharding::Iid,
            seed: SEED,
            sim: None,
            faults: None,
            threads: 1,
            eval_every: dflt.eval_every,
            eval_rows: 512,
        }
    }

    // -- builders (used by the static figure tables; panic on bad specs,
    //    which the figure tests exercise) ---------------------------------

    pub fn with_label(mut self, label: &str) -> Self {
        self.label = label.to_string();
        self
    }

    pub fn with_up(mut self, spec: &str) -> Self {
        self.up = CompressorSpec::parse(spec).expect("bad uplink compressor spec");
        self
    }

    pub fn with_down(mut self, spec: &str) -> Self {
        self.down = CompressorSpec::parse(spec).expect("bad downlink compressor spec");
        self
    }

    pub fn with_h(mut self, h: usize) -> Self {
        self.schedule = ScheduleSpec::Sync { h };
        self
    }

    pub fn asynchronous(mut self, h: usize) -> Self {
        self.schedule = ScheduleSpec::Async { h };
        self
    }

    pub fn with_participation(mut self, spec: &str, scale: AggScale) -> Self {
        self.participation =
            ParticipationSpec::parse(spec).expect("bad participation spec");
        self.agg_scale = scale;
        self
    }

    pub fn with_server_opt(mut self, spec: &str) -> Self {
        self.server_opt = ServerOptSpec::parse(spec).expect("bad server-opt spec");
        self
    }

    pub fn with_codec(mut self, codec: Codec) -> Self {
        self.codec = codec;
        self
    }

    pub fn with_steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Embed a simulator scenario (stragglers, bandwidth skew, churn) —
    /// consumed by `qsparse sim` / [`ResolvedExperiment::run_sim`], ignored
    /// by the engine and threaded substrates.
    pub fn with_sim(mut self, sim: SimSpec) -> Self {
        self.sim = Some(sim);
        self
    }

    /// Embed a fault-injection scenario (`crate::faults` CLI grammar) —
    /// consumed by the simulator and threaded substrates, ignored by the
    /// sequential engine (which has no wire to fault).
    pub fn with_faults(mut self, spec: &str) -> Self {
        self.faults = Some(FaultSpec::parse(spec).expect("bad fault spec"));
        self
    }

    // -- validation ---------------------------------------------------------

    /// Range-check every field (called by `from_json` and `resolve`, so a
    /// spec that reaches the engine is always well-formed).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.steps >= 1, "`steps` must be >= 1, got {}", self.steps);
        anyhow::ensure!(self.workers >= 1, "`workers` must be >= 1, got {}", self.workers);
        anyhow::ensure!(self.batch >= 1, "`batch` must be >= 1, got {}", self.batch);
        anyhow::ensure!(
            self.eval_every >= 1,
            "`eval_every` must be >= 1, got {}",
            self.eval_every
        );
        anyhow::ensure!(self.eval_rows >= 1, "`eval_rows` must be >= 1");
        anyhow::ensure!(
            self.schedule.h() >= 1,
            "`schedule` H must be >= 1, got {}",
            self.schedule.h()
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.momentum),
            "`momentum` must be in [0, 1), got {}",
            self.momentum
        );
        anyhow::ensure!(
            self.seed <= (1u64 << 53),
            "`seed` must be <= 2^53 (JSON numbers are f64), got {}",
            self.seed
        );
        self.up.resolve().map_err(|e| anyhow::anyhow!("`up`: {e}"))?;
        self.down.resolve().map_err(|e| anyhow::anyhow!("`down`: {e}"))?;
        self.server_opt.validate()?;
        self.participation.validate(self.workers)?;
        if let Some(sim) = &self.sim {
            sim.validate()?;
        }
        if let Some(faults) = &self.faults {
            faults.validate().map_err(|e| anyhow::anyhow!("`faults`: {e}"))?;
        }
        Ok(())
    }

    // -- JSON ---------------------------------------------------------------

    /// Serialize to a JSON object (all fields, canonical spellings).
    /// `from_json(to_json(s)) == s` — property-tested. The `codec` field is
    /// emitted only when it differs from the default `raw`, so every spec
    /// written before the codec existed serializes byte-identically.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("label", Json::str(self.label.as_str())),
            ("workload", Json::str(self.workload.spec_str())),
            ("steps", Json::from(self.steps)),
            ("workers", Json::from(self.workers)),
            ("batch", Json::from(self.batch)),
            ("lr", lr_to_json(&self.lr)),
            ("momentum", Json::num(self.momentum)),
            ("up", Json::str(self.up.as_str())),
            ("down", Json::str(self.down.as_str())),
            ("schedule", Json::str(self.schedule.spec_str())),
            ("participation", Json::str(self.participation.spec_str())),
            ("agg_scale", Json::str(self.agg_scale.spec_str())),
        ];
        if self.codec != Codec::Raw {
            fields.push(("codec", Json::str(self.codec.as_str())));
        }
        // Like `codec`: emitted only when set, so every spec written before
        // the simulator existed serializes byte-identically.
        if let Some(sim) = &self.sim {
            fields.push(("sim", sim.to_json()));
        }
        if let Some(faults) = &self.faults {
            fields.push(("faults", faults.to_json()));
        }
        fields.extend([
            ("server_opt", Json::str(self.server_opt.spec_str())),
            ("sharding", Json::str(self.sharding.spec_str())),
            ("seed", Json::from(self.seed)),
            ("threads", Json::from(self.threads)),
            ("eval_every", Json::from(self.eval_every)),
            ("eval_rows", Json::from(self.eval_rows)),
        ]);
        Json::obj(fields)
    }

    /// Deserialize. Missing fields take the workload defaults (so sparse
    /// hand-written specs work); unknown fields and out-of-range values are
    /// hard errors naming the offending field.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("experiment spec must be a JSON object"))?;
        for key in obj.keys() {
            anyhow::ensure!(
                FIELDS.contains(&key.as_str()),
                "unknown field `{key}` in experiment spec (known fields: {})",
                FIELDS.join(", ")
            );
        }
        let workload = match j.get("workload") {
            Json::Null => Workload::ConvexSoftmax,
            v => Workload::parse(str_field(v, "workload")?)?,
        };
        let mut s = ExperimentSpec::for_workload(workload);
        if let Some(v) = opt(j, "label") {
            s.label = str_field(v, "label")?.to_string();
        }
        if let Some(v) = opt(j, "steps") {
            s.steps = usize_field(v, "steps")?;
        }
        if let Some(v) = opt(j, "workers") {
            s.workers = usize_field(v, "workers")?;
        }
        if let Some(v) = opt(j, "batch") {
            s.batch = usize_field(v, "batch")?;
        }
        if let Some(v) = opt(j, "lr") {
            s.lr = lr_from_json(v)?;
        }
        if let Some(v) = opt(j, "momentum") {
            s.momentum = f64_field(v, "momentum")?;
        }
        if let Some(v) = opt(j, "up") {
            s.up = CompressorSpec::parse(str_field(v, "up")?)
                .map_err(|e| anyhow::anyhow!("`up`: {e}"))?;
        }
        if let Some(v) = opt(j, "down") {
            s.down = CompressorSpec::parse(str_field(v, "down")?)
                .map_err(|e| anyhow::anyhow!("`down`: {e}"))?;
        }
        if let Some(v) = opt(j, "schedule") {
            s.schedule = ScheduleSpec::parse(str_field(v, "schedule")?)?;
        }
        if let Some(v) = opt(j, "participation") {
            s.participation = ParticipationSpec::parse(str_field(v, "participation")?)?;
        }
        if let Some(v) = opt(j, "agg_scale") {
            s.agg_scale = AggScale::parse(str_field(v, "agg_scale")?)?;
        }
        if let Some(v) = opt(j, "codec") {
            let text = str_field(v, "codec")?;
            s.codec = Codec::parse(text)
                .ok_or_else(|| anyhow::anyhow!("`codec`: unknown codec `{text}` (raw | rans)"))?;
        }
        if let Some(v) = opt(j, "server_opt") {
            s.server_opt = ServerOptSpec::parse(str_field(v, "server_opt")?)?;
        }
        if let Some(v) = opt(j, "sharding") {
            s.sharding = Sharding::parse(str_field(v, "sharding")?)?;
        }
        if let Some(v) = opt(j, "seed") {
            s.seed = u64_field(v, "seed")?;
        }
        if let Some(v) = opt(j, "sim") {
            s.sim = Some(SimSpec::from_json(v).map_err(|e| anyhow::anyhow!("`sim`: {e}"))?);
        }
        if let Some(v) = opt(j, "faults") {
            s.faults =
                Some(FaultSpec::from_json(v).map_err(|e| anyhow::anyhow!("`faults`: {e}"))?);
        }
        if let Some(v) = opt(j, "threads") {
            s.threads = usize_field(v, "threads")?;
        }
        if let Some(v) = opt(j, "eval_every") {
            s.eval_every = usize_field(v, "eval_every")?;
        }
        if let Some(v) = opt(j, "eval_rows") {
            s.eval_rows = usize_field(v, "eval_rows")?;
        }
        s.validate()?;
        Ok(s)
    }

    pub fn from_json_str(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("experiment spec: {e}"))?;
        Self::from_json(&j)
    }

    // -- resolution ---------------------------------------------------------

    /// Build the concrete operators this spec names — the single registry
    /// point for every plug-in axis (compression, schedule, participation).
    /// `steps` governs the materialized horizons (schedules and participant
    /// sets), so the figure harness can shorten runs in quick mode without
    /// touching the stored spec.
    pub(crate) fn resolve_ops(&self, steps: usize) -> anyhow::Result<ResolvedOps> {
        let up = self.up.resolve().map_err(|e| anyhow::anyhow!("`up`: {e}"))?;
        let down = self.down.resolve().map_err(|e| anyhow::anyhow!("`down`: {e}"))?;
        let schedule = self.schedule.materialize(self.workers, steps, self.seed);
        self.participation.validate(self.workers)?;
        let participation = self.participation.materialize(self.workers, steps, self.seed);
        Ok(ResolvedOps { up, down, schedule, participation })
    }

    /// Resolve the whole spec: instantiate the workload (model + datasets +
    /// init; `quick` shrinks the synthetic data exactly as the figure
    /// harness's quick mode) and every trait object, in one place. The
    /// result owns everything a run needs; `TrainSpec` exists only as its
    /// short-lived borrowed view.
    pub fn resolve(&self, quick: bool) -> anyhow::Result<ResolvedExperiment> {
        self.validate()?;
        let workload = self.workload.instantiate(quick);
        let ops = self.resolve_ops(self.steps)?;
        Ok(ResolvedExperiment { spec: self.clone(), workload, ops })
    }
}

/// The trait objects a spec resolves to (one bundle per run).
pub(crate) struct ResolvedOps {
    pub up: Box<dyn Compressor>,
    pub down: Box<dyn Compressor>,
    pub schedule: Box<dyn SyncSchedule>,
    pub participation: Participation,
}

/// A fully resolved experiment: owned workload instance + owned operators.
/// Borrow a [`TrainSpec`] view via [`ResolvedExperiment::train_spec`] or
/// just call [`ResolvedExperiment::run`].
pub struct ResolvedExperiment {
    pub spec: ExperimentSpec,
    pub workload: WorkloadInstance,
    ops: ResolvedOps,
}

impl ResolvedExperiment {
    /// The short-lived borrowed view the engine consumes.
    pub fn train_spec(&self) -> TrainSpec<'_> {
        TrainSpec {
            model: self.workload.model.as_ref(),
            train: &self.workload.train,
            test: Some(&self.workload.test),
            workers: self.spec.workers,
            batch: self.spec.batch,
            steps: self.spec.steps,
            lr: self.spec.lr.clone(),
            momentum: self.spec.momentum,
            compressor: self.ops.up.as_ref(),
            down_compressor: self.ops.down.as_ref(),
            schedule: self.ops.schedule.as_ref(),
            participation: &self.ops.participation,
            agg_scale: self.spec.agg_scale,
            codec: self.spec.codec,
            server_opt: self.spec.server_opt,
            sharding: self.spec.sharding,
            seed: self.spec.seed,
            eval_every: self.spec.eval_every,
            eval_rows: self.spec.eval_rows,
            threads: self.spec.threads,
        }
    }

    /// Run on the deterministic engine (from the workload's init).
    pub fn run(&self) -> History {
        engine::run_from(&self.train_spec(), self.workload.init.clone())
    }

    /// Run on the event-driven network simulator (`crate::sim`), from the
    /// workload's init, under the spec's embedded scenario (or the
    /// degenerate default when none is embedded). The returned
    /// `SimResult::history` is bit-identical to [`ResolvedExperiment::run`]
    /// whenever churn skipped no sync.
    pub fn run_sim(&self) -> crate::sim::SimResult {
        let sim = self.spec.sim.unwrap_or_default();
        crate::sim::run_from_faulty(
            &self.train_spec(),
            &sim,
            self.spec.faults.as_ref(),
            self.workload.init.clone(),
        )
    }

    /// Run on the threaded master/worker runtime (consumes the resolution:
    /// datasets move into `Arc`s, operators into the config). Native
    /// workloads only — the model factory is derived from the workload.
    pub fn run_threaded(self) -> anyhow::Result<History> {
        let ResolvedExperiment { spec, workload, ops } = self;
        let factory = spec.workload.model_factory(
            workload.train.dim,
            workload.train.classes,
            workload.train.n,
        );
        let mut cfg = CoordinatorConfig::new(Arc::from(ops.up), Arc::from(ops.schedule));
        cfg.down_compressor = Arc::from(ops.down);
        cfg.participation = ops.participation;
        cfg.agg_scale = spec.agg_scale;
        cfg.codec = spec.codec;
        cfg.server_opt = spec.server_opt;
        cfg.workers = spec.workers;
        cfg.batch = spec.batch;
        cfg.steps = spec.steps;
        cfg.lr = spec.lr.clone();
        cfg.momentum = spec.momentum;
        cfg.sharding = spec.sharding;
        cfg.seed = spec.seed;
        cfg.eval_every = spec.eval_every;
        cfg.eval_rows = spec.eval_rows;
        cfg.init = Some(workload.init);
        cfg.faults = spec.faults;
        run_threaded(&cfg, factory, Arc::new(workload.train), Some(Arc::new(workload.test)))
    }
}

// -- JSON field helpers -----------------------------------------------------

/// `Some(value)` for present keys, `None` for absent ones (obj lookup
/// returns `Null` for both an explicit `null` and a missing key; treating
/// explicit `null` as "use the default" is fine here).
fn opt<'a>(j: &'a Json, key: &str) -> Option<&'a Json> {
    match j.get(key) {
        Json::Null => None,
        v => Some(v),
    }
}

fn str_field<'a>(v: &'a Json, key: &str) -> anyhow::Result<&'a str> {
    v.as_str().ok_or_else(|| anyhow::anyhow!("field `{key}` must be a string"))
}

fn f64_field(v: &Json, key: &str) -> anyhow::Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("field `{key}` must be a number"))
}

fn usize_field(v: &Json, key: &str) -> anyhow::Result<usize> {
    v.as_usize()
        .ok_or_else(|| anyhow::anyhow!("field `{key}` must be a non-negative integer"))
}

fn u64_field(v: &Json, key: &str) -> anyhow::Result<u64> {
    let n = f64_field(v, key)?;
    anyhow::ensure!(
        n >= 0.0 && n.fract() == 0.0 && n <= (1u64 << 53) as f64,
        "field `{key}` must be a non-negative integer <= 2^53"
    );
    Ok(n as u64)
}

/// Learning-rate schedule codec: `{"kind": "const", "eta": ..}` |
/// `{"kind": "invtime", "xi": .., "a": ..}` |
/// `{"kind": "warmup", "peak": .., "warmup": .., "milestones": [..],
///   "decay": ..}`.
fn lr_to_json(lr: &LrSchedule) -> Json {
    match lr {
        LrSchedule::Const { eta } => {
            Json::obj(vec![("kind", Json::str("const")), ("eta", Json::num(*eta))])
        }
        LrSchedule::InvTime { xi, a } => Json::obj(vec![
            ("kind", Json::str("invtime")),
            ("xi", Json::num(*xi)),
            ("a", Json::num(*a)),
        ]),
        LrSchedule::WarmupPiecewise { peak, warmup, milestones, decay } => Json::obj(vec![
            ("kind", Json::str("warmup")),
            ("peak", Json::num(*peak)),
            ("warmup", Json::from(*warmup)),
            ("milestones", Json::arr(milestones.iter().map(|&m| Json::from(m)))),
            ("decay", Json::num(*decay)),
        ]),
    }
}

fn lr_from_json(v: &Json) -> anyhow::Result<LrSchedule> {
    let kind = str_field(v.get("kind"), "lr.kind")
        .map_err(|_| anyhow::anyhow!("field `lr` must be an object with a string `kind`"))?;
    match kind {
        "const" => Ok(LrSchedule::Const { eta: f64_field(v.get("eta"), "lr.eta")? }),
        "invtime" => Ok(LrSchedule::InvTime {
            xi: f64_field(v.get("xi"), "lr.xi")?,
            a: f64_field(v.get("a"), "lr.a")?,
        }),
        "warmup" => {
            let milestones = v
                .get("milestones")
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("field `lr.milestones` must be an array"))?
                .iter()
                .map(|m| usize_field(m, "lr.milestones[..]"))
                .collect::<anyhow::Result<Vec<_>>>()?;
            Ok(LrSchedule::WarmupPiecewise {
                peak: f64_field(v.get("peak"), "lr.peak")?,
                warmup: usize_field(v.get("warmup"), "lr.warmup")?,
                milestones,
                decay: f64_field(v.get("decay"), "lr.decay")?,
            })
        }
        other => anyhow::bail!("unknown lr kind `{other}` (expected const | invtime | warmup)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_workload_roundtrips_through_json() {
        for w in [Workload::ConvexSoftmax, Workload::NonConvexMlp] {
            let s = ExperimentSpec::for_workload(w);
            let j = s.to_json();
            let back = ExperimentSpec::from_json(&j).unwrap();
            assert_eq!(back, s);
            // And through the textual form (compact and pretty).
            assert_eq!(ExperimentSpec::from_json_str(&j.to_string()).unwrap(), s);
            assert_eq!(ExperimentSpec::from_json_str(&j.pretty()).unwrap(), s);
        }
    }

    #[test]
    fn builders_compose_and_roundtrip() {
        let s = ExperimentSpec::for_workload(Workload::ConvexSoftmax)
            .with_label("QTopK-bidir_mom")
            .with_up("qtopk:k=40,bits=4,scaled")
            .with_down("qtopk:k=400,bits=4")
            .with_h(4)
            .with_participation("bernoulli:0.5", AggScale::Participants)
            .with_server_opt("momentum:beta=0.9,lr=0.1")
            .with_codec(Codec::Rans)
            .with_steps(321);
        assert_eq!(ExperimentSpec::from_json(&s.to_json()).unwrap(), s);
        assert_eq!(s.schedule, ScheduleSpec::Sync { h: 4 });
        assert_eq!(s.server_opt, ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 });
        assert_eq!(s.codec, Codec::Rans);
    }

    #[test]
    fn codec_json_roundtrip_and_default_omission() {
        let s = ExperimentSpec::for_workload(Workload::ConvexSoftmax);
        // Default raw codec is not serialized, keeping pre-codec specs
        // byte-stable; absent field deserializes to raw.
        assert!(!s.to_json().to_string().contains("codec"));
        assert_eq!(ExperimentSpec::from_json(&s.to_json()).unwrap().codec, Codec::Raw);
        let s = s.with_codec(Codec::Rans);
        let j = s.to_json();
        assert!(j.to_string().contains("\"codec\""));
        assert_eq!(ExperimentSpec::from_json(&j).unwrap(), s);
        let err = ExperimentSpec::from_json_str(r#"{"codec": "zstd"}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("codec"), "{err}");
    }

    #[test]
    fn sim_json_roundtrip_and_default_omission() {
        // Like `codec`: no embedded scenario ⇒ no `sim` key, so pre-sim
        // specs stay byte-stable; absent field deserializes to None.
        let s = ExperimentSpec::for_workload(Workload::ConvexSoftmax);
        assert!(!s.to_json().to_string().contains("\"sim\""));
        assert_eq!(ExperimentSpec::from_json(&s.to_json()).unwrap().sim, None);
        let s = s.with_sim(SimSpec {
            compute_sigma: 0.8,
            straggler_prob: 0.05,
            straggler_mult: 8.0,
            ..SimSpec::default()
        });
        let j = s.to_json();
        assert!(j.to_string().contains("\"sim\""));
        assert_eq!(ExperimentSpec::from_json(&j).unwrap(), s);
        // Errors inside the scenario are named (prefixed) errors.
        let err = ExperimentSpec::from_json_str(r#"{"sim": {"straggler_prob": 2.0}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("straggler_prob"), "{err}");
        let err = ExperimentSpec::from_json_str(r#"{"sim": {"bogus_knob": 1}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("bogus_knob"), "{err}");
    }

    #[test]
    fn faults_json_roundtrip_and_default_omission() {
        // Like `sim`: no fault scenario ⇒ no `faults` key, so pre-fault
        // specs stay byte-stable; absent field deserializes to None.
        let s = ExperimentSpec::for_workload(Workload::ConvexSoftmax);
        assert!(!s.to_json().to_string().contains("\"faults\""));
        assert_eq!(ExperimentSpec::from_json(&s.to_json()).unwrap().faults, None);
        let s = s.with_faults("drop=0.1,corrupt=0.02,delay=0.05:20000,deadline=40000,seed=9");
        let j = s.to_json();
        assert!(j.to_string().contains("\"faults\""));
        assert_eq!(ExperimentSpec::from_json(&j).unwrap(), s);
        // Errors inside the scenario are named errors, not panics.
        let err = ExperimentSpec::from_json_str(r#"{"faults": {"drop_up": 2.0}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("drop_up"), "{err}");
        let err = ExperimentSpec::from_json_str(r#"{"faults": {"bogus": 1}}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("bogus"), "{err}");
    }

    #[test]
    fn sparse_json_takes_workload_defaults() {
        let s = ExperimentSpec::from_json_str(
            r#"{"workload": "nonconvex", "up": "topk:k=170", "steps": 99}"#,
        )
        .unwrap();
        let dflt = Workload::NonConvexMlp.defaults();
        assert_eq!(s.steps, 99);
        assert_eq!(s.workers, dflt.workers);
        assert_eq!(s.lr, dflt.lr);
        assert_eq!(s.up.as_str(), "topk:k=170");
        assert_eq!(s.down.as_str(), "identity");
    }

    #[test]
    fn unknown_field_and_bad_values_are_named_errors() {
        let err = ExperimentSpec::from_json_str(r#"{"workload": "convex", "stepz": 5}"#)
            .unwrap_err()
            .to_string();
        assert!(err.contains("stepz"), "{err}");
        for (json, needle) in [
            (r#"{"steps": 0}"#, "steps"),
            (r#"{"workers": 0}"#, "workers"),
            (r#"{"momentum": 1.5}"#, "momentum"),
            (r#"{"up": "bogus:k=1"}"#, "up"),
            (r#"{"schedule": "sometimes:3"}"#, "schedule"),
            (r#"{"lr": {"kind": "cosine"}}"#, "lr"),
            (r#"{"server_opt": "momentum:beta=2"}"#, "beta"),
            (r#"{"seed": 1.5}"#, "seed"),
            (r#"{"participation": "fixed:99"}"#, "fixed"),
        ] {
            let err = ExperimentSpec::from_json_str(json).unwrap_err().to_string();
            assert!(err.contains(needle), "{json}: {err}");
        }
    }

    #[test]
    fn lr_codec_covers_all_variants() {
        for lr in [
            LrSchedule::Const { eta: 0.25 },
            LrSchedule::InvTime { xi: 1884.0, a: 1570.0 },
            LrSchedule::WarmupPiecewise {
                peak: 1.5,
                warmup: 10,
                milestones: vec![30, 60],
                decay: 0.1,
            },
        ] {
            assert_eq!(lr_from_json(&lr_to_json(&lr)).unwrap(), lr);
        }
    }

    #[test]
    fn resolve_runs_and_matches_handbuilt_trainspec() {
        // The resolved view must reproduce a hand-built TrainSpec run
        // bit for bit (same ops, same salts, same horizons).
        let spec = ExperimentSpec::for_workload(Workload::ConvexSoftmax)
            .with_up("topk:k=40")
            .with_h(4)
            .with_steps(30);
        let resolved = spec.resolve(true).unwrap();
        let h_spec = resolved.run();

        let w = Workload::ConvexSoftmax.instantiate(true);
        let up = crate::compress::parse_spec("topk:k=40").unwrap();
        let down = crate::compress::parse_spec("identity").unwrap();
        let sched = FixedPeriod::new(4);
        let part = ParticipationSpec::Full.materialize(w.workers, 30, SEED);
        let hand = TrainSpec {
            model: w.model.as_ref(),
            train: &w.train,
            test: Some(&w.test),
            workers: w.workers,
            batch: w.batch,
            steps: 30,
            lr: w.lr.clone(),
            momentum: w.momentum,
            compressor: up.as_ref(),
            down_compressor: down.as_ref(),
            schedule: &sched,
            participation: &part,
            agg_scale: AggScale::Workers,
            codec: Codec::Raw,
            server_opt: ServerOptSpec::Avg,
            sharding: Sharding::Iid,
            seed: SEED,
            eval_every: w.eval_every,
            eval_rows: 512,
            threads: 1,
        };
        let h_hand = engine::run_from(&hand, w.init.clone());
        assert_eq!(h_spec.final_params, h_hand.final_params);
        for (a, b) in h_spec.points.iter().zip(&h_hand.points) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.bits_up, b.bits_up);
            assert_eq!(a.bits_down, b.bits_down);
        }
    }

    #[test]
    fn malformed_spec_json_is_an_error_not_a_panic() {
        // Regression: a malformed numeric literal in a spec file used to be
        // able to reach a `from_utf8(..).unwrap()` inside the JSON number
        // parser. Every corrupt spec must surface as `Err` from the public
        // entry point.
        for bad in [
            r#"{"workload": "convex-softmax", "steps": -}"#,
            r#"{"workload": "convex-softmax", "lr": 1e}"#,
            r#"{"workload": "convex-softmax", "lr": 0.1.2}"#,
            r#"{"workload": "convex-softmax", "steps": +5}"#,
            r#"{"workload""#,
        ] {
            let r = ExperimentSpec::from_json_str(bad);
            assert!(r.is_err(), "accepted malformed spec {bad:?}");
        }
    }

    #[test]
    fn resolve_rejects_invalid_specs() {
        let mut s = ExperimentSpec::for_workload(Workload::ConvexSoftmax);
        s.steps = 0;
        assert!(s.resolve(true).is_err());
        let mut s = ExperimentSpec::for_workload(Workload::ConvexSoftmax);
        s.participation = ParticipationSpec::FixedSize { m: 99 };
        assert!(s.resolve(true).is_err());
    }
}
