//! The single source of truth for Algorithm 1/2 arithmetic.
//!
//! Historically the worker/master update rules lived twice: once in the
//! deterministic engine (`engine::run_from`) and once in the threaded
//! runtime (`coordinator::{worker, master}`), and the bit-identical-sync
//! guarantee between the two was maintained by careful copy-paste. This
//! module extracts the arithmetic into two state machines so both execution
//! substrates are thin drivers over the *same* f32 operations, in the same
//! order:
//!
//! * [`WorkerCore`] — the per-worker side: one local SGD(+momentum) step,
//!   net progress `delta = x_anchor − x̂_{t+1/2}` against the sync anchor,
//!   error-compensated compression (Algorithm 1 lines 6–10), and anchor
//!   reconstruction from a master broadcast.
//! * [`MasterCore`] — the master side: fold decoded updates as
//!   `x ← x − s·g` (Algorithm 1 line 18 / Algorithm 2 line 19; the round
//!   scale s is the paper's `1/R`, or the unbiased `1/|S_t|` under sampled
//!   participation — see [`AggScale`] and [`MasterCore::begin_round`]) and
//!   produce the broadcast payload for each syncing worker.
//!
//! # Downlink (master → worker) compression
//!
//! The paper compresses only the uplink; the broadcast is a dense model at
//! `32·d` bits per worker per sync. On top of the cores this module adds the
//! bidirectional extension studied in *Double Quantization* (Yu et al.) and
//! *Error Compensated Quantized SGD* (Wu et al.): the master keeps, per
//! worker, a mirror of that worker's anchor (the model the worker has
//! reconstructed so far) and broadcasts the error-compensated, compressed
//! *model delta*
//!
//! ```text
//!   v_t       = x_t − anchor_r                   (the worker's staleness)
//!   q_t       = C_down(v_t)                      (broadcast, encoded wire)
//!   anchor_r  ← anchor_r + q_t                   (mirrors the worker)
//! ```
//!
//! and the worker reconstructs its anchor identically. The server error
//! memory of the explicit EF recursion (`v = m + Δ`, `m' = v − q`) satisfies
//! `m_t^{(r)} = x_t − anchor_r` by induction, so it is *implicit* here:
//! storing the anchor mirror alone (`R·d` floats, down from the historical
//! `2·R·d` prev-snapshot + memory pair) gives the same recursion — every
//! dropped coordinate stays part of `x_t − anchor_r` and is re-offered at
//! the next sync, and the anchor tracks the global model.
//!
//! The `Identity` downlink operator short-circuits to the classic dense
//! broadcast (`WorkerCore::apply_dense_broadcast` copies the model
//! verbatim), which keeps pre-existing trajectories bit-identical: a dense
//! delta reconstruction `a + (x − a)` would differ from `x` in the last
//! f32 ulp, a full copy cannot.
//!
//! Determinism: all stochastic downlink compression draws from per-worker
//! PCG streams salted with [`DOWNLINK_RNG_SALT`], so the engine and the
//! threaded runtime consume identical randomness per (worker, sync) pair
//! regardless of thread interleaving.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

pub mod checkpoint;
mod master;
mod worker;

pub use checkpoint::{CheckpointError, CHECKPOINT_VERSION};
pub use master::{DownlinkWorker, MasterCore};
pub use worker::WorkerCore;

/// How the master scales each folded update when only a subset S_t of
/// workers syncs in a round (sampled participation).
///
/// The paper's Algorithms 1/2 divide by the fleet size R. That is exact
/// under full participation, but the moment S_t is a random subset the
/// `1/R` step is biased low by a factor `E|S_t|/R` — the same unbiasedness
/// concern that makes Wangni et al. rescale sampled coordinates by `d/k`.
/// `Participants` divides by `|S_t|` instead, which keeps the expected
/// round step equal to the full-participation step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggScale {
    /// `x ← x − (1/R)·Σ g` — the paper's scaling (exact for S_t = [R]).
    Workers,
    /// `x ← x − (1/|S_t|)·Σ g` — unbiased under sampled participation.
    Participants,
}

impl AggScale {
    /// Parse a CLI spec: `workers` (aka `1/R`) | `participants` (aka `1/S`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "workers" | "1/R" => Ok(AggScale::Workers),
            "participants" | "sampled" | "1/S" => Ok(AggScale::Participants),
            other => anyhow::bail!(
                "unknown aggregation scale `{other}` (expected workers | participants)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggScale::Workers => "1/R",
            AggScale::Participants => "1/|S_t|",
        }
    }

    /// Canonical spec token — `parse(spec_str(s)) == s` (unlike `name`,
    /// whose display forms are not all accepted by `parse`).
    pub fn spec_str(&self) -> &'static str {
        match self {
            AggScale::Workers => "workers",
            AggScale::Participants => "participants",
        }
    }
}

/// Stream salt for the master's per-worker downlink RNGs (distinct from the
/// worker-side uplink salt `0xc0ffee` so the two never share a stream).
pub const DOWNLINK_RNG_SALT: u64 = 0xd05eed;

/// Stream salt for the worker-side uplink compression RNGs (kept identical
/// to the historical engine/coordinator constant so seeded trajectories are
/// preserved across the refactor).
pub const UPLINK_RNG_SALT: u64 = 0xc0ffee;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{parse_spec, Identity, TopK};
    use crate::data::gaussian_clusters;
    use crate::grad::{GradModel, SoftmaxRegression};
    use crate::util::rng::Pcg64;
    use crate::util::stats::norm2_sq;

    fn setup() -> (crate::data::Dataset, SoftmaxRegression) {
        let ds = gaussian_clusters(120, 12, 3, 1.5, 0.4, 9);
        let model = SoftmaxRegression::new(12, 3, 1.0 / 120.0);
        (ds, model)
    }

    #[test]
    fn worker_update_then_dense_broadcast_roundtrip() {
        let (ds, model) = setup();
        let d = model.dim();
        let shard: Vec<usize> = (0..ds.n).collect();
        let mut w = WorkerCore::new(0, vec![0.0; d], shard, 4, 0.0, 7);
        let mut m = MasterCore::new(vec![0.0; d], 1, 7, false);
        w.local_step(&model, &ds, 0.3);
        let msg = w.make_update(&Identity);
        // Identity: the transmitted delta is exactly the negative local step.
        assert_eq!(msg.dim(), d);
        m.apply_update(msg).unwrap();
        // R = 1 + identity ⇒ master model equals the worker's local iterate.
        for (g, l) in m.params().iter().zip(w.params()) {
            assert!((g - l).abs() < 1e-7);
        }
        w.apply_dense_broadcast(m.params());
        assert_eq!(w.params(), m.params());
        assert!(w.mem_norm_sq() < 1e-12);
    }

    #[test]
    fn delta_broadcast_memory_equals_staleness() {
        // Invariant from the module docs: after every broadcast to worker r,
        // the server memory equals global − anchor_r (within f32 rounding of
        // the two subtraction orders).
        let d = 64;
        let down = TopK::new(6);
        let mut rng = Pcg64::seeded(41);
        let mut master = MasterCore::new(vec![0.0; d], 2, 41, true);
        let mut anchors = vec![vec![0.0f32; d]; 2];
        for _round in 0..12 {
            // Drift the global model by a random dense "update".
            let noise: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
            master
                .apply_update(&crate::compress::Message::Dense { values: noise })
                .unwrap();
            for (r, anchor) in anchors.iter_mut().enumerate() {
                let msg = master.delta_broadcast(r, &down);
                msg.add_into(anchor, 1.0);
                let resid: Vec<f32> = master
                    .params()
                    .iter()
                    .zip(anchor.iter())
                    .map(|(g, a)| g - a)
                    .collect();
                let mem = master.down_memory(r).unwrap();
                let diff: Vec<f32> = resid.iter().zip(mem).map(|(x, y)| x - y).collect();
                assert!(
                    norm2_sq(&diff) < 1e-8 * (1.0 + norm2_sq(&resid)),
                    "server memory drifted from anchor staleness"
                );
            }
        }
        // Freeze the global model and keep broadcasting: error feedback must
        // drain the staleness (every dropped coordinate is re-offered).
        let before: f64 = anchors
            .iter()
            .map(|a| {
                let r: Vec<f32> =
                    master.params().iter().zip(a.iter()).map(|(g, x)| g - x).collect();
                norm2_sq(&r)
            })
            .sum();
        for _round in 0..60 {
            for (r, anchor) in anchors.iter_mut().enumerate() {
                let msg = master.delta_broadcast(r, &down);
                msg.add_into(anchor, 1.0);
            }
        }
        let after: f64 = anchors
            .iter()
            .map(|a| {
                let r: Vec<f32> =
                    master.params().iter().zip(a.iter()).map(|(g, x)| g - x).collect();
                norm2_sq(&r)
            })
            .sum();
        assert!(
            after < 0.05 * before + 1e-10,
            "staleness did not drain: {before:.3e} → {after:.3e}"
        );
    }

    #[test]
    fn participant_scaling_divides_by_round_size() {
        let d = 4;
        let g = crate::compress::Message::Dense { values: vec![1.0f32; d] };
        // Unbiased mode: two updates in a |S_t| = 2 round, each scaled 1/2.
        let mut m = MasterCore::new(vec![0.0; d], 8, 0, false);
        m.set_agg_scale(AggScale::Participants);
        m.begin_round(2);
        m.apply_update(&g).unwrap();
        m.apply_update(&g).unwrap();
        assert!(m.params().iter().all(|&x| (x + 1.0).abs() < 1e-7));
        // Paper mode: the announced |S_t| is ignored, scale stays 1/R.
        let mut w = MasterCore::new(vec![0.0; d], 8, 0, false);
        w.begin_round(2);
        w.apply_update(&g).unwrap();
        w.apply_update(&g).unwrap();
        assert!(w.params().iter().all(|&x| (x + 0.25).abs() < 1e-7));
    }

    #[test]
    fn fold_target_partition_matches_apply_update() {
        use crate::compress::Message;
        use crate::optim::ServerOptSpec;
        let d = 37;
        let mut rng = Pcg64::seeded(55);
        let updates: Vec<Message> = (0..3)
            .map(|_| Message::Dense { values: (0..d).map(|_| rng.normal_f32()).collect() })
            .collect();
        for spec in [ServerOptSpec::Avg, ServerOptSpec::Momentum { beta: 0.9, lr: 0.5 }] {
            let mk = || {
                let mut m = MasterCore::new(vec![0.125f32; d], 4, 0, false);
                m.set_server_opt(spec);
                m.begin_round(3);
                m
            };
            // Reference: the sequential apply_update fold.
            let mut seq = mk();
            for u in &updates {
                seq.apply_update(u).unwrap();
            }
            seq.end_round();
            // Sharded: every chunk folds all messages in the same order.
            let mut par = mk();
            {
                let (target, scale) = par.fold_target();
                for (lo, hi) in [(0usize, 10usize), (10, 10), (10, 37)] {
                    for u in &updates {
                        u.add_into_range(&mut target[lo..hi], scale, lo..hi);
                    }
                }
            }
            par.end_round();
            assert_eq!(seq.params(), par.params(), "{spec:?}");
        }
    }

    #[test]
    fn downlink_worker_matches_master_broadcast_stream() {
        // MasterCore's per-worker broadcast and a standalone DownlinkWorker
        // (the parallel engine's form) produce identical message streams.
        let d = 48;
        let down = parse_spec("qsgd:bits=2").unwrap();
        let mut rng = Pcg64::seeded(63);
        let init = vec![0.5f32; d];
        let mut master = MasterCore::new(init.clone(), 2, 17, true);
        let mut lone = super::DownlinkWorker::new(init, 17, 1);
        let mut scratch = vec![0.0f32; d];
        let mut buf = crate::compress::MessageBuf::new();
        for _round in 0..6 {
            let noise: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.1).collect();
            master
                .apply_update(&crate::compress::Message::Dense { values: noise })
                .unwrap();
            let _ = master.delta_broadcast(0, down.as_ref());
            let from_master = master.delta_broadcast(1, down.as_ref());
            lone.delta_into(master.params(), &mut scratch, down.as_ref(), &mut buf);
            assert_eq!(&from_master, buf.message());
            assert!(master.down_memory(1).is_some());
        }
    }

    #[test]
    fn dense_snapshot_cached_until_model_changes() {
        use std::sync::Arc;
        let mut m = MasterCore::new(vec![1.0f32; 4], 2, 0, false);
        let a = m.params_snapshot();
        let b = m.params_snapshot();
        assert!(Arc::ptr_eq(&a, &b), "snapshot rebuilt without a model change");
        m.apply_update(&crate::compress::Message::Dense { values: vec![1.0; 4] })
            .unwrap();
        let c = m.params_snapshot();
        assert!(!Arc::ptr_eq(&a, &c), "stale snapshot served after an update");
        assert_eq!(&c[..], m.params());
    }

    #[test]
    fn delta_broadcast_without_state_panics() {
        let mut master = MasterCore::new(vec![0.0; 8], 1, 0, false);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            master.delta_broadcast(0, &Identity)
        }));
        assert!(r.is_err());
    }

    #[test]
    fn server_avg_path_is_untouched_and_momentum_accumulates() {
        use crate::optim::ServerOptSpec;
        let d = 4;
        let g = crate::compress::Message::Dense { values: vec![1.0f32; d] };
        // Avg (explicit) ≡ default: fold is immediate, end_round a no-op.
        let mut avg = MasterCore::new(vec![0.0; d], 4, 0, false);
        avg.set_server_opt(ServerOptSpec::Avg);
        avg.begin_round(4);
        avg.apply_update(&g).unwrap();
        assert!(avg.params().iter().all(|&x| (x + 0.25).abs() < 1e-7));
        avg.end_round();
        assert!(avg.params().iter().all(|&x| (x + 0.25).abs() < 1e-7));

        // Momentum β=0.5, lr=1: model only moves at end_round; two rounds of
        // the same Δ=0.5 give x = −Δ, then x = −Δ − (0.5Δ + Δ) = −2.5Δ.
        let mut mom = MasterCore::new(vec![0.0; d], 4, 0, false);
        mom.set_server_opt(ServerOptSpec::Momentum { beta: 0.5, lr: 1.0 });
        mom.begin_round(2);
        mom.apply_update(&g).unwrap();
        mom.apply_update(&g).unwrap();
        assert!(mom.params().iter().all(|&x| x == 0.0), "model moved before end_round");
        mom.end_round();
        assert!(mom.params().iter().all(|&x| (x + 0.5).abs() < 1e-7));
        mom.begin_round(2);
        mom.apply_update(&g).unwrap();
        mom.apply_update(&g).unwrap();
        mom.end_round();
        assert!(mom.params().iter().all(|&x| (x + 1.25).abs() < 1e-7), "{:?}", mom.params());
        // An empty round applies nothing.
        mom.end_round();
        assert!(mom.params().iter().all(|&x| (x + 1.25).abs() < 1e-7));
    }

    #[test]
    fn server_lr_schedule_is_clocked_by_applied_rounds() {
        use crate::optim::{LrSchedule, ServerOptSpec};
        let d = 2;
        let g = crate::compress::Message::Dense { values: vec![1.0f32; d] };
        // β=0, R=1 ⇒ each round moves the model by exactly −lr_k·Δ with
        // Δ = 1, so the trajectory reads the schedule back directly.
        let mut m = MasterCore::new(vec![0.0; d], 1, 0, false);
        m.set_server_opt(ServerOptSpec::Momentum { beta: 0.0, lr: 9.0 });
        m.set_server_lr_schedule(LrSchedule::InvTime { xi: 1.0, a: 1.0 });
        // Round 0 (lr = 1/1), an empty end_round (must NOT advance the
        // round clock), then round 1 (lr = 1/2).
        m.begin_round(1);
        m.apply_update(&g).unwrap();
        m.end_round();
        assert!((m.params()[0] + 1.0).abs() < 1e-7, "{:?}", m.params());
        m.end_round();
        m.begin_round(1);
        m.apply_update(&g).unwrap();
        m.end_round();
        assert!((m.params()[0] + 1.5).abs() < 1e-7, "{:?}", m.params());
        // Without a schedule the configured constant lr is untouched, and
        // under Avg the hook is inert (no server step exists to scale).
        let mut plain = MasterCore::new(vec![0.0; d], 1, 0, false);
        plain.set_server_opt(ServerOptSpec::Avg);
        plain.set_server_lr_schedule(LrSchedule::Const { eta: 123.0 });
        plain.begin_round(1);
        plain.apply_update(&g).unwrap();
        plain.end_round();
        assert!((plain.params()[0] + 1.0).abs() < 1e-7, "{:?}", plain.params());
    }

    #[test]
    fn server_opt_invalidates_snapshot_at_end_round() {
        use crate::optim::ServerOptSpec;
        use std::sync::Arc;
        let mut m = MasterCore::new(vec![1.0f32; 4], 2, 0, false);
        m.set_server_opt(ServerOptSpec::Momentum { beta: 0.9, lr: 0.1 });
        let a = m.params_snapshot();
        m.begin_round(1);
        m.apply_update(&crate::compress::Message::Dense { values: vec![1.0; 4] }).unwrap();
        // Accumulation alone leaves the model (and thus the snapshot) valid.
        assert!(Arc::ptr_eq(&a, &m.params_snapshot()));
        m.end_round();
        let b = m.params_snapshot();
        assert!(!Arc::ptr_eq(&a, &b), "stale snapshot served after the optimizer step");
        assert_eq!(&b[..], m.params());
    }

    #[test]
    fn downlink_rngs_are_per_worker_deterministic() {
        // Two masters with the same seed produce identical broadcast streams
        // per worker, independent of interleaving order across workers.
        let d = 32;
        let down = parse_spec("qsgd:bits=2").unwrap();
        let mk = || MasterCore::new(vec![0.5; d], 3, 99, true);
        let mut a = mk();
        let mut b = mk();
        // a: workers in order 0,1,2 — b: order 2,0,1.
        let ma: Vec<_> = (0..3).map(|r| a.delta_broadcast(r, down.as_ref())).collect();
        let order = [2usize, 0, 1];
        let mut mb = vec![None, None, None];
        for &r in &order {
            mb[r] = Some(b.delta_broadcast(r, down.as_ref()));
        }
        for r in 0..3 {
            assert_eq!(Some(&ma[r]), mb[r].as_ref(), "worker {r} stream order-dependent");
        }
    }
}
