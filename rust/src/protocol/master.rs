//! Master-side protocol state machine (Algorithm 1/2, master lines).
//!
//! Aggregation: every received update is folded as `x ← x − s·g` where the
//! per-round scale `s` is `1/R` (Algorithm 1 line 18 / Algorithm 2 line 19)
//! or, under sampled participation with [`AggScale::Participants`],
//! `1/|S_t|` — the driver announces each round via [`MasterCore::begin_round`].
//! Broadcast: either the dense model (Identity downlink — the paper's
//! setting) or a per-worker error-compensated compressed model delta (see
//! the module docs of [`crate::protocol`] for the recursion and its
//! invariant). Per-worker downlink state (anchor mirrors, RNG streams) only
//! advances for workers the driver actually broadcasts to, i.e. the round's
//! participants.

use super::{AggScale, DOWNLINK_RNG_SALT};
use crate::compress::{Compressor, Message, MessageBuf};
use crate::optim::{LrSchedule, ServerOpt, ServerOptSpec};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// One worker's downlink compression state: the master's mirror of that
/// worker's anchor (reconstructed model) plus the worker's dedicated
/// broadcast RNG stream, so broadcast randomness is independent of the
/// order workers are served in (engine vs threaded, sync vs async).
///
/// Memory: `d` floats per worker (`R·d` total). An earlier representation
/// kept both a per-worker prev-sync model snapshot *and* an explicit error
/// memory (`2·R·d`), but by the module invariant `m_t^{(r)} = x_t −
/// anchor_r` the memory is a pure function of the global model and the
/// worker's anchor — so only the anchor mirror is stored and the error
/// compensation is implicit: `v_t = x_t − anchor_r` already equals
/// `m_t + Δ_t` of the explicit recursion.
///
/// [`MasterCore`] owns one per worker on the sequential and threaded
/// substrates; the parallel engine instead constructs each worker's state
/// on the pool thread that owns the worker (`engine/parallel`), so the
/// per-round delta + compress + encode fan out with zero sharing. Either
/// way the arithmetic lives here — the substrates cannot drift.
pub struct DownlinkWorker {
    anchor: Vec<f32>,
    rng: Pcg64,
}

impl DownlinkWorker {
    /// `init` must equal the initial global model handed to worker `r` —
    /// the shared anchor the downlink recursion starts from.
    pub fn new(init: Vec<f32>, seed: u64, r: usize) -> Self {
        DownlinkWorker {
            anchor: init,
            rng: Pcg64::new(seed ^ DOWNLINK_RNG_SALT, r as u64 + 1),
        }
    }

    /// Produce this worker's error-compensated compressed model delta into
    /// `buf` and advance the anchor mirror, exactly the recursion from the
    /// module docs: `v = global − anchor; q = C_down(v); anchor += q`.
    /// `scratch` is caller-owned `d`-float storage for `v` (shared across
    /// workers by `MasterCore`, per-thread in the parallel engine).
    pub fn delta_into(
        &mut self,
        global: &[f32],
        scratch: &mut [f32],
        down: &dyn Compressor,
        buf: &mut MessageBuf,
    ) {
        debug_assert_eq!(global.len(), self.anchor.len());
        debug_assert_eq!(scratch.len(), self.anchor.len());
        // v = x_t − anchor_r: the worker's full staleness. Error
        // compensation is implicit — the anchor already absorbed every past
        // broadcast, so whatever compression dropped is still part of this
        // difference.
        for ((dv, g), a) in scratch.iter_mut().zip(global).zip(&self.anchor) {
            *dv = g - a;
        }
        down.compress_into(scratch, &mut self.rng, buf);
        // Mirror the worker's reconstruction: anchor_r ← anchor_r + q_t.
        buf.message().add_into(&mut self.anchor, 1.0);
    }

    /// The mirrored anchor — the model this worker has reconstructed from
    /// the broadcasts it received so far.
    pub fn anchor(&self) -> &[f32] {
        &self.anchor
    }

    /// Serialize the mirror (checkpointing): anchor + broadcast RNG.
    pub fn save_state(&self, w: &mut crate::compress::encode::BitWriter) {
        w.push_f32s(&self.anchor);
        super::checkpoint::push_rng(w, &self.rng);
    }

    /// Restore state written by [`DownlinkWorker::save_state`].
    pub fn load_state(
        &mut self,
        r: &mut crate::compress::encode::BitReader,
    ) -> Result<(), super::checkpoint::CheckpointError> {
        super::checkpoint::read_f32s(r, &mut self.anchor)?;
        self.rng = super::checkpoint::read_rng(r)?;
        Ok(())
    }
}

/// Master state: the global model plus optional downlink compression state.
pub struct MasterCore {
    global: Vec<f32>,
    workers: usize,
    down: Option<Vec<DownlinkWorker>>,
    delta_buf: Vec<f32>,
    agg: AggScale,
    /// Scale applied to every update folded this round (set by
    /// `begin_round`; `1/R` until the first round begins).
    round_scale: f32,
    /// Cached dense-broadcast payload, invalidated whenever the model
    /// changes — one snapshot per aggregation round, however many workers
    /// it is sent to.
    snapshot: Option<Arc<[f32]>>,
    /// FedOpt-style server optimizer state. `None` ⇔ `ServerOptSpec::Avg`:
    /// updates are folded straight into the model (the paper's exact,
    /// historically bit-identical arithmetic). Otherwise each round's
    /// updates accumulate into `ServerRound::accum` and
    /// [`MasterCore::end_round`] applies one optimizer step to the model.
    server: Option<ServerRound>,
}

/// Per-round accumulator + optimizer for a non-`Avg` server optimizer.
struct ServerRound {
    opt: Box<dyn ServerOpt>,
    /// Σ over the round of `round_scale · g_r` — the plain-average step
    /// Δ_t the optimizer consumes. Cleared by `end_round`.
    accum: Vec<f32>,
    /// True when `accum` holds folded-but-unapplied updates.
    pending: bool,
    /// Server-side LR schedule, indexed by *applied round* count (not the
    /// global step — under Algorithm 2 or churn, rounds are the server's
    /// only clock). `None` keeps the optimizer's built-in constant lr.
    lr_schedule: Option<LrSchedule>,
    /// Rounds applied so far — the schedule's round clock.
    rounds_applied: usize,
}

impl MasterCore {
    /// `init` is the initial global model — it must equal the init handed to
    /// every `WorkerCore` (the downlink recursion starts from the shared
    /// anchor). Pass `compressed_downlink = true` iff the run broadcasts
    /// compressed deltas; the per-worker state is `R·d` floats (one anchor
    /// mirror each), skipped entirely for the classic dense broadcast.
    pub fn new(init: Vec<f32>, workers: usize, seed: u64, compressed_downlink: bool) -> Self {
        assert!(workers >= 1);
        let d = init.len();
        let down = compressed_downlink.then(|| {
            (0..workers).map(|r| DownlinkWorker::new(init.clone(), seed, r)).collect()
        });
        MasterCore {
            global: init,
            workers,
            down,
            delta_buf: vec![0.0f32; d],
            agg: AggScale::Workers,
            round_scale: 1.0 / workers as f32,
            snapshot: None,
            server: None,
        }
    }

    /// Install the server optimizer (default: `Avg`, the paper's plain
    /// averaging — a no-op here). Any previous optimizer state is reset.
    /// Drivers call this once, before the first round.
    pub fn set_server_opt(&mut self, spec: ServerOptSpec) {
        let d = self.global.len();
        self.server = spec.build(d).map(|opt| ServerRound {
            opt,
            accum: vec![0.0f32; d],
            pending: false,
            lr_schedule: None,
            rounds_applied: 0,
        });
    }

    /// Install a server-side learning-rate schedule: before each
    /// [`MasterCore::end_round`] optimizer step, the round's lr is set to
    /// `schedule.at(k)` where `k` counts previously *applied* rounds. A
    /// no-op under `Avg` (there is no server step to scale); call after
    /// [`MasterCore::set_server_opt`], which resets it.
    pub fn set_server_lr_schedule(&mut self, schedule: LrSchedule) {
        if let Some(sr) = &mut self.server {
            sr.lr_schedule = Some(schedule);
        }
    }

    /// Choose the aggregation scaling policy (default: the paper's `1/R`).
    /// With `AggScale::Workers` this is a no-op arithmetically — the scale
    /// is `1/R` whatever `begin_round` announces — so full-participation
    /// trajectories are preserved bit-for-bit.
    pub fn set_agg_scale(&mut self, agg: AggScale) {
        self.agg = agg;
        if agg == AggScale::Workers {
            self.round_scale = 1.0 / self.workers as f32;
        }
    }

    pub fn agg_scale(&self) -> AggScale {
        self.agg
    }

    /// Announce a sync round with `participants = |S_t|` syncing workers.
    /// Every update folded until the next `begin_round` is scaled by `1/R`
    /// (`AggScale::Workers`) or `1/|S_t|` (`AggScale::Participants`).
    pub fn begin_round(&mut self, participants: usize) {
        assert!(
            participants >= 1 && participants <= self.workers,
            "round with {participants} participants out of {} workers",
            self.workers
        );
        self.round_scale = match self.agg {
            AggScale::Workers => 1.0 / self.workers as f32,
            AggScale::Participants => 1.0 / participants as f32,
        };
    }

    /// The current global model x_t.
    pub fn params(&self) -> &[f32] {
        &self.global
    }

    /// Consume the core, returning the final model.
    pub fn into_params(self) -> Vec<f32> {
        self.global
    }

    pub fn dim(&self) -> usize {
        self.global.len()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Fold one decoded worker update into this round's aggregate:
    /// `x ← x − s·g` with the current round's scale (see `begin_round`)
    /// under plain averaging, or `accum ← accum + s·g` under a non-`Avg`
    /// server optimizer (the model then moves in [`MasterCore::end_round`]).
    /// Errors on dimension mismatch (malformed wire message) rather than
    /// corrupting the model.
    pub fn apply_update(&mut self, msg: &Message) -> anyhow::Result<()> {
        anyhow::ensure!(
            msg.dim() == self.global.len(),
            "update dimension mismatch: message d={} vs model d={}",
            msg.dim(),
            self.global.len()
        );
        match &mut self.server {
            None => {
                msg.add_into(&mut self.global, -self.round_scale);
                self.snapshot = None;
            }
            Some(sr) => {
                msg.add_into(&mut sr.accum, self.round_scale);
                sr.pending = true;
            }
        }
        Ok(())
    }

    /// Close the current aggregation round: under a non-`Avg` server
    /// optimizer, apply one optimizer step on the accumulated round delta
    /// Δ_t = s·Σ g and clear the accumulator. A no-op under `Avg` (updates
    /// were already folded) and when the round folded nothing, so drivers
    /// call it unconditionally after the fold loop, before broadcasting.
    pub fn end_round(&mut self) {
        if let Some(sr) = &mut self.server {
            if sr.pending {
                if let Some(sch) = &sr.lr_schedule {
                    sr.opt.set_round_lr(sch.at(sr.rounds_applied));
                }
                sr.opt.apply(&mut self.global, &sr.accum);
                sr.rounds_applied += 1;
                sr.accum.fill(0.0);
                sr.pending = false;
                self.snapshot = None;
            }
        }
    }

    /// The dense-broadcast payload: a shared snapshot of the current model,
    /// rebuilt only after the model has changed. All recipients of one
    /// aggregation round share a single allocation.
    pub fn params_snapshot(&mut self) -> Arc<[f32]> {
        if self.snapshot.is_none() {
            self.snapshot = Some(Arc::from(&self.global[..]));
        }
        Arc::clone(self.snapshot.as_ref().unwrap())
    }

    /// Produce the compressed downlink message for worker `r`: the
    /// error-compensated model delta since `r`'s previous broadcast. The
    /// caller transmits it (engine: in-memory; coordinator: encoded) and the
    /// worker applies it via `WorkerCore::apply_delta_broadcast`.
    /// Allocating wrapper around [`MasterCore::delta_broadcast_into`].
    ///
    /// Panics if the core was built with `compressed_downlink = false` —
    /// drivers choose the broadcast mode once, up front, from
    /// `Compressor::is_identity`.
    pub fn delta_broadcast(&mut self, r: usize, down: &dyn Compressor) -> Message {
        let mut buf = MessageBuf::new();
        self.delta_broadcast_into(r, down, &mut buf);
        buf.take()
    }

    /// As `delta_broadcast`, producing the message into reusable storage —
    /// the engine's allocation-free broadcast path. Delegates to worker
    /// `r`'s [`DownlinkWorker`] — the same state machine the parallel
    /// engine drives on the pool threads.
    pub fn delta_broadcast_into(&mut self, r: usize, down: &dyn Compressor, buf: &mut MessageBuf) {
        let st = self
            .down
            .as_mut()
            .expect("MasterCore built without compressed-downlink state");
        st[r].delta_into(&self.global, &mut self.delta_buf, down, buf);
    }

    /// Split view for a parallel driver's sharded fold: the round's fold
    /// target — the model itself under plain averaging, the round
    /// accumulator under a non-`Avg` server optimizer — plus the signed
    /// per-message scale `s` such that `target[i] += s * g[i]` is exactly
    /// the per-coordinate operation [`MasterCore::apply_update`] performs.
    /// Marks the target dirty exactly as `apply_update` would (snapshot
    /// invalidation under `Avg`, pending round otherwise), so take it only
    /// for a round that folds at least one update.
    pub fn fold_target(&mut self) -> (&mut [f32], f32) {
        match &mut self.server {
            None => {
                self.snapshot = None;
                (self.global.as_mut_slice(), -self.round_scale)
            }
            Some(sr) => {
                sr.pending = true;
                (sr.accum.as_mut_slice(), self.round_scale)
            }
        }
    }

    /// Server-side error memory of worker `r` (None for dense downlink):
    /// `global − anchor_r`, the staleness probe. Computed on demand — the
    /// collapsed downlink state stores only the anchor mirror.
    pub fn down_memory(&self, r: usize) -> Option<Vec<f32>> {
        self.down.as_ref().map(|st| {
            self.global
                .iter()
                .zip(st[r].anchor())
                .map(|(g, a)| g - a)
                .collect()
        })
    }

    /// Serialize the master's trajectory-dependent state: the global model,
    /// the current round scale, every downlink mirror, and the server
    /// optimizer's round accumulator + internal state. The dense-broadcast
    /// snapshot cache is derived and skipped.
    pub fn save_state(&self, w: &mut crate::compress::encode::BitWriter) {
        w.push_f32s(&self.global);
        w.push_f32(self.round_scale);
        match &self.down {
            None => w.push_bit(false),
            Some(st) => {
                w.push_bit(true);
                for dw in st {
                    dw.save_state(w);
                }
            }
        }
        match &self.server {
            None => w.push_bit(false),
            Some(sr) => {
                w.push_bit(true);
                w.push_f32s(&sr.accum);
                w.push_bit(sr.pending);
                w.push_bits(sr.rounds_applied as u64, 64);
                sr.opt.save_state(w);
            }
        }
    }

    /// Restore state written by [`MasterCore::save_state`] onto a freshly
    /// constructed core of the same spec (same worker count, downlink mode
    /// and server optimizer — a presence mismatch is a structured error,
    /// never a panic). On error the core is partially written and must be
    /// discarded.
    pub fn load_state(
        &mut self,
        r: &mut crate::compress::encode::BitReader,
    ) -> Result<(), super::checkpoint::CheckpointError> {
        use super::checkpoint::{read_f32s, CheckpointError};
        use crate::compress::encode::OrTruncated as _;
        read_f32s(r, &mut self.global)?;
        self.round_scale = r.read_f32().or_truncated().map_err(CheckpointError::Decode)?;
        self.snapshot = None;
        let has_down = r.read_bit().or_truncated().map_err(CheckpointError::Decode)?;
        match (&mut self.down, has_down) {
            (None, false) => {}
            (Some(st), true) => {
                for dw in st.iter_mut() {
                    dw.load_state(r)?;
                }
            }
            _ => return Err(CheckpointError::ShapeMismatch),
        }
        let has_server = r.read_bit().or_truncated().map_err(CheckpointError::Decode)?;
        match (&mut self.server, has_server) {
            (None, false) => {}
            (Some(sr), true) => {
                read_f32s(r, &mut sr.accum)?;
                sr.pending = r.read_bit().or_truncated().map_err(CheckpointError::Decode)?;
                let rounds =
                    r.read_bits(64).or_truncated().map_err(CheckpointError::Decode)?;
                sr.rounds_applied = usize::try_from(rounds)
                    .map_err(|_| CheckpointError::ShapeMismatch)?;
                sr.opt.load_state(r).map_err(CheckpointError::Decode)?;
            }
            _ => return Err(CheckpointError::ShapeMismatch),
        }
        Ok(())
    }

    /// Average ‖m^{(r)}‖² across workers (0.0 for dense downlink) — the
    /// server-side analogue of the uplink memory metric.
    pub fn down_mem_norm_sq(&self) -> f64 {
        match &self.down {
            None => 0.0,
            Some(st) => {
                let sum: f64 = st
                    .iter()
                    .map(|w| {
                        self.global
                            .iter()
                            .zip(w.anchor())
                            .map(|(g, a)| {
                                let m = (g - a) as f64;
                                m * m
                            })
                            .sum::<f64>()
                    })
                    .sum();
                sum / st.len() as f64
            }
        }
    }
}
