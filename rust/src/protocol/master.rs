//! Master-side protocol state machine (Algorithm 1/2, master lines).
//!
//! Aggregation: every received update is folded as `x ← x − s·g` where the
//! per-round scale `s` is `1/R` (Algorithm 1 line 18 / Algorithm 2 line 19)
//! or, under sampled participation with [`AggScale::Participants`],
//! `1/|S_t|` — the driver announces each round via [`MasterCore::begin_round`].
//! Broadcast: either the dense model (Identity downlink — the paper's
//! setting) or a per-worker error-compensated compressed model delta (see
//! the module docs of [`crate::protocol`] for the recursion and its
//! invariant). Per-worker downlink state (`prev`, `mems`, RNG streams) only
//! advances for workers the driver actually broadcasts to, i.e. the round's
//! participants.

use super::{AggScale, DOWNLINK_RNG_SALT};
use crate::compress::{Compressor, ErrorMemory, Message};
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Per-worker downlink compression state (only allocated when the run uses
/// a non-Identity downlink operator).
struct DownlinkState {
    /// Global model snapshot at this worker's previous broadcast.
    prev: Vec<Vec<f32>>,
    /// Server-side error memory m^{(r)} (≡ global − anchor_r, see mod docs).
    mems: Vec<ErrorMemory>,
    /// Per-worker streams so broadcast randomness is independent of the
    /// order workers are served in (engine vs threaded, sync vs async).
    rngs: Vec<Pcg64>,
}

/// Master state: the global model plus optional downlink compression state.
pub struct MasterCore {
    global: Vec<f32>,
    workers: usize,
    down: Option<DownlinkState>,
    delta_buf: Vec<f32>,
    agg: AggScale,
    /// Scale applied to every update folded this round (set by
    /// `begin_round`; `1/R` until the first round begins).
    round_scale: f32,
    /// Cached dense-broadcast payload, invalidated whenever the model
    /// changes — one snapshot per aggregation round, however many workers
    /// it is sent to.
    snapshot: Option<Arc<[f32]>>,
}

impl MasterCore {
    /// `init` is the initial global model — it must equal the init handed to
    /// every `WorkerCore` (the downlink recursion starts from the shared
    /// anchor). Pass `compressed_downlink = true` iff the run broadcasts
    /// compressed deltas; the per-worker state is `2·R·d` floats, skipped
    /// entirely for the classic dense broadcast.
    pub fn new(init: Vec<f32>, workers: usize, seed: u64, compressed_downlink: bool) -> Self {
        assert!(workers >= 1);
        let d = init.len();
        let down = compressed_downlink.then(|| DownlinkState {
            prev: vec![init.clone(); workers],
            mems: (0..workers).map(|_| ErrorMemory::zeros(d)).collect(),
            rngs: (0..workers)
                .map(|r| Pcg64::new(seed ^ DOWNLINK_RNG_SALT, r as u64 + 1))
                .collect(),
        });
        MasterCore {
            global: init,
            workers,
            down,
            delta_buf: vec![0.0f32; d],
            agg: AggScale::Workers,
            round_scale: 1.0 / workers as f32,
            snapshot: None,
        }
    }

    /// Choose the aggregation scaling policy (default: the paper's `1/R`).
    /// With `AggScale::Workers` this is a no-op arithmetically — the scale
    /// is `1/R` whatever `begin_round` announces — so full-participation
    /// trajectories are preserved bit-for-bit.
    pub fn set_agg_scale(&mut self, agg: AggScale) {
        self.agg = agg;
        if agg == AggScale::Workers {
            self.round_scale = 1.0 / self.workers as f32;
        }
    }

    pub fn agg_scale(&self) -> AggScale {
        self.agg
    }

    /// Announce a sync round with `participants = |S_t|` syncing workers.
    /// Every update folded until the next `begin_round` is scaled by `1/R`
    /// (`AggScale::Workers`) or `1/|S_t|` (`AggScale::Participants`).
    pub fn begin_round(&mut self, participants: usize) {
        assert!(
            participants >= 1 && participants <= self.workers,
            "round with {participants} participants out of {} workers",
            self.workers
        );
        self.round_scale = match self.agg {
            AggScale::Workers => 1.0 / self.workers as f32,
            AggScale::Participants => 1.0 / participants as f32,
        };
    }

    /// The current global model x_t.
    pub fn params(&self) -> &[f32] {
        &self.global
    }

    /// Consume the core, returning the final model.
    pub fn into_params(self) -> Vec<f32> {
        self.global
    }

    pub fn dim(&self) -> usize {
        self.global.len()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Fold one decoded worker update into the global model:
    /// `x ← x − s·g` with the current round's scale (see `begin_round`).
    /// Errors on dimension mismatch (malformed wire message) rather than
    /// corrupting the model.
    pub fn apply_update(&mut self, msg: &Message) -> anyhow::Result<()> {
        anyhow::ensure!(
            msg.dim() == self.global.len(),
            "update dimension mismatch: message d={} vs model d={}",
            msg.dim(),
            self.global.len()
        );
        msg.add_into(&mut self.global, -self.round_scale);
        self.snapshot = None;
        Ok(())
    }

    /// The dense-broadcast payload: a shared snapshot of the current model,
    /// rebuilt only after the model has changed. All recipients of one
    /// aggregation round share a single allocation.
    pub fn params_snapshot(&mut self) -> Arc<[f32]> {
        if self.snapshot.is_none() {
            self.snapshot = Some(Arc::from(&self.global[..]));
        }
        Arc::clone(self.snapshot.as_ref().unwrap())
    }

    /// Produce the compressed downlink message for worker `r`: the
    /// error-compensated model delta since `r`'s previous broadcast. The
    /// caller transmits it (engine: in-memory; coordinator: encoded) and the
    /// worker applies it via `WorkerCore::apply_delta_broadcast`.
    ///
    /// Panics if the core was built with `compressed_downlink = false` —
    /// drivers choose the broadcast mode once, up front, from
    /// `Compressor::is_identity`.
    pub fn delta_broadcast(&mut self, r: usize, down: &dyn Compressor) -> Message {
        let st = self
            .down
            .as_mut()
            .expect("MasterCore built without compressed-downlink state");
        // Δ = x_t − x_{prev sync of r} (model progress this worker missed).
        for ((dv, g), p) in self.delta_buf.iter_mut().zip(&self.global).zip(&st.prev[r]) {
            *dv = g - p;
        }
        let msg = st.mems[r].compress_update(&self.delta_buf, down, &mut st.rngs[r]);
        st.prev[r].copy_from_slice(&self.global);
        msg
    }

    /// Server-side error memory of worker `r` (None for dense downlink).
    /// Equals `global − anchor_r` up to f32 rounding — the staleness probe.
    pub fn down_memory(&self, r: usize) -> Option<&[f32]> {
        self.down.as_ref().map(|st| st.mems[r].as_slice())
    }

    /// Average ‖m^{(r)}‖² across workers (0.0 for dense downlink) — the
    /// server-side analogue of the uplink memory metric.
    pub fn down_mem_norm_sq(&self) -> f64 {
        match &self.down {
            None => 0.0,
            Some(st) => {
                st.mems.iter().map(|m| m.norm_sq()).sum::<f64>() / st.mems.len() as f64
            }
        }
    }
}
