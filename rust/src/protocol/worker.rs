//! Worker-side protocol state machine (Algorithm 1/2, worker lines).
//!
//! Owns everything a worker mutates between syncs: the local iterate, the
//! sync anchor, the error-feedback memory, the local optimizer, the shard
//! sampler and the compression RNG. The engine drives one `WorkerCore` per
//! simulated worker in-process; the threaded runtime drives one per OS
//! thread — both through exactly these methods, so the arithmetic (and its
//! f32 rounding) cannot drift between the two substrates.

use super::UPLINK_RNG_SALT;
use crate::compress::{Compressor, ErrorMemory, Message, MessageBuf};
use crate::data::{Batch, Dataset, ShardSampler};
use crate::grad::GradModel;
use crate::optim::LocalSgd;
use crate::util::rng::Pcg64;

/// Per-worker state: local iterate, sync anchor, error memory, optimizer.
///
/// All per-step scratch (minibatch, gradient, delta, compressed message) is
/// owned here and reused, so the steady-state `local_step`/`make_update`
/// cycle performs no heap allocation.
pub struct WorkerCore {
    id: usize,
    /// x̂_t^{(r)} — local iterate.
    local: Vec<f32>,
    /// x_t^{(r)} — the last global model this worker received (its sync
    /// anchor; in Alg 1 this equals the master's x_t at sync points).
    anchor: Vec<f32>,
    memory: ErrorMemory,
    opt: LocalSgd,
    sampler: ShardSampler,
    rng: Pcg64,
    grad_buf: Vec<f32>,
    delta_buf: Vec<f32>,
    batch_buf: Batch,
    msg_buf: MessageBuf,
}

impl WorkerCore {
    /// `init` is the initial global model (also the first anchor); `shard`
    /// the worker's data indices. RNG/sampler streams are derived from
    /// `(seed, id)` exactly as the pre-refactor engine and coordinator did,
    /// so existing seeded trajectories are preserved.
    pub fn new(
        id: usize,
        init: Vec<f32>,
        shard: Vec<usize>,
        batch: usize,
        momentum: f64,
        seed: u64,
    ) -> Self {
        let d = init.len();
        WorkerCore {
            id,
            anchor: init.clone(),
            local: init,
            memory: ErrorMemory::zeros(d),
            opt: LocalSgd::new(d, momentum, 0.0),
            sampler: ShardSampler::new(shard, batch, seed, id),
            rng: Pcg64::new(seed ^ UPLINK_RNG_SALT, id as u64 + 1),
            grad_buf: vec![0.0f32; d],
            delta_buf: vec![0.0f32; d],
            batch_buf: Batch::empty(),
            msg_buf: MessageBuf::new(),
        }
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn dim(&self) -> usize {
        self.local.len()
    }

    /// The current local iterate x̂_t^{(r)}.
    pub fn params(&self) -> &[f32] {
        &self.local
    }

    /// ‖m_t^{(r)}‖² — the Lemma 4/5 probe reported in metrics.
    pub fn mem_norm_sq(&self) -> f64 {
        self.memory.norm_sq()
    }

    /// One local SGD(+momentum) step on the worker's shard (Alg 1 line 5).
    pub fn local_step(&mut self, model: &dyn GradModel, train: &Dataset, eta: f64) {
        self.sampler.next_batch_into(train, &mut self.batch_buf);
        model.loss_grad(&self.local, &self.batch_buf, &mut self.grad_buf);
        self.opt.step(&mut self.local, &self.grad_buf, eta);
    }

    /// Synchronization, worker side (Alg 1 lines 6–10): net local progress
    /// `delta = x_anchor − x̂_{t+1/2}`, error-compensated and compressed.
    /// The returned message is what goes on the wire (uplink); it borrows
    /// the worker's reusable buffer — use [`WorkerCore::take_update`] when
    /// ownership is needed (e.g. to send it to another thread).
    pub fn make_update(&mut self, compressor: &dyn Compressor) -> &Message {
        for ((dv, a), l) in self.delta_buf.iter_mut().zip(&self.anchor).zip(&self.local) {
            *dv = a - l;
        }
        self.memory
            .compress_update_into(&self.delta_buf, compressor, &mut self.rng, &mut self.msg_buf);
        self.msg_buf.message()
    }

    /// Take ownership of the message produced by the last `make_update`
    /// (the parallel engine sends it to the master thread). Pair with
    /// [`WorkerCore::recycle_update`] to return the buffer afterwards.
    pub fn take_update(&mut self) -> Message {
        self.msg_buf.take()
    }

    /// Return a consumed update message so its heap capacity is reused by
    /// the next `make_update`.
    pub fn recycle_update(&mut self, msg: Message) {
        self.msg_buf.recycle(msg);
    }

    /// Dense broadcast (Identity downlink): adopt the master's model
    /// verbatim as both anchor and local iterate. Bit-identical to the
    /// pre-refactor broadcast.
    pub fn apply_dense_broadcast(&mut self, params: &[f32]) {
        assert_eq!(params.len(), self.local.len(), "broadcast dimension mismatch");
        self.local.copy_from_slice(params);
        self.anchor.copy_from_slice(params);
    }

    /// Compressed broadcast: reconstruct the anchor from the master's
    /// error-compensated model delta (`x_anchor ← x_anchor + q_t`) and
    /// restart local iterations from it.
    pub fn apply_delta_broadcast(&mut self, msg: &Message) {
        assert_eq!(msg.dim(), self.anchor.len(), "downlink delta dimension mismatch");
        msg.add_into(&mut self.anchor, 1.0);
        self.local.copy_from_slice(&self.anchor);
    }

    // ---- fault recovery ---------------------------------------------------
    // The EF memory is a ledger of everything not yet delivered; these
    // methods extend it to network losses. All of them re-anchor
    // (`local ← anchor`) exactly like a received broadcast would, so the
    // worker's next delta is measured against the model it actually has.

    /// The uplink carrying `msg` (this worker's own last update) was lost:
    /// fold it back into the error memory (`m ← m + g`, restoring the full
    /// pre-compression signal — see `ErrorMemory::absorb`) and restart
    /// local iterations from the stale anchor. Nothing is lost, only
    /// delayed to the next sync.
    pub fn reabsorb_update(&mut self, msg: &Message) {
        self.memory.absorb(msg);
        self.local.copy_from_slice(&self.anchor);
    }

    /// As [`WorkerCore::reabsorb_update`], for the message still sitting in
    /// the reusable buffer from the last `make_update` — the threaded
    /// worker's path, where the buffer is encoded (borrowed, not taken)
    /// before sending.
    pub fn reabsorb_last_update(&mut self) {
        self.memory.absorb(self.msg_buf.message());
        self.local.copy_from_slice(&self.anchor);
    }

    /// This worker's *downlink* was lost after its uplink was applied: the
    /// round's broadcast never arrived, so continue from the stale anchor.
    /// The memory is untouched — the update was delivered, and a compressed
    /// downlink's master-side mirror only advances for workers it actually
    /// encoded for, so the implicit downlink EF stays consistent.
    pub fn miss_broadcast(&mut self) {
        self.local.copy_from_slice(&self.anchor);
    }

    /// Crash-restart at a sync point: volatile state (error memory,
    /// momentum velocity) is lost, and the worker restarts from the last
    /// model it durably has — its anchor. Unlike re-absorption this *does*
    /// lose signal; the convergence tests quantify the difference.
    pub fn crash_restart(&mut self) {
        self.local.copy_from_slice(&self.anchor);
        self.memory.clear();
        self.opt.reset();
    }

    // ---- checkpointing ----------------------------------------------------

    /// Serialize this worker's trajectory-dependent state. Scratch buffers
    /// (gradient, delta, batch, message) are derived per step and skipped.
    pub fn save_state(&self, w: &mut crate::compress::encode::BitWriter) {
        w.push_f32s(&self.local);
        w.push_f32s(&self.anchor);
        w.push_f32s(self.memory.as_slice());
        w.push_f32s(self.opt.velocity());
        super::checkpoint::push_rng(w, self.sampler.rng());
        super::checkpoint::push_rng(w, &self.rng);
    }

    /// Restore state written by [`WorkerCore::save_state`] onto a freshly
    /// constructed core of the same spec (id, shard, dimension). On error
    /// the core is partially written and must be discarded — the resume
    /// paths abort the whole load.
    pub fn load_state(
        &mut self,
        r: &mut crate::compress::encode::BitReader,
    ) -> Result<(), super::checkpoint::CheckpointError> {
        use super::checkpoint::{read_f32s, read_rng};
        read_f32s(r, &mut self.local)?;
        read_f32s(r, &mut self.anchor)?;
        read_f32s(r, &mut self.delta_buf)?;
        self.memory.load(&self.delta_buf);
        read_f32s(r, &mut self.grad_buf)?;
        self.opt.load_velocity(&self.grad_buf);
        *self.sampler.rng_mut() = read_rng(r)?;
        self.rng = read_rng(r)?;
        Ok(())
    }
}
