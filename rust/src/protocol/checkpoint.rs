//! Bit-identical checkpoint/resume for the sequential training engine.
//!
//! A checkpoint is a snapshot of *every* piece of trajectory-dependent
//! state at a step boundary: the master model (plus server-optimizer
//! accumulators and downlink anchor mirrors), each worker's iterate /
//! anchor / error memory / momentum velocity / RNG streams, the run
//! counters (step, cumulative uplink and downlink bits) and the metric
//! `History` collected so far. Restoring it onto freshly spec-constructed
//! cores and continuing the loop MUST reproduce the uninterrupted run
//! bit-for-bit — `tests/integration_faults.rs` asserts exactly that.
//!
//! # Wire format (version 1)
//!
//! A single MSB-first bit stream (the same [`BitWriter`]/[`BitReader`]
//! machinery as the compression codecs), byte-padded at the end:
//!
//! ```text
//!   magic    32 bits   "QSCK" big-endian
//!   version   8 bits   CHECKPOINT_VERSION
//!   spec_fp  64 bits   FNV-1a of the canonical experiment spec JSON
//!   step     64 bits   completed steps
//!   bits_up  64 bits   cumulative uplink wire bits
//!   bits_dn  64 bits   cumulative downlink wire bits
//!   d        64 bits   model dimension
//!   workers  64 bits   fleet size
//!   points   64 bits   History point count, then 7×64 bits per point
//!   master   …         MasterCore::save_state
//!   worker×R …         WorkerCore::save_state each
//! ```
//!
//! `final_params` is not stored: a mid-run checkpoint has not produced it
//! yet, and resume recomputes it at run completion.
//!
//! # Decode discipline
//!
//! Checkpoint bytes are untrusted input (a file on disk), so loading
//! follows the same rules as the wire codecs: every failure is a
//! structured [`CheckpointError`], never a panic; the `History` point
//! count goes through [`checked_count`]'s decompression-bomb ceiling
//! before any allocation; and RNG increments are validated odd (a PCG
//! invariant) before reconstructing a generator. This file is on
//! repo-lint's no-panic list alongside the decoders.

use crate::compress::encode::{checked_count, BitReader, BitWriter, OrTruncated as _};
use crate::compress::DecodeError;
use crate::engine::{History, MetricPoint};
use crate::sim::Fnv1a64;
use crate::util::rng::Pcg64;

use super::{MasterCore, WorkerCore};

/// Bumped on any change to the checkpoint layout. Old versions are
/// rejected with [`CheckpointError::BadVersion`] — there is no migration
/// path, by design: a checkpoint is a resume token for one run, not an
/// archival format.
pub const CHECKPOINT_VERSION: u8 = 1;

const MAGIC: u32 = u32::from_be_bytes(*b"QSCK");

/// Each History point serializes to exactly 7 × 64 bits; used as the
/// per-element floor for the decompression-bomb ceiling.
const POINT_BITS: u64 = 7 * 64;

/// Why a checkpoint failed to load. All variants are recoverable — the
/// caller reports the error and starts fresh (or aborts); nothing panics.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum CheckpointError {
    /// The underlying bit stream was malformed (truncated, bomb ceiling).
    Decode(DecodeError),
    /// The leading bytes are not `QSCK` — not a checkpoint file.
    BadMagic,
    /// A checkpoint from an incompatible layout version.
    BadVersion(u8),
    /// The checkpoint was taken under a different experiment spec.
    SpecMismatch,
    /// Dimension / fleet-size / optional-state shape disagrees with the
    /// cores being restored onto.
    ShapeMismatch,
    /// A serialized RNG violates the PCG stream invariant (even
    /// increment) — the bytes cannot come from a real generator.
    BadRngState,
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::Decode(e) => write!(f, "malformed checkpoint stream: {e}"),
            CheckpointError::BadMagic => write!(f, "not a checkpoint file (bad magic)"),
            CheckpointError::BadVersion(v) => {
                write!(f, "unsupported checkpoint version {v} (expected {CHECKPOINT_VERSION})")
            }
            CheckpointError::SpecMismatch => {
                write!(f, "checkpoint was taken under a different experiment spec")
            }
            CheckpointError::ShapeMismatch => {
                write!(f, "checkpoint shape does not match the run being resumed")
            }
            CheckpointError::BadRngState => {
                write!(f, "serialized RNG state is invalid (even PCG increment)")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

impl From<DecodeError> for CheckpointError {
    fn from(e: DecodeError) -> Self {
        CheckpointError::Decode(e)
    }
}

/// FNV-1a fingerprint of the canonical spec text. Stored in the header
/// and required to match on resume, so a checkpoint can never silently
/// continue a *different* experiment.
pub fn spec_fingerprint(canonical_spec: &str) -> u64 {
    let mut h = Fnv1a64::new();
    h.write(canonical_spec.as_bytes());
    h.finish()
}

// ---- shared primitives (used by the core save_state/load_state impls) ----

/// Serialize a PCG stream as four 64-bit halves (state hi/lo, inc hi/lo).
pub(crate) fn push_rng(w: &mut BitWriter, rng: &Pcg64) {
    let (state, inc) = rng.snapshot();
    w.push_bits((state >> 64) as u64, 64);
    w.push_bits(state as u64, 64);
    w.push_bits((inc >> 64) as u64, 64);
    w.push_bits(inc as u64, 64);
}

/// Inverse of [`push_rng`]; rejects even increments (see
/// [`CheckpointError::BadRngState`]) before touching the generator.
pub(crate) fn read_rng(r: &mut BitReader) -> Result<Pcg64, CheckpointError> {
    let state_hi = r.read_bits(64).or_truncated()?;
    let state_lo = r.read_bits(64).or_truncated()?;
    let inc_hi = r.read_bits(64).or_truncated()?;
    let inc_lo = r.read_bits(64).or_truncated()?;
    let state = ((state_hi as u128) << 64) | state_lo as u128;
    let inc = ((inc_hi as u128) << 64) | inc_lo as u128;
    if inc & 1 == 0 {
        return Err(CheckpointError::BadRngState);
    }
    Ok(Pcg64::restore(state, inc))
}

/// Fill `out` from the stream, erroring (not panicking) on truncation.
pub(crate) fn read_f32s(r: &mut BitReader, out: &mut [f32]) -> Result<(), CheckpointError> {
    for v in out.iter_mut() {
        *v = r.read_f32().or_truncated()?;
    }
    Ok(())
}

fn push_f64(w: &mut BitWriter, v: f64) {
    w.push_bits(v.to_bits(), 64);
}

fn read_f64(r: &mut BitReader) -> Result<f64, CheckpointError> {
    Ok(f64::from_bits(r.read_bits(64).or_truncated()?))
}

fn read_usize(r: &mut BitReader) -> Result<usize, CheckpointError> {
    let v = r.read_bits(64).or_truncated()?;
    usize::try_from(v).map_err(|_| CheckpointError::ShapeMismatch)
}

// ---- full-run snapshot ---------------------------------------------------

/// The run-level counters restored from a checkpoint; the master and
/// worker cores are restored in place by [`load`].
pub struct Resumed {
    /// Completed steps at snapshot time — the loop continues from here.
    pub step: usize,
    /// Cumulative wire bits at snapshot time.
    pub bits_up: u64,
    pub bits_down: u64,
    /// Metric history collected so far (`final_params` empty; the
    /// resumed run fills it on completion).
    pub history: History,
}

/// Serialize a full sequential-engine snapshot at a step boundary.
pub fn save(
    spec_fp: u64,
    step: usize,
    bits_up: u64,
    bits_down: u64,
    history: &History,
    master: &MasterCore,
    workers: &[WorkerCore],
) -> Vec<u8> {
    let mut w = BitWriter::new();
    w.push_bits(MAGIC as u64, 32);
    w.push_bits(CHECKPOINT_VERSION as u64, 8);
    w.push_bits(spec_fp, 64);
    w.push_bits(step as u64, 64);
    w.push_bits(bits_up, 64);
    w.push_bits(bits_down, 64);
    w.push_bits(master.dim() as u64, 64);
    w.push_bits(workers.len() as u64, 64);
    w.push_bits(history.points.len() as u64, 64);
    for p in &history.points {
        w.push_bits(p.step as u64, 64);
        push_f64(&mut w, p.train_loss);
        push_f64(&mut w, p.test_err);
        push_f64(&mut w, p.test_top5_err);
        w.push_bits(p.bits_up, 64);
        w.push_bits(p.bits_down, 64);
        push_f64(&mut w, p.mem_norm_sq);
    }
    master.save_state(&mut w);
    for wk in workers {
        wk.save_state(&mut w);
    }
    let (bytes, _bit_len) = w.into_bytes();
    bytes
}

/// Restore a snapshot written by [`save`] onto freshly spec-constructed
/// cores. On success the cores hold the checkpointed state and the
/// returned [`Resumed`] carries the run counters; on error the cores are
/// partially written and must be discarded (the engine rebuilds them).
pub fn load(
    bytes: &[u8],
    spec_fp: u64,
    master: &mut MasterCore,
    workers: &mut [WorkerCore],
) -> Result<Resumed, CheckpointError> {
    let bit_len = (bytes.len() as u64).saturating_mul(8);
    let mut r = BitReader::new(bytes, bit_len);
    if r.read_bits(32).or_truncated()? as u32 != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let version = r.read_bits(8).or_truncated()? as u8;
    if version != CHECKPOINT_VERSION {
        return Err(CheckpointError::BadVersion(version));
    }
    if r.read_bits(64).or_truncated()? != spec_fp {
        return Err(CheckpointError::SpecMismatch);
    }
    let step = read_usize(&mut r)?;
    let bits_up = r.read_bits(64).or_truncated()?;
    let bits_down = r.read_bits(64).or_truncated()?;
    let d = read_usize(&mut r)?;
    let fleet = read_usize(&mut r)?;
    if d != master.dim() || fleet != workers.len() {
        return Err(CheckpointError::ShapeMismatch);
    }
    let n_points = r.read_bits(64).or_truncated()?;
    let n_points = checked_count(n_points, POINT_BITS, &r)?;
    let mut history = History::new();
    history.points.reserve(n_points);
    for _ in 0..n_points {
        let p = MetricPoint {
            step: read_usize(&mut r)?,
            train_loss: read_f64(&mut r)?,
            test_err: read_f64(&mut r)?,
            test_top5_err: read_f64(&mut r)?,
            bits_up: r.read_bits(64).or_truncated()?,
            bits_down: r.read_bits(64).or_truncated()?,
            mem_norm_sq: read_f64(&mut r)?,
        };
        history.points.push(p);
    }
    master.load_state(&mut r)?;
    for wk in workers.iter_mut() {
        wk.load_state(&mut r)?;
    }
    // Byte padding aside, the stream must be fully consumed — trailing
    // data means the file does not describe this run's shape.
    if r.remaining() >= 8 {
        return Err(CheckpointError::ShapeMismatch);
    }
    Ok(Resumed { step, bits_up, bits_down, history })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::ServerOptSpec;

    fn mk_cores(d: usize, fleet: usize, seed: u64) -> (MasterCore, Vec<WorkerCore>) {
        let master = MasterCore::new(vec![0.25f32; d], fleet, seed, true);
        let workers = (0..fleet)
            .map(|r| {
                WorkerCore::new(r, vec![0.25f32; d], (0..16).collect(), 4, 0.9, seed)
            })
            .collect();
        (master, workers)
    }

    fn perturbed(seed: u64) -> (MasterCore, Vec<WorkerCore>, History) {
        let d = 12;
        let (mut master, mut workers) = mk_cores(d, 2, seed);
        master.set_server_opt(ServerOptSpec::Momentum { beta: 0.9, lr: 0.5 });
        // Drive some state through the cores so the snapshot is non-trivial.
        let ds = crate::data::gaussian_clusters(48, 4, 3, 1.5, 0.4, seed);
        let model = crate::grad::SoftmaxRegression::new(4, 3, 1.0 / 48.0);
        let op = crate::compress::TopK::new(3);
        master.begin_round(2);
        for wk in workers.iter_mut() {
            wk.local_step(&model, &ds, 0.1);
            let msg = wk.make_update(&op);
            master.apply_update(msg).unwrap();
        }
        master.end_round();
        let mut history = History::new();
        history.push(MetricPoint {
            step: 1,
            train_loss: 1.25,
            test_err: 0.5,
            test_top5_err: 0.125,
            bits_up: 96,
            bits_down: 384,
            mem_norm_sq: 0.015625,
        });
        (master, workers, history)
    }

    #[test]
    fn roundtrips_bit_for_bit() {
        let fp = spec_fingerprint("{\"spec\":1}");
        let (master, workers, history) = perturbed(11);
        let bytes = save(fp, 7, 1000, 2000, &history, &master, &workers);
        let (mut m2, mut w2) = mk_cores(12, 2, 99);
        m2.set_server_opt(ServerOptSpec::Momentum { beta: 0.9, lr: 0.5 });
        let resumed = load(&bytes, fp, &mut m2, &mut w2).unwrap();
        assert_eq!(resumed.step, 7);
        assert_eq!(resumed.bits_up, 1000);
        assert_eq!(resumed.bits_down, 2000);
        assert_eq!(resumed.history.points.len(), 1);
        assert_eq!(resumed.history.points[0].train_loss.to_bits(), 1.25f64.to_bits());
        assert_eq!(m2.params(), master.params());
        for (a, b) in w2.iter().zip(&workers) {
            assert_eq!(a.params(), b.params());
            assert_eq!(a.mem_norm_sq().to_bits(), b.mem_norm_sq().to_bits());
        }
        // Saving the restored state reproduces the exact bytes.
        let again = save(fp, 7, 1000, 2000, &resumed.history, &m2, &w2);
        assert_eq!(again, bytes);
    }

    #[test]
    fn rejects_bad_magic_version_and_spec() {
        let fp = spec_fingerprint("spec-a");
        let (master, workers, history) = perturbed(12);
        let bytes = save(fp, 3, 10, 20, &history, &master, &workers);

        let (mut m, mut w) = mk_cores(12, 2, 1);
        m.set_server_opt(ServerOptSpec::Momentum { beta: 0.9, lr: 0.5 });
        let mut mangled = bytes.clone();
        mangled[0] ^= 0xff;
        assert_eq!(load(&mangled, fp, &mut m, &mut w), Err(CheckpointError::BadMagic));

        let mut versioned = bytes.clone();
        versioned[4] = CHECKPOINT_VERSION + 1;
        assert_eq!(
            load(&versioned, fp, &mut m, &mut w),
            Err(CheckpointError::BadVersion(CHECKPOINT_VERSION + 1))
        );

        let other_fp = spec_fingerprint("spec-b");
        assert_eq!(load(&bytes, other_fp, &mut m, &mut w), Err(CheckpointError::SpecMismatch));
    }

    #[test]
    fn rejects_shape_mismatch_and_truncation_without_panicking() {
        let fp = spec_fingerprint("spec");
        let (master, workers, history) = perturbed(13);
        let bytes = save(fp, 3, 10, 20, &history, &master, &workers);

        // Wrong fleet size.
        let (mut m3, mut w3) = mk_cores(12, 3, 1);
        m3.set_server_opt(ServerOptSpec::Momentum { beta: 0.9, lr: 0.5 });
        assert_eq!(load(&bytes, fp, &mut m3, &mut w3), Err(CheckpointError::ShapeMismatch));

        // Every truncation point is a structured error, never a panic.
        for cut in [0, 3, 4, 5, 12, 40, bytes.len() / 2, bytes.len() - 1] {
            let (mut m, mut w) = mk_cores(12, 2, 1);
            m.set_server_opt(ServerOptSpec::Momentum { beta: 0.9, lr: 0.5 });
            assert!(load(&bytes[..cut], fp, &mut m, &mut w).is_err(), "cut={cut}");
        }

        // Trailing garbage is rejected too.
        let mut padded = bytes.clone();
        padded.extend_from_slice(&[0u8; 16]);
        let (mut m, mut w) = mk_cores(12, 2, 1);
        m.set_server_opt(ServerOptSpec::Momentum { beta: 0.9, lr: 0.5 });
        assert_eq!(load(&padded, fp, &mut m, &mut w), Err(CheckpointError::ShapeMismatch));
    }

    #[test]
    fn rejects_even_rng_increment_as_bad_state() {
        let mut w = BitWriter::new();
        push_rng(&mut w, &Pcg64::seeded(5));
        let (bytes, bit_len) = w.into_bytes();
        let mut r = BitReader::new(&bytes, bit_len);
        assert!(read_rng(&mut r).is_ok());
        // An all-zero stream decodes four zero halves → inc is even.
        let zeros = [0u8; 32];
        let mut r = BitReader::new(&zeros, 256);
        assert_eq!(read_rng(&mut r).err(), Some(CheckpointError::BadRngState));
    }

    #[test]
    fn bomb_sized_history_count_is_rejected_before_allocation() {
        // Craft a valid header claiming u64::MAX history points; the
        // checked-count ceiling must reject it without allocating.
        let fp = spec_fingerprint("bomb");
        let mut w = BitWriter::new();
        w.push_bits(u32::from_be_bytes(*b"QSCK") as u64, 32);
        w.push_bits(CHECKPOINT_VERSION as u64, 8);
        w.push_bits(fp, 64);
        w.push_bits(0, 64); // step
        w.push_bits(0, 64); // bits_up
        w.push_bits(0, 64); // bits_down
        w.push_bits(12, 64); // d
        w.push_bits(2, 64); // workers
        w.push_bits(u64::MAX, 64); // history points: absurd
        let (bytes, _) = w.into_bytes();
        let (mut m, mut wk) = mk_cores(12, 2, 1);
        assert!(matches!(
            load(&bytes, fp, &mut m, &mut wk),
            Err(CheckpointError::Decode(_))
        ));
    }
}
