//! FNV-1a state hashing for determinism twins.
//!
//! Every eval point of a simulation records one 64-bit digest of the
//! observable simulator state — the global model bits, the virtual clock and
//! the event-queue length — so two runs of the same spec + seed can be
//! compared point-by-point ("determinism twins") without storing full model
//! snapshots. FNV-1a is used for its tiny, dependency-free, byte-exact
//! definition; this is a fingerprint for drift detection, not a
//! cryptographic commitment.

/// Incremental FNV-1a (64-bit).
#[derive(Clone, Debug)]
pub struct Fnv1a64 {
    state: u64,
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

impl Fnv1a64 {
    pub fn new() -> Self {
        Fnv1a64 { state: FNV_OFFSET }
    }

    /// Absorb raw bytes.
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.state ^= b as u64;
            self.state = self.state.wrapping_mul(FNV_PRIME);
        }
    }

    /// Absorb a `u64` as 8 little-endian bytes.
    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.state
    }
}

impl Default for Fnv1a64 {
    fn default() -> Self {
        Self::new()
    }
}

/// The simulator's per-eval-point digest: model parameter bits (exact f32
/// bit patterns, little-endian), the virtual clock, and the number of
/// pending events. Identical specs + seeds must produce identical digest
/// sequences — the determinism-twin invariant asserted in
/// `tests/integration_sim.rs`.
pub fn state_hash(params: &[f32], clock: u64, queue_len: usize) -> u64 {
    let mut h = Fnv1a64::new();
    for &p in params {
        h.write(&p.to_bits().to_le_bytes());
    }
    h.write_u64(clock);
    h.write_u64(queue_len as u64);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_vectors() {
        // Classic FNV-1a test vectors.
        let mut h = Fnv1a64::new();
        assert_eq!(h.finish(), 0xcbf2_9ce4_8422_2325);
        h.write(b"a");
        assert_eq!(h.finish(), 0xaf63_dc4c_8601_ec8c);
        let mut h2 = Fnv1a64::new();
        h2.write(b"foobar");
        assert_eq!(h2.finish(), 0x85944171f73967e8);
    }

    #[test]
    fn state_hash_sensitive_to_each_input() {
        let p = [1.0f32, -2.5, 0.0];
        let base = state_hash(&p, 100, 3);
        assert_ne!(base, state_hash(&[1.0, -2.5, 1e-30], 100, 3), "params");
        assert_ne!(base, state_hash(&p, 101, 3), "clock");
        assert_ne!(base, state_hash(&p, 100, 4), "queue length");
        assert_eq!(base, state_hash(&p, 100, 3), "deterministic");
    }

    #[test]
    fn negative_zero_differs_from_positive_zero() {
        // The digest covers exact f32 bit patterns, not numeric equality.
        assert_ne!(state_hash(&[0.0], 0, 0), state_hash(&[-0.0], 0, 0));
    }
}
