//! Deterministic discrete-event queue.
//!
//! A min-heap over `(time, seq)` where `seq` is a monotonically increasing
//! push counter: two events scheduled for the same virtual tick pop in the
//! order they were pushed. The tie-break makes the pop order a *total*
//! order — a pure function of the push sequence — which is what turns the
//! binary heap (whose internal layout is famously order-unstable) into a
//! deterministic scheduler. This is the tick/delta/event simulation-loop
//! discipline: handlers never read a wall clock, they only schedule future
//! events relative to the popped event's time.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct Entry<T> {
    time: u64,
    seq: u64,
    ev: T,
}

// Identity and order live entirely in `(time, seq)`; `seq` is unique per
// queue, so the derived equivalence is consistent with `Ord`.
impl<T> PartialEq for Entry<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Entry<T> {}

impl<T> PartialOrd for Entry<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<T> Ord for Entry<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        (other.time, other.seq).cmp(&(self.time, self.seq))
    }
}

/// Event queue with `(time, seq)` total-order tie-breaking.
pub struct EventQueue<T> {
    heap: BinaryHeap<Entry<T>>,
    seq: u64,
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    /// Pre-size the heap spine so a bounded-occupancy steady state performs
    /// no further heap allocation (bench-asserted via `alloc/sim-steady-*`).
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap), seq: 0 }
    }

    /// Schedule `ev` at absolute virtual time `time`.
    pub fn push(&mut self, time: u64, ev: T) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, ev });
    }

    /// Pop the earliest event; same-tick events pop in push order.
    pub fn pop(&mut self) -> Option<(u64, T)> {
        self.heap.pop().map(|e| (e.time, e.ev))
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total number of events ever pushed.
    pub fn pushed(&self) -> u64 {
        self.seq
    }
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn same_tick_breaks_ties_by_push_order() {
        let mut q = EventQueue::new();
        for i in 0..32usize {
            q.push(7, i);
        }
        // Interleave an earlier and a later event to stress the heap layout.
        q.push(3, 1000);
        q.push(9, 2000);
        assert_eq!(q.pop(), Some((3, 1000)));
        for i in 0..32usize {
            assert_eq!(q.pop(), Some((7, i)), "FIFO within tick 7");
        }
        assert_eq!(q.pop(), Some((9, 2000)));
    }

    #[test]
    fn tie_break_survives_pop_push_interleaving() {
        // Push/pop interleaving must not reorder same-tick events: seq is
        // assigned at push, not at heap position.
        let mut q = EventQueue::new();
        q.push(5, "first");
        q.push(1, "warm");
        assert_eq!(q.pop(), Some((1, "warm")));
        q.push(5, "second");
        q.push(5, "third");
        assert_eq!(q.pop(), Some((5, "first")));
        assert_eq!(q.pop(), Some((5, "second")));
        assert_eq!(q.pop(), Some((5, "third")));
    }

    #[test]
    fn len_and_pushed_track_activity() {
        let mut q: EventQueue<u8> = EventQueue::with_capacity(8);
        assert!(q.is_empty());
        q.push(1, 0);
        q.push(2, 1);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        assert_eq!(q.pushed(), 2);
    }
}
