//! Deterministic event-driven network simulator: simulated seconds to
//! target, not just bits to target.
//!
//! Every other execution substrate in this crate advances a lockstep round
//! grid — useful for bit accounting, silent about *time*. This module gives
//! the same protocol arithmetic a virtual wall clock: a discrete-event
//! engine schedules each worker's compute steps and wire transfers on a
//! `u64` tick clock, with per-client compute speed and link bandwidth drawn
//! from seeded lognormal-ish distributions, transfer durations charged from
//! each message's *actual* `wire_bits` under the configured codec, plus
//! straggler and drop/reconnect-churn processes. That answers the question
//! the paper's headline claim actually turns on: how much wall-clock time a
//! compressor (or the async schedule of Algorithm 2, which exists precisely
//! to dodge stragglers) buys under skewed client speeds.
//!
//! # Architecture
//!
//! * [`queue`] — binary-heap event queue with `(time, seq)` total-order
//!   tie-breaking; the simulation loop is a pure fold over its pop order.
//! * [`client`] — seeded per-client profiles and the straggler/churn
//!   processes, each on its own salted `Pcg64` stream.
//! * [`run`] — the driver: it moves the *existing*
//!   `protocol::{WorkerCore, MasterCore}` state machines through the event
//!   timeline, so the learning arithmetic is shared with the engine and the
//!   threaded coordinator, not reimplemented.
//! * [`hash`] — FNV-1a state digests (model bits + clock + queue length)
//!   recorded per eval point for determinism twins.
//!
//! # Parity contract
//!
//! The master folds each round's updates in worker-index order and
//! processes rounds in global-step order, and every worker draws only from
//! its own salted streams — so without churn the produced [`History`] is
//! **bit-identical to `engine::run`** for *any* timing parameters: timing
//! moves the clock, never the arithmetic. The degenerate configuration
//! (homogeneous speeds, zero latency, synchronous `H`) asserted in
//! `tests/integration_sim.rs` is the acceptance instance of that contract.
//! Divergence from the engine is possible only through churn (a worker
//! offline at a sync point skips the round) — and there the error-feedback
//! anchors are frozen on both sides while offline, so reconnection is
//! arithmetically free (see [`client`]).
//!
//! Because every round here is explicit — `begin_round`/`end_round` fire at
//! the round's completion tick — FedOpt server optimizers (`momentum`,
//! `adam`) compose with *asynchronous* schedules on this substrate, unlike
//! the threaded coordinator's aggregate-on-arrival path, which keeps its
//! up-front rejection (`coordinator::master`).
//!
//! [`History`]: crate::engine::History
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

pub mod client;
pub mod hash;
pub mod queue;
pub mod run;

pub use client::{transfer_ticks, ChurnTrack, ClientProfile};
pub use hash::{state_hash, Fnv1a64};
pub use queue::EventQueue;
pub use run::{run, run_from, run_from_faulty, SimPoint, SimResult};

use crate::util::json::Json;

/// Network/compute scenario description — the `"sim"` object of an
/// `ExperimentSpec` JSON. All fields have degenerate-friendly defaults;
/// `Default` is a homogeneous, zero-latency, failure-free cluster.
///
/// Time is measured in virtual ticks; `ticks_per_sec` only converts ticks
/// to reported seconds. With the default `1_000_000` a tick is 1 µs, the
/// default compute mean (5000 ticks) is 5 ms/step, and the default
/// bandwidth (100 bits/tick) is 100 Mbit/s.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SimSpec {
    /// Virtual ticks per reported second (display conversion only).
    pub ticks_per_sec: u64,
    /// Mean compute ticks per local SGD step.
    pub compute_mean: f64,
    /// Lognormal-ish spread of per-client compute speed (0 = homogeneous).
    /// `sigma ≈ 0.8` gives a p99/p50 client-speed ratio of ≈ 6×.
    pub compute_sigma: f64,
    /// Mean link bandwidth in wire bits per tick (symmetric up/down).
    pub bw_mean: f64,
    /// Lognormal-ish spread of per-client bandwidth (0 = homogeneous).
    pub bw_sigma: f64,
    /// Fixed propagation latency added to every transfer, in ticks.
    pub latency: u64,
    /// Per-step probability that a worker's step is straggler-slowed.
    pub straggler_prob: f64,
    /// Compute-time multiplier applied to straggler-hit steps.
    pub straggler_mult: f64,
    /// Mean online-window duration in ticks; 0 disables churn entirely.
    pub churn_online_mean: u64,
    /// Mean offline-window duration in ticks (must be ≥ 1 when churn is on).
    pub churn_offline_mean: u64,
    /// Lognormal-ish spread of churn window durations.
    pub churn_sigma: f64,
}

impl Default for SimSpec {
    fn default() -> Self {
        SimSpec {
            ticks_per_sec: 1_000_000,
            compute_mean: 5_000.0,
            compute_sigma: 0.0,
            bw_mean: 100.0,
            bw_sigma: 0.0,
            latency: 0,
            straggler_prob: 0.0,
            straggler_mult: 10.0,
            churn_online_mean: 0,
            churn_offline_mean: 0,
            churn_sigma: 0.5,
        }
    }
}

/// JSON field names, in emission order (BTreeMap sorts them anyway; this
/// list is the single source for the strict unknown-key check).
const SIM_FIELDS: &[&str] = &[
    "ticks_per_sec",
    "compute_mean",
    "compute_sigma",
    "bw_mean",
    "bw_sigma",
    "latency",
    "straggler_prob",
    "straggler_mult",
    "churn_online_mean",
    "churn_offline_mean",
    "churn_sigma",
];

impl SimSpec {
    /// Range-check the scenario (shared by spec validation and the CLI).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.ticks_per_sec >= 1, "sim: ticks_per_sec must be >= 1");
        anyhow::ensure!(
            self.compute_mean >= 1.0 && self.compute_mean.is_finite(),
            "sim: compute_mean must be >= 1 tick, got {}",
            self.compute_mean
        );
        anyhow::ensure!(
            self.bw_mean > 0.0 && self.bw_mean.is_finite(),
            "sim: bw_mean must be > 0 bits/tick, got {}",
            self.bw_mean
        );
        for (name, sigma) in [
            ("compute_sigma", self.compute_sigma),
            ("bw_sigma", self.bw_sigma),
            ("churn_sigma", self.churn_sigma),
        ] {
            anyhow::ensure!(
                sigma >= 0.0 && sigma.is_finite(),
                "sim: {name} must be finite and >= 0, got {sigma}"
            );
        }
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.straggler_prob),
            "sim: straggler_prob must be in [0, 1], got {}",
            self.straggler_prob
        );
        anyhow::ensure!(
            self.straggler_mult >= 1.0 && self.straggler_mult.is_finite(),
            "sim: straggler_mult must be >= 1, got {}",
            self.straggler_mult
        );
        if self.churn_online_mean > 0 {
            anyhow::ensure!(
                self.churn_offline_mean >= 1,
                "sim: churn_offline_mean must be >= 1 tick when churn is enabled"
            );
        } else {
            anyhow::ensure!(
                self.churn_offline_mean == 0,
                "sim: churn_offline_mean set but churn_online_mean is 0 (churn disabled)"
            );
        }
        Ok(())
    }

    /// Emit the full scenario (every field, explicit) as a JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ticks_per_sec", Json::num(self.ticks_per_sec as f64)),
            ("compute_mean", Json::num(self.compute_mean)),
            ("compute_sigma", Json::num(self.compute_sigma)),
            ("bw_mean", Json::num(self.bw_mean)),
            ("bw_sigma", Json::num(self.bw_sigma)),
            ("latency", Json::num(self.latency as f64)),
            ("straggler_prob", Json::num(self.straggler_prob)),
            ("straggler_mult", Json::num(self.straggler_mult)),
            ("churn_online_mean", Json::num(self.churn_online_mean as f64)),
            ("churn_offline_mean", Json::num(self.churn_offline_mean as f64)),
            ("churn_sigma", Json::num(self.churn_sigma)),
        ])
    }

    /// Parse a `"sim"` JSON object. Missing fields take their defaults;
    /// unknown fields are a hard error (same strictness as the enclosing
    /// `ExperimentSpec`). Ends with [`SimSpec::validate`].
    pub fn from_json(j: &Json) -> anyhow::Result<SimSpec> {
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("sim: expected a JSON object"))?;
        if let Some(unknown) = obj.keys().find(|k| !SIM_FIELDS.contains(&k.as_str())) {
            anyhow::bail!("sim: unknown field `{unknown}`");
        }
        let f64_field = |key: &str, default: f64| -> anyhow::Result<f64> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("sim: field `{key}` must be a number")),
            }
        };
        let u64_field = |key: &str, default: u64| -> anyhow::Result<u64> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                    .map(|n| n as u64)
                    .ok_or_else(|| {
                        anyhow::anyhow!("sim: field `{key}` must be a non-negative integer")
                    }),
            }
        };
        let d = SimSpec::default();
        let s = SimSpec {
            ticks_per_sec: u64_field("ticks_per_sec", d.ticks_per_sec)?,
            compute_mean: f64_field("compute_mean", d.compute_mean)?,
            compute_sigma: f64_field("compute_sigma", d.compute_sigma)?,
            bw_mean: f64_field("bw_mean", d.bw_mean)?,
            bw_sigma: f64_field("bw_sigma", d.bw_sigma)?,
            latency: u64_field("latency", d.latency)?,
            straggler_prob: f64_field("straggler_prob", d.straggler_prob)?,
            straggler_mult: f64_field("straggler_mult", d.straggler_mult)?,
            churn_online_mean: u64_field("churn_online_mean", d.churn_online_mean)?,
            churn_offline_mean: u64_field("churn_offline_mean", d.churn_offline_mean)?,
            churn_sigma: f64_field("churn_sigma", d.churn_sigma)?,
        };
        s.validate()?;
        Ok(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_validates_and_roundtrips() {
        let s = SimSpec::default();
        s.validate().unwrap();
        let back = SimSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn nondefault_roundtrips_exactly() {
        let s = SimSpec {
            ticks_per_sec: 1000,
            compute_mean: 123.5,
            compute_sigma: 0.8,
            bw_mean: 12.25,
            bw_sigma: 0.4,
            latency: 2_000,
            straggler_prob: 0.05,
            straggler_mult: 8.0,
            churn_online_mean: 4_000_000,
            churn_offline_mean: 900_000,
            churn_sigma: 0.3,
        };
        s.validate().unwrap();
        let text = s.to_json().pretty();
        let back = SimSpec::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn missing_fields_take_defaults() {
        let j = Json::parse(r#"{"compute_sigma": 0.8, "latency": 10}"#).unwrap();
        let s = SimSpec::from_json(&j).unwrap();
        let d = SimSpec::default();
        assert_eq!(s.compute_sigma, 0.8);
        assert_eq!(s.latency, 10);
        assert_eq!(s.bw_mean, d.bw_mean);
        assert_eq!(s.ticks_per_sec, d.ticks_per_sec);
    }

    #[test]
    fn rejects_unknown_fields_and_bad_ranges() {
        assert!(SimSpec::from_json(&Json::parse(r#"{"bogus": 1}"#).unwrap()).is_err());
        assert!(SimSpec::from_json(&Json::parse(r#"{"latency": 1.5}"#).unwrap()).is_err());
        assert!(SimSpec::from_json(&Json::parse(r#"{"latency": -3}"#).unwrap()).is_err());
        assert!(SimSpec::from_json(&Json::parse(r#"{"straggler_prob": 1.5}"#).unwrap()).is_err());
        assert!(SimSpec::from_json(&Json::parse(r#"{"bw_mean": 0}"#).unwrap()).is_err());
        assert!(SimSpec::from_json(&Json::parse(r#"{"straggler_mult": 0.5}"#).unwrap()).is_err());
        // Churn consistency: offline mean without an online mean is a typo.
        assert!(
            SimSpec::from_json(&Json::parse(r#"{"churn_offline_mean": 100}"#).unwrap()).is_err()
        );
        assert!(SimSpec::from_json(&Json::parse(r#"{"churn_online_mean": 100}"#).unwrap()).is_err());
        assert!(SimSpec::from_json(
            &Json::parse(r#"{"churn_online_mean": 100, "churn_offline_mean": 50}"#).unwrap()
        )
        .is_ok());
        assert!(SimSpec::from_json(&Json::parse("[1,2]").unwrap()).is_err());
    }
}
