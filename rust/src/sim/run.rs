//! The event-driven driver: `protocol::{WorkerCore, MasterCore}` on a
//! virtual clock.
//!
//! # Event model
//!
//! Each live worker owns **exactly one** in-flight event at any moment:
//!
//! * [`Ev::StepDone`] — the worker finishes local SGD step `t` after
//!   `compute_ticks` (× the straggler multiplier when the per-step
//!   Bernoulli hits). If `t` is one of its sync points and it is online, it
//!   starts uploading; if offline, it reports a *skip* to the master
//!   (bookkeeping, not wire traffic) and keeps computing; otherwise it just
//!   schedules the next step.
//! * [`Ev::UploadArrived`] — the worker's compressed update lands at the
//!   master after `transfer_ticks(wire_bits, bw, latency)`. The worker now
//!   blocks: its model for step `t + 1` depends on round `t`'s broadcast.
//! * [`Ev::DownArrived`] — the round-`t` broadcast lands back at the
//!   worker, which applies it and resumes computing.
//!
//! so the queue occupancy is bounded by the worker count and the steady
//! state allocates nothing (round buffers are pooled, messages are
//! recycled through their owning worker's `MessageBuf`).
//!
//! # Round ordering = engine parity
//!
//! The master buffers arrivals per round and processes rounds **strictly in
//! global-step order**, each as soon as every *expected* participant
//! (schedule ∩ sampled participation — a static table) has either arrived
//! or skipped. Within a round, updates fold in worker-index order. Those
//! two rules make the folded arithmetic — and hence the emitted `History`
//! — bit-identical to `engine::run` for *any* timing parameters as long as
//! no sync is skipped; timing only decides *when* (in virtual ticks) each
//! round completes. Churn is the single source of arithmetic divergence,
//! by design.
//!
//! # Eval semantics
//!
//! The eval grid is the engine's (`step % eval_every == 0 || step == steps`,
//! plus the step-0 snapshot). Grid step `s` is emitted the moment the last
//! round with step `≤ s − 1` has been processed — the global model, bit
//! totals and per-worker error memories are then exactly the engine's at
//! that step — and is stamped with the virtual tick at which that happened
//! plus an FNV-1a state digest for determinism twins.
//!
//! # Fault injection ([`run_from_faulty`])
//!
//! With an active [`FaultSpec`] the wire is lossy: uplink messages can be
//! dropped, corrupted, duplicated or delayed, downlink broadcasts dropped,
//! and workers crash-restarted — all decided by the stateless
//! [`FaultPlan`], so the same fault seed injects the same faults on any
//! substrate. Rounds then stop being barriers: a round force-closes
//! `deadline_ticks` after it opens ([`Ev::RoundDeadline`]), folding
//! whatever arrived, and a worker whose update was lost re-absorbs it into
//! its error memory ([`WorkerCore::reabsorb_update`]) — the lost signal is
//! delayed to its next sync, never destroyed. Duplicate deliveries dedup
//! per (worker, round) via the round's `arrived` mask; late deliveries
//! (after force-close) degrade to drops. Uplink bits are accounted at fold
//! time for delivered updates and at re-absorption time for lost ones, so
//! a dup/delay-only scenario (no signal loss) reproduces the fault-free
//! `History` bit for bit — asserted in `tests/integration_faults.rs`.
//! Corruption here is semantic (the master discards the arrival): the sim
//! exchanges `Message` values, not wire bytes; real byte mangling and the
//! decode-error path are exercised by the threaded coordinator.

use std::collections::VecDeque;
use std::sync::Arc;

use super::client::{transfer_ticks, ChurnTrack, ClientProfile, SIM_STRAGGLER_RNG_SALT};
use super::hash::state_hash;
use super::queue::EventQueue;
use super::SimSpec;
use crate::compress::{encode, Compressor, Message, MessageBuf};
use crate::data::shard_indices;
use crate::engine::{EvalSets, History, TrainSpec};
use crate::faults::{Channel, FaultAction, FaultPlan, FaultSpec};
use crate::grad::GradModel;
use crate::protocol::{MasterCore, WorkerCore};
use crate::topology::SyncSchedule;
use crate::util::rng::Pcg64;

/// Simulator events. `Copy`-small on purpose: payloads (messages, broadcast
/// snapshots) live in per-worker slots, not in the queue.
#[derive(Clone, Copy, Debug)]
enum Ev {
    /// Worker `r` finished its current local step.
    StepDone { r: usize },
    /// Worker `r`'s uplink message for round `t` reached the master. The
    /// step rides in the event because faults (duplication, delay past a
    /// deadline) can deliver it after the worker has moved on.
    UploadArrived { r: usize, t: usize },
    /// The round broadcast reached worker `r`.
    DownArrived { r: usize },
    /// Worker `r` gave up waiting for round feedback (its uplink was lost,
    /// nacked, or too late): re-absorb the staged message and resume.
    Missed { r: usize },
    /// Worker `r`'s broadcast was lost: re-anchor and resume without it.
    DownMissed { r: usize },
    /// Round `t`'s deadline: force-close every open round up to `t`.
    RoundDeadline { t: usize },
}

/// One worker's simulation shell around its protocol core.
struct SimWorker {
    core: WorkerCore,
    profile: ClientProfile,
    /// Per-step straggler Bernoulli stream (`None` when `straggler_prob` is 0).
    straggler: Option<Pcg64>,
    churn: ChurnTrack,
    /// Index of the local step currently computing (or, while the worker is
    /// blocked on a sync round-trip, the step it synced at).
    step: usize,
    done: bool,
    /// An update that left the worker but will never be folded (dropped,
    /// corrupt-nacked, or salvaged from a force-closed round). Consumed by
    /// [`Ev::Missed`] / a late [`Ev::UploadArrived`], which re-absorb it.
    lost: Option<Message>,
    /// Two-slot ‖m‖² tracker: because a worker blocks until its sync's
    /// broadcast returns, at most one of its syncs is ever unprocessed by
    /// the master — so the memory value any eval cutoff needs is either the
    /// latest (`mem_cur`, produced at sync step `mem_cur_t`) or the one
    /// before it. No per-sync log required.
    mem_prev: f64,
    mem_cur: f64,
    mem_cur_t: usize,
}

impl SimWorker {
    /// ‖m‖² as of eval cutoff `cutoff` (= eval step − 1; −1 for step 0):
    /// the engine's `mem_norm_sq` after all rounds `t ≤ cutoff`.
    fn mem_at(&self, cutoff: i64) -> f64 {
        if self.mem_cur_t as i64 <= cutoff {
            self.mem_cur
        } else {
            self.mem_prev
        }
    }
}

/// Buffered state of one aggregation round while its participants trickle in.
struct RoundBuf {
    /// Global step of the round.
    t: usize,
    /// |schedule ∩ sampled participation| — static, churn-independent.
    expected: usize,
    /// Arrived uploads + skip notices received so far.
    reports: usize,
    /// Arrived messages, slot-per-worker (worker-order fold needs no sort).
    msgs: Vec<Option<Message>>,
    arrived: Vec<bool>,
}

impl RoundBuf {
    fn empty() -> Self {
        RoundBuf { t: 0, expected: 0, reports: 0, msgs: Vec::new(), arrived: Vec::new() }
    }

    fn reset(&mut self, t: usize, expected: usize, workers: usize) {
        self.t = t;
        self.expected = expected;
        self.reports = 0;
        self.msgs.clear();
        self.msgs.resize_with(workers, || None);
        self.arrived.clear();
        self.arrived.resize(workers, false);
    }
}

/// One eval point's virtual-time view (parallel to `History::points`).
#[derive(Clone, Copy, Debug)]
pub struct SimPoint {
    /// Global eval step (same grid as the paired `MetricPoint`).
    pub step: usize,
    /// Virtual tick at which the model state of this eval became final.
    pub ticks: u64,
    /// `ticks` converted through `SimSpec::ticks_per_sec`.
    pub secs: f64,
    /// FNV-1a digest of (model bits, clock, queue length) — the
    /// determinism-twin fingerprint.
    pub state_hash: u64,
}

/// A finished simulation: the engine-compatible metric history plus the
/// virtual-time track.
pub struct SimResult {
    /// Bit-identical to `engine::run` whenever churn skipped no sync.
    pub history: History,
    /// One entry per `history.points` entry, same order.
    pub points: Vec<SimPoint>,
    /// Total events processed (a cheap workload fingerprint).
    pub events: u64,
    /// Virtual tick of the last event (when the slowest worker finished).
    pub final_ticks: u64,
    /// Copied from the spec, so consumers can convert without re-plumbing it.
    pub ticks_per_sec: u64,
}

impl SimResult {
    /// Total simulated wall-clock seconds.
    pub fn final_secs(&self) -> f64 {
        self.final_ticks as f64 / self.ticks_per_sec as f64
    }

    /// Simulated seconds until train loss first reaches `target`
    /// (`None` if it never does) — the fig13 headline measurement.
    pub fn secs_to_loss(&self, target: f64) -> Option<f64> {
        self.history
            .points
            .iter()
            .zip(&self.points)
            .find(|(m, _)| m.train_loss <= target)
            .map(|(_, p)| p.secs)
    }
}

/// Simulate a full training job from the zero init (the paper's convex
/// setting). `spec.threads` is ignored: the simulator is single-threaded by
/// construction — determinism comes from the event order, not thread count.
pub fn run(spec: &TrainSpec, sim: &SimSpec) -> SimResult {
    run_from(spec, sim, vec![0.0f32; spec.model.dim()])
}

/// As [`run`], from explicit initial parameters (non-convex figures).
pub fn run_from(spec: &TrainSpec, sim: &SimSpec, global: Vec<f32>) -> SimResult {
    run_from_faulty(spec, sim, None, global)
}

/// As [`run_from`], over a faulty network. `faults: None` (or an inactive
/// spec) takes the exact fault-free code paths, so existing histories are
/// preserved structurally.
pub fn run_from_faulty(
    spec: &TrainSpec,
    sim: &SimSpec,
    faults: Option<&FaultSpec>,
    global: Vec<f32>,
) -> SimResult {
    sim.validate().expect("invalid SimSpec");
    if let Some(f) = faults {
        f.validate().expect("invalid FaultSpec");
    }
    let plan = faults.copied().and_then(FaultPlan::new);
    let d = spec.model.dim();
    assert_eq!(global.len(), d);
    assert!(spec.workers >= 1);
    assert!(spec.eval_every >= 1, "eval_every must be >= 1");
    let r_count = spec.workers;
    let shards = shard_indices(spec.train, r_count, spec.sharding);
    let dense_down = spec.down_compressor.is_identity();

    let workers: Vec<SimWorker> = (0..r_count)
        .map(|r| SimWorker {
            core: WorkerCore::new(
                r,
                global.clone(),
                shards[r].clone(),
                spec.batch,
                spec.momentum,
                spec.seed,
            ),
            profile: ClientProfile::draw(sim, spec.seed, r),
            straggler: (sim.straggler_prob > 0.0)
                .then(|| Pcg64::new(spec.seed ^ SIM_STRAGGLER_RNG_SALT, r as u64 + 1)),
            churn: ChurnTrack::new(sim, spec.seed, r),
            step: 0,
            done: false,
            lost: None,
            mem_prev: 0.0,
            mem_cur: 0.0,
            mem_cur_t: 0,
        })
        .collect();
    let mut master = MasterCore::new(global, r_count, spec.seed, !dense_down);
    master.set_agg_scale(spec.agg_scale);
    master.set_server_opt(spec.server_opt);

    // Static round table: rounds exist where the schedule ∩ sampled
    // participation is non-empty, independent of timing and churn.
    // Pre-sized so run setup costs a fixed number of allocations
    // regardless of step count (the steady-state alloc probe diffs a
    // 2N-step run against an N-step run and expects exact cancellation).
    let mut round_steps: Vec<usize> = Vec::with_capacity(spec.steps);
    let mut round_expected: Vec<usize> = Vec::with_capacity(spec.steps);
    for t in 0..spec.steps {
        let expected = (0..r_count)
            .filter(|&r| spec.schedule.syncs_at(r, t) && spec.participation.participates(r, t))
            .count();
        if expected > 0 {
            round_steps.push(t);
            round_expected.push(expected);
        }
    }
    // The engine's eval grid, verbatim (pre-sized, same reason as above).
    let mut eval_steps = Vec::with_capacity(spec.steps / spec.eval_every + 2);
    eval_steps.push(0usize);
    eval_steps.extend((1..=spec.steps).filter(|&s| s % spec.eval_every == 0 || s == spec.steps));

    let mut sim_state = Sim {
        spec,
        sim: *sim,
        plan,
        dim: d,
        dense_down,
        eval: EvalSets::new(spec),
        workers,
        master,
        down_bufs: (0..r_count).map(|_| MessageBuf::new()).collect(),
        down_snaps: vec![None; r_count],
        // Each live worker owns exactly one queued event, so occupancy is
        // bounded by the worker count: pre-size once, never regrow.
        queue: EventQueue::with_capacity(r_count + 1),
        round_steps,
        round_expected,
        next_round_idx: 0,
        pending: VecDeque::new(),
        pool: Vec::new(),
        bits_up: 0,
        bits_down: 0,
        history: History::new(),
        points: Vec::with_capacity(eval_steps.len()),
        eval_steps,
        next_eval: 0,
    };
    sim_state.run()
}

struct Sim<'s, 'a> {
    spec: &'s TrainSpec<'a>,
    sim: SimSpec,
    /// Stateless fault injector; `None` = reliable network (the exact
    /// pre-fault code paths).
    plan: Option<FaultPlan>,
    dim: usize,
    dense_down: bool,
    eval: EvalSets,
    workers: Vec<SimWorker>,
    master: MasterCore,
    /// Per-worker compressed-downlink payload awaiting its `DownArrived`.
    down_bufs: Vec<MessageBuf>,
    /// Per-worker dense-downlink payload (one model snapshot per round,
    /// shared via `Arc` by all that round's recipients).
    down_snaps: Vec<Option<Arc<[f32]>>>,
    queue: EventQueue<Ev>,
    round_steps: Vec<usize>,
    round_expected: Vec<usize>,
    /// Index into `round_steps` of the next unprocessed round.
    next_round_idx: usize,
    /// Open rounds, contiguous from `next_round_idx` (front = oldest).
    pending: VecDeque<RoundBuf>,
    /// Recycled round buffers — the steady state allocates none.
    pool: Vec<RoundBuf>,
    bits_up: u64,
    bits_down: u64,
    history: History,
    points: Vec<SimPoint>,
    eval_steps: Vec<usize>,
    next_eval: usize,
}

impl Sim<'_, '_> {
    fn run(mut self) -> SimResult {
        if self.spec.steps > 0 {
            for r in 0..self.workers.len() {
                self.schedule_step(r, 0);
            }
        }
        // Evals wholly before the first round (step-0 snapshot; everything,
        // if there are no rounds) are final at tick 0.
        self.flush_evals(0);
        let mut clock = 0u64;
        while let Some((time, ev)) = self.queue.pop() {
            debug_assert!(time >= clock, "virtual time ran backwards");
            clock = time;
            self.handle(ev, clock);
        }
        debug_assert!(self.pending.is_empty(), "undrained round at exit");
        self.flush_evals(clock);
        debug_assert_eq!(self.next_eval, self.eval_steps.len(), "missed eval points");
        let events = self.queue.pushed();
        let mut history = self.history;
        history.final_params = self.master.into_params();
        SimResult {
            history,
            points: self.points,
            events,
            final_ticks: clock,
            ticks_per_sec: self.sim.ticks_per_sec,
        }
    }

    fn handle(&mut self, ev: Ev, clock: u64) {
        match ev {
            Ev::StepDone { r } => {
                let t = {
                    let w = &mut self.workers[r];
                    let t = w.step;
                    w.core.local_step(self.spec.model, self.spec.train, self.spec.lr.at(t));
                    t
                };
                let syncs = self.spec.schedule.syncs_at(r, t)
                    && self.spec.participation.participates(r, t);
                if !syncs {
                    self.advance(r, clock);
                } else if self.plan.map_or(false, |p| p.crash_at(r, t)) {
                    // Crash-restart at the sync point: volatile state (error
                    // memory, momentum velocity) is gone; restart from the
                    // last anchor. Unlike a lost message this loses signal.
                    let w = &mut self.workers[r];
                    w.core.crash_restart();
                    w.mem_prev = w.mem_cur;
                    w.mem_cur = 0.0;
                    w.mem_cur_t = t;
                    if self.round_open(t) {
                        self.report_skip(t, r, clock);
                        self.process_ready_rounds(clock);
                    }
                    self.advance(r, clock);
                } else if !self.workers[r].churn.online_at(clock) {
                    // Offline at the sync point: the device keeps training,
                    // the link is down. Tell the master not to wait (a
                    // control-plane notice, not wire traffic) and move on;
                    // uplink memory and both anchors stay frozen, so the
                    // error-feedback recursion is untouched.
                    if self.round_open(t) {
                        self.report_skip(t, r, clock);
                        self.process_ready_rounds(clock);
                    }
                    self.advance(r, clock);
                } else if self.round_open(t) {
                    self.begin_upload(r, t, clock);
                } else {
                    // This straggler reached its sync only after the round's
                    // deadline already closed it. The update still goes
                    // through the EF recursion (and the wire, briefly) but
                    // cannot join the round: stage it as lost and re-absorb
                    // after the "too late" nack returns.
                    let msg = {
                        let w = &mut self.workers[r];
                        let _ = w.core.make_update(self.spec.compressor);
                        w.mem_prev = w.mem_cur;
                        w.mem_cur = w.core.mem_norm_sq();
                        w.mem_cur_t = t;
                        w.core.take_update()
                    };
                    self.workers[r].lost = Some(msg);
                    self.queue.push(clock + self.sim.latency.max(1), Ev::Missed { r });
                }
            }
            Ev::UploadArrived { r, t } => {
                if !self.round_open(t) {
                    // The round force-closed before this delivery: a late
                    // original was salvaged into `lost` at force-close and
                    // is re-absorbed now; a duplicate of an already-folded
                    // copy finds nothing and is a no-op.
                    self.recover_lost(r, clock);
                } else {
                    let corrupt = matches!(
                        self.plan.map(|p| p.decide(r, t, Channel::Up)),
                        Some(FaultAction::Corrupt)
                    );
                    let idx = self.ensure_round(t, clock);
                    let buf = &mut self.pending[idx];
                    if buf.arrived[r] {
                        // Duplicate delivery: already applied once for this
                        // (worker, round) — dedup makes the copy a no-op.
                    } else if corrupt {
                        // Mangled in flight: the master's decode fails, so
                        // it logs + drops and nacks at once (the round need
                        // not wait for its deadline). The worker re-absorbs
                        // when the nack lands.
                        buf.reports += 1;
                        let msg = buf.msgs[r].take();
                        self.workers[r].lost = msg;
                        self.queue.push(clock + self.sim.latency.max(1), Ev::Missed { r });
                        self.process_ready_rounds(clock);
                    } else {
                        debug_assert!(buf.msgs[r].is_some(), "arrival without a staged message");
                        buf.arrived[r] = true;
                        buf.reports += 1;
                        self.process_ready_rounds(clock);
                        // The worker stays blocked until `DownArrived`.
                    }
                }
            }
            Ev::DownArrived { r } => {
                if self.dense_down {
                    let snap = self.down_snaps[r].take().expect("DownArrived without payload");
                    self.workers[r].core.apply_dense_broadcast(&snap);
                } else {
                    self.workers[r].core.apply_delta_broadcast(self.down_bufs[r].message());
                }
                self.advance(r, clock);
            }
            Ev::Missed { r } => self.recover_lost(r, clock),
            Ev::DownMissed { r } => {
                // The broadcast never arrived; the master's downlink mirror
                // was never advanced for us, so continuing from the stale
                // anchor keeps the implicit downlink EF consistent.
                self.workers[r].core.miss_broadcast();
                self.advance(r, clock);
            }
            Ev::RoundDeadline { t } => self.force_close_through(t, clock),
        }
    }

    /// Is round `t` still unprocessed (pending or not yet opened)?
    fn round_open(&self, t: usize) -> bool {
        self.round_steps[self.next_round_idx..].binary_search(&t).is_ok()
    }

    /// Re-absorb a lost update staged in `lost`: fold it back into the
    /// error memory (bitwise `m ← m + g` — see `ErrorMemory::absorb`),
    /// account its spent wire bits, and resume computing from the stale
    /// anchor. A no-op when nothing is staged (duplicate deliveries).
    fn recover_lost(&mut self, r: usize, clock: u64) {
        if let Some(msg) = self.workers[r].lost.take() {
            self.bits_up += msg.wire_bits_with(self.spec.codec);
            let w = &mut self.workers[r];
            w.core.reabsorb_update(&msg);
            w.core.recycle_update(msg);
            // The memory changed at the sync step it was produced for.
            w.mem_cur = w.core.mem_norm_sq();
            self.advance(r, clock);
        }
    }

    /// Deadline expiry: force-close every still-open round with step ≤ `t`,
    /// oldest first, folding what arrived. Staged-but-unarrived messages
    /// are salvaged back to their workers, whose in-flight timeout or late
    /// arrival re-absorbs them.
    fn force_close_through(&mut self, t: usize, clock: u64) {
        while self.pending.front().map_or(false, |b| b.t <= t) {
            let mut buf = self.pending.pop_front().expect("checked non-empty");
            for r in 0..self.workers.len() {
                if !buf.arrived[r] {
                    if let Some(msg) = buf.msgs[r].take() {
                        self.workers[r].lost = Some(msg);
                    }
                }
            }
            self.process_round(&mut buf, clock);
            self.next_round_idx += 1;
            self.pool.push(buf);
            self.flush_evals(clock);
        }
        self.process_ready_rounds(clock);
    }

    /// Compress + stage worker `r`'s update for round `t` and put its
    /// upload on the wire (through the fault injector, if any). The worker
    /// then blocks awaiting the broadcast — or its loss timeout.
    fn begin_upload(&mut self, r: usize, t: usize, clock: u64) {
        let (msg, bw) = {
            let w = &mut self.workers[r];
            let _ = w.core.make_update(self.spec.compressor);
            // The two-slot memory tracker advances exactly at update
            // creation, mirroring when the engine's `mem_norm_sq` changes.
            w.mem_prev = w.mem_cur;
            w.mem_cur = w.core.mem_norm_sq();
            w.mem_cur_t = t;
            (w.core.take_update(), w.profile.bw)
        };
        let wire_bits = msg.wire_bits_with(self.spec.codec);
        let idx = self.ensure_round(t, clock);
        let dur = transfer_ticks(wire_bits, bw, self.sim.latency);
        let action = match &self.plan {
            Some(p) => p.decide(r, t, Channel::Up),
            None => FaultAction::Deliver,
        };
        match action {
            FaultAction::Drop => {
                // Never reaches the master. The worker's own round-trip
                // timer expires just after the round deadline would have;
                // it then re-absorbs and resumes.
                let timeout = self.plan.as_ref().map_or(0, |p| p.deadline_ticks());
                self.workers[r].lost = Some(msg);
                self.queue.push(clock + timeout + self.sim.latency.max(1), Ev::Missed { r });
            }
            FaultAction::Delay(extra) => {
                self.pending[idx].msgs[r] = Some(msg);
                self.queue.push(clock + dur + extra, Ev::UploadArrived { r, t });
            }
            FaultAction::Duplicate => {
                self.pending[idx].msgs[r] = Some(msg);
                self.queue.push(clock + dur, Ev::UploadArrived { r, t });
                self.queue
                    .push(clock + dur + self.sim.latency.max(1), Ev::UploadArrived { r, t });
            }
            FaultAction::Deliver | FaultAction::Corrupt => {
                // Corruption is detected at arrival (the decode fails on
                // the master); on the wire the two look the same.
                self.pending[idx].msgs[r] = Some(msg);
                self.queue.push(clock + dur, Ev::UploadArrived { r, t });
            }
        }
    }

    /// Schedule worker `r`'s next local step after the current one (or,
    /// from `StepDone`/`DownArrived`, after finishing step `r.step`).
    fn advance(&mut self, r: usize, clock: u64) {
        let t = self.workers[r].step;
        if t + 1 >= self.spec.steps {
            self.workers[r].done = true;
            return;
        }
        self.workers[r].step = t + 1;
        self.schedule_step(r, clock);
    }

    /// Push `StepDone` for worker `r`'s current step: base compute ticks,
    /// straggler-multiplied when the per-step Bernoulli hits.
    fn schedule_step(&mut self, r: usize, clock: u64) {
        let w = &mut self.workers[r];
        let base = w.profile.compute_ticks;
        let hit = match &mut w.straggler {
            Some(rng) => rng.f64() < self.sim.straggler_prob,
            None => false,
        };
        let dur = if hit {
            ((base as f64) * self.sim.straggler_mult).round().max(1.0) as u64
        } else {
            base
        };
        self.queue.push(clock + dur, Ev::StepDone { r });
    }

    /// Index (within `pending`) of round `t`'s buffer, opening buffers —
    /// from the pool when possible — up to and including it. Under a fault
    /// plan with a deadline, every newly opened round schedules its
    /// force-close.
    fn ensure_round(&mut self, t: usize, clock: u64) -> usize {
        let pos = self.round_steps[self.next_round_idx..]
            .binary_search(&t)
            .expect("sync report for a step with no round");
        while self.pending.len() <= pos {
            let i = self.next_round_idx + self.pending.len();
            let step = self.round_steps[i];
            let mut buf = self.pool.pop().unwrap_or_else(RoundBuf::empty);
            buf.reset(step, self.round_expected[i], self.workers.len());
            self.pending.push_back(buf);
            if let Some(deadline) = self.plan.map(|p| p.deadline_ticks()).filter(|&d| d > 0) {
                self.queue.push(clock + deadline, Ev::RoundDeadline { t: step });
            }
        }
        pos
    }

    fn report_skip(&mut self, t: usize, r: usize, clock: u64) {
        let _ = r;
        let idx = self.ensure_round(t, clock);
        self.pending[idx].reports += 1;
    }

    /// Process every fully-reported round at the front of the line, oldest
    /// first — rounds never complete out of order, which is what pins the
    /// fold sequence to the engine's.
    fn process_ready_rounds(&mut self, clock: u64) {
        while self.pending.front().map_or(false, |b| b.reports == b.expected) {
            let mut buf = self.pending.pop_front().expect("checked non-empty");
            self.process_round(&mut buf, clock);
            self.next_round_idx += 1;
            self.pool.push(buf);
            // Eagerly emit evals this round unlocked (eagerness keeps the
            // two-slot memory tracker sufficient: no worker can stage
            // another sync before its previous round is processed).
            self.flush_evals(clock);
        }
    }

    /// The engine's round body: fold in worker order, close the server
    /// round, broadcast to the workers that arrived. A round whose every
    /// expected participant skipped moves no state at all.
    fn process_round(&mut self, buf: &mut RoundBuf, clock: u64) {
        let arrived_n = buf.arrived.iter().filter(|&&a| a).count();
        if arrived_n == 0 {
            return;
        }
        self.master.begin_round(arrived_n);
        for r in 0..self.workers.len() {
            if let Some(msg) = buf.msgs[r].take() {
                self.bits_up += msg.wire_bits_with(self.spec.codec);
                self.master.apply_update(&msg).expect("sim-internal update dim mismatch");
                self.workers[r].core.recycle_update(msg);
            }
        }
        self.master.end_round();
        for r in 0..self.workers.len() {
            if !buf.arrived[r] {
                continue;
            }
            // Downlink faults are decided *before* encoding: the master's
            // per-worker downlink mirror never advances for a skipped
            // broadcast, so the implicit downlink error feedback stays
            // consistent and the dropped delta is re-offered next sync.
            // (A corrupted broadcast is modeled as a drop here; real byte
            // corruption is the threaded coordinator's territory.)
            if matches!(
                self.plan.map(|p| p.decide(r, buf.t, Channel::Down)),
                Some(FaultAction::Drop) | Some(FaultAction::Corrupt)
            ) {
                self.queue.push(clock + self.sim.latency.max(1), Ev::DownMissed { r });
                continue;
            }
            let bits = if self.dense_down {
                self.down_snaps[r] = Some(self.master.params_snapshot());
                encode::dense_model_bits(self.dim)
            } else {
                self.master.delta_broadcast_into(
                    r,
                    self.spec.down_compressor,
                    &mut self.down_bufs[r],
                );
                self.down_bufs[r].message().wire_bits_with(self.spec.codec)
            };
            self.bits_down += bits;
            let dur = transfer_ticks(bits, self.workers[r].profile.bw, self.sim.latency);
            self.queue.push(clock + dur, Ev::DownArrived { r });
        }
    }

    /// Emit every eval-grid step whose model state is now final: grid step
    /// `s` needs all rounds with step ≤ s − 1 processed.
    fn flush_evals(&mut self, clock: u64) {
        while let Some(&s) = self.eval_steps.get(self.next_eval) {
            let covered = match self.round_steps.get(self.next_round_idx) {
                None => true,
                Some(&rt) => rt >= s,
            };
            if !covered {
                break;
            }
            self.emit_eval(s, clock);
            self.next_eval += 1;
        }
    }

    fn emit_eval(&mut self, s: usize, clock: u64) {
        let cutoff = s as i64 - 1;
        // Worker-index-order f64 sum — the exact `engine::avg_mem` fold.
        let mem = self.workers.iter().map(|w| w.mem_at(cutoff)).sum::<f64>()
            / self.workers.len() as f64;
        self.history.push(self.eval.measure(
            self.spec,
            s,
            self.master.params(),
            self.bits_up,
            self.bits_down,
            mem,
        ));
        self.points.push(SimPoint {
            step: s,
            ticks: clock,
            secs: clock as f64 / self.sim.ticks_per_sec as f64,
            state_hash: state_hash(self.master.params(), clock, self.queue.len()),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::TopK;
    use crate::data::gaussian_clusters;
    use crate::engine;
    use crate::grad::SoftmaxRegression;
    use crate::optim::LrSchedule;
    use crate::topology::FixedPeriod;

    fn setup() -> (crate::data::Dataset, SoftmaxRegression) {
        let ds = gaussian_clusters(160, 8, 3, 2.0, 0.4, 7);
        let model = SoftmaxRegression::new(8, 3, 1.0 / 160.0);
        (ds, model)
    }

    fn base_spec<'a>(
        model: &'a SoftmaxRegression,
        ds: &'a crate::data::Dataset,
        comp: &'a dyn crate::compress::Compressor,
        sched: &'a FixedPeriod,
    ) -> TrainSpec<'a> {
        let mut spec = TrainSpec::new(model, ds, comp, sched);
        spec.workers = 3;
        spec.steps = 40;
        spec.eval_every = 8;
        spec.lr = LrSchedule::Const { eta: 0.4 };
        spec
    }

    /// The core contract: heterogeneous timing changes the clock, never the
    /// arithmetic — the sim `History` matches the engine bit for bit.
    #[test]
    fn parity_with_engine_even_under_skewed_timing() {
        let (ds, model) = setup();
        let topk = TopK::new(4);
        let sched = FixedPeriod::new(4);
        let spec = base_spec(&model, &ds, &topk, &sched);
        let engine_h = engine::run(&spec);
        let sim = SimSpec {
            compute_sigma: 0.9,
            bw_sigma: 0.7,
            latency: 500,
            straggler_prob: 0.2,
            straggler_mult: 6.0,
            ..SimSpec::default()
        };
        let res = run(&spec, &sim);
        assert_eq!(res.history.points.len(), engine_h.points.len());
        for (a, b) in res.history.points.iter().zip(&engine_h.points) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "step {}", a.step);
            assert_eq!(a.bits_up, b.bits_up);
            assert_eq!(a.bits_down, b.bits_down);
            assert_eq!(a.mem_norm_sq.to_bits(), b.mem_norm_sq.to_bits(), "step {}", a.step);
        }
        assert_eq!(res.history.final_params, engine_h.final_params);
        assert_eq!(res.points.len(), res.history.points.len());
        assert!(res.final_ticks > 0);
    }

    /// Ticks must be monotone over eval points and scale with the clock
    /// resolution; slower clients make the same run take longer.
    #[test]
    fn virtual_time_is_monotone_and_reacts_to_compute_speed() {
        let (ds, model) = setup();
        let topk = TopK::new(4);
        let sched = FixedPeriod::new(2);
        let spec = base_spec(&model, &ds, &topk, &sched);
        let fast = run(&spec, &SimSpec { compute_mean: 100.0, ..SimSpec::default() });
        let slow = run(&spec, &SimSpec { compute_mean: 10_000.0, ..SimSpec::default() });
        let ticks: Vec<u64> = fast.points.iter().map(|p| p.ticks).collect();
        assert!(ticks.windows(2).all(|w| w[0] <= w[1]), "non-monotone: {ticks:?}");
        assert!(slow.final_ticks > 10 * fast.final_ticks);
        assert_eq!(fast.history.final_params, slow.history.final_params, "timing moved arithmetic");
    }

    /// Churn must not deadlock or corrupt rounds: every round still
    /// completes (arrived + skipped = expected) and the run drains.
    #[test]
    fn churn_completes_and_diverges_from_engine_only_in_bits() {
        let (ds, model) = setup();
        let topk = TopK::new(4);
        let sched = FixedPeriod::new(2);
        let mut spec = base_spec(&model, &ds, &topk, &sched);
        spec.steps = 60;
        let sim = SimSpec {
            churn_online_mean: 40_000,
            churn_offline_mean: 40_000,
            ..SimSpec::default()
        };
        let res = run(&spec, &sim);
        let no_churn = run(&spec, &SimSpec::default());
        assert_eq!(res.history.points.len(), no_churn.history.points.len());
        let b_churn = res.history.points.last().unwrap().bits_up;
        let b_full = no_churn.history.points.last().unwrap().bits_up;
        assert!(b_churn < b_full, "churn skipped no sync: {b_churn} vs {b_full}");
        // Twin determinism under churn.
        let twin = run(&spec, &sim);
        let hashes: Vec<u64> = res.points.iter().map(|p| p.state_hash).collect();
        let twin_hashes: Vec<u64> = twin.points.iter().map(|p| p.state_hash).collect();
        assert_eq!(hashes, twin_hashes);
        assert_eq!(res.events, twin.events);
    }

    /// A dup/delay-only scenario loses no signal: duplicates dedup, delays
    /// only move the clock, and rounds stay barriers (no deadline). The
    /// `History` must equal the fault-free run bit for bit — the sim-side
    /// idempotence + reordering guarantee.
    #[test]
    fn dup_and_delay_only_matches_faultless_bit_for_bit() {
        let (ds, model) = setup();
        let topk = TopK::new(4);
        let sched = FixedPeriod::new(2);
        let spec = base_spec(&model, &ds, &topk, &sched);
        let sim = SimSpec { latency: 800, bw_sigma: 0.6, ..SimSpec::default() };
        let clean = run_from_faulty(&spec, &sim, None, vec![0.0; model.dim()]);
        let faults = crate::faults::FaultSpec {
            seed: 5,
            dup_up: 0.4,
            delay_up: 0.4,
            delay_ticks: 20_000,
            ..Default::default()
        };
        let lossy = run_from_faulty(&spec, &sim, Some(&faults), vec![0.0; model.dim()]);
        assert_eq!(lossy.history.points.len(), clean.history.points.len());
        for (a, b) in lossy.history.points.iter().zip(&clean.history.points) {
            assert_eq!(a.step, b.step);
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "step {}", a.step);
            assert_eq!(a.bits_up, b.bits_up, "step {}", a.step);
            assert_eq!(a.bits_down, b.bits_down, "step {}", a.step);
            assert_eq!(a.mem_norm_sq.to_bits(), b.mem_norm_sq.to_bits(), "step {}", a.step);
        }
        assert_eq!(lossy.history.final_params, clean.history.final_params);
        // Duplication put extra events on the wire.
        assert!(lossy.events > clean.events, "{} vs {}", lossy.events, clean.events);
    }

    /// The full fault cocktail must drain (no deadlock), converge in the
    /// same ballpark, and be twin-deterministic: same fault seed ⇒ same
    /// state-hash sequence and event count.
    #[test]
    fn fault_cocktail_drains_and_twins_agree() {
        let (ds, model) = setup();
        let topk = TopK::new(4);
        let sched = FixedPeriod::new(2);
        let mut spec = base_spec(&model, &ds, &topk, &sched);
        spec.steps = 60;
        let sim = SimSpec {
            compute_sigma: 0.6,
            bw_sigma: 0.5,
            latency: 1_000,
            straggler_prob: 0.05,
            straggler_mult: 6.0,
            ..SimSpec::default()
        };
        let faults = crate::faults::FaultSpec {
            seed: 21,
            drop_up: 0.15,
            corrupt_up: 0.05,
            dup_up: 0.1,
            delay_up: 0.1,
            delay_ticks: 30_000,
            drop_down: 0.08,
            corrupt_down: 0.02,
            crash: 0.01,
            deadline_ticks: 60_000,
        };
        let a = run_from_faulty(&spec, &sim, Some(&faults), vec![0.0; model.dim()]);
        let b = run_from_faulty(&spec, &sim, Some(&faults), vec![0.0; model.dim()]);
        assert_eq!(a.history.points.len(), b.history.points.len());
        let ha: Vec<u64> = a.points.iter().map(|p| p.state_hash).collect();
        let hb: Vec<u64> = b.points.iter().map(|p| p.state_hash).collect();
        assert_eq!(ha, hb, "fault twins diverged");
        assert_eq!(a.events, b.events);
        assert_eq!(a.history.final_params, b.history.final_params);
        // Loss still improves despite the lossy network (EF re-absorption).
        let first = a.history.points.first().unwrap().train_loss;
        let last = a.history.points.last().unwrap().train_loss;
        assert!(last < first, "no progress under faults: {first} → {last}");
        // A different fault seed must produce a different trajectory.
        let other = crate::faults::FaultSpec { seed: 22, ..faults };
        let c = run_from_faulty(&spec, &sim, Some(&other), vec![0.0; model.dim()]);
        let hc: Vec<u64> = c.points.iter().map(|p| p.state_hash).collect();
        assert_ne!(ha, hc, "fault seed had no effect");
    }

    /// secs_to_loss finds the first crossing on the sim clock.
    #[test]
    fn secs_to_loss_reports_first_crossing() {
        let (ds, model) = setup();
        let topk = TopK::new(4);
        let sched = FixedPeriod::new(2);
        let spec = base_spec(&model, &ds, &topk, &sched);
        let res = run(&spec, &SimSpec::default());
        let first = res.history.points.first().unwrap().train_loss;
        let last = res.history.points.last().unwrap().train_loss;
        assert!(last < first, "loss did not improve: {first} → {last}");
        let mid = 0.5 * (first + last);
        let secs = res.secs_to_loss(mid).expect("crossed the midpoint");
        assert!(secs > 0.0 && secs <= res.final_secs());
        assert_eq!(res.secs_to_loss(f64::NEG_INFINITY), None);
        assert_eq!(res.secs_to_loss(f64::INFINITY), Some(res.points[0].secs));
    }
}
