//! Seeded per-client network/compute profiles and failure processes.
//!
//! Every stochastic simulator input draws from its own salted `Pcg64`
//! stream keyed by `(run seed, worker id)`, mirroring the uplink/downlink/
//! participation salt discipline in `protocol::` and `topology::` — so the
//! sampled profiles are a pure function of the spec and never depend on
//! event-processing order:
//!
//! * **profile** (`SIM_PROFILE_RNG_SALT`): one-shot per-client compute
//!   speed and link bandwidth, drawn lognormal-ish around the spec means.
//! * **straggler** (`SIM_STRAGGLER_RNG_SALT`): a per-step Bernoulli draw;
//!   a hit multiplies that step's compute time by `straggler_mult`
//!   (transient slowdown — GC pause, co-tenant burst, thermal throttle).
//! * **churn** (`SIM_CHURN_RNG_SALT`): alternating online/offline windows
//!   on the virtual clock. A worker that reaches a sync point while
//!   offline *skips* that round (no upload, no broadcast); its anchor and
//!   error memory are untouched, so the error-feedback downlink recursion
//!   stays valid across arbitrarily long outages — reconnection needs no
//!   special arithmetic, the next participated round simply carries the
//!   accumulated staleness.

use super::SimSpec;
use crate::util::rng::Pcg64;

/// Stream salt for per-client profile draws (distinct from the uplink
/// `0xc0ffee`, downlink `0xd05eed`, participation `0x5e7ec7`, async-schedule
/// `0xa5ce9d`, schedule-materialize `0x5eed` and eval `0xe7a1` salts).
pub const SIM_PROFILE_RNG_SALT: u64 = 0x513a11;
/// Stream salt for the per-step straggler Bernoulli process.
pub const SIM_STRAGGLER_RNG_SALT: u64 = 0x57a616;
/// Stream salt for the churn (drop/reconnect) window process.
pub const SIM_CHURN_RNG_SALT: u64 = 0xc6a12d;

/// `mean · exp(sigma · z)`, z ~ N(0, 1) — the "lognormal-ish" family used
/// for every duration/rate draw. `sigma = 0` gives exactly `mean` (the
/// multiplier is `exp(0) = 1.0`, exact in IEEE arithmetic), which is what
/// makes homogeneous degenerate configs reproducible without special cases.
pub(crate) fn lognormalish(mean: f64, sigma: f64, rng: &mut Pcg64) -> f64 {
    mean * (sigma * rng.normal()).exp()
}

/// One client's static capacity, drawn once at simulation start.
#[derive(Clone, Copy, Debug)]
pub struct ClientProfile {
    /// Base virtual ticks per local SGD step.
    pub compute_ticks: u64,
    /// Link bandwidth in wire bits per virtual tick (symmetric up/down).
    pub bw: f64,
}

impl ClientProfile {
    /// Draw worker `r`'s profile from the salted stream. Draw order (compute
    /// first, then bandwidth) is part of the determinism contract.
    pub fn draw(sim: &SimSpec, seed: u64, r: usize) -> Self {
        let mut rng = Pcg64::new(seed ^ SIM_PROFILE_RNG_SALT, r as u64 + 1);
        let compute = lognormalish(sim.compute_mean, sim.compute_sigma, &mut rng);
        let bw = lognormalish(sim.bw_mean, sim.bw_sigma, &mut rng);
        ClientProfile {
            compute_ticks: (compute.round() as u64).max(1),
            bw: bw.max(f64::MIN_POSITIVE),
        }
    }
}

/// Wire-transfer duration: `ceil(bits / bandwidth) + latency` virtual ticks.
/// A zero-bit transfer costs only the propagation latency; any nonzero
/// payload costs at least one tick (the ceiling of a positive quotient).
/// `bits` is the message's *actual* `wire_bits` under the configured codec —
/// the simulator charges exactly what the wire format would carry.
pub fn transfer_ticks(bits: u64, bw_bits_per_tick: f64, latency: u64) -> u64 {
    if bits == 0 {
        return latency;
    }
    debug_assert!(bw_bits_per_tick > 0.0);
    latency + (bits as f64 / bw_bits_per_tick).ceil() as u64
}

/// Per-worker online/offline window process, advanced lazily.
///
/// Window durations alternate between lognormal-ish draws around
/// `churn_online_mean` and `churn_offline_mean`. Queries must come with
/// non-decreasing clocks (each worker's sync attempts do), so the track
/// walks forward through as many windows as the clock has passed. The whole
/// timeline is a pure function of `(seed, r)` — independent of every other
/// worker and of event order.
#[derive(Clone, Debug)]
pub struct ChurnTrack {
    rng: Option<Pcg64>,
    online_mean: f64,
    offline_mean: f64,
    sigma: f64,
    online: bool,
    window_end: u64,
}

impl ChurnTrack {
    pub fn new(sim: &SimSpec, seed: u64, r: usize) -> Self {
        if sim.churn_online_mean == 0 {
            // Churn disabled: always online, no stream consumed.
            return ChurnTrack {
                rng: None,
                online_mean: 0.0,
                offline_mean: 0.0,
                sigma: 0.0,
                online: true,
                window_end: u64::MAX,
            };
        }
        let mut rng = Pcg64::new(seed ^ SIM_CHURN_RNG_SALT, r as u64 + 1);
        let first = Self::window(sim.churn_online_mean as f64, sim.churn_sigma, &mut rng);
        ChurnTrack {
            rng: Some(rng),
            online_mean: sim.churn_online_mean as f64,
            offline_mean: sim.churn_offline_mean as f64,
            sigma: sim.churn_sigma,
            online: true,
            window_end: first,
        }
    }

    fn window(mean: f64, sigma: f64, rng: &mut Pcg64) -> u64 {
        (lognormalish(mean, sigma, rng).round() as u64).max(1)
    }

    /// Is this worker online at virtual time `clock`? Clocks must be
    /// non-decreasing across calls for one track.
    pub fn online_at(&mut self, clock: u64) -> bool {
        let Some(rng) = &mut self.rng else { return true };
        while clock >= self.window_end {
            self.online = !self.online;
            let mean = if self.online { self.online_mean } else { self.offline_mean };
            let dur = Self::window(mean, self.sigma, rng);
            self.window_end = self.window_end.saturating_add(dur);
        }
        self.online
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SimSpec {
        SimSpec::default()
    }

    #[test]
    fn transfer_rounding_is_ceiling_plus_latency() {
        // Exact division: 90 bits at 30 bits/tick = 3 ticks.
        assert_eq!(transfer_ticks(90, 30.0, 0), 3);
        // Fractional quotient rounds up: 100/30 = 3.33… → 4.
        assert_eq!(transfer_ticks(100, 30.0, 0), 4);
        // One bit on a fat pipe still costs one tick.
        assert_eq!(transfer_ticks(1, 1e9, 0), 1);
        // Latency is additive, and pure-latency for empty payloads.
        assert_eq!(transfer_ticks(100, 30.0, 7), 11);
        assert_eq!(transfer_ticks(0, 30.0, 7), 7);
        assert_eq!(transfer_ticks(0, 30.0, 0), 0);
    }

    #[test]
    fn profiles_deterministic_and_skewed_by_sigma() {
        let mut s = spec();
        let a = ClientProfile::draw(&s, 42, 3);
        let b = ClientProfile::draw(&s, 42, 3);
        assert_eq!(a.compute_ticks, b.compute_ticks);
        assert_eq!(a.bw, b.bw);
        // sigma = 0 ⇒ exactly the configured means for every client.
        assert_eq!(a.compute_ticks, s.compute_mean.round() as u64);
        assert_eq!(a.bw, s.bw_mean);
        // sigma > 0 ⇒ clients spread (overwhelmingly likely over 16 draws).
        s.compute_sigma = 0.8;
        let ticks: Vec<u64> =
            (0..16).map(|r| ClientProfile::draw(&s, 42, r).compute_ticks).collect();
        assert!(ticks.iter().any(|&t| t != ticks[0]), "no skew: {ticks:?}");
    }

    #[test]
    fn churn_disabled_is_always_online() {
        let mut t = ChurnTrack::new(&spec(), 1, 0);
        assert!(t.online_at(0));
        assert!(t.online_at(u64::MAX - 1));
    }

    #[test]
    fn churn_alternates_and_is_deterministic() {
        let mut s = spec();
        s.churn_online_mean = 1000;
        s.churn_offline_mean = 500;
        s.churn_sigma = 0.3;
        let sample = |seed: u64| -> Vec<bool> {
            let mut t = ChurnTrack::new(&s, seed, 2);
            (0..200).map(|i| t.online_at(i * 50)).collect()
        };
        let a = sample(9);
        assert_eq!(a, sample(9), "same seed, same timeline");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "never flips: {a:?}");
        assert_ne!(a, sample(10), "seed changes the timeline");
    }
}
