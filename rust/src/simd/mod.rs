//! Runtime-dispatched SIMD kernels for the compression hot path.
//!
//! The four hot stream kernels — top-k magnitude keying/threshold scan,
//! QSGD level quantization, the sparse-fold inner loops, and wire bit
//! pack/unpack — route through this module. `scalar.rs` is the reference
//! semantics (portable, `#![forbid(unsafe_code)]`); `avx2.rs` (x86_64) and
//! `neon.rs` (aarch64) are drop-in twins that must match it bit for bit,
//! property-tested here and proven end-to-end by `tests/integration_simd.rs`
//! (forced-scalar vs auto `History` parity).
//!
//! Dispatch idiom (after squirrel-json, SNIPPETS.md §2): one safe public
//! entry point per kernel, detection done once and cached in a `OnceLock`,
//! `#[target_feature]` inner fns behind wrappers that re-assert the guard.
//!
//! Controls:
//! - `QSPARSE_FORCE_SCALAR=1` (any value but `0`) pins detection to the
//!   portable path — the CI forced-fallback job runs the whole suite this
//!   way.
//! - [`force_backend`] is the in-process override benches and parity tests
//!   use for A/B runs; requests for an unavailable backend clamp to scalar.
//!
//! Because every backend is bit-identical, flipping the override mid-run
//! never changes any result — only which instructions compute it.

mod scalar;

#[cfg(target_arch = "x86_64")]
mod avx2;

#[cfg(target_arch = "aarch64")]
mod neon;

pub(crate) use scalar::ordered;

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Which kernel implementation the dispatcher selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Portable reference implementation (always available).
    Scalar,
    /// 8-lane f32 path on x86_64 with runtime-detected AVX2.
    Avx2,
    /// 4-lane f32 path on aarch64 with runtime-detected Neon.
    Neon,
}

impl Backend {
    /// Stable lowercase name (bench/report labels).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Neon => "neon",
        }
    }
}

/// Detection result, computed once per process.
static DETECTED: OnceLock<Backend> = OnceLock::new();

/// In-process override: 0 = none, 1 = scalar, 2 = avx2, 3 = neon.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn detect() -> Backend {
    if std::env::var_os("QSPARSE_FORCE_SCALAR").is_some_and(|v| v != "0") {
        return Backend::Scalar;
    }
    #[cfg(target_arch = "x86_64")]
    if std::arch::is_x86_feature_detected!("avx2") {
        return Backend::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return Backend::Neon;
    }
    Backend::Scalar
}

fn detected() -> Backend {
    *DETECTED.get_or_init(detect)
}

/// The backend the next kernel call will use.
pub fn active_backend() -> Backend {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => Backend::Scalar,
        2 => Backend::Avx2,
        3 => Backend::Neon,
        _ => detected(),
    }
}

/// Override dispatch for this process: `Some(backend)` pins every kernel to
/// that implementation (clamped to [`Backend::Scalar`] if the request is
/// not the detected backend — you can never force an ISA the CPU lacks, nor
/// escape `QSPARSE_FORCE_SCALAR`); `None` restores auto detection. Returns
/// the backend now in effect. Safe to flip at any time: all backends are
/// bit-identical, so concurrent kernel calls see at most a different speed.
pub fn force_backend(req: Option<Backend>) -> Backend {
    let det = detected();
    match req {
        None => {
            OVERRIDE.store(0, Ordering::Relaxed);
            det
        }
        Some(b) => {
            let eff = if b == det { b } else { Backend::Scalar };
            let code = match eff {
                Backend::Scalar => 1,
                Backend::Avx2 => 2,
                Backend::Neon => 3,
            };
            OVERRIDE.store(code, Ordering::Relaxed);
            eff
        }
    }
}

/// Append `(ordered(|x_i|) << 32) | i` for every element — the packed
/// introselect array of top-k selection. See [`scalar::pack_ordered_into`].
pub fn pack_ordered_into(x: &[f32], out: &mut Vec<u64>) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::pack_ordered_into(x, out),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::pack_ordered_into(x, out),
        _ => scalar::pack_ordered_into(x, out),
    }
}

/// Append packed candidates with magnitude key `≥ thresh` in index order;
/// `false` aborts the moment the cap would be exceeded. See
/// [`scalar::scan_threshold_into`].
pub fn scan_threshold_into(x: &[f32], thresh: u32, cap: usize, cand: &mut Vec<u64>) -> bool {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::scan_threshold_into(x, thresh, cap, cand),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::scan_threshold_into(x, thresh, cap, cand),
        _ => scalar::scan_threshold_into(x, thresh, cap, cand),
    }
}

/// Σ xᵢ² in f64 with the fixed stride-4 chunked reduction (identical
/// addition sequence on every backend). See [`scalar::norm2_sq_chunked`].
pub fn norm2_sq_chunked(x: &[f32]) -> f64 {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::norm2_sq_chunked(x),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::norm2_sq_chunked(x),
        _ => scalar::norm2_sq_chunked(x),
    }
}

/// One QSGD bucket's stochastic levels + signs; consumes exactly one
/// `rng.f32()` per element in element order on every backend. See
/// [`scalar::quantize_bucket_into`].
pub fn quantize_bucket_into(
    chunk: &[f32],
    inv: f32,
    s: u32,
    rng: &mut crate::util::rng::Pcg64,
    levels: &mut Vec<u32>,
    neg: &mut Vec<bool>,
) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::quantize_bucket_into(chunk, inv, s, rng, levels, neg),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::quantize_bucket_into(chunk, inv, s, rng, levels, neg),
        _ => scalar::quantize_bucket_into(chunk, inv, s, rng, levels, neg),
    }
}

/// `out[i] += scale * vals[i]` — dense fold inner loop. See
/// [`scalar::add_scaled`].
pub fn add_scaled(out: &mut [f32], vals: &[f32], scale: f32) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::add_scaled(out, vals, scale),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::add_scaled(out, vals, scale),
        _ => scalar::add_scaled(out, vals, scale),
    }
}

/// `out[i] += scale * (neg[i] ? -mag : mag)` — sign-message fold inner
/// loop. See [`scalar::add_signed`].
pub fn add_signed(out: &mut [f32], neg: &[bool], mag: f32, scale: f32) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::add_signed(out, neg, mag, scale),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::add_signed(out, neg, mag, scale),
        _ => scalar::add_signed(out, neg, mag, scale),
    }
}

/// Append each f32's big-endian byte image (`BitWriter` bulk-write helper).
/// See [`scalar::be_bytes_into`].
pub fn be_bytes_into(vals: &[f32], out: &mut Vec<u8>) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::be_bytes_into(vals, out),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::be_bytes_into(vals, out),
        _ => scalar::be_bytes_into(vals, out),
    }
}

/// Append `count` fixed-`width`-bit big-endian fields starting at absolute
/// bit `start_bit`. Caller guarantees the run lies inside `bytes`. See
/// [`scalar::unpack_fixed_into`].
pub fn unpack_fixed_into(
    bytes: &[u8],
    start_bit: u64,
    width: u32,
    count: usize,
    out: &mut Vec<u32>,
) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Avx2 => avx2::unpack_fixed_into(bytes, start_bit, width, count, out),
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => neon::unpack_fixed_into(bytes, start_bit, width, count, out),
        _ => scalar::unpack_fixed_into(bytes, start_bit, width, count, out),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Adversarial f32 soup: NaNs (both signs, odd payloads), ±0,
    /// denormals, ±inf, extremes, exact ties, then deterministic noise.
    /// Lengths are chosen by callers to straddle every lane boundary.
    fn adversarial(len: usize, seed: u64) -> Vec<f32> {
        let specials = [
            f32::NAN,
            -f32::NAN,
            f32::from_bits(0x7fc0_dead), // NaN, nonstandard payload
            f32::from_bits(0xffc0_0001), // -NaN, nonstandard payload
            0.0,
            -0.0,
            f32::from_bits(1), // smallest denormal
            -f32::from_bits(1),
            f32::MIN_POSITIVE, // smallest normal
            f32::MIN_POSITIVE / 2.0, // denormal
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MAX,
            f32::MIN,
            1.0,
            -1.0,
            1.0, // exact tie with the previous 1.0 pair
            0.5,
            -0.5,
            0.5,
        ];
        let mut rng = Pcg64::seeded(seed);
        (0..len)
            .map(|i| {
                if i % 3 == 0 && i / 3 < specials.len() {
                    specials[i / 3]
                } else {
                    rng.f32_range(-4.0, 4.0)
                }
            })
            .collect()
    }

    /// Lengths straddling the 4-lane and 8-lane boundaries, plus empties.
    const LENS: &[usize] = &[0, 1, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 40, 100];

    #[test]
    fn backend_forcing_round_trips() {
        let det = detected();
        assert_eq!(force_backend(Some(Backend::Scalar)), Backend::Scalar);
        assert_eq!(active_backend(), Backend::Scalar);
        // Requesting an unavailable ISA clamps to scalar; requesting the
        // detected one is honored.
        assert_eq!(force_backend(Some(det)), det);
        assert_eq!(force_backend(None), det);
        assert_eq!(active_backend(), det);
    }

    #[test]
    fn ordered_key_is_monotone_and_nan_lowest() {
        assert_eq!(ordered(f32::NAN), 0);
        assert_eq!(ordered(f32::from_bits(0x7fc0_dead)), 0);
        assert_eq!(ordered(0.0), 0);
        let seq = [
            0.0,
            f32::from_bits(1),
            f32::MIN_POSITIVE,
            0.5,
            1.0,
            2.0,
            f32::MAX,
            f32::INFINITY,
        ];
        for w in seq.windows(2) {
            assert!(ordered(w[0]) <= ordered(w[1]), "{:?}", w);
        }
    }

    #[test]
    fn pack_ordered_matches_scalar() {
        for &len in LENS {
            let x = adversarial(len, 11 + len as u64);
            let mut a = Vec::new();
            let mut b = Vec::new();
            scalar::pack_ordered_into(&x, &mut a);
            pack_ordered_into(&x, &mut b);
            assert_eq!(a, b, "len={len} backend={:?}", active_backend());
        }
    }

    #[test]
    fn scan_threshold_matches_scalar() {
        for &len in LENS {
            let x = adversarial(len, 23 + len as u64);
            // Thresholds are magnitude keys, including 0 (everything
            // passes) and u32 keys of mid/huge magnitudes.
            for thresh in [0, ordered(0.25), ordered(1.0), ordered(f32::MAX)] {
                for cap in [0, 1, len / 2, len, len + 8] {
                    let mut a = Vec::new();
                    let mut b = Vec::new();
                    let ra = scalar::scan_threshold_into(&x, thresh, cap, &mut a);
                    let rb = scan_threshold_into(&x, thresh, cap, &mut b);
                    // Abort point and partial contents must agree exactly.
                    assert_eq!(ra, rb, "len={len} thresh={thresh} cap={cap}");
                    assert_eq!(a, b, "len={len} thresh={thresh} cap={cap}");
                }
            }
        }
    }

    #[test]
    fn norm2_matches_scalar_bitwise() {
        for &len in LENS {
            // Finite-only soup: the norm consumer (QSGD) never feeds
            // non-finite buckets, but denormals and ties stay in.
            let mut x = adversarial(len, 37 + len as u64);
            for v in &mut x {
                if !v.is_finite() {
                    *v = 3.25;
                }
            }
            let a = scalar::norm2_sq_chunked(&x);
            let b = norm2_sq_chunked(&x);
            assert_eq!(a.to_bits(), b.to_bits(), "len={len}");
        }
    }

    #[test]
    fn quantize_matches_scalar_with_rng_lockstep() {
        for &len in LENS {
            let mut x = adversarial(len, 41 + len as u64);
            for v in &mut x {
                if !v.is_finite() {
                    *v = -0.75;
                }
            }
            for s in [1u32, 3, 15, 255] {
                let norm = scalar::norm2_sq_chunked(&x).sqrt() as f32;
                let inv = if norm > 0.0 { s as f32 / norm } else { 0.0 };
                let mut rng_a = Pcg64::new(9 + len as u64, s as u64);
                let mut rng_b = rng_a.clone();
                let (mut la, mut na) = (Vec::new(), Vec::new());
                let (mut lb, mut nb) = (Vec::new(), Vec::new());
                scalar::quantize_bucket_into(&x, inv, s, &mut rng_a, &mut la, &mut na);
                quantize_bucket_into(&x, inv, s, &mut rng_b, &mut lb, &mut nb);
                assert_eq!(la, lb, "levels len={len} s={s}");
                assert_eq!(na, nb, "signs len={len} s={s}");
                // The RNG streams must stay in lockstep (same number of
                // draws in the same order).
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "rng len={len} s={s}");
            }
        }
    }

    #[test]
    fn add_scaled_matches_scalar_bitwise() {
        for &len in LENS {
            let base = adversarial(len, 53 + len as u64);
            let vals = adversarial(len, 59 + len as u64);
            for scale in [1.0f32, -1.0, 0.5, -0.03125, 1.0 / 3.0] {
                let mut a = base.clone();
                let mut b = base.clone();
                scalar::add_scaled(&mut a, &vals, scale);
                add_scaled(&mut b, &vals, scale);
                let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb, "len={len} scale={scale}");
            }
        }
    }

    #[test]
    fn add_signed_matches_scalar_bitwise() {
        for &len in LENS {
            let base = adversarial(len, 61 + len as u64);
            let mut rng = Pcg64::seeded(67 + len as u64);
            let neg: Vec<bool> = (0..len).map(|_| rng.f32() < 0.5).collect();
            for (mag, scale) in [
                (0.75f32, 1.0f32),
                (0.75, -0.5),
                (0.0, 1.0),
                (-0.0, 1.0),
                (f32::NAN, 0.5),
                (f32::MIN_POSITIVE / 4.0, 3.0),
            ] {
                let mut a = base.clone();
                let mut b = base.clone();
                scalar::add_signed(&mut a, &neg, mag, scale);
                add_signed(&mut b, &neg, mag, scale);
                let ab: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
                let bb: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
                assert_eq!(ab, bb, "len={len} mag={mag} scale={scale}");
            }
        }
    }

    #[test]
    fn be_bytes_matches_scalar() {
        for &len in LENS {
            let x = adversarial(len, 71 + len as u64);
            let mut a = Vec::new();
            let mut b = Vec::new();
            scalar::be_bytes_into(&x, &mut a);
            be_bytes_into(&x, &mut b);
            assert_eq!(a, b, "len={len}");
        }
    }

    #[test]
    fn unpack_fixed_matches_scalar() {
        let mut rng = Pcg64::seeded(79);
        // Random byte streams; every width, several misaligned starts,
        // counts that force both the windowed and the zero-padded tail
        // paths (the stream's final bytes).
        for trial in 0..40u64 {
            let nbytes = 9 + (trial as usize % 57);
            let bytes: Vec<u8> = (0..nbytes).map(|_| rng.next_u32() as u8).collect();
            for width in [1u32, 2, 3, 5, 7, 8, 13, 16, 19, 24, 27, 31, 32] {
                for start_bit in [0u64, 1, 5, 7, 8, 13] {
                    let avail = 8 * nbytes as u64 - start_bit;
                    let count = (avail / width as u64) as usize;
                    let mut a = Vec::new();
                    let mut b = Vec::new();
                    scalar::unpack_fixed_into(&bytes, start_bit, width, count, &mut a);
                    unpack_fixed_into(&bytes, start_bit, width, count, &mut b);
                    assert_eq!(a, b, "nbytes={nbytes} width={width} start={start_bit}");
                }
            }
        }
    }
}
