//! Portable reference implementations of the SIMD kernels.
//!
//! These are the *semantics* of every kernel in this module: the AVX2 and
//! Neon paths must reproduce each function here bit for bit (property-tested
//! in `simd::tests` and end-to-end via forced-scalar `History` parity).
//! They are also the fallback the dispatcher selects when no vector ISA is
//! detected or `QSPARSE_FORCE_SCALAR` is set, so they stay optimized scalar
//! code, not naive sketches.
//!
//! Bit-identity rules encoded here (ROADMAP "SIMD the scalar kernels"):
//! per-element f32 work (quantization decisions, magnitude keys, packing)
//! vectorizes freely because lanes are independent; the one cross-element
//! f32 reduction (`norm2_sq_chunked`) uses a *fixed* 4-accumulator stride-4
//! chunking with a fixed combine order, so every backend — scalar, 4-lane
//! Neon, 8-lane AVX2 — performs the identical sequence of f64 additions.

#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;

/// Map an f32 magnitude (non-negative input) to a totally ordered u32 key:
/// the raw IEEE bits, with every NaN collapsed to 0 (smallest key, so NaNs
/// lose all top-k comparisons). For non-NaN `v ≥ 0` the bit pattern is
/// monotone in the value, so u32 order = magnitude order.
#[inline]
pub(crate) fn ordered(v: f32) -> u32 {
    if v.is_nan() {
        0
    } else {
        v.to_bits()
    }
}

/// Append `(ordered(|x_i|) << 32) | i` for every element — the flat
/// introselect array of `top_k_packed_into` (magnitude key in the high
/// half so u64 order = magnitude order, index in the low half).
pub(crate) fn pack_ordered_into(x: &[f32], out: &mut Vec<u64>) {
    out.reserve(x.len());
    out.extend(
        x.iter()
            .enumerate()
            .map(|(i, &v)| ((ordered(v.abs()) as u64) << 32) | i as u64),
    );
}

/// Append the packed `(key << 32) | i` of every element whose magnitude key
/// is `≥ thresh`, in ascending index order, aborting with `false` the
/// moment a `cap + 1`-th candidate appears (the sampled top-k's blow-up
/// fallback). Returns `true` when the scan completed under the cap.
pub(crate) fn scan_threshold_into(
    x: &[f32],
    thresh: u32,
    cap: usize,
    cand: &mut Vec<u64>,
) -> bool {
    for (i, &v) in x.iter().enumerate() {
        let o = ordered(v.abs());
        if o >= thresh {
            if cand.len() == cap {
                return false;
            }
            cand.push(((o as u64) << 32) | i as u64);
        }
    }
    true
}

/// Σ xᵢ² in f64, with a FIXED stride-4 chunked reduction: four f64
/// accumulators (lane j sums elements 4·i + j), combined as
/// `(acc0 + acc2) + (acc1 + acc3)`, then the `len % 4` tail added in
/// element order. Every backend performs this exact addition sequence —
/// the chunking is part of the kernel's definition, like the sharded
/// fold's worker-index order — so QSGD bucket norms are identical across
/// scalar/AVX2/Neon (and deterministic, but NOT equal to a naive
/// sequential sum; `Qsgd` documents the switch).
pub(crate) fn norm2_sq_chunked(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; 4];
    let mut it = x.chunks_exact(4);
    for c in it.by_ref() {
        for (a, &v) in acc.iter_mut().zip(c) {
            let v = v as f64;
            *a += v * v;
        }
    }
    let mut total = (acc[0] + acc[2]) + (acc[1] + acc[3]);
    for &v in it.remainder() {
        let v = v as f64;
        total += v * v;
    }
    total
}

/// One QSGD bucket, after the norm pass: per element, stochastic level
/// `min(⌊|v|·inv⌋ + 1[r < frac], s)` and canonical sign (zero levels carry
/// no sign). Draws exactly one `rng.f32()` per element, in element order —
/// the SIMD paths pre-draw lane blocks in the same order, so the RNG
/// stream stays in lockstep with this loop.
pub(crate) fn quantize_bucket_into(
    chunk: &[f32],
    inv: f32,
    s: u32,
    rng: &mut Pcg64,
    levels: &mut Vec<u32>,
    neg: &mut Vec<bool>,
) {
    for &v in chunk {
        let a = v.abs() * inv; // in [0, s] for finite inputs
        let lo = a.floor();
        let p = a - lo; // probability of rounding up
        let l = (lo as u32 + u32::from(rng.f32() < p)).min(s);
        levels.push(l);
        neg.push(l != 0 && v < 0.0);
    }
}

/// `out[i] += scale * vals[i]` — the dense fold inner loop. The expression
/// is multiply-then-add per element (never fused: Rust does not contract
/// to FMA, and the vector paths use explicit mul/add), so each lane's
/// rounding matches this loop exactly.
pub(crate) fn add_scaled(out: &mut [f32], vals: &[f32], scale: f32) {
    debug_assert_eq!(out.len(), vals.len());
    for (o, &v) in out.iter_mut().zip(vals) {
        *o += scale * v;
    }
}

/// `out[i] += scale * (neg[i] ? -mag : mag)` — the sign-message fold inner
/// loop. IEEE multiplication is sign-magnitude, so `scale * (-mag)` is
/// exactly `-(scale * mag)`: the vector paths compute `scale * mag` once
/// and flip the sign bit per lane, which is bit-identical to this loop.
pub(crate) fn add_signed(out: &mut [f32], neg: &[bool], mag: f32, scale: f32) {
    debug_assert_eq!(out.len(), neg.len());
    for (o, &n) in out.iter_mut().zip(neg) {
        *o += scale * if n { -mag } else { mag };
    }
}

/// Append the big-endian byte image of each f32 — what `BitWriter` emits
/// for a run of `push_f32` calls at a byte-aligned position. The writer's
/// bulk path byte-swaps here, then merges the byte stream at its current
/// bit offset.
pub(crate) fn be_bytes_into(vals: &[f32], out: &mut Vec<u8>) {
    out.reserve(4 * vals.len());
    for &v in vals {
        out.extend_from_slice(&v.to_bits().to_be_bytes());
    }
}

/// Append `count` fixed-`width`-bit big-endian fields starting at absolute
/// bit `start_bit` — the bulk twin of `count` successive
/// `BitReader::read_bits(width)` calls. Caller guarantees the whole run
/// lies inside `bytes` (`start_bit + count·width ≤ 8·bytes.len()`); each
/// field spans at most 5 bytes (`width ≤ 32`), extracted through one
/// 8-byte big-endian window.
pub(crate) fn unpack_fixed_into(
    bytes: &[u8],
    start_bit: u64,
    width: u32,
    count: usize,
    out: &mut Vec<u32>,
) {
    debug_assert!((1..=32).contains(&width));
    debug_assert!(start_bit + count as u64 * width as u64 <= 8 * bytes.len() as u64);
    out.reserve(count);
    for j in 0..count as u64 {
        let off = start_bit + j * width as u64;
        let byte = (off / 8) as usize;
        let sh = (off % 8) as u32;
        let w = if bytes.len() - byte >= 8 {
            u64::from_be_bytes(bytes[byte..byte + 8].try_into().unwrap())
        } else {
            // Stream tail: widen the last < 8 bytes, zero-padded on the
            // right (the in-bounds guarantee means the field itself ends
            // inside the real bytes).
            let mut w = 0u64;
            for (b, &x) in bytes[byte..].iter().enumerate() {
                w |= (x as u64) << (56 - 8 * b as u32);
            }
            w
        };
        out.push(((w << sh) >> (64 - width)) as u32);
    }
}
