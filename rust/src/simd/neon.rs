//! Neon kernels (aarch64). Compiled into every aarch64 build and selected at
//! runtime by `simd::active_backend()`; nothing here executes unless
//! `is_aarch64_feature_detected!("neon")` returned true (Neon is baseline on
//! aarch64, but the dispatcher still proves it).
//!
//! Layout mirrors `scalar.rs` one function for one function; see `avx2.rs`
//! for the wrapper/inner-fn soundness idiom. Bit-identity notes:
//!
//! - f32 lane math is mul-then-add (`vmulq`/`vaddq`) — never `vfmaq`.
//! - `vcvtq_u32_f32` is FCVTZU, which already has Rust's saturating
//!   `as u32` cast semantics (NaN → 0, negative → 0, overflow → MAX), so
//!   the quantizer needs no NaN/clamp fix-up here.
//! - `norm2_sq_chunked` keeps the fixed stride-4 chunking as two f64×2
//!   accumulators: lanes [acc0, acc1] and [acc2, acc3], combined
//!   `(acc0 + acc2) + (acc1 + acc3)` exactly like the scalar twin.
//! - `unpack_fixed_into` delegates to scalar: aarch64 has no gather, and
//!   the per-field work is a handful of scalar shifts already.

use crate::util::rng::Pcg64;
use core::arch::aarch64::*;

/// Cached CPU check shared by every wrapper's soundness assert.
#[inline]
fn have_neon() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

pub(crate) fn pack_ordered_into(x: &[f32], out: &mut Vec<u64>) {
    assert!(have_neon(), "simd::neon entered without Neon (dispatcher bug)");
    // SAFETY: the assert above establishes the `neon` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { pack_ordered_neon(x, out) }
}

/// # Safety
/// CPU must support Neon (the wrapper asserts the detection guard).
#[target_feature(enable = "neon")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn pack_ordered_neon(x: &[f32], out: &mut Vec<u64>) {
    out.reserve(x.len());
    let n4 = x.len() / 4 * 4;
    let mut obuf = [0u32; 4];
    // SAFETY: loads read 4 f32 at `base ≤ n4 − 4` inside `x`; stores target
    // the stack buffer; Neon is guaranteed by the caller.
    unsafe {
        let abs_mask = vdupq_n_u32(0x7fff_ffff);
        let nan_min = vdupq_n_u32(0x7f80_0000);
        for base in (0..n4).step_by(4) {
            let bits = vld1q_u32(x.as_ptr().add(base) as *const u32);
            let m = vandq_u32(bits, abs_mask);
            // ordered(): NaN (magnitude bits > inf's) collapses to key 0.
            let nan = vcgtq_u32(m, nan_min);
            let o = vbicq_u32(m, nan);
            vst1q_u32(obuf.as_mut_ptr(), o);
            for (j, &k) in obuf.iter().enumerate() {
                out.push(((k as u64) << 32) | (base + j) as u64);
            }
        }
    }
    for (i, &v) in x.iter().enumerate().skip(n4) {
        out.push(((super::scalar::ordered(v.abs()) as u64) << 32) | i as u64);
    }
}

pub(crate) fn scan_threshold_into(x: &[f32], thresh: u32, cap: usize, cand: &mut Vec<u64>) -> bool {
    assert!(have_neon(), "simd::neon entered without Neon (dispatcher bug)");
    // SAFETY: the assert above establishes the `neon` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { scan_threshold_neon(x, thresh, cap, cand) }
}

/// # Safety
/// CPU must support Neon (the wrapper asserts the detection guard).
#[target_feature(enable = "neon")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn scan_threshold_neon(x: &[f32], thresh: u32, cap: usize, cand: &mut Vec<u64>) -> bool {
    let n4 = x.len() / 4 * 4;
    let mut obuf = [0u32; 4];
    let mut pbuf = [0u32; 4];
    // SAFETY: loads read 4 f32 at `base ≤ n4 − 4` inside `x`; stores target
    // the stack buffers; Neon is guaranteed by the caller.
    unsafe {
        let abs_mask = vdupq_n_u32(0x7fff_ffff);
        let nan_min = vdupq_n_u32(0x7f80_0000);
        let tv = vdupq_n_u32(thresh);
        for base in (0..n4).step_by(4) {
            let bits = vld1q_u32(x.as_ptr().add(base) as *const u32);
            let m = vandq_u32(bits, abs_mask);
            let nan = vcgtq_u32(m, nan_min);
            let o = vbicq_u32(m, nan);
            let pass = vcgeq_u32(o, tv);
            if vmaxvq_u32(pass) == 0 {
                continue;
            }
            vst1q_u32(obuf.as_mut_ptr(), o);
            vst1q_u32(pbuf.as_mut_ptr(), pass);
            // Extract passing lanes in ascending index order, with the
            // scalar path's exact cap-abort point.
            for (j, (&pb, &ob)) in pbuf.iter().zip(obuf.iter()).enumerate() {
                if pb != 0 {
                    if cand.len() == cap {
                        return false;
                    }
                    cand.push(((ob as u64) << 32) | (base + j) as u64);
                }
            }
        }
    }
    for (i, &v) in x.iter().enumerate().skip(n4) {
        let o = super::scalar::ordered(v.abs());
        if o >= thresh {
            if cand.len() == cap {
                return false;
            }
            cand.push(((o as u64) << 32) | i as u64);
        }
    }
    true
}

pub(crate) fn norm2_sq_chunked(x: &[f32]) -> f64 {
    assert!(have_neon(), "simd::neon entered without Neon (dispatcher bug)");
    // SAFETY: the assert above establishes the `neon` target feature, the
    // only contract the inner fn has beyond its slice argument.
    unsafe { norm2_sq_neon(x) }
}

/// # Safety
/// CPU must support Neon (the wrapper asserts the detection guard).
#[target_feature(enable = "neon")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn norm2_sq_neon(x: &[f32]) -> f64 {
    let n4 = x.len() / 4 * 4;
    // SAFETY: loads read 4 f32 at `base ≤ n4 − 4` inside `x`; Neon is
    // guaranteed by the caller.
    let mut total = unsafe {
        let mut acc01 = vdupq_n_f64(0.0);
        let mut acc23 = vdupq_n_f64(0.0);
        for base in (0..n4).step_by(4) {
            let v4 = vld1q_f32(x.as_ptr().add(base));
            let d01 = vcvt_f64_f32(vget_low_f32(v4));
            let d23 = vcvt_high_f64_f32(v4);
            // mul then add — the scalar twin's unfused `a += v * v`.
            acc01 = vaddq_f64(acc01, vmulq_f64(d01, d01));
            acc23 = vaddq_f64(acc23, vmulq_f64(d23, d23));
        }
        // Fixed combine order (acc0 + acc2) + (acc1 + acc3), matching the
        // scalar twin lane for lane.
        let pair = vaddq_f64(acc01, acc23);
        vgetq_lane_f64::<0>(pair) + vgetq_lane_f64::<1>(pair)
    };
    for &v in &x[n4..] {
        let v = v as f64;
        total += v * v;
    }
    total
}

pub(crate) fn quantize_bucket_into(
    chunk: &[f32],
    inv: f32,
    s: u32,
    rng: &mut Pcg64,
    levels: &mut Vec<u32>,
    neg: &mut Vec<bool>,
) {
    assert!(have_neon(), "simd::neon entered without Neon (dispatcher bug)");
    // SAFETY: the assert above establishes the `neon` target feature, the
    // only contract the inner fn has beyond its (safe) arguments.
    unsafe { quantize_bucket_neon(chunk, inv, s, rng, levels, neg) }
}

/// # Safety
/// CPU must support Neon (the wrapper asserts the detection guard).
#[target_feature(enable = "neon")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn quantize_bucket_neon(
    chunk: &[f32],
    inv: f32,
    s: u32,
    rng: &mut Pcg64,
    levels: &mut Vec<u32>,
    neg: &mut Vec<bool>,
) {
    let n4 = chunk.len() / 4 * 4;
    let mut draws = [0f32; 4];
    let mut lbuf = [0u32; 4];
    // SAFETY: loads read 4 f32 at `base ≤ n4 − 4` inside `chunk` (or the
    // stack arrays); stores target the stack buffer; Neon is guaranteed by
    // the caller.
    unsafe {
        let inv_v = vdupq_n_f32(inv);
        let s_v = vdupq_n_u32(s);
        for base in (0..n4).step_by(4) {
            // Pre-draw the lane block so the RNG stream is consumed in
            // element order, exactly like the scalar loop.
            for d in &mut draws {
                *d = rng.f32();
            }
            let v = vld1q_f32(chunk.as_ptr().add(base));
            let a = vmulq_f32(vabsq_f32(v), inv_v);
            let lo = vrndmq_f32(a); // FRINTM = floor, NaN-propagating
            let p = vsubq_f32(a, lo);
            let r = vld1q_f32(draws.as_ptr());
            // FCVTZU: NaN → 0, overflow → MAX — exactly Rust's `as u32`.
            let mut li = vcvtq_u32_f32(lo);
            // r < p, false on NaN p — the stochastic round-up; all-ones
            // mask acts as −1, so subtracting adds the increment. (`li`
            // can't be MAX when the mask fires: a ≥ 2²³ means p = 0.)
            let up = vcltq_f32(r, p);
            li = vsubq_u32(li, up);
            li = vminq_u32(li, s_v);
            vst1q_u32(lbuf.as_mut_ptr(), li);
            for (j, &l) in lbuf.iter().enumerate() {
                levels.push(l);
                neg.push(l != 0 && chunk[base + j] < 0.0);
            }
        }
    }
    // Tail in element order — the scalar twin's exact expression.
    for &v in &chunk[n4..] {
        let a = v.abs() * inv;
        let lo = a.floor();
        let p = a - lo;
        let l = (lo as u32 + u32::from(rng.f32() < p)).min(s);
        levels.push(l);
        neg.push(l != 0 && v < 0.0);
    }
}

pub(crate) fn add_scaled(out: &mut [f32], vals: &[f32], scale: f32) {
    assert!(have_neon(), "simd::neon entered without Neon (dispatcher bug)");
    // SAFETY: the assert above establishes the `neon` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { add_scaled_neon(out, vals, scale) }
}

/// # Safety
/// CPU must support Neon (the wrapper asserts the detection guard).
#[target_feature(enable = "neon")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn add_scaled_neon(out: &mut [f32], vals: &[f32], scale: f32) {
    debug_assert_eq!(out.len(), vals.len());
    let n = out.len().min(vals.len());
    let n4 = n / 4 * 4;
    // SAFETY: loads/stores touch 4 f32 at `base ≤ n4 − 4`, in bounds for
    // both slices; Neon is guaranteed by the caller.
    unsafe {
        let sv = vdupq_n_f32(scale);
        for base in (0..n4).step_by(4) {
            let o = vld1q_f32(out.as_ptr().add(base));
            let v = vld1q_f32(vals.as_ptr().add(base));
            // mul then add — the scalar `*o += scale * v`, unfused.
            let r = vaddq_f32(o, vmulq_f32(sv, v));
            vst1q_f32(out.as_mut_ptr().add(base), r);
        }
    }
    for (o, &v) in out[n4..n].iter_mut().zip(&vals[n4..n]) {
        *o += scale * v;
    }
}

pub(crate) fn add_signed(out: &mut [f32], neg: &[bool], mag: f32, scale: f32) {
    assert!(have_neon(), "simd::neon entered without Neon (dispatcher bug)");
    // SAFETY: the assert above establishes the `neon` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { add_signed_neon(out, neg, mag, scale) }
}

/// # Safety
/// CPU must support Neon (the wrapper asserts the detection guard).
#[target_feature(enable = "neon")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn add_signed_neon(out: &mut [f32], neg: &[bool], mag: f32, scale: f32) {
    debug_assert_eq!(out.len(), neg.len());
    let n = out.len().min(neg.len());
    let n4 = n / 4 * 4;
    // `scale * (-mag)` is exactly `-(scale * mag)` (IEEE multiplication is
    // sign-magnitude), so one product + a per-lane sign flip reproduces the
    // scalar expression bit for bit.
    let t = scale * mag;
    // SAFETY: loads/stores touch 4 f32 at `base ≤ n4 − 4` inside `out`; the
    // sign array is built from in-bounds `neg` reads; Neon is guaranteed by
    // the caller.
    unsafe {
        let tv = vreinterpretq_u32_f32(vdupq_n_f32(t));
        for base in (0..n4).step_by(4) {
            let sbits = [
                (neg[base] as u32) << 31,
                (neg[base + 1] as u32) << 31,
                (neg[base + 2] as u32) << 31,
                (neg[base + 3] as u32) << 31,
            ];
            let sign = vld1q_u32(sbits.as_ptr());
            let val = vreinterpretq_f32_u32(veorq_u32(tv, sign));
            let o = vld1q_f32(out.as_ptr().add(base));
            vst1q_f32(out.as_mut_ptr().add(base), vaddq_f32(o, val));
        }
    }
    for (o, &nb) in out[n4..n].iter_mut().zip(&neg[n4..n]) {
        *o += scale * if nb { -mag } else { mag };
    }
}

pub(crate) fn be_bytes_into(vals: &[f32], out: &mut Vec<u8>) {
    assert!(have_neon(), "simd::neon entered without Neon (dispatcher bug)");
    // SAFETY: the assert above establishes the `neon` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { be_bytes_neon(vals, out) }
}

/// # Safety
/// CPU must support Neon (the wrapper asserts the detection guard).
#[target_feature(enable = "neon")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn be_bytes_neon(vals: &[f32], out: &mut Vec<u8>) {
    out.reserve(4 * vals.len());
    let n4 = vals.len() / 4 * 4;
    let mut buf = [0u8; 16];
    // SAFETY: loads read 16 bytes (4 f32) at `base ≤ n4 − 4` inside `vals`;
    // stores target the stack buffer; Neon is guaranteed by the caller.
    unsafe {
        for base in (0..n4).step_by(4) {
            let v = vld1q_u8(vals.as_ptr().add(base) as *const u8);
            // Byte swap within each 32-bit element → big-endian images.
            let b = vrev32q_u8(v);
            vst1q_u8(buf.as_mut_ptr(), b);
            out.extend_from_slice(&buf);
        }
    }
    for &v in &vals[n4..] {
        out.extend_from_slice(&v.to_bits().to_be_bytes());
    }
}

pub(crate) fn unpack_fixed_into(
    bytes: &[u8],
    start_bit: u64,
    width: u32,
    count: usize,
    out: &mut Vec<u32>,
) {
    // No gather on aarch64, and each field is already a couple of scalar
    // shifts through one 8-byte window — the portable kernel is the fast
    // path here. (The wrapper keeps the backend surface uniform.)
    super::scalar::unpack_fixed_into(bytes, start_bit, width, count, out);
}
