//! AVX2 kernels (x86_64). Compiled into every x86_64 build and selected at
//! runtime by `simd::active_backend()`; nothing here executes unless
//! `is_x86_feature_detected!("avx2")` returned true.
//!
//! Layout mirrors `scalar.rs` one function for one function. Every public
//! wrapper re-proves the CPU feature with a hard `assert!` before entering
//! its `#[target_feature]` inner fn — the check is a cached atomic load in
//! std, and it makes each wrapper sound on its own (a direct call on a
//! non-AVX2 machine panics instead of executing illegal instructions).
//!
//! Bit-identity: per-lane f32 ops (mul/add/floor/compare/abs) are the same
//! IEEE operations the scalar loop performs, explicitly unfused (mul then
//! add — never FMA); the one cross-lane reduction (`norm2_sq_chunked`)
//! reproduces the scalar twin's fixed 4-accumulator chunking exactly.

use crate::util::rng::Pcg64;
use core::arch::x86_64::*;

/// Cached CPU check shared by every wrapper's soundness assert.
#[inline]
fn have_avx2() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// `_mm256_shuffle_epi8` control for a byte swap within each 32-bit lane
/// (controls are relative to each 128-bit half).
static BSWAP32: [u8; 32] = bswap32_control();

const fn bswap32_control() -> [u8; 32] {
    let mut c = [0u8; 32];
    let mut i = 0;
    while i < 32 {
        let r = (i & 15) as u8;
        c[i] = (r & !3) | (3 - (r & 3));
        i += 1;
    }
    c
}

/// `_mm256_shuffle_epi8` control for a byte swap within each 64-bit lane.
static BSWAP64: [u8; 32] = bswap64_control();

const fn bswap64_control() -> [u8; 32] {
    let mut c = [0u8; 32];
    let mut i = 0;
    while i < 32 {
        let r = (i & 15) as u8;
        c[i] = (r & 8) | (7 - (r & 7));
        i += 1;
    }
    c
}

pub(crate) fn pack_ordered_into(x: &[f32], out: &mut Vec<u64>) {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    // SAFETY: the assert above establishes the `avx2` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { pack_ordered_avx2(x, out) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard).
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn pack_ordered_avx2(x: &[f32], out: &mut Vec<u64>) {
    out.reserve(x.len());
    let n8 = x.len() / 8 * 8;
    let mut buf = [0u64; 8];
    // SAFETY: all loads read 8 f32 at `base ≤ n8 − 8` inside `x`; stores
    // target the stack buffer; AVX2 is guaranteed by the caller.
    unsafe {
        let abs_mask = _mm256_set1_epi32(0x7fff_ffff);
        let nan_min = _mm256_set1_epi32(0x7f80_0000);
        let step = _mm256_set1_epi64x(8);
        let mut idx_lo = _mm256_set_epi64x(3, 2, 1, 0);
        let mut idx_hi = _mm256_set_epi64x(7, 6, 5, 4);
        for base in (0..n8).step_by(8) {
            let bits = _mm256_loadu_si256(x.as_ptr().add(base) as *const __m256i);
            let m = _mm256_and_si256(bits, abs_mask);
            // ordered(): NaN (magnitude bits > inf's) collapses to key 0.
            let nan = _mm256_cmpgt_epi32(m, nan_min);
            let o = _mm256_andnot_si256(nan, m);
            let lo4 = _mm256_castsi256_si128(o);
            let hi4 = _mm256_extracti128_si256::<1>(o);
            let w0 = _mm256_cvtepu32_epi64(lo4);
            let w1 = _mm256_cvtepu32_epi64(hi4);
            let k0 = _mm256_or_si256(_mm256_slli_epi64::<32>(w0), idx_lo);
            let k1 = _mm256_or_si256(_mm256_slli_epi64::<32>(w1), idx_hi);
            _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, k0);
            _mm256_storeu_si256(buf.as_mut_ptr().add(4) as *mut __m256i, k1);
            out.extend_from_slice(&buf);
            idx_lo = _mm256_add_epi64(idx_lo, step);
            idx_hi = _mm256_add_epi64(idx_hi, step);
        }
    }
    for (i, &v) in x.iter().enumerate().skip(n8) {
        out.push(((super::scalar::ordered(v.abs()) as u64) << 32) | i as u64);
    }
}

pub(crate) fn scan_threshold_into(x: &[f32], thresh: u32, cap: usize, cand: &mut Vec<u64>) -> bool {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    // SAFETY: the assert above establishes the `avx2` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { scan_threshold_avx2(x, thresh, cap, cand) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard).
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn scan_threshold_avx2(x: &[f32], thresh: u32, cap: usize, cand: &mut Vec<u64>) -> bool {
    let n8 = x.len() / 8 * 8;
    let mut obuf = [0u32; 8];
    // SAFETY: loads read 8 f32 at `base ≤ n8 − 8` inside `x`; stores target
    // the stack buffer; AVX2 is guaranteed by the caller.
    unsafe {
        let abs_mask = _mm256_set1_epi32(0x7fff_ffff);
        let nan_min = _mm256_set1_epi32(0x7f80_0000);
        // Keys are ≤ 0x7f80_0000 (and `thresh` is itself a key), so the
        // signed epi32 compare below agrees with unsigned key order.
        let tv = _mm256_set1_epi32(thresh as i32);
        for base in (0..n8).step_by(8) {
            let bits = _mm256_loadu_si256(x.as_ptr().add(base) as *const __m256i);
            let m = _mm256_and_si256(bits, abs_mask);
            let nan = _mm256_cmpgt_epi32(m, nan_min);
            let o = _mm256_andnot_si256(nan, m);
            let lt = _mm256_cmpgt_epi32(tv, o);
            let fail = _mm256_movemask_ps(_mm256_castsi256_ps(lt)) as u32 & 0xff;
            let mut pass = !fail & 0xff;
            if pass == 0 {
                continue;
            }
            _mm256_storeu_si256(obuf.as_mut_ptr() as *mut __m256i, o);
            // Extract passing lanes in ascending index order, with the
            // scalar path's exact cap-abort point.
            while pass != 0 {
                let j = pass.trailing_zeros() as usize;
                pass &= pass - 1;
                if cand.len() == cap {
                    return false;
                }
                cand.push(((obuf[j] as u64) << 32) | (base + j) as u64);
            }
        }
    }
    for (i, &v) in x.iter().enumerate().skip(n8) {
        let o = super::scalar::ordered(v.abs());
        if o >= thresh {
            if cand.len() == cap {
                return false;
            }
            cand.push(((o as u64) << 32) | i as u64);
        }
    }
    true
}

pub(crate) fn norm2_sq_chunked(x: &[f32]) -> f64 {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    // SAFETY: the assert above establishes the `avx2` target feature, the
    // only contract the inner fn has beyond its slice argument.
    unsafe { norm2_sq_avx2(x) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard).
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn norm2_sq_avx2(x: &[f32]) -> f64 {
    let n4 = x.len() / 4 * 4;
    // SAFETY: loads read 4 f32 at `base ≤ n4 − 4` inside `x`; AVX2 is
    // guaranteed by the caller.
    let mut total = unsafe {
        let mut acc = _mm256_setzero_pd();
        for base in (0..n4).step_by(4) {
            let v4 = _mm_loadu_ps(x.as_ptr().add(base));
            let d4 = _mm256_cvtps_pd(v4);
            // mul then add — the scalar twin's unfused `a += v * v`.
            acc = _mm256_add_pd(acc, _mm256_mul_pd(d4, d4));
        }
        // Fixed combine order (acc0 + acc2) + (acc1 + acc3), matching the
        // scalar twin lane for lane.
        let lo = _mm256_castpd256_pd128(acc);
        let hi = _mm256_extractf128_pd::<1>(acc);
        let pair = _mm_add_pd(lo, hi);
        _mm_cvtsd_f64(pair) + _mm_cvtsd_f64(_mm_unpackhi_pd(pair, pair))
    };
    for &v in &x[n4..] {
        let v = v as f64;
        total += v * v;
    }
    total
}

pub(crate) fn quantize_bucket_into(
    chunk: &[f32],
    inv: f32,
    s: u32,
    rng: &mut Pcg64,
    levels: &mut Vec<u32>,
    neg: &mut Vec<bool>,
) {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    // SAFETY: the assert above establishes the `avx2` target feature, the
    // only contract the inner fn has beyond its (safe) arguments.
    unsafe { quantize_bucket_avx2(chunk, inv, s, rng, levels, neg) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard).
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn quantize_bucket_avx2(
    chunk: &[f32],
    inv: f32,
    s: u32,
    rng: &mut Pcg64,
    levels: &mut Vec<u32>,
    neg: &mut Vec<bool>,
) {
    let n8 = chunk.len() / 8 * 8;
    let mut draws = [0f32; 8];
    let mut lbuf = [0u32; 8];
    // SAFETY: loads read 8 f32 at `base ≤ n8 − 8` inside `chunk` (or the
    // stack arrays); stores target the stack buffer; AVX2 is guaranteed by
    // the caller.
    unsafe {
        let abs_ps = _mm256_castsi256_ps(_mm256_set1_epi32(0x7fff_ffff));
        let inv_v = _mm256_set1_ps(inv);
        let s_f = _mm256_set1_ps(s as f32);
        let s_i = _mm256_set1_epi32(s as i32);
        for base in (0..n8).step_by(8) {
            // Pre-draw the lane block so the RNG stream is consumed in
            // element order, exactly like the scalar loop.
            for d in &mut draws {
                *d = rng.f32();
            }
            let v = _mm256_loadu_ps(chunk.as_ptr().add(base));
            let a = _mm256_mul_ps(_mm256_and_ps(v, abs_ps), inv_v);
            let lo = _mm256_floor_ps(a);
            let p = _mm256_sub_ps(a, lo);
            let r = _mm256_loadu_ps(draws.as_ptr());
            let nan = _mm256_cmp_ps::<_CMP_UNORD_Q>(a, a);
            // Replicate the scalar saturating f32→u32 cast: clamp into
            // [0, s] before the i32 conversion (minps returns its second
            // operand on NaN, so NaN lanes read s here...), then zero NaN
            // lanes (...and are corrected to the cast's NaN → 0).
            let lo_c = _mm256_min_ps(lo, s_f);
            let mut li = _mm256_cvttps_epi32(lo_c);
            li = _mm256_andnot_si256(_mm256_castps_si256(nan), li);
            // r < p, ordered (false on NaN) — the stochastic round-up.
            let up = _mm256_cmp_ps::<_CMP_LT_OQ>(r, p);
            li = _mm256_sub_epi32(li, _mm256_castps_si256(up));
            li = _mm256_min_epu32(li, s_i);
            _mm256_storeu_si256(lbuf.as_mut_ptr() as *mut __m256i, li);
            for (j, &l) in lbuf.iter().enumerate() {
                levels.push(l);
                neg.push(l != 0 && chunk[base + j] < 0.0);
            }
        }
    }
    // Tail in element order — the scalar twin's exact expression.
    for &v in &chunk[n8..] {
        let a = v.abs() * inv;
        let lo = a.floor();
        let p = a - lo;
        let l = (lo as u32 + u32::from(rng.f32() < p)).min(s);
        levels.push(l);
        neg.push(l != 0 && v < 0.0);
    }
}

pub(crate) fn add_scaled(out: &mut [f32], vals: &[f32], scale: f32) {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    // SAFETY: the assert above establishes the `avx2` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { add_scaled_avx2(out, vals, scale) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard).
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn add_scaled_avx2(out: &mut [f32], vals: &[f32], scale: f32) {
    debug_assert_eq!(out.len(), vals.len());
    let n = out.len().min(vals.len());
    let n8 = n / 8 * 8;
    // SAFETY: loads/stores touch 8 f32 at `base ≤ n8 − 8`, in bounds for
    // both slices; AVX2 is guaranteed by the caller.
    unsafe {
        let sv = _mm256_set1_ps(scale);
        for base in (0..n8).step_by(8) {
            let o = _mm256_loadu_ps(out.as_ptr().add(base));
            let v = _mm256_loadu_ps(vals.as_ptr().add(base));
            // mul then add — the scalar `*o += scale * v`, unfused.
            let r = _mm256_add_ps(o, _mm256_mul_ps(sv, v));
            _mm256_storeu_ps(out.as_mut_ptr().add(base), r);
        }
    }
    for (o, &v) in out[n8..n].iter_mut().zip(&vals[n8..n]) {
        *o += scale * v;
    }
}

pub(crate) fn add_signed(out: &mut [f32], neg: &[bool], mag: f32, scale: f32) {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    // SAFETY: the assert above establishes the `avx2` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { add_signed_avx2(out, neg, mag, scale) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard).
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn add_signed_avx2(out: &mut [f32], neg: &[bool], mag: f32, scale: f32) {
    debug_assert_eq!(out.len(), neg.len());
    let n = out.len().min(neg.len());
    let n8 = n / 8 * 8;
    // `scale * (-mag)` is exactly `-(scale * mag)` (IEEE multiplication is
    // sign-magnitude), so one product + a per-lane sign flip reproduces the
    // scalar expression bit for bit.
    let t = scale * mag;
    // SAFETY: f32 loads/stores touch 8 elements at `base ≤ n8 − 8`; the
    // `_mm_loadl_epi64` reads 8 `bool`s (guaranteed 0x00/0x01 bytes) at the
    // same in-bounds offset; AVX2 is guaranteed by the caller.
    unsafe {
        let tv = _mm256_set1_ps(t);
        for base in (0..n8).step_by(8) {
            let b = _mm_loadl_epi64(neg.as_ptr().add(base) as *const __m128i);
            let w = _mm256_cvtepu8_epi32(b);
            let sign = _mm256_slli_epi32::<31>(w);
            let val = _mm256_xor_ps(tv, _mm256_castsi256_ps(sign));
            let o = _mm256_loadu_ps(out.as_ptr().add(base));
            _mm256_storeu_ps(out.as_mut_ptr().add(base), _mm256_add_ps(o, val));
        }
    }
    for (o, &nb) in out[n8..n].iter_mut().zip(&neg[n8..n]) {
        *o += scale * if nb { -mag } else { mag };
    }
}

pub(crate) fn be_bytes_into(vals: &[f32], out: &mut Vec<u8>) {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    // SAFETY: the assert above establishes the `avx2` target feature, the
    // only contract the inner fn has beyond its slice arguments.
    unsafe { be_bytes_avx2(vals, out) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard).
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn be_bytes_avx2(vals: &[f32], out: &mut Vec<u8>) {
    out.reserve(4 * vals.len());
    let n8 = vals.len() / 8 * 8;
    let mut buf = [0u8; 32];
    // SAFETY: loads read 8 f32 at `base ≤ n8 − 8` inside `vals` (and the
    // static shuffle control); stores target the stack buffer; AVX2 is
    // guaranteed by the caller.
    unsafe {
        let shuf = _mm256_loadu_si256(BSWAP32.as_ptr() as *const __m256i);
        for base in (0..n8).step_by(8) {
            let v = _mm256_loadu_si256(vals.as_ptr().add(base) as *const __m256i);
            let b = _mm256_shuffle_epi8(v, shuf);
            _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, b);
            out.extend_from_slice(&buf);
        }
    }
    for &v in &vals[n8..] {
        out.extend_from_slice(&v.to_bits().to_be_bytes());
    }
}

pub(crate) fn unpack_fixed_into(
    bytes: &[u8],
    start_bit: u64,
    width: u32,
    count: usize,
    out: &mut Vec<u32>,
) {
    assert!(have_avx2(), "simd::avx2 entered without AVX2 (dispatcher bug)");
    if bytes.len() > i32::MAX as usize {
        // Gather offsets are i32; wire buffers never get close, but stay
        // sound rather than clever.
        super::scalar::unpack_fixed_into(bytes, start_bit, width, count, out);
        return;
    }
    // SAFETY: the assert above establishes the `avx2` target feature; the
    // inner fn inherits the caller's in-bounds contract
    // (`start_bit + count·width ≤ 8·bytes.len()`).
    unsafe { unpack_fixed_avx2(bytes, start_bit, width, count, out) }
}

/// # Safety
/// CPU must support AVX2 (the wrapper asserts the detection guard), and the
/// whole run must lie inside `bytes` (`start_bit + count·width ≤
/// 8·bytes.len()`), as for the scalar twin.
#[target_feature(enable = "avx2")]
#[allow(unused_unsafe)] // value intrinsics are safe here on newer toolchains
unsafe fn unpack_fixed_avx2(
    bytes: &[u8],
    start_bit: u64,
    width: u32,
    count: usize,
    out: &mut Vec<u32>,
) {
    debug_assert!((1..=32).contains(&width));
    out.reserve(count);
    let mut j = 0usize;
    let mut wbuf = [0u64; 4];
    // SAFETY: each gather lane reads an 8-byte window at byte offset
    // `off/8`; the loop condition admits a group only when the *last*
    // lane's window ends inside `bytes` (offsets ascend with j), so every
    // lane is in bounds. Stores target the stack buffer; AVX2 is
    // guaranteed by the caller.
    unsafe {
        let shuf = _mm256_loadu_si256(BSWAP64.as_ptr() as *const __m256i);
        let rcnt = _mm_cvtsi32_si128((64 - width) as i32);
        while j + 4 <= count {
            let off = |q: usize| start_bit + (j + q) as u64 * width as u64;
            if (off(3) / 8) as usize + 8 > bytes.len() {
                break;
            }
            let b = |q: usize| (off(q) / 8) as i32;
            let sh = |q: usize| (off(q) % 8) as i64;
            let vindex = _mm_set_epi32(b(3), b(2), b(1), b(0));
            let g = _mm256_i32gather_epi64::<1>(bytes.as_ptr() as *const i64, vindex);
            let be = _mm256_shuffle_epi8(g, shuf);
            let shl = _mm256_sllv_epi64(be, _mm256_set_epi64x(sh(3), sh(2), sh(1), sh(0)));
            let res = _mm256_srl_epi64(shl, rcnt);
            _mm256_storeu_si256(wbuf.as_mut_ptr() as *mut __m256i, res);
            out.extend(wbuf.iter().map(|&w| w as u32));
            j += 4;
        }
    }
    if j < count {
        let done = j as u64 * width as u64;
        super::scalar::unpack_fixed_into(bytes, start_bit + done, width, count - j, out);
    }
}
