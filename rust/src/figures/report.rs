//! Figure output: CSV files + paper-style summary tables.

use super::FigureSpec;
use crate::engine::History;
use crate::sim::SimPoint;
use std::path::Path;

/// Virtual-time sidecar for one series that ran under the event-driven
/// network simulator (`sim::`, figure 13): the per-eval-point virtual-time
/// track plus the run fingerprint. `points` is parallel to the series'
/// `History::points`.
pub struct SimTrace {
    pub points: Vec<SimPoint>,
    pub events: u64,
    pub final_secs: f64,
}

impl SimTrace {
    /// Simulated seconds until the train loss first reaches `target`.
    fn secs_to_loss(&self, hist: &History, target: f64) -> Option<f64> {
        hist.points
            .iter()
            .zip(&self.points)
            .find(|(m, _)| m.train_loss <= target)
            .map(|(_, p)| p.secs)
    }

    /// The sidecar CSV (`step,ticks,secs,state_hash`): the simulated-time
    /// curve plus the per-eval-point determinism-twin fingerprint.
    fn to_csv(&self) -> String {
        let mut out = String::from("step,ticks,secs,state_hash\n");
        for p in &self.points {
            out.push_str(&format!("{},{},{},{:016x}\n", p.step, p.ticks, p.secs, p.state_hash));
        }
        out
    }
}

/// The result of running every series of one figure.
pub struct FigureResult {
    pub id: String,
    pub title: String,
    pub steps: usize,
    pub target_loss: f64,
    pub target_test_err: f64,
    pub series: Vec<(String, History, f64)>,
    /// Parallel to `series`: `Some` for series that ran under `sim::`.
    pub sim: Vec<Option<SimTrace>>,
}

impl FigureResult {
    pub fn new(spec: &FigureSpec, steps: usize) -> Self {
        FigureResult {
            id: spec.id.to_string(),
            title: spec.title.to_string(),
            steps,
            target_loss: spec.target_loss,
            target_test_err: spec.target_test_err,
            series: Vec::new(),
            sim: Vec::new(),
        }
    }

    pub fn add(&mut self, label: &str, hist: History, wall_secs: f64) {
        self.add_with_sim(label, hist, None, wall_secs);
    }

    pub fn add_with_sim(
        &mut self,
        label: &str,
        hist: History,
        sim: Option<SimTrace>,
        wall_secs: f64,
    ) {
        self.series.push((label.to_string(), hist, wall_secs));
        self.sim.push(sim);
    }

    /// Write `<out>/<fig>/<series>.csv` for every series, plus a
    /// `<series>.sim.csv` virtual-time sidecar for simulated series.
    pub fn write_csvs(&self, out_dir: impl AsRef<Path>) -> anyhow::Result<()> {
        let dir = out_dir.as_ref().join(&self.id);
        std::fs::create_dir_all(&dir)?;
        for ((label, hist, _), trace) in self.series.iter().zip(&self.sim) {
            std::fs::write(dir.join(format!("{}.csv", sanitize(label))), hist.to_csv())?;
            if let Some(trace) = trace {
                let fname = format!("{}.sim.csv", sanitize(label));
                std::fs::write(dir.join(fname), trace.to_csv())?;
            }
        }
        Ok(())
    }

    /// Paper-style summary: final loss/error, total bits, bits-to-target on
    /// both metrics, and the savings factor vs the first series (the
    /// uncompressed baseline by convention). The savings column uses the
    /// test-error crossing when available and not NaN (the paper's fig 6c
    /// metric), else the train-loss crossing.
    pub fn summary(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("== {} — {} (T={} steps)\n", self.id, self.title, self.steps));
        out.push_str(&format!(
            "{:<30} {:>10} {:>9} {:>11} {:>11} {:>12} {:>12} {:>9}\n",
            "series", "loss", "test_err", "Mbits_up", "Mbits_dn", "bits→loss", "bits→terr",
            "saving×"
        ));
        let headline = |h: &History| {
            h.bits_to_test_err(self.target_test_err)
                .or_else(|| h.bits_to_loss(self.target_loss))
        };
        let baseline_bits = self.series.first().and_then(|(_, h, _)| headline(h));
        for (label, hist, _) in &self.series {
            let bl = hist.bits_to_loss(self.target_loss);
            let bt = hist.bits_to_test_err(self.target_test_err);
            let saving = match (baseline_bits, headline(hist)) {
                (Some(b), Some(x)) if x > 0 => format!("{:.1}", b as f64 / x as f64),
                _ => "-".to_string(),
            };
            let fmt_m = |v: Option<u64>| {
                v.map_or("-".to_string(), |b| format!("{:.2}M", b as f64 / 1e6))
            };
            out.push_str(&format!(
                "{:<30} {:>10.4} {:>9.4} {:>11.2} {:>11.2} {:>12} {:>12} {:>9}\n",
                label,
                hist.final_loss(),
                hist.points.last().map_or(f64::NAN, |p| p.test_err),
                hist.total_bits_up() as f64 / 1e6,
                hist.total_bits_down() as f64 / 1e6,
                fmt_m(bl),
                fmt_m(bt),
                saving,
            ));
        }
        if self.sim.iter().any(Option::is_some) {
            out.push_str(&format!(
                "-- simulated network time (virtual clock; s→loss = first loss≤{} crossing)\n",
                self.target_loss
            ));
            for ((label, hist, _), trace) in self.series.iter().zip(&self.sim) {
                let Some(trace) = trace else { continue };
                let to_target = trace
                    .secs_to_loss(hist, self.target_loss)
                    .map_or("-".to_string(), |s| format!("{s:.1}s"));
                out.push_str(&format!(
                    "{:<30} {:>10} {:>12} {:>12}\n",
                    label,
                    format!("{:.1}s", trace.final_secs),
                    format!("s→loss={to_target}"),
                    format!("events={}", trace.events),
                ));
            }
        }
        out
    }

    /// Machine-readable summary row set (used by EXPERIMENTS.md generation).
    pub fn summary_rows(&self) -> Vec<(String, f64, f64, u64, Option<u64>)> {
        self.series
            .iter()
            .map(|(label, h, _)| {
                (
                    label.clone(),
                    h.final_loss(),
                    h.points.last().map_or(f64::NAN, |p| p.test_err),
                    h.total_bits_up(),
                    h.bits_to_loss(self.target_loss),
                )
            })
            .collect()
    }
}

fn sanitize(label: &str) -> String {
    label
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::MetricPoint;

    fn fake_history(final_loss: f64, bits: u64) -> History {
        let mut h = History::new();
        for (i, frac) in [(0usize, 1.0f64), (50, 0.6), (100, 0.3)] {
            h.push(MetricPoint {
                step: i,
                train_loss: final_loss + frac,
                test_err: frac / 2.0,
                test_top5_err: frac / 4.0,
                bits_up: bits * i as u64 / 100,
                bits_down: 0,
                mem_norm_sq: 0.0,
            });
        }
        h
    }

    #[test]
    fn summary_contains_all_series_and_savings() {
        let spec = crate::figures::figure_spec("fig4").unwrap();
        let mut r = FigureResult::new(&spec, 100);
        r.add("SGD", fake_history(0.1, 1_000_000), 1.0);
        r.add("TopK", fake_history(0.1, 10_000), 1.0);
        let s = r.summary();
        assert!(s.contains("SGD"));
        assert!(s.contains("TopK"));
        let rows = r.summary_rows();
        assert_eq!(rows.len(), 2);
        assert!(rows[1].3 < rows[0].3);
    }

    #[test]
    fn write_csvs_creates_files() {
        let spec = crate::figures::figure_spec("fig1").unwrap();
        let mut r = FigureResult::new(&spec, 10);
        r.add("A/B weird label", fake_history(0.5, 100), 0.1);
        let dir = std::env::temp_dir().join(format!("qsparse_test_{}", std::process::id()));
        r.write_csvs(&dir).unwrap();
        let written = dir.join("fig1").join("A_B_weird_label.csv");
        assert!(written.exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
