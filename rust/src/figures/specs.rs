//! The per-figure series definitions (paper §5 + Appendix D), expressed as
//! owned [`ExperimentSpec`] values — the same tables are bundled as JSON
//! under `specs/` at the repo root (`qsparse specs dump` regenerates them;
//! golden tests assert table ≡ bundle, and the pre-redesign hand-built
//! runs are asserted bit-identical in `rust/tests/spec_roundtrip.rs`).
//!
//! Labels follow the paper's legends. k values: 40 for the convex workload
//! (§5.2.2) and ~1% of d for the non-convex workload (the paper's
//! per-tensor min(d_t, 1000) amounts to 0.4% of ResNet-50).

use super::{FigureSpec, Workload};
use crate::compress::Codec;
use crate::protocol::AggScale;
use crate::sim::SimSpec;
use crate::spec::ExperimentSpec;

/// All figure ids in paper order (fig9 — bidirectional compression, fig10 —
/// sampled partial participation, fig11 — server optimizers, fig12 — the
/// rANS wire codec, fig13 — the event-driven network simulator, fig14 —
/// fault injection with deadline rounds — are this repo's extensions, not
/// paper figures).
pub fn all_figure_ids() -> Vec<&'static str> {
    vec![
        "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
        "fig12", "fig13", "fig14",
    ]
}

/// Series factory for one figure: every series starts from the workload's
/// defaults with the figure's horizon, exactly what the legacy tables
/// hardcoded.
struct Fig {
    workload: Workload,
    steps: usize,
}

impl Fig {
    fn s(&self, label: &str, up: &str, h: usize) -> ExperimentSpec {
        ExperimentSpec::for_workload(self.workload)
            .with_label(label)
            .with_up(up)
            .with_h(h)
            .with_steps(self.steps)
    }

    fn a(&self, label: &str, up: &str, h: usize) -> ExperimentSpec {
        self.s(label, up, h).asynchronous(h)
    }

    fn build(
        &self,
        id: &str,
        title: &str,
        target_loss: f64,
        target_test_err: f64,
        series: Vec<ExperimentSpec>,
    ) -> FigureSpec {
        FigureSpec {
            id: id.to_string(),
            title: title.to_string(),
            workload: self.workload,
            series,
            steps: self.steps,
            target_loss,
            target_test_err,
        }
    }
}

/// Build the spec for one figure id.
pub fn figure_spec(id: &str) -> Option<FigureSpec> {
    // k for the non-convex MLP workload (d ≈ 17k ⇒ k ≈ 170).
    const KNC: &str = "170";
    // k for the convex softmax workload (paper: 40).
    const KC: &str = "40";
    let nc = Fig { workload: Workload::NonConvexMlp, steps: 800 };
    let cv = Fig { workload: Workload::ConvexSoftmax, steps: 1500 };
    Some(match id {
        // ---- non-convex (ResNet-50 stand-in) --------------------------------
        "fig1" => nc.build(
            "fig1",
            "non-convex: Qsparse operators vs baselines (loss/acc vs iters & bits)",
            0.05,
            0.12,
            vec![
                nc.s("SGD", "identity", 1),
                nc.s("EF-QSGD-4bit", "qsgd:bits=4", 1),
                nc.s("EF-SignSGD", "sign", 1),
                nc.s("TopK", &format!("topk:k={KNC}"), 1),
                nc.s("QTopK-4bit", &format!("qtopk:k={KNC},bits=4"), 1),
                nc.s("SignTopK", &format!("signtopk:k={KNC},m=1"), 1),
            ],
        ),
        "fig2" => nc.build(
            "fig2",
            "non-convex: effect of local iterations H ∈ {1,4,8}",
            0.05,
            0.12,
            vec![
                nc.s("SGD_1L", "identity", 1),
                nc.s("SGD_4L", "identity", 4),
                nc.s("SGD_8L", "identity", 8),
                nc.s("SignTopK_1L", &format!("signtopk:k={KNC},m=1"), 1),
                nc.s("SignTopK_4L", &format!("signtopk:k={KNC},m=1"), 4),
                nc.s("SignTopK_8L", &format!("signtopk:k={KNC},m=1"), 8),
                nc.s("QTopK_4L", &format!("qtopk:k={KNC},bits=4"), 4),
                nc.s("TopK_4L", &format!("topk:k={KNC}"), 4),
            ],
        ),
        "fig3" => nc.build(
            "fig3",
            "non-convex: Qsparse-local-SGD vs EF-SignSGD / TopK-SGD / local SGD",
            0.05,
            0.12,
            vec![
                nc.s("SGD", "identity", 1),
                nc.s("LocalSGD_8L", "identity", 8),
                nc.s("EF-SignSGD", "sign", 1),
                nc.s("TopK-SGD", &format!("topk:k={KNC}"), 1),
                nc.s("Qsparse-local(SignTopK,8L)", &format!("signtopk:k={KNC},m=1"), 8),
                nc.s("Qsparse-local(QTopK,8L)", &format!("qtopk:k={KNC},bits=4"), 8),
            ],
        ),
        // ---- convex (MNIST-geometry softmax) --------------------------------
        "fig4" => cv.build(
            "fig4",
            "convex: composed operators (2-bit vs 4-bit QSGD; loss vs iters & bits)",
            0.10,
            0.15,
            vec![
                cv.s("SGD", "identity", 1),
                cv.s("EF-QSGD-4bit", "qsgd:bits=4", 1),
                cv.s("EF-QSGD-2bit", "qsgd:bits=2", 1),
                cv.s("TopK", &format!("topk:k={KC}"), 1),
                cv.s("QTopK-4bit", &format!("qtopk:k={KC},bits=4,scaled"), 1),
                cv.s("QTopK-2bit", &format!("qtopk:k={KC},bits=2,scaled"), 1),
                cv.s("SignTopK", &format!("signtopk:k={KC},m=1"), 1),
            ],
        ),
        "fig5" => cv.build(
            "fig5",
            "convex: local iterations × operators; coarse vs fine quantizers",
            0.10,
            0.15,
            vec![
                cv.s("SGD_1L", "identity", 1),
                cv.s("SGD_8L", "identity", 8),
                cv.s("TopK_8L", &format!("topk:k={KC}"), 8),
                cv.s("SignTopK_1L", &format!("signtopk:k={KC},m=1"), 1),
                cv.s("SignTopK_4L", &format!("signtopk:k={KC},m=1"), 4),
                cv.s("SignTopK_8L", &format!("signtopk:k={KC},m=1"), 8),
                cv.s("QTopK-2bit_1L", &format!("qtopk:k={KC},bits=2,scaled"), 1),
                cv.s("QTopK-2bit_8L", &format!("qtopk:k={KC},bits=2,scaled"), 8),
                cv.s("QTopK-4bit_1L", &format!("qtopk:k={KC},bits=4,scaled"), 1),
                cv.s("QTopK-4bit_8L", &format!("qtopk:k={KC},bits=4,scaled"), 8),
            ],
        ),
        "fig6" => cv.build(
            "fig6",
            "convex: Qsparse-local-SGD vs EF-QSGD / EF-SignSGD / TopK-SGD",
            0.10,
            0.15,
            vec![
                cv.s("SGD", "identity", 1),
                cv.s("EF-QSGD", "qsgd:bits=4", 1),
                cv.s("EF-SignSGD", "sign", 1),
                cv.s("TopK-SGD", &format!("topk:k={KC}"), 1),
                cv.s("Qsparse-local(SignTopK,8L)", &format!("signtopk:k={KC},m=1"), 8),
                cv.s("Qsparse-local(QTopK,8L)", &format!("qtopk:k={KC},bits=4,scaled"), 8),
            ],
        ),
        "fig7" => cv.build(
            "fig7",
            "convex asynchronous (Algorithm 2): random per-worker gaps U[1,H]",
            0.10,
            0.15,
            vec![
                cv.a("SGD-async", "identity", 8),
                cv.a("EF-SignSGD-async", "sign", 8),
                cv.a("TopK-async", &format!("topk:k={KC}"), 8),
                cv.a("Qsparse-async(SignTopK)", &format!("signtopk:k={KC},m=1"), 8),
                cv.a("Qsparse-async(QTopK)", &format!("qtopk:k={KC},bits=4,scaled"), 8),
            ],
        ),
        // ---- appendix D ------------------------------------------------------
        "fig8" => nc.build(
            "fig8",
            "appendix D: scaled vs unscaled QTopK under local iterations",
            0.05,
            0.12,
            vec![
                nc.s("QTopK_L0", &format!("qtopk:k={KNC},bits=4"), 1),
                nc.s("QTopK-scaled_L0", &format!("qtopk:k={KNC},bits=4,scaled"), 1),
                nc.s("QTopK_L4", &format!("qtopk:k={KNC},bits=4"), 4),
                nc.s("QTopK-scaled_L4", &format!("qtopk:k={KNC},bits=4,scaled"), 4),
                nc.s("QTopK_L8", &format!("qtopk:k={KNC},bits=4"), 8),
                nc.s("QTopK-scaled_L8", &format!("qtopk:k={KNC},bits=4,scaled"), 8),
            ],
        ),
        // ---- bidirectional extension (not in the paper) ----------------------
        // Downlink error-compensated compression (Double Quantization /
        // EC-QSGD style) on top of the paper's uplink operators. The downlink
        // k is 10× the uplink k: the broadcast carries the *aggregate* of R
        // worker updates, so its support is naturally wider.
        "fig9" => cv.build(
            "fig9",
            "convex: bidirectional compression (downlink EF) vs dense broadcast",
            0.10,
            0.15,
            vec![
                cv.s("SGD", "identity", 1),
                cv.s("QTopK-up", &format!("qtopk:k={KC},bits=4,scaled"), 1),
                cv.s("QTopK-bidir", &format!("qtopk:k={KC},bits=4,scaled"), 1)
                    .with_down("qtopk:k=400,bits=4"),
                cv.s("TopK-bidir", &format!("topk:k={KC}"), 1).with_down("topk:k=400"),
                cv.s("SignTopK-bidir_8L", &format!("signtopk:k={KC},m=1"), 8)
                    .with_down("qtopk:k=400,bits=4"),
            ],
        ),
        // ---- sampled partial participation (not in the paper) ----------------
        // Bits-to-target under sampled worker subsets per sync round: only
        // S_t ⊆ [R] workers sync each round (federated-style client
        // sampling), uplink QTop_k + compressed downlink. The unbiased
        // 1/|S_t| scale is compared with the paper's 1/R fold, which under-
        // steps by E|S_t|/R the moment participation is partial.
        "fig10" => cv.build(
            "fig10",
            "convex: sampled participation p ∈ {1.0, 0.5, 0.25} (1/|S_t| vs 1/R)",
            0.10,
            0.15,
            vec![
                cv.s("QTopK-bidir_p1.00", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4"),
                cv.s("QTopK-bidir_p0.50", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("bernoulli:0.5", AggScale::Participants),
                cv.s("QTopK-bidir_p0.25", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("bernoulli:0.25", AggScale::Participants),
                cv.s("QTopK-bidir_m8", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("fixed:8", AggScale::Participants),
                cv.s("QTopK-bidir_p0.50_1R", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("bernoulli:0.5", AggScale::Workers),
            ],
        ),
        // ---- server optimizers (not in the paper) ----------------------------
        // FedOpt-style server momentum/Adam on the round aggregate, composed
        // with the error-compensated bidirectional path: bits-to-target of a
        // stepped server vs the paper's plain averaging, everything else
        // (QTopK uplink, compressed downlink, H = 4) held fixed. The
        // momentum series use lr = 1 − β (EMA of round deltas: steady-state
        // step magnitude matches Avg, so differences are pure smoothing).
        "fig11" => cv.build(
            "fig11",
            "convex: server optimizer (FedOpt) vs plain averaging under QTopK + compressed downlink",
            0.10,
            0.15,
            vec![
                cv.s("QTopK-bidir_avg", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4"),
                cv.s("QTopK-bidir_mom0.9", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_server_opt("momentum:beta=0.9,lr=0.1"),
                cv.s("QTopK-bidir_mom0.5", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_server_opt("momentum:beta=0.5,lr=0.5"),
                cv.s("QTopK-bidir_adam", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_server_opt("adam:b1=0.9,b2=0.99,eps=0.001,lr=0.01"),
                cv.s("QTopK-up_mom0.9", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_server_opt("momentum:beta=0.9,lr=0.1"),
            ],
        ),
        // ---- entropy-coded wire format (not in the paper) --------------------
        // rANS gap/level coding as the wire codec on the bidirectional paths.
        // Decoded payloads are bit-identical to raw — each raw/rans pair
        // produces the *same* trajectory, so the bits-to-target table isolates
        // the pure wire saving (uplink index gaps + values, downlink levels).
        "fig12" => cv.build(
            "fig12",
            "convex: rANS entropy-coded wire format vs raw (same trajectories, fewer bits)",
            0.10,
            0.15,
            vec![
                cv.s("TopK-bidir-raw", &format!("topk:k={KC}"), 1).with_down("topk:k=400"),
                cv.s("TopK-bidir-rans", &format!("topk:k={KC}"), 1)
                    .with_down("topk:k=400")
                    .with_codec(Codec::Rans),
                cv.s("QTopK-bidir-raw", &format!("qtopk:k={KC},bits=4,scaled"), 1)
                    .with_down("qtopk:k=400,bits=4"),
                cv.s("QTopK-bidir-rans", &format!("qtopk:k={KC},bits=4,scaled"), 1)
                    .with_down("qtopk:k=400,bits=4")
                    .with_codec(Codec::Rans),
            ],
        ),
        // ---- event-driven network simulator (not in the paper) ---------------
        // Simulated seconds-to-target on a virtual clock (`sim::`): per-client
        // compute/bandwidth drawn from skewed lognormal-ish distributions
        // (p50-vs-p99 client-speed skew), occasional 8× stragglers, and a
        // disconnect/reconnect churn scenario. The sync barrier pays the p99
        // client every round; Algorithm 2's random gaps decouple it — the
        // figure quantifies that wall-clock gap, which bits-to-target (fig
        // 1–12) cannot see. The async+momentum series exercises the server
        // optimizer under the simulator's round clock.
        "fig13" => {
            let skew = SimSpec {
                compute_sigma: 0.8,
                bw_sigma: 0.5,
                latency: 2_000,
                straggler_prob: 0.05,
                straggler_mult: 8.0,
                ..SimSpec::default()
            };
            let churn = SimSpec {
                churn_online_mean: 400_000,
                churn_offline_mean: 200_000,
                ..skew
            };
            cv.build(
                "fig13",
                "convex: simulated seconds-to-target under stragglers, bandwidth skew and churn",
                0.10,
                0.15,
                vec![
                    cv.s("SGD-sync", "identity", 8).with_sim(skew),
                    cv.s("TopK-sync", &format!("topk:k={KC}"), 8).with_sim(skew),
                    cv.a("TopK-async", &format!("topk:k={KC}"), 8).with_sim(skew),
                    cv.a("QTopK-async", &format!("qtopk:k={KC},bits=4,scaled"), 8).with_sim(skew),
                    cv.a("QTopK-async_p0.5", &format!("qtopk:k={KC},bits=4,scaled"), 8)
                        .with_participation("bernoulli:0.5", AggScale::Participants)
                        .with_sim(skew),
                    cv.a("QTopK-async_churn", &format!("qtopk:k={KC},bits=4,scaled"), 8)
                        .with_sim(churn),
                    cv.a("QTopK-async_mom0.9", &format!("qtopk:k={KC},bits=4,scaled"), 8)
                        .with_server_opt("momentum:beta=0.9,lr=0.1")
                        .with_sim(skew),
                ],
            )
        }
        // ---- fault tolerance (not in the paper) ------------------------------
        // Loss under deterministic uplink loss on the simulator's virtual
        // clock: the master closes each round at a deadline, dropped or
        // corrupted updates are re-absorbed into the sender's error memory
        // (m ← m + ĝ), so lost mass is delayed rather than destroyed. The
        // sweep varies the drop rate with everything else fixed; the last
        // series piles on corruption, duplication, delay-reordering and
        // crash-restarts to show the cocktail still converges.
        "fig14" => {
            let skew = SimSpec {
                compute_sigma: 0.8,
                bw_sigma: 0.5,
                latency: 2_000,
                straggler_prob: 0.05,
                straggler_mult: 8.0,
                ..SimSpec::default()
            };
            let qtopk = format!("qtopk:k={KC},bits=4,scaled");
            cv.build(
                "fig14",
                "convex: loss vs uplink drop rate under deadline rounds and EF re-absorption",
                0.10,
                0.15,
                vec![
                    cv.s("QTopK_drop0.0", &qtopk, 8).with_sim(skew),
                    cv.s("QTopK_drop0.1", &qtopk, 8)
                        .with_sim(skew)
                        .with_faults("drop=0.1,deadline=40000,seed=14"),
                    cv.s("QTopK_drop0.2", &qtopk, 8)
                        .with_sim(skew)
                        .with_faults("drop=0.2,deadline=40000,seed=14"),
                    cv.s("QTopK_drop0.3", &qtopk, 8)
                        .with_sim(skew)
                        .with_faults("drop=0.3,deadline=40000,seed=14"),
                    cv.s("QTopK_cocktail", &qtopk, 8).with_sim(skew).with_faults(
                        "drop=0.1,corrupt=0.05,dup=0.05,delay=0.05:20000,\
                         drop-down=0.05,corrupt-down=0.05,crash=0.01,deadline=40000,seed=14",
                    ),
                ],
            )
        }
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_has_a_spec_that_validates() {
        for id in all_figure_ids() {
            let spec = figure_spec(id).unwrap_or_else(|| panic!("{id} missing"));
            assert_eq!(spec.id, id);
            assert!(!spec.series.is_empty());
            for s in &spec.series {
                s.validate().unwrap_or_else(|e| panic!("{id}/{}: {e}", s.label));
                assert_eq!(s.workload, spec.workload, "{id}/{}", s.label);
                assert_eq!(s.steps, spec.steps, "{id}/{}", s.label);
                assert!(s.schedule.h() >= 1);
            }
        }
        assert!(figure_spec("fig99").is_none());
    }

    #[test]
    fn labels_unique_within_figure() {
        for id in all_figure_ids() {
            let spec = figure_spec(id).unwrap();
            let mut labels: Vec<_> = spec.series.iter().map(|s| s.label.clone()).collect();
            labels.sort_unstable();
            labels.dedup();
            assert_eq!(labels.len(), spec.series.len(), "{id} duplicate labels");
        }
    }

    #[test]
    fn fig12_pairs_differ_only_in_the_codec() {
        // Each raw/rans pair must describe the same run up to the wire
        // codec — that is what makes the figure's trajectories identical
        // and its bits comparison a pure wire measurement.
        let spec = figure_spec("fig12").unwrap();
        assert_eq!(spec.series.len() % 2, 0);
        for pair in spec.series.chunks(2) {
            let (raw, rans) = (&pair[0], &pair[1]);
            assert_eq!(raw.codec, Codec::Raw, "{}", raw.label);
            assert_eq!(rans.codec, Codec::Rans, "{}", rans.label);
            let mut normalized = rans.clone();
            normalized.codec = Codec::Raw;
            normalized.label = raw.label.clone();
            assert_eq!(&normalized, raw);
        }
    }

    #[test]
    fn fig11_varies_only_the_server_opt_on_the_bidir_axis() {
        use crate::optim::ServerOptSpec;
        let spec = figure_spec("fig11").unwrap();
        assert!(spec.series[0].server_opt.is_avg(), "first series is the Avg baseline");
        assert!(spec.series.iter().skip(1).all(|s| !s.server_opt.is_avg()));
        assert!(matches!(spec.series[3].server_opt, ServerOptSpec::Adam { .. }));
    }
}
