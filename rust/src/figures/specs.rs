//! The per-figure series definitions (paper §5 + Appendix D).
//!
//! Labels follow the paper's legends. k values: 40 for the convex workload
//! (§5.2.2) and ~1% of d for the non-convex workload (the paper's
//! per-tensor min(d_t, 1000) amounts to 0.4% of ResNet-50).

use super::{FigureSpec, SeriesSpec, Workload};
use crate::protocol::AggScale;

/// All figure ids in paper order (fig9 — bidirectional compression — and
/// fig10 — sampled partial participation — are this repo's extensions, not
/// paper figures).
pub fn all_figure_ids() -> Vec<&'static str> {
    vec!["fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10"]
}

/// Build the spec for one figure id.
pub fn figure_spec(id: &str) -> Option<FigureSpec> {
    // k for the non-convex MLP workload (d ≈ 17k ⇒ k ≈ 170).
    const KNC: &str = "170";
    // k for the convex softmax workload (paper: 40).
    const KC: &str = "40";
    let s = SeriesSpec::new;
    let a = SeriesSpec::asynchronous;
    Some(match id {
        // ---- non-convex (ResNet-50 stand-in) --------------------------------
        "fig1" => FigureSpec {
            id: "fig1",
            title: "non-convex: Qsparse operators vs baselines (loss/acc vs iters & bits)",
            workload: Workload::NonConvexMlp,
            steps: 800,
            target_loss: 0.05,
            target_test_err: 0.12,
            series: vec![
                s("SGD", "identity", 1),
                s("EF-QSGD-4bit", "qsgd:bits=4", 1),
                s("EF-SignSGD", "sign", 1),
                s("TopK", &format!("topk:k={KNC}"), 1),
                s("QTopK-4bit", &format!("qtopk:k={KNC},bits=4"), 1),
                s("SignTopK", &format!("signtopk:k={KNC},m=1"), 1),
            ],
        },
        "fig2" => FigureSpec {
            id: "fig2",
            title: "non-convex: effect of local iterations H ∈ {1,4,8}",
            workload: Workload::NonConvexMlp,
            steps: 800,
            target_loss: 0.05,
            target_test_err: 0.12,
            series: vec![
                s("SGD_1L", "identity", 1),
                s("SGD_4L", "identity", 4),
                s("SGD_8L", "identity", 8),
                s("SignTopK_1L", &format!("signtopk:k={KNC},m=1"), 1),
                s("SignTopK_4L", &format!("signtopk:k={KNC},m=1"), 4),
                s("SignTopK_8L", &format!("signtopk:k={KNC},m=1"), 8),
                s("QTopK_4L", &format!("qtopk:k={KNC},bits=4"), 4),
                s("TopK_4L", &format!("topk:k={KNC}"), 4),
            ],
        },
        "fig3" => FigureSpec {
            id: "fig3",
            title: "non-convex: Qsparse-local-SGD vs EF-SignSGD / TopK-SGD / local SGD",
            workload: Workload::NonConvexMlp,
            steps: 800,
            target_loss: 0.05,
            target_test_err: 0.12,
            series: vec![
                s("SGD", "identity", 1),
                s("LocalSGD_8L", "identity", 8),
                s("EF-SignSGD", "sign", 1),
                s("TopK-SGD", &format!("topk:k={KNC}"), 1),
                s("Qsparse-local(SignTopK,8L)", &format!("signtopk:k={KNC},m=1"), 8),
                s("Qsparse-local(QTopK,8L)", &format!("qtopk:k={KNC},bits=4"), 8),
            ],
        },
        // ---- convex (MNIST-geometry softmax) --------------------------------
        "fig4" => FigureSpec {
            id: "fig4",
            title: "convex: composed operators (2-bit vs 4-bit QSGD; loss vs iters & bits)",
            workload: Workload::ConvexSoftmax,
            steps: 1500,
            target_loss: 0.10,
            target_test_err: 0.15,
            series: vec![
                s("SGD", "identity", 1),
                s("EF-QSGD-4bit", "qsgd:bits=4", 1),
                s("EF-QSGD-2bit", "qsgd:bits=2", 1),
                s("TopK", &format!("topk:k={KC}"), 1),
                s("QTopK-4bit", &format!("qtopk:k={KC},bits=4,scaled"), 1),
                s("QTopK-2bit", &format!("qtopk:k={KC},bits=2,scaled"), 1),
                s("SignTopK", &format!("signtopk:k={KC},m=1"), 1),
            ],
        },
        "fig5" => FigureSpec {
            id: "fig5",
            title: "convex: local iterations × operators; coarse vs fine quantizers",
            workload: Workload::ConvexSoftmax,
            steps: 1500,
            target_loss: 0.10,
            target_test_err: 0.15,
            series: vec![
                s("SGD_1L", "identity", 1),
                s("SGD_8L", "identity", 8),
                s("TopK_8L", &format!("topk:k={KC}"), 8),
                s("SignTopK_1L", &format!("signtopk:k={KC},m=1"), 1),
                s("SignTopK_4L", &format!("signtopk:k={KC},m=1"), 4),
                s("SignTopK_8L", &format!("signtopk:k={KC},m=1"), 8),
                s("QTopK-2bit_1L", &format!("qtopk:k={KC},bits=2,scaled"), 1),
                s("QTopK-2bit_8L", &format!("qtopk:k={KC},bits=2,scaled"), 8),
                s("QTopK-4bit_1L", &format!("qtopk:k={KC},bits=4,scaled"), 1),
                s("QTopK-4bit_8L", &format!("qtopk:k={KC},bits=4,scaled"), 8),
            ],
        },
        "fig6" => FigureSpec {
            id: "fig6",
            title: "convex: Qsparse-local-SGD vs EF-QSGD / EF-SignSGD / TopK-SGD",
            workload: Workload::ConvexSoftmax,
            steps: 1500,
            target_loss: 0.10,
            target_test_err: 0.15,
            series: vec![
                s("SGD", "identity", 1),
                s("EF-QSGD", "qsgd:bits=4", 1),
                s("EF-SignSGD", "sign", 1),
                s("TopK-SGD", &format!("topk:k={KC}"), 1),
                s("Qsparse-local(SignTopK,8L)", &format!("signtopk:k={KC},m=1"), 8),
                s("Qsparse-local(QTopK,8L)", &format!("qtopk:k={KC},bits=4,scaled"), 8),
            ],
        },
        "fig7" => FigureSpec {
            id: "fig7",
            title: "convex asynchronous (Algorithm 2): random per-worker gaps U[1,H]",
            workload: Workload::ConvexSoftmax,
            steps: 1500,
            target_loss: 0.10,
            target_test_err: 0.15,
            series: vec![
                a("SGD-async", "identity", 8),
                a("EF-SignSGD-async", "sign", 8),
                a("TopK-async", &format!("topk:k={KC}"), 8),
                a("Qsparse-async(SignTopK)", &format!("signtopk:k={KC},m=1"), 8),
                a("Qsparse-async(QTopK)", &format!("qtopk:k={KC},bits=4,scaled"), 8),
            ],
        },
        // ---- appendix D ------------------------------------------------------
        "fig8" => FigureSpec {
            id: "fig8",
            title: "appendix D: scaled vs unscaled QTopK under local iterations",
            workload: Workload::NonConvexMlp,
            steps: 800,
            target_loss: 0.05,
            target_test_err: 0.12,
            series: vec![
                s("QTopK_L0", &format!("qtopk:k={KNC},bits=4"), 1),
                s("QTopK-scaled_L0", &format!("qtopk:k={KNC},bits=4,scaled"), 1),
                s("QTopK_L4", &format!("qtopk:k={KNC},bits=4"), 4),
                s("QTopK-scaled_L4", &format!("qtopk:k={KNC},bits=4,scaled"), 4),
                s("QTopK_L8", &format!("qtopk:k={KNC},bits=4"), 8),
                s("QTopK-scaled_L8", &format!("qtopk:k={KNC},bits=4,scaled"), 8),
            ],
        },
        // ---- bidirectional extension (not in the paper) ----------------------
        // Downlink error-compensated compression (Double Quantization /
        // EC-QSGD style) on top of the paper's uplink operators. The downlink
        // k is 10× the uplink k: the broadcast carries the *aggregate* of R
        // worker updates, so its support is naturally wider.
        "fig9" => FigureSpec {
            id: "fig9",
            title: "convex: bidirectional compression (downlink EF) vs dense broadcast",
            workload: Workload::ConvexSoftmax,
            steps: 1500,
            target_loss: 0.10,
            target_test_err: 0.15,
            series: vec![
                s("SGD", "identity", 1),
                s("QTopK-up", &format!("qtopk:k={KC},bits=4,scaled"), 1),
                s("QTopK-bidir", &format!("qtopk:k={KC},bits=4,scaled"), 1)
                    .with_down("qtopk:k=400,bits=4"),
                s("TopK-bidir", &format!("topk:k={KC}"), 1).with_down("topk:k=400"),
                s("SignTopK-bidir_8L", &format!("signtopk:k={KC},m=1"), 8)
                    .with_down("qtopk:k=400,bits=4"),
            ],
        },
        // ---- sampled partial participation (not in the paper) ----------------
        // Bits-to-target under sampled worker subsets per sync round: only
        // S_t ⊆ [R] workers sync each round (federated-style client
        // sampling), uplink QTop_k + compressed downlink. The unbiased
        // 1/|S_t| scale is compared with the paper's 1/R fold, which under-
        // steps by E|S_t|/R the moment participation is partial.
        "fig10" => FigureSpec {
            id: "fig10",
            title: "convex: sampled participation p ∈ {1.0, 0.5, 0.25} (1/|S_t| vs 1/R)",
            workload: Workload::ConvexSoftmax,
            steps: 1500,
            target_loss: 0.10,
            target_test_err: 0.15,
            series: vec![
                s("QTopK-bidir_p1.00", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4"),
                s("QTopK-bidir_p0.50", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("bernoulli:0.5", AggScale::Participants),
                s("QTopK-bidir_p0.25", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("bernoulli:0.25", AggScale::Participants),
                s("QTopK-bidir_m8", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("fixed:8", AggScale::Participants),
                s("QTopK-bidir_p0.50_1R", &format!("qtopk:k={KC},bits=4,scaled"), 4)
                    .with_down("qtopk:k=400,bits=4")
                    .with_participation("bernoulli:0.5", AggScale::Workers),
            ],
        },
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_id_has_a_spec_and_parses() {
        for id in all_figure_ids() {
            let spec = figure_spec(id).unwrap_or_else(|| panic!("{id} missing"));
            assert_eq!(spec.id, id);
            assert!(!spec.series.is_empty());
            for s in &spec.series {
                crate::compress::parse_spec(&s.compressor)
                    .unwrap_or_else(|e| panic!("{id}/{}: {e}", s.label));
                crate::compress::parse_spec(&s.down)
                    .unwrap_or_else(|e| panic!("{id}/{} downlink: {e}", s.label));
                crate::topology::ParticipationSpec::parse(&s.participation)
                    .unwrap_or_else(|e| panic!("{id}/{} participation: {e}", s.label));
                assert!(s.h >= 1);
            }
        }
        assert!(figure_spec("fig99").is_none());
    }

    #[test]
    fn labels_unique_within_figure() {
        for id in all_figure_ids() {
            let spec = figure_spec(id).unwrap();
            let mut labels: Vec<_> = spec.series.iter().map(|s| s.label).collect();
            labels.sort_unstable();
            labels.dedup();
            assert_eq!(labels.len(), spec.series.len(), "{id} duplicate labels");
        }
    }
}
