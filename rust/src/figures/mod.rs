//! Figure harness: one spec per paper figure (DESIGN.md §4).
//!
//! Every figure is a set of *series* (compressor × sync period × schedule
//! kind) over one of two workloads:
//!
//! * `ConvexSoftmax` — ℓ2-regularized softmax regression with the paper's
//!   MNIST geometry (d = 7850, R = 15, b = 8; §5.2) on synthetic clusters.
//! * `NonConvexMlp` — ReLU MLP with momentum 0.9 on local iterations,
//!   standing in for ResNet-50/ImageNet (§5.1; substitution DESIGN.md §6).
//!
//! `run_figure` executes every series through the deterministic engine,
//! writes `results/<fig>/<series>.csv` and prints the paper-style summary
//! (bits-to-target ratios vs the uncompressed baseline).

pub mod report;
pub mod specs;

pub use report::FigureResult;
pub use specs::{all_figure_ids, figure_spec};

use crate::compress::Compressor;
use crate::data::{gaussian_clusters_split, Dataset, Sharding};
use crate::engine::{self, History, TrainSpec};
use crate::grad::{GradModel, Mlp, SoftmaxRegression};
use crate::optim::LrSchedule;
use crate::protocol::AggScale;
use crate::topology::{FixedPeriod, ParticipationSpec, RandomGaps, SyncSchedule};

/// The two simulated workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// d = 7850 softmax regression, R = 15, b = 8 (paper §5.2).
    ConvexSoftmax,
    /// MLP classifier with momentum, R = 8, b = 16 (stand-in for §5.1).
    NonConvexMlp,
}

/// One curve in a figure.
pub struct SeriesSpec {
    pub label: &'static str,
    /// Compressor spec string (`compress::parse_spec`).
    pub compressor: String,
    /// Downlink compressor spec; `identity` = dense model broadcast.
    pub down: String,
    /// Sync period H (1 = sync every step).
    pub h: usize,
    /// Use the asynchronous schedule of Algorithm 2 (random per-worker gaps).
    pub asynchronous: bool,
    /// Sampled participation spec (`ParticipationSpec::parse`); `full` is
    /// the paper's setting.
    pub participation: String,
    /// Aggregation scaling under sampled participation.
    pub agg_scale: AggScale,
}

impl SeriesSpec {
    pub fn new(label: &'static str, compressor: &str, h: usize) -> Self {
        SeriesSpec {
            label,
            compressor: compressor.to_string(),
            down: "identity".to_string(),
            h,
            asynchronous: false,
            participation: "full".to_string(),
            agg_scale: AggScale::Workers,
        }
    }

    pub fn asynchronous(label: &'static str, compressor: &str, h: usize) -> Self {
        SeriesSpec { asynchronous: true, ..SeriesSpec::new(label, compressor, h) }
    }

    /// Builder: compress the downlink with `spec` (bidirectional series).
    pub fn with_down(mut self, spec: &str) -> Self {
        self.down = spec.to_string();
        self
    }

    /// Builder: sample worker participation per sync round.
    pub fn with_participation(mut self, spec: &str, scale: AggScale) -> Self {
        self.participation = spec.to_string();
        self.agg_scale = scale;
        self
    }
}

/// A full figure: workload + series + horizon + headline targets.
pub struct FigureSpec {
    pub id: &'static str,
    pub title: &'static str,
    pub workload: Workload,
    pub series: Vec<SeriesSpec>,
    pub steps: usize,
    /// Train-loss target for the bits-to-target summary.
    pub target_loss: f64,
    /// Test-error target (convex figures report test error).
    pub target_test_err: f64,
}

/// Workload instantiation shared by all series of a figure (same data, same
/// eval subsets, same seed ⇒ curves are directly comparable).
pub struct WorkloadInstance {
    pub train: Dataset,
    pub test: Dataset,
    pub model: Box<dyn GradModel>,
    pub init: Vec<f32>,
    pub workers: usize,
    pub batch: usize,
    pub lr: LrSchedule,
    pub momentum: f64,
    /// Reference k for Top_k in this workload (paper: 40 convex, ~1k/tensor
    /// non-convex).
    pub k: usize,
    pub eval_every: usize,
}

pub const SEED: u64 = 20190527; // NeurIPS 2019 submission deadline :-)

impl Workload {
    pub fn instantiate(self, quick: bool) -> WorkloadInstance {
        match self {
            Workload::ConvexSoftmax => {
                let (n, steps_scale) = if quick { (1500, 1) } else { (6000, 1) };
                let dim = 784;
                let classes = 10;
                let (train, test) =
                    gaussian_clusters_split(n, n / 4, dim, classes, 0.12, 1.0, SEED);
                let model = SoftmaxRegression::new(dim, classes, 1.0 / n as f64);
                let d = (dim + 1) * classes;
                let _ = steps_scale;
                let k = 40; // paper §5.2.2
                let h_ref = 8usize;
                // η_t = ξ/(a+t), a = dH/k (paper §5.2.2), ξ chosen so η_0 ≈ 1.2.
                let a = (d * h_ref / k) as f64;
                WorkloadInstance {
                    init: vec![0.0; model.dim()],
                    model: Box::new(model),
                    train,
                    test,
                    workers: 15,
                    batch: 8,
                    lr: LrSchedule::InvTime { xi: 1.2 * a, a },
                    momentum: 0.0,
                    k,
                    eval_every: 25,
                }
            }
            Workload::NonConvexMlp => {
                let n = if quick { 1200 } else { 4000 };
                let dim = 256;
                let classes = 10;
                let widths = vec![dim, 64, classes];
                let (train, test) =
                    gaussian_clusters_split(n, n / 4, dim, classes, 0.22, 1.0, SEED ^ 2);
                let model = Mlp::new(widths);
                let init = model.init_params(SEED);
                let d = model.dim();
                WorkloadInstance {
                    init,
                    model: Box::new(model),
                    train,
                    test,
                    workers: 8,
                    batch: 16,
                    lr: LrSchedule::Const { eta: 0.08 },
                    momentum: 0.9,
                    k: d / 100, // ~1% like the paper's per-tensor min(d_t, 1000)
                    eval_every: 20,
                }
            }
        }
    }
}

/// Run one series of a figure on an instantiated workload.
pub fn run_series(
    w: &WorkloadInstance,
    s: &SeriesSpec,
    steps: usize,
    seed: u64,
) -> anyhow::Result<History> {
    let compressor: Box<dyn Compressor> = crate::compress::parse_spec(&s.compressor)?;
    let down_compressor: Box<dyn Compressor> = crate::compress::parse_spec(&s.down)?;
    let schedule: Box<dyn SyncSchedule> = if s.asynchronous {
        Box::new(RandomGaps::generate(w.workers, s.h, steps, seed ^ 0x5eed))
    } else {
        Box::new(FixedPeriod::new(s.h))
    };
    let participation =
        ParticipationSpec::parse(&s.participation)?.materialize(w.workers, steps, seed);
    let spec = TrainSpec {
        model: w.model.as_ref(),
        train: &w.train,
        test: Some(&w.test),
        workers: w.workers,
        batch: w.batch,
        steps,
        lr: w.lr.clone(),
        momentum: w.momentum,
        compressor: compressor.as_ref(),
        down_compressor: down_compressor.as_ref(),
        schedule: schedule.as_ref(),
        participation: &participation,
        agg_scale: s.agg_scale,
        sharding: Sharding::Iid,
        seed,
        eval_every: w.eval_every,
        eval_rows: 512,
        threads: 1,
    };
    Ok(engine::run_from(&spec, w.init.clone()))
}

/// Run a whole figure; returns per-series histories with labels.
pub fn run_figure(spec: &FigureSpec, quick: bool) -> anyhow::Result<FigureResult> {
    let w = spec.workload.instantiate(quick);
    let steps = if quick { spec.steps / 4 } else { spec.steps };
    let mut result = FigureResult::new(spec, steps);
    for s in &spec.series {
        let t0 = std::time::Instant::now();
        let hist = run_series(&w, s, steps, SEED)?;
        result.add(s.label, hist, t0.elapsed().as_secs_f64());
    }
    Ok(result)
}

/// The γ table (Lemmas 1–3): analytic worst-case γ plus the measured
/// residual ratio E‖x−C(x)‖²/‖x‖² on random Gaussian vectors.
pub fn gamma_table(d: usize, k: usize) -> Vec<(String, f64, f64)> {
    use crate::util::rng::Pcg64;
    use crate::util::stats::norm2_sq;
    let specs = [
        format!("topk:k={k}"),
        format!("randk:k={k}"),
        "qsgd:bits=4".to_string(),
        "sign".to_string(),
        format!("qtopk:k={k},bits=4"),
        format!("qtopk:k={k},bits=4,scaled"),
        format!("qtopk:k={k},bits=2,scaled"),
        format!("signtopk:k={k},m=1"),
        format!("signtopk:k={k},m=2"),
    ];
    let mut rng = Pcg64::seeded(SEED);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let x_norm = norm2_sq(&x);
    let mut out = Vec::new();
    for spec in &specs {
        let op = crate::compress::parse_spec(spec).unwrap();
        let trials = 200;
        let mut acc = 0.0;
        for _ in 0..trials {
            let dense = op.compress(&x, &mut rng).to_dense();
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            acc += norm2_sq(&resid);
        }
        let measured_ratio = acc / trials as f64 / x_norm;
        out.push((op.name(), op.gamma(d), measured_ratio));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workloads_instantiate() {
        for wl in [Workload::ConvexSoftmax, Workload::NonConvexMlp] {
            let w = wl.instantiate(true);
            assert_eq!(w.init.len(), w.model.dim());
            assert!(w.train.n > 0 && w.test.n > 0);
        }
    }

    #[test]
    fn gamma_table_bounds_hold() {
        // measured residual ratio ≤ 1 − γ_analytic (+ MC slack) for every op.
        // Dense QSGD has γ = 0 when β_{d,s} ≥ 1 (Remark 1: outside the
        // operating regime) — the bound is then vacuous, so skip it.
        for (name, gamma, measured) in gamma_table(512, 32) {
            assert!((0.0..=1.0).contains(&gamma), "{name}: γ={gamma}");
            if gamma > 0.0 {
                assert!(
                    measured <= (1.0 - gamma) + 0.05,
                    "{name}: measured {measured} vs 1−γ {}",
                    1.0 - gamma
                );
            }
        }
    }

    #[test]
    fn quick_series_runs() {
        let w = Workload::ConvexSoftmax.instantiate(true);
        let s = SeriesSpec::new("t", "topk:k=40", 4);
        let h = run_series(&w, &s, 40, SEED).unwrap();
        assert!(h.points.len() >= 2);
        assert!(h.final_loss().is_finite());
    }
}
