//! Figure harness: one spec per paper figure (DESIGN.md §4).
//!
//! Every figure is a set of *series* — each an owned
//! [`crate::spec::ExperimentSpec`] — over one of the two workloads
//! (re-exported from `spec::workload`, where they moved so the spec layer
//! can name them). The per-figure tables live in [`specs`], are bundled as
//! JSON under `specs/` at the repo root (`qsparse specs dump` regenerates,
//! `qsparse specs validate` smoke-runs them), and golden tests assert the
//! two stay equal.
//!
//! `run_figure` instantiates the workload once (all series share the same
//! data/eval subsets, so curves are comparable), runs every series through
//! the deterministic engine — concurrently, one scoped thread per series,
//! when the model is `Sync`; per-series seeds are unchanged, so the CSVs
//! are bit-identical to the sequential harness — and writes
//! `results/<fig>/<series>.csv` plus the paper-style summary.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) — everywhere else it is a compile error.
#![forbid(unsafe_code)]

pub mod report;
pub mod specs;

pub use report::{FigureResult, SimTrace};
pub use specs::{all_figure_ids, figure_spec};

// Workload types live in `spec::` now; re-exported here so historical
// `figures::Workload` / `figures::SEED` call sites keep working.
pub use crate::spec::{Workload, WorkloadInstance, SEED};

use crate::data::Dataset;
use crate::engine::{self, History, TrainSpec};
use crate::grad::GradModel;
use crate::spec::ExperimentSpec;
use crate::util::json::Json;

/// A full figure: workload + series + horizon + headline targets.
#[derive(Debug, PartialEq)]
pub struct FigureSpec {
    pub id: String,
    pub title: String,
    pub workload: Workload,
    pub series: Vec<ExperimentSpec>,
    pub steps: usize,
    /// Train-loss target for the bits-to-target summary.
    pub target_loss: f64,
    /// Test-error target (convex figures report test error).
    pub target_test_err: f64,
}

impl FigureSpec {
    /// Serialize (the bundled `specs/<id>.json` format).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.as_str())),
            ("title", Json::str(self.title.as_str())),
            ("workload", Json::str(self.workload.spec_str())),
            ("steps", Json::from(self.steps)),
            ("target_loss", Json::num(self.target_loss)),
            ("target_test_err", Json::num(self.target_test_err)),
            ("series", Json::arr(self.series.iter().map(ExperimentSpec::to_json))),
        ])
    }

    /// Deserialize, with the same strict unknown-field policy as
    /// `ExperimentSpec::from_json`.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let obj = j
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("figure spec must be a JSON object"))?;
        const KNOWN: &[&str] =
            &["id", "title", "workload", "steps", "target_loss", "target_test_err", "series"];
        for key in obj.keys() {
            anyhow::ensure!(
                KNOWN.contains(&key.as_str()),
                "unknown field `{key}` in figure spec (known fields: {})",
                KNOWN.join(", ")
            );
        }
        let get_str = |key: &str| -> anyhow::Result<String> {
            j.get(key)
                .as_str()
                .map(str::to_string)
                .ok_or_else(|| anyhow::anyhow!("figure field `{key}` must be a string"))
        };
        let get_f64 = |key: &str| -> anyhow::Result<f64> {
            j.get(key)
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("figure field `{key}` must be a number"))
        };
        let series = j
            .get("series")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("figure field `series` must be an array"))?
            .iter()
            .map(ExperimentSpec::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(!series.is_empty(), "figure field `series` must be non-empty");
        let steps = j
            .get("steps")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("figure field `steps` must be an integer"))?;
        let workload = Workload::parse(&get_str("workload")?)?;
        // Series must agree with the figure on workload and horizon — the
        // harness shares one workload instance and one step count across
        // all series, so a mismatch would silently run a hybrid config.
        for s in &series {
            anyhow::ensure!(
                s.workload == workload,
                "series `{}` declares workload `{}` but the figure is `{}`",
                s.label,
                s.workload.spec_str(),
                workload.spec_str()
            );
            anyhow::ensure!(
                s.steps == steps,
                "series `{}` declares {} steps but the figure runs {steps}",
                s.label,
                s.steps
            );
        }
        Ok(FigureSpec {
            id: get_str("id")?,
            title: get_str("title")?,
            workload,
            series,
            steps,
            target_loss: get_f64("target_loss")?,
            target_test_err: get_f64("target_test_err")?,
        })
    }

    pub fn from_json_str(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("figure spec: {e}"))?;
        Self::from_json(&j)
    }
}

/// Run one series on an instantiated workload, truncating the horizon to
/// `steps` (the figure harness's quick mode). The series' own stored
/// `steps` is the full-fidelity horizon.
pub fn run_series(
    w: &WorkloadInstance,
    s: &ExperimentSpec,
    steps: usize,
) -> anyhow::Result<History> {
    Ok(run_series_on(w.model.as_ref(), &w.train, &w.test, &w.init, s, steps)?.0)
}

/// As [`run_series`], over the workload's individual (all `Sync`) pieces —
/// the parallel harness hands each scoped thread the model's `Sync` view
/// plus shared references to the datasets and init. A series whose spec
/// embeds a `sim` scenario runs through the event-driven network simulator
/// (same arithmetic, virtual clock) and also returns its [`SimTrace`].
fn run_series_on(
    model: &dyn GradModel,
    train: &Dataset,
    test: &Dataset,
    init: &[f32],
    s: &ExperimentSpec,
    steps: usize,
) -> anyhow::Result<(History, Option<SimTrace>)> {
    let ops = s.resolve_ops(steps)?;
    let spec = TrainSpec {
        model,
        train,
        test: Some(test),
        workers: s.workers,
        batch: s.batch,
        steps,
        lr: s.lr.clone(),
        momentum: s.momentum,
        compressor: ops.up.as_ref(),
        down_compressor: ops.down.as_ref(),
        schedule: ops.schedule.as_ref(),
        participation: &ops.participation,
        agg_scale: s.agg_scale,
        server_opt: s.server_opt,
        codec: s.codec,
        sharding: s.sharding,
        seed: s.seed,
        eval_every: s.eval_every,
        eval_rows: s.eval_rows,
        threads: s.threads,
    };
    Ok(match (s.sim, s.faults) {
        (None, None) => (engine::run_from(&spec, init.to_vec()), None),
        // Faults without an explicit scenario still run on the simulator's
        // virtual clock (default timing model) — the engine has no wire to
        // inject faults into.
        (sim, faults) => {
            let sim = sim.unwrap_or_default();
            let r = crate::sim::run_from_faulty(&spec, &sim, faults.as_ref(), init.to_vec());
            let final_secs = r.final_secs();
            let trace = SimTrace { points: r.points, events: r.events, final_secs };
            (r.history, Some(trace))
        }
    })
}

/// Run a whole figure; returns per-series histories with labels.
///
/// Independent series run concurrently (one scoped thread each) whenever
/// the model exposes a `Sync` view — native workloads always do. Results
/// are collected in series order and each series draws only from its own
/// seeded streams, so the output is bit-identical to the sequential loop.
// Wall-clock here only annotates per-series runtime in the emitted JSON; it
// never feeds back into the trajectory (allowed exception to `clippy.toml`).
#[allow(clippy::disallowed_methods)]
pub fn run_figure(spec: &FigureSpec, quick: bool) -> anyhow::Result<FigureResult> {
    let w = spec.workload.instantiate(quick);
    let steps = if quick { spec.steps / 4 } else { spec.steps };
    let mut result = FigureResult::new(spec, steps);
    let runs: Vec<anyhow::Result<(History, Option<SimTrace>, f64)>> = match w.model.as_sync() {
        Some(model) => {
            // Capture only `Sync` pieces (the instance itself holds the
            // non-`Sync`-bounded `Box<dyn GradModel>`).
            let (train, test, init) = (&w.train, &w.test, &w.init[..]);
            crate::engine::parallel::map_parallel(&spec.series, move |_i, s| {
                let t0 = std::time::Instant::now();
                let (hist, trace) = run_series_on(model, train, test, init, s, steps)?;
                Ok((hist, trace, t0.elapsed().as_secs_f64()))
            })
        }
        None => spec
            .series
            .iter()
            .map(|s| {
                let t0 = std::time::Instant::now();
                let (hist, trace) =
                    run_series_on(w.model.as_ref(), &w.train, &w.test, &w.init, s, steps)?;
                Ok((hist, trace, t0.elapsed().as_secs_f64()))
            })
            .collect(),
    };
    for (s, run) in spec.series.iter().zip(runs) {
        let (hist, trace, secs) =
            run.map_err(|e| anyhow::anyhow!("series `{}`: {e}", s.label))?;
        result.add_with_sim(&s.label, hist, trace, secs);
    }
    Ok(result)
}

/// The γ table (Lemmas 1–3): analytic worst-case γ plus the measured
/// residual ratio E‖x−C(x)‖²/‖x‖² on random Gaussian vectors.
pub fn gamma_table(d: usize, k: usize) -> Vec<(String, f64, f64)> {
    use crate::util::rng::Pcg64;
    use crate::util::stats::norm2_sq;
    let specs = [
        format!("topk:k={k}"),
        format!("randk:k={k}"),
        "qsgd:bits=4".to_string(),
        "sign".to_string(),
        format!("qtopk:k={k},bits=4"),
        format!("qtopk:k={k},bits=4,scaled"),
        format!("qtopk:k={k},bits=2,scaled"),
        format!("signtopk:k={k},m=1"),
        format!("signtopk:k={k},m=2"),
    ];
    let mut rng = Pcg64::seeded(SEED);
    let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let x_norm = norm2_sq(&x);
    let mut out = Vec::new();
    for spec in &specs {
        let op = crate::compress::parse_spec(spec).unwrap();
        let trials = 200;
        let mut acc = 0.0;
        for _ in 0..trials {
            let dense = op.compress(&x, &mut rng).to_dense();
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            acc += norm2_sq(&resid);
        }
        let measured_ratio = acc / trials as f64 / x_norm;
        out.push((op.name(), op.gamma(d), measured_ratio));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workloads_instantiate() {
        for wl in [Workload::ConvexSoftmax, Workload::NonConvexMlp] {
            let w = wl.instantiate(true);
            assert_eq!(w.init.len(), w.model.dim());
            assert!(w.train.n > 0 && w.test.n > 0);
        }
    }

    #[test]
    fn gamma_table_bounds_hold() {
        // measured residual ratio ≤ 1 − γ_analytic (+ MC slack) for every op.
        // Dense QSGD has γ = 0 when β_{d,s} ≥ 1 (Remark 1: outside the
        // operating regime) — the bound is then vacuous, so skip it.
        for (name, gamma, measured) in gamma_table(512, 32) {
            assert!((0.0..=1.0).contains(&gamma), "{name}: γ={gamma}");
            if gamma > 0.0 {
                assert!(
                    measured <= (1.0 - gamma) + 0.05,
                    "{name}: measured {measured} vs 1−γ {}",
                    1.0 - gamma
                );
            }
        }
    }

    #[test]
    fn quick_series_runs() {
        let w = Workload::ConvexSoftmax.instantiate(true);
        let s = ExperimentSpec::for_workload(Workload::ConvexSoftmax)
            .with_label("t")
            .with_up("topk:k=40")
            .with_h(4);
        let h = run_series(&w, &s, 40).unwrap();
        assert!(h.points.len() >= 2);
        assert!(h.final_loss().is_finite());
    }

    #[test]
    fn figure_spec_json_roundtrips() {
        for id in all_figure_ids() {
            let spec = figure_spec(id).unwrap();
            let back = FigureSpec::from_json(&spec.to_json())
                .unwrap_or_else(|e| panic!("{id}: {e}"));
            assert_eq!(back, spec, "{id}");
            let back = FigureSpec::from_json_str(&spec.to_json().pretty()).unwrap();
            assert_eq!(back, spec, "{id} (pretty)");
        }
    }

    #[test]
    fn parallel_figure_harness_matches_sequential_series() {
        // The concurrent per-series harness must reproduce the sequential
        // runner bit for bit (per-series seeds are independent of the
        // execution order).
        let mut fig = figure_spec("fig9").unwrap();
        fig.series.truncate(3);
        let steps = 24;
        let w = fig.workload.instantiate(true);
        let seq: Vec<History> = fig
            .series
            .iter()
            .map(|s| run_series(&w, s, steps).unwrap())
            .collect();
        fig.steps = steps * 4; // quick mode divides by 4
        let par = run_figure(&fig, true).unwrap();
        assert_eq!(par.series.len(), seq.len());
        for ((label, hist, _), (s, want)) in par.series.iter().zip(fig.series.iter().zip(&seq)) {
            assert_eq!(label, &s.label);
            assert_eq!(hist.final_params, want.final_params, "{label}");
            for (a, b) in hist.points.iter().zip(&want.points) {
                assert_eq!(a.step, b.step, "{label}");
                assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{label}");
                assert_eq!((a.bits_up, a.bits_down), (b.bits_up, b.bits_down), "{label}");
            }
        }
    }
}
