//! Wire encoding and exact bit accounting (DESIGN.md §8).
//!
//! The paper's headline metric is *bits transmitted to reach a target
//! loss/accuracy*, so the bit counts must be honest: this module serializes
//! every `Message` to an actual bitstream and decodes it back; the figures
//! report `encode(msg).bit_len()`. Formats:
//!
//! * header: 3-bit tag + dimension (Elias-γ of d+1)
//! * `Dense`      : d × f32
//! * `SparseF32`  : count (Elias-γ) + indices + k × f32
//! * `SparseSign` : count + f32 scale + indices + k sign bits
//! * `DenseSign`  : f32 scale + d sign bits
//! * `Qsgd`       : s (Elias-γ), f32 norm, f32 post_scale, optional indices,
//!                  per-coordinate Elias-γ(level+1) + sign bit for nonzeros
//!                  (zeros cost 1 bit — this matches the spirit of QSGD's
//!                  Elias coding [AGL+17], where small levels are cheap).
//!
//! Index coding picks per message the cheaper of (a) raw ceil(log2 d) binary
//! indices, or (b) Elias-γ coded successive gaps (indices must be ascending),
//! signalled by one flag bit.

use super::{Message, MessageBuf};

/// Growable bitstream writer (MSB-first within each byte).
///
/// Perf note (§Perf iteration 1): bits accumulate in a 64-bit register and
/// spill to the byte buffer in whole bytes — 15–20× faster than the original
/// bit-at-a-time writer on f32-heavy messages (see EXPERIMENTS.md §Perf).
/// §Perf iteration 8: f32 runs and sign-bit runs go through the bulk paths
/// ([`BitWriter::push_f32s`], [`BitWriter::push_bools`]), which byte-swap
/// via the `crate::simd` kernels and merge whole 64-bit words at the
/// current bit offset — byte-identical to the per-element calls.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Pending bits, left-aligned (top `nacc` bits are valid).
    acc: u64,
    nacc: u32,
    /// Total bits written.
    len: u64,
    /// Reusable byte-image scratch for the bulk f32 path (steady-state
    /// zero-alloc, like `buf`).
    scratch: Vec<u8>,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn bit_len(&self) -> u64 {
        self.len
    }

    #[inline]
    fn spill(&mut self) {
        while self.nacc >= 8 {
            self.buf.push((self.acc >> 56) as u8);
            self.acc <<= 8;
            self.nacc -= 8;
        }
    }

    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        self.push_bits(u64::from(bit), 1);
    }

    /// Write the low `n` bits of `v`, MSB first.
    #[inline]
    pub fn push_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 64);
        if n == 0 {
            return;
        }
        if n > 57 {
            // Split so the accumulator (≤ 7 pending bits) never overflows.
            self.push_bits(v >> 32, n - 32);
            self.push_bits(v & 0xffff_ffff, 32);
            return;
        }
        let v = v & (u64::MAX >> (64 - n));
        self.acc |= (v << (64 - n)) >> self.nacc;
        self.nacc += n;
        self.len += n as u64;
        self.spill();
    }

    pub fn push_f32(&mut self, v: f32) {
        self.push_bits(v.to_bits() as u64, 32);
    }

    /// Bulk [`BitWriter::push_f32`] over a slice: the values' big-endian
    /// byte images are materialized through the `crate::simd` byte-swap
    /// kernel into reusable scratch, then merged at the current bit offset
    /// in whole 64-bit words. Byte-identical to pushing each value
    /// individually (asserted by `bulk_writer_paths_match_per_element`).
    pub fn push_f32s(&mut self, vals: &[f32]) {
        if vals.len() < 8 {
            for &v in vals {
                self.push_f32(v);
            }
            return;
        }
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        crate::simd::be_bytes_into(vals, &mut scratch);
        self.push_byte_stream(&scratch);
        self.scratch = scratch;
    }

    /// Bulk [`BitWriter::push_bit`]: packs sign bits 32 per accumulator
    /// word instead of one register round-trip per bit. Bit-identical to
    /// the per-bit loop.
    pub fn push_bools(&mut self, bits: &[bool]) {
        let mut it = bits.chunks_exact(32);
        for c in it.by_ref() {
            let mut v = 0u64;
            for &b in c {
                v = (v << 1) | u64::from(b);
            }
            self.push_bits(v, 32);
        }
        for &b in it.remainder() {
            self.push_bit(b);
        }
    }

    /// Merge a whole byte stream at the current (arbitrary) bit offset —
    /// the bulk twin of pushing each byte via `push_bits(b, 8)`. With
    /// `k = nacc` pending bits, each emitted chunk carries the k carried
    /// bits followed by the stream shifted right by k; the final k bits
    /// stay in the accumulator. `nacc` is unchanged (`k < 8` throughout).
    fn push_byte_stream(&mut self, bytes: &[u8]) {
        let k = self.nacc;
        self.len += 8 * bytes.len() as u64;
        if k == 0 {
            self.buf.extend_from_slice(bytes);
            return;
        }
        let mut carry = self.acc;
        let mut it = bytes.chunks_exact(8);
        for c in it.by_ref() {
            let mut w = 0u64;
            for &b in c {
                w = (w << 8) | b as u64;
            }
            let out = carry | (w >> k);
            self.buf.extend_from_slice(&out.to_be_bytes());
            carry = w << (64 - k);
        }
        for &b in it.remainder() {
            let bb = (b as u64) << 56;
            self.buf.push(((carry | (bb >> k)) >> 56) as u8);
            carry = (b as u64) << (64 - k);
        }
        self.acc = carry;
    }

    /// Elias-γ code of v ≥ 1: (⌊log2 v⌋ zeros) ++ binary(v). Length
    /// 2⌊log2 v⌋ + 1 bits.
    pub fn push_elias_gamma(&mut self, v: u64) {
        debug_assert!(v >= 1);
        let nbits = 64 - v.leading_zeros();
        // 2·nbits − 1 ≤ 127 bits total: emit as (nbits−1 zeros) ++ v.
        self.push_bits(0, nbits - 1);
        self.push_bits(v, nbits);
    }

    pub fn into_bytes(mut self) -> (Vec<u8>, u64) {
        self.flush();
        (self.buf, self.len)
    }

    /// Flush pending bits and borrow the encoded bytes (reusable-buffer
    /// mode: call `clear` and write again without reallocating).
    pub fn finish(&mut self) -> (&[u8], u64) {
        self.flush();
        (&self.buf, self.len)
    }

    /// Reset for reuse, keeping the byte buffer's capacity.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.nacc = 0;
        self.len = 0;
    }

    fn flush(&mut self) {
        if self.nacc > 0 {
            self.buf.push((self.acc >> 56) as u8);
            self.acc = 0;
            self.nacc = 0;
        }
    }
}

/// Bitstream reader matching `BitWriter`.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: u64,
    len: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8], bit_len: u64) -> Self {
        BitReader { buf, pos: 0, len: bit_len }
    }

    #[inline]
    pub fn read_bit(&mut self) -> Option<bool> {
        if self.pos >= self.len {
            return None;
        }
        let byte = (self.pos / 8) as usize;
        let bit = (self.buf[byte] >> (7 - (self.pos % 8))) & 1 == 1;
        self.pos += 1;
        Some(bit)
    }

    /// Byte-at-a-time extraction (§Perf iteration 1; ~8× over bit-at-a-time).
    pub fn read_bits(&mut self, n: u32) -> Option<u64> {
        if n == 0 {
            return Some(0);
        }
        if self.pos + n as u64 > self.len {
            self.pos = self.len; // poison
            return None;
        }
        let mut v = 0u64;
        let mut remaining = n;
        while remaining > 0 {
            let byte = self.buf[(self.pos / 8) as usize] as u32;
            let bit_off = (self.pos % 8) as u32;
            let avail = 8 - bit_off;
            let take = avail.min(remaining);
            let bits = (byte >> (avail - take)) & ((1u32 << take) - 1);
            v = (v << take) | bits as u64;
            self.pos += take as u64;
            remaining -= take;
        }
        Some(v)
    }

    pub fn read_f32(&mut self) -> Option<f32> {
        self.read_bits(32).map(|b| f32::from_bits(b as u32))
    }

    /// Bulk twin of `count` successive `read_bits(width)` calls
    /// (`1 ≤ width ≤ 32`), appending each field to `out` through the
    /// `crate::simd` fixed-width unpack kernel. The whole run is checked
    /// against the stream bound up front (poisoning the cursor exactly like
    /// `read_bits` on overrun); the decode entry's `bit_len ≤ 8·bytes.len()`
    /// guard then makes every byte window the kernel touches in bounds.
    pub(crate) fn read_fixed_u32s_into(
        &mut self,
        count: usize,
        width: u32,
        out: &mut Vec<u32>,
    ) -> Option<()> {
        debug_assert!((1..=32).contains(&width));
        debug_assert!(self.len <= 8 * self.buf.len() as u64);
        let total = count as u64 * width as u64;
        if self.pos + total > self.len {
            self.pos = self.len; // poison
            return None;
        }
        crate::simd::unpack_fixed_into(self.buf, self.pos, width, count, out);
        self.pos += total;
        Some(())
    }

    pub fn read_elias_gamma(&mut self) -> Option<u64> {
        let mut zeros = 0u32;
        while !self.read_bit()? {
            zeros += 1;
            if zeros >= 64 {
                // 64 leading zeros would need a 65-bit value: `1u64 << 64`
                // is a shift overflow, so reject here (no valid writer emits
                // more than 63 zeros).
                return None;
            }
        }
        // Already consumed the leading 1 of binary(v).
        let rest = self.read_bits(zeros)?;
        Some((1u64 << zeros) | rest)
    }

    /// Bits left before the stream ends — the decode guards' budget for
    /// rejecting corrupt element counts before any allocation happens.
    #[inline]
    pub(crate) fn remaining(&self) -> u64 {
        self.len - self.pos
    }

    /// Current cursor position in bits (rANS container framing).
    #[inline]
    pub(crate) fn bit_pos(&self) -> u64 {
        self.pos
    }

    /// A bounded sub-reader over the same bytes, from the current position
    /// to absolute bit `end` — the rANS blob cursor, read alongside the
    /// main reader's raw-bits tail.
    pub(crate) fn sub(&self, end: u64) -> Option<BitReader<'a>> {
        if end < self.pos || end > self.len {
            return None;
        }
        Some(BitReader { buf: self.buf, pos: self.pos, len: end })
    }

    /// Advance the cursor by `bits` without reading (skips the blob region).
    pub(crate) fn skip(&mut self, bits: u64) -> Option<()> {
        let np = self.pos.checked_add(bits)?;
        if np > self.len {
            self.pos = self.len; // poison, matching read_bits
            return None;
        }
        self.pos = np;
        Some(())
    }
}

/// Why a wire decode failed. Every variant is a *graceful* rejection: the
/// decode paths never panic, index out of bounds, shift-overflow, or
/// allocate unbounded memory on corrupt input — they return one of these.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// The stream ended (or the claimed `bit_len` exceeds the byte buffer)
    /// before the message did.
    Truncated,
    /// Unknown wire tag or unknown inner variant tag.
    BadTag,
    /// A decoded count or dimension is impossibly large for the stream (or
    /// exceeds the wire-format ceiling of 2^27 elements per message).
    CountOverflow,
    /// A decoded sparse index is out of range `0..d`, exceeds `u32`, or
    /// breaks the strictly-ascending support order the fold relies on.
    BadIndex,
    /// An rANS frequency table is inconsistent (symbol outside its
    /// alphabet, frequencies not summing to the 2^12 scale).
    BadTable,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let msg = match self {
            DecodeError::Truncated => "truncated wire stream",
            DecodeError::BadTag => "unknown wire tag",
            DecodeError::CountOverflow => "element count exceeds stream or format bounds",
            DecodeError::BadIndex => "sparse index out of range or out of order",
            DecodeError::BadTable => "inconsistent rANS frequency table",
        };
        f.write_str(msg)
    }
}

impl std::error::Error for DecodeError {}

/// `Option` → `DecodeError::Truncated` adapter: the bit-level readers speak
/// `Option` (a `None` always means the stream ran dry), the decode stack
/// speaks `Result`. Shared with `rans.rs` so both paths convert identically.
pub(crate) trait OrTruncated<T> {
    fn or_truncated(self) -> Result<T, DecodeError>;
}

impl<T> OrTruncated<T> for Option<T> {
    fn or_truncated(self) -> Result<T, DecodeError> {
        self.ok_or(DecodeError::Truncated)
    }
}

/// Wire-format ceiling on any decoded element count (dimension, support
/// size, bucket-norm count). Entropy-coded streams can emit symbols at
/// asymptotically zero wire cost (a single-symbol rANS table renormalizes
/// never), so stream-length-proportional bounds alone cannot stop a
/// decompression bomb; this absolute cap bounds every `reserve` the decode
/// paths perform. 2^27 (~134M) is ≥ 250× the largest model this system
/// trains — encoding a larger message is unsupported (its decode reports
/// `CountOverflow`).
pub(crate) const MAX_WIRE_ELEMS: u64 = 1 << 27;

/// Validate a decoded element count before reserving storage for it:
/// `count` elements at a floor cost of `min_bits` each must fit in the
/// reader's remaining bits, and `count` must respect [`MAX_WIRE_ELEMS`].
/// A floor of 0 (blob-coded streams with no per-element tail bits) still
/// gets the absolute cap.
pub(crate) fn checked_count(
    count: u64,
    min_bits: u64,
    r: &BitReader,
) -> Result<usize, DecodeError> {
    if count > MAX_WIRE_ELEMS {
        return Err(DecodeError::CountOverflow);
    }
    // No overflow: count ≤ 2^27 and min_bits ≤ 32.
    if count * min_bits > r.remaining() {
        return Err(DecodeError::CountOverflow);
    }
    Ok(count as usize)
}

/// Cost in bits of the Elias-γ code of v ≥ 1.
#[inline]
pub fn elias_gamma_bits(v: u64) -> u64 {
    debug_assert!(v >= 1);
    2 * (63 - v.leading_zeros()) as u64 + 1
}

#[inline]
fn ceil_log2(n: u64) -> u32 {
    if n <= 1 {
        1
    } else {
        64 - (n - 1).leading_zeros()
    }
}

// Message tags. Shared with the rANS container (`rans.rs`), which claims
// wire tag 5 and repeats the inner variant tag inside its own header.
pub(crate) const TAG_DENSE: u64 = 0;
pub(crate) const TAG_SPARSE_F32: u64 = 1;
pub(crate) const TAG_SPARSE_SIGN: u64 = 2;
pub(crate) const TAG_DENSE_SIGN: u64 = 3;
pub(crate) const TAG_QSGD: u64 = 4;

/// Total Elias-γ cost of the successive-gap coding of ascending `idx`
/// (first gap = idx[0]+1). Shared by the writer and the pure cost walk so
/// the two cannot diverge.
fn index_gap_bits(idx: &[u32]) -> u64 {
    let mut total = 0u64;
    let mut prev = 0u64;
    for (j, &i) in idx.iter().enumerate() {
        let gap = i as u64 - prev + u64::from(j == 0); // first gap = idx+1
        total += elias_gamma_bits(gap.max(1));
        prev = i as u64;
    }
    total
}

/// Exact bit cost of `write_indices` (flag bit + the cheaper coding).
fn index_bits(idx: &[u32], d: usize) -> u64 {
    let raw_total = ceil_log2(d as u64) as u64 * idx.len() as u64;
    let gap_total = index_gap_bits(idx);
    1 + if gap_total < raw_total { gap_total } else { raw_total }
}

/// Pick the cheaper index coding and write it. Indices must be ascending.
fn write_indices(w: &mut BitWriter, idx: &[u32], d: usize) {
    let raw_bits_per = ceil_log2(d as u64);
    let raw_total = raw_bits_per as u64 * idx.len() as u64;
    let use_gaps = index_gap_bits(idx) < raw_total;
    w.push_bit(use_gaps);
    if use_gaps {
        let mut prev = 0u64;
        for (j, &i) in idx.iter().enumerate() {
            let gap = i as u64 - prev + u64::from(j == 0);
            w.push_elias_gamma(gap.max(1));
            prev = i as u64;
        }
    } else {
        for &i in idx {
            w.push_bits(i as u64, raw_bits_per);
        }
    }
}

/// Read `count` indices into caller-provided (cleared) storage — the
/// decode path's allocation-free core. Every index is validated on the way
/// in: strictly ascending and `< d` (the fold's binary searches and range
/// folds rely on both), rejecting corrupt streams as [`DecodeError::BadIndex`]
/// instead of letting a bad index panic deep inside `add_into`.
fn read_indices_into(
    r: &mut BitReader,
    count: usize,
    d: usize,
    idx: &mut Vec<u32>,
) -> Result<(), DecodeError> {
    debug_assert!(idx.is_empty());
    let use_gaps = r.read_bit().or_truncated()?;
    idx.reserve(count);
    if use_gaps {
        let mut prev = 0u64;
        for j in 0..count {
            let gap = r.read_elias_gamma().or_truncated()?;
            // gap ≥ 1, so indices after the first ascend strictly by
            // construction; only the range check can fail. saturating: a
            // corrupt gap near u64::MAX must land in the range rejection,
            // not wrap (debug overflow panic).
            let i = prev.saturating_add(gap) - u64::from(j == 0);
            if i >= d as u64 || i > u32::MAX as u64 {
                return Err(DecodeError::BadIndex);
            }
            idx.push(i as u32);
            prev = i;
        }
    } else {
        // Bulk fixed-width unpack (§Perf iteration 8) followed by one
        // validation sweep: every index < d and strictly ascending. The
        // whole run is bounds-checked up front, so a stream that is both
        // truncated AND carries a bad index now reports `Truncated` where
        // the old interleaved loop could report `BadIndex` first — both
        // are graceful rejections of the same corrupt stream.
        let n = ceil_log2(d as u64);
        r.read_fixed_u32s_into(count, n, idx).or_truncated()?;
        let mut prev = 0u64;
        for (j, &i) in idx.iter().enumerate() {
            if i as u64 >= d as u64 || (j > 0 && i as u64 <= prev) {
                return Err(DecodeError::BadIndex);
            }
            prev = i as u64;
        }
    }
    Ok(())
}

/// Serialize a message to (bytes, bit length).
pub fn encode(msg: &Message) -> (Vec<u8>, u64) {
    let mut w = BitWriter::new();
    encode_into(msg, &mut w);
    w.into_bytes()
}

/// Serialize a message into a reusable writer (cleared first). The encoded
/// bytes are available through `w.finish()`; with a long-lived writer the
/// encode path performs no allocation once the buffer capacity is reached.
pub fn encode_into(msg: &Message, w: &mut BitWriter) {
    w.clear();
    w.push_bits(raw_tag(msg), 3);
    w.push_elias_gamma(msg.dim() as u64 + 1);
    match msg {
        Message::Dense { values } => {
            w.push_f32s(values);
        }
        Message::SparseF32 { d, idx, vals } => {
            w.push_elias_gamma(idx.len() as u64 + 1);
            write_indices(w, idx, *d);
            w.push_f32s(vals);
        }
        Message::SparseSign { d, scale, idx, neg } => {
            w.push_elias_gamma(idx.len() as u64 + 1);
            w.push_f32(*scale);
            write_indices(w, idx, *d);
            w.push_bools(neg);
        }
        Message::DenseSign { scale, neg } => {
            w.push_f32(*scale);
            w.push_bools(neg);
        }
        Message::Qsgd { s, bucket, norms, post_scale, idx, levels, neg, .. } => {
            w.push_elias_gamma(*s as u64);
            w.push_elias_gamma(*bucket as u64);
            w.push_f32(*post_scale);
            match idx {
                Some(idx) => {
                    w.push_bit(true);
                    w.push_elias_gamma(idx.len() as u64 + 1);
                    write_indices(w, idx, msg.dim());
                }
                None => w.push_bit(false),
            }
            // One ℓ2-norm scale per bucket (the bucketing overhead is
            // counted honestly: 32 bits each).
            w.push_elias_gamma(norms.len() as u64 + 1);
            w.push_f32s(norms);
            for (&l, &n) in levels.iter().zip(neg) {
                if l == 0 {
                    // zero level: 1 bit
                    w.push_bit(false);
                } else {
                    w.push_bit(true);
                    w.push_elias_gamma(l as u64);
                    w.push_bit(n);
                }
            }
        }
    }
}

/// The variant's wire tag — also the *inner* tag of the rANS container.
pub(crate) fn raw_tag(msg: &Message) -> u64 {
    match msg {
        Message::Dense { .. } => TAG_DENSE,
        Message::SparseF32 { .. } => TAG_SPARSE_F32,
        Message::SparseSign { .. } => TAG_SPARSE_SIGN,
        Message::DenseSign { .. } => TAG_DENSE_SIGN,
        Message::Qsgd { .. } => TAG_QSGD,
    }
}

/// Exact wire size in bits: a pure O(nnz) cost walk over the message —
/// no byte buffer, no allocation. Mirrors `encode_into` field by field;
/// `prop_wire_bits_matches_encoding` asserts equality with `encode(msg).1`
/// for every operator.
pub fn wire_bits(msg: &Message) -> u64 {
    let mut bits = 3 + elias_gamma_bits(msg.dim() as u64 + 1);
    match msg {
        Message::Dense { values } => bits += 32 * values.len() as u64,
        Message::SparseF32 { d, idx, .. } => {
            bits += elias_gamma_bits(idx.len() as u64 + 1)
                + index_bits(idx, *d)
                + 32 * idx.len() as u64;
        }
        Message::SparseSign { d, idx, .. } => {
            // count + f32 scale + indices + k sign bits.
            bits += elias_gamma_bits(idx.len() as u64 + 1)
                + 32
                + index_bits(idx, *d)
                + idx.len() as u64;
        }
        Message::DenseSign { neg, .. } => bits += 32 + neg.len() as u64,
        Message::Qsgd { s, bucket, norms, idx, levels, .. } => {
            // s + bucket + f32 post_scale + support-flag bit.
            bits += elias_gamma_bits(*s as u64) + elias_gamma_bits(*bucket as u64) + 32 + 1;
            if let Some(idx) = idx {
                bits += elias_gamma_bits(idx.len() as u64 + 1) + index_bits(idx, msg.dim());
            }
            bits += elias_gamma_bits(norms.len() as u64 + 1) + 32 * norms.len() as u64;
            for &l in levels {
                // zero level: 1 flag bit; nonzero: flag + Elias-γ(l) + sign.
                bits += if l == 0 { 1 } else { 2 + elias_gamma_bits(l as u64) };
            }
        }
    }
    bits
}

/// Wire size in bits of a dense model broadcast of dimension `d` — equal to
/// `wire_bits(&Message::Dense { .. })` but computed in O(1): 3-bit tag +
/// Elias-γ(d+1) header + d × f32. Lets the dense downlink path account bits
/// honestly without serializing `32·d` bits per worker per sync.
pub fn dense_model_bits(d: usize) -> u64 {
    3 + elias_gamma_bits(d as u64 + 1) + 32 * d as u64
}

/// Decode a message produced by `encode` — allocating wrapper over
/// [`decode_into`] through a fresh buffer, so the two cannot drift.
pub fn decode(bytes: &[u8], bit_len: u64) -> Result<Message, DecodeError> {
    let mut buf = MessageBuf::new();
    decode_into(bytes, bit_len, &mut buf)?;
    Ok(buf.take())
}

/// Decode a message produced by `encode` into reusable storage: the message
/// lands in `buf` (borrow via `MessageBuf::message`, or move out with
/// `MessageBuf::take`), recycling the previous message's vectors when the
/// variant matches. With a fixed operator per sender — the steady state of
/// every run — repeated decodes through the same buffer perform no heap
/// allocation once capacities have grown to the message size, which is what
/// lets the threaded master's receive loop stay off the allocator.
///
/// Returns `Err` on a malformed stream — truncated, corrupt, or lying about
/// its own length — without panicking or allocating unbounded memory; the
/// buffer's previous message is consumed either way (its storage is dropped
/// on the error path, so no caller can mistake a stale decode for a
/// malformed sender's payload).
pub fn decode_into(bytes: &[u8], bit_len: u64, buf: &mut MessageBuf) -> Result<(), DecodeError> {
    let res = decode_into_inner(bytes, bit_len, buf);
    if res.is_err() {
        buf.msg = Message::default();
    }
    res
}

fn decode_into_inner(
    bytes: &[u8],
    bit_len: u64,
    buf: &mut MessageBuf,
) -> Result<(), DecodeError> {
    if bit_len > 8 * bytes.len() as u64 {
        // A transport header lying about the length would otherwise send
        // the readers indexing past the byte buffer.
        return Err(DecodeError::Truncated);
    }
    let mut r = BitReader::new(bytes, bit_len);
    let tag = r.read_bits(3).or_truncated()?;
    if tag == super::rans::TAG_RANS {
        // Entropy-coded container: self-describing (it repeats the variant
        // tag inside), so decoding needs no codec parameter and raw/rANS
        // messages interleave freely on one stream.
        return super::rans::decode_body(&mut r, buf);
    }
    let d = checked_count(r.read_elias_gamma().or_truncated()? - 1, 0, &r)?;
    match tag {
        TAG_DENSE => {
            checked_count(d as u64, 32, &r)?;
            let mut values = buf.take_dense();
            values.reserve(d);
            for _ in 0..d {
                values.push(r.read_f32().or_truncated()?);
            }
            buf.msg = Message::Dense { values };
        }
        TAG_SPARSE_F32 => {
            // Floor cost per element: ≥ 1 index bit + 32 value bits.
            let k = checked_count(r.read_elias_gamma().or_truncated()? - 1, 33, &r)?;
            let (mut idx, mut vals) = buf.take_sparse_f32();
            read_indices_into(&mut r, k, d, &mut idx)?;
            vals.reserve(k);
            for _ in 0..k {
                vals.push(r.read_f32().or_truncated()?);
            }
            buf.msg = Message::SparseF32 { d, idx, vals };
        }
        TAG_SPARSE_SIGN => {
            // Floor cost per element: ≥ 1 index bit + 1 sign bit.
            let k = checked_count(r.read_elias_gamma().or_truncated()? - 1, 2, &r)?;
            let scale = r.read_f32().or_truncated()?;
            let (mut idx, mut neg) = buf.take_sparse_sign();
            read_indices_into(&mut r, k, d, &mut idx)?;
            neg.reserve(k);
            for _ in 0..k {
                neg.push(r.read_bit().or_truncated()?);
            }
            buf.msg = Message::SparseSign { d, scale, idx, neg };
        }
        TAG_DENSE_SIGN => {
            checked_count(d as u64, 1, &r)?;
            let scale = r.read_f32().or_truncated()?;
            let mut neg = buf.take_dense_sign();
            neg.reserve(d);
            for _ in 0..d {
                neg.push(r.read_bit().or_truncated()?);
            }
            buf.msg = Message::DenseSign { scale, neg };
        }
        TAG_QSGD => {
            let s = r.read_elias_gamma().or_truncated()? as u32;
            let bucket = r.read_elias_gamma().or_truncated()? as u32;
            let post_scale = r.read_f32().or_truncated()?;
            let has_idx = r.read_bit().or_truncated()?;
            let (mut norms, mut idx, mut levels, mut neg) = buf.take_qsgd();
            let count = if has_idx {
                let k = checked_count(r.read_elias_gamma().or_truncated()? - 1, 1, &r)?;
                read_indices_into(&mut r, k, d, &mut idx)?;
                k
            } else {
                // Every level costs ≥ 1 flag bit.
                checked_count(d as u64, 1, &r)?
            };
            let n_norms = checked_count(r.read_elias_gamma().or_truncated()? - 1, 32, &r)?;
            norms.reserve(n_norms);
            for _ in 0..n_norms {
                norms.push(r.read_f32().or_truncated()?);
            }
            levels.reserve(count);
            neg.reserve(count);
            for _ in 0..count {
                if r.read_bit().or_truncated()? {
                    levels.push(r.read_elias_gamma().or_truncated()? as u32);
                    neg.push(r.read_bit().or_truncated()?);
                } else {
                    levels.push(0);
                    neg.push(false);
                }
            }
            buf.msg = Message::Qsgd {
                d,
                s,
                bucket,
                norms,
                post_scale,
                idx: has_idx.then_some(idx),
                levels,
                neg,
            };
        }
        _ => return Err(DecodeError::BadTag),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, QTopK, Qsgd, RandK, SignDense, SignTopK, TopK};
    use crate::util::rng::Pcg64;

    #[test]
    fn bitstream_roundtrip_primitives() {
        let mut w = BitWriter::new();
        w.push_bits(0b101, 3);
        w.push_f32(-1.5);
        w.push_elias_gamma(1);
        w.push_elias_gamma(77);
        w.push_bit(true);
        let (bytes, len) = w.into_bytes();
        let mut r = BitReader::new(&bytes, len);
        assert_eq!(r.read_bits(3), Some(0b101));
        assert_eq!(r.read_f32(), Some(-1.5));
        assert_eq!(r.read_elias_gamma(), Some(1));
        assert_eq!(r.read_elias_gamma(), Some(77));
        assert_eq!(r.read_bit(), Some(true));
        assert_eq!(r.read_bit(), None);
    }

    #[test]
    fn bulk_writer_paths_match_per_element() {
        // push_f32s / push_bools must be byte-identical to the per-element
        // calls at every starting bit misalignment and across the
        // small-input fallback, 8-byte-word, and tail-byte merge paths.
        let mut rng = Pcg64::seeded(91);
        for misalign in 0..8u32 {
            for n in [0usize, 1, 7, 8, 9, 31, 32, 33, 100] {
                let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
                let bits: Vec<bool> = (0..n).map(|_| rng.f32() < 0.5).collect();
                let mut a = BitWriter::new();
                let mut b = BitWriter::new();
                a.push_bits(0x2a, misalign);
                b.push_bits(0x2a, misalign);
                for &v in &vals {
                    a.push_f32(v);
                }
                b.push_f32s(&vals);
                for &s in &bits {
                    a.push_bit(s);
                }
                b.push_bools(&bits);
                let (ab, al) = a.into_bytes();
                let (bb, bl) = b.into_bytes();
                assert_eq!(al, bl, "misalign={misalign} n={n}");
                assert_eq!(ab, bb, "misalign={misalign} n={n}");
            }
        }
    }

    #[test]
    fn bulk_fixed_reads_match_read_bits() {
        let mut rng = Pcg64::seeded(93);
        for width in [1u32, 5, 11, 17, 24, 32] {
            for start in [0u32, 3, 7] {
                let count = 50usize;
                let vals: Vec<u32> =
                    (0..count).map(|_| rng.next_u32() >> (32 - width)).collect();
                let mut w = BitWriter::new();
                w.push_bits(0, start);
                for &v in &vals {
                    w.push_bits(v as u64, width);
                }
                let (bytes, len) = w.into_bytes();
                let mut r1 = BitReader::new(&bytes, len);
                assert_eq!(r1.read_bits(start), Some(0));
                let mut got = Vec::new();
                assert_eq!(r1.read_fixed_u32s_into(count, width, &mut got), Some(()));
                assert_eq!(got, vals, "width={width} start={start}");
                // Scalar reference on the same stream.
                let mut r2 = BitReader::new(&bytes, len);
                assert_eq!(r2.read_bits(start), Some(0));
                for (j, &v) in vals.iter().enumerate() {
                    assert_eq!(r2.read_bits(width), Some(v as u64), "j={j}");
                }
                // Overrun: rejected up front, cursor poisoned like read_bits.
                let mut r3 = BitReader::new(&bytes, len);
                assert_eq!(r3.read_bits(start), Some(0));
                let mut g3 = Vec::new();
                assert_eq!(r3.read_fixed_u32s_into(count + 1, width, &mut g3), None);
                assert!(g3.is_empty());
                assert_eq!(r3.read_bit(), None);
            }
        }
    }

    #[test]
    fn elias_gamma_lengths() {
        assert_eq!(elias_gamma_bits(1), 1);
        assert_eq!(elias_gamma_bits(2), 3);
        assert_eq!(elias_gamma_bits(3), 3);
        assert_eq!(elias_gamma_bits(4), 5);
        assert_eq!(elias_gamma_bits(255), 15);
        // writer agrees with the cost function
        for v in [1u64, 2, 3, 100, 12345] {
            let mut w = BitWriter::new();
            w.push_elias_gamma(v);
            assert_eq!(w.bit_len(), elias_gamma_bits(v), "v={v}");
        }
    }

    #[test]
    fn message_roundtrip_all_operators() {
        let mut rng = Pcg64::seeded(31);
        let d = 300;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(crate::compress::Identity),
            Box::new(TopK::new(13)),
            Box::new(RandK::new(13)),
            Box::new(Qsgd::from_bits(4)),
            Box::new(SignDense::new()),
            Box::new(QTopK::new(13, Qsgd::from_bits(4), true)),
            Box::new(QTopK::new(13, Qsgd::from_bits(2), false)),
            Box::new(SignTopK::new(13, 1)),
        ];
        for op in ops {
            let msg = op.compress(&x, &mut rng);
            let (bytes, len) = encode(&msg);
            assert_eq!(len, wire_bits(&msg));
            let back = decode(&bytes, len).unwrap_or_else(|e| panic!("{} decode: {e}", op.name()));
            assert_eq!(msg, back, "{} roundtrip", op.name());
        }
    }

    #[test]
    fn bit_costs_ordering_matches_paper() {
        // vanilla ≫ topk ≫ signtopk for the same k; qsgd < dense.
        let mut rng = Pcg64::seeded(32);
        let d = 10_000;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        let dense = crate::compress::Identity.compress(&x, &mut rng).wire_bits();
        let topk = TopK::new(100).compress(&x, &mut rng).wire_bits();
        let signtopk = SignTopK::new(100, 1).compress(&x, &mut rng).wire_bits();
        let qsgd = Qsgd::from_bits(4).compress(&x, &mut rng).wire_bits();
        assert!(dense as f64 >= 32.0 * d as f64);
        assert!(topk < dense / 50, "topk={topk} dense={dense}");
        assert!(signtopk < topk, "signtopk={signtopk} topk={topk}");
        assert!(qsgd < dense / 3, "qsgd={qsgd} dense={dense}");
    }

    #[test]
    fn dense_model_bits_matches_real_encoding() {
        for d in [1usize, 7, 300, 7850] {
            let msg = Message::Dense { values: vec![0.25f32; d] };
            // Both closed forms agree with the actual serialized length.
            assert_eq!(dense_model_bits(d), encode(&msg).1, "d={d}");
            assert_eq!(wire_bits(&msg), encode(&msg).1, "d={d}");
        }
    }

    #[test]
    fn encode_into_reuses_writer_and_matches_encode() {
        let mut rng = Pcg64::seeded(77);
        let x: Vec<f32> = (0..200).map(|_| rng.normal_f32()).collect();
        let mut w = BitWriter::new();
        for op in [
            Box::new(TopK::new(9)) as Box<dyn Compressor>,
            Box::new(Qsgd::from_bits(3)),
            Box::new(SignTopK::new(9, 1)),
        ] {
            let msg = op.compress(&x, &mut rng);
            let (bytes, len) = encode(&msg);
            encode_into(&msg, &mut w);
            let (rbytes, rlen) = w.finish();
            assert_eq!(len, rlen, "{}", op.name());
            assert_eq!(bytes, rbytes, "{}", op.name());
        }
    }

    #[test]
    fn decode_into_matches_decode_and_recycles() {
        let mut rng = Pcg64::seeded(83);
        let d = 300;
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(crate::compress::Identity),
            Box::new(TopK::new(13)),
            Box::new(Qsgd::from_bits(4)),
            Box::new(SignDense::new()),
            Box::new(QTopK::new(13, Qsgd::from_bits(2), false)),
            Box::new(SignTopK::new(13, 1)),
        ];
        // One shared buffer across *different* variants (worst case for
        // recycling: every decode changes the message shape) — results must
        // still match the allocating decoder exactly.
        let mut buf = MessageBuf::new();
        for op in &ops {
            for round in 0..3 {
                let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let msg = op.compress(&x, &mut rng);
                let (bytes, len) = encode(&msg);
                assert_eq!(
                    decode_into(&bytes, len, &mut buf),
                    Ok(()),
                    "{} round {round}",
                    op.name()
                );
                assert_eq!(buf.message(), &msg, "{} round {round}", op.name());
                assert_eq!(decode(&bytes, len).as_ref(), Ok(&msg), "{}", op.name());
            }
        }
        // Malformed stream: truncated bits fail cleanly and leave the
        // buffer reusable.
        let msg = TopK::new(13).compress(&vec![1.0f32; d], &mut rng);
        let (bytes, len) = encode(&msg);
        assert!(decode_into(&bytes, len / 2, &mut buf).is_err());
        assert_eq!(decode_into(&bytes, len, &mut buf), Ok(()));
        assert_eq!(buf.message(), &msg);
        // Unknown tag: fails AND consumes the previous message (documented
        // contract) — no stale decode is observable afterwards.
        let mut w = BitWriter::new();
        w.push_bits(7, 3); // unused tag
        w.push_elias_gamma(5);
        let (bad, bad_len) = w.into_bytes();
        assert_eq!(decode_into(&bad, bad_len, &mut buf), Err(DecodeError::BadTag));
        assert_eq!(buf.message(), &Message::default());
    }

    #[test]
    fn decode_rejects_lying_bit_len() {
        // A transport header claiming more bits than the byte buffer holds
        // must be rejected up front, not discovered by a slice-index panic.
        let msg = Message::Dense { values: vec![1.0, 2.0, 3.0] };
        let (bytes, len) = encode(&msg);
        assert_eq!(
            decode(&bytes, 8 * bytes.len() as u64 + 1),
            Err(DecodeError::Truncated)
        );
        assert_eq!(decode(&bytes, u64::MAX), Err(DecodeError::Truncated));
        assert_eq!(decode(&bytes, len).as_ref(), Ok(&msg));
    }

    #[test]
    fn decode_rejects_overlong_elias_gamma() {
        // 64+ leading zeros would shift-overflow a u64; the reader must
        // reject, not panic (this is reachable from an all-zeros stream).
        let zeros = vec![0u8; 40];
        let mut r = BitReader::new(&zeros, 320);
        assert_eq!(r.read_elias_gamma(), None);
        assert!(decode(&zeros, 320).is_err());
    }

    #[test]
    fn decode_rejects_huge_counts_without_allocating() {
        // Dimension/count fields claiming ~2^40 elements from a 5-byte
        // stream must fail as CountOverflow before any reserve() happens.
        for tag in [TAG_DENSE, TAG_SPARSE_F32, TAG_SPARSE_SIGN, TAG_DENSE_SIGN] {
            let mut w = BitWriter::new();
            w.push_bits(tag, 3);
            w.push_elias_gamma((1u64 << 40) + 1); // d = 2^40
            let (bytes, len) = w.into_bytes();
            assert_eq!(
                decode(&bytes, len),
                Err(DecodeError::CountOverflow),
                "tag {tag}"
            );
        }
        // In-cap dimension but an element count the stream cannot hold.
        let mut w = BitWriter::new();
        w.push_bits(TAG_SPARSE_F32, 3);
        w.push_elias_gamma(10_001); // d = 10k
        w.push_elias_gamma(5_001); // k = 5k ⇒ needs ≥ 165k bits
        let (bytes, len) = w.into_bytes();
        assert_eq!(decode(&bytes, len), Err(DecodeError::CountOverflow));
    }

    #[test]
    fn decode_rejects_out_of_range_and_unordered_indices() {
        // Raw (fixed-width) index coding: out-of-range index.
        let mut w = BitWriter::new();
        w.push_bits(TAG_SPARSE_F32, 3);
        w.push_elias_gamma(5); // d = 4
        w.push_elias_gamma(2); // k = 1
        w.push_bit(false); // raw index coding
        w.push_bits(3, 2); // index 3: fine
        w.push_f32(1.0);
        let (bytes, len) = w.into_bytes();
        assert!(decode(&bytes, len).is_ok());
        let mut w = BitWriter::new();
        w.push_bits(TAG_SPARSE_SIGN, 3);
        w.push_elias_gamma(6); // d = 5
        w.push_elias_gamma(3); // k = 2
        w.push_f32(1.0); // scale
        w.push_bit(false); // raw index coding
        w.push_bits(4, 3); // index 4
        w.push_bits(2, 3); // index 2: breaks ascending order
        w.push_bits(0, 2); // signs
        let (bytes, len) = w.into_bytes();
        assert_eq!(decode(&bytes, len), Err(DecodeError::BadIndex));
        // Gap coding walking past d.
        let mut w = BitWriter::new();
        w.push_bits(TAG_SPARSE_F32, 3);
        w.push_elias_gamma(5); // d = 4
        w.push_elias_gamma(2); // k = 1
        w.push_bit(true); // gap coding
        w.push_elias_gamma(9); // first index = 8 ≥ d
        w.push_f32(1.0);
        let (bytes, len) = w.into_bytes();
        assert_eq!(decode(&bytes, len), Err(DecodeError::BadIndex));
    }

    #[test]
    fn sparse_indices_gap_coding_kicks_in_for_clustered_support() {
        // Clustered indices → gap coding much cheaper than raw.
        let d = 1 << 20;
        let idx: Vec<u32> = (0..128u32).collect();
        let vals = vec![1.0f32; 128];
        let msg = Message::SparseF32 { d, idx, vals };
        let bits = wire_bits(&msg);
        // raw would be ≥ 128 * 20 = 2560 index bits; gaps cost 128*1..3 bits.
        assert!(bits < 128 * 33 + 2560, "bits={bits}");
        let (bytes, len) = encode(&msg);
        assert_eq!(decode(&bytes, len).unwrap(), msg);
    }
}
