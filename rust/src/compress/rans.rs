//! Entropy-coded wire format: a range-ANS (rANS) layer over the raw
//! bitstream of `encode.rs`, selectable per run as `codec: raw | rans`.
//!
//! The paper's headline metric is *bits to reach a target accuracy*; the raw
//! format already spends Elias-γ gaps and per-level codes, but Top_k index
//! gaps are heavily skewed toward small values and quantizer levels are far
//! from uniform, so a static-frequency entropy coder harvests the remaining
//! slack without touching one f32 of the optimization trajectory.
//!
//! Container format (wire tag 5; tags 0–4 stay the raw variants, so decode
//! is self-describing and needs no codec parameter):
//!
//! ```text
//! 3b tag=5 | 3b inner variant tag | Elias-γ(d+1) | variant header fields
//! | frequency tables (per stream, ascending symbol ids, Elias-γ deltas +
//!   Elias-γ freqs, last freq derived from the 2^12 total)
//! | Elias-γ(blob_len_bytes+1) | blob (rANS renorm bytes + 32-bit state)
//! | raw-bits tail (gap low bits, f32 mantissas)
//! ```
//!
//! Symbol streams per variant (everything else rides in the raw tail, so
//! decoding stays exactly invertible for any f32 bit pattern):
//!
//! * index gaps  → class `⌊log2 gap⌋` (≤ 33 symbols) + `class` raw low bits
//! * f32 values  → sign+exponent (top 9 bits, ≤ 512 symbols) + 23 raw
//!   mantissa bits
//! * QSGD levels → the level itself (alphabet `0..=s`, requires s ≤ 255 —
//!   larger quantizers fall back to the raw format)
//! * sign flags  → 2-symbol table (QSGD signs only where the level ≠ 0,
//!   mirroring the raw format)
//!
//! Invariants inherited from the seed architecture:
//!
//! * [`wire_bits`] is a pure O(nnz) cost walk — it runs the same rANS state
//!   machine as the encoder against a byte *counter*, so it equals
//!   `encode().1` exactly (property-tested) without materializing a buffer.
//! * The encoder emits the rANS container only when it is *strictly* smaller
//!   than the raw encoding, so `rans ≤ raw` holds per message by
//!   construction and mixed streams decode transparently.
//! * [`WireEncoder`] reuses its writer and blob scratch; frequency tables
//!   and coder state live on the stack, so steady-state encode/decode touch
//!   the heap exactly as often as the raw path: never.
//!
//! Coder math is the byte-wise rANS of Duda's range variant (ryg_rans
//! idiom, cf. the Draco `AnsCoder`/`RAnsSymbolCoder` pair): 32-bit state,
//! renormalization interval `[2^23, 2^31)`, 12-bit frequency scale. The
//! encoder feeds symbols in reverse decode order and the reversed byte
//! stream starts with the big-endian final state.

use super::encode::{
    checked_count, elias_gamma_bits, BitReader, BitWriter, DecodeError, OrTruncated,
};
use super::{encode, Message, MessageBuf};

/// Frequency scale: all tables are normalized to sum to `1 << SCALE_BITS`.
const SCALE_BITS: u32 = 12;
const TOTAL: u32 = 1 << SCALE_BITS;
/// Lower bound of the coder's renormalization interval.
const RANS_L: u32 = 1 << 23;

/// Wire tag of the rANS container (encode.rs owns tags 0–4).
pub(crate) const TAG_RANS: u64 = 5;

/// Gap classes `⌊log2 gap⌋` for gaps up to 2^33 (u32 index + the +1 first
/// gap), f32 sign+exponent (top 9 bits), QSGD levels, binary flags.
const GAP_SYMS: usize = 33;
const VAL_SYMS: usize = 512;
const LVL_SYMS: usize = 256;
const BIT_SYMS: usize = 2;

/// Wire codec selection: `raw` is the seed bitstream (bit-identical to
/// every historical trajectory), `rans` wraps each message in the entropy
/// container whenever that is strictly smaller. The decoded message — and
/// therefore every `History` — is identical under either choice; only the
/// accounted wire length changes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    #[default]
    Raw,
    Rans,
}

impl Codec {
    pub fn parse(s: &str) -> Option<Codec> {
        match s {
            "raw" => Some(Codec::Raw),
            "rans" => Some(Codec::Rans),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Codec::Raw => "raw",
            Codec::Rans => "rans",
        }
    }
}

// ---------------------------------------------------------------------------
// Sinks: one generic emit path serves both the real writer and the pure
// cost walk, so the two cannot drift.

trait BitSink {
    fn bits(&mut self, v: u64, n: u32);
    fn elias(&mut self, v: u64);
    /// The rANS blob: counted as `8·len` by the cost walk, written
    /// byte-by-byte by the real writer (`blob` is `None` only when counting).
    fn raw_blob(&mut self, blob: Option<&[u8]>, len_bytes: u64);
    fn bit(&mut self, b: bool) {
        self.bits(u64::from(b), 1);
    }
    fn f32v(&mut self, v: f32) {
        self.bits(v.to_bits() as u64, 32);
    }
}

impl BitSink for BitWriter {
    fn bits(&mut self, v: u64, n: u32) {
        self.push_bits(v, n);
    }
    fn elias(&mut self, v: u64) {
        self.push_elias_gamma(v);
    }
    fn raw_blob(&mut self, blob: Option<&[u8]>, len_bytes: u64) {
        // Only the cost walk (`BitCost`) passes `None`; the writer back end
        // is always driven with the materialized blob. Degrade to an empty
        // blob rather than panicking (repo rule: no panics in this module) —
        // the length debug_assert still catches a drifted caller in tests.
        debug_assert!(blob.is_some(), "writer emit requires the materialized blob");
        let blob = blob.unwrap_or(&[]);
        debug_assert_eq!(blob.len() as u64, len_bytes);
        for &b in blob {
            self.push_bits(b as u64, 8);
        }
    }
}

/// Pure bit counter — the cost-walk back end.
struct BitCost(u64);

impl BitSink for BitCost {
    fn bits(&mut self, _v: u64, n: u32) {
        self.0 += n as u64;
    }
    fn elias(&mut self, v: u64) {
        self.0 += elias_gamma_bits(v);
    }
    fn raw_blob(&mut self, _blob: Option<&[u8]>, len_bytes: u64) {
        self.0 += 8 * len_bytes;
    }
}

/// Byte sink for the rANS coder: the encoder pushes into a reusable `Vec`,
/// the cost walk into a counter — same state machine either way.
trait ByteSink {
    fn push_byte(&mut self, b: u8);
}

impl ByteSink for Vec<u8> {
    fn push_byte(&mut self, b: u8) {
        self.push(b);
    }
}

struct ByteCount(u64);

impl ByteSink for ByteCount {
    fn push_byte(&mut self, _b: u8) {
        self.0 += 1;
    }
}

// ---------------------------------------------------------------------------
// Static-frequency tables.

/// A normalized frequency table over a fixed alphabet of `N` symbols.
/// Frequencies of the present symbols sum to exactly `TOTAL`; absent
/// symbols have frequency 0 and never reach the coder.
struct Table<const N: usize> {
    freq: [u16; N],
    cum: [u16; N],
    /// Present (nonzero-frequency) symbol count.
    m: u32,
}

impl<const N: usize> Table<N> {
    /// Deterministic integer normalization: floor-scale each count to the
    /// 2^12 grid, clamp to ≥ 1, then settle the remainder on the
    /// largest-frequency symbol (lowest index on ties) so every present
    /// symbol keeps a nonzero slot.
    fn build(counts: &[u32; N]) -> Table<N> {
        let n: u64 = counts.iter().map(|&c| c as u64).sum();
        let mut freq = [0u16; N];
        let mut m = 0u32;
        if n > 0 {
            let mut sum: i64 = 0;
            for s in 0..N {
                if counts[s] == 0 {
                    continue;
                }
                m += 1;
                let f = ((counts[s] as u64 * TOTAL as u64) / n).max(1);
                freq[s] = f as u16;
                sum += f as i64;
            }
            let mut diff = TOTAL as i64 - sum;
            if diff > 0 {
                freq[Self::argmax(&freq)] += diff as u16;
            }
            while diff < 0 {
                let best = Self::argmax(&freq);
                let take = (freq[best] as i64 - 1).min(-diff);
                debug_assert!(take > 0, "cannot normalize: alphabet too large");
                freq[best] -= take as u16;
                diff += take;
            }
        }
        let mut cum = [0u16; N];
        let mut c = 0u32;
        for s in 0..N {
            cum[s] = c as u16;
            c += freq[s] as u32;
        }
        debug_assert!(n == 0 || c == TOTAL);
        Table { freq, cum, m }
    }

    /// First index of the maximal frequency (deterministic tie-break).
    fn argmax(freq: &[u16; N]) -> usize {
        let mut best = 0usize;
        for (s, &f) in freq.iter().enumerate().skip(1) {
            if f > freq[best] {
                best = s;
            }
        }
        best
    }

    /// Serialize: Elias-γ(m+1), then per present symbol (ascending) the
    /// Elias-γ id delta (first = id+1) and — except for the last symbol,
    /// whose frequency is implied by the 2^12 total — Elias-γ(freq).
    fn write<S: BitSink>(&self, s: &mut S) {
        s.elias(self.m as u64 + 1);
        let mut prev = 0u64;
        let mut j = 0u32;
        for (sym, &f) in self.freq.iter().enumerate() {
            if f == 0 {
                continue;
            }
            s.elias(sym as u64 - prev + u64::from(j == 0));
            if j + 1 < self.m {
                s.elias(f as u64);
            }
            prev = sym as u64;
            j += 1;
        }
    }

    #[inline]
    fn put<B: ByteSink>(&self, enc: &mut RansEnc, sym: usize, out: &mut B) {
        enc.put(self.freq[sym], self.cum[sym], out);
    }
}

/// Decoder-side table: serialized form plus a slot → symbol lookup. Lives
/// on the stack (≈ 10 KB) so `decode_into` stays allocation-free.
struct DecTable<const N: usize> {
    slot: [u16; TOTAL as usize],
    freq: [u16; N],
    cum: [u16; N],
    m: u32,
}

impl<const N: usize> DecTable<N> {
    fn zeroed() -> Self {
        DecTable { slot: [0; TOTAL as usize], freq: [0; N], cum: [0; N], m: 0 }
    }

    /// Read the serialized table; `Err` on truncation or any inconsistency
    /// (symbol out of alphabet, frequencies not summing to the 2^12 total).
    fn read(&mut self, r: &mut BitReader) -> Result<(), DecodeError> {
        self.freq = [0; N];
        let m = (r.read_elias_gamma().or_truncated()? - 1) as u32;
        if m as usize > N {
            return Err(DecodeError::BadTable);
        }
        self.m = m;
        if m == 0 {
            return Ok(());
        }
        let mut prev = 0u64;
        let mut sum: u64 = 0;
        for j in 0..m {
            let delta = r.read_elias_gamma().or_truncated()?;
            // saturating: a corrupt delta near u64::MAX must land in the
            // `>= N` rejection below, not wrap (debug overflow panic).
            let sym = if j == 0 { delta - 1 } else { prev.saturating_add(delta) };
            if sym as usize >= N {
                return Err(DecodeError::BadTable);
            }
            prev = sym;
            let f = if j + 1 < m {
                let f = r.read_elias_gamma().or_truncated()?;
                if f > TOTAL as u64 {
                    return Err(DecodeError::BadTable);
                }
                f
            } else {
                if sum >= TOTAL as u64 {
                    return Err(DecodeError::BadTable);
                }
                TOTAL as u64 - sum
            };
            self.freq[sym as usize] = f as u16;
            sum += f;
            if sum > TOTAL as u64 {
                return Err(DecodeError::BadTable);
            }
        }
        let mut c = 0u32;
        for s in 0..N {
            self.cum[s] = c as u16;
            let f = self.freq[s] as u32;
            for t in c..c + f {
                self.slot[t as usize] = s as u16;
            }
            c += f;
        }
        if c != TOTAL {
            return Err(DecodeError::BadTable);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The rANS coder (byte-wise renormalization, 32-bit state).

struct RansEnc {
    x: u32,
}

impl RansEnc {
    fn new() -> Self {
        RansEnc { x: RANS_L }
    }

    #[inline]
    fn put<B: ByteSink>(&mut self, freq: u16, cum: u16, out: &mut B) {
        let f = freq as u32;
        debug_assert!(f > 0, "coded symbol must have nonzero frequency");
        let x_max = ((RANS_L >> SCALE_BITS) << 8) * f;
        let mut x = self.x;
        while x >= x_max {
            out.push_byte((x & 0xff) as u8);
            x >>= 8;
        }
        self.x = ((x / f) << SCALE_BITS) + (x % f) + cum as u32;
    }

    /// Emit the final state (4 bytes, low first — the reversed stream then
    /// opens with the state big-endian, read back via `read_bits(32)`).
    fn flush<B: ByteSink>(&mut self, out: &mut B) {
        let mut x = self.x;
        for _ in 0..4 {
            out.push_byte((x & 0xff) as u8);
            x >>= 8;
        }
    }
}

struct RansDec {
    x: u32,
}

impl RansDec {
    fn init(blob: &mut BitReader) -> Option<Self> {
        Some(RansDec { x: blob.read_bits(32)? as u32 })
    }

    #[inline]
    fn get<const N: usize>(&mut self, t: &DecTable<N>, blob: &mut BitReader) -> Option<usize> {
        let slot = (self.x & (TOTAL - 1)) as usize;
        let sym = t.slot[slot] as usize;
        self.x = t.freq[sym] as u32 * (self.x >> SCALE_BITS) + slot as u32 - t.cum[sym] as u32;
        while self.x < RANS_L {
            self.x = (self.x << 8) | blob.read_bits(8)? as u32;
        }
        Some(sym)
    }
}

// ---------------------------------------------------------------------------
// Symbol-stream plumbing shared by histogram, feed and tail passes.

/// The j-th successive index gap (first gap = idx[0]+1), exactly as the raw
/// format's gap coder computes it.
#[inline]
fn gap_at(idx: &[u32], j: usize) -> u64 {
    let prev = if j == 0 { 0 } else { idx[j - 1] as u64 };
    (idx[j] as u64 - prev + u64::from(j == 0)).max(1)
}

#[inline]
fn gap_class(gap: u64) -> u32 {
    63 - gap.leading_zeros()
}

/// Top 9 bits (sign + exponent) of an f32 — the entropy-coded part; the 23
/// mantissa bits ride raw, so every bit pattern (±0, subnormals, inf, NaN)
/// round-trips exactly.
#[inline]
fn top9(v: f32) -> usize {
    (v.to_bits() >> 23) as usize
}

/// All four stream tables; the message variant decides which are written.
struct Tables {
    gap: Table<GAP_SYMS>,
    val: Table<VAL_SYMS>,
    lvl: Table<LVL_SYMS>,
    bit: Table<BIT_SYMS>,
}

/// Histogram pass. `None` when the message cannot take the rANS container
/// (QSGD with more than 255 levels — the level alphabet would overflow).
fn build_tables(msg: &Message) -> Option<Tables> {
    let mut gap = [0u32; GAP_SYMS];
    let mut val = [0u32; VAL_SYMS];
    let mut lvl = [0u32; LVL_SYMS];
    let mut bit = [0u32; BIT_SYMS];
    let count_gaps = |hist: &mut [u32; GAP_SYMS], idx: &[u32]| {
        for j in 0..idx.len() {
            hist[gap_class(gap_at(idx, j)) as usize] += 1;
        }
    };
    match msg {
        Message::Dense { values } => {
            for &v in values {
                val[top9(v)] += 1;
            }
        }
        Message::SparseF32 { idx, vals, .. } => {
            count_gaps(&mut gap, idx);
            for &v in vals {
                val[top9(v)] += 1;
            }
        }
        Message::SparseSign { idx, neg, .. } => {
            count_gaps(&mut gap, idx);
            for &n in neg {
                bit[n as usize] += 1;
            }
        }
        Message::DenseSign { neg, .. } => {
            for &n in neg {
                bit[n as usize] += 1;
            }
        }
        Message::Qsgd { s, idx, levels, neg, .. } => {
            if *s as usize >= LVL_SYMS {
                return None;
            }
            if let Some(idx) = idx {
                count_gaps(&mut gap, idx);
            }
            for (&l, &n) in levels.iter().zip(neg) {
                if l as usize >= LVL_SYMS {
                    return None;
                }
                lvl[l as usize] += 1;
                if l != 0 {
                    bit[n as usize] += 1;
                }
            }
        }
    }
    Some(Tables {
        gap: Table::build(&gap),
        val: Table::build(&val),
        lvl: Table::build(&lvl),
        bit: Table::build(&bit),
    })
}

fn feed_gaps_rev<B: ByteSink>(idx: &[u32], t: &Table<GAP_SYMS>, enc: &mut RansEnc, out: &mut B) {
    for j in (0..idx.len()).rev() {
        t.put(enc, gap_class(gap_at(idx, j)) as usize, out);
    }
}

/// Feed every entropy-coded symbol in exact *reverse* decode order (rANS is
/// LIFO). One code path serves the counter and the writer.
fn feed<B: ByteSink>(msg: &Message, t: &Tables, enc: &mut RansEnc, out: &mut B) {
    match msg {
        Message::Dense { values } => {
            for v in values.iter().rev() {
                t.val.put(enc, top9(*v), out);
            }
        }
        Message::SparseF32 { idx, vals, .. } => {
            for v in vals.iter().rev() {
                t.val.put(enc, top9(*v), out);
            }
            feed_gaps_rev(idx, &t.gap, enc, out);
        }
        Message::SparseSign { idx, neg, .. } => {
            for &n in neg.iter().rev() {
                t.bit.put(enc, n as usize, out);
            }
            feed_gaps_rev(idx, &t.gap, enc, out);
        }
        Message::DenseSign { neg, .. } => {
            for &n in neg.iter().rev() {
                t.bit.put(enc, n as usize, out);
            }
        }
        Message::Qsgd { idx, levels, neg, .. } => {
            for i in (0..levels.len()).rev() {
                let l = levels[i];
                if l != 0 {
                    t.bit.put(enc, neg[i] as usize, out);
                }
                t.lvl.put(enc, l as usize, out);
            }
            if let Some(idx) = idx {
                feed_gaps_rev(idx, &t.gap, enc, out);
            }
        }
    }
}

/// Exact blob length in bytes: the same state machine as the writer,
/// against a counter.
fn blob_len(msg: &Message, t: &Tables) -> u64 {
    let mut count = ByteCount(0);
    let mut enc = RansEnc::new();
    feed(msg, t, &mut enc, &mut count);
    enc.flush(&mut count);
    count.0
}

/// Write the index-gap low bits (tail), in decode order.
fn tail_gap_lows<S: BitSink>(idx: &[u32], s: &mut S) {
    for j in 0..idx.len() {
        let gap = gap_at(idx, j);
        let c = gap_class(gap);
        s.bits(gap - (1u64 << c), c);
    }
}

/// The complete container, generically over the sink: the cost walk passes
/// `BitCost` (with `blob = None`), the encoder passes the real writer.
fn container<S: BitSink>(msg: &Message, t: &Tables, blob: Option<&[u8]>, blen: u64, s: &mut S) {
    s.bits(TAG_RANS, 3);
    s.bits(encode::raw_tag(msg), 3);
    s.elias(msg.dim() as u64 + 1);
    match msg {
        Message::Dense { values } => {
            t.val.write(s);
            s.elias(blen + 1);
            s.raw_blob(blob, blen);
            for &v in values {
                s.bits((v.to_bits() & 0x7f_ffff) as u64, 23);
            }
        }
        Message::SparseF32 { idx, vals, .. } => {
            s.elias(idx.len() as u64 + 1);
            t.gap.write(s);
            t.val.write(s);
            s.elias(blen + 1);
            s.raw_blob(blob, blen);
            tail_gap_lows(idx, s);
            for &v in vals {
                s.bits((v.to_bits() & 0x7f_ffff) as u64, 23);
            }
        }
        Message::SparseSign { scale, idx, .. } => {
            s.elias(idx.len() as u64 + 1);
            s.f32v(*scale);
            t.gap.write(s);
            t.bit.write(s);
            s.elias(blen + 1);
            s.raw_blob(blob, blen);
            tail_gap_lows(idx, s);
        }
        Message::DenseSign { scale, .. } => {
            s.f32v(*scale);
            t.bit.write(s);
            s.elias(blen + 1);
            s.raw_blob(blob, blen);
        }
        Message::Qsgd { s: levels_s, bucket, norms, post_scale, idx, .. } => {
            s.elias(*levels_s as u64);
            s.elias(*bucket as u64);
            s.f32v(*post_scale);
            match idx {
                Some(idx) => {
                    s.bit(true);
                    s.elias(idx.len() as u64 + 1);
                }
                None => s.bit(false),
            }
            s.elias(norms.len() as u64 + 1);
            for &nm in norms {
                s.f32v(nm);
            }
            if idx.is_some() {
                t.gap.write(s);
            }
            t.lvl.write(s);
            t.bit.write(s);
            s.elias(blen + 1);
            s.raw_blob(blob, blen);
            if let Some(idx) = idx {
                tail_gap_lows(idx, s);
            }
        }
    }
}

/// rANS container size in bits, or `None` when the message cannot take the
/// container (oversized QSGD alphabet).
fn rans_bits(msg: &Message) -> Option<u64> {
    let t = build_tables(msg)?;
    let blen = blob_len(msg, &t);
    let mut cost = BitCost(0);
    container(msg, &t, None, blen, &mut cost);
    Some(cost.0)
}

/// Exact wire size in bits under `codec` — a pure O(nnz) cost walk, equal
/// to the corresponding encoder's `encode().1` by shared construction.
/// Under `Rans` this is `min(rans container, raw)`: the encoder falls back
/// to the raw format whenever entropy coding would not strictly win.
pub fn wire_bits(msg: &Message, codec: Codec) -> u64 {
    let raw = encode::wire_bits(msg);
    match codec {
        Codec::Raw => raw,
        Codec::Rans => match rans_bits(msg) {
            Some(r) if r < raw => r,
            _ => raw,
        },
    }
}

/// Reusable codec-aware encoder: owns the bit writer and the rANS blob
/// scratch, so steady-state encoding performs no heap allocation once the
/// buffers have grown to the message size (bench-asserted).
pub struct WireEncoder {
    codec: Codec,
    w: BitWriter,
    blob: Vec<u8>,
}

impl WireEncoder {
    pub fn new(codec: Codec) -> Self {
        WireEncoder { codec, w: BitWriter::new(), blob: Vec::new() }
    }

    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Encode `msg` under the codec; returns the borrowed wire bytes and
    /// the exact bit length (equal to [`wire_bits`] for the same codec).
    pub fn encode(&mut self, msg: &Message) -> (&[u8], u64) {
        let mut used_rans = false;
        if self.codec == Codec::Rans {
            if let Some(t) = build_tables(msg) {
                let blen = blob_len(msg, &t);
                let mut cost = BitCost(0);
                container(msg, &t, None, blen, &mut cost);
                if cost.0 < encode::wire_bits(msg) {
                    self.blob.clear();
                    let mut enc = RansEnc::new();
                    feed(msg, &t, &mut enc, &mut self.blob);
                    enc.flush(&mut self.blob);
                    self.blob.reverse();
                    self.w.clear();
                    container(msg, &t, Some(&self.blob), blen, &mut self.w);
                    debug_assert_eq!(
                        self.w.bit_len(),
                        cost.0,
                        "rANS cost walk drifted from the writer"
                    );
                    used_rans = true;
                }
            }
        }
        if !used_rans {
            encode::encode_into(msg, &mut self.w);
        }
        self.w.finish()
    }
}

/// Allocating convenience wrapper over [`WireEncoder`] (figures, tests).
pub fn encode_with(msg: &Message, codec: Codec) -> (Vec<u8>, u64) {
    let mut e = WireEncoder::new(codec);
    let (bytes, bits) = e.encode(msg);
    (bytes.to_vec(), bits)
}

// ---------------------------------------------------------------------------
// Decode (the tag-5 arm of `encode::decode_into`).

/// Decode the container body (the 3-bit wire tag is already consumed).
/// Two cursors: the bounded blob reader feeds the rANS renormalization,
/// while the main reader skips past the blob and serves the raw-bits tail.
///
/// Element counts are validated against the stream (and the absolute
/// `MAX_WIRE_ELEMS` ceiling — an rANS stream can code symbols at ~zero wire
/// cost, so the count alone must bound every allocation) before any
/// `reserve`; sparse indices are range-checked as they are rebuilt.
pub(crate) fn decode_body(r: &mut BitReader, buf: &mut MessageBuf) -> Result<(), DecodeError> {
    let inner = r.read_bits(3).or_truncated()?;
    let d = checked_count(r.read_elias_gamma().or_truncated()? - 1, 0, r)?;
    match inner {
        encode::TAG_DENSE => {
            // Each value spends 23 raw mantissa bits in the tail.
            checked_count(d as u64, 23, r)?;
            let mut val_t = DecTable::<VAL_SYMS>::zeroed();
            val_t.read(r)?;
            let (mut blob, mut dec) = open_blob(r)?;
            let mut values = buf.take_dense();
            values.reserve(d);
            for _ in 0..d {
                let top = dec.get(&val_t, &mut blob).or_truncated()? as u32;
                let mant = r.read_bits(23).or_truncated()? as u32;
                values.push(f32::from_bits((top << 23) | mant));
            }
            buf.msg = Message::Dense { values };
        }
        encode::TAG_SPARSE_F32 => {
            let k = checked_count(r.read_elias_gamma().or_truncated()? - 1, 23, r)?;
            let mut gap_t = DecTable::<GAP_SYMS>::zeroed();
            gap_t.read(r)?;
            let mut val_t = DecTable::<VAL_SYMS>::zeroed();
            val_t.read(r)?;
            let (mut blob, mut dec) = open_blob(r)?;
            let (mut idx, mut vals) = buf.take_sparse_f32();
            read_gaps(&mut dec, &gap_t, &mut blob, r, k, d, &mut idx)?;
            vals.reserve(k);
            for _ in 0..k {
                let top = dec.get(&val_t, &mut blob).or_truncated()? as u32;
                let mant = r.read_bits(23).or_truncated()? as u32;
                vals.push(f32::from_bits((top << 23) | mant));
            }
            buf.msg = Message::SparseF32 { d, idx, vals };
        }
        encode::TAG_SPARSE_SIGN => {
            // Signs and gap classes ride in the blob at ~zero marginal wire
            // cost, so only the ceiling bounds k — but ascending indices
            // < d cap the loop at d pushes regardless.
            let k = checked_count(r.read_elias_gamma().or_truncated()? - 1, 0, r)?;
            if k > d {
                return Err(DecodeError::CountOverflow);
            }
            let scale = r.read_f32().or_truncated()?;
            let mut gap_t = DecTable::<GAP_SYMS>::zeroed();
            gap_t.read(r)?;
            let mut bit_t = DecTable::<BIT_SYMS>::zeroed();
            bit_t.read(r)?;
            let (mut blob, mut dec) = open_blob(r)?;
            let (mut idx, mut neg) = buf.take_sparse_sign();
            read_gaps(&mut dec, &gap_t, &mut blob, r, k, d, &mut idx)?;
            neg.reserve(k);
            for _ in 0..k {
                neg.push(dec.get(&bit_t, &mut blob).or_truncated()? != 0);
            }
            buf.msg = Message::SparseSign { d, scale, idx, neg };
        }
        encode::TAG_DENSE_SIGN => {
            let scale = r.read_f32().or_truncated()?;
            let mut bit_t = DecTable::<BIT_SYMS>::zeroed();
            bit_t.read(r)?;
            let (mut blob, mut dec) = open_blob(r)?;
            let mut neg = buf.take_dense_sign();
            neg.reserve(d);
            for _ in 0..d {
                neg.push(dec.get(&bit_t, &mut blob).or_truncated()? != 0);
            }
            buf.msg = Message::DenseSign { scale, neg };
        }
        encode::TAG_QSGD => {
            let s = r.read_elias_gamma().or_truncated()? as u32;
            let bucket = r.read_elias_gamma().or_truncated()? as u32;
            let post_scale = r.read_f32().or_truncated()?;
            let has_idx = r.read_bit().or_truncated()?;
            let k = if has_idx {
                let k = checked_count(r.read_elias_gamma().or_truncated()? - 1, 0, r)?;
                if k > d {
                    return Err(DecodeError::CountOverflow);
                }
                k
            } else {
                0
            };
            let count = if has_idx { k } else { d };
            let (mut norms, mut idx, mut levels, mut neg) = buf.take_qsgd();
            let n_norms = checked_count(r.read_elias_gamma().or_truncated()? - 1, 32, r)?;
            norms.reserve(n_norms);
            for _ in 0..n_norms {
                norms.push(r.read_f32().or_truncated()?);
            }
            let mut gap_t = DecTable::<GAP_SYMS>::zeroed();
            if has_idx {
                gap_t.read(r)?;
            }
            let mut lvl_t = DecTable::<LVL_SYMS>::zeroed();
            lvl_t.read(r)?;
            let mut bit_t = DecTable::<BIT_SYMS>::zeroed();
            bit_t.read(r)?;
            let (mut blob, mut dec) = open_blob(r)?;
            if has_idx {
                read_gaps(&mut dec, &gap_t, &mut blob, r, k, d, &mut idx)?;
            }
            levels.reserve(count);
            neg.reserve(count);
            for _ in 0..count {
                let l = dec.get(&lvl_t, &mut blob).or_truncated()? as u32;
                if l != 0 {
                    levels.push(l);
                    neg.push(dec.get(&bit_t, &mut blob).or_truncated()? != 0);
                } else {
                    levels.push(0);
                    neg.push(false);
                }
            }
            buf.msg = Message::Qsgd {
                d,
                s,
                bucket,
                norms,
                post_scale,
                idx: has_idx.then_some(idx),
                levels,
                neg,
            };
        }
        _ => return Err(DecodeError::BadTag),
    }
    Ok(())
}

/// Read the blob header, split off the bounded blob reader, advance the
/// main reader past the blob (to the raw-bits tail) and prime the decoder.
fn open_blob<'a>(r: &mut BitReader<'a>) -> Result<(BitReader<'a>, RansDec), DecodeError> {
    let blen = r.read_elias_gamma().or_truncated()? - 1;
    let nbits = blen.checked_mul(8).ok_or(DecodeError::CountOverflow)?;
    let end = r.bit_pos().checked_add(nbits).ok_or(DecodeError::CountOverflow)?;
    let mut blob = r.sub(end).or_truncated()?;
    r.skip(nbits).or_truncated()?;
    let dec = RansDec::init(&mut blob).or_truncated()?;
    Ok((blob, dec))
}

/// Decode `k` gap classes (rANS) + low bits (tail) into ascending indices —
/// the inverse of `feed_gaps_rev` + `tail_gap_lows`. Indices ascend
/// strictly by construction (every gap ≥ 1); each must land in `0..d`.
fn read_gaps(
    dec: &mut RansDec,
    t: &DecTable<GAP_SYMS>,
    blob: &mut BitReader,
    r: &mut BitReader,
    k: usize,
    d: usize,
    idx: &mut Vec<u32>,
) -> Result<(), DecodeError> {
    debug_assert!(idx.is_empty());
    idx.reserve(k);
    let mut prev = 0u64;
    for j in 0..k {
        let class = dec.get(t, blob).or_truncated()? as u32;
        if class >= GAP_SYMS as u32 {
            return Err(DecodeError::BadIndex);
        }
        let low = r.read_bits(class).or_truncated()?;
        let gap = (1u64 << class) | low;
        // class ≤ 32 ⇒ gap ≤ 2^33 and prev < d ≤ 2^27: no overflow.
        let i = prev + gap - u64::from(j == 0);
        if i >= d as u64 {
            return Err(DecodeError::BadIndex);
        }
        idx.push(i as u32);
        prev = i;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Compressor, Identity, QTopK, Qsgd, RandK, SignDense, SignTopK, TopK};
    use crate::util::rng::Pcg64;

    /// Force the rANS container (bypassing the strict-min raw fallback) so
    /// degenerate histograms exercise the entropy path even when raw wins.
    fn force_rans(msg: &Message) -> Option<(Vec<u8>, u64)> {
        let t = build_tables(msg)?;
        let blen = blob_len(msg, &t);
        let mut blob = Vec::new();
        let mut enc = RansEnc::new();
        feed(msg, &t, &mut enc, &mut blob);
        enc.flush(&mut blob);
        blob.reverse();
        assert_eq!(blob.len() as u64, blen, "blob cost walk drifted");
        let mut cost = BitCost(0);
        container(msg, &t, None, blen, &mut cost);
        let mut w = BitWriter::new();
        container(msg, &t, Some(&blob), blen, &mut w);
        let (bytes, bits) = w.into_bytes();
        assert_eq!(bits, cost.0, "container cost walk drifted");
        Some((bytes, bits))
    }

    fn assert_bits_identical(a: &Message, b: &Message) {
        // PartialEq would reject NaN == NaN; the wire contract is *bit*
        // identity, so compare the raw serializations.
        assert_eq!(encode::encode(a), encode::encode(b));
    }

    #[test]
    fn codec_parse_and_display() {
        assert_eq!(Codec::parse("raw"), Some(Codec::Raw));
        assert_eq!(Codec::parse("rans"), Some(Codec::Rans));
        assert_eq!(Codec::parse("zstd"), None);
        assert_eq!(Codec::default(), Codec::Raw);
        assert_eq!(Codec::Rans.as_str(), "rans");
    }

    #[test]
    fn forced_container_roundtrips_all_operators() {
        let mut rng = Pcg64::seeded(411);
        let d = 300;
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(TopK::new(13)),
            Box::new(RandK::new(13)),
            Box::new(Qsgd::from_bits(4)),
            Box::new(SignDense::new()),
            Box::new(QTopK::new(13, Qsgd::from_bits(4), true)),
            Box::new(QTopK::new(13, Qsgd::from_bits(2), false)),
            Box::new(SignTopK::new(13, 1)),
        ];
        for op in ops {
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let msg = op.compress(&x, &mut rng);
            let (bytes, bits) = force_rans(&msg).expect("container applies");
            let back = encode::decode(&bytes, bits)
                .unwrap_or_else(|e| panic!("{}: rans decode failed: {e}", op.name()));
            assert_eq!(back, msg, "{}: rans roundtrip", op.name());
        }
    }

    #[test]
    fn degenerate_histograms_roundtrip() {
        let cases: Vec<Message> = vec![
            // nnz = 0
            Message::SparseF32 { d: 100, idx: vec![], vals: vec![] },
            // nnz = 1 (single gap symbol, single value symbol)
            Message::SparseF32 { d: 100, idx: vec![7], vals: vec![2.5] },
            // single value symbol with frequency 4096 (constant dense)
            Message::Dense { values: vec![1.0; 50] },
            // all-same-sign sparse signs
            Message::SparseSign {
                d: 64,
                scale: 0.5,
                idx: (0..20).collect(),
                neg: vec![false; 20],
            },
            Message::DenseSign { scale: 1.5, neg: vec![true; 32] },
            // QSGD with every level zero: empty sign histogram
            Message::Qsgd {
                d: 10,
                s: 4,
                bucket: 10,
                norms: vec![0.0],
                post_scale: 1.0,
                idx: None,
                levels: vec![0; 10],
                neg: vec![false; 10],
            },
            // exotic f32 bit patterns must survive exactly
            Message::SparseF32 {
                d: 16,
                idx: vec![1, 5, 9, 12],
                vals: vec![f32::NAN, f32::INFINITY, -0.0, 1.1e-42],
            },
        ];
        for (i, msg) in cases.iter().enumerate() {
            let (bytes, bits) = force_rans(msg).expect("container applies");
            let back = encode::decode(&bytes, bits)
                .unwrap_or_else(|e| panic!("case {i}: rans decode failed: {e}"));
            assert_bits_identical(&back, msg);
            // The public encoder (min rule) must also round-trip, whichever
            // format it picks.
            let (pbytes, pbits) = encode_with(msg, Codec::Rans);
            assert_eq!(pbits, wire_bits(msg, Codec::Rans), "case {i}");
            let back = encode::decode(&pbytes, pbits).expect("decode");
            assert_bits_identical(&back, msg);
        }
    }

    #[test]
    fn oversized_qsgd_alphabet_falls_back_to_raw() {
        let msg = Message::Qsgd {
            d: 8,
            s: 300, // > 255 levels: no rANS container
            bucket: 8,
            norms: vec![2.0],
            post_scale: 1.0,
            idx: None,
            levels: vec![0, 1, 300, 7, 0, 299, 3, 2],
            neg: vec![false, true, false, true, false, false, true, false],
        };
        assert!(build_tables(&msg).is_none());
        assert_eq!(wire_bits(&msg, Codec::Rans), encode::wire_bits(&msg));
        let mut enc = WireEncoder::new(Codec::Rans);
        let (bytes, bits) = enc.encode(&msg);
        let (raw_bytes, raw_bits) = encode::encode(&msg);
        assert_eq!(bytes, &raw_bytes[..]);
        assert_eq!(bits, raw_bits);
    }

    #[test]
    fn wire_bits_matches_encoder_for_both_codecs() {
        let mut rng = Pcg64::seeded(417);
        let d = 500;
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(TopK::new(40)),
            Box::new(RandK::new(40)),
            Box::new(Qsgd::from_bits(4)),
            Box::new(SignDense::new()),
            Box::new(QTopK::new(40, Qsgd::from_bits(4), false)),
            Box::new(SignTopK::new(40, 2)),
        ];
        let mut raw_enc = WireEncoder::new(Codec::Raw);
        let mut rans_enc = WireEncoder::new(Codec::Rans);
        for op in &ops {
            for _ in 0..4 {
                let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
                let msg = op.compress(&x, &mut rng);
                let (_, raw_bits) = raw_enc.encode(&msg);
                assert_eq!(raw_bits, msg.wire_bits_with(Codec::Raw), "{}", op.name());
                assert_eq!(raw_bits, encode::wire_bits(&msg), "{}", op.name());
                let (bytes, bits) = rans_enc.encode(&msg);
                assert_eq!(bits, msg.wire_bits_with(Codec::Rans), "{}", op.name());
                assert!(bits <= raw_bits, "{}: rans exceeded raw", op.name());
                let back = encode::decode(bytes, bits)
                    .unwrap_or_else(|e| panic!("{}: decode: {e}", op.name()));
                assert_eq!(back, msg, "{}: roundtrip through rans encoder", op.name());
            }
        }
    }

    #[test]
    fn rans_beats_raw_on_skewed_supports() {
        let mut rng = Pcg64::seeded(423);
        let d = 7850;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for (name, msg) in [
            ("topk:k=400", TopK::new(400).compress(&x, &mut rng)),
            (
                "qtopk:k=400,bits=4",
                QTopK::new(400, Qsgd::from_bits(4), false).compress(&x, &mut rng),
            ),
        ] {
            let raw = wire_bits(&msg, Codec::Raw);
            let rans = wire_bits(&msg, Codec::Rans);
            assert!(
                (rans as f64) < 0.9 * raw as f64,
                "{name}: rans {rans} not well below raw {raw}"
            );
        }
        // Clustered support: heavily skewed gap histogram.
        let idx: Vec<u32> = (1000..1400).collect();
        let vals: Vec<f32> = (0..400).map(|_| rng.normal_f32()).collect();
        let msg = Message::SparseF32 { d: 1 << 20, idx, vals };
        let raw = wire_bits(&msg, Codec::Raw);
        let rans = wire_bits(&msg, Codec::Rans);
        assert!(rans < raw, "clustered: rans {rans} vs raw {raw}");
    }
}
