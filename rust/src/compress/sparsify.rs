//! Sparsification operators: Top_k and Rand_k (paper §2.2).
//!
//! Both satisfy Definition 3 with γ = k/d (Top_k deterministically, Rand_k in
//! expectation). Top_k selection uses an introselect (quickselect with
//! median-of-three pivots and a heapsort fallback) over |x_i| so the hot path
//! is O(d) expected — no full sort of 25M-element gradients.

use super::{Compressor, Message, MessageBuf};
// The magnitude→u32 key mapping and the pack/scan passes are SIMD kernels
// (scalar reference + AVX2/Neon twins in `crate::simd`); selection and
// tie-breaking stay here so the chosen support is backend-independent.
use crate::simd::ordered;
use crate::util::rng::Pcg64;

/// Reusable buffers for the sparsifier selection paths: Top_k's packed
/// introselect array, strided sample and candidate list, plus Rand_k's
/// seen-index bitmap and Fisher–Yates arena. Owned by [`MessageBuf`] so
/// steady-state selection allocates nothing once capacities are reached.
#[derive(Default)]
pub struct TopKScratch {
    packed: Vec<u64>,
    sample: Vec<u32>,
    cand: Vec<u64>,
    /// Rand_k: per-call seen bitmap for Floyd's distinct-index sampler
    /// (⌈d/64⌉ words, cleared by `resize`+`fill` each call).
    seen: Vec<u64>,
    /// Rand_k: partial Fisher–Yates arena for the dense regime (k·4 > d).
    fy: Vec<u32>,
}

/// Keep the k largest-magnitude coordinates at full precision.
#[derive(Clone, Debug)]
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "TopK requires k > 0");
        TopK { k }
    }
}

impl Compressor for TopK {
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        super::compress_owned(self, x, rng)
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Pcg64, buf: &mut MessageBuf) {
        let (mut idx, mut vals) = buf.take_sparse_f32();
        top_k_indices_into(x, self.k.min(x.len()), &mut idx, &mut buf.topk);
        vals.extend(idx.iter().map(|&i| x[i as usize]));
        buf.msg = Message::SparseF32 { d: x.len(), idx, vals };
    }

    fn gamma(&self, d: usize) -> f64 {
        (self.k.min(d) as f64) / d.max(1) as f64
    }

    fn name(&self) -> String {
        format!("topk(k={})", self.k)
    }
}

/// Keep k uniformly random coordinates at full precision.
///
/// This is the *biased* Rand_k of the paper (values are not rescaled by d/k);
/// it satisfies Definition 3 with γ = k/d in expectation.
#[derive(Clone, Debug)]
pub struct RandK {
    pub k: usize,
}

impl RandK {
    pub fn new(k: usize) -> Self {
        assert!(k > 0, "RandK requires k > 0");
        RandK { k }
    }
}

impl Compressor for RandK {
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        super::compress_owned(self, x, rng)
    }

    /// Allocation-free in steady state: the distinct-index sampler draws
    /// through [`sample_indices_into`], which replays exactly the RNG
    /// sequence of `Pcg64::sample_indices` against reusable scratch (a seen
    /// bitmap / Fisher–Yates arena held in [`TopKScratch`]), so seeded
    /// Rand_k trajectories are unchanged and the engine's zero-allocation
    /// guarantee now covers Rand_k too.
    fn compress_into(&self, x: &[f32], rng: &mut Pcg64, buf: &mut MessageBuf) {
        let (mut idx, mut vals) = buf.take_sparse_f32();
        let k = self.k.min(x.len());
        sample_indices_into(rng, x.len(), k, &mut idx, &mut buf.topk);
        idx.sort_unstable();
        vals.extend(idx.iter().map(|&i| x[i as usize]));
        buf.msg = Message::SparseF32 { d: x.len(), idx, vals };
    }

    fn gamma(&self, d: usize) -> f64 {
        (self.k.min(d) as f64) / d.max(1) as f64
    }

    fn name(&self) -> String {
        format!("randk(k={})", self.k)
    }
}

/// Sample `k` distinct indices from `[0, n)` into `out`, reusing `scratch`
/// — the allocation-free twin of [`Pcg64::sample_indices`]. The two MUST
/// stay in lockstep: same branch condition, same per-iteration draws, same
/// output order, so seeded Rand_k trajectories are independent of which
/// API produced them (property-tested via `compress` ≡ `compress_into`).
pub(crate) fn sample_indices_into(
    rng: &mut Pcg64,
    n: usize,
    k: usize,
    out: &mut Vec<u32>,
    scratch: &mut TopKScratch,
) {
    assert!(k <= n, "sample_indices_into: k={k} > n={n}");
    out.clear();
    if k == 0 {
        return;
    }
    if k * 4 <= n {
        // Floyd's sampler; the hash set becomes a reusable bitmap.
        let words = (n + 63) / 64;
        let seen = &mut scratch.seen;
        seen.clear();
        seen.resize(words, 0);
        for j in (n - k)..n {
            let t = rng.below_usize(j + 1);
            if (seen[t / 64] >> (t % 64)) & 1 == 0 {
                seen[t / 64] |= 1 << (t % 64);
                out.push(t as u32);
            } else {
                // j itself cannot have been drawn before (earlier draws are
                // all < j), exactly as in Floyd's original.
                seen[j / 64] |= 1 << (j % 64);
                out.push(j as u32);
            }
        }
    } else {
        // Dense regime: partial Fisher–Yates over a reusable index arena.
        let fy = &mut scratch.fy;
        fy.clear();
        fy.extend(0..n as u32);
        for i in 0..k {
            let j = i + rng.below_usize(n - i);
            fy.swap(i, j);
        }
        out.extend_from_slice(&fy[..k]);
    }
}

/// Indices of the k largest |x_i|, ascending index order.
///
/// O(d) expected: introselect partitions an index array around the k-th
/// magnitude. Ties are broken arbitrarily (any valid top-k set is returned,
/// matching the paper's definition). Allocating wrapper around
/// [`top_k_indices_into`].
pub fn top_k_indices(x: &[f32], k: usize) -> Vec<u32> {
    let mut out = Vec::new();
    let mut scratch = TopKScratch::default();
    top_k_indices_into(x, k, &mut out, &mut scratch);
    out
}

/// As [`top_k_indices`], writing into `out` and reusing `scratch` — the
/// allocation-free hot-path variant (§Perf iteration 5). The selection
/// logic (and its tie-breaking) is identical to the allocating wrapper.
pub fn top_k_indices_into(x: &[f32], k: usize, out: &mut Vec<u32>, scratch: &mut TopKScratch) {
    let d = x.len();
    let k = k.min(d);
    out.clear();
    if k == 0 {
        return;
    }
    if k == d {
        out.extend(0..d as u32);
        return;
    }
    // §Perf iteration 4: for large d with small k, estimate the k-th
    // magnitude from a strided sample, collect the few candidates above it
    // in one read-only pass, and select exactly among those. Falls back to
    // the exact packed path when the estimate misfires.
    if d >= (1 << 16) && k * 8 < d && top_k_sampled_into(x, k, out, scratch) {
        return;
    }
    top_k_packed_into(x, k, out, scratch);
}

/// Exact path (§Perf iteration 2): pack (magnitude, index) into one u64 so
/// the introselect partitions a flat array with no indirection back into `x`
/// (the original by-key select was cache-miss bound at ResNet-50 scale).
/// Magnitude occupies the high 32 bits, so u64 order = magnitude order.
fn top_k_packed_into(x: &[f32], k: usize, out: &mut Vec<u32>, scratch: &mut TopKScratch) {
    let d = x.len();
    let packed = &mut scratch.packed;
    packed.clear();
    crate::simd::pack_ordered_into(x, packed);
    // Ascending select: the k largest live in packed[d-k..].
    packed.select_nth_unstable(d - k);
    out.clear();
    out.extend(packed[d - k..].iter().map(|&p| p as u32));
    out.sort_unstable();
}

/// Sampled-threshold path: deterministic strided sample → conservative
/// threshold near the (1 − 2k/d) quantile → one filtering pass → exact
/// select among ~2k candidates. Returns false (caller falls back) when the
/// sample misjudges the tail (too few candidates, or a blow-up past 8k).
fn top_k_sampled_into(x: &[f32], k: usize, out: &mut Vec<u32>, scratch: &mut TopKScratch) -> bool {
    let d = x.len();
    let sample_n = 8192.min(d / 2);
    let stride = d / sample_n;
    let sample = &mut scratch.sample;
    sample.clear();
    sample.extend(x.iter().step_by(stride).map(|&v| ordered(v.abs())));
    // Aim to collect ~2k candidates so the estimate has slack on both sides.
    let target = ((2 * k) as f64 / d as f64 * sample.len() as f64).ceil() as usize;
    let pos = match sample.len().checked_sub(target.max(1)) {
        Some(0) | None => return false,
        Some(pos) => pos,
    };
    sample.select_nth_unstable(pos);
    let thresh = sample[pos];
    let cap = 8 * k;
    let cand = &mut scratch.cand;
    cand.clear();
    if !crate::simd::scan_threshold_into(x, thresh, cap, cand) {
        return false; // threshold too permissive — exact fallback
    }
    if cand.len() < k {
        return false; // threshold too strict — exact fallback
    }
    let n = cand.len();
    cand.select_nth_unstable(n - k);
    out.clear();
    out.extend(cand[n - k..].iter().map(|&p| p as u32));
    out.sort_unstable();
    true
}

#[cfg(test)]
#[allow(clippy::disallowed_types)] // hash containers as assertion scratch only
mod tests {
    use super::*;
    use crate::util::stats::norm2_sq;

    #[test]
    fn topk_picks_largest_magnitudes() {
        let x = vec![0.1f32, -5.0, 0.2, 3.0, -0.05, 4.0];
        let idx = top_k_indices(&x, 3);
        assert_eq!(idx, vec![1, 3, 5]);
    }

    #[test]
    fn topk_k_ge_d_is_identity_support() {
        let x = vec![1.0f32, 2.0];
        let mut rng = Pcg64::seeded(0);
        let m = TopK::new(10).compress(&x, &mut rng);
        assert_eq!(m.to_dense(), x);
    }

    #[test]
    fn topk_compression_property_deterministic() {
        // ‖x − Top_k(x)‖² ≤ (1 − k/d)‖x‖² holds deterministically.
        let mut rng = Pcg64::seeded(4);
        for trial in 0..50 {
            let d = 32 + trial * 7;
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let k = 1 + trial % 13;
            let op = TopK::new(k);
            let dense = op.compress(&x, &mut rng).to_dense();
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            let bound = (1.0 - op.gamma(d)) * norm2_sq(&x);
            assert!(
                norm2_sq(&resid) <= bound + 1e-6,
                "d={d} k={k}: {} > {bound}",
                norm2_sq(&resid)
            );
        }
    }

    #[test]
    fn randk_support_size_and_unbiased_support() {
        let mut rng = Pcg64::seeded(6);
        let d = 64;
        let x: Vec<f32> = (0..d).map(|i| i as f32 + 1.0).collect();
        let op = RandK::new(8);
        let mut counts = vec![0usize; d];
        for _ in 0..2000 {
            match op.compress(&x, &mut rng) {
                Message::SparseF32 { idx, .. } => {
                    assert_eq!(idx.len(), 8);
                    for &i in &idx {
                        counts[i as usize] += 1;
                    }
                }
                _ => panic!("wrong message"),
            }
        }
        // Each index should appear with frequency ≈ k/d = 1/8 of 2000 = 250.
        for (i, &c) in counts.iter().enumerate() {
            assert!((150..380).contains(&c), "index {i} count {c}");
        }
    }

    #[test]
    fn sampled_path_matches_exact_magnitudes() {
        // Large-d path: the sampled top-k must select a set with the same
        // k-th magnitude threshold as the exact path (sets may differ only
        // in tie-breaks).
        let mut rng = Pcg64::seeded(8);
        let d = 1 << 17;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for k in [16usize, 256, 1000] {
            let got = top_k_indices(&x, k);
            let mut exact = Vec::new();
            top_k_packed_into(&x, k, &mut exact, &mut TopKScratch::default());
            assert_eq!(got.len(), k);
            let min_got = got.iter().map(|&i| x[i as usize].abs()).fold(f32::MAX, f32::min);
            let min_exact = exact.iter().map(|&i| x[i as usize].abs()).fold(f32::MAX, f32::min);
            assert_eq!(min_got.to_bits(), min_exact.to_bits(), "k={k}");
            // sum of selected magnitudes identical
            let s_got: f64 = got.iter().map(|&i| x[i as usize].abs() as f64).sum();
            let s_exact: f64 = exact.iter().map(|&i| x[i as usize].abs() as f64).sum();
            assert!((s_got - s_exact).abs() < 1e-3, "k={k}");
        }
    }

    #[test]
    fn sampled_path_falls_back_on_adversarial_input() {
        // Constant vector: every candidate passes the threshold → blow-up →
        // fallback must still return exactly k indices.
        let d = 1 << 17;
        let x = vec![1.0f32; d];
        let idx = top_k_indices(&x, 64);
        assert_eq!(idx.len(), 64);
        // Heavy-tail spike vector: sample misses the spikes → strict
        // threshold path; still exact.
        let mut x2 = vec![0.0f32; d];
        for i in 0..32 {
            x2[i * 919] = 100.0 + i as f32;
        }
        let idx2 = top_k_indices(&x2, 32);
        assert_eq!(idx2.len(), 32);
        let set: std::collections::HashSet<u32> = idx2.into_iter().collect();
        for i in 0..32u32 {
            assert!(set.contains(&(i * 919)), "missing spike {i}");
        }
    }

    #[test]
    fn sample_indices_into_replays_sample_indices_exactly() {
        // Same seed → same draws, same outputs, in both regimes (Floyd and
        // partial Fisher–Yates) — the lockstep contract RandK relies on.
        let mut scratch = TopKScratch::default();
        let mut out = Vec::new();
        for &(n, k) in &[(100usize, 5usize), (100, 90), (64, 16), (10, 10), (50, 0), (1, 1)] {
            let mut a = Pcg64::seeded(42 + n as u64);
            let mut b = a.clone();
            let want = a.sample_indices(n, k);
            sample_indices_into(&mut b, n, k, &mut out, &mut scratch);
            let got: Vec<usize> = out.iter().map(|&i| i as usize).collect();
            assert_eq!(got, want, "n={n} k={k}");
            // RNG streams consumed identically.
            assert_eq!(a.next_u64(), b.next_u64(), "n={n} k={k}: draw counts differ");
        }
    }

    #[test]
    fn topk_handles_ties_and_zeros() {
        let x = vec![0.0f32; 16];
        let idx = top_k_indices(&x, 4);
        assert_eq!(idx.len(), 4);
        let x2 = vec![1.0f32; 16];
        let idx2 = top_k_indices(&x2, 4);
        assert_eq!(idx2.len(), 4);
    }
}
