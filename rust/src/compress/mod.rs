//! Communication-efficient compression operators (paper §2).
//!
//! Every operator maps a d-vector to a compressed message. The library keeps
//! two views of each compressed update in lockstep:
//!
//! * the **mathematical** view: `Message::to_dense(d)` reconstructs exactly
//!   the vector `C(x)` the algorithm applies to the model and subtracts from
//!   the error memory;
//! * the **wire** view: `encode::encode(&msg)` serializes the message to a
//!   bitstream whose length is the bit cost the paper's figures report.
//!
//! All operators satisfy (deterministically or in expectation) the
//! γ-compression property of Definition 3:
//!     E ‖x − C(x)‖² ≤ (1 − γ) ‖x‖².
//! `Compressor::gamma(d)` returns the worst-case γ from Lemmas 1–3 so the
//! theory-facing code (learning-rate pre-conditions, tests) can use it.
// `unsafe` lives only in the fork-join core (`engine::parallel`,
// `coordinator::master`) and the vector backends (`simd::{avx2, neon}`) —
// everywhere else, including all of `compress`, it is a compile error; the
// kernels this module calls are `crate::simd`'s safe dispatch entry points.
#![forbid(unsafe_code)]

pub mod composed;
pub mod encode;
pub mod memory;
pub mod piecewise;
pub mod quantize;
pub mod rans;
pub mod sparsify;

pub use composed::{QTopK, SignTopK};
pub use encode::DecodeError;
pub use memory::ErrorMemory;
pub use piecewise::Piecewise;
pub use quantize::{Qsgd, SignDense};
pub use rans::{Codec, WireEncoder};
pub use sparsify::{RandK, TopK};

use crate::simd;
use crate::util::rng::Pcg64;

/// A compressed model update, as produced by a `Compressor`.
///
/// `d` is always the full dimension; sparse variants carry the support set
/// explicitly. Value semantics: `to_dense` is the exact vector the algorithm
/// uses (i.e. any scaling factors are already folded in).
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Full-precision dense vector (identity / vanilla SGD / local SGD).
    Dense { values: Vec<f32> },
    /// Sparse full-precision values on an explicit support (Top_k / Rand_k).
    SparseF32 { d: usize, idx: Vec<u32>, vals: Vec<f32> },
    /// Sparse sign message: value at idx[i] is `scale * sign[i]`
    /// (SignTop_k, Lemma 3). Signs are stored as booleans (true = +1).
    SparseSign { d: usize, scale: f32, idx: Vec<u32>, neg: Vec<bool> },
    /// Dense scaled-sign message (EF-SignSGD baseline): value_i = scale * sign_i.
    DenseSign { scale: f32, neg: Vec<bool> },
    /// QSGD s-level stochastic quantization (Alistarh et al. 2017) of either
    /// the full vector (`idx == None`) or a sparse support (`QTop_k`).
    /// Quantization is *bucketed* (AGL+17 §3.3): the transmitted values are
    /// split into contiguous buckets of `bucket` coordinates, each carrying
    /// its own ℓ2 norm, which bounds the variance blow-up by β_{bucket,s}.
    /// value at support[i] = `norms[i / bucket] * sign_i * level_i / s * post_scale`.
    Qsgd {
        d: usize,
        s: u32,
        bucket: u32,
        norms: Vec<f32>,
        /// `1.0` for the unscaled operator (Lemma 1); `1/(1+β)` for the
        /// scaled operator (Lemma 2).
        post_scale: f32,
        idx: Option<Vec<u32>>,
        levels: Vec<u32>,
        neg: Vec<bool>,
    },
}

impl Message {
    /// Dimension of the underlying vector.
    pub fn dim(&self) -> usize {
        match self {
            Message::Dense { values } => values.len(),
            Message::SparseF32 { d, .. } => *d,
            Message::SparseSign { d, .. } => *d,
            Message::DenseSign { neg, .. } => neg.len(),
            Message::Qsgd { d, .. } => *d,
        }
    }

    /// Number of explicitly transmitted coordinates.
    pub fn nnz(&self) -> usize {
        match self {
            Message::Dense { values } => values.len(),
            Message::SparseF32 { idx, .. } => idx.len(),
            Message::SparseSign { idx, .. } => idx.len(),
            Message::DenseSign { neg, .. } => neg.len(),
            Message::Qsgd { levels, idx, .. } => idx.as_ref().map_or(levels.len(), |i| i.len()),
        }
    }

    /// Reconstruct the dense vector `C(x)`.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.dim()];
        self.add_into(&mut out, 1.0);
        out
    }

    /// `out += scale * C(x)`. This is the hot path on the master (aggregation)
    /// and on workers (memory update), so it avoids materializing the dense
    /// vector for sparse messages. Dense-destination inner loops route
    /// through the `crate::simd` fold kernels (scalar/AVX2/Neon,
    /// bit-identical by construction — each coordinate still receives
    /// exactly one unfused `scale * v` add); scattered sparse supports stay
    /// scalar, except a fully contiguous index run, which folds as one
    /// dense slice.
    pub fn add_into(&self, out: &mut [f32], scale: f32) {
        match self {
            Message::Dense { values } => {
                debug_assert_eq!(out.len(), values.len());
                simd::add_scaled(out, values, scale);
            }
            Message::SparseF32 { idx, vals, .. } => {
                if let Some(base) = contiguous_run(idx) {
                    simd::add_scaled(&mut out[base..base + vals.len()], vals, scale);
                } else {
                    for (&i, &v) in idx.iter().zip(vals) {
                        out[i as usize] += scale * v;
                    }
                }
            }
            Message::SparseSign { scale: s, idx, neg, .. } => {
                if let Some(base) = contiguous_run(idx) {
                    simd::add_signed(&mut out[base..base + neg.len()], neg, *s, scale);
                } else {
                    for (&i, &n) in idx.iter().zip(neg) {
                        out[i as usize] += scale * if n { -s } else { *s };
                    }
                }
            }
            Message::DenseSign { scale: s, neg } => {
                debug_assert_eq!(out.len(), neg.len());
                simd::add_signed(out, neg, *s, scale);
            }
            Message::Qsgd { s, bucket, norms, post_scale, idx, levels, neg, .. } => {
                let unit0 = *post_scale / *s as f32;
                let bucket = (*bucket).max(1) as usize;
                match idx {
                    None => {
                        for (j, (&l, &n)) in levels.iter().zip(neg).enumerate() {
                            if l != 0 {
                                let v = unit0 * norms[j / bucket] * l as f32;
                                out[j] += scale * if n { -v } else { v };
                            }
                        }
                    }
                    Some(idx) => {
                        for (j, ((&i, &l), &n)) in idx.iter().zip(levels).zip(neg).enumerate() {
                            if l != 0 {
                                let v = unit0 * norms[j / bucket] * l as f32;
                                out[i as usize] += scale * if n { -v } else { v };
                            }
                        }
                    }
                }
            }
        }
    }

    /// Exact size of this message on the wire, in bits (delegates to
    /// `encode::wire_bits`, a pure O(nnz) cost walk; equal to
    /// `encode::encode(self).1` — asserted by property tests).
    pub fn wire_bits(&self) -> u64 {
        encode::wire_bits(self)
    }

    /// Exact wire size in bits under the given codec — still a pure cost
    /// walk (no serialization); equal to what a [`WireEncoder`] with the
    /// same codec would emit for this message (property-tested).
    pub fn wire_bits_with(&self, codec: Codec) -> u64 {
        rans::wire_bits(self, codec)
    }

    /// Visit every coordinate of `C(x)` that [`Message::add_into`] would
    /// touch, restricted to indices in `range`, in ascending index order:
    /// `f(i, v)` receives the *global* coordinate `i` and the exact signed
    /// value `v` such that `add_into` performs `out[i] += scale * v`.
    ///
    /// The visit set matches `add_into` exactly — explicitly transmitted
    /// coordinates are visited even when their value happens to be `0.0`
    /// (a dense zero is on the wire), while structural zeros (Qsgd zero
    /// levels) are skipped, exactly as `add_into` skips them. Sparse
    /// supports are ascending (the wire encoder's index coding relies on
    /// it), so the in-range span is located by binary search:
    /// O(log nnz + nnz_in_range) per call.
    pub fn for_each_nonzero_in(
        &self,
        range: std::ops::Range<usize>,
        mut f: impl FnMut(usize, f32),
    ) {
        debug_assert!(range.end <= self.dim());
        match self {
            Message::Dense { values } => {
                for (j, &v) in values[range.clone()].iter().enumerate() {
                    f(range.start + j, v);
                }
            }
            Message::SparseF32 { idx, vals, .. } => {
                let (a, b) = idx_span(idx, &range);
                for (&i, &v) in idx[a..b].iter().zip(&vals[a..b]) {
                    f(i as usize, v);
                }
            }
            Message::SparseSign { scale: s, idx, neg, .. } => {
                let (a, b) = idx_span(idx, &range);
                for (&i, &n) in idx[a..b].iter().zip(&neg[a..b]) {
                    f(i as usize, if n { -s } else { *s });
                }
            }
            Message::DenseSign { scale: s, neg } => {
                for (j, &n) in neg[range.clone()].iter().enumerate() {
                    f(range.start + j, if n { -s } else { *s });
                }
            }
            Message::Qsgd { s, bucket, norms, post_scale, idx, levels, neg, .. } => {
                let unit0 = *post_scale / *s as f32;
                let bucket = (*bucket).max(1) as usize;
                match idx {
                    None => {
                        let span = range.clone();
                        for (j, (&l, &n)) in
                            levels[span.clone()].iter().zip(&neg[span]).enumerate()
                        {
                            if l != 0 {
                                let i = range.start + j;
                                let v = unit0 * norms[i / bucket] * l as f32;
                                f(i, if n { -v } else { v });
                            }
                        }
                    }
                    Some(idx) => {
                        let (a, b) = idx_span(idx, &range);
                        for (j, ((&i, &l), &n)) in
                            idx[a..b].iter().zip(&levels[a..b]).zip(&neg[a..b]).enumerate()
                        {
                            if l != 0 {
                                // norms are indexed by position in the
                                // transmitted list, not by coordinate.
                                let v = unit0 * norms[(a + j) / bucket] * l as f32;
                                f(i as usize, if n { -v } else { v });
                            }
                        }
                    }
                }
            }
        }
    }

    /// `out[i − range.start] += scale * C(x)[i]` for every `i ∈ range` —
    /// the range-restricted form of [`Message::add_into`] the sharded
    /// master fold is built on (`engine/parallel`). `out` is the chunk
    /// covering `range` (`out.len() == range.len()`).
    ///
    /// Per coordinate this performs the *same* f32 expression `add_into`
    /// evaluates (same value reconstruction, same `scale` multiply, same
    /// addition), so folding a partition of `0..d` chunk by chunk — each
    /// chunk processing messages in the same order — is bit-identical to
    /// one full `add_into` sequence.
    ///
    /// Like [`Message::add_into`], dense destinations and contiguous
    /// in-range index runs use the `crate::simd` fold kernels; everything
    /// else goes through the generic [`Message::for_each_nonzero_in`] walk.
    pub fn add_into_range(&self, out: &mut [f32], scale: f32, range: std::ops::Range<usize>) {
        debug_assert_eq!(out.len(), range.len());
        let lo = range.start;
        match self {
            Message::Dense { values } => {
                simd::add_scaled(out, &values[range], scale);
            }
            Message::DenseSign { scale: s, neg } => {
                simd::add_signed(out, &neg[range], *s, scale);
            }
            Message::SparseF32 { idx, vals, .. } => {
                let (a, b) = idx_span(idx, &range);
                if let Some(base) = contiguous_run(&idx[a..b]) {
                    simd::add_scaled(&mut out[base - lo..base - lo + (b - a)], &vals[a..b], scale);
                } else {
                    for (&i, &v) in idx[a..b].iter().zip(&vals[a..b]) {
                        out[i as usize - lo] += scale * v;
                    }
                }
            }
            Message::SparseSign { scale: s, idx, neg, .. } => {
                let (a, b) = idx_span(idx, &range);
                if let Some(base) = contiguous_run(&idx[a..b]) {
                    let run = &mut out[base - lo..base - lo + (b - a)];
                    simd::add_signed(run, &neg[a..b], *s, scale);
                } else {
                    for (&i, &n) in idx[a..b].iter().zip(&neg[a..b]) {
                        out[i as usize - lo] += scale * if n { -s } else { *s };
                    }
                }
            }
            Message::Qsgd { .. } => {
                self.for_each_nonzero_in(range, |i, v| out[i - lo] += scale * v);
            }
        }
    }
}

/// Half-open span `[a, b)` of the ascending index list `idx` whose values
/// fall in `range` (binary search at both ends).
fn idx_span(idx: &[u32], range: &std::ops::Range<usize>) -> (usize, usize) {
    let a = idx.partition_point(|&i| (i as usize) < range.start);
    let b = a + idx[a..].partition_point(|&i| (i as usize) < range.end);
    (a, b)
}

/// `Some(first)` iff the (strictly ascending) support is one contiguous run
/// `first..first + len` — the case where a sparse fold is really a dense
/// fold over a sub-slice and can take the vector kernel. O(1).
fn contiguous_run(idx: &[u32]) -> Option<usize> {
    match (idx.first(), idx.last()) {
        (Some(&f), Some(&l)) if (l - f) as usize == idx.len() - 1 => Some(f as usize),
        _ => None,
    }
}

/// Reusable storage for [`Compressor::compress_into`].
///
/// Holds the produced [`Message`] (whose internal vectors are recycled on
/// the next call when the operator produces the same variant) plus the
/// operator-side scratch (Top_k selection buffers, gathered sub-vectors).
/// After the first few calls with a fixed operator and dimension, a
/// `compress_into` through the same buffer performs no heap allocation —
/// the steady-state guarantee the engine's hot path relies on.
#[derive(Default)]
pub struct MessageBuf {
    /// The most recently produced message (empty `Dense` initially).
    pub(crate) msg: Message,
    /// Gathered sub-vector scratch (`QTopK`, `SignTopK`).
    pub(crate) vals: Vec<f32>,
    /// Top_k selection scratch.
    pub(crate) topk: sparsify::TopKScratch,
}

impl MessageBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// Borrow the message produced by the last `compress_into`.
    pub fn message(&self) -> &Message {
        &self.msg
    }

    /// Take ownership of the produced message (e.g. to send it across a
    /// thread boundary), leaving an empty placeholder behind. Pair with
    /// [`MessageBuf::recycle`] to return the capacity afterwards.
    pub fn take(&mut self) -> Message {
        std::mem::take(&mut self.msg)
    }

    /// Return a previously `take`n (and since consumed) message so its
    /// heap capacity is reused by the next `compress_into`.
    pub fn recycle(&mut self, msg: Message) {
        self.msg = msg;
    }

    /// Extract cleared `(idx, vals)` storage for a `SparseF32` message,
    /// reusing the previous message's buffers when the variant matches.
    pub(crate) fn take_sparse_f32(&mut self) -> (Vec<u32>, Vec<f32>) {
        match std::mem::take(&mut self.msg) {
            Message::SparseF32 { mut idx, mut vals, .. } => {
                idx.clear();
                vals.clear();
                (idx, vals)
            }
            _ => (Vec::new(), Vec::new()),
        }
    }

    /// Extract cleared `values` storage for a `Dense` message.
    pub(crate) fn take_dense(&mut self) -> Vec<f32> {
        match std::mem::take(&mut self.msg) {
            Message::Dense { mut values } => {
                values.clear();
                values
            }
            _ => Vec::new(),
        }
    }

    /// Extract cleared `(idx, neg)` storage for a `SparseSign` message.
    pub(crate) fn take_sparse_sign(&mut self) -> (Vec<u32>, Vec<bool>) {
        match std::mem::take(&mut self.msg) {
            Message::SparseSign { mut idx, mut neg, .. } => {
                idx.clear();
                neg.clear();
                (idx, neg)
            }
            _ => (Vec::new(), Vec::new()),
        }
    }

    /// Extract cleared `neg` storage for a `DenseSign` message.
    pub(crate) fn take_dense_sign(&mut self) -> Vec<bool> {
        match std::mem::take(&mut self.msg) {
            Message::DenseSign { mut neg, .. } => {
                neg.clear();
                neg
            }
            _ => Vec::new(),
        }
    }

    /// Extract cleared `(norms, idx, levels, neg)` storage for a `Qsgd`
    /// message (idx is empty for the dense quantizer).
    pub(crate) fn take_qsgd(&mut self) -> (Vec<f32>, Vec<u32>, Vec<u32>, Vec<bool>) {
        match std::mem::take(&mut self.msg) {
            Message::Qsgd { mut norms, idx, mut levels, mut neg, .. } => {
                let mut idx = idx.unwrap_or_default();
                norms.clear();
                idx.clear();
                levels.clear();
                neg.clear();
                (norms, idx, levels, neg)
            }
            _ => (Vec::new(), Vec::new(), Vec::new(), Vec::new()),
        }
    }
}

impl Default for Message {
    fn default() -> Self {
        Message::Dense { values: Vec::new() }
    }
}

/// A γ-compression operator (Definition 3).
pub trait Compressor: Send + Sync {
    /// Compress `x`. Stochastic operators draw from `rng`.
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message;

    /// Compress `x` into reusable storage. Semantically identical to
    /// `compress` (same RNG consumption, bit-identical message — property
    /// tested), but the built-in operators reuse `buf`'s vectors so the
    /// steady-state training loop performs no heap allocation here. The
    /// default implementation falls back to `compress` (allocating), so
    /// external operators stay source-compatible.
    fn compress_into(&self, x: &[f32], rng: &mut Pcg64, buf: &mut MessageBuf) {
        buf.msg = self.compress(x, rng);
    }

    /// Worst-case compression coefficient γ ∈ (0, 1] for dimension `d`
    /// (Lemmas 1–3). Used by theory-facing code and tests.
    fn gamma(&self, d: usize) -> f64;

    /// Human-readable name used in figure legends / CSV headers.
    fn name(&self) -> String;

    /// True for the identity operator. Drivers use this to pick the exact
    /// dense broadcast path on the downlink (copying the model bit-for-bit)
    /// instead of a delta encoding, which would differ in the last f32 ulp.
    fn is_identity(&self) -> bool {
        false
    }
}

/// Identity operator: no compression (vanilla / local SGD payloads).
#[derive(Clone, Debug)]
pub struct Identity;

impl Compressor for Identity {
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        compress_owned(self, x, rng)
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Pcg64, buf: &mut MessageBuf) {
        let mut values = buf.take_dense();
        values.extend_from_slice(x);
        buf.msg = Message::Dense { values };
    }

    fn gamma(&self, _d: usize) -> f64 {
        1.0
    }

    fn name(&self) -> String {
        "identity".to_string()
    }

    fn is_identity(&self) -> bool {
        true
    }
}

/// A `'static` identity operator, used as the default downlink compressor in
/// borrowing configs (`TrainSpec`).
pub static IDENTITY: Identity = Identity;

/// Shared body for the built-in operators' `compress`: the allocating form
/// is a thin wrapper over `compress_into` through a fresh buffer, so each
/// operator's arithmetic exists exactly once and the two APIs cannot drift.
pub(crate) fn compress_owned<C: Compressor + ?Sized>(
    op: &C,
    x: &[f32],
    rng: &mut Pcg64,
) -> Message {
    let mut buf = MessageBuf::new();
    op.compress_into(x, rng, &mut buf);
    buf.take()
}

/// Parse a compressor spec string, e.g.
/// `identity`, `topk:k=1000`, `randk:k=1000`, `qsgd:bits=4`,
/// `sign`, `qtopk:k=1000,bits=4[,scaled]`, `signtopk:k=1000[,m=2]`.
pub fn parse_spec(spec: &str) -> anyhow::Result<Box<dyn Compressor>> {
    let (head, rest) = match spec.split_once(':') {
        Some((h, r)) => (h, r),
        None => (spec, ""),
    };
    // BTreeMap/Set: `compress` is a deterministic-path module (repo-lint
    // bans RandomState-seeded collections here), and spec parsing feeds
    // error messages that must not depend on hash order.
    let mut kv = std::collections::BTreeMap::new();
    let mut flags = std::collections::BTreeSet::new();
    for part in rest.split(',').filter(|p| !p.is_empty()) {
        match part.split_once('=') {
            Some((k, v)) => {
                kv.insert(k.trim().to_string(), v.trim().to_string());
            }
            None => {
                flags.insert(part.trim().to_string());
            }
        }
    }
    let get_usize = |key: &str| -> anyhow::Result<usize> {
        kv.get(key)
            .ok_or_else(|| anyhow::anyhow!("compressor `{head}` requires `{key}=`"))?
            .parse::<usize>()
            .map_err(|e| anyhow::anyhow!("bad `{key}`: {e}"))
    };
    let bits = kv
        .get("bits")
        .map(|v| v.parse::<u32>())
        .transpose()
        .map_err(|e| anyhow::anyhow!("bad `bits`: {e}"))?;
    Ok(match head {
        "identity" | "none" | "sgd" => Box::new(Identity),
        "topk" => Box::new(TopK::new(get_usize("k")?)),
        "randk" => Box::new(RandK::new(get_usize("k")?)),
        "qsgd" => Box::new(match kv.get("bucket") {
            Some(b) => Qsgd::from_bits(bits.unwrap_or(4)).with_bucket(b.parse::<usize>()?),
            None => Qsgd::from_bits(bits.unwrap_or(4)),
        }),
        "sign" | "signsgd" => Box::new(SignDense::new()),
        "qtopk" => Box::new(QTopK::new(
            get_usize("k")?,
            Qsgd::from_bits(bits.unwrap_or(4)),
            flags.contains("scaled"),
        )),
        "qrandk" => Box::new(QTopK::new_rand(
            get_usize("k")?,
            Qsgd::from_bits(bits.unwrap_or(4)),
            flags.contains("scaled"),
        )),
        "signtopk" => Box::new(SignTopK::new(
            get_usize("k")?,
            kv.get("m").map(|v| v.parse::<u32>()).transpose()?.unwrap_or(1),
        )),
        other => anyhow::bail!("unknown compressor `{other}`"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip() {
        let x = vec![1.0f32, -2.0, 3.5];
        let mut rng = Pcg64::seeded(1);
        let m = Identity.compress(&x, &mut rng);
        assert_eq!(m.to_dense(), x);
        assert_eq!(Identity.gamma(3), 1.0);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn parse_specs() {
        for spec in [
            "identity",
            "topk:k=10",
            "randk:k=4",
            "qsgd:bits=2",
            "sign",
            "qtopk:k=8,bits=4",
            "qtopk:k=8,bits=4,scaled",
            "signtopk:k=8,m=2",
        ] {
            let c = parse_spec(spec).unwrap_or_else(|e| panic!("{spec}: {e}"));
            assert!(!c.name().is_empty());
        }
        assert!(parse_spec("topk").is_err());
        assert!(parse_spec("bogus:k=1").is_err());
    }

    /// The operator set exercised by the range-restricted traversal tests —
    /// one of every message variant, including clustered/sparse supports.
    fn range_test_ops() -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(Identity),
            Box::new(TopK::new(9)),
            Box::new(RandK::new(9)),
            Box::new(Qsgd::from_bits(2)),
            Box::new(SignDense::new()),
            Box::new(QTopK::new(9, Qsgd::from_bits(4), false)),
            Box::new(SignTopK::new(9, 1)),
        ]
    }

    #[test]
    fn add_into_range_partition_is_bit_identical_to_add_into() {
        let mut rng = Pcg64::seeded(91);
        let d = 97; // prime: chunk boundaries land mid-support
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for op in range_test_ops() {
            let m = op.compress(&x, &mut rng);
            for scale in [1.0f32, -0.125] {
                let mut whole = vec![0.25f32; d];
                m.add_into(&mut whole, scale);
                // Fold the same message chunk by chunk over several
                // partition granularities, including empty head/tail chunks.
                for nchunks in [1usize, 2, 3, 8, 97, 120] {
                    let mut parts = vec![0.25f32; d];
                    for c in 0..nchunks {
                        let lo = c * d / nchunks;
                        let hi = (c + 1) * d / nchunks;
                        m.add_into_range(&mut parts[lo..hi], scale, lo..hi);
                    }
                    for (i, (w, p)) in whole.iter().zip(&parts).enumerate() {
                        assert_eq!(
                            w.to_bits(),
                            p.to_bits(),
                            "{} scale={scale} nchunks={nchunks} i={i}",
                            op.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn for_each_nonzero_in_visits_exactly_the_add_into_set() {
        let mut rng = Pcg64::seeded(92);
        let d = 64;
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
        for op in range_test_ops() {
            let m = op.compress(&x, &mut rng);
            // Reconstruct via the visitor and compare with add_into(1.0)
            // from zero — both the values and the visited set must agree.
            let mut via_visit = vec![0.0f32; d];
            let mut last: isize = -1;
            m.for_each_nonzero_in(0..d, |i, v| {
                assert!(i as isize > last, "{}: indices not ascending", op.name());
                last = i as isize;
                via_visit[i] += v;
            });
            let mut via_add = vec![0.0f32; d];
            m.add_into(&mut via_add, 1.0);
            // add_into from zero and the visitor write the same values
            // (modulo +0/−0 on unvisited coords, which both leave at +0).
            for i in 0..d {
                assert_eq!(via_visit[i].to_bits(), via_add[i].to_bits(), "{} i={i}", op.name());
            }
            // Sub-range visits partition the full visit.
            let mut count_full = 0usize;
            m.for_each_nonzero_in(0..d, |_, _| count_full += 1);
            let mut count_split = 0usize;
            for (lo, hi) in [(0usize, 17usize), (17, 17), (17, 40), (40, d)] {
                m.for_each_nonzero_in(lo..hi, |i, _| {
                    assert!((lo..hi).contains(&i));
                    count_split += 1;
                });
            }
            assert_eq!(count_full, count_split, "{}", op.name());
        }
    }

    #[test]
    fn add_into_matches_to_dense() {
        let mut rng = Pcg64::seeded(2);
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let ops: Vec<Box<dyn Compressor>> = vec![
            Box::new(Identity),
            Box::new(TopK::new(7)),
            Box::new(RandK::new(7)),
            Box::new(Qsgd::from_bits(2)),
            Box::new(SignDense::new()),
            Box::new(QTopK::new(7, Qsgd::from_bits(4), false)),
            Box::new(SignTopK::new(7, 1)),
        ];
        for op in ops {
            let m = op.compress(&x, &mut rng);
            let dense = m.to_dense();
            let mut acc = vec![1.0f32; x.len()];
            m.add_into(&mut acc, 2.0);
            for (a, d) in acc.iter().zip(&dense) {
                assert!((a - (1.0 + 2.0 * d)).abs() < 1e-6, "{}", op.name());
            }
        }
    }
}
