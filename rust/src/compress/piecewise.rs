//! Piecewise (per-layer) compression — Corollary 1.
//!
//! Applies possibly different compression operators to disjoint coordinate
//! ranges of the update vector (e.g. one Top_k per tensor, as the paper's
//! ResNet-50 experiment does with k_t = min(d_t, 1000) per tensor). The
//! result is a compression operator with γ = min_i γ_i.

use super::{Compressor, Message};
use crate::util::rng::Pcg64;

/// One segment: coordinates [start, start+len) compressed by `op`.
pub struct Segment {
    pub start: usize,
    pub len: usize,
    pub op: Box<dyn Compressor>,
}

/// Per-segment composition (Corollary 1).
pub struct Piecewise {
    segments: Vec<Segment>,
    d: usize,
}

impl Piecewise {
    /// Build from contiguous segments; they must tile [0, d) in order.
    pub fn new(segments: Vec<Segment>) -> anyhow::Result<Self> {
        let mut expect = 0usize;
        for s in &segments {
            anyhow::ensure!(
                s.start == expect,
                "segments must tile the vector: expected start {expect}, got {}",
                s.start
            );
            anyhow::ensure!(s.len > 0, "empty segment");
            expect = s.start + s.len;
        }
        Ok(Piecewise { segments, d: expect })
    }

    /// Convenience: split [0, d) into `layer_sizes` and apply `mk(layer_len)`
    /// to each layer — mirrors the paper's per-tensor Top_{min(d_t, 1000)}.
    pub fn per_layer(
        layer_sizes: &[usize],
        mk: impl Fn(usize) -> Box<dyn Compressor>,
    ) -> anyhow::Result<Self> {
        let mut segments = Vec::with_capacity(layer_sizes.len());
        let mut start = 0;
        for &len in layer_sizes {
            segments.push(Segment { start, len, op: mk(len) });
            start += len;
        }
        Piecewise::new(segments)
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Compress each segment and return the per-segment messages. The engine
    /// treats the collection as one logical update; total wire cost is the
    /// sum of segment costs.
    pub fn compress_segments(&self, x: &[f32], rng: &mut Pcg64) -> Vec<Message> {
        assert_eq!(x.len(), self.d, "piecewise dimension mismatch");
        self.segments
            .iter()
            .map(|s| s.op.compress(&x[s.start..s.start + s.len], rng))
            .collect()
    }

    /// Reassemble the dense update from per-segment messages.
    pub fn to_dense(&self, msgs: &[Message]) -> Vec<f32> {
        let mut out = vec![0.0f32; self.d];
        self.add_into(msgs, &mut out, 1.0);
        out
    }

    /// `out += scale * C(x)` from per-segment messages.
    pub fn add_into(&self, msgs: &[Message], out: &mut [f32], scale: f32) {
        assert_eq!(msgs.len(), self.segments.len());
        for (s, m) in self.segments.iter().zip(msgs) {
            m.add_into(&mut out[s.start..s.start + s.len], scale);
        }
    }

    /// Total wire bits across segments.
    pub fn wire_bits(&self, msgs: &[Message]) -> u64 {
        msgs.iter().map(|m| m.wire_bits()).sum()
    }
}

impl Compressor for Piecewise {
    /// As a plain `Compressor`, a piecewise operator produces one fused
    /// sparse message (the engine's generic path); `compress_segments` is the
    /// layer-aware path used when per-layer bit accounting matters.
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        let msgs = self.compress_segments(x, rng);
        // Fuse into one SparseF32 over the global index space. This preserves
        // to_dense() semantics; wire cost is taken from the segment encodings
        // (the fused view is only a mathematical convenience, so we keep the
        // honest per-segment costs in `wire_bits` via the engine).
        let dense = self.to_dense(&msgs);
        let mut idx = Vec::new();
        let mut vals = Vec::new();
        for (i, &v) in dense.iter().enumerate() {
            if v != 0.0 {
                idx.push(i as u32);
                vals.push(v);
            }
        }
        Message::SparseF32 { d: self.d, idx, vals }
    }

    fn gamma(&self, _d: usize) -> f64 {
        // Corollary 1: γ = min_i γ_i, each γ_i evaluated at its segment size.
        self.segments
            .iter()
            .map(|s| s.op.gamma(s.len))
            .fold(1.0, f64::min)
    }

    fn name(&self) -> String {
        format!("piecewise({} segs)", self.segments.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Qsgd, SignTopK, TopK};
    use crate::util::stats::norm2_sq;

    #[test]
    fn tiles_must_be_contiguous() {
        let bad = Piecewise::new(vec![
            Segment { start: 0, len: 4, op: Box::new(TopK::new(2)) },
            Segment { start: 5, len: 4, op: Box::new(TopK::new(2)) },
        ]);
        assert!(bad.is_err());
    }

    #[test]
    fn per_layer_topk_matches_manual() {
        let mut rng = crate::util::rng::Pcg64::seeded(41);
        let x: Vec<f32> = (0..24).map(|_| rng.normal_f32()).collect();
        let pw = Piecewise::per_layer(&[8, 16], |len| Box::new(TopK::new(len.min(3)))).unwrap();
        let msgs = pw.compress_segments(&x, &mut rng);
        assert_eq!(msgs.len(), 2);
        let dense = pw.to_dense(&msgs);
        assert_eq!(dense.len(), 24);
        let nnz = dense.iter().filter(|v| **v != 0.0).count();
        assert!(nnz <= 6);
        // Each segment's support is the segment's own top-3.
        let seg1 = crate::compress::sparsify::top_k_indices(&x[..8], 3);
        for &i in &seg1 {
            assert_eq!(dense[i as usize], x[i as usize]);
        }
    }

    #[test]
    fn gamma_is_min_over_segments() {
        let pw = Piecewise::per_layer(&[100, 1000], |_| Box::new(TopK::new(10))).unwrap();
        assert!((pw.gamma(0) - 10.0 / 1000.0).abs() < 1e-12);
    }

    #[test]
    fn compression_property_piecewise() {
        // Corollary 1: E‖x − C(x)‖² ≤ (1 − min γ_i)‖x‖².
        let mut rng = crate::util::rng::Pcg64::seeded(42);
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let pw = Piecewise::new(vec![
            Segment { start: 0, len: 32, op: Box::new(TopK::new(8)) },
            Segment { start: 32, len: 16, op: Box::new(SignTopK::new(4, 1)) },
            Segment { start: 48, len: 16, op: Box::new(Qsgd::from_bits(3)) },
        ])
        .unwrap();
        let gamma = pw.gamma(64);
        let trials = 3000;
        let mut acc = 0.0;
        for _ in 0..trials {
            let msgs = pw.compress_segments(&x, &mut rng);
            let dense = pw.to_dense(&msgs);
            let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
            acc += norm2_sq(&resid);
        }
        assert!(acc / trials as f64 <= (1.0 - gamma) * norm2_sq(&x) * 1.03);
    }
}
