//! Error-feedback memory (paper §3.2).
//!
//! Each worker keeps m_t ∈ R^d accumulating what compression dropped:
//!
//!   v_t      = m_t + (x_sync − x_local)          (error-compensated update)
//!   g_t      = QComp_k(v_t)                       (transmitted)
//!   m_{t+1}  = v_t − g_t                          (new memory)
//!
//! Lemma 5 bounds E‖m_t‖² ≤ 4 η²(1−γ²)/γ² H²G² for fixed η; Lemma 4 shows
//! O(η_t²) contraction for decaying η. Both are validated in tests against
//! this implementation.

use super::{Compressor, Message, MessageBuf};
use crate::util::rng::Pcg64;
use crate::util::stats::norm2_sq;

/// Per-worker error-feedback state.
#[derive(Clone, Debug)]
pub struct ErrorMemory {
    m: Vec<f32>,
    /// Scratch buffer for v_t = m + delta (avoids reallocating per sync).
    scratch: Vec<f32>,
}

impl ErrorMemory {
    pub fn zeros(d: usize) -> Self {
        ErrorMemory { m: vec![0.0; d], scratch: vec![0.0; d] }
    }

    pub fn dim(&self) -> usize {
        self.m.len()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.m
    }

    /// ‖m‖² — used by metrics and the Lemma 4/5 validation tests.
    pub fn norm_sq(&self) -> f64 {
        norm2_sq(&self.m)
    }

    /// One synchronization round: given the net local progress
    /// `delta = x_sync − x_{t+1/2}` (Algorithm 1 line 8), produce the
    /// compressed message and update the memory in place. Allocating
    /// wrapper around [`ErrorMemory::compress_update_into`].
    pub fn compress_update(
        &mut self,
        delta: &[f32],
        op: &dyn Compressor,
        rng: &mut Pcg64,
    ) -> Message {
        let mut buf = MessageBuf::new();
        self.compress_update_into(delta, op, rng, &mut buf);
        buf.take()
    }

    /// As `compress_update`, producing the message into reusable storage —
    /// the engine's allocation-free hot path (identical arithmetic and RNG
    /// consumption).
    pub fn compress_update_into(
        &mut self,
        delta: &[f32],
        op: &dyn Compressor,
        rng: &mut Pcg64,
        buf: &mut MessageBuf,
    ) {
        assert_eq!(delta.len(), self.m.len(), "memory dimension mismatch");
        // v = m + delta
        for (s, (m, d)) in self.scratch.iter_mut().zip(self.m.iter().zip(delta)) {
            *s = *m + *d;
        }
        op.compress_into(&self.scratch, rng, buf);
        // m' = v − g : copy v into m, then subtract the reconstruction.
        self.m.copy_from_slice(&self.scratch);
        buf.message().add_into(&mut self.m, -1.0);
    }

    /// Reset (used when a run reuses worker state).
    pub fn clear(&mut self) {
        self.m.iter_mut().for_each(|v| *v = 0.0);
    }

    /// Fold a *sent but lost* compressed message back into the memory:
    /// `m ← m + g`. With the update recursion `m' = v − g` this restores
    /// `m' + g = v = m + Δ` — exactly the pre-compression state, as if the
    /// round had used the identity "send nothing" compressor. The fault-
    /// tolerant drivers call this when the uplink carrying `g` was dropped
    /// or corrupted, so the lost signal re-enters the very next update.
    pub fn absorb(&mut self, msg: &Message) {
        assert_eq!(msg.dim(), self.m.len(), "absorb dimension mismatch");
        msg.add_into(&mut self.m, 1.0);
    }

    /// Restore the memory vector from a checkpoint. The caller validates
    /// the length first (`protocol::checkpoint` rejects mismatches as a
    /// structured error before getting here).
    pub fn load(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.m.len(), "memory dimension mismatch");
        self.m.copy_from_slice(src);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};

    #[test]
    fn identity_compressor_leaves_no_memory() {
        let mut mem = ErrorMemory::zeros(8);
        let mut rng = Pcg64::seeded(50);
        let delta: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let msg = mem.compress_update(&delta, &Identity, &mut rng);
        assert_eq!(msg.to_dense(), delta);
        assert!(mem.norm_sq() < 1e-12);
    }

    #[test]
    fn memory_accumulates_dropped_coordinates() {
        let mut mem = ErrorMemory::zeros(4);
        let mut rng = Pcg64::seeded(51);
        let op = TopK::new(1);
        // Round 1: delta = [10, 1, 2, 3] → send [10,0,0,0], keep [0,1,2,3].
        let m1 = mem.compress_update(&[10.0, 1.0, 2.0, 3.0], &op, &mut rng);
        assert_eq!(m1.to_dense(), vec![10.0, 0.0, 0.0, 0.0]);
        assert_eq!(mem.as_slice(), &[0.0, 1.0, 2.0, 3.0]);
        // Round 2: delta = [0,0,0,0] → v = memory → send [0,0,0,3].
        let m2 = mem.compress_update(&[0.0; 4], &op, &mut rng);
        assert_eq!(m2.to_dense(), vec![0.0, 0.0, 0.0, 3.0]);
        assert_eq!(mem.as_slice(), &[0.0, 1.0, 2.0, 0.0]);
        // Every coordinate is eventually transmitted (error compensation).
        let m3 = mem.compress_update(&[0.0; 4], &op, &mut rng);
        let m4 = mem.compress_update(&[0.0; 4], &op, &mut rng);
        let mut total = vec![0.0f32; 4];
        for m in [&m1, &m2, &m3, &m4] {
            m.add_into(&mut total, 1.0);
        }
        assert_eq!(total, vec![10.0, 1.0, 2.0, 3.0]);
        assert!(mem.norm_sq() < 1e-12);
    }

    #[test]
    fn absorbing_a_lost_message_restores_the_ledger() {
        let mut mem = ErrorMemory::zeros(4);
        let mut rng = Pcg64::seeded(53);
        let op = TopK::new(1);
        let delta = [10.0f32, 1.0, 2.0, 3.0];
        let g = mem.compress_update(&delta, &op, &mut rng);
        assert_eq!(g.to_dense(), vec![10.0, 0.0, 0.0, 0.0]);
        // Uplink lost: m ← m + g recovers v = m_prev + Δ — the full
        // pre-compression signal is back in the ledger.
        mem.absorb(&g);
        assert_eq!(mem.as_slice(), &delta);
        // The next round re-sends the strongest lost coordinate first.
        let g2 = mem.compress_update(&[0.0; 4], &op, &mut rng);
        assert_eq!(g2.to_dense(), vec![10.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn memory_norm_contracts_with_decaying_updates() {
        // Feed deltas of norm η_t·G with η_t = 1/(a+t); memory should track
        // O(η_t²) (Lemma 4 flavor, single worker).
        let d = 256;
        let mut mem = ErrorMemory::zeros(d);
        let mut rng = Pcg64::seeded(52);
        let op = TopK::new(16); // γ = 1/16
        let a = 200.0;
        let mut worst_ratio = 0.0f64;
        for t in 0..400 {
            let eta = 1.0 / (a + t as f64);
            let delta: Vec<f32> = (0..d).map(|_| rng.normal_f32() * eta as f32).collect();
            mem.compress_update(&delta, &op, &mut rng);
            if t > 50 {
                worst_ratio = worst_ratio.max(mem.norm_sq() / (eta * eta));
            }
        }
        // The ratio must stay bounded (not grow with t): check final vs early.
        let eta_end = 1.0 / (a + 399.0);
        assert!(
            mem.norm_sq() <= worst_ratio * eta_end * eta_end * 1.5 + 1e-9,
            "memory did not contract: ‖m‖²={} bound={}",
            mem.norm_sq(),
            worst_ratio * eta_end * eta_end
        );
    }
}
