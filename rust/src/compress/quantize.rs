//! Quantization operators (paper §2.1).
//!
//! * `Qsgd` — the stochastic s-level quantizer of Alistarh et al. (QSGD,
//!   Definition 1 example 1): unbiased, second-moment blow-up
//!   β_{d,s} = min(d/s², √d/s).
//! * `SignDense` — the deterministic scaled sign quantizer (Definition 2),
//!   transmitted as `(‖x‖₁/d) · Sign(x)` as in EF-SignSGD [KRSJ19], which
//!   makes it a compression operator with data-dependent γ ≥ 1/d.

use super::{Compressor, Message, MessageBuf};
use crate::util::rng::Pcg64;
use crate::util::stats::norm1;

/// QSGD stochastic quantizer with `s` positive levels (s = 2^bits − 1) and
/// bucketing (AGL+17 §3.3): the input is quantized in contiguous buckets of
/// `bucket` coordinates, each with its own ℓ2 norm scale.
///
/// For v ≠ 0 (per bucket): Q(v)_i = ‖v‖₂ · sign(v_i) · ξ_i(v)/s where
/// ξ_i ∈ {0, 1, …, s} with E[ξ_i] = s·|v_i|/‖v‖₂ — unbiased
/// (Definition 1(i)) with E‖Q(v)‖² ≤ (1 + β_{B,s})‖v‖² (Definition 1(ii)),
/// where B is the bucket size — bucketing is exactly how QSGD keeps β < 1
/// for coarse quantizers on high-dimensional vectors.
#[derive(Clone, Debug)]
pub struct Qsgd {
    pub s: u32,
    /// Bucket size B (coordinates per ℓ2-norm scale).
    pub bucket: usize,
}

impl Qsgd {
    pub fn new(s: u32) -> Self {
        assert!(s >= 1, "QSGD needs at least one level");
        let bucket = Self::default_bucket(s);
        Qsgd { s, bucket }
    }

    /// Construct from a bit budget: s = 2^bits − 1 levels (paper §5.2.3:
    /// “s = 2^{#bits} − 1”).
    pub fn from_bits(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "bits must be in 1..=16");
        Qsgd::new((1u32 << bits) - 1)
    }

    pub fn with_bucket(mut self, bucket: usize) -> Self {
        assert!(bucket >= 1);
        self.bucket = bucket;
        self
    }

    /// Largest power of two B with β_{B,s} ≤ 0.8 (so the operator stays in
    /// Lemma 1's operating regime), clamped to [4, 512].
    fn default_bucket(s: u32) -> usize {
        let s = s as f64;
        let mut b = 4usize;
        while b < 512 {
            let nb = b * 2;
            let beta = ((nb as f64) / (s * s)).min((nb as f64).sqrt() / s);
            if beta > 0.8 {
                break;
            }
            b = nb;
        }
        b
    }

    /// Legend label for the level count: `"{b}bit"` iff s = 2^b − 1 (the
    /// exact `from_bits` inverse), otherwise the explicit `"s=N"`. The old
    /// `32 − s.leading_zeros()` derivation mislabeled every non-2^b−1 level
    /// count (e.g. s = 4 printed as "3bit", which round-trips to s = 7).
    pub fn level_label(&self) -> String {
        if self.s.wrapping_add(1).is_power_of_two() {
            format!("{}bit", (self.s + 1).trailing_zeros())
        } else {
            format!("s={}", self.s)
        }
    }

    /// Variance blow-up β = min(B/s², √B/s) at the effective bucket size
    /// B = min(d, bucket) [AGL+17].
    pub fn beta(&self, d: usize) -> f64 {
        let b = d.min(self.bucket) as f64;
        let s = self.s as f64;
        (b / (s * s)).min(b.sqrt() / s)
    }

    /// Quantize `vals` bucket-by-bucket; returns (norms, levels, neg).
    /// Shared by the dense operator and `QTop_k`. Allocating wrapper around
    /// [`Qsgd::quantize_values_into`].
    pub fn quantize_values(
        &self,
        vals: &[f32],
        rng: &mut Pcg64,
    ) -> (Vec<f32>, Vec<u32>, Vec<bool>) {
        let mut norms = Vec::new();
        let mut levels = Vec::new();
        let mut neg = Vec::new();
        self.quantize_values_into(vals, rng, &mut norms, &mut levels, &mut neg);
        (norms, levels, neg)
    }

    /// As `quantize_values`, appending into caller-provided (cleared)
    /// buffers — the allocation-free hot-path variant. RNG consumption and
    /// outputs are bit-identical to the wrapper.
    ///
    /// Both the bucket-norm pass and the per-element level/sign pass are
    /// `crate::simd` kernels (§Perf iteration 8). The norm uses the fixed
    /// stride-4 chunked f64 reduction (`simd::norm2_sq_chunked`) so every
    /// backend performs the identical addition sequence — deterministic,
    /// but intentionally *not* equal to the old sequential `norm2` sum, so
    /// seeded QSGD trajectories differ from pre-SIMD releases. The level
    /// kernel consumes one `rng.f32()` per element in element order on
    /// every backend, keeping the stochastic-rounding stream in lockstep.
    pub fn quantize_values_into(
        &self,
        vals: &[f32],
        rng: &mut Pcg64,
        norms: &mut Vec<f32>,
        levels: &mut Vec<u32>,
        neg: &mut Vec<bool>,
    ) {
        norms.clear();
        levels.clear();
        neg.clear();
        norms.reserve(vals.len().div_ceil(self.bucket.max(1)));
        levels.reserve(vals.len());
        neg.reserve(vals.len());
        let s = self.s as f32;
        for chunk in vals.chunks(self.bucket.max(1)) {
            let norm = crate::simd::norm2_sq_chunked(chunk).sqrt() as f32;
            norms.push(norm);
            if norm == 0.0 {
                levels.extend(std::iter::repeat(0).take(chunk.len()));
                neg.extend(std::iter::repeat(false).take(chunk.len()));
                continue;
            }
            // §Perf iteration 3: one division per bucket instead of one per
            // coordinate (the inner kernel is then mul/floor/cmp only).
            let inv = s / norm;
            crate::simd::quantize_bucket_into(chunk, inv, self.s, rng, levels, neg);
        }
    }
}

impl Compressor for Qsgd {
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        super::compress_owned(self, x, rng)
    }

    fn compress_into(&self, x: &[f32], rng: &mut Pcg64, buf: &mut MessageBuf) {
        let (mut norms, _idx, mut levels, mut neg) = buf.take_qsgd();
        self.quantize_values_into(x, rng, &mut norms, &mut levels, &mut neg);
        buf.msg = Message::Qsgd {
            d: x.len(),
            s: self.s,
            bucket: self.bucket as u32,
            norms,
            post_scale: 1.0,
            idx: None,
            levels,
            neg,
        };
    }

    fn gamma(&self, d: usize) -> f64 {
        // Definition 3 holds for a stochastic quantizer when β < 1, with
        // γ = 1 − β (from E‖x − Q(x)‖² = E‖Q(x)‖² − ‖x‖² ≤ β‖x‖²).
        (1.0 - self.beta(d)).max(0.0)
    }

    fn name(&self) -> String {
        format!("qsgd({},B={})", self.level_label(), self.bucket)
    }
}

/// Scaled deterministic sign operator: C(x) = (‖x‖₁/d) · Sign(x).
///
/// This is the EF-SignSGD [KRSJ19] update; a compression operator with
/// γ(x) = ‖x‖₁² / (d‖x‖₂²) ∈ [1/d, 1].
#[derive(Clone, Debug, Default)]
pub struct SignDense;

impl SignDense {
    pub fn new() -> Self {
        SignDense
    }
}

impl Compressor for SignDense {
    fn compress(&self, x: &[f32], rng: &mut Pcg64) -> Message {
        super::compress_owned(self, x, rng)
    }

    fn compress_into(&self, x: &[f32], _rng: &mut Pcg64, buf: &mut MessageBuf) {
        let mut neg = buf.take_dense_sign();
        let scale = (norm1(x) / x.len().max(1) as f64) as f32;
        neg.extend(x.iter().map(|&v| v < 0.0));
        buf.msg = Message::DenseSign { scale, neg };
    }

    fn gamma(&self, d: usize) -> f64 {
        // Worst case over x (x = e_i): ‖x‖₁²/(d‖x‖₂²) = 1/d.
        1.0 / d.max(1) as f64
    }

    fn name(&self) -> String {
        "signsgd".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{norm2, norm2_sq};

    #[test]
    fn qsgd_is_unbiased() {
        // E[Q(x)] = x: average many draws.
        let mut rng = Pcg64::seeded(10);
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let q = Qsgd::from_bits(2); // coarse: 3 levels
        let trials = 20_000;
        let mut mean = vec![0.0f64; x.len()];
        for _ in 0..trials {
            let dense = q.compress(&x, &mut rng).to_dense();
            for (m, v) in mean.iter_mut().zip(&dense) {
                *m += *v as f64;
            }
        }
        let nrm = norm2(&x);
        for (i, m) in mean.iter().enumerate() {
            let avg = m / trials as f64;
            assert!(
                (avg - x[i] as f64).abs() < 0.03 * nrm,
                "coord {i}: E[Q]={avg} x={}",
                x[i]
            );
        }
    }

    #[test]
    fn qsgd_second_moment_bound() {
        // E‖Q(x)‖² ≤ (1 + β)‖x‖².
        let mut rng = Pcg64::seeded(11);
        for &bits in &[2u32, 4, 8] {
            let q = Qsgd::from_bits(bits);
            let d = 64;
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let bound = (1.0 + q.beta(d)) * norm2_sq(&x);
            let trials = 3000;
            let mut acc = 0.0;
            for _ in 0..trials {
                acc += norm2_sq(&q.compress(&x, &mut rng).to_dense());
            }
            let mean = acc / trials as f64;
            assert!(
                mean <= bound * 1.05,
                "bits={bits}: E‖Q‖²={mean} > (1+β)‖x‖²={bound}"
            );
        }
    }

    #[test]
    fn qsgd_levels_within_range_and_zero_vector() {
        let mut rng = Pcg64::seeded(12);
        let q = Qsgd::from_bits(4);
        let zeros = vec![0.0f32; 8];
        let m = q.compress(&zeros, &mut rng);
        assert_eq!(m.to_dense(), zeros);
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32() * 10.0).collect();
        if let Message::Qsgd { levels, s, .. } = q.compress(&x, &mut rng) {
            assert!(levels.iter().all(|&l| l <= s));
        } else {
            panic!("wrong message type");
        }
    }

    #[test]
    fn sign_dense_value_and_gamma() {
        let x = vec![2.0f32, -1.0, 0.5, -0.5];
        let mut rng = Pcg64::seeded(13);
        let m = SignDense::new().compress(&x, &mut rng);
        let dense = m.to_dense();
        let scale = 4.0 / 4.0; // ‖x‖₁/d = 1
        assert_eq!(dense, vec![scale, -scale, scale, -scale]);
        // compression property with data-dependent γ:
        let resid: Vec<f32> = x.iter().zip(&dense).map(|(a, b)| a - b).collect();
        let gamma = norm1(&x).powi(2) / (4.0 * norm2_sq(&x));
        assert!(norm2_sq(&resid) <= (1.0 - gamma) * norm2_sq(&x) + 1e-9);
    }

    #[test]
    fn qsgd_name_reports_exact_levels() {
        // s = 2^b − 1 keeps the familiar bit-width label…
        assert!(Qsgd::from_bits(4).name().contains("4bit")); // s = 15
        assert!(Qsgd::from_bits(2).name().contains("2bit")); // s = 3
        assert!(Qsgd::from_bits(1).name().contains("1bit")); // s = 1
        // …but a non-2^b−1 level count is reported exactly, not rounded to a
        // bit width it does not have (s = 4 used to print "3bit" ⇒ s = 7).
        let odd = Qsgd::new(4);
        assert!(odd.name().contains("s=4"), "{}", odd.name());
        assert!(!odd.name().contains("bit"), "{}", odd.name());
    }

    #[test]
    fn beta_matches_formula_and_buckets() {
        let q = Qsgd::new(15).with_bucket(100);
        assert!((q.beta(1000) - (100.0f64 / 225.0).min(10.0 / 15.0)).abs() < 1e-12);
        // Default buckets keep β < 1 for every practical bit width (a 1-bit
        // *stochastic* quantizer has β ≥ 1 at any bucket size — use the
        // scaled operator of Lemma 2 or the deterministic Sign for 1 bit).
        for bits in [2u32, 4, 8] {
            let q = Qsgd::from_bits(bits);
            assert!(q.beta(1 << 20) < 1.0, "bits={bits} β={}", q.beta(1 << 20));
        }
    }
}
